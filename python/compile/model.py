"""L2: the paper's compute graph in JAX, calling the L1 Pallas kernels.

Every public function here is lowered once per Config by aot.py to HLO text
and executed from the Rust coordinator via PJRT.  Python never runs on the
request path.

theta packing convention (shared with Rust): theta = [ell_1..ell_d, sigf, sigma],
all raw positive values (the softplus reparameterisation lives in the Rust
optimiser, L3).
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.grad import grad_quad_kernel
from .kernels.kmv import kmv


def unpack(theta, d):
    """Split packed hyperparameters into (ell [d], sigf, sigma)."""
    return theta[:d], theta[d], theta[d + 1]


# ---------------------------------------------------------------------------
# Solver products (hot path)
# ---------------------------------------------------------------------------


def kmv_full(x, v, theta, *, tile, family):
    """H @ V = K(X,X) @ V + sigma^2 V   for the CG full-batch iteration."""
    d = x.shape[1]
    ell, sigf, sign = unpack(theta, d)
    xs = x / ell
    kv = kmv(xs, xs, v, sigf * sigf, tile_m=tile, tile_n=tile, family=family)
    return kv + (sign * sign) * v


def kmv_full_ref(x, v, theta, *, family):
    """Pure-jnp variant of kmv_full (perf-ablation artifact, no pallas)."""
    return ref.hv_ref(x, v, theta, family)


def kmv_cols(x, xb, u, theta, *, tile, tile_b, family):
    """K(X, X_I) @ U  for the AP residual downdate (noise handled in L3)."""
    d = x.shape[1]
    ell, sigf, _ = unpack(theta, d)
    return kmv(x / ell, xb / ell, u, sigf * sigf, tile_m=tile, tile_n=tile_b, family=family)


def kmv_rows(xa, x, v, theta, *, tile, tile_b, family):
    """K(X_I, X) @ V  for the SGD minibatch gradient (noise handled in L3)."""
    d = x.shape[1]
    ell, sigf, _ = unpack(theta, d)
    return kmv(xa / ell, x / ell, v, sigf * sigf, tile_m=tile_b, tile_n=tile, family=family)


# ---------------------------------------------------------------------------
# Gradient estimator (standard & pathwise share this primitive)
# ---------------------------------------------------------------------------


def grad_quad(x, a, b, w, theta, *, tile, family):
    """d/dtheta of  sum_j w_j a_j^T H(theta) b_j,  all d+2 components.

    The d+1 kernel components come from the fused Pallas kernel (single
    sweep over the n^2 tile space); the noise component is the cheap
    closed form  2 sigma sum_j w_j <a_j, b_j>.
    """
    d = x.shape[1]
    ell, sigf, sign = unpack(theta, d)
    xs = x / ell
    a_w = a * w[None, :]
    g_kern = grad_quad_kernel(xs, a_w, b, ell, sigf * sigf, tile=tile, family=family)
    g_noise = 2.0 * sign * jnp.sum(w * jnp.sum(a * b, axis=0))
    return jnp.concatenate([g_kern, g_noise[None]])


# ---------------------------------------------------------------------------
# Pathwise machinery: RFF prior samples and pathwise-conditioned predictions
# ---------------------------------------------------------------------------


def _rff_features(x, omega0, ell, sigf, m):
    """Random Fourier features Phi [n, 2m] for the stationary kernel.

    omega0 holds *base* frequencies (sampled once in Rust from the kernel's
    spectral density at unit lengthscale); the current lengthscales enter
    as omega = omega0 / ell, which is what keeps the prior-function sample
    "the same function" as theta moves (Appendix B of the paper).
    """
    z = (x / ell) @ omega0  # [n, m]
    scale = sigf * jnp.sqrt(1.0 / m)
    return scale * jnp.concatenate([jnp.cos(z), jnp.sin(z)], axis=1)


def rff_eval(x, omega0, wts, noise, theta):
    """Pathwise probe targets  Xi = f(X) + sigma * w_noise   [n, s].

    f ~ GP(0, K) approximated with RFF: f(X) = Phi(X) @ wts, wts ~ N(0, I).
    noise is a fixed standard-normal matrix (the eps = sigma*w
    reparameterisation required by warm starting).
    """
    d = x.shape[1]
    m = omega0.shape[1]
    ell, sigf, sign = unpack(theta, d)
    phi = _rff_features(x, omega0, ell, sigf, m)
    return phi @ wts + sign * noise


def predict(xt, x, theta, vy, zhat, omega0, wts, *, tile, tile_t, family):
    """Pathwise-conditioned predictions (eq. 16 of the paper).

    mean      = K(X*, X) v_y                                    [t]
    sample_j  = f_j(X*) + K(X*, X) (v_y - zhat_j)               [t, s]

    One rectangular Pallas product serves the mean and all samples: the RHS
    batch is [v_y | v_y - zhat_1 | ... | v_y - zhat_s].
    """
    d = x.shape[1]
    m = omega0.shape[1]
    ell, sigf, _ = unpack(theta, d)
    u = jnp.concatenate([vy[:, None], vy[:, None] - zhat], axis=1)  # [n, s+1]
    kx = kmv(xt / ell, x / ell, u, sigf * sigf, tile_m=tile_t, tile_n=tile, family=family)
    mean = kx[:, 0]
    phi_t = _rff_features(xt, omega0, ell, sigf, m)
    samples = phi_t @ wts + kx[:, 1:]
    return mean, samples


# ---------------------------------------------------------------------------
# Exact Cholesky baseline (small n): value + gradient of the exact MLL
# ---------------------------------------------------------------------------


def exact_mll(x, y, theta, *, family):
    """(L(theta), dL/dtheta) via Cholesky + autodiff. O(n^3); small n only."""
    val, g = jax.value_and_grad(lambda th: ref.mll_ref(x, y, th, family))(theta)
    return val, g
