"""L1 perf tool: sweep Pallas tile sizes for the kmv kernel and report
wall-clock (CPU interpret — structure signal only, NOT a TPU proxy) plus
the VMEM footprint estimate per DESIGN.md §7 that *is* the TPU signal.

Usage:
    python -m compile.tile_sweep [--n 1024] [--d 26] [--k 17]
"""

import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from .kernels.kmv import kmv  # noqa: E402
from .kernels import ref  # noqa: E402


def vmem_floats(tile_m, tile_n, d, k):
    """VMEM-resident floats per grid step (DESIGN.md §7): two input slabs,
    RHS slab, output block and the distance scratch tile."""
    return tile_m * d + tile_n * d + tile_n * k + tile_m * k + tile_m * tile_n


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--d", type=int, default=26)
    ap.add_argument("--k", type=int, default=17)
    ap.add_argument("--reps", type=int, default=5)
    args = ap.parse_args()
    n, d, k = args.n, args.d, args.k

    rng = np.random.default_rng(0)
    x = rng.standard_normal((n, d))
    v = rng.standard_normal((n, k))
    want = np.asarray(ref.kmv_ref(x, x, v, np.ones(d), 1.0))

    print(f"n={n} d={d} k={k}  (f64; interpret=True wallclock is structural only)")
    print(f"{'tile':>6} {'wall (ms)':>10} {'VMEM/step':>12} {'grid':>8} {'max err':>10}")
    for tile in [32, 64, 128, 256]:
        if n % tile != 0:
            continue
        f = jax.jit(lambda xs, vs: kmv(xs, xs, vs, 1.0, tile_m=tile, tile_n=tile))
        out = f(x, v)
        out.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(args.reps):
            out = f(x, v)
            out.block_until_ready()
        wall = (time.perf_counter() - t0) / args.reps * 1e3
        err = float(np.abs(np.asarray(out) - want).max())
        floats = vmem_floats(tile, tile, d, k)
        grid = (n // tile) ** 2
        # f32 bytes on real TPU (we lower f64 on CPU; production would be f32/bf16)
        print(f"{tile:>6} {wall:>10.2f} {floats * 4 / 1024:>9.0f}KiB {grid:>8} {err:>10.2e}")


if __name__ == "__main__":
    main()
