"""Static-shape configuration registry shared between the AOT compile path
(aot.py) and the Rust coordinator (via artifacts/<name>/meta.txt).

Every artifact is lowered for exactly one Config, so all shapes are static.
Dataset-shaped configs mirror the UCI datasets of the paper with n scaled
down (see DESIGN.md §3 Substitutions); `d` and the noise character are kept.
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class Config:
    name: str
    n: int        # training points
    n_test: int   # test points
    d: int        # input dimension
    s: int        # probe vectors (solver batch is k = s + 1 columns)
    m: int        # random Fourier feature sin/cos pairs
    b: int        # AP block size == SGD batch size
    tile: int     # pallas tile edge (must divide n, b and n_test)
    kernel: str   # matern12 | matern32 | matern52 | rbf
    exact: bool   # also lower the Cholesky exact-MLL artifact

    @property
    def k(self) -> int:
        """Solver RHS batch width: [y | probe_1 .. probe_s]."""
        return self.s + 1

    def validate(self) -> None:
        assert self.n % self.tile == 0, (self.name, "tile must divide n")
        assert self.b % self.tile == 0 or self.tile % self.b == 0 or self.b % 64 == 0, self.name
        assert self.n % self.b == 0, (self.name, "b must divide n")
        assert self.n_test % self.tile == 0, (self.name, "tile must divide n_test")
        assert self.kernel in ("matern12", "matern32", "matern52", "rbf"), self.name

    @property
    def tile_b(self) -> int:
        """Tile edge used along a block/batch axis of size b."""
        return min(self.tile, self.b)


def _cfg(name, n, n_test, d, s=16, m=256, b=128, tile=256, kernel="matern32", exact=None):
    # tile=256 adopted from the §Perf sweep (EXPERIMENTS.md): 1.38x over 128
    # on the hot kmv_full path, VMEM/step still ~2% of a TPU core's 16 MiB.
    if exact is None:
        exact = n <= 2048
    c = Config(name, n, n_test, d, s, m, b, tile, kernel, exact)
    c.validate()
    return c


# The registry. Names mirror the paper's UCI datasets (scaled down).
CONFIGS = {
    c.name: c
    for c in [
        # tiny config used by pytest / cargo integration tests / quickstart
        _cfg("test", n=256, n_test=64, d=4, s=8, m=64, b=64, tile=64),
        # "small" datasets of Table 1 (paper: n = 13.5k .. 44k)
        _cfg("pol", n=1024, n_test=256, d=26),
        _cfg("elevators", n=1024, n_test=256, d=18),
        _cfg("bike", n=1024, n_test=256, d=17),
        _cfg("protein", n=2048, n_test=512, d=9, b=256),
        _cfg("keggdir", n=2048, n_test=512, d=20, b=256),
        # "large" datasets of Section 5 (paper: n = 391k .. 1.84M), budgeted
        _cfg("threedroad", n=2048, n_test=512, d=3, exact=False),
        _cfg("song", n=2048, n_test=512, d=24, exact=False),
        _cfg("buzz", n=2048, n_test=512, d=32, exact=False),
        _cfg("houseelectric", n=4096, n_test=512, d=11, b=256, exact=False),
        # Fig. 4 probe-count sweep variants of pol
        _cfg("pol_s4", n=1024, n_test=256, d=26, s=4),
        _cfg("pol_s64", n=1024, n_test=256, d=26, s=64),
    ]
}


def get(name: str) -> Config:
    return CONFIGS[name]
