"""L1 Pallas kernel: blocked kernel-matrix/vector product  K(Xa, Xb) @ V.

This is the compute hot-spot of every linear-system solver in the paper:
CG multiplies the full H against the [n, s+1] RHS batch each iteration, AP
multiplies a column block K(X, X_I), SGD a row batch K(X_I, X).  One kernel
covers all three because Xa and Xb are independent operands.

TPU mapping (DESIGN.md §Hardware-Adaptation): the kernel matrix is never
materialised in HBM.  Each grid step stages a (Tm,d) and a (Tn,d) input slab
plus a (Tn,k) RHS slab into VMEM, forms the (Tm,Tn) covariance tile via an
MXU matmul (the -2*xa@xb.T term) + VPU transcendentals, and immediately
contracts it against the RHS slab on the MXU, accumulating into the (Tm,k)
output block.  `interpret=True` is mandatory here: the CPU PJRT plugin
cannot execute Mosaic custom-calls.

Inputs are lengthscale-scaled (xs = x / ell); sigf2 arrives via a tiny
params array because it is a traced value that changes every outer step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import unit_cov


def _kmv_kernel(params_ref, xa_ref, xb_ref, v_ref, o_ref, *, family):
    j = pl.program_id(1)
    sigf2 = params_ref[0]
    xa = xa_ref[...]
    xb = xb_ref[...]
    na = jnp.sum(xa * xa, axis=1)[:, None]
    nb = jnp.sum(xb * xb, axis=1)[None, :]
    sq = jnp.maximum(na + nb - 2.0 * (xa @ xb.T), 0.0)
    cov = sigf2 * unit_cov(sq, family)
    acc = cov @ v_ref[...]

    @pl.when(j == 0)
    def _init():
        o_ref[...] = acc

    @pl.when(j > 0)
    def _acc():
        o_ref[...] = o_ref[...] + acc


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n", "family"))
def kmv(xa_s, xb_s, v, sigf2, *, tile_m, tile_n, family="matern32"):
    """K(xa, xb) @ v with scaled inputs.

    xa_s: [M, d] (= xa / ell), xb_s: [N, d], v: [N, k] -> [M, k].
    M % tile_m == 0 and N % tile_n == 0 are required (configs guarantee it).
    """
    m, d = xa_s.shape
    n, k = v.shape
    assert xb_s.shape == (n, d), (xa_s.shape, xb_s.shape, v.shape)
    assert m % tile_m == 0 and n % tile_n == 0, (m, n, tile_m, tile_n)
    params = jnp.stack([sigf2])
    grid = (m // tile_m, n // tile_n)
    return pl.pallas_call(
        functools.partial(_kmv_kernel, family=family),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((tile_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_n, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_m, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), v.dtype),
        interpret=True,
    )(params, xa_s, xb_s, v)
