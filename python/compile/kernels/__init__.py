# L1: Pallas kernel(s) for the paper's compute hot-spot.
from . import common, grad, kmv, ref  # noqa: F401
