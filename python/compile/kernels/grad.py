"""L1 Pallas kernel: fused marginal-likelihood-gradient quadratic forms.

Computes, in a single pass over the (n x n) tile space, every kernel-
hyperparameter component of

    G_k = sum_j w_j * a_j^T (dK/dtheta_k) b_j          k = 1..d+1

for the lengthscales (k = 1..d) and the signal scale (k = d+1).  The noise
component (dH/dsigma = 2 sigma I) needs no pairwise pass and is added by the
L2 wrapper.  Both the standard Hutchinson estimator (a_j, b_j) = (v_j, z_j)
and the pathwise estimator (a_j, b_j) = (zhat_j, zhat_j) reduce to this
primitive; only the column assembly differs (done in Rust, L3).

Fusion rationale (DESIGN.md §Hardware-Adaptation): on an accelerator the
O(n^2 d) pairwise-difference work dominates.  A naive implementation runs
one sweep per hyperparameter (d+1 sweeps); this kernel shares the distance
tile, the radial weight h(r) and the C = (A w) B^T cross-moment tile across
all components, so the n^2 space is swept exactly once.

Weight pre-multiplication: callers pass A already scaled by w (column j of
A multiplied by w_j), so C = A_w @ B^T absorbs the weights.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import dl_weight, unit_cov


def _grad_kernel(params_ref, ell_ref, xa_ref, xb_ref, a_ref, b_ref, o_ref, *, family):
    i = pl.program_id(0)
    j = pl.program_id(1)
    sigf2 = params_ref[0]
    xa = xa_ref[...]  # [Tm, d] scaled
    xb = xb_ref[...]  # [Tn, d] scaled
    na = jnp.sum(xa * xa, axis=1)[:, None]
    nb = jnp.sum(xb * xb, axis=1)[None, :]
    sq = jnp.maximum(na + nb - 2.0 * (xa @ xb.T), 0.0)

    c = a_ref[...] @ b_ref[...].T  # [Tm, Tn] weighted cross moments

    # Lengthscale components: dk/d ell_d = sigf2 * h(r) * dss_d / ell_d.
    w_tile = c * (sigf2 * dl_weight(sq, family))  # [Tm, Tn]
    diff = xa[:, None, :] - xb[None, :, :]  # [Tm, Tn, d] scaled diffs
    g_ell = jnp.einsum("mn,mnd->d", w_tile, diff * diff) / ell_ref[...]

    # Signal-scale component: dk/d sigf = 2 k / sigf  ->  (2/sigf) sum C*K.
    kfull = sigf2 * unit_cov(sq, family)
    g_sigf = 2.0 / jnp.sqrt(sigf2) * jnp.sum(c * kfull)

    upd = jnp.concatenate([g_ell, g_sigf[None]])

    @pl.when((i == 0) & (j == 0))
    def _init():
        o_ref[...] = upd

    @pl.when((i > 0) | (j > 0))
    def _acc():
        o_ref[...] = o_ref[...] + upd


@functools.partial(jax.jit, static_argnames=("tile", "family"))
def grad_quad_kernel(x_s, a_w, b, ell, sigf2, *, tile, family="matern32"):
    """Fused gradient quadratic forms over the kernel part of H.

    x_s: [n, d] scaled inputs; a_w: [n, q] left vectors (pre-multiplied by
    weights); b: [n, q] right vectors; ell: [d] lengthscales.
    Returns [d+1]: (lengthscale grads, signal-scale grad).
    """
    n, d = x_s.shape
    q = a_w.shape[1]
    assert a_w.shape == (n, q) and b.shape == (n, q)
    assert n % tile == 0
    params = jnp.stack([sigf2])
    grid = (n // tile, n // tile)
    return pl.pallas_call(
        functools.partial(_grad_kernel, family=family),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
            pl.BlockSpec((tile, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tile, q), lambda i, j: (i, 0)),
            pl.BlockSpec((tile, q), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((d + 1,), lambda i, j: (0,)),
        out_shape=jax.ShapeDtypeStruct((d + 1,), x_s.dtype),
        interpret=True,
    )(params, ell, x_s, x_s, a_w, b)
