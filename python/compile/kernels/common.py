"""Shared covariance-family math used by both the Pallas kernels (L1) and
the pure-jnp oracles (ref.py).

All functions operate on *lengthscale-scaled* inputs: callers pass
``xs = x / ell`` so pairwise squared distances are already in scaled units.
The signal variance ``sigf2 = sigf**2`` multiplies the unit covariance.

Lengthscale-derivative identity (per input dimension d, raw inputs):

    d k / d ell_d = sigf2 * h(r) * dss_d / ell_d

where ``dss_d`` is the *scaled* squared difference ((xa_d - xb_d)/ell_d)^2
and ``h(r)`` is the family-specific radial weight returned by
:func:`dl_weight`.  See DESIGN.md and Appendix tests for derivations.
"""

import jax.numpy as jnp

SQRT3 = 1.7320508075688772
SQRT5 = 2.23606797749979
EPS_R = 1e-30

FAMILIES = ("matern12", "matern32", "matern52", "rbf")


def sqdist(xa, xb):
    """Pairwise squared Euclidean distance between rows of xa [M,d], xb [N,d]."""
    na = jnp.sum(xa * xa, axis=1)[:, None]
    nb = jnp.sum(xb * xb, axis=1)[None, :]
    return jnp.maximum(na + nb - 2.0 * (xa @ xb.T), 0.0)


def _safe_r(sq):
    """sqrt(sq) with a well-defined (zero) gradient at sq == 0.

    Plain jnp.sqrt yields NaN under jax.grad on the diagonal (sq = 0); the
    true directional derivative of every supported family w.r.t. any
    hyperparameter is 0 there, which the where-trick recovers exactly.
    """
    pos = sq > 0.0
    r = jnp.sqrt(jnp.where(pos, sq, 1.0))
    return jnp.where(pos, r, 0.0)


def unit_cov(sq, family):
    """Unit-signal covariance g(r) from squared scaled distance."""
    if family == "rbf":
        return jnp.exp(-0.5 * sq)
    r = _safe_r(sq)
    if family == "matern12":
        return jnp.exp(-r)
    if family == "matern32":
        return (1.0 + SQRT3 * r) * jnp.exp(-SQRT3 * r)
    if family == "matern52":
        return (1.0 + SQRT5 * r + (5.0 / 3.0) * sq) * jnp.exp(-SQRT5 * r)
    raise ValueError(family)


def dl_weight(sq, family):
    """Radial weight h(r) with  dk/d ell_d = sigf2 * h * dss_d / ell_d.

    Derivations (k = sigf2 * g(r), r^2 = sum_d dss_d):
      rbf      : g = exp(-sq/2)                 -> h = exp(-sq/2)
      matern12 : g = exp(-r)                    -> h = exp(-r)/r   (safe at 0)
      matern32 : g = (1+c3 r)exp(-c3 r)         -> h = 3 exp(-c3 r)
      matern52 : g = (1+c5 r+5 sq/3)exp(-c5 r)  -> h = (5/3)(1+c5 r)exp(-c5 r)
    """
    if family == "rbf":
        return jnp.exp(-0.5 * sq)
    r = _safe_r(sq)
    if family == "matern12":
        return jnp.exp(-r) / jnp.maximum(r, EPS_R)
    if family == "matern32":
        return 3.0 * jnp.exp(-SQRT3 * r)
    if family == "matern52":
        return (5.0 / 3.0) * (1.0 + SQRT5 * r) * jnp.exp(-SQRT5 * r)
    raise ValueError(family)
