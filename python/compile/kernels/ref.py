"""Pure-jnp correctness oracles for the Pallas kernels.

Everything here materialises the n x n kernel matrix, so it is only used
at build time by pytest (and by the `kmv_full_ref` perf-ablation artifact).
"""

import jax
import jax.numpy as jnp

from .common import sqdist, unit_cov


def kernel_matrix(xa, xb, ell, sigf, family="matern32"):
    """Full covariance matrix K(xa, xb; ell, sigf) with raw (unscaled) inputs."""
    sq = sqdist(xa / ell, xb / ell)
    return (sigf * sigf) * unit_cov(sq, family)


def h_matrix(x, theta, family="matern32"):
    """Regularised kernel matrix H = K + sigma^2 I from a packed theta."""
    d = x.shape[1]
    ell, sigf, sign = theta[:d], theta[d], theta[d + 1]
    return kernel_matrix(x, x, ell, sigf, family) + (sign * sign) * jnp.eye(x.shape[0], dtype=x.dtype)


def kmv_ref(xa, xb, v, ell, sigf, family="matern32"):
    """Oracle for kernels.kmv.kmv (without the noise term)."""
    return kernel_matrix(xa, xb, ell, sigf, family) @ v


def hv_ref(x, v, theta, family="matern32"):
    """Oracle for the full H @ V product."""
    return h_matrix(x, theta, family) @ v


def grad_quad_ref(x, a, b, w, theta, family="matern32"):
    """Autodiff oracle for the fused gradient kernel + noise component.

    Returns [d+2]: d/dtheta of  sum_j w_j a_j^T H(theta) b_j  with
    theta = [ell_1..ell_d, sigf, sigma].
    """

    def qf(th):
        hm = h_matrix(x, th, family)
        return jnp.sum(w * jnp.einsum("nj,nm,mj->j", a, hm, b))

    return jax.grad(qf)(theta)


def mll_ref(x, y, theta, family="matern32"):
    """Exact marginal log-likelihood via Cholesky (oracle for model.exact_mll)."""
    n = x.shape[0]
    hm = h_matrix(x, theta, family)
    chol = jnp.linalg.cholesky(hm)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diag(chol)))
    return -0.5 * y @ alpha - 0.5 * logdet - 0.5 * n * jnp.log(2.0 * jnp.pi)
