"""L2 perf tool: inspect a lowered artifact's HLO — op histogram, fusion
opportunities, and a FLOP/byte estimate for the roofline discussion in
DESIGN.md §6/§7.

Usage:
    python -m compile.hlo_inspect ../artifacts/pol/kmv_full.hlo.txt
"""

import re
import sys
from collections import Counter


def op_histogram(text: str) -> Counter:
    ops = Counter()
    for line in text.splitlines():
        line = line.strip()
        # instruction lines look like: "%name = f64[...] opcode(...)"
        m = re.match(r"%?[\w.\-]+ = [\w\[\],{}\d\s]+? ([a-z][\w\-]*)\(", line)
        if m:
            ops[m.group(1)] += 1
    return ops


def tensor_bytes(text: str) -> int:
    """Upper bound on live tensor traffic: sum of all instruction output
    shapes (f64 = 8 bytes)."""
    total = 0
    for m in re.finditer(r"f64\[([\d,]*)\]", text):
        dims = m.group(1)
        if not dims:
            total += 8
            continue
        prod = 1
        for d in dims.split(","):
            prod *= int(d)
        total += 8 * prod
    return total


def main() -> None:
    if len(sys.argv) < 2:
        print(__doc__)
        sys.exit(1)
    for path in sys.argv[1:]:
        text = open(path).read()
        ops = op_histogram(text)
        print(f"== {path}")
        print(f"   instructions: {sum(ops.values())}")
        for op, count in ops.most_common(12):
            print(f"   {op:<24} {count}")
        # markers of concern
        loops = ops.get("while", 0)
        fusions = ops.get("fusion", 0)
        dots = ops.get("dot", 0)
        custom = ops.get("custom-call", 0)
        print(f"   while-loops={loops} fusions={fusions} dots={dots} custom-calls={custom}")
        if custom:
            print("   WARNING: custom-calls will not compile on xla_extension 0.5.1")
        print(f"   est. tensor traffic: {tensor_bytes(text) / 1e6:.1f} MB")


if __name__ == "__main__":
    main()
