"""AOT lowering driver: JAX (L2 + L1) -> HLO text artifacts for the Rust runtime.

Emits HLO *text* (NOT serialized HloModuleProto): jax >= 0.5 emits protos
with 64-bit instruction ids which xla_extension 0.5.1 (the version the
published `xla` 0.1.6 crate links) rejects; the HLO text parser reassigns
ids so text round-trips cleanly.  See /opt/xla-example/README.md.

Usage:
    python -m compile.aot --out ../artifacts [--config NAME ...]

Layout per config:
    artifacts/<config>/meta.txt            # shapes for the Rust loader
    artifacts/<config>/<fn>.hlo.txt        # one module per L2 entry point
"""

import argparse
import functools
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import configs, model  # noqa: E402

F64 = jnp.float64


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F64)


def entry_points(cfg: configs.Config):
    """(name, fn, arg_specs) for every artifact of this config."""
    n, nt, d, s, m, b, t, tb = (
        cfg.n, cfg.n_test, cfg.d, cfg.s, cfg.m, cfg.b, cfg.tile, cfg.tile_b,
    )
    k = cfg.k  # s + 1
    fam = cfg.kernel
    th = spec(d + 2)
    eps = [
        ("kmv_full",
         functools.partial(model.kmv_full, tile=t, family=fam),
         [spec(n, d), spec(n, k), th]),
        ("kmv_full_ref",
         functools.partial(model.kmv_full_ref, family=fam),
         [spec(n, d), spec(n, k), th]),
        ("kmv_cols",
         functools.partial(model.kmv_cols, tile=t, tile_b=tb, family=fam),
         [spec(n, d), spec(b, d), spec(b, k), th]),
        ("kmv_rows",
         functools.partial(model.kmv_rows, tile=t, tile_b=tb, family=fam),
         [spec(b, d), spec(n, d), spec(n, k), th]),
        ("grad_quad",
         functools.partial(model.grad_quad, tile=t, family=fam),
         [spec(n, d), spec(n, k), spec(n, k), spec(k), th]),
        ("rff_eval",
         model.rff_eval,
         [spec(n, d), spec(d, m), spec(2 * m, s), spec(n, s), th]),
        ("predict",
         functools.partial(model.predict, tile=t, tile_t=min(t, nt), family=fam),
         [spec(nt, d), spec(n, d), th, spec(n), spec(n, s), spec(d, m), spec(2 * m, s)]),
    ]
    # NOTE: no exact_mll artifact — jnp.linalg.cholesky lowers to a
    # API_VERSION_TYPED_FFI LAPACK custom-call that xla_extension 0.5.1
    # cannot compile.  The exact baseline runs in Rust (gp::ExactGp),
    # cross-validated against model.exact_mll in pytest.  cfg.exact only
    # gates whether the Rust side may use the O(n^3) exact path.
    return eps


def meta_text(cfg: configs.Config) -> str:
    lines = [
        f"name={cfg.name}",
        f"n={cfg.n}",
        f"n_test={cfg.n_test}",
        f"d={cfg.d}",
        f"s={cfg.s}",
        f"m={cfg.m}",
        f"b={cfg.b}",
        f"tile={cfg.tile}",
        f"kernel={cfg.kernel}",
        f"exact={'true' if cfg.exact else 'false'}",
    ]
    return "\n".join(lines) + "\n"


def build_config(cfg: configs.Config, out_dir: str, force: bool = False) -> None:
    cdir = os.path.join(out_dir, cfg.name)
    os.makedirs(cdir, exist_ok=True)
    meta_path = os.path.join(cdir, "meta.txt")
    # config drift detection: if the shapes/tiling changed since the last
    # build, the cached HLO is stale even though the files exist.
    if not force and os.path.exists(meta_path):
        if open(meta_path).read() != meta_text(cfg):
            print(f"  {cfg.name}: config changed, rebuilding")
            force = True
    for name, fn, args in entry_points(cfg):
        path = os.path.join(cdir, f"{name}.hlo.txt")
        if not force and os.path.exists(path) and os.path.exists(meta_path):
            continue
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"  {cfg.name}/{name}: {len(text) / 1e3:.0f} kB")
    with open(meta_path, "w") as f:
        f.write(meta_text(cfg))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--config", action="append", default=None,
                    help="config name(s); default: all registered configs")
    ap.add_argument("--force", action="store_true", help="rebuild even if present")
    args = ap.parse_args()
    names = args.config or list(configs.CONFIGS)
    os.makedirs(args.out, exist_ok=True)
    for name in names:
        cfg = configs.get(name)
        print(f"[aot] {name} (n={cfg.n} d={cfg.d} s={cfg.s} b={cfg.b} tile={cfg.tile})")
        build_config(cfg, args.out, force=args.force)
    # stamp for make's up-to-date check
    with open(os.path.join(args.out, ".stamp"), "w") as f:
        f.write("ok\n")
    print("[aot] done")


if __name__ == "__main__":
    main()
