"""AOT path: lowering produces parseable HLO text with the expected entry
computations, and the config registry is internally consistent."""

import os

import jax
import pytest

from compile import aot, configs


def test_all_configs_validate():
    for cfg in configs.CONFIGS.values():
        cfg.validate()
        assert cfg.k == cfg.s + 1


def test_config_registry_has_paper_datasets():
    for name in [
        "pol", "elevators", "bike", "protein", "keggdir",
        "threedroad", "song", "buzz", "houseelectric",
    ]:
        assert name in configs.CONFIGS, name


def test_entry_points_cover_contract():
    cfg = configs.get("test")
    names = {n for n, _, _ in aot.entry_points(cfg)}
    want = {"kmv_full", "kmv_full_ref", "kmv_cols", "kmv_rows",
            "grad_quad", "rff_eval", "predict"}
    assert names == want


def test_no_exact_mll_artifact_anywhere():
    # old XLA cannot compile the LAPACK typed-FFI cholesky custom-call
    for cfg in configs.CONFIGS.values():
        names = {n for n, _, _ in aot.entry_points(cfg)}
        assert "exact_mll" not in names


@pytest.mark.parametrize("fn_name", ["kmv_full", "grad_quad", "rff_eval", "predict"])
def test_lowering_emits_hlo_text(fn_name):
    cfg = configs.get("test")
    for name, fn, args in aot.entry_points(cfg):
        if name != fn_name:
            continue
        text = aot.to_hlo_text(jax.jit(fn).lower(*args))
        assert "ENTRY" in text
        assert "f64" in text  # double precision throughout (paper setting)
        # interchange must be text, never a serialized proto
        assert text.lstrip().startswith("HloModule")


def test_meta_text_roundtrip():
    cfg = configs.get("test")
    meta = aot.meta_text(cfg)
    kv = dict(line.split("=", 1) for line in meta.strip().splitlines())
    assert int(kv["n"]) == cfg.n
    assert int(kv["s"]) == cfg.s
    assert kv["kernel"] == cfg.kernel


def test_build_config_writes_artifacts(tmp_path):
    cfg = configs.get("test")
    aot.build_config(cfg, str(tmp_path), force=True)
    cdir = tmp_path / "test"
    assert (cdir / "meta.txt").exists()
    assert (cdir / "kmv_full.hlo.txt").exists()
    # idempotent second run keeps files
    aot.build_config(cfg, str(tmp_path), force=False)
    assert (cdir / "kmv_full.hlo.txt").exists()
