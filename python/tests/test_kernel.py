"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes/families; every case asserts allclose
against ref.py.  This is the core correctness signal for the hot path.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.common import FAMILIES
from compile.kernels.grad import grad_quad_kernel
from compile.kernels.kmv import kmv

RNG = np.random.default_rng(0)

# matern12 is non-differentiable at r=0: the pairwise-distance trick's
# cancellation (~1e-13 in sq) amplifies to ~1e-7 in exp(-sqrt(sq)) near the
# diagonal, in *both* the Pallas and the reference path (different summation
# order). Smooth families keep ~1e-10.
TOL = {"matern12": 1e-6, "matern32": 1e-9, "matern52": 1e-9, "rbf": 1e-9}


def _data(m, n, d, k, dtype=np.float64):
    rng = np.random.default_rng(42 + m + n + d + k)
    xa = rng.standard_normal((m, d)).astype(dtype)
    xb = rng.standard_normal((n, d)).astype(dtype)
    v = rng.standard_normal((n, k)).astype(dtype)
    ell = (0.5 + rng.random(d)).astype(dtype)
    sigf = dtype(1.3)
    return xa, xb, v, ell, sigf


# ----------------------------------------------------------------------
# kmv: K(Xa, Xb) @ V
# ----------------------------------------------------------------------


@pytest.mark.parametrize("family", FAMILIES)
def test_kmv_square_matches_ref(family):
    xa, _, v, ell, sigf = _data(128, 128, 5, 9)
    got = kmv(xa / ell, xa / ell, v, sigf**2, tile_m=64, tile_n=64, family=family)
    want = ref.kmv_ref(xa, xa, v, ell, sigf, family)
    np.testing.assert_allclose(got, want, rtol=TOL[family], atol=TOL[family])


@pytest.mark.parametrize("family", FAMILIES)
def test_kmv_rectangular_matches_ref(family):
    xa, xb, v, ell, sigf = _data(64, 192, 3, 4)
    got = kmv(xa / ell, xb / ell, v, sigf**2, tile_m=32, tile_n=64, family=family)
    want = ref.kmv_ref(xa, xb, v, ell, sigf, family)
    np.testing.assert_allclose(got, want, rtol=TOL[family], atol=TOL[family])


@settings(max_examples=12, deadline=None)
@given(
    mt=st.integers(1, 3),
    nt=st.integers(1, 3),
    tile=st.sampled_from([16, 32]),
    d=st.integers(1, 8),
    k=st.integers(1, 10),
    family=st.sampled_from(FAMILIES),
)
def test_kmv_hypothesis_shapes(mt, nt, tile, d, k, family):
    m, n = mt * tile, nt * tile
    xa, xb, v, ell, sigf = _data(m, n, d, k)
    got = kmv(xa / ell, xb / ell, v, sigf**2, tile_m=tile, tile_n=tile, family=family)
    want = ref.kmv_ref(xa, xb, v, ell, sigf, family)
    np.testing.assert_allclose(got, want, rtol=TOL[family], atol=TOL[family])


def test_kmv_float32_dtype():
    xa, xb, v, ell, sigf = _data(64, 64, 4, 3, dtype=np.float32)
    got = kmv(xa / ell, xb / ell, v, np.float32(sigf**2), tile_m=32, tile_n=32)
    want = ref.kmv_ref(xa, xb, v, ell, sigf, "matern32")
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_kmv_identity_diagonal():
    """k(x, x) must equal sigf^2 up to the distance-trick's cancellation
    (sq ~ 1e-13 on the diagonal -> ~1e-7 for the non-smooth matern12)."""
    rng = np.random.default_rng(11)
    x = rng.standard_normal((32, 2))
    v = np.eye(32)
    for family in FAMILIES:
        kmat = kmv(x, x, v, 4.0, tile_m=32, tile_n=32, family=family)
        np.testing.assert_allclose(np.diag(kmat), 4.0, rtol=0, atol=1e-6)


def test_kmv_tile_invariance():
    """Result must not depend on the tiling."""
    xa, xb, v, ell, sigf = _data(128, 128, 6, 7)
    a = kmv(xa / ell, xb / ell, v, sigf**2, tile_m=32, tile_n=64)
    b = kmv(xa / ell, xb / ell, v, sigf**2, tile_m=128, tile_n=16)
    np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-12)


# ----------------------------------------------------------------------
# grad_quad_kernel: fused d/dtheta of sum_j w_j a_j' K b_j
# ----------------------------------------------------------------------


def _grad_case(n, d, q, family, tile):
    rng = np.random.default_rng(7 * n + d + q)
    x = rng.standard_normal((n, d))
    a = rng.standard_normal((n, q))
    b = rng.standard_normal((n, q))
    w = rng.standard_normal(q)
    ell = 0.5 + rng.random(d)
    sigf, sign = 1.4, 0.3
    theta = np.concatenate([ell, [sigf, sign]])
    got_kern = grad_quad_kernel(
        x / ell, a * w[None, :], b, ell, sigf**2, tile=tile, family=family
    )
    want = ref.grad_quad_ref(x, a, b, w, theta, family)
    tol = max(TOL[family], 1e-8)
    # kernel part: lengthscales + signal scale
    np.testing.assert_allclose(got_kern[:d], want[:d], rtol=tol, atol=tol)
    np.testing.assert_allclose(got_kern[d], want[d], rtol=tol, atol=tol)


@pytest.mark.parametrize("family", FAMILIES)
def test_grad_quad_vs_autodiff(family):
    _grad_case(96, 4, 5, family, tile=32)


@settings(max_examples=10, deadline=None)
@given(
    nt=st.integers(1, 3),
    tile=st.sampled_from([16, 32]),
    d=st.integers(1, 6),
    q=st.integers(1, 6),
    family=st.sampled_from(FAMILIES),
)
def test_grad_quad_hypothesis(nt, tile, d, q, family):
    _grad_case(nt * tile, d, q, family, tile)


def test_grad_quad_tile_invariance():
    rng = np.random.default_rng(3)
    n, d, q = 128, 3, 4
    x = rng.standard_normal((n, d))
    a = rng.standard_normal((n, q))
    b = rng.standard_normal((n, q))
    w = rng.standard_normal(q)
    ell = np.ones(d)
    g1 = grad_quad_kernel(x, a * w, b, ell, 1.0, tile=32)
    g2 = grad_quad_kernel(x, a * w, b, ell, 1.0, tile=64)
    np.testing.assert_allclose(g1, g2, rtol=1e-11, atol=1e-11)
