"""L2 correctness: model entry points vs oracles and closed forms."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

FAM = "matern32"


def _case(n=128, d=4, k=5, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d))
    v = rng.standard_normal((n, k))
    ell = 0.5 + rng.random(d)
    theta = np.concatenate([ell, [1.2, 0.4]])
    return x, v, theta


def test_kmv_full_adds_noise_term():
    x, v, theta = _case()
    got = model.kmv_full(x, v, theta, tile=32, family=FAM)
    want = ref.hv_ref(x, v, theta, FAM)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-10)


def test_kmv_full_ref_matches_pallas_path():
    x, v, theta = _case()
    a = model.kmv_full(x, v, theta, tile=64, family=FAM)
    b = model.kmv_full_ref(x, v, theta, family=FAM)
    np.testing.assert_allclose(a, b, rtol=1e-11, atol=1e-11)


def test_kmv_cols_rows_consistency():
    """K[:, I] @ U must equal (K[I, :])^T @ U by kernel symmetry."""
    x, v, theta = _case(n=128, k=3)
    idx = np.arange(32, 64)
    xb = x[idx]
    u = v[idx]
    cols = model.kmv_cols(x, xb, u, theta, tile=32, tile_b=32, family=FAM)
    d = x.shape[1]
    km = ref.kernel_matrix(x, x, theta[:d], theta[d], FAM)
    np.testing.assert_allclose(cols, km[:, idx] @ u, rtol=1e-10, atol=1e-10)
    rows = model.kmv_rows(xb, x, v, theta, tile=32, tile_b=32, family=FAM)
    np.testing.assert_allclose(rows, km[idx, :] @ v, rtol=1e-10, atol=1e-10)


def test_grad_quad_full_vector_vs_autodiff():
    x, _, theta = _case(n=96, d=3, seed=1)
    rng = np.random.default_rng(5)
    q = 4
    a = rng.standard_normal((96, q))
    b = rng.standard_normal((96, q))
    w = rng.standard_normal(q)
    got = model.grad_quad(x, a, b, w, theta, tile=32, family=FAM)
    want = ref.grad_quad_ref(x, a, b, w, theta, FAM)
    np.testing.assert_allclose(got, want, rtol=1e-8, atol=1e-8)


def test_grad_quad_estimator_identity():
    """E over probes of the Hutchinson form recovers tr(H^-1 dH) exactly
    when probes span the full basis: use the identity as probe matrix."""
    n, d = 64, 2
    x, _, theta = _case(n=n, d=d, seed=2)
    hm = np.asarray(ref.h_matrix(x, theta, FAM))
    hinv = np.linalg.inv(hm)
    # probes = all n basis vectors, a_j = H^-1 e_j, b_j = e_j, w = 1
    a = hinv
    b = np.eye(n)
    w = np.ones(n)
    got = model.grad_quad(x, a, b, w, theta, tile=32, family=FAM)
    # oracle: tr(H^-1 dH/dtheta_k) by autodiff of tr-form
    def tr_form(th):
        h = ref.h_matrix(x, th, FAM)
        return jnp.sum(hinv * h)  # tr(H^-1 H(th)) differentiating only H(th)
    want = jax.grad(tr_form)(jnp.asarray(theta, dtype=jnp.float64))
    np.testing.assert_allclose(got, want, rtol=1e-7, atol=1e-7)


# ----------------------------------------------------------------------
# RFF prior samples
# ----------------------------------------------------------------------


def _student_t_freqs(rng, d, m, df=3.0):
    """Matern-3/2 spectral density = multivariate-t with df = 2*nu = 3."""
    z = rng.standard_normal((d, m))
    g = rng.chisquare(df, size=m)
    return z * np.sqrt(df / g)[None, :]


def test_rff_second_moment_matches_kernel():
    """E[xi xi^T] ~= H: statistical check with many weight draws."""
    rng = np.random.default_rng(0)
    n, d, m, s = 48, 2, 4096, 512
    x = rng.standard_normal((n, d))
    theta = np.array([0.8, 1.2, 1.0, 0.3])
    omega0 = _student_t_freqs(rng, d, m)
    wts = rng.standard_normal((2 * m, s))
    noise = rng.standard_normal((n, s))
    xi = np.asarray(model.rff_eval(x, omega0, wts, noise, theta))
    emp = xi @ xi.T / s
    want = np.asarray(ref.h_matrix(x, theta, FAM))
    # Monte-Carlo + RFF approximation error: loose tolerance, tight enough
    # to catch scaling mistakes (off by sqrt(2), missing sigf, etc.).
    assert np.abs(emp - want).max() < 0.25
    np.testing.assert_allclose(np.diag(emp), np.diag(want), rtol=0.15)


def test_rff_noise_reparameterisation():
    """xi must be exactly Phi w + sigma * noise (deterministic given inputs)."""
    rng = np.random.default_rng(1)
    n, d, m, s = 16, 2, 8, 3
    x = rng.standard_normal((n, d))
    omega0 = rng.standard_normal((d, m))
    wts = rng.standard_normal((2 * m, s))
    noise = rng.standard_normal((n, s))
    theta = np.array([1.0, 1.0, 1.5, 0.7])
    xi = np.asarray(model.rff_eval(x, omega0, wts, noise, theta))
    z = (x / theta[:d]) @ omega0
    phi = 1.5 * np.sqrt(1.0 / m) * np.concatenate([np.cos(z), np.sin(z)], axis=1)
    np.testing.assert_allclose(xi, phi @ wts + 0.7 * noise, rtol=1e-12, atol=1e-12)


# ----------------------------------------------------------------------
# Pathwise-conditioned prediction
# ----------------------------------------------------------------------


def test_predict_mean_is_kv():
    rng = np.random.default_rng(2)
    n, nt, d, s, m = 96, 32, 3, 4, 16
    x = rng.standard_normal((n, d))
    xt = rng.standard_normal((nt, d))
    theta = np.concatenate([0.5 + rng.random(d), [1.1, 0.35]])
    vy = rng.standard_normal(n)
    zhat = rng.standard_normal((n, s))
    omega0 = rng.standard_normal((d, m))
    wts = rng.standard_normal((2 * m, s))
    mean, samples = model.predict(
        xt, x, theta, vy, zhat, omega0, wts, tile=32, tile_t=32, family=FAM
    )
    km = ref.kernel_matrix(xt, x, theta[:d], theta[d], FAM)
    np.testing.assert_allclose(mean, km @ vy, rtol=1e-10, atol=1e-10)
    # sample j = prior_j(xt) + K(xt,x)(vy - zhat_j)
    z = (xt / theta[:d]) @ omega0
    phi = theta[d] * np.sqrt(1.0 / m) * np.concatenate([np.cos(z), np.sin(z)], axis=1)
    want = phi @ wts + km @ (vy[:, None] - zhat)
    np.testing.assert_allclose(samples, want, rtol=1e-10, atol=1e-10)


def test_predict_exact_posterior_consistency():
    """With zhat = H^-1 xi the sample mean over many samples approaches the
    exact posterior mean; here we check the *single-sample identity*:
    posterior sample evaluated with zero prior draw equals the mean shift."""
    rng = np.random.default_rng(3)
    n, nt, d, m = 64, 32, 2, 8
    x = rng.standard_normal((n, d))
    xt = rng.standard_normal((nt, d))
    theta = np.array([1.0, 1.0, 1.0, 0.5])
    y = rng.standard_normal(n)
    hm = np.asarray(ref.h_matrix(x, theta, FAM))
    vy = np.linalg.solve(hm, y)
    zhat = np.zeros((n, 1))
    omega0 = rng.standard_normal((d, m))
    wts = np.zeros((2 * m, 1))
    mean, samples = model.predict(
        xt, x, theta, vy, zhat, omega0, wts, tile=32, tile_t=32, family=FAM
    )
    np.testing.assert_allclose(samples[:, 0], mean, rtol=1e-10, atol=1e-10)


# ----------------------------------------------------------------------
# Exact MLL baseline
# ----------------------------------------------------------------------


def test_exact_mll_value_matches_dense_formula():
    rng = np.random.default_rng(4)
    n, d = 64, 3
    x = rng.standard_normal((n, d))
    y = rng.standard_normal(n)
    theta = np.concatenate([0.5 + rng.random(d), [1.3, 0.45]])
    val, grad = model.exact_mll(x, y, theta, family=FAM)
    hm = np.asarray(ref.h_matrix(x, theta, FAM))
    sign_det, logdet = np.linalg.slogdet(hm)
    assert sign_det > 0
    want = -0.5 * y @ np.linalg.solve(hm, y) - 0.5 * logdet - 0.5 * n * np.log(2 * np.pi)
    np.testing.assert_allclose(float(val), want, rtol=1e-10)
    assert grad.shape == (d + 2,)


def test_exact_mll_grad_matches_eq5():
    """Autodiff gradient must equal the closed-form eq. (5) of the paper."""
    rng = np.random.default_rng(5)
    n, d = 48, 2
    x = rng.standard_normal((n, d))
    y = rng.standard_normal(n)
    theta = np.array([0.9, 1.1, 1.2, 0.5])
    _, grad = model.exact_mll(x, y, theta, family=FAM)
    hm = np.asarray(ref.h_matrix(x, theta, FAM))
    hinv = np.linalg.inv(hm)
    vy = hinv @ y
    # finite-difference dH/dtheta_k against closed form via autodiff of H
    for kk in range(d + 2):
        def h_of(t):
            th = theta.copy()
            th[kk] = t
            return np.asarray(ref.h_matrix(x, th, FAM))
        eps = 1e-6
        dh = (h_of(theta[kk] + eps) - h_of(theta[kk] - eps)) / (2 * eps)
        want_k = 0.5 * vy @ dh @ vy - 0.5 * np.trace(hinv @ dh)
        np.testing.assert_allclose(float(grad[kk]), want_k, rtol=1e-4, atol=1e-6)


# ----------------------------------------------------------------------
# Estimator theory identities (eqs. 12, 14, 15)
# ----------------------------------------------------------------------


def test_initial_distance_identities():
    """E||u||_H^2 = tr(H^-1) for standard probes and = n for pathwise ones."""
    rng = np.random.default_rng(6)
    n, d, s = 48, 2, 4000
    x = rng.standard_normal((n, d))
    theta = np.array([0.9, 1.1, 1.3, 0.4])
    hm = np.asarray(ref.h_matrix(x, theta, FAM))
    hinv = np.linalg.inv(hm)
    # standard: b = z ~ N(0, I), E[b' H^-1 b] = tr(H^-1)
    z = rng.standard_normal((n, s))
    std_emp = np.mean(np.einsum("ns,nm,ms->s", z, hinv, z))
    np.testing.assert_allclose(std_emp, np.trace(hinv), rtol=0.1)
    # pathwise: b = xi ~ N(0, H), E[b' H^-1 b] = n
    lchol = np.linalg.cholesky(hm)
    xi = lchol @ rng.standard_normal((n, s))
    pw_emp = np.mean(np.einsum("ns,nm,ms->s", xi, hinv, xi))
    np.testing.assert_allclose(pw_emp, n, rtol=0.1)
