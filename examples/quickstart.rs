//! Quickstart: train a GP on the bundled `test` config with the pathwise
//! estimator, warm-started alternating projections, and make predictions.
//!
//!     make artifacts && cargo run --release --example quickstart

use igp::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. data (synthetic UCI-like dataset; see igp::data::registry())
    let ds = igp::data::generate(&igp::data::spec("test")?);
    println!("dataset: n={} d={} test={}", ds.spec.n, ds.spec.d, ds.spec.n_test);

    // 2. compiled model (AOT artifacts from `make artifacts`)
    let rt = igp::runtime::Runtime::cpu()?;
    let model = rt.load_config("artifacts", "test")?;
    let block = model.meta.b;
    let op = XlaOperator::new(model, &ds);

    // 3. coordinator: pathwise estimator + warm-started AP
    let opts = TrainerOptions {
        solver: SolverKind::Ap,
        estimator: EstimatorKind::Pathwise,
        warm_start: true,
        block_size: Some(block),
        ..Default::default()
    };
    let mut trainer = Trainer::new(opts, Box::new(op), &ds);
    let out = trainer.run(30)?;

    for t in out.telemetry.iter().step_by(5) {
        println!(
            "step {:>3}: residuals ry={:.4} rz={:.4}  epochs={:>6.1}  sigma={:.3}",
            t.step,
            t.ry,
            t.rz,
            t.epochs,
            t.theta[t.theta.len() - 1],
        );
    }
    println!(
        "\nfinal: rmse={:.4} llh={:.4}  ({:.2}s total, {:.2}s in the solver)",
        out.final_metrics.rmse, out.final_metrics.llh, out.total_secs, out.solver_secs
    );
    Ok(())
}
