//! Online data arrival: a production service rarely sees its dataset all
//! at once.  Replay a dataset in K chunks and compare two strategies per
//! arrival:
//!
//! * **warm-carried** — one long-lived `Trainer`; each arrival goes
//!   through `Trainer::extend_data`, which grows the operator in place,
//!   zero-pads the warm-start store, extends the probe randomness from a
//!   per-chunk derived stream and invalidates the preconditioner cache —
//!   solver and optimiser progress accumulate across arrivals;
//! * **cold restart** — a fresh `Trainer` on the accumulated data at every
//!   arrival, the only option before the online subsystem existed.
//!
//! The warm-carried run must reach tolerance in fewer total epochs.
//!
//!     cargo run --release --example online -- [dataset] [chunks] [steps_per_arrival] [threads]

use igp::prelude::*;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(String::as_str).unwrap_or("test");
    let chunks_k: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(4);
    let steps: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(3);
    let threads: usize = args.get(3).map(|s| s.parse()).transpose()?.unwrap_or(0);

    let ds = igp::data::generate(&igp::data::spec(dataset)?);
    anyhow::ensure!(
        chunks_k >= 2 && chunks_k <= ds.spec.n,
        "chunks must be in 2..={} for {dataset} (one chunk has no arrivals to compare), got {chunks_k}",
        ds.spec.n
    );
    let (base, arrivals) = ds.replay_chunks(chunks_k);
    println!(
        "{dataset}: n={} in {chunks_k} arrivals of ~{} rows, {steps} outer steps each\n",
        ds.spec.n,
        ds.spec.n / chunks_k
    );

    // both strategies warm-start *within* a run; what the cold baseline
    // loses is the state carried *across* arrivals
    let opts = || TrainerOptions {
        solver: SolverKind::Ap,
        estimator: EstimatorKind::Pathwise,
        warm_start: true,
        lr: 0.05,
        seed: 5,
        threads,
        ..Default::default()
    };
    let tiled = |d: &Dataset| {
        TiledOperator::with_options(d, 16, 128, TiledOptions { tile: 256, threads })
    };

    // warm-carried: one trainer lives across every arrival
    println!("{:>8} {:>7} {:>12} {:>12}", "arrival", "n", "warm epochs", "cold epochs");
    let mut warm = Trainer::new(opts(), Box::new(tiled(&base)), &base);
    let mut warm_total = 0.0;
    let mut cold_total = 0.0;
    let mut acc_x = base.x_train.clone();
    let mut acc_y = base.y_train.clone();
    for arrival in 0..chunks_k {
        if arrival > 0 {
            let (x, y) = &arrivals[arrival - 1];
            warm.extend_data(x, y)?;
            acc_x.append_rows(x);
            acc_y.extend_from_slice(y);
        }
        let warm_out = warm.run(steps)?;
        // cold restart retrains from scratch on the accumulated data
        let acc = ds.with_train(acc_x.clone(), acc_y.clone());
        let mut cold = Trainer::new(opts(), Box::new(tiled(&acc)), &acc);
        let cold_out = cold.run(steps)?;
        warm_total += warm_out.total_epochs;
        cold_total += cold_out.total_epochs;
        println!(
            "{arrival:>8} {:>7} {:>12.1} {:>12.1}",
            warm.operator().n(),
            warm_out.total_epochs,
            cold_out.total_epochs
        );
    }
    println!("\ntotal warm-carried {warm_total:.1} epochs vs cold restarts {cold_total:.1}");
    anyhow::ensure!(
        warm_total < cold_total,
        "warm-carried online training must beat cold restarts"
    );
    Ok(())
}
