//! Bayesian optimisation with iterative-GP hyperparameter learning — the
//! kind of downstream workload the paper's introduction motivates.
//!
//! Maximises a synthetic 2-D black-box (Branin-like) function with a GP
//! surrogate whose hyperparameters are re-learned every few acquisitions
//! using the pathwise estimator + warm-started solvers (DenseOperator
//! backend: BO needs a growing n, which the static-shape XLA artifacts do
//! not cover — the public API makes the backend swap a one-liner).
//!
//!     cargo run --release --example bayesopt

use igp::data::{Dataset, DatasetSpec};
use igp::gp::ExactGp;
use igp::kernels::{Hyperparams, KernelFamily};
use igp::linalg::Mat;
use igp::operators::DenseOperator;
use igp::prelude::*;

/// Black box: negated Branin (maximum ~ -0.398 at three optima).
fn branin(x: f64, y: f64) -> f64 {
    let a = 1.0;
    let b = 5.1 / (4.0 * std::f64::consts::PI.powi(2));
    let c = 5.0 / std::f64::consts::PI;
    let r = 6.0;
    let s = 10.0;
    let t = 1.0 / (8.0 * std::f64::consts::PI);
    -(a * (y - b * x * x + c * x - r).powi(2) + s * (1.0 - t) * x.cos() + s)
}

fn make_dataset(xs: &[(f64, f64)], ys: &[f64]) -> Dataset {
    // package observations in the library's Dataset shape (BO has no
    // test split; reuse the last point to keep shapes nonempty)
    let n = xs.len();
    let x_train = Mat::from_fn(n, 2, |i, j| if j == 0 { xs[i].0 / 5.0 } else { xs[i].1 / 5.0 });
    let spec = DatasetSpec {
        name: "bayesopt",
        paper_n: 0,
        n,
        n_test: 1,
        d: 2,
        true_sigma: 0.05,
        ell_lo: 0.5,
        ell_hi: 1.5,
        cluster_frac: 0.0,
        family: KernelFamily::Matern52,
        seed: 0,
    };
    Dataset {
        spec,
        x_train: x_train.clone(),
        y_train: ys.to_vec(),
        x_test: x_train.gather_rows(&[n - 1]),
        y_test: vec![ys[n - 1]],
        true_hp: Hyperparams::ones(2),
    }
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(42);
    // initial design: 12 random points in the Branin domain
    let mut xs: Vec<(f64, f64)> = (0..12)
        .map(|_| (rng.uniform_in(-5.0, 10.0), rng.uniform_in(0.0, 15.0)))
        .collect();
    let mut ys: Vec<f64> = xs.iter().map(|&(a, b)| branin(a, b)).collect();
    let mut hp = Hyperparams { ell: vec![0.5, 0.5], sigf: 10.0, sigma: 0.1 };

    for round in 0..12 {
        let mut y_std = ys.clone();
        let y_mean = igp::util::stats::mean(&y_std);
        let y_sd = igp::util::stats::variance(&y_std).sqrt().max(1e-9);
        for v in &mut y_std {
            *v = (*v - y_mean) / y_sd;
        }
        let ds = make_dataset(&xs, &y_std);

        // re-learn hyperparameters every 3 acquisitions via the iterative
        // coordinator (pathwise + warm-started CG)
        if round % 3 == 0 {
            let op = DenseOperator::new(&ds, 8, 64);
            let opts = TrainerOptions {
                solver: SolverKind::Cg,
                estimator: EstimatorKind::Pathwise,
                warm_start: true,
                lr: 0.1,
                epoch_cap: 60.0,
                block_size: Some(4),
                seed: round as u64,
                ..Default::default()
            };
            let mut trainer = Trainer::new(opts, Box::new(op), &ds);
            let out = trainer.run(25)?;
            hp = Hyperparams::unpack(&out.theta, 2);
            println!(
                "round {round:>2}: re-learned hp  ell=[{:.2},{:.2}] sigf={:.2} sigma={:.3} ({:.2}s)",
                hp.ell[0], hp.ell[1], hp.sigf, hp.sigma, out.total_secs
            );
        }

        // acquisition: UCB over a random candidate set via the exact GP
        let gp = ExactGp::fit(&ds.x_train, &ds.y_train, &hp, ds.spec.family)?;
        let cands: Vec<(f64, f64)> = (0..512)
            .map(|_| (rng.uniform_in(-5.0, 10.0), rng.uniform_in(0.0, 15.0)))
            .collect();
        let cmat = Mat::from_fn(cands.len(), 2, |i, j| {
            if j == 0 { cands[i].0 / 5.0 } else { cands[i].1 / 5.0 }
        });
        let (mean, var) = gp.predict(&cmat);
        let best = (0..cands.len())
            .max_by(|&a, &b| {
                let ua = mean[a] + 2.0 * var[a].sqrt();
                let ub = mean[b] + 2.0 * var[b].sqrt();
                ua.partial_cmp(&ub).unwrap()
            })
            .unwrap();
        let (nx,ny) = cands[best];
        let fv = branin(nx, ny);
        xs.push((nx, ny));
        ys.push(fv);
        let best_so_far = ys.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        println!(
            "round {round:>2}: acquired ({nx:6.2},{ny:6.2}) f={fv:8.3}  best={best_so_far:8.3}"
        );
    }
    let best = ys.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    println!("\nbest objective found: {best:.3} (global optimum ~ -0.398)");
    anyhow::ensure!(best > -3.0, "BO failed to get close to the optimum");
    Ok(())
}
