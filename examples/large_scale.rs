//! Section-5 scenario: a large dataset where solving to tolerance is
//! infeasible — train under a 10-epoch budget and watch warm starting
//! accumulate solver progress across outer steps (the paper's Fig 10).
//!
//! Runs on the matrix-free multi-threaded [`TiledOperator`] backend, so it
//! needs no compiled artifacts and scales to n where the dense O(n²)
//! backend cannot even allocate H.
//!
//!     cargo run --release --example large_scale -- [dataset] [steps] [tile] [threads]

use igp::prelude::*;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(String::as_str).unwrap_or("threedroad");
    let steps: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(12);
    let tile: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(256);
    let threads: usize = args.get(3).map(|s| s.parse()).transpose()?.unwrap_or(0);

    let ds = igp::data::generate(&igp::data::spec(dataset)?);

    println!(
        "{dataset}: n={} d={} — tiled backend (tile={tile}, threads={}), 10-epoch budget\n",
        ds.spec.n,
        ds.spec.d,
        igp::util::parallel::num_threads(if threads == 0 { None } else { Some(threads) }),
    );
    println!("{:<6} {:>10} {:>10} {:>10}", "", "first rz", "last rz", "test llh");
    for warm in [false, true] {
        let op = TiledOperator::with_options(&ds, 16, 256, TiledOptions { tile, threads });
        let opts = TrainerOptions {
            solver: SolverKind::Ap,
            estimator: EstimatorKind::Pathwise,
            warm_start: warm,
            lr: 0.03,
            max_epochs: Some(10.0),
            seed: 5,
            ..Default::default()
        };
        let mut trainer = Trainer::new(opts, Box::new(op), &ds);
        let out = trainer.run(steps)?;
        let first = out.telemetry.first().unwrap().rz;
        let last = out.telemetry.last().unwrap().rz;
        println!(
            "{:<6} {:>10.4} {:>10.4} {:>10.4}",
            if warm { "warm" } else { "cold" },
            first,
            last,
            out.final_metrics.llh
        );
        if warm {
            anyhow::ensure!(last < first, "warm starting must accumulate progress");
        }
    }
    Ok(())
}
