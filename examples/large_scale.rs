//! Section-5 scenario: a large dataset where solving to tolerance is
//! infeasible — train under a 10-epoch budget and watch warm starting
//! accumulate solver progress across outer steps (the paper's Fig 10).
//!
//!     cargo run --release --example large_scale -- [dataset] [steps]

use igp::prelude::*;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(String::as_str).unwrap_or("threedroad");
    let steps: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(12);

    let ds = igp::data::generate(&igp::data::spec(dataset)?);
    let rt = igp::runtime::Runtime::cpu()?;

    println!("{dataset}: n={} d={} — 10-epoch budget per outer step\n", ds.spec.n, ds.spec.d);
    println!("{:<6} {:>10} {:>10} {:>10}", "", "first rz", "last rz", "test llh");
    for warm in [false, true] {
        let model = rt.load_config("artifacts", dataset)?;
        let block = model.meta.b;
        let op = XlaOperator::new(model, &ds);
        let opts = TrainerOptions {
            solver: SolverKind::Ap,
            estimator: EstimatorKind::Pathwise,
            warm_start: warm,
            lr: 0.03,
            max_epochs: Some(10.0),
            block_size: Some(block),
            seed: 5,
            ..Default::default()
        };
        let mut trainer = Trainer::new(opts, Box::new(op), &ds);
        let out = trainer.run(steps)?;
        let first = out.telemetry.first().unwrap().rz;
        let last = out.telemetry.last().unwrap().rz;
        println!(
            "{:<6} {:>10.4} {:>10.4} {:>10.4}",
            if warm { "warm" } else { "cold" },
            first,
            last,
            out.final_metrics.llh
        );
        if warm {
            anyhow::ensure!(last < first, "warm starting must accumulate progress");
        }
    }
    Ok(())
}
