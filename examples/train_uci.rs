//! End-to-end driver (DESIGN.md §5 validation ladder, step 5): full
//! bilevel marginal-likelihood optimisation on a real (synthetic-UCI)
//! workload, logging the per-step loss/likelihood curve.
//!
//!     cargo run --release --example train_uci -- [dataset] [solver] [estimator] [warm|cold] [steps]
//!
//! e.g.  cargo run --release --example train_uci -- pol ap pathwise warm 40

use igp::prelude::*;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(String::as_str).unwrap_or("pol");
    let solver = SolverKind::parse(args.get(1).map(String::as_str).unwrap_or("ap"))?;
    let estimator = EstimatorKind::parse(args.get(2).map(String::as_str).unwrap_or("pathwise"))?;
    let warm = args.get(3).map(String::as_str).unwrap_or("warm") == "warm";
    let steps: usize = args.get(4).map(|s| s.parse()).transpose()?.unwrap_or(40);

    let ds = igp::data::generate(&igp::data::spec(dataset)?);
    let rt = igp::runtime::Runtime::cpu()?;
    println!("platform: {}", rt.platform());
    let model = rt.load_config("artifacts", dataset)?;
    let block = model.meta.b;
    let op = XlaOperator::new(model, &ds);

    let opts = TrainerOptions {
        solver,
        estimator,
        warm_start: warm,
        block_size: Some(block),
        predict_every: Some(5),
        track_exact: ds.spec.n <= 1024, // exact MLL curve on small configs
        ..Default::default()
    };
    let mut trainer = Trainer::new(opts, Box::new(op), &ds);
    let out = trainer.run(steps)?;

    println!("\nstep  epochs   ry       rz       exact-MLL    test-llh");
    for t in &out.telemetry {
        let mll = t.exact_mll.map(|v| format!("{v:10.1}")).unwrap_or_else(|| "         -".into());
        let llh = t
            .metrics
            .map(|m| format!("{:8.4}", m.llh))
            .unwrap_or_else(|| "       -".into());
        println!(
            "{:>4}  {:>6.1}  {:.5}  {:.5}  {mll}  {llh}",
            t.step, t.epochs, t.ry, t.rz
        );
    }
    println!(
        "\nfinal: rmse={:.4} llh={:.4}  total={:.1}s solver={:.1}s epochs={:.0}",
        out.final_metrics.rmse,
        out.final_metrics.llh,
        out.total_secs,
        out.solver_secs,
        out.total_epochs
    );

    // write the loss curve for EXPERIMENTS.md
    let path = format!("results/train_uci_{dataset}_{}.csv", solver.name());
    let mut w = igp::util::csv::CsvWriter::create(
        &path,
        &["step", "epochs", "ry", "rz", "exact_mll", "test_llh"],
    )?;
    for t in &out.telemetry {
        w.row(&[
            t.step.to_string(),
            format!("{:.2}", t.epochs),
            format!("{:.6}", t.ry),
            format!("{:.6}", t.rz),
            t.exact_mll.map(|v| v.to_string()).unwrap_or_default(),
            t.metrics.map(|m| m.llh.to_string()).unwrap_or_default(),
        ])?;
    }
    w.flush()?;
    println!("curve written to {path}");
    Ok(())
}
