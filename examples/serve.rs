//! Prediction serving over the amortised pathwise posterior: the full
//! train -> serve -> extend -> serve-again lifecycle the serving subsystem
//! exists for.
//!
//! * train on an initial prefix of the dataset;
//! * wrap the trainer in a [`PredictionService`] and answer queries at the
//!   held-out split — the posterior artifact is pulled from the cache the
//!   training tail already populated, so serving costs **zero** extra
//!   solves;
//! * an online arrival (`extend_data`) invalidates the artifact; the next
//!   query refreshes it with exactly **one warm solve** from the carried
//!   solution store — not a cold restart;
//! * keep training after the arrival and serve again.
//!
//!     cargo run --release --example serve -- [dataset] [steps] [batch] [threads]

use igp::prelude::*;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(String::as_str).unwrap_or("test");
    let steps: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(4);
    let batch: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(64);
    let threads: usize = args.get(3).map(|s| s.parse()).transpose()?.unwrap_or(0);

    let ds = igp::data::generate(&igp::data::spec(dataset)?);
    let (base, arrivals) = ds.replay_chunks(2);
    let (x_new, y_new) = &arrivals[0];
    println!(
        "{dataset}: train on {} rows, serve, absorb {} arrival rows, serve again\n",
        base.spec.n,
        x_new.rows
    );

    let op = TiledOperator::with_options(&base, 16, 128, TiledOptions { tile: 256, threads });
    let opts = TrainerOptions {
        solver: SolverKind::Ap,
        estimator: EstimatorKind::Pathwise,
        warm_start: true,
        lr: 0.05,
        seed: 17,
        threads,
        ..Default::default()
    };
    let mut trainer = Trainer::new(opts, Box::new(op), &base);
    let out = trainer.run(steps)?;
    println!(
        "trained {steps} steps: rmse={:.4} llh={:.4} ({:.1} epochs)",
        out.final_metrics.rmse, out.final_metrics.llh, out.total_epochs
    );

    // --- serve: the training tail already published the artifact --------
    let solves_after_training = trainer.solve_count();
    let mut service = PredictionService::new(trainer, ServeOptions { batch, threads });
    let t0 = std::time::Instant::now();
    let m = service.score(&ds.x_test, &ds.y_test)?;
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "serve #1 (test split): rmse={:.4} llh={:.4} ({} rows, {:.0} rows/s)",
        m.rmse,
        m.llh,
        ds.x_test.rows,
        ds.x_test.rows as f64 / secs.max(1e-9)
    );
    anyhow::ensure!(m.rmse.is_finite() && m.llh.is_finite());
    anyhow::ensure!(
        service.trainer().solve_count() == solves_after_training,
        "serving from the cached artifact must not re-solve"
    );

    // --- online arrival: artifact goes stale, refresh is one warm solve -
    service.extend_data(x_new, y_new)?;
    let solves_before_refresh = service.trainer().solve_count();
    let (mean, var) = service.predict(&ds.x_test)?;
    anyhow::ensure!(mean.iter().all(|v| v.is_finite()));
    anyhow::ensure!(var.iter().all(|v| *v > 0.0));
    anyhow::ensure!(
        service.trainer().solve_count() == solves_before_refresh + 1,
        "post-arrival refresh must cost exactly one (warm) solve"
    );
    println!(
        "serve #2 after {}-row arrival: refreshed with one warm solve (n = {})",
        x_new.rows,
        service.trainer().operator().n()
    );

    // --- keep training on the grown dataset, then serve once more -------
    let out = service.trainer_mut().run(steps)?;
    let m = service.score(&ds.x_test, &ds.y_test)?;
    println!(
        "serve #3 after {steps} more steps: rmse={:.4} llh={:.4} ({:.1} epochs)",
        m.rmse, m.llh, out.total_epochs
    );
    anyhow::ensure!(m.rmse.is_finite() && m.llh.is_finite());

    let st = service.stats();
    println!(
        "\nservice counters: {} rows in {} batches; artifact builds={} hits={}",
        st.rows_served, st.batches, st.artifact_builds, st.artifact_hits
    );
    anyhow::ensure!(st.rows_served as usize == 3 * ds.x_test.rows);
    anyhow::ensure!(st.artifact_hits >= 2, "serve cycles should hit the artifact cache");
    Ok(())
}
