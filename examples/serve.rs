//! Prediction serving over the amortised pathwise posterior: the full
//! train -> serve -> extend -> serve-again lifecycle the serving subsystem
//! exists for, including all three staleness policies, the deadline-aware
//! request queue and a two-tenant fleet over one shared artifact cache.
//!
//! * train on an initial prefix of the dataset;
//! * wrap the trainer in a [`PredictionService`] and answer queries at the
//!   held-out split — the posterior artifact is pulled from the cache the
//!   training tail already populated, so serving costs **zero** extra
//!   solves;
//! * an online arrival (`extend_data`) invalidates the artifact; what
//!   happens next is the staleness policy's call:
//!   - `refuse` rejects queries with a typed error until `refresh()`;
//!   - `serve_stale` answers from the retained pre-arrival snapshot —
//!     bitwise the pre-arrival answers, zero solves;
//!   - `refresh_first` pays exactly **one warm solve** from the carried
//!     solution store (not a cold restart), then answers fresh;
//! * deadline-tagged requests drain earliest-deadline-first, coalesced
//!   into shared evaluation batches, bitwise-identical to serving each
//!   request alone;
//! * a [`ModelFleet`] serves two differently-seeded tenants over ONE
//!   shared capacity-bounded artifact cache.
//!
//!     cargo run --release --example serve -- [dataset] [steps] [batch] [threads]

use igp::prelude::*;

fn bitwise_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(String::as_str).unwrap_or("test");
    let steps: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(4);
    let batch: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(64);
    let threads: usize = args.get(3).map(|s| s.parse()).transpose()?.unwrap_or(0);

    let ds = igp::data::generate(&igp::data::spec(dataset)?);
    let (base, arrivals) = ds.replay_chunks(2);
    let (x_new, y_new) = &arrivals[0];
    println!(
        "{dataset}: train on {} rows, serve, absorb {} arrival rows under each \
         staleness policy, serve again\n",
        base.spec.n,
        x_new.rows
    );

    let make_trainer = |seed: u64| -> Trainer {
        let op = TiledOperator::with_options(&base, 16, 128, TiledOptions { tile: 256, threads });
        let opts = TrainerOptions {
            solver: SolverKind::Ap,
            estimator: EstimatorKind::Pathwise,
            warm_start: true,
            lr: 0.05,
            seed,
            threads,
            ..Default::default()
        };
        Trainer::new(opts, Box::new(op), &base)
    };
    let mut trainer = make_trainer(17);
    let out = trainer.run(steps)?;
    println!(
        "trained {steps} steps: rmse={:.4} llh={:.4} ({:.1} epochs)",
        out.final_metrics.rmse, out.final_metrics.llh, out.total_epochs
    );

    // --- serve: the training tail already published the artifact --------
    let solves_after_training = trainer.solve_count();
    let mut service = PredictionService::new(
        trainer,
        ServeOptions { batch, threads, ..Default::default() },
    );
    let t0 = std::time::Instant::now();
    let m = service.score(&ds.x_test, &ds.y_test)?;
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "serve #1 (test split): rmse={:.4} llh={:.4} ({} rows, {:.0} rows/s)",
        m.rmse,
        m.llh,
        ds.x_test.rows,
        ds.x_test.rows as f64 / secs.max(1e-9)
    );
    anyhow::ensure!(m.rmse.is_finite() && m.llh.is_finite());
    anyhow::ensure!(
        service.trainer().solve_count() == solves_after_training,
        "serving from the cached artifact must not re-solve"
    );

    // --- online arrival under each staleness policy ----------------------
    let (mean_pre, var_pre) = service.predict(&ds.x_test)?;
    service.set_policy(StalenessPolicy::Refuse);
    service.extend_data(x_new, y_new)?;

    // refuse: queries inside the staleness window get a typed rejection
    let err = service.predict(&ds.x_test).expect_err("policy refuse must reject");
    println!("policy refuse    : rejected as expected ({err:#})");

    // serve_stale: the retained pre-arrival snapshot answers — bitwise the
    // pre-arrival answers, and not a single extra solve
    service.set_policy(StalenessPolicy::ServeStale);
    let solves = service.trainer().solve_count();
    let (mean_stale, var_stale) = service.predict(&ds.x_test)?;
    anyhow::ensure!(
        service.trainer().solve_count() == solves,
        "serve_stale must not solve"
    );
    anyhow::ensure!(
        bitwise_eq(&mean_stale, &mean_pre) && bitwise_eq(&var_stale, &var_pre),
        "stale answers must be bitwise the pre-arrival answers"
    );
    println!(
        "policy serve_stale: answered {} rows from the pre-arrival snapshot (0 solves)",
        mean_stale.len()
    );

    // refresh_first: exactly one warm solve, then fresh answers
    service.set_policy(StalenessPolicy::RefreshFirst);
    let solves = service.trainer().solve_count();
    let (mean_fresh, var_fresh) = service.predict(&ds.x_test)?;
    anyhow::ensure!(mean_fresh.iter().all(|v| v.is_finite()));
    anyhow::ensure!(var_fresh.iter().all(|v| *v > 0.0));
    anyhow::ensure!(
        service.trainer().solve_count() == solves + 1,
        "post-arrival refresh must cost exactly one (warm) solve"
    );
    println!(
        "policy refresh_first: one warm solve, fresh answers at n = {}",
        service.trainer().operator().n()
    );

    // --- deadline-aware micro-batching -----------------------------------
    // three requests, deadlines 3 / 1 / none: the drain answers them
    // earliest-deadline-first in coalesced batches, each bitwise equal to
    // its direct answer
    let rows = ds.x_test.rows;
    let idx_a: Vec<usize> = (0..rows / 2).collect();
    let idx_b: Vec<usize> = (rows / 2..rows).collect();
    let xa = ds.x_test.gather_rows(&idx_a);
    let xb = ds.x_test.gather_rows(&idx_b);
    let id_a = service.enqueue_with_deadline(&xa, Some(3))?;
    let id_b = service.enqueue_with_deadline(&xb, Some(1))?;
    let id_c = service.enqueue_with_deadline(&xa, None)?;
    let results = service.drain()?;
    let order: Vec<u64> = results.iter().map(|r| r.id).collect();
    anyhow::ensure!(
        order == vec![id_b, id_a, id_c],
        "drain must serve earliest-deadline-first (got {order:?})"
    );
    anyhow::ensure!(
        bitwise_eq(&results[1].mean, &mean_fresh[..rows / 2])
            && bitwise_eq(&results[0].mean, &mean_fresh[rows / 2..]),
        "queued answers must be bitwise the direct answers"
    );
    println!(
        "deadline drain   : {} requests answered EDF in {} rows total",
        results.len(),
        results.iter().map(|r| r.mean.len()).sum::<usize>()
    );

    // --- keep training on the grown dataset, then serve once more --------
    let out = service.trainer_mut().run(steps)?;
    let m = service.score(&ds.x_test, &ds.y_test)?;
    println!(
        "serve after {steps} more steps: rmse={:.4} llh={:.4} ({:.1} epochs)",
        m.rmse, m.llh, out.total_epochs
    );
    anyhow::ensure!(m.rmse.is_finite() && m.llh.is_finite());

    let st = service.stats();
    println!(
        "\nservice counters: {} rows in {} batches; artifact builds={} hits={} \
         stale_rows={} rejected={}",
        st.counters.rows_served,
        st.counters.batches,
        st.counters.artifact_builds,
        st.counters.artifact_hits,
        st.counters.stale_rows_served,
        st.counters.rejected
    );
    println!(
        "latency: p50={:.3}ms p99={:.3}ms ({:.0} rows/s in backend eval)",
        st.p50_ns() as f64 * 1e-6,
        st.p99_ns() as f64 * 1e-6,
        st.rows_per_sec()
    );
    anyhow::ensure!(st.counters.stale_rows_served as usize == rows);
    anyhow::ensure!(st.counters.rejected == 1, "the refuse policy rejection is counted");
    anyhow::ensure!(st.counters.artifact_hits >= 2, "serve cycles should hit the artifact cache");
    anyhow::ensure!(st.latency.count() > 0 && st.p99_ns() >= st.p50_ns());

    // --- two-tenant fleet over one shared artifact cache ------------------
    let mut fleet = ModelFleet::new(2);
    for (name, seed) in [("alpha", 17u64), ("beta", 23u64)] {
        let mut t = make_trainer(seed);
        t.run(steps)?;
        fleet.add_tenant(name, t, ServeOptions { batch, threads, ..Default::default() })?;
    }
    // beta's deadline is earlier: it drains first despite being added last
    fleet.enqueue("alpha", &xa, Some(9))?;
    fleet.enqueue("beta", &xb, Some(1))?;
    let outcome = fleet.drain();
    anyhow::ensure!(outcome.refused.is_empty());
    let served: Vec<&str> = outcome.answered.iter().map(|(n, _)| n.as_str()).collect();
    anyhow::ensure!(served == vec!["beta", "alpha"], "fleet drain is deadline-ordered");
    anyhow::ensure!(fleet.cache().len() <= fleet.cache().capacity());
    println!(
        "\nfleet: served {:?}; shared cache {}/{} entries, builds={} hits={}",
        served,
        fleet.cache().len(),
        fleet.cache().capacity(),
        fleet.cache().builds(),
        fleet.cache().hits()
    );
    Ok(())
}
