//! Chaos isolation across a multi-tenant fleet: injected refresh
//! failures on ONE tenant must not perturb the other tenants' answers
//! (bitwise) or corrupt the shared artifact-cache counters.
//!
//! Two identical fleets run the same traffic; one arms a `refresh~1`
//! fault plan on a single tenant (`beta`).  The faulted tenant degrades
//! gracefully — its `refresh_first` policy downgrades to serving the
//! retained stale snapshot, flagged `degraded` — while every other
//! tenant's answers and per-tenant cache counters stay bit-identical to
//! the fault-free fleet.  Re-arming a benign plan heals `beta`: its next
//! drain pays the deferred refresh and converges bitwise with the
//! fault-free tenant.

use std::sync::Arc;

use igp::coordinator::{Trainer, TrainerOptions};
use igp::data::{Dataset, DatasetSpec};
use igp::estimator::EstimatorKind;
use igp::fault::FaultPlan;
use igp::kernels::{Hyperparams, KernelFamily};
use igp::linalg::Mat;
use igp::operators::DenseOperator;
use igp::serve::{ModelFleet, RequestResult, ServeOptions};
use igp::solvers::SolverKind;
use igp::util::rng::Rng;

const NAMES: [&str; 3] = ["alpha", "beta", "gamma"];
// generous capacity: no LRU churn, so per-tenant counters across the two
// fleets must match *exactly* (an eviction-free baseline isolates the
// fault's effect from LRU noise)
const CACHE_CAP: usize = 6;

fn toy_dataset(rng: &mut Rng, n: usize, n_test: usize, d: usize) -> Dataset {
    let x_train = Mat::from_fn(n, d, |_, _| rng.gaussian());
    let y_train = rng.gaussian_vec(n);
    let x_test = Mat::from_fn(n_test, d, |_, _| rng.gaussian());
    let y_test = rng.gaussian_vec(n_test);
    let spec = DatasetSpec {
        name: "toy",
        paper_n: 0,
        n,
        n_test,
        d,
        true_sigma: 0.3,
        ell_lo: 0.5,
        ell_hi: 1.5,
        cluster_frac: 0.0,
        family: KernelFamily::Rbf,
        seed: 0,
    };
    Dataset { spec, x_train, y_train, x_test, y_test, true_hp: Hyperparams::ones(d) }
}

fn make_trainer(ds: &Dataset, seed: u64) -> Trainer {
    let op = Box::new(DenseOperator::new(ds, 4, 16));
    let opts = TrainerOptions {
        solver: SolverKind::Cg,
        estimator: EstimatorKind::Standard,
        warm_start: true,
        lr: 0.05,
        seed,
        ..Default::default()
    };
    Trainer::new(opts, op, ds)
}

fn build_fleet(datasets: &[Dataset]) -> ModelFleet {
    let mut fleet = ModelFleet::new(CACHE_CAP);
    for (i, name) in NAMES.iter().enumerate() {
        let so = ServeOptions { batch: 16, threads: 1, ..Default::default() };
        fleet.add_tenant(name, make_trainer(&datasets[i], 100 + i as u64), so).unwrap();
    }
    fleet
}

fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Enqueue the same per-tenant queries on both fleets, drain both, and
/// return the answers keyed `(tenant, RequestResult)` in drain order.
fn round(
    chaos: &mut ModelFleet,
    clean: &mut ModelFleet,
    queries: &[(usize, Mat)],
) -> (Vec<(String, RequestResult)>, Vec<(String, RequestResult)>) {
    for (t, x) in queries {
        chaos.enqueue(NAMES[*t], x, None).unwrap();
        clean.enqueue(NAMES[*t], x, None).unwrap();
    }
    let a = chaos.drain();
    let b = clean.drain();
    assert!(a.refused.is_empty(), "chaos fleet refused: {:?}", a.refused);
    assert!(b.refused.is_empty(), "clean fleet refused: {:?}", b.refused);
    (a.answered, b.answered)
}

#[test]
fn refresh_faults_on_one_tenant_leave_the_rest_of_the_fleet_bitwise_intact() {
    let mut data_rng = Rng::new(0xF1EE7);
    let d = 3;
    let datasets: Vec<Dataset> =
        (0..NAMES.len()).map(|_| toy_dataset(&mut data_rng, 24, 4, d)).collect();
    let mut chaos = build_fleet(&datasets);
    let mut clean = build_fleet(&datasets);

    let queries = |rng: &mut Rng| -> Vec<(usize, Mat)> {
        (0..NAMES.len()).map(|t| (t, Mat::from_fn(5, d, |_, _| rng.gaussian()))).collect()
    };

    // round 1: fault-free warm-up, both fleets build every artifact
    let mut qrng = Rng::new(0xC0FFEE);
    let q1 = queries(&mut qrng);
    let (got, want) = round(&mut chaos, &mut clean, &q1);
    assert_eq!(got.len(), want.len());
    for ((gn, g), (wn, w)) in got.iter().zip(&want) {
        assert_eq!(gn, wn);
        assert!(bits_eq(&g.mean, &w.mean) && bits_eq(&g.var, &w.var));
        assert!(!g.stale && !g.degraded);
    }

    // arm refresh failures on beta only, then age beta's artifact with an
    // online arrival (same new data in both fleets)
    let beta = chaos.tenant_mut("beta").unwrap();
    beta.arm_faults(Arc::new(FaultPlan::parse("seed=3;refresh~1").unwrap()));
    let x_new = Mat::from_fn(8, d, |_, _| data_rng.gaussian());
    let y_new = data_rng.gaussian_vec(8);
    chaos.extend_data("beta", &x_new, &y_new).unwrap();
    clean.extend_data("beta", &x_new, &y_new).unwrap();

    // round 2: beta's refresh_first refresh fails in the chaos fleet and
    // degrades to the retained stale snapshot; alpha and gamma must not
    // notice
    let q2 = queries(&mut qrng);
    let (got, want) = round(&mut chaos, &mut clean, &q2);
    let mut beta_rows = 0u64;
    for ((gn, g), (wn, w)) in got.iter().zip(&want) {
        assert_eq!(gn, wn, "drain order perturbed by the injected fault");
        if gn == "beta" {
            assert!(g.stale && g.degraded, "beta did not degrade: {g:?}");
            assert!(!w.stale && !w.degraded, "fault leaked into the clean fleet");
            assert!(g.mean.iter().all(|v| v.is_finite()), "degraded answer is poisoned");
            beta_rows += g.mean.len() as u64;
        } else {
            assert!(
                bits_eq(&g.mean, &w.mean) && bits_eq(&g.var, &w.var),
                "tenant {gn} perturbed by beta's injected refresh failure"
            );
            assert!(!g.stale && !g.degraded);
        }
    }
    assert!(beta_rows > 0, "beta served nothing in round 2");

    // shared-cache counters: the unfaulted tenants' accounting is
    // bit-identical across fleets, beta's failed refresh counted no
    // phantom build, and the degradation is metered
    for name in ["alpha", "gamma"] {
        let g = chaos.stats(name).unwrap().counters;
        let w = clean.stats(name).unwrap().counters;
        assert_eq!(g, w, "tenant {name} counters corrupted by beta's fault");
        assert_eq!(g.degraded_rows_served, 0);
    }
    let gb = chaos.stats("beta").unwrap().counters;
    let wb = clean.stats("beta").unwrap().counters;
    assert_eq!(gb.artifact_builds, 1, "failed refresh must not count a build");
    assert_eq!(wb.artifact_builds, 2, "clean beta pays its refresh build");
    assert_eq!(gb.degraded_rows_served, beta_rows);
    assert_eq!(gb.stale_rows_served, beta_rows);
    assert_eq!(wb.degraded_rows_served, 0);
    assert!(chaos.cache().len() <= CACHE_CAP && clean.cache().len() <= CACHE_CAP);
    let rec = chaos.tenant("beta").unwrap().recovery_stats();
    assert_eq!(rec.retries, 0, "refresh degradation is not a solve retry: {rec:?}");
    // heal beta: re-arm a benign plan; the next drain pays the deferred
    // refresh and beta converges bitwise with the fault-free tenant
    chaos
        .tenant_mut("beta")
        .unwrap()
        .arm_faults(Arc::new(FaultPlan::parse("seed=3").unwrap()));
    let q3 = queries(&mut qrng);
    let (got, want) = round(&mut chaos, &mut clean, &q3);
    for ((gn, g), (wn, w)) in got.iter().zip(&want) {
        assert_eq!(gn, wn);
        assert!(
            bits_eq(&g.mean, &w.mean) && bits_eq(&g.var, &w.var),
            "tenant {gn} did not heal bitwise after disarming"
        );
        assert!(!g.stale && !g.degraded, "tenant {gn} still degraded after healing");
    }
    let gb = chaos.stats("beta").unwrap().counters;
    assert_eq!(gb.artifact_builds, 2, "healed beta pays exactly the deferred refresh");
}
