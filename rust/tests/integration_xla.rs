//! Cross-layer integration: the XLA (Pallas/PJRT) backend must agree with
//! the pure-Rust dense oracle on every operator method, and the full
//! trainer must run end-to-end on compiled artifacts.
//!
//! Requires `make artifacts` (skips gracefully otherwise so `cargo test`
//! works on a fresh checkout).

use igp::coordinator::{run_exact, Trainer, TrainerOptions};
use igp::data;
use igp::estimator::EstimatorKind;
use igp::kernels::Hyperparams;
use igp::linalg::Mat;
use igp::operators::{DenseOperator, KernelOperator, XlaOperator};
use igp::runtime::Runtime;
use igp::solvers::SolverKind;
use igp::util::rng::Rng;

fn artifacts_ready() -> bool {
    cfg!(feature = "xla") && std::path::Path::new("artifacts/test/meta.txt").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_ready() {
            eprintln!(
                "skipping: needs artifacts/ (run `make artifacts`) and the `xla` cargo feature"
            );
            return;
        }
    };
}

fn make_ops() -> (XlaOperator, DenseOperator, data::Dataset) {
    let ds = data::generate(&data::spec("test").unwrap());
    let rt = Runtime::cpu().unwrap();
    let model = rt.load_config("artifacts", "test").unwrap();
    let s = model.meta.s;
    let m = model.meta.m;
    let xla = XlaOperator::new(model, &ds);
    let dense = DenseOperator::new(&ds, s, m);
    (xla, dense, ds)
}

fn set_both(xla: &mut XlaOperator, dense: &mut DenseOperator, hp: &Hyperparams) {
    xla.set_hp(hp);
    dense.set_hp(hp);
}

#[test]
fn xla_hv_matches_dense() {
    require_artifacts!();
    let (mut xla, mut dense, _) = make_ops();
    let hp = Hyperparams { ell: vec![0.8, 1.1, 1.3, 0.9], sigf: 1.2, sigma: 0.3 };
    set_both(&mut xla, &mut dense, &hp);
    let mut rng = Rng::new(0);
    let v = Mat::from_fn(xla.n(), xla.k_width(), |_, _| rng.gaussian());
    let a = xla.hv(&v);
    let b = dense.hv(&v);
    assert!(a.max_abs_diff(&b) < 1e-8, "{}", a.max_abs_diff(&b));
    // and the non-pallas reference artifact agrees too
    let c = xla.hv_ref(&v);
    assert!(a.max_abs_diff(&c) < 1e-8);
}

#[test]
fn xla_k_cols_rows_match_dense() {
    require_artifacts!();
    let (mut xla, mut dense, _) = make_ops();
    let hp = Hyperparams { ell: vec![1.0; 4], sigf: 0.9, sigma: 0.5 };
    set_both(&mut xla, &mut dense, &hp);
    let mut rng = Rng::new(1);
    let b = xla.meta().b;
    let idx: Vec<usize> = (64..64 + b).collect();
    let u = Mat::from_fn(b, xla.k_width(), |_, _| rng.gaussian());
    let a1 = xla.k_cols(&idx, &u);
    let b1 = dense.k_cols(&idx, &u);
    assert!(a1.max_abs_diff(&b1) < 1e-8);
    let v = Mat::from_fn(xla.n(), xla.k_width(), |_, _| rng.gaussian());
    // non-contiguous batch, as SGD samples it
    let idx2 = Rng::new(7).sample_indices(xla.n(), b);
    let a2 = xla.k_rows(&idx2, &v);
    let b2 = dense.k_rows(&idx2, &v);
    assert!(a2.max_abs_diff(&b2) < 1e-8);
}

#[test]
fn xla_grad_quad_matches_dense() {
    require_artifacts!();
    let (mut xla, mut dense, _) = make_ops();
    let hp = Hyperparams { ell: vec![0.7, 1.4, 1.0, 1.2], sigf: 1.1, sigma: 0.4 };
    set_both(&mut xla, &mut dense, &hp);
    let mut rng = Rng::new(2);
    let k = xla.k_width();
    let a = Mat::from_fn(xla.n(), k, |_, _| rng.gaussian());
    let b = Mat::from_fn(xla.n(), k, |_, _| rng.gaussian());
    let mut w = vec![-1.0 / 16.0; k];
    w[0] = 0.5;
    let g1 = xla.grad_quad(&a, &b, &w);
    let g2 = dense.grad_quad(&a, &b, &w);
    assert_eq!(g1.len(), g2.len());
    for (i, (x, y)) in g1.iter().zip(&g2).enumerate() {
        assert!((x - y).abs() < 1e-6 * (1.0 + y.abs()), "comp {i}: {x} vs {y}");
    }
}

#[test]
fn xla_rff_and_predict_match_dense() {
    require_artifacts!();
    let (mut xla, mut dense, _) = make_ops();
    let hp = Hyperparams { ell: vec![1.0; 4], sigf: 1.3, sigma: 0.2 };
    set_both(&mut xla, &mut dense, &hp);
    let mut rng = Rng::new(3);
    let (d, m, s, n) = (xla.d(), xla.m(), xla.s(), xla.n());
    let omega0 = Mat::from_fn(d, m, |_, _| rng.gaussian());
    let wts = Mat::from_fn(2 * m, s, |_, _| rng.gaussian());
    let noise = Mat::from_fn(n, s, |_, _| rng.gaussian());
    let xi1 = xla.rff_eval(&omega0, &wts, &noise);
    let xi2 = dense.rff_eval(&omega0, &wts, &noise);
    assert!(xi1.max_abs_diff(&xi2) < 1e-9, "{}", xi1.max_abs_diff(&xi2));

    let vy = rng.gaussian_vec(n);
    let zhat = Mat::from_fn(n, s, |_, _| rng.gaussian());
    let (m1, s1) = xla.predict(&vy, &zhat, &omega0, &wts);
    let (m2, s2) = dense.predict(&vy, &zhat, &omega0, &wts);
    for (a, b) in m1.iter().zip(&m2) {
        assert!((a - b).abs() < 1e-8);
    }
    assert!(s1.max_abs_diff(&s2) < 1e-8);
}

#[test]
fn xla_exact_mll_matches_rust_exact_gp() {
    require_artifacts!();
    let (mut xla, _, ds) = make_ops();
    let hp = Hyperparams { ell: vec![0.9; 4], sigf: 1.0, sigma: 0.35 };
    xla.set_hp(&hp);
    let (l_xla, g_xla) = xla.exact_mll(&ds.y_train).expect("exact artifact present");
    let gp = igp::gp::ExactGp::fit(&ds.x_train, &ds.y_train, &hp, xla.family()).unwrap();
    let l_rust = gp.mll(&ds.y_train);
    let g_rust = gp.mll_grad();
    assert!((l_xla - l_rust).abs() < 1e-6, "{l_xla} vs {l_rust}");
    for (i, (a, b)) in g_xla.iter().zip(&g_rust).enumerate() {
        assert!((a - b).abs() < 1e-5 * (1.0 + b.abs()), "comp {i}: {a} vs {b}");
    }
}

#[test]
fn trainer_end_to_end_on_xla_backend() {
    require_artifacts!();
    let ds = data::generate(&data::spec("test").unwrap());
    let rt = Runtime::cpu().unwrap();
    for (solver, estimator) in [
        (SolverKind::Cg, EstimatorKind::Pathwise),
        (SolverKind::Ap, EstimatorKind::Standard),
        (SolverKind::Sgd, EstimatorKind::Pathwise),
    ] {
        let model = rt.load_config("artifacts", "test").unwrap();
        let block = model.meta.b;
        let op = XlaOperator::new(model, &ds);
        let opts = TrainerOptions {
            solver,
            estimator,
            warm_start: true,
            block_size: Some(block),
            sgd_lr: Some(8.0),
            epoch_cap: 100.0,
            seed: 11,
            ..Default::default()
        };
        let mut t = Trainer::new(opts, Box::new(op), &ds);
        let out = t.run(5).unwrap();
        assert_eq!(out.telemetry.len(), 5);
        assert!(out.final_metrics.rmse.is_finite());
        assert!(out.final_metrics.llh.is_finite());
        assert!(out.total_epochs > 0.0, "{solver:?}");
    }
}

#[test]
fn exact_trajectory_on_xla_backend() {
    require_artifacts!();
    let ds = data::generate(&data::spec("test").unwrap());
    let rt = Runtime::cpu().unwrap();
    let model = rt.load_config("artifacts", "test").unwrap();
    let mut op = XlaOperator::new(model, &ds);
    let traj = run_exact(&mut op, &ds.y_train, 8, 0.1, 1.0).unwrap();
    assert_eq!(traj.len(), 8);
    assert!(traj.last().unwrap().1 > traj.first().unwrap().1, "MLL must increase");
}
