//! Multi-tenant fleet property test: interleaved traffic through a
//! [`ModelFleet`] over ONE shared capacity-bounded artifact cache must be
//! **bitwise-identical** to a fleet of isolated per-tenant services, while
//! the shared LRU's per-tenant build / hit / eviction counters track an
//! explicit reference model and the cache never exceeds its capacity.
//!
//! The tenants use the Standard estimator deliberately: its artifact
//! builds draw their probes from an evaluation stream keyed by
//! `(seed, step)` and touch no trainer state, so a rebuild forced by a
//! cross-tenant LRU eviction is bitwise the evicted snapshot — which is
//! exactly what makes the shared cache *safe* to bound.

use std::collections::HashMap;

use igp::coordinator::{Trainer, TrainerOptions};
use igp::data::{Dataset, DatasetSpec};
use igp::estimator::EstimatorKind;
use igp::kernels::{Hyperparams, KernelFamily};
use igp::linalg::Mat;
use igp::operators::DenseOperator;
use igp::serve::{
    ModelFleet, PredictionService, ServeCounters, ServeError, ServeOptions, StalenessPolicy,
};
use igp::solvers::SolverKind;
use igp::util::proptest::{check, PropConfig};
use igp::util::rng::Rng;

fn toy_dataset(rng: &mut Rng, n: usize, n_test: usize, d: usize) -> Dataset {
    let x_train = Mat::from_fn(n, d, |_, _| rng.gaussian());
    let y_train = rng.gaussian_vec(n);
    let x_test = Mat::from_fn(n_test, d, |_, _| rng.gaussian());
    let y_test = rng.gaussian_vec(n_test);
    let spec = DatasetSpec {
        name: "toy",
        paper_n: 0,
        n,
        n_test,
        d,
        true_sigma: 0.3,
        ell_lo: 0.5,
        ell_hi: 1.5,
        cluster_frac: 0.0,
        family: KernelFamily::Rbf,
        seed: 0,
    };
    Dataset { spec, x_train, y_train, x_test, y_test, true_hp: Hyperparams::ones(d) }
}

fn make_trainer(ds: &Dataset, seed: u64) -> Trainer {
    let op = Box::new(DenseOperator::new(ds, 4, 16));
    let opts = TrainerOptions {
        solver: SolverKind::Cg,
        estimator: EstimatorKind::Standard,
        warm_start: true,
        lr: 0.05,
        seed,
        ..Default::default()
    };
    // deliberately no run(): theta stays at its init, so cache keys vary
    // only in (tenant, n) and Standard rebuilds are bitwise reproducible
    Trainer::new(opts, op, ds)
}

fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Reference model of the shared LRU: keys in recency order (front =
/// next victim), per-tenant counters written into `exp`.
struct LruModel {
    cap: usize,
    keys: Vec<(usize, usize)>, // (tenant index, n)
}

impl LruModel {
    /// One serve/refresh-time artifact access: a hit refreshes recency, a
    /// miss builds (evicting the LRU entry of a full cache, charged to the
    /// victim's tenant).
    fn access(&mut self, exp: &mut [ServeCounters], t: usize, n: usize) {
        if let Some(pos) = self.keys.iter().position(|k| *k == (t, n)) {
            exp[t].artifact_hits += 1;
            let k = self.keys.remove(pos);
            self.keys.push(k);
        } else {
            if self.keys.len() >= self.cap {
                let (victim, _) = self.keys.remove(0);
                exp[victim].artifact_evictions += 1;
            }
            exp[t].artifact_builds += 1;
            self.keys.push((t, n));
        }
    }

    /// Online arrival: the tenant's snapshots drop, everyone else's stay.
    fn invalidate(&mut self, t: usize) {
        self.keys.retain(|k| k.0 != t);
    }
}

#[test]
fn prop_fleet_traffic_is_bitwise_isolated_and_lru_accounted() {
    const NAMES: [&str; 3] = ["alpha", "beta", "gamma"];
    const CACHE_CAP: usize = 2; // 3 tenants over 2 slots: constant churn
    check(
        "serve_fleet_model",
        PropConfig { cases: 6, max_size: 6, ..Default::default() },
        |rng, size| {
            let gamma_cap = 6 + rng.below(4); // row admission cap, gamma only
            let d = 1 + rng.below(3);
            let batch = 1 + rng.below(5);

            let mut fleet = ModelFleet::new(CACHE_CAP);
            let mut mirrors: Vec<PredictionService> = Vec::new();
            let mut ns: Vec<usize> = Vec::new();
            for (i, name) in NAMES.iter().enumerate() {
                let n = 16 + rng.below(8 + 4 * size.max(1));
                let ds = toy_dataset(rng, n, 4, d);
                let seed = 100 + size as u64 * 10 + i as u64;
                let queue_cap = if i == 2 { gamma_cap } else { 0 };
                let so = ServeOptions { batch, threads: 1, queue_cap, ..Default::default() };
                fleet
                    .add_tenant(name, make_trainer(&ds, seed), so)
                    .map_err(|e| e.to_string())?;
                // the isolated reference: an identical trainer behind a
                // plain service with a PRIVATE cache and different batching
                // — parity across them is the whole point of the test
                let mso = ServeOptions { batch: 32, threads: 1, ..Default::default() };
                mirrors.push(PredictionService::new(make_trainer(&ds, seed), mso));
                ns.push(n);
            }

            let mut lru = LruModel { cap: CACHE_CAP, keys: Vec::new() };
            let mut exp = vec![ServeCounters::default(); NAMES.len()];
            // (id, deadline, rows) per tenant, in arrival order
            let mut pending: Vec<Vec<(u64, Option<u64>, usize)>> =
                vec![Vec::new(); NAMES.len()];
            let mut stash: Vec<HashMap<u64, Mat>> = vec![HashMap::new(); NAMES.len()];

            for step in 1..=10 {
                let t = rng.below(NAMES.len());
                let name = NAMES[t];
                match rng.below(5) {
                    0 | 1 => {
                        // admit a deadline-tagged request (gamma may bounce
                        // off its row cap — typed, counted, queue untouched)
                        let rows = 1 + rng.below(4);
                        let x = Mat::from_fn(rows, d, |_, _| rng.gaussian());
                        let deadline =
                            if rng.below(3) == 0 { None } else { Some(rng.below(10) as u64) };
                        let queued: usize = pending[t].iter().map(|p| p.2).sum();
                        let res = fleet.enqueue(name, &x, deadline);
                        if t == 2 && queued + rows > gamma_cap {
                            match res {
                                Err(ServeError::QueueFull { .. }) => exp[t].rejected += 1,
                                other => {
                                    return Err(format!(
                                        "op {step}: expected QueueFull, got {other:?}"
                                    ))
                                }
                            }
                        } else {
                            let id = res.map_err(|e| format!("op {step}: {e}"))?;
                            stash[t].insert(id, x);
                            pending[t].push((id, deadline, rows));
                        }
                    }
                    2 => {
                        // fleet-wide drain: tenants by earliest deadline
                        // (insertion order breaks ties), EDF within each
                        let mut order: Vec<usize> =
                            (0..NAMES.len()).filter(|&i| !pending[i].is_empty()).collect();
                        order.sort_by_key(|&i| {
                            (
                                pending[i].iter().filter_map(|p| p.1).min().unwrap_or(u64::MAX),
                                i,
                            )
                        });
                        let mut expect_ids = Vec::new();
                        for &i in &order {
                            let mut reqs = pending[i].clone();
                            reqs.sort_by_key(|p| (p.1.unwrap_or(u64::MAX), p.0));
                            let rows: usize = reqs.iter().map(|p| p.2).sum();
                            lru.access(&mut exp, i, ns[i]);
                            exp[i].rows_served += rows as u64;
                            exp[i].batches += ((rows + batch - 1) / batch) as u64;
                            expect_ids.extend(reqs.iter().map(|p| (i, p.0)));
                            pending[i].clear();
                        }
                        let out = fleet.drain();
                        if !out.refused.is_empty() {
                            return Err(format!(
                                "op {step}: unexpected refusals {:?}",
                                out.refused
                            ));
                        }
                        let got: Vec<(usize, u64)> = out
                            .answered
                            .iter()
                            .map(|(n, r)| {
                                (NAMES.iter().position(|x| x == n).unwrap(), r.id)
                            })
                            .collect();
                        if got != expect_ids {
                            return Err(format!(
                                "op {step}: drain order {got:?}, expected {expect_ids:?}"
                            ));
                        }
                        // bitwise parity with the isolated services
                        for (nm, r) in &out.answered {
                            let i = NAMES.iter().position(|x| x == nm).unwrap();
                            let x = stash[i]
                                .remove(&r.id)
                                .ok_or_else(|| format!("op {step}: unknown id {}", r.id))?;
                            let (mean, var) =
                                mirrors[i].predict(&x).map_err(|e| e.to_string())?;
                            if !bits_eq(&r.mean, &mean) || !bits_eq(&r.var, &var) {
                                return Err(format!(
                                    "op {step}: tenant {nm} request {} drifted from its \
                                     isolated mirror",
                                    r.id
                                ));
                            }
                            if r.stale {
                                return Err(format!(
                                    "op {step}: refresh_first must never serve stale"
                                ));
                            }
                        }
                    }
                    3 => {
                        // online arrival: same chunk to tenant and mirror;
                        // only this tenant's shared-cache entries drop
                        let rows = 1 + rng.below(3);
                        let x = Mat::from_fn(rows, d, |_, _| rng.gaussian());
                        let y = rng.gaussian_vec(rows);
                        fleet.extend_data(name, &x, &y).map_err(|e| e.to_string())?;
                        mirrors[t].extend_data(&x, &y).map_err(|e| e.to_string())?;
                        lru.invalidate(t);
                        ns[t] += rows;
                    }
                    _ => {
                        // explicit refresh: pays the build/hit, serves no rows
                        fleet.refresh(name).map_err(|e| e.to_string())?;
                        lru.access(&mut exp, t, ns[t]);
                    }
                }

                // invariants after every op
                let len = fleet.cache().len();
                if len != lru.keys.len() || len > CACHE_CAP {
                    return Err(format!(
                        "op {step}: shared cache holds {len} entries, model {} (cap {})",
                        lru.keys.len(),
                        CACHE_CAP
                    ));
                }
                for (i, name) in NAMES.iter().enumerate() {
                    let got = fleet.stats(name).unwrap().counters;
                    if got != exp[i] {
                        return Err(format!(
                            "op {step}: tenant {name} counters {got:?}, expected {:?}",
                            exp[i]
                        ));
                    }
                }
                let pend: usize = pending.iter().flatten().map(|p| p.2).sum();
                if fleet.pending_rows() != pend {
                    return Err(format!(
                        "op {step}: fleet queues {} rows, model {pend}",
                        fleet.pending_rows()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn refused_tenants_keep_their_queues_and_the_rest_still_serve() {
    let mut rng = Rng::new(3);
    let d = 2;
    let ds_a = toy_dataset(&mut rng, 20, 4, d);
    let ds_b = toy_dataset(&mut rng, 24, 4, d);
    let mut fleet = ModelFleet::new(2);
    fleet
        .add_tenant(
            "strict",
            make_trainer(&ds_a, 1),
            ServeOptions {
                batch: 8,
                threads: 1,
                policy: StalenessPolicy::Refuse,
                ..Default::default()
            },
        )
        .unwrap();
    fleet
        .add_tenant(
            "fresh",
            make_trainer(&ds_b, 2),
            ServeOptions { batch: 8, threads: 1, ..Default::default() },
        )
        .unwrap();
    // duplicate names are rejected up front
    assert!(fleet.add_tenant("fresh", make_trainer(&ds_b, 9), Default::default()).is_err());

    // put "strict" inside a staleness window
    let xa = Mat::from_fn(3, d, |_, _| rng.gaussian());
    fleet.predict("strict", &xa).unwrap();
    let chunk = Mat::from_fn(2, d, |_, _| rng.gaussian());
    let y = rng.gaussian_vec(2);
    fleet.extend_data("strict", &chunk, &y).unwrap();

    fleet.enqueue("strict", &xa, Some(1)).unwrap();
    let xb = Mat::from_fn(2, d, |_, _| rng.gaussian());
    fleet.enqueue("fresh", &xb, Some(5)).unwrap();

    let out = fleet.drain();
    assert_eq!(out.answered.len(), 1, "the fresh tenant must still be served");
    assert_eq!(out.answered[0].0, "fresh");
    assert_eq!(out.refused.len(), 1);
    assert_eq!(out.refused[0].0, "strict");
    assert!(matches!(out.refused[0].1, ServeError::Stale { .. }));
    // nothing dropped: the refused queue survives until refresh()
    assert_eq!(fleet.tenant("strict").unwrap().pending_rows(), 3);
    fleet.refresh("strict").unwrap();
    let served = fleet.drain_tenant("strict").unwrap();
    assert_eq!(served.len(), 1);
    assert_eq!(served[0].mean.len(), 3);

    // unknown tenants get a typed error, not a panic
    assert!(matches!(
        fleet.enqueue("nobody", &xa, None),
        Err(ServeError::UnknownTenant { .. })
    ));
}
