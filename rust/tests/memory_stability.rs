//! Regression test for the PJRT argument-buffer leak: the literal-args
//! `execute` path of this xla_extension build leaks ~arg-size bytes per
//! call, which OOM-killed long training runs.  The runtime therefore uses
//! caller-managed `PjRtBuffer`s (Model::call_b); this test pins the fix by
//! asserting bounded RSS growth over many operator calls.

use igp::kernels::Hyperparams;
use igp::linalg::Mat;
use igp::operators::{KernelOperator, XlaOperator};
use igp::util::rng::Rng;

fn rss_bytes() -> f64 {
    let s = std::fs::read_to_string("/proc/self/statm").unwrap_or_default();
    let pages: f64 = s
        .split_whitespace()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.0);
    pages * 4096.0
}

#[test]
fn operator_calls_do_not_leak() {
    if !cfg!(feature = "xla") || !std::path::Path::new("artifacts/test/meta.txt").exists() {
        eprintln!("skipping: needs artifacts/ and the `xla` cargo feature");
        return;
    }
    let ds = igp::data::generate(&igp::data::spec("test").unwrap());
    let rt = igp::runtime::Runtime::cpu().unwrap();
    let model = rt.load_config("artifacts", "test").unwrap();
    let mut op = XlaOperator::new(model, &ds);
    op.set_hp(&Hyperparams { ell: vec![1.0; 4], sigf: 1.0, sigma: 0.3 });
    let mut rng = Rng::new(0);
    let v = Mat::from_fn(op.n(), op.k_width(), |_, _| rng.gaussian());
    // warm up allocators / caches
    for _ in 0..50 {
        let _ = op.hv(&v);
    }
    let before = rss_bytes();
    for _ in 0..1000 {
        let _ = op.hv(&v);
    }
    let growth = rss_bytes() - before;
    // leaky path grew ~27 KB/call (~27 MB over 1000); fixed path is flat.
    assert!(
        growth < 8e6,
        "RSS grew by {:.1} MB over 1000 calls — argument buffers are leaking",
        growth / 1e6
    );
}
