//! Property tests for the kernel panel engine (`igp::kernels::panel`):
//!
//! * panel evaluation matches the retained scalar `kval` reference within
//!   1e-8 across all kernel families, ARD lengthscales, ragged tile tails
//!   and duplicate/near-duplicate rows (the Gram-trick cancellation clamp);
//! * tiled == dense `hv` is **bitwise** on the panel path for every
//!   thread count and tile size (both backends share the panel fills and
//!   `Mat::matmul`'s accumulation order);
//! * `hv`/`hv_into` are bit-deterministic across repeated calls, buffer
//!   reuse, thread counts and extensions (regression for the old
//!   thread-partial reduction scheme).

use igp::data::{Dataset, DatasetSpec};
use igp::kernels::panel::{self, ScaledX};
use igp::kernels::{self, Hyperparams, KernelFamily};
use igp::linalg::Mat;
use igp::operators::{DenseOperator, HvScratch, KernelOperator, TiledOperator, TiledOptions};
use igp::prop_assert;
use igp::util::proptest::{check, PropConfig};
use igp::util::rng::Rng;

fn random_family(rng: &mut Rng) -> KernelFamily {
    match rng.below(4) {
        0 => KernelFamily::Matern12,
        1 => KernelFamily::Matern32,
        2 => KernelFamily::Matern52,
        _ => KernelFamily::Rbf,
    }
}

fn random_hp(rng: &mut Rng, d: usize) -> Hyperparams {
    Hyperparams {
        // genuinely ARD: every dimension draws its own lengthscale
        ell: (0..d).map(|_| rng.uniform_in(0.3, 2.5)).collect(),
        sigf: rng.uniform_in(0.5, 1.5),
        sigma: rng.uniform_in(0.1, 0.9),
    }
}

/// Random inputs with planted exact-duplicate and near-duplicate rows —
/// the worst case for the Gram trick's `‖xi‖² + ‖xj‖² − 2⟨xi,xj⟩`
/// cancellation.  Exact duplicates clamp to a bit-exact sigf² (the clamp
/// plus the shared-dot diagonal property); the near-duplicate offset of
/// 1e-4 keeps the true squared distance well above the ~1e-13
/// cancellation noise floor, which is what a 1e-8 agreement with the
/// scalar reference requires for the sqrt-amplifying Matérn families —
/// still a ~1e-10 relative cancellation in the Gram expression.
fn inputs_with_duplicates(rng: &mut Rng, n: usize, d: usize) -> Mat {
    let mut x = Mat::from_fn(n, d, |_, _| rng.gaussian());
    if n >= 4 {
        let r0 = x.row(0).to_vec();
        x.row_mut(1).copy_from_slice(&r0); // exact duplicate
        let mut r2 = x.row(2).to_vec();
        r2[0] += 1e-4; // near-duplicate
        x.row_mut(3).copy_from_slice(&r2);
    }
    x
}

#[test]
fn prop_panel_matches_kval_reference() {
    check(
        "panel_vs_kval",
        PropConfig { cases: 32, max_size: 24, ..Default::default() },
        |rng, size| {
            let n = 4 + rng.below(4 + 4 * size.max(1)); // rarely a multiple of 4: ragged tails
            let d = 1 + rng.below(6);
            let family = random_family(rng);
            let x = inputs_with_duplicates(rng, n, d);
            let hp = random_hp(rng, d);
            let sf2 = hp.sigf * hp.sigf;
            let sx = ScaledX::new(&x, &hp.ell);
            let km = panel::cross_matrix(&sx, &sx, sf2, family);
            for i in 0..n {
                for j in 0..n {
                    let want = kernels::kval(x.row(i), x.row(j), &hp, family);
                    prop_assert!(
                        (km[(i, j)] - want).abs() <= 1e-8,
                        "{family:?} n={n} d={d} ({i},{j}): panel {} vs kval {want}",
                        km[(i, j)]
                    );
                    prop_assert!(
                        km[(i, j)] <= sf2 + 1e-12,
                        "clamp failed: k({i},{j}) = {} > sigf^2 = {sf2}",
                        km[(i, j)]
                    );
                }
                // the diagonal is exact: the cached norm and the
                // cross-product share one dot, so sq_ii clamps to 0
                prop_assert!(
                    km[(i, i)].to_bits() == sf2.to_bits(),
                    "diag {i}: {} vs sigf^2 {sf2}",
                    km[(i, i)]
                );
            }
            // exact duplicates collapse to a bit-exact sigf^2 (clamp +
            // shared-dot property), matching kval's zero-distance value
            if n >= 4 {
                prop_assert!(
                    km[(0, 1)].to_bits() == sf2.to_bits(),
                    "duplicate pair: {} vs sigf^2 {sf2}",
                    km[(0, 1)]
                );
            }
            // ragged sub-panels reproduce the same bits as the full fill
            let i0 = rng.below(n);
            let j0 = rng.below(n);
            let w = 1 + rng.below(n - j0);
            let rows = 1 + rng.below(n - i0);
            let mut sub = vec![0.0; rows * w];
            panel::fill_panel(&sx, i0, i0 + rows, &sx, j0, j0 + w, sf2, family, &mut sub);
            for r in 0..rows {
                for c in 0..w {
                    prop_assert!(
                        sub[r * w + c].to_bits() == km[(i0 + r, j0 + c)].to_bits(),
                        "sub-panel ({},{}) differs from full fill",
                        i0 + r,
                        j0 + c
                    );
                }
            }
            Ok(())
        },
    );
}

fn toy_dataset(rng: &mut Rng, n: usize, d: usize, family: KernelFamily) -> Dataset {
    let x_train = inputs_with_duplicates(rng, n, d);
    let y_train = rng.gaussian_vec(n);
    let x_test = Mat::from_fn(4, d, |_, _| rng.gaussian());
    let y_test = rng.gaussian_vec(4);
    let spec = DatasetSpec {
        name: "toy",
        paper_n: 0,
        n,
        n_test: 4,
        d,
        true_sigma: 0.3,
        ell_lo: 0.5,
        ell_hi: 1.5,
        cluster_frac: 0.0,
        family,
        seed: 0,
    };
    Dataset { spec, x_train, y_train, x_test, y_test, true_hp: Hyperparams::ones(d) }
}

#[test]
fn prop_hv_is_bitwise_tiled_eq_dense_for_every_thread_count() {
    check(
        "panel_hv_bitwise_parity",
        PropConfig { cases: 20, max_size: 16, ..Default::default() },
        |rng, size| {
            let n = 8 + rng.below(8 + 6 * size.max(1));
            let d = 1 + rng.below(5);
            let family = random_family(rng);
            let ds = toy_dataset(rng, n, d, family);
            let hp = random_hp(rng, d);
            let s = 1 + rng.below(4);
            let mut dense = DenseOperator::new(&ds, s, 8);
            dense.set_hp(&hp);
            let v = Mat::from_fn(n, s + 1, |_, _| rng.gaussian());
            let want = dense.hv(&v);
            let tile = match rng.below(3) {
                0 => 1,
                1 => 1 + rng.below(n),
                _ => n + 1 + rng.below(32),
            };
            for threads in 1..=4 {
                let mut tiled =
                    TiledOperator::with_options(&ds, s, 8, TiledOptions { tile, threads });
                tiled.set_hp(&hp);
                let got = tiled.hv(&v);
                for (i, (a, b)) in got.data.iter().zip(&want.data).enumerate() {
                    prop_assert!(
                        a.to_bits() == b.to_bits(),
                        "tile={tile} threads={threads} elem {i}: {a} vs {b}"
                    );
                }
            }
            Ok(())
        },
    );
}

#[test]
fn hv_into_is_deterministic_across_reuse_threads_and_extension() {
    // regression for the old scheme: `hv` summed thread partials into a
    // freshly zeroed Mat each call.  The panel path has no partials —
    // output rows are disjoint — and must be bit-stable across repeated
    // calls, dirty-buffer reuse, every thread count and online extension.
    let mut rng = Rng::new(42);
    let ds = toy_dataset(&mut rng, 97, 3, KernelFamily::Matern52);
    let hp = Hyperparams { ell: vec![0.8, 1.3, 0.6], sigf: 1.2, sigma: 0.35 };
    let v = Mat::from_fn(97, 4, |_, _| rng.gaussian());

    let mut reference: Option<Mat> = None;
    for threads in [1, 2, 3, 5] {
        let mut op =
            TiledOperator::with_options(&ds, 3, 8, TiledOptions { tile: 17, threads });
        op.set_hp(&hp);
        let scratch = HvScratch::default();
        let mut out = Mat::from_fn(97, 4, |_, _| f64::NAN); // dirty, incl. NaN
        op.hv_into(&v, &mut out, &scratch);
        let first = out.clone();
        op.hv_into(&v, &mut out, &scratch); // scratch + buffer reuse
        assert_eq!(out.data, first.data, "threads={threads}: reuse changed bits");
        assert_eq!(op.hv(&v).data, first.data, "threads={threads}: hv != hv_into");
        match &reference {
            None => reference = Some(first),
            Some(want) => assert_eq!(
                first.data, want.data,
                "threads={threads}: thread count changed bits"
            ),
        }
    }

    // extension keeps determinism and the bitwise dense parity
    let mut tiled =
        TiledOperator::with_options(&ds, 3, 8, TiledOptions { tile: 17, threads: 3 });
    tiled.set_hp(&hp);
    let mut dense = DenseOperator::new(&ds, 3, 8);
    dense.set_hp(&hp);
    let chunk = Mat::from_fn(21, 3, |_, _| rng.gaussian());
    tiled.extend(&chunk).unwrap();
    dense.extend(&chunk).unwrap();
    let v2 = Mat::from_fn(tiled.n(), 4, |_, _| rng.gaussian());
    let a = tiled.hv(&v2);
    let b = dense.hv(&v2);
    assert!(a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits()));
    assert_eq!(tiled.hv(&v2), a);
}

#[test]
fn prop_k_cols_k_rows_and_predict_are_bitwise_across_backends() {
    // the panel engine routes every kernel-evaluation site of both
    // backends through the same fills, so the remaining operator products
    // are bitwise too — not just hv
    check(
        "panel_products_bitwise_parity",
        PropConfig { cases: 16, max_size: 12, ..Default::default() },
        |rng, size| {
            let n = 8 + rng.below(8 + 6 * size.max(1));
            let d = 1 + rng.below(5);
            let family = random_family(rng);
            let ds = toy_dataset(rng, n, d, family);
            let hp = random_hp(rng, d);
            let s = 1 + rng.below(3);
            let m = 4 + rng.below(8);
            let tile = 1 + rng.below(n + 8);
            let threads = 1 + rng.below(4);
            let mut dense = DenseOperator::new(&ds, s, m);
            dense.set_hp(&hp);
            let mut tiled =
                TiledOperator::with_options(&ds, s, m, TiledOptions { tile, threads });
            tiled.set_hp(&hp);

            let bsz = 1 + rng.below(n);
            let idx = rng.sample_indices(n, bsz);
            let u = Mat::from_fn(bsz, s + 1, |_, _| rng.gaussian());
            let bits_eq = |a: &Mat, b: &Mat| {
                a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits())
            };
            prop_assert!(
                bits_eq(&tiled.k_cols(&idx, &u), &dense.k_cols(&idx, &u)),
                "k_cols differs in bits (tile={tile} threads={threads})"
            );
            let v = Mat::from_fn(n, s + 1, |_, _| rng.gaussian());
            prop_assert!(
                bits_eq(&tiled.k_rows(&idx, &v), &dense.k_rows(&idx, &v)),
                "k_rows differs in bits (tile={tile} threads={threads})"
            );

            let omega0 = Mat::from_fn(d, m, |_, _| rng.gaussian());
            let wts = Mat::from_fn(2 * m, s, |_, _| rng.gaussian());
            let zhat = Mat::from_fn(n, s, |_, _| rng.gaussian());
            let vy = rng.gaussian_vec(n);
            let xq = Mat::from_fn(1 + rng.below(16), d, |_, _| rng.gaussian());
            let (m1, s1) = tiled.predict_at(&xq, &vy, &zhat, &omega0, &wts).unwrap();
            let (m2, s2) = dense.predict_at(&xq, &vy, &zhat, &omega0, &wts).unwrap();
            prop_assert!(
                m1.iter().zip(&m2).all(|(a, b)| a.to_bits() == b.to_bits()),
                "predict_at mean differs in bits"
            );
            prop_assert!(bits_eq(&s1, &s2), "predict_at samples differ in bits");
            Ok(())
        },
    );
}
