//! Property tests: the row-sharded `ShardedOperator` must agree
//! **bitwise** with the monolithic `TiledOperator` on every
//! `KernelOperator` method, across random draws of n, d, probe count,
//! tile size, thread count, kernel family and shard count — including
//! ragged last shards, shard counts clamped at n, and post-`extend`
//! growth.  The contract is stronger than the tiled-vs-dense tolerance
//! suite: sharding is a *layout* change, so every bit must survive it.
//!
//! The one documented exception is [`ShardedOperator::hv_shard_partial`]:
//! folding separately accumulated per-shard partials reassociates the
//! column sweep, so the fold matches `hv` to FP tolerance, not bitwise.

use igp::coordinator::{Trainer, TrainerOptions};
use igp::data::{self, Dataset, DatasetSpec};
use igp::estimator::EstimatorKind;
use igp::kernels::{Hyperparams, KernelFamily};
use igp::linalg::Mat;
use igp::operators::{
    DenseOperator, HvScratch, KernelOperator, ShardedOperator, TiledOperator, TiledOptions,
};
use igp::solvers::SolverKind;
use igp::util::proptest::{check, PropConfig};
use igp::util::rng::Rng;

fn random_family(rng: &mut Rng) -> KernelFamily {
    match rng.below(4) {
        0 => KernelFamily::Matern12,
        1 => KernelFamily::Matern32,
        2 => KernelFamily::Matern52,
        _ => KernelFamily::Rbf,
    }
}

fn toy_dataset(rng: &mut Rng, n: usize, n_test: usize, d: usize, family: KernelFamily) -> Dataset {
    let x_train = Mat::from_fn(n, d, |_, _| rng.gaussian());
    let y_train = rng.gaussian_vec(n);
    let x_test = Mat::from_fn(n_test, d, |_, _| rng.gaussian());
    let y_test = rng.gaussian_vec(n_test);
    let spec = DatasetSpec {
        name: "toy",
        paper_n: 0,
        n,
        n_test,
        d,
        true_sigma: 0.3,
        ell_lo: 0.5,
        ell_hi: 1.5,
        cluster_frac: 0.0,
        family,
        seed: 0,
    };
    Dataset {
        spec,
        x_train,
        y_train,
        x_test,
        y_test,
        true_hp: Hyperparams::ones(d),
    }
}

/// One random case: the same dataset, hyperparameters, tile size and
/// thread count behind a monolithic tiled operator and a sharded one.
struct Case {
    ds: Dataset,
    tiled: TiledOperator,
    sharded: ShardedOperator,
    shards: usize,
}

fn random_case_with_shards(rng: &mut Rng, size: usize, shards: usize) -> Case {
    let n = 8 + rng.below(8 + 6 * size.max(1));
    let n_test = 1 + rng.below(8);
    let d = 1 + rng.below(5);
    let s = 1 + rng.below(4);
    let m = 4 + rng.below(12);
    let family = random_family(rng);
    // tile sizes deliberately include 1, non-divisors of n, and > n
    let tile = match rng.below(4) {
        0 => 1,
        1 => 1 + rng.below(n),
        2 => n,
        _ => n + 1 + rng.below(64),
    };
    let threads = 1 + rng.below(4);
    let ds = toy_dataset(rng, n, n_test, d, family);
    let hp = Hyperparams {
        ell: (0..d).map(|_| rng.uniform_in(0.4, 2.0)).collect(),
        sigf: rng.uniform_in(0.5, 1.5),
        sigma: rng.uniform_in(0.1, 0.9),
    };
    let opts = TiledOptions { tile, threads };
    let mut tiled = TiledOperator::with_options(&ds, s, m, opts.clone());
    tiled.set_hp(&hp);
    let mut sharded = ShardedOperator::with_options(&ds, s, m, opts, shards);
    sharded.set_hp(&hp);
    Case { ds, tiled, sharded, shards }
}

fn random_case(rng: &mut Rng, size: usize) -> Case {
    // the issue's canonical shard counts; the clamp-at-n and deep-ragged
    // regimes get their own generator below
    let shards = [1usize, 2, 3, 5, 8][rng.below(5)];
    random_case_with_shards(rng, size, shards)
}

fn bitwise(label: &str, got: &Mat, want: &Mat) -> Result<(), String> {
    if (got.rows, got.cols) != (want.rows, want.cols) {
        return Err(format!(
            "{label}: shape ({}, {}) vs ({}, {})",
            got.rows, got.cols, want.rows, want.cols
        ));
    }
    bitwise_slice(label, &got.data, &want.data)
}

fn bitwise_slice(label: &str, got: &[f64], want: &[f64]) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{label}: len {} vs {}", got.len(), want.len()));
    }
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        if a.to_bits() != b.to_bits() {
            return Err(format!(
                "{label}: element {i}: {a:e} vs {b:e} ({:#018x} vs {:#018x})",
                a.to_bits(),
                b.to_bits()
            ));
        }
    }
    Ok(())
}

fn close(label: &str, got: &Mat, want: &Mat) -> Result<(), String> {
    if (got.rows, got.cols) != (want.rows, want.cols) {
        return Err(format!(
            "{label}: shape ({}, {}) vs ({}, {})",
            got.rows, got.cols, want.rows, want.cols
        ));
    }
    let scale = 1.0 + want.data.iter().fold(0.0f64, |a, &x| a.max(x.abs()));
    let err = got.max_abs_diff(want);
    if err > 1e-10 * scale {
        return Err(format!("{label}: max abs err {err} (scale {scale})"));
    }
    Ok(())
}

#[test]
fn prop_hv_is_bitwise_equal() {
    check("sharded_hv_parity", PropConfig { cases: 24, max_size: 16, ..Default::default() }, |rng, size| {
        let c = random_case(rng, size);
        let v = Mat::from_fn(c.tiled.n(), c.tiled.k_width(), |_, _| rng.gaussian());
        let want = c.tiled.hv(&v);
        bitwise("hv", &c.sharded.hv(&v), &want)?;
        // hv_into must fully overwrite a dirty buffer through a shared pool
        let scratch = HvScratch::default();
        let mut out = Mat::from_fn(c.tiled.n(), c.tiled.k_width(), |_, _| f64::NAN);
        c.sharded.hv_into(&v, &mut out, &scratch);
        bitwise("hv_into (dirty buffer)", &out, &want)?;
        // and pooling must not change bits on a second pass
        c.sharded.hv_into(&v, &mut out, &scratch);
        bitwise("hv_into (pooled rerun)", &out, &want)
    });
}

#[test]
fn prop_ragged_and_clamped_shard_counts_are_bitwise_equal() {
    // shard counts drawn up past n: exercises maximally ragged last
    // shards and the clamp at S = n (one row per shard)
    check("sharded_hv_ragged", PropConfig { cases: 16, max_size: 12, ..Default::default() }, |rng, size| {
        let probe = 8 + rng.below(8 + 6 * size.max(1));
        let shards = 1 + rng.below(probe + 4);
        let c = random_case_with_shards(rng, size, shards);
        let v = Mat::from_fn(c.tiled.n(), c.tiled.k_width(), |_, _| rng.gaussian());
        bitwise(
            &format!("hv (S={} over n={})", c.sharded.num_shards(), c.tiled.n()),
            &c.sharded.hv(&v),
            &c.tiled.hv(&v),
        )
    });
}

#[test]
fn prop_shard_partial_fold_matches_hv() {
    // the multi-process contract: summing per-shard partial products is
    // a reassociation, so the fold matches to FP tolerance (not bitwise)
    check("sharded_partial_fold", PropConfig { cases: 16, max_size: 12, ..Default::default() }, |rng, size| {
        let c = random_case(rng, size);
        let (n, k) = (c.tiled.n(), c.tiled.k_width());
        let v = Mat::from_fn(n, k, |_, _| rng.gaussian());
        // hv_shard_partial overwrites its output, so each shard's partial
        // goes into a scratch buffer and is summed into the fold
        let mut fold = Mat::zeros(n, k);
        let mut part = Mat::zeros(n, k);
        for sh in 0..c.sharded.num_shards() {
            c.sharded.hv_shard_partial(sh, &v, &mut part);
            for (f, p) in fold.data.iter_mut().zip(&part.data) {
                *f += p;
            }
        }
        close("shard-partial fold", &fold, &c.tiled.hv(&v))
    });
}

#[test]
fn prop_k_cols_and_k_rows_are_bitwise_equal() {
    check("sharded_kcols_krows_parity", PropConfig { cases: 24, max_size: 16, ..Default::default() }, |rng, size| {
        let c = random_case(rng, size);
        let n = c.tiled.n();
        let bsz = 1 + rng.below(n);
        let idx = rng.sample_indices(n, bsz);
        let u = Mat::from_fn(bsz, c.tiled.k_width(), |_, _| rng.gaussian());
        bitwise("k_cols", &c.sharded.k_cols(&idx, &u), &c.tiled.k_cols(&idx, &u))?;
        let v = Mat::from_fn(n, c.tiled.k_width(), |_, _| rng.gaussian());
        bitwise("k_rows", &c.sharded.k_rows(&idx, &v), &c.tiled.k_rows(&idx, &v))
    });
}

#[test]
fn prop_grad_quad_and_rff_eval_are_bitwise_equal() {
    check("sharded_grad_rff_parity", PropConfig { cases: 16, max_size: 12, ..Default::default() }, |rng, size| {
        let c = random_case(rng, size);
        let (n, d, s, m) = (c.tiled.n(), c.tiled.d(), c.tiled.s(), c.tiled.m());
        let k = c.tiled.k_width();
        let a = Mat::from_fn(n, k, |_, _| rng.gaussian());
        let b = Mat::from_fn(n, k, |_, _| rng.gaussian());
        let w: Vec<f64> = (0..k).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        bitwise_slice(
            "grad_quad",
            &c.sharded.grad_quad(&a, &b, &w),
            &c.tiled.grad_quad(&a, &b, &w),
        )?;
        let omega0 = Mat::from_fn(d, m, |_, _| rng.gaussian());
        let wts = Mat::from_fn(2 * m, s, |_, _| rng.gaussian());
        let noise = Mat::from_fn(n, s, |_, _| rng.gaussian());
        bitwise(
            "rff_eval",
            &c.sharded.rff_eval(&omega0, &wts, &noise),
            &c.tiled.rff_eval(&omega0, &wts, &noise),
        )
    });
}

#[test]
fn prop_predict_paths_are_bitwise_equal() {
    check("sharded_predict_parity", PropConfig { cases: 16, max_size: 12, ..Default::default() }, |rng, size| {
        let c = random_case(rng, size);
        let (n, d, s, m) = (c.tiled.n(), c.tiled.d(), c.tiled.s(), c.tiled.m());
        let omega0 = Mat::from_fn(d, m, |_, _| rng.gaussian());
        let wts = Mat::from_fn(2 * m, s, |_, _| rng.gaussian());
        let vy = rng.gaussian_vec(n);
        let zhat = Mat::from_fn(n, s, |_, _| rng.gaussian());
        // arbitrary query points, not just the held-out test split
        let tq = 1 + rng.below(12);
        let xq = Mat::from_fn(tq, d, |_, _| rng.gaussian());
        let (m1, s1) = c.sharded.predict_at(&xq, &vy, &zhat, &omega0, &wts).map_err(|e| e.to_string())?;
        let (m2, s2) = c.tiled.predict_at(&xq, &vy, &zhat, &omega0, &wts).map_err(|e| e.to_string())?;
        bitwise_slice("predict_at mean", &m1, &m2)?;
        bitwise("predict_at samples", &s1, &s2)?;
        let batch = 1 + rng.below(tq + 4);
        let (m3, s3, _) = c
            .sharded
            .predict_batched(&xq, batch, 0, &vy, &zhat, &omega0, &wts)
            .map_err(|e| e.to_string())?;
        bitwise_slice("predict_batched mean", &m3, &m2)?;
        bitwise("predict_batched samples", &s3, &s2)?;
        // the default predict (at x_test) rides the same path
        let (m4, s4) = c.sharded.predict(&vy, &zhat, &omega0, &wts);
        let (m5, s5) = c.tiled.predict(&vy, &zhat, &omega0, &wts);
        bitwise_slice("predict mean", &m4, &m5)?;
        bitwise("predict samples", &s4, &s5)
    });
}

#[test]
fn prop_extend_preserves_bitwise_parity() {
    // grow both operators with the same chunk(s); the sharded layout
    // appends to its last shard, the monolithic one to its single panel
    // cache — products must stay bitwise-equal afterwards
    check("sharded_extend_parity", PropConfig { cases: 16, max_size: 12, ..Default::default() }, |rng, size| {
        let mut c = random_case(rng, size);
        let d = c.tiled.d();
        for _ in 0..1 + rng.below(3) {
            let grow = 1 + rng.below(9);
            let x_new = Mat::from_fn(grow, d, |_, _| rng.gaussian());
            c.tiled.extend(&x_new).map_err(|e| e.to_string())?;
            c.sharded.extend(&x_new).map_err(|e| e.to_string())?;
        }
        let n = c.tiled.n();
        if c.sharded.n() != n {
            return Err(format!("extend: sharded n {} vs tiled n {}", c.sharded.n(), n));
        }
        let v = Mat::from_fn(n, c.tiled.k_width(), |_, _| rng.gaussian());
        bitwise("hv after extend", &c.sharded.hv(&v), &c.tiled.hv(&v))?;
        let idx = rng.sample_indices(n, 1 + rng.below(n));
        bitwise(
            "k_rows after extend",
            &c.sharded.k_rows(&idx, &v),
            &c.tiled.k_rows(&idx, &v),
        )?;
        let (s, m) = (c.tiled.s(), c.tiled.m());
        let omega0 = Mat::from_fn(d, m, |_, _| rng.gaussian());
        let wts = Mat::from_fn(2 * m, s, |_, _| rng.gaussian());
        let vy = rng.gaussian_vec(n);
        let zhat = Mat::from_fn(n, s, |_, _| rng.gaussian());
        let xq = Mat::from_fn(1 + rng.below(6), d, |_, _| rng.gaussian());
        let (m1, s1) = c.sharded.predict_at(&xq, &vy, &zhat, &omega0, &wts).map_err(|e| e.to_string())?;
        let (m2, s2) = c.tiled.predict_at(&xq, &vy, &zhat, &omega0, &wts).map_err(|e| e.to_string())?;
        bitwise_slice("predict_at mean after extend", &m1, &m2)?;
        bitwise("predict_at samples after extend", &s1, &s2)
    });
}

#[test]
fn prop_dense_and_tiled_hv_into_tolerate_dirty_buffers() {
    // the sharded dirty-buffer prop above has dense/tiled mirrors: hv_into
    // must fully overwrite whatever is in the output (NaN poison included)
    // and pooled scratch reuse must not change a bit vs the allocating hv
    check("dense_tiled_hv_into_dirty", PropConfig { cases: 16, max_size: 12, ..Default::default() }, |rng, size| {
        let n = 8 + rng.below(8 + 6 * size.max(1));
        let d = 1 + rng.below(5);
        let s = 1 + rng.below(4);
        let m = 4 + rng.below(12);
        let tile = 1 + rng.below(n + 8);
        let threads = 1 + rng.below(4);
        let ds = toy_dataset(rng, n, 2, d, random_family(rng));
        let hp = Hyperparams {
            ell: (0..d).map(|_| rng.uniform_in(0.4, 2.0)).collect(),
            sigf: rng.uniform_in(0.5, 1.5),
            sigma: rng.uniform_in(0.1, 0.9),
        };
        let mut tiled = TiledOperator::with_options(&ds, s, m, TiledOptions { tile, threads });
        tiled.set_hp(&hp);
        let mut dense = DenseOperator::new(&ds, s, m);
        dense.set_hp(&hp);

        let k = tiled.k_width();
        let v = Mat::from_fn(n, k, |_, _| rng.gaussian());
        let scratch = HvScratch::default();

        let want = tiled.hv(&v);
        let mut out = Mat::from_fn(n, k, |_, _| f64::NAN);
        tiled.hv_into(&v, &mut out, &scratch);
        bitwise("tiled hv_into (dirty buffer)", &out, &want)?;
        tiled.hv_into(&v, &mut out, &scratch);
        bitwise("tiled hv_into (pooled rerun)", &out, &want)?;

        // dense agrees with tiled only to tolerance, so its dirty-buffer
        // contract is checked against its own allocating hv
        let want = dense.hv(&v);
        let mut out = Mat::from_fn(n, k, |_, _| f64::NAN);
        dense.hv_into(&v, &mut out, &scratch);
        bitwise("dense hv_into (dirty buffer)", &out, &want)?;
        dense.hv_into(&v, &mut out, &scratch);
        bitwise("dense hv_into (pooled rerun)", &out, &want)
    });
}

#[test]
fn prop_matmul_into_is_bitwise_equal_to_matmul() {
    // Mat::matmul allocates a zeroed output; matmul_into writes into a
    // caller buffer.  The two must agree bitwise for any shape, including
    // degenerate inner dimensions, and regardless of the buffer's prior
    // contents.
    check("matmul_into_parity", PropConfig { cases: 32, max_size: 16, ..Default::default() }, |rng, size| {
        let m = 1 + rng.below(4 + 2 * size.max(1));
        let kk = rng.below(4 + 2 * size.max(1)); // 0 = empty inner dim
        let n = 1 + rng.below(4 + 2 * size.max(1));
        let a = Mat::from_fn(m, kk, |_, _| rng.gaussian());
        let b = Mat::from_fn(kk, n, |_, _| rng.gaussian());
        let want = a.matmul(&b);
        let mut out = Mat::from_fn(m, n, |_, _| f64::NAN);
        a.matmul_into(&b, &mut out);
        bitwise("matmul_into (dirty buffer)", &out, &want)?;
        a.matmul_into(&b, &mut out);
        bitwise("matmul_into (rerun)", &out, &want)
    });
}

/// Everything a training run produces except wall-clock timings, as bit
/// patterns: if any solver trajectory, epoch count or metric moved by one
/// ULP between shard counts, this fingerprint catches it.
fn run_fingerprint(out: &igp::coordinator::TrainOutcome) -> Vec<u64> {
    let mut fp = Vec::new();
    for t in &out.telemetry {
        fp.push(t.step as u64);
        fp.push(t.ry.to_bits());
        fp.push(t.rz.to_bits());
        fp.push(t.iterations as u64);
        fp.push(t.epochs.to_bits());
        fp.push(t.converged as u64);
        fp.push(t.init_residual_sq.to_bits());
        fp.extend(t.theta.iter().map(|x| x.to_bits()));
        fp.extend(t.grad.iter().map(|x| x.to_bits()));
        if let Some(m) = &t.metrics {
            fp.push(m.rmse.to_bits());
            fp.push(m.llh.to_bits());
        }
    }
    fp.extend(out.theta.iter().map(|x| x.to_bits()));
    fp.push(out.final_metrics.rmse.to_bits());
    fp.push(out.final_metrics.llh.to_bits());
    fp.push(out.total_epochs.to_bits());
    fp
}

#[test]
fn trainer_telemetry_is_bitwise_identical_across_shard_counts() {
    // end-to-end: train, grow the dataset online, train again — the full
    // telemetry stream must be bit-identical for every shard count,
    // including through the warm-started post-extend solves
    let ds = data::generate(&data::spec("test").unwrap());
    let (base, chunks) = ds.replay_chunks(2);
    let (x_new, y_new) = &chunks[0];
    let run = |op: Box<dyn KernelOperator>| -> Vec<u64> {
        let opts = TrainerOptions {
            solver: SolverKind::Cg,
            estimator: EstimatorKind::Pathwise,
            warm_start: true,
            lr: 0.05,
            seed: 13,
            ..Default::default()
        };
        let mut t = Trainer::new(opts, op, &base);
        let mut fp = run_fingerprint(&t.run(3).unwrap());
        t.extend_data(x_new, y_new).unwrap();
        fp.extend(run_fingerprint(&t.run(2).unwrap()));
        fp
    };
    let topts = TiledOptions { tile: 96, threads: 2 };
    let want = run(Box::new(TiledOperator::with_options(&base, 8, 64, topts.clone())));
    for shards in [1usize, 2, 3, 5, 8] {
        let got = run(Box::new(ShardedOperator::with_options(
            &base,
            8,
            64,
            topts.clone(),
            shards,
        )));
        assert_eq!(
            got, want,
            "trainer telemetry fingerprint diverged at S = {shards}"
        );
    }
}
