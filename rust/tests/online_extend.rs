//! Online data-arrival properties: an `extend`-ed operator must be
//! indistinguishable from one freshly built on the concatenated data —
//! bitwise for the dense backend's materialised H, elementwise-tight for
//! every product on both pure-Rust backends — and a warm-carried online
//! training run must beat cold restarts on the same chunk schedule.

use igp::coordinator::{Trainer, TrainerOptions};
use igp::data::{Dataset, DatasetSpec};
use igp::estimator::EstimatorKind;
use igp::kernels::{Hyperparams, KernelFamily};
use igp::linalg::Mat;
use igp::operators::{DenseOperator, KernelOperator, TiledOperator, TiledOptions};
use igp::prop_assert;
use igp::solvers::SolverKind;
use igp::util::proptest::{check, PropConfig};
use igp::util::rng::Rng;

fn toy_dataset(rng: &mut Rng, n: usize, d: usize, family: KernelFamily) -> Dataset {
    let x_train = Mat::from_fn(n, d, |_, _| rng.gaussian());
    let y_train = rng.gaussian_vec(n);
    let x_test = Mat::from_fn(4, d, |_, _| rng.gaussian());
    let y_test = rng.gaussian_vec(4);
    let spec = DatasetSpec {
        name: "toy",
        paper_n: 0,
        n,
        n_test: 4,
        d,
        true_sigma: 0.3,
        ell_lo: 0.5,
        ell_hi: 1.5,
        cluster_frac: 0.0,
        family,
        seed: 0,
    };
    Dataset { spec, x_train, y_train, x_test, y_test, true_hp: Hyperparams::ones(d) }
}

fn random_family(rng: &mut Rng) -> KernelFamily {
    match rng.below(4) {
        0 => KernelFamily::Matern12,
        1 => KernelFamily::Matern32,
        2 => KernelFamily::Matern52,
        _ => KernelFamily::Rbf,
    }
}

#[test]
fn prop_extended_dense_is_bitwise_equal_to_rebuilt() {
    check(
        "online_dense_extend_bitwise",
        PropConfig { cases: 24, max_size: 16, ..Default::default() },
        |rng, size| {
            let d = 1 + rng.below(4);
            let family = random_family(rng);
            let n_full = 12 + rng.below(8 + 6 * size);
            let full_ds = toy_dataset(rng, n_full, d, family);
            let hp = Hyperparams {
                ell: (0..d).map(|_| rng.uniform_in(0.4, 2.0)).collect(),
                sigf: rng.uniform_in(0.5, 1.5),
                sigma: rng.uniform_in(0.1, 0.9),
            };
            // random split into a base plus 1-3 arrival chunks
            let mut cuts = vec![0, n_full];
            for _ in 0..1 + rng.below(3) {
                cuts.push(1 + rng.below(n_full - 1));
            }
            cuts.sort_unstable();
            cuts.dedup();
            let base_n = cuts[1];
            let base = full_ds.with_train(
                full_ds.x_train.gather_rows(&(0..base_n).collect::<Vec<_>>()),
                full_ds.y_train[..base_n].to_vec(),
            );
            let mut grown = DenseOperator::new(&base, 2, 8);
            grown.set_hp(&hp);
            for w in cuts[1..].windows(2) {
                let idx: Vec<usize> = (w[0]..w[1]).collect();
                grown
                    .extend(&full_ds.x_train.gather_rows(&idx))
                    .map_err(|e| e.to_string())?;
            }
            let mut full = DenseOperator::new(&full_ds, 2, 8);
            full.set_hp(&hp);
            prop_assert!(grown.n() == full.n(), "n {} vs {}", grown.n(), full.n());
            prop_assert!(grown.x().data == full.x().data, "inputs differ");
            for (i, (a, b)) in grown.h().data.iter().zip(&full.h().data).enumerate() {
                prop_assert!(
                    a.to_bits() == b.to_bits(),
                    "H entry {i}: {a} vs {b} (family {family:?})"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_extended_tiled_matches_extended_dense() {
    check(
        "online_tiled_extend_parity",
        PropConfig { cases: 16, max_size: 12, ..Default::default() },
        |rng, size| {
            let d = 1 + rng.below(4);
            let family = random_family(rng);
            let n0 = 8 + rng.below(8 + 4 * size);
            let ds = toy_dataset(rng, n0, d, family);
            let hp = Hyperparams {
                ell: (0..d).map(|_| rng.uniform_in(0.4, 2.0)).collect(),
                sigf: rng.uniform_in(0.5, 1.5),
                sigma: rng.uniform_in(0.1, 0.9),
            };
            let tile = 1 + rng.below(2 * n0);
            let threads = 1 + rng.below(4);
            let mut tiled =
                TiledOperator::with_options(&ds, 2, 8, TiledOptions { tile, threads });
            tiled.set_hp(&hp);
            let mut dense = DenseOperator::new(&ds, 2, 8);
            dense.set_hp(&hp);
            let chunk = Mat::from_fn(1 + rng.below(2 * n0), d, |_, _| rng.gaussian());
            tiled.extend(&chunk).map_err(|e| e.to_string())?;
            dense.extend(&chunk).map_err(|e| e.to_string())?;
            let n1 = dense.n();
            let k = tiled.k_width();
            let v = Mat::from_fn(n1, k, |_, _| rng.gaussian());
            let (a, b) = (tiled.hv(&v), dense.hv(&v));
            let err = a.max_abs_diff(&b);
            prop_assert!(err < 1e-10, "post-extend hv err {err}");
            let bsz = 1 + rng.below(n1);
            let idx = rng.sample_indices(n1, bsz);
            let u = Mat::from_fn(bsz, k, |_, _| rng.gaussian());
            let err = tiled.k_cols(&idx, &u).max_abs_diff(&dense.k_cols(&idx, &u));
            prop_assert!(err < 1e-10, "post-extend k_cols err {err}");
            let err = tiled.k_rows(&idx, &v).max_abs_diff(&dense.k_rows(&idx, &v));
            prop_assert!(err < 1e-10, "post-extend k_rows err {err}");
            Ok(())
        },
    );
}

/// Warm-carried online training must reach tolerance in strictly fewer
/// total epochs than cold restarts on the same chunk schedule (the
/// acceptance property of the online subsystem), on the tiled backend.
#[test]
fn warm_carried_online_beats_cold_restarts_on_tiled() {
    let ds = igp::data::generate(&igp::data::spec("test").unwrap());
    let (base, arrivals) = ds.replay_chunks(4);
    let steps = 3;
    let opts = TrainerOptions {
        solver: SolverKind::Ap,
        estimator: EstimatorKind::Pathwise,
        warm_start: true,
        lr: 0.05,
        seed: 21,
        ..Default::default()
    };
    let mk_op = |d: &Dataset| {
        TiledOperator::with_options(d, 8, 64, TiledOptions { tile: 96, threads: 2 })
    };

    let mut warm = Trainer::new(opts.clone(), Box::new(mk_op(&base)), &base);
    let mut warm_epochs = warm.run(steps).unwrap().total_epochs;
    for (x, y) in &arrivals {
        warm.extend_data(x, y).unwrap();
        warm_epochs += warm.run(steps).unwrap().total_epochs;
    }
    assert_eq!(warm.operator().n(), ds.spec.n);

    let mut cold_epochs = 0.0;
    let mut acc_x = base.x_train.clone();
    let mut acc_y = base.y_train.clone();
    for arrival in 0..4 {
        if arrival > 0 {
            let (x, y) = &arrivals[arrival - 1];
            acc_x.append_rows(x);
            acc_y.extend_from_slice(y);
        }
        let acc = ds.with_train(acc_x.clone(), acc_y.clone());
        let mut cold = Trainer::new(opts.clone(), Box::new(mk_op(&acc)), &acc);
        cold_epochs += cold.run(steps).unwrap().total_epochs;
    }

    assert!(
        warm_epochs < cold_epochs,
        "warm-carried {warm_epochs} vs cold restarts {cold_epochs}"
    );
}
