//! Property tests for the precision-generic compute layer.
//!
//! Three contracts, in descending order of strictness:
//!
//! 1. **f64 is the untouched bitwise reference.**  Building the f32
//!    mirrors (`set_precision(F32)`) must not move a single bit of any
//!    f64 product — `hv`, `hv_into_prec(F64)`, `k_cols`, `k_rows` and
//!    `predict_at` all reproduce their pre-mirror outputs exactly, on
//!    every backend.
//! 2. **f32 is layout-independent.**  The f32 products accumulate f32
//!    kernel entries into f64 in ascending index order, so the tiled and
//!    sharded backends must agree *bitwise* at f32 just as they do at
//!    f64.  (Dense-f32 goes through a materialised `h32` matrix and is
//!    held to tolerance, mirroring the dense-vs-tiled f64 suite.)
//! 3. **f32 + refinement reaches f64 quality.**  CG with `precision =
//!    F32` must converge to the solver tolerance as verified by an
//!    independent f64 residual recomputation, and a drift guard forced
//!    with `drift_ratio = 0` must return the pure-f64 answer bitwise.

use igp::data::{Dataset, DatasetSpec};
use igp::kernels::{Hyperparams, KernelFamily};
use igp::linalg::Mat;
use igp::operators::{
    DenseOperator, HvScratch, KernelOperator, Precision, ShardedOperator, TiledOperator,
    TiledOptions,
};
use igp::solvers::{make_solver, verify_residuals_f64, SolveOptions, SolverKind};
use igp::util::proptest::{check, PropConfig};
use igp::util::rng::Rng;

fn random_family(rng: &mut Rng) -> KernelFamily {
    match rng.below(4) {
        0 => KernelFamily::Matern12,
        1 => KernelFamily::Matern32,
        2 => KernelFamily::Matern52,
        _ => KernelFamily::Rbf,
    }
}

fn toy_dataset(rng: &mut Rng, n: usize, n_test: usize, d: usize, family: KernelFamily) -> Dataset {
    let x_train = Mat::from_fn(n, d, |_, _| rng.gaussian());
    let y_train = rng.gaussian_vec(n);
    let x_test = Mat::from_fn(n_test, d, |_, _| rng.gaussian());
    let y_test = rng.gaussian_vec(n_test);
    let spec = DatasetSpec {
        name: "toy",
        paper_n: 0,
        n,
        n_test,
        d,
        true_sigma: 0.3,
        ell_lo: 0.5,
        ell_hi: 1.5,
        cluster_frac: 0.0,
        family,
        seed: 0,
    };
    Dataset { spec, x_train, y_train, x_test, y_test, true_hp: Hyperparams::ones(d) }
}

/// One random case: the same dataset and hyperparameters behind all
/// three CPU backends.
struct Ops {
    tiled: TiledOperator,
    dense: DenseOperator,
    sharded: ShardedOperator,
}

fn random_ops(rng: &mut Rng, size: usize) -> Ops {
    let n = 8 + rng.below(8 + 6 * size.max(1));
    let n_test = 1 + rng.below(6);
    let d = 1 + rng.below(5);
    let s = 1 + rng.below(4);
    let m = 4 + rng.below(12);
    let tile = 1 + rng.below(n + 8);
    let threads = 1 + rng.below(4);
    let shards = 1 + rng.below(4);
    let family = random_family(rng);
    let ds = toy_dataset(rng, n, n_test, d, family);
    let hp = Hyperparams {
        ell: (0..d).map(|_| rng.uniform_in(0.4, 2.0)).collect(),
        sigf: rng.uniform_in(0.5, 1.5),
        sigma: rng.uniform_in(0.1, 0.9),
    };
    let opts = TiledOptions { tile, threads };
    let mut tiled = TiledOperator::with_options(&ds, s, m, opts.clone());
    tiled.set_hp(&hp);
    let mut dense = DenseOperator::new(&ds, s, m);
    dense.set_hp(&hp);
    let mut sharded = ShardedOperator::with_options(&ds, s, m, opts, shards);
    sharded.set_hp(&hp);
    Ops { tiled, dense, sharded }
}

fn bitwise(label: &str, got: &Mat, want: &Mat) -> Result<(), String> {
    if (got.rows, got.cols) != (want.rows, want.cols) {
        return Err(format!(
            "{label}: shape ({}, {}) vs ({}, {})",
            got.rows, got.cols, want.rows, want.cols
        ));
    }
    bitwise_slice(label, &got.data, &want.data)
}

fn bitwise_slice(label: &str, got: &[f64], want: &[f64]) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{label}: len {} vs {}", got.len(), want.len()));
    }
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        if a.to_bits() != b.to_bits() {
            return Err(format!(
                "{label}: element {i}: {a:e} vs {b:e} ({:#018x} vs {:#018x})",
                a.to_bits(),
                b.to_bits()
            ));
        }
    }
    Ok(())
}

/// Max elementwise difference, relative to the magnitude scale of `want`.
fn close(label: &str, got: &Mat, want: &Mat, tol: f64) -> Result<(), String> {
    if (got.rows, got.cols) != (want.rows, want.cols) {
        return Err(format!(
            "{label}: shape ({}, {}) vs ({}, {})",
            got.rows, got.cols, want.rows, want.cols
        ));
    }
    let scale = 1.0 + want.data.iter().fold(0.0f64, |m, v| m.max(v.abs()));
    for (i, (a, b)) in got.data.iter().zip(&want.data).enumerate() {
        let err = (a - b).abs() / scale;
        if !(err <= tol) {
            return Err(format!("{label}: element {i}: {a:e} vs {b:e} (rel err {err:e})"));
        }
    }
    Ok(())
}

/// Contract 1: enabling the f32 mirrors leaves every f64 product
/// bitwise-identical on all three backends — `F64` stays the untouched
/// reference path no matter what precision state the operator carries.
#[test]
fn prop_f64_products_unchanged_by_f32_mirrors() {
    check("f64_unchanged_by_mirrors", PropConfig { cases: 16, max_size: 10, ..Default::default() }, |rng, size| {
        let mut o = random_ops(rng, size);
        let (n, d, s, m) = (o.tiled.n(), o.tiled.d(), o.tiled.s(), o.tiled.m());
        let k = o.tiled.k_width();
        let v = Mat::from_fn(n, k, |_, _| rng.gaussian());
        let nb = 1 + rng.below(n);
        let idx = rng.sample_indices(n, nb);
        let u = Mat::from_fn(idx.len(), k, |_, _| rng.gaussian());
        let omega0 = Mat::from_fn(d, m, |_, _| rng.gaussian());
        let wts = Mat::from_fn(2 * m, s, |_, _| rng.gaussian());
        let vy = rng.gaussian_vec(n);
        let zhat = Mat::from_fn(n, s, |_, _| rng.gaussian());
        let xq = Mat::from_fn(1 + rng.below(6), d, |_, _| rng.gaussian());

        // reference products before any f32 state exists
        let hv_t = o.tiled.hv(&v);
        let hv_d = o.dense.hv(&v);
        let hv_s = o.sharded.hv(&v);
        let kc_t = o.tiled.k_cols(&idx, &u);
        let kr_t = o.tiled.k_rows(&idx, &v);
        let (pm_t, ps_t) = o.tiled.predict_at(&xq, &vy, &zhat, &omega0, &wts).map_err(|e| e.to_string())?;

        o.tiled.set_precision(Precision::F32).map_err(|e| e.to_string())?;
        o.dense.set_precision(Precision::F32).map_err(|e| e.to_string())?;
        o.sharded.set_precision(Precision::F32).map_err(|e| e.to_string())?;

        bitwise("tiled hv after mirror", &o.tiled.hv(&v), &hv_t)?;
        bitwise("dense hv after mirror", &o.dense.hv(&v), &hv_d)?;
        bitwise("sharded hv after mirror", &o.sharded.hv(&v), &hv_s)?;

        // the explicit-precision entry points at F64 are the same path
        let scratch = HvScratch::default();
        let mut out = Mat::from_fn(n, k, |_, _| f64::NAN);
        o.tiled.hv_into_prec(&v, &mut out, &scratch, Precision::F64);
        bitwise("tiled hv_into_prec(F64)", &out, &hv_t)?;
        o.sharded.hv_into_prec(&v, &mut out, &scratch, Precision::F64);
        bitwise("sharded hv_into_prec(F64)", &out, &hv_s)?;
        o.dense.hv_into_prec(&v, &mut out, &scratch, Precision::F64);
        bitwise("dense hv_into_prec(F64)", &out, &hv_d)?;

        bitwise("k_cols_prec(F64)", &o.tiled.k_cols_prec(&idx, &u, Precision::F64), &kc_t)?;
        bitwise("k_rows_prec(F64)", &o.tiled.k_rows_prec(&idx, &v, Precision::F64), &kr_t)?;
        let (pm, ps) = o
            .tiled
            .predict_at_prec(&xq, &vy, &zhat, &omega0, &wts, Precision::F64)
            .map_err(|e| e.to_string())?;
        bitwise_slice("predict_at_prec(F64) mean", &pm, &pm_t)?;
        bitwise("predict_at_prec(F64) samples", &ps, &ps_t)
    });
}

/// Contract 2: f32 products are close to f64 and layout-independent —
/// tiled and sharded agree bitwise at f32 (same mirror bits, same
/// ascending-index f64 accumulation), dense agrees to tolerance through
/// its materialised `h32`.
#[test]
fn prop_f32_products_close_and_layout_independent() {
    check("f32_products", PropConfig { cases: 16, max_size: 10, ..Default::default() }, |rng, size| {
        let mut o = random_ops(rng, size);
        let n = o.tiled.n();
        let k = o.tiled.k_width();
        o.tiled.set_precision(Precision::F32).map_err(|e| e.to_string())?;
        o.dense.set_precision(Precision::F32).map_err(|e| e.to_string())?;
        o.sharded.set_precision(Precision::F32).map_err(|e| e.to_string())?;

        let v = Mat::from_fn(n, k, |_, _| rng.gaussian());
        let scratch = HvScratch::default();
        let mut hv_t = Mat::zeros(n, k);
        let mut hv_s = Mat::zeros(n, k);
        let mut hv_d = Mat::zeros(n, k);
        o.tiled.hv_into_prec(&v, &mut hv_t, &scratch, Precision::F32);
        o.sharded.hv_into_prec(&v, &mut hv_s, &scratch, Precision::F32);
        o.dense.hv_into_prec(&v, &mut hv_d, &scratch, Precision::F32);
        bitwise("f32 hv tiled vs sharded", &hv_s, &hv_t)?;
        close("f32 hv dense vs tiled", &hv_d, &hv_t, 1e-5)?;
        close("f32 hv vs f64 hv", &hv_t, &o.tiled.hv(&v), 5e-4)?;

        let nb = 1 + rng.below(n);
        let idx = rng.sample_indices(n, nb);
        let u = Mat::from_fn(idx.len(), k, |_, _| rng.gaussian());
        let kc_t = o.tiled.k_cols_prec(&idx, &u, Precision::F32);
        bitwise(
            "f32 k_cols tiled vs sharded",
            &o.sharded.k_cols_prec(&idx, &u, Precision::F32),
            &kc_t,
        )?;
        close("f32 k_cols vs f64", &kc_t, &o.tiled.k_cols(&idx, &u), 5e-4)?;

        let kr_t = o.tiled.k_rows_prec(&idx, &v, Precision::F32);
        bitwise(
            "f32 k_rows tiled vs sharded",
            &o.sharded.k_rows_prec(&idx, &v, Precision::F32),
            &kr_t,
        )?;
        close("f32 k_rows vs f64", &kr_t, &o.tiled.k_rows(&idx, &v), 5e-4)
    });
}

/// Contract 3a: CG at `precision = F32` (iterative refinement) converges
/// to the solver tolerance, as certified by an independent f64 residual
/// recomputation against the reference operator — not by the solver's
/// own bookkeeping.
#[test]
fn prop_cg_f32_refinement_reaches_f64_tolerance() {
    check("cg_f32_refinement", PropConfig { cases: 10, max_size: 8, ..Default::default() }, |rng, size| {
        let mut o = random_ops(rng, size);
        o.tiled.set_precision(Precision::F32).map_err(|e| e.to_string())?;
        let n = o.tiled.n();
        let k = o.tiled.k_width();
        let b = Mat::from_fn(n, k, |_, _| rng.gaussian());
        let tol = 1e-4;
        let opts32 = SolveOptions {
            tolerance: tol,
            max_epochs: 400.0,
            precond_rank: 8,
            precision: Precision::F32,
            ..Default::default()
        };
        let mut v32 = Mat::zeros(n, k);
        let rep32 = make_solver(SolverKind::Cg).solve(&o.tiled, &b, &mut v32, &opts32);
        if !rep32.converged {
            return Err(format!("f32 CG failed to converge: {rep32:?}"));
        }
        // certify with a from-scratch f64 residual, allowing only the
        // normalisation round-off between raw and solver-internal space
        let (ry, rz) = verify_residuals_f64(&o.tiled, &b, &v32, 1);
        if !(ry <= 2.0 * tol && rz <= 2.0 * tol) {
            return Err(format!("f64-verified residual too high: ry={ry:e} rz={rz:e}"));
        }
        // and the solution agrees with the pure-f64 solve to residual level
        let opts64 = SolveOptions { precision: Precision::F64, ..opts32 };
        let mut v64 = Mat::zeros(n, k);
        let rep64 = make_solver(SolverKind::Cg).solve(&o.tiled, &b, &mut v64, &opts64);
        if !rep64.converged {
            return Err(format!("f64 CG failed to converge: {rep64:?}"));
        }
        close("f32-refined vs f64 solution", &v32, &v64, 1e-2)
    });
}

/// Contract 3b: a tripped drift guard must hand back the *reference*
/// answer.  `drift_ratio = 0` makes the guard fire unconditionally, so
/// the f32 solve is thrown away and the fallback rerun — same solver
/// instance, same warm start — must match a pure f64 solve bitwise,
/// with the wasted f32 epochs charged on top.
#[test]
fn prop_drift_guard_fallback_is_bitwise_f64() {
    check("drift_guard_fallback", PropConfig { cases: 10, max_size: 8, ..Default::default() }, |rng, size| {
        let mut o = random_ops(rng, size);
        o.tiled.set_precision(Precision::F32).map_err(|e| e.to_string())?;
        let n = o.tiled.n();
        let k = o.tiled.k_width();
        let b = Mat::from_fn(n, k, |_, _| rng.gaussian());
        let base = SolveOptions {
            tolerance: 1e-4,
            max_epochs: 200.0,
            precond_rank: 8,
            ..Default::default()
        };
        let forced = SolveOptions {
            precision: Precision::F32,
            drift_ratio: 0.0,
            ..base.clone()
        };
        let mut v_guard = Mat::zeros(n, k);
        let rep_guard = make_solver(SolverKind::Cg).solve(&o.tiled, &b, &mut v_guard, &forced);
        let mut v_f64 = Mat::zeros(n, k);
        let rep_f64 = make_solver(SolverKind::Cg).solve(&o.tiled, &b, &mut v_f64, &base);
        bitwise("guard-fallback solution vs pure f64", &v_guard, &v_f64)?;
        if rep_guard.ry.to_bits() != rep_f64.ry.to_bits()
            || rep_guard.rz.to_bits() != rep_f64.rz.to_bits()
            || rep_guard.iterations != rep_f64.iterations
            || rep_guard.converged != rep_f64.converged
        {
            return Err(format!("fallback report diverged: {rep_guard:?} vs {rep_f64:?}"));
        }
        // the wasted f32 work (plus the verify epoch) is billed on top
        if !(rep_guard.epochs > rep_f64.epochs) {
            return Err(format!(
                "fallback must charge wasted epochs: {} vs {}",
                rep_guard.epochs, rep_f64.epochs
            ));
        }
        Ok(())
    });
}
