//! Property-based tests (in-tree proptest-lite harness) over the
//! coordinator-level invariants DESIGN.md §5 calls out: budget accounting,
//! warm-start state routing, solver correctness on random SPD systems,
//! normalisation round-trips and config parsing.

use igp::config;
use igp::coordinator::{Trainer, TrainerOptions};
use igp::data::{generate_split, spec};
use igp::estimator::{EstimatorKind, ProbeSet};
use igp::kernels::Hyperparams;
use igp::linalg::{Cholesky, Mat};
use igp::operators::{DenseOperator, KernelOperator, TiledOperator, TiledOptions};
use igp::prop_assert;
use igp::solvers::{
    col_norms, make_solver, Normalized, SolveOptions, SolverKind,
};
use igp::util::proptest::{check, PropConfig};
use igp::util::rng::Rng;

fn dense_op(rng: &mut Rng, size_hint: usize) -> (DenseOperator, Mat) {
    // random small SPD kernel system with random hyperparameters
    let ds = generate_split(&spec("test").unwrap(), rng.next_u64() % 8);
    let s = 2 + size_hint % 6;
    let mut op = DenseOperator::new(&ds, s, 16);
    let d = op.d();
    let hp = Hyperparams {
        ell: (0..d).map(|_| rng.uniform_in(0.5, 2.0)).collect(),
        sigf: rng.uniform_in(0.5, 1.5),
        sigma: rng.uniform_in(0.1, 0.8),
    };
    op.set_hp(&hp);
    let mut b = Mat::from_fn(op.n(), op.k_width(), |_, _| rng.gaussian());
    b.set_col(0, &ds.y_train);
    (op, b)
}

/// Same random SPD system behind both pure-Rust backends.
fn backend_pair(rng: &mut Rng, size_hint: usize) -> (DenseOperator, TiledOperator, Mat) {
    let ds = generate_split(&spec("test").unwrap(), rng.next_u64() % 8);
    let s = 2 + size_hint % 6;
    let mut dense = DenseOperator::new(&ds, s, 16);
    let d = dense.d();
    let hp = Hyperparams {
        ell: (0..d).map(|_| rng.uniform_in(0.5, 2.0)).collect(),
        sigf: rng.uniform_in(0.5, 1.5),
        sigma: rng.uniform_in(0.1, 0.8),
    };
    dense.set_hp(&hp);
    let tile = 1 + rng.below(2 * dense.n());
    let threads = 1 + rng.below(4);
    let mut tiled = TiledOperator::with_options(&ds, s, 16, TiledOptions { tile, threads });
    tiled.set_hp(&hp);
    let mut b = Mat::from_fn(dense.n(), dense.k_width(), |_, _| rng.gaussian());
    b.set_col(0, &ds.y_train);
    (dense, tiled, b)
}

#[test]
fn prop_budget_never_exceeded() {
    check("budget_never_exceeded", PropConfig { cases: 12, max_size: 12, ..Default::default() }, |rng, size| {
        let (op, b) = dense_op(rng, size);
        let budget = 1.0 + (size % 7) as f64;
        let kind = match size % 3 {
            0 => SolverKind::Cg,
            1 => SolverKind::Ap,
            _ => SolverKind::Sgd,
        };
        let opts = SolveOptions {
            tolerance: 1e-14,
            max_epochs: budget,
            block_size: 64,
            sgd_lr: 4.0,
            ..Default::default()
        };
        let mut v = Mat::zeros(op.n(), op.k_width());
        let rep = make_solver(kind).solve(&op, &b, &mut v, &opts);
        prop_assert!(
            rep.epochs <= budget + 1e-9,
            "{kind:?}: spent {} > budget {budget}",
            rep.epochs
        );
        prop_assert!(!rep.converged, "tolerance 1e-14 must not be reachable");
        Ok(())
    });
}

#[test]
fn prop_cg_converges_and_matches_direct() {
    check("cg_matches_direct", PropConfig { cases: 8, max_size: 8, ..Default::default() }, |rng, size| {
        let (op, b) = dense_op(rng, size);
        let opts = SolveOptions {
            tolerance: 1e-9,
            max_epochs: 400.0,
            precond_rank: 32,
            ..Default::default()
        };
        let mut v = Mat::zeros(op.n(), op.k_width());
        let rep = make_solver(SolverKind::Cg).solve(&op, &b, &mut v, &opts);
        prop_assert!(rep.converged, "CG failed to converge: {rep:?}");
        let want = Cholesky::factor(op.h()).unwrap().solve_mat(&b);
        let err = v.max_abs_diff(&want);
        prop_assert!(err < 1e-5, "solution error {err}");
        Ok(())
    });
}

#[test]
fn prop_warm_start_from_solution_is_instant() {
    check("warm_start_instant", PropConfig { cases: 8, max_size: 8, ..Default::default() }, |rng, size| {
        let (op, b) = dense_op(rng, size);
        let kind = if size % 2 == 0 { SolverKind::Cg } else { SolverKind::Ap };
        let opts = SolveOptions {
            tolerance: 0.01,
            max_epochs: 500.0,
            block_size: 64,
            ..Default::default()
        };
        let mut v = Mat::zeros(op.n(), op.k_width());
        make_solver(kind).solve(&op, &b, &mut v, &opts);
        // restart at the solution: must terminate after the initial
        // residual check (<= 1 epoch, zero iterations)
        let mut v2 = v.clone();
        let rep = make_solver(kind).solve(&op, &b, &mut v2, &opts);
        prop_assert!(rep.iterations == 0, "{kind:?} took {} iterations", rep.iterations);
        prop_assert!(rep.converged, "{kind:?} not converged from solution");
        // and the solution is unchanged
        let drift = v2.max_abs_diff(&v);
        prop_assert!(drift < 1e-12, "warm restart drifted by {drift}");
        Ok(())
    });
}

#[test]
fn prop_normalisation_roundtrip() {
    check("normalisation_roundtrip", PropConfig { cases: 16, max_size: 16, ..Default::default() }, |rng, size| {
        let (op, b) = dense_op(rng, size);
        let mut v = Mat::from_fn(op.n(), op.k_width(), |_, _| rng.gaussian());
        let v_orig = v.clone();
        let (norm, _r) = Normalized::setup(&op, &b, &mut v);
        norm.finish(&mut v);
        let err = v.max_abs_diff(&v_orig);
        prop_assert!(err < 1e-10, "normalise/denormalise drift {err}");
        // scaled targets have unit columns
        let mut bs = b.clone();
        let inv: Vec<f64> = norm.norms.iter().map(|&x| 1.0 / x).collect();
        igp::solvers::scale_cols(&mut bs, &inv);
        for nn in col_norms(&bs) {
            prop_assert!((nn - 1.0).abs() < 1e-9, "column norm {nn}");
        }
        Ok(())
    });
}

#[test]
fn prop_probe_targets_freeze_under_warm_start() {
    check("probe_freeze", PropConfig { cases: 6, max_size: 6, ..Default::default() }, |rng, size| {
        let (mut op, _) = dense_op(rng, size);
        let y = vec![0.5; op.n()];
        let kind = if size % 2 == 0 { EstimatorKind::Standard } else { EstimatorKind::Pathwise };
        let ps = ProbeSet::sample(kind, &op, rng);
        let b1 = ps.targets(&op, &y);
        let b1_again = ps.targets(&op, &y);
        prop_assert!(
            b1.max_abs_diff(&b1_again) == 0.0,
            "targets not deterministic under fixed theta"
        );
        // pathwise targets must respond to theta (reparameterised), while
        // standard targets must not
        let d = op.d();
        let hp2 = Hyperparams {
            ell: vec![rng.uniform_in(0.4, 0.6); d],
            sigf: 1.4,
            sigma: 0.7,
        };
        op.set_hp(&hp2);
        let b2 = ps.targets(&op, &y);
        match kind {
            EstimatorKind::Standard => {
                prop_assert!(b1.max_abs_diff(&b2) == 0.0, "standard probes changed with theta")
            }
            EstimatorKind::Pathwise => {
                prop_assert!(b1.max_abs_diff(&b2) > 1e-6, "pathwise probes ignored theta")
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ap_epoch_accounting_is_block_fraction() {
    check("ap_epoch_accounting", PropConfig { cases: 8, max_size: 8, ..Default::default() }, |rng, size| {
        let (op, b) = dense_op(rng, size);
        let budget = 1.0 + (size % 4) as f64;
        let opts = SolveOptions {
            tolerance: 1e-14,
            max_epochs: budget,
            block_size: 64,
            ..Default::default()
        };
        let mut v = Mat::zeros(op.n(), op.k_width());
        let rep = make_solver(SolverKind::Ap).solve(&op, &b, &mut v, &opts);
        let per_iter = 64.0 / op.n() as f64;
        let expected = rep.iterations as f64 * per_iter;
        prop_assert!(
            (rep.epochs - expected).abs() < 1e-9,
            "epochs {} != iterations*b/n {expected}",
            rep.epochs
        );
        Ok(())
    });
}

#[test]
fn prop_config_parser_roundtrip() {
    check("config_roundtrip", PropConfig { cases: 32, max_size: 32, ..Default::default() }, |rng, size| {
        // random scalar values survive render -> parse
        let ints: Vec<i64> = (0..size).map(|_| rng.next_u64() as i64 % 10_000).collect();
        let floats: Vec<f64> = (0..size).map(|_| rng.uniform_in(-10.0, 10.0)).collect();
        let mut text = String::from("[s]\n");
        for (i, v) in ints.iter().enumerate() {
            text.push_str(&format!("i{i} = {v}\n"));
        }
        for (i, v) in floats.iter().enumerate() {
            text.push_str(&format!("f{i} = {v:.12}\n"));
        }
        let doc = config::parse(&text).map_err(|e| e.to_string())?;
        for (i, v) in ints.iter().enumerate() {
            let got = doc.get("s", &format!("i{i}")).unwrap().as_int().map_err(|e| e.to_string())?;
            prop_assert!(got == *v, "int {i}: {got} != {v}");
        }
        for (i, v) in floats.iter().enumerate() {
            let got = doc.get("s", &format!("f{i}")).unwrap().as_float().map_err(|e| e.to_string())?;
            prop_assert!((got - v).abs() < 1e-9, "float {i}: {got} != {v}");
        }
        Ok(())
    });
}

#[test]
fn prop_solver_residuals_match_across_backends() {
    // CG/AP/SGD on random SPD systems must reach the same residual norms
    // (and essentially the same solutions) whether the O(n^2) products run
    // through the dense oracle or the matrix-free tiled backend.
    check("backend_residual_parity", PropConfig { cases: 9, max_size: 9, ..Default::default() }, |rng, size| {
        let (dense, tiled, b) = backend_pair(rng, size);
        let kind = match size % 3 {
            0 => SolverKind::Cg,
            1 => SolverKind::Ap,
            _ => SolverKind::Sgd,
        };
        let opts = SolveOptions {
            tolerance: 0.01,
            max_epochs: 300.0,
            precond_rank: 32,
            block_size: 64,
            sgd_lr: 4.0,
            ..Default::default()
        };
        let mut vd = Mat::zeros(dense.n(), dense.k_width());
        let rep_d = make_solver(kind).solve(&dense, &b, &mut vd, &opts);
        let mut vt = Mat::zeros(tiled.n(), tiled.k_width());
        let rep_t = make_solver(kind).solve(&tiled, &b, &mut vt, &opts);

        if kind == SolverKind::Cg {
            // CG's hv goes through the symmetric tiling, so iterates carry
            // FP-level drift; a boundary tie can shift termination by one
            // iteration.  Both runs must converge either way.
            prop_assert!(
                rep_d.converged && rep_t.converged,
                "CG must converge: dense {rep_d:?} vs tiled {rep_t:?}"
            );
            let di = rep_d.iterations as i64 - rep_t.iterations as i64;
            prop_assert!(di.abs() <= 1, "CG iterations {} vs {}", rep_d.iterations, rep_t.iterations);
            if rep_d.iterations == rep_t.iterations {
                prop_assert!(
                    (rep_d.ry - rep_t.ry).abs() <= 1e-6 && (rep_d.rz - rep_t.rz).abs() <= 1e-6,
                    "CG residuals ({}, {}) vs ({}, {})",
                    rep_d.ry,
                    rep_d.rz,
                    rep_t.ry,
                    rep_t.rz
                );
                let drift = vd.max_abs_diff(&vt);
                prop_assert!(drift <= 1e-4, "CG solution drift {drift}");
            }
        } else {
            // AP/SGD touch the operator only through k_cols/k_rows, which
            // the tiled backend evaluates in the same summation order as
            // dense — the whole trajectory must match to FP noise.
            prop_assert!(
                rep_d.converged == rep_t.converged,
                "{kind:?} convergence mismatch: dense {rep_d:?} vs tiled {rep_t:?}"
            );
            prop_assert!(
                rep_d.iterations == rep_t.iterations,
                "{kind:?} iterations {} vs {}",
                rep_d.iterations,
                rep_t.iterations
            );
            prop_assert!(
                (rep_d.ry - rep_t.ry).abs() <= 1e-10 && (rep_d.rz - rep_t.rz).abs() <= 1e-10,
                "{kind:?} residuals ({}, {}) vs ({}, {})",
                rep_d.ry,
                rep_d.rz,
                rep_t.ry,
                rep_t.rz
            );
            let drift = vd.max_abs_diff(&vt);
            prop_assert!(drift <= 1e-8, "{kind:?} solution drift {drift}");
        }
        Ok(())
    });
}

#[test]
fn prop_checkpoint_roundtrip_on_tiled_backend() {
    // Warm-start state must survive a checkpoint/restore cycle with the
    // tiled backend selected: N straight outer steps == N1 steps +
    // checkpoint + restore + N2 steps.
    check("tiled_checkpoint_roundtrip", PropConfig { cases: 3, max_size: 3, ..Default::default() }, |rng, size| {
        let seed = rng.next_u64() % 1000;
        let steps_a = 2 + size % 3;
        let steps_b = 2;
        let mk_trainer = || {
            let ds = generate_split(&spec("test").unwrap(), 0);
            let op = TiledOperator::with_options(
                &ds,
                8,
                32,
                TiledOptions { tile: 96, threads: 2 },
            );
            let opts = TrainerOptions {
                solver: SolverKind::Ap,
                estimator: EstimatorKind::Pathwise,
                warm_start: true,
                lr: 0.1,
                epoch_cap: 150.0,
                block_size: Some(64),
                seed,
                ..Default::default()
            };
            Trainer::new(opts, Box::new(op), &ds)
        };
        let mut straight = mk_trainer();
        straight.run(steps_a + steps_b).map_err(|e| e.to_string())?;

        let mut first = mk_trainer();
        first.run(steps_a).map_err(|e| e.to_string())?;
        let ck = first.checkpoint();
        let mut resumed = mk_trainer();
        resumed.restore(&ck).map_err(|e| e.to_string())?;
        resumed.run(steps_b).map_err(|e| e.to_string())?;

        let ta = straight.theta();
        let tb = resumed.theta();
        for (i, (x, y)) in ta.iter().zip(&tb).enumerate() {
            prop_assert!((x - y).abs() < 1e-9, "theta[{i}]: {x} vs {y}");
        }
        Ok(())
    });
}

#[test]
fn prop_threaded_recurrences_bitwise_equal_serial() {
    // the recurrence-layer contract end to end: a full solve with any
    // recurrence thread count returns bit-identical reports and solutions
    // to the serial one (the operator is dense, i.e. single-threaded, so
    // only the recurrence layer varies)
    check("recurrence_bitwise", PropConfig { cases: 9, max_size: 9, ..Default::default() }, |rng, size| {
        let (op, b) = dense_op(rng, size);
        let kind = match size % 3 {
            0 => SolverKind::Cg,
            1 => SolverKind::Ap,
            _ => SolverKind::Sgd,
        };
        let threads = 2 + size % 5;
        let run = |t: usize| {
            let opts = SolveOptions {
                tolerance: 0.01,
                max_epochs: 60.0,
                block_size: 64,
                precond_rank: 16,
                sgd_lr: 4.0,
                threads: t,
                ..Default::default()
            };
            let mut v = Mat::zeros(op.n(), op.k_width());
            // fixed-seed solvers so SGD minibatch draws are identical
            let mut solver: Box<dyn igp::solvers::LinearSolver> = match kind {
                SolverKind::Sgd => Box::new(igp::solvers::SgdSolver::with_seed(7)),
                _ => make_solver(kind),
            };
            let rep = solver.solve(&op, &b, &mut v, &opts);
            (rep, v)
        };
        let (rep_s, v_s) = run(1);
        let (rep_t, v_t) = run(threads);
        prop_assert!(rep_t == rep_s, "{kind:?} t={threads}: {rep_t:?} vs {rep_s:?}");
        let bit_equal = v_t.data.iter().zip(&v_s.data).all(|(a, b)| a.to_bits() == b.to_bits());
        prop_assert!(bit_equal, "{kind:?} t={threads}: solutions differ in bits");
        Ok(())
    });
}

#[test]
fn prop_cached_preconditioner_applies_like_fresh() {
    check("precond_cache_apply", PropConfig { cases: 8, max_size: 8, ..Default::default() }, |rng, size| {
        let (op, b) = dense_op(rng, size);
        let rank = 4 + 4 * (size % 5);
        let cache = igp::solvers::PreconditionerCache::default();
        // warm the cache, then fetch again (hit) and compare with a build
        // that never saw the cache, under different thread counts
        let first = cache.woodbury(&op, rank, 1 + size % 4).unwrap();
        let cached = cache.woodbury(&op, rank, 1).unwrap();
        prop_assert!(cache.hits() >= 1, "second fetch must hit");
        let fresh = igp::solvers::WoodburyPreconditioner::build_threaded(
            op.x(),
            op.hp(),
            op.family(),
            rank,
            1,
        )
        .unwrap();
        let applied_cached = cached.apply_t(&b, 2 + size % 3);
        let applied_fresh = fresh.apply_t(&b, 1);
        let bit_equal = applied_cached
            .data
            .iter()
            .zip(&applied_fresh.data)
            .all(|(x, y)| x.to_bits() == y.to_bits());
        prop_assert!(bit_equal, "cached apply differs from fresh (rank {rank})");
        // and the first fetch is literally the same object as the hit
        prop_assert!(
            std::sync::Arc::ptr_eq(&first, &cached),
            "cache returned a different preconditioner for the same key"
        );
        Ok(())
    });
}

#[test]
fn prop_rng_gaussian_matrix_is_full_rank_ish() {
    // sanity guard for probe sampling: no degenerate columns
    check("probe_rank", PropConfig { cases: 8, max_size: 8, ..Default::default() }, |rng, size| {
        let n = 16 + 8 * size;
        let z = Mat::from_fn(n, 4, |_, _| rng.gaussian());
        let norms = col_norms(&z);
        for nn in norms {
            prop_assert!(nn > 1e-3, "degenerate probe column");
        }
        Ok(())
    });
}
