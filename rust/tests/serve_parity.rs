//! Serving parity: the acceptance bar of the prediction-serving subsystem
//! (after Maddox et al. 2021, predictive quality is the bar — the serving
//! path must be *exactly* the evaluate path, not just fast).
//!
//! * `PredictionService` on the dataset's own test split is bitwise-equal
//!   to `Trainer::evaluate`'s mean/variance (checked through the metric
//!   bits and through the artifact directly);
//! * tiled `predict_at` == dense `predict_at` bitwise at arbitrary query
//!   batches;
//! * threaded == serial for several thread counts and batch sizes;
//! * artifact refresh after `extend_data` matches a from-scratch rebuild
//!   and costs exactly one warm solve.

use igp::coordinator::{Trainer, TrainerOptions};
use igp::data::Dataset;
use igp::estimator::EstimatorKind;
use igp::gp::pathwise_variances;
use igp::kernels::Hyperparams;
use igp::linalg::Mat;
use igp::operators::{DenseOperator, KernelOperator, TiledOperator, TiledOptions};
use igp::serve::{PredictionService, ServeOptions};
use igp::solvers::SolverKind;
use igp::util::rng::Rng;

fn dataset() -> Dataset {
    igp::data::generate(&igp::data::spec("test").unwrap())
}

fn trainer(ds: &Dataset, estimator: EstimatorKind, seed: u64) -> Trainer {
    let op = DenseOperator::new(ds, 8, 32);
    let opts = TrainerOptions {
        solver: SolverKind::Ap,
        estimator,
        warm_start: true,
        lr: 0.1,
        epoch_cap: 200.0,
        block_size: Some(64),
        seed,
        ..Default::default()
    };
    Trainer::new(opts, Box::new(op), ds)
}

fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn service_on_the_test_split_is_bitwise_equal_to_evaluate() {
    for estimator in [EstimatorKind::Pathwise, EstimatorKind::Standard] {
        let ds = dataset();
        let mut t = trainer(&ds, estimator, 7);
        let out = t.run(5).unwrap();
        let solves = t.solve_count();

        // reference mean/variance straight from the artifact the tail
        // evaluation published (the exact state evaluate used)
        let art = t.posterior_artifact().unwrap();
        let (ref_mean, ref_samples) = t
            .operator()
            .predict_at(&ds.x_test, &art.vy, &art.zhat, &art.omega0, &art.wts)
            .unwrap();
        let ref_var = pathwise_variances(&ref_samples, art.noise_var);

        let mut service =
            PredictionService::new(t, ServeOptions { batch: 17, threads: 2, ..Default::default() });
        let (mean, var) = service.predict(&ds.x_test).unwrap();
        assert!(bits_eq(&mean, &ref_mean), "{estimator:?}: service mean drifted");
        assert!(bits_eq(&var, &ref_var), "{estimator:?}: service variance drifted");

        // the metrics recomputed from the served values carry the same
        // bits as the evaluate path's final_metrics
        let m = service.score(&ds.x_test, &ds.y_test).unwrap();
        assert_eq!(
            m.rmse.to_bits(),
            out.final_metrics.rmse.to_bits(),
            "{estimator:?}: rmse bits differ"
        );
        assert_eq!(
            m.llh.to_bits(),
            out.final_metrics.llh.to_bits(),
            "{estimator:?}: llh bits differ"
        );
        // and none of it re-solved anything
        assert_eq!(service.trainer().solve_count(), solves, "{estimator:?}: serving re-solved");
    }
}

#[test]
fn tiled_predict_at_is_bitwise_equal_to_dense_on_arbitrary_queries() {
    let ds = dataset();
    let hp = Hyperparams { ell: vec![0.9, 1.2, 0.7, 1.1], sigf: 1.2, sigma: 0.35 };
    let mut dense = DenseOperator::new(&ds, 4, 16);
    dense.set_hp(&hp);
    let mut rng = Rng::new(3);
    let n = dense.n();
    let (m, s) = (8, 3);
    let omega0 = Mat::from_fn(dense.d(), m, |_, _| rng.gaussian());
    let wts = Mat::from_fn(2 * m, s, |_, _| rng.gaussian());
    let zhat = Mat::from_fn(n, s, |_, _| rng.gaussian());
    let vy = rng.gaussian_vec(n);
    // query batches of several shapes, none of them the stored test split
    for rows in [1, 7, 64, 333] {
        let xq = Mat::from_fn(rows, dense.d(), |_, _| rng.gaussian());
        let (dm, dsamp) = dense.predict_at(&xq, &vy, &zhat, &omega0, &wts).unwrap();
        for (tile, threads) in [(1, 1), (32, 2), (256, 4), (500, 3)] {
            let mut tiled =
                TiledOperator::with_options(&ds, 4, 16, TiledOptions { tile, threads });
            tiled.set_hp(&hp);
            let (tm, tsamp) = tiled.predict_at(&xq, &vy, &zhat, &omega0, &wts).unwrap();
            assert!(
                bits_eq(&tm, &dm),
                "rows={rows} tile={tile} threads={threads}: mean bits differ"
            );
            assert!(
                bits_eq(&tsamp.data, &dsamp.data),
                "rows={rows} tile={tile} threads={threads}: sample bits differ"
            );
        }
    }
}

#[test]
fn threaded_service_is_bitwise_equal_to_serial() {
    // identical trainers (deterministic from the seed) wrapped in services
    // with different thread counts and batch sizes must serve identical
    // bits — the order-canonical reduction contract
    let ds = dataset();
    let mut rng = Rng::new(9);
    let xq = Mat::from_fn(301, ds.spec.d, |_, _| rng.gaussian());
    let serve = |threads: usize, batch: usize| -> (Vec<f64>, Vec<f64>) {
        let mut t = trainer(&ds, EstimatorKind::Pathwise, 21);
        t.run(3).unwrap();
        let mut service =
            PredictionService::new(t, ServeOptions { batch, threads, ..Default::default() });
        service.predict(&xq).unwrap()
    };
    let (mean1, var1) = serve(1, 32);
    for threads in [2, 3, 8] {
        let (m, v) = serve(threads, 32);
        assert!(bits_eq(&m, &mean1), "threads={threads}: mean bits differ");
        assert!(bits_eq(&v, &var1), "threads={threads}: variance bits differ");
    }
    // batch size is equally irrelevant to the bits (per-row independence)
    for batch in [1, 50, 1024] {
        let (m, v) = serve(4, batch);
        assert!(bits_eq(&m, &mean1), "batch={batch}: mean bits differ");
        assert!(bits_eq(&v, &var1), "batch={batch}: variance bits differ");
    }
}

#[test]
fn artifact_refresh_after_extend_matches_a_from_scratch_rebuild() {
    // two identical trainers follow the same train -> extend schedule; one
    // serves through the service (lazy artifact refresh on first query),
    // the other rebuilds its artifact directly — the served values must be
    // bitwise identical, and the service must pay exactly one warm solve
    let ds = dataset();
    let (base, chunks) = ds.replay_chunks(2);
    let (x_new, y_new) = &chunks[0];
    let mut rng = Rng::new(31);
    let xq = Mat::from_fn(50, ds.spec.d, |_, _| rng.gaussian());

    let mut a = trainer(&base, EstimatorKind::Pathwise, 5);
    a.run(4).unwrap();
    a.extend_data(x_new, y_new).unwrap();
    let solves_before = a.solve_count();
    let mut service =
        PredictionService::new(a, ServeOptions { batch: 16, threads: 2, ..Default::default() });
    let (mean_service, var_service) = service.predict(&xq).unwrap();
    assert_eq!(
        service.trainer().solve_count(),
        solves_before + 1,
        "lazy refresh must cost exactly one solve"
    );

    let mut b = trainer(&base, EstimatorKind::Pathwise, 5);
    b.run(4).unwrap();
    b.extend_data(x_new, y_new).unwrap();
    let art = b.posterior_artifact().unwrap();
    assert_eq!(art.n, base.spec.n + x_new.rows);
    let (mean_direct, samples) = b
        .operator()
        .predict_at(&xq, &art.vy, &art.zhat, &art.omega0, &art.wts)
        .unwrap();
    let var_direct = pathwise_variances(&samples, art.noise_var);

    assert!(bits_eq(&mean_service, &mean_direct), "refreshed mean drifted");
    assert!(bits_eq(&var_service, &var_direct), "refreshed variance drifted");

    // the refresh really was warm: the warm-carried store should need
    // fewer epochs than a cold artifact build on the same grown data
    let mut cold = trainer(
        &ds.with_train(
            {
                let mut x = base.x_train.clone();
                x.append_rows(x_new);
                x
            },
            {
                let mut y = base.y_train.clone();
                y.extend_from_slice(y_new);
                y
            },
        ),
        EstimatorKind::Pathwise,
        5,
    );
    // same hyperparameters as the warm trainer so the comparison is fair
    cold.set_init_theta(&service.trainer().theta());
    let warm_epochs = {
        // rebuild b's artifact from scratch to read its refresh cost:
        // instead, measure through telemetry-free epoch deltas on a third
        // identical warm trainer
        let mut c = trainer(&base, EstimatorKind::Pathwise, 5);
        c.run(4).unwrap();
        c.extend_data(x_new, y_new).unwrap();
        let before = c.total_spent_epochs();
        let _ = c.posterior_artifact().unwrap();
        c.total_spent_epochs() - before
    };
    let cold_epochs = {
        let before = cold.total_spent_epochs();
        let _ = cold.posterior_artifact().unwrap();
        cold.total_spent_epochs() - before
    };
    assert!(
        warm_epochs < cold_epochs,
        "warm refresh ({warm_epochs} epochs) should beat a cold build ({cold_epochs})"
    );
}

#[test]
fn service_queue_accumulates_and_flushes_in_order() {
    let ds = dataset();
    let mut t = trainer(&ds, EstimatorKind::Pathwise, 11);
    t.run(3).unwrap();
    let mut rng = Rng::new(13);
    let q1 = Mat::from_fn(10, ds.spec.d, |_, _| rng.gaussian());
    let q2 = Mat::from_fn(23, ds.spec.d, |_, _| rng.gaussian());
    let mut all = q1.clone();
    all.append_rows(&q2);

    let mut service =
        PredictionService::new(t, ServeOptions { batch: 8, threads: 1, ..Default::default() });
    service.enqueue(&q1).unwrap();
    service.enqueue(&q2).unwrap();
    assert_eq!(service.pending_rows(), 33);
    let (mean_flush, var_flush) = service.flush().unwrap();
    assert_eq!(service.pending_rows(), 0);
    let (mean_once, var_once) = service.predict(&all).unwrap();
    assert!(bits_eq(&mean_flush, &mean_once));
    assert!(bits_eq(&var_flush, &var_once));
    // dimension mismatches are rejected
    assert!(service.enqueue(&Mat::zeros(2, ds.spec.d + 1)).is_err());
    assert!(service.predict(&Mat::zeros(2, ds.spec.d + 1)).is_err());
    // empty queries are fine
    let (m, v) = service.predict(&Mat::zeros(0, ds.spec.d)).unwrap();
    assert!(m.is_empty() && v.is_empty());
    let st = service.stats();
    assert_eq!(st.counters.rows_served, 66);
    assert!(st.counters.batches >= 10); // ceil(33/8) twice (dense fan-out)
}
