//! Property tests: the matrix-free multi-threaded `TiledOperator` must
//! agree elementwise with the `DenseOperator` oracle on every
//! `KernelOperator` method, across random draws of n, d, probe count,
//! tile size (including sizes that do not divide n), thread count and
//! kernel family.

use igp::data::{Dataset, DatasetSpec};
use igp::kernels::{Hyperparams, KernelFamily};
use igp::linalg::Mat;
use igp::operators::{DenseOperator, KernelOperator, TiledOperator, TiledOptions};
use igp::prop_assert;
use igp::util::proptest::{check, PropConfig};
use igp::util::rng::Rng;

fn random_family(rng: &mut Rng) -> KernelFamily {
    match rng.below(4) {
        0 => KernelFamily::Matern12,
        1 => KernelFamily::Matern32,
        2 => KernelFamily::Matern52,
        _ => KernelFamily::Rbf,
    }
}

fn toy_dataset(rng: &mut Rng, n: usize, n_test: usize, d: usize, family: KernelFamily) -> Dataset {
    let x_train = Mat::from_fn(n, d, |_, _| rng.gaussian());
    let y_train = rng.gaussian_vec(n);
    let x_test = Mat::from_fn(n_test, d, |_, _| rng.gaussian());
    let y_test = rng.gaussian_vec(n_test);
    let spec = DatasetSpec {
        name: "toy",
        paper_n: 0,
        n,
        n_test,
        d,
        true_sigma: 0.3,
        ell_lo: 0.5,
        ell_hi: 1.5,
        cluster_frac: 0.0,
        family,
        seed: 0,
    };
    Dataset {
        spec,
        x_train,
        y_train,
        x_test,
        y_test,
        true_hp: Hyperparams::ones(d),
    }
}

/// One random case: dataset + hyperparameters + a tiled/dense operator pair.
struct Case {
    ds: Dataset,
    tiled: TiledOperator,
    dense: DenseOperator,
}

fn random_case(rng: &mut Rng, size: usize) -> Case {
    let n = 8 + rng.below(8 + 6 * size.max(1));
    let n_test = 1 + rng.below(8);
    let d = 1 + rng.below(5);
    let s = 1 + rng.below(4);
    let m = 4 + rng.below(12);
    let family = random_family(rng);
    // tile sizes deliberately include 1, non-divisors of n, and > n
    let tile = match rng.below(4) {
        0 => 1,
        1 => 1 + rng.below(n),
        2 => n,
        _ => n + 1 + rng.below(64),
    };
    let threads = 1 + rng.below(4);
    let ds = toy_dataset(rng, n, n_test, d, family);
    let hp = Hyperparams {
        ell: (0..d).map(|_| rng.uniform_in(0.4, 2.0)).collect(),
        sigf: rng.uniform_in(0.5, 1.5),
        sigma: rng.uniform_in(0.1, 0.9),
    };
    let mut tiled = TiledOperator::with_options(&ds, s, m, TiledOptions { tile, threads });
    tiled.set_hp(&hp);
    let mut dense = DenseOperator::new(&ds, s, m);
    dense.set_hp(&hp);
    Case { ds, tiled, dense }
}

fn close(label: &str, got: &Mat, want: &Mat) -> Result<(), String> {
    if (got.rows, got.cols) != (want.rows, want.cols) {
        return Err(format!(
            "{label}: shape ({}, {}) vs ({}, {})",
            got.rows, got.cols, want.rows, want.cols
        ));
    }
    let scale = 1.0 + want.data.iter().fold(0.0f64, |a, &x| a.max(x.abs()));
    let err = got.max_abs_diff(want);
    if err > 1e-10 * scale {
        return Err(format!("{label}: max abs err {err} (scale {scale})"));
    }
    Ok(())
}

#[test]
fn prop_hv_matches_dense() {
    check("tiled_hv_parity", PropConfig { cases: 24, max_size: 16, ..Default::default() }, |rng, size| {
        let c = random_case(rng, size);
        let v = Mat::from_fn(c.tiled.n(), c.tiled.k_width(), |_, _| rng.gaussian());
        close("hv", &c.tiled.hv(&v), &c.dense.hv(&v))
    });
}

#[test]
fn prop_k_cols_and_k_rows_match_dense() {
    check("tiled_kcols_krows_parity", PropConfig { cases: 24, max_size: 16, ..Default::default() }, |rng, size| {
        let c = random_case(rng, size);
        let n = c.tiled.n();
        let bsz = 1 + rng.below(n);
        let idx = rng.sample_indices(n, bsz);
        let u = Mat::from_fn(bsz, c.tiled.k_width(), |_, _| rng.gaussian());
        close("k_cols", &c.tiled.k_cols(&idx, &u), &c.dense.k_cols(&idx, &u))?;
        let v = Mat::from_fn(n, c.tiled.k_width(), |_, _| rng.gaussian());
        close("k_rows", &c.tiled.k_rows(&idx, &v), &c.dense.k_rows(&idx, &v))
    });
}

#[test]
fn prop_grad_quad_matches_dense() {
    check("tiled_grad_quad_parity", PropConfig { cases: 16, max_size: 12, ..Default::default() }, |rng, size| {
        let c = random_case(rng, size);
        let k = c.tiled.k_width();
        let n = c.tiled.n();
        let a = Mat::from_fn(n, k, |_, _| rng.gaussian());
        let b = Mat::from_fn(n, k, |_, _| rng.gaussian());
        let w: Vec<f64> = (0..k).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        let g1 = c.tiled.grad_quad(&a, &b, &w);
        let g2 = c.dense.grad_quad(&a, &b, &w);
        prop_assert!(g1.len() == g2.len(), "len {} vs {}", g1.len(), g2.len());
        for (i, (x, y)) in g1.iter().zip(&g2).enumerate() {
            prop_assert!(
                (x - y).abs() <= 1e-10 * (1.0 + y.abs()),
                "grad comp {i}: {x} vs {y}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_rff_eval_matches_dense() {
    check("tiled_rff_parity", PropConfig { cases: 16, max_size: 12, ..Default::default() }, |rng, size| {
        let c = random_case(rng, size);
        let (n, d, s, m) = (c.tiled.n(), c.tiled.d(), c.tiled.s(), c.tiled.m());
        let omega0 = Mat::from_fn(d, m, |_, _| rng.gaussian());
        let wts = Mat::from_fn(2 * m, s, |_, _| rng.gaussian());
        let noise = Mat::from_fn(n, s, |_, _| rng.gaussian());
        close(
            "rff_eval",
            &c.tiled.rff_eval(&omega0, &wts, &noise),
            &c.dense.rff_eval(&omega0, &wts, &noise),
        )
    });
}

#[test]
fn prop_predict_matches_dense() {
    check("tiled_predict_parity", PropConfig { cases: 16, max_size: 12, ..Default::default() }, |rng, size| {
        let c = random_case(rng, size);
        let (n, d, s, m) = (c.tiled.n(), c.tiled.d(), c.tiled.s(), c.tiled.m());
        let omega0 = Mat::from_fn(d, m, |_, _| rng.gaussian());
        let wts = Mat::from_fn(2 * m, s, |_, _| rng.gaussian());
        let vy = rng.gaussian_vec(n);
        let zhat = Mat::from_fn(n, s, |_, _| rng.gaussian());
        let (m1, s1) = c.tiled.predict(&vy, &zhat, &omega0, &wts);
        let (m2, s2) = c.dense.predict(&vy, &zhat, &omega0, &wts);
        for (i, (x, y)) in m1.iter().zip(&m2).enumerate() {
            prop_assert!(
                (x - y).abs() <= 1e-10 * (1.0 + y.abs()),
                "mean {i}: {x} vs {y}"
            );
        }
        close("predict samples", &s1, &s2)
    });
}

#[test]
fn prop_exact_mll_matches_dense() {
    check("tiled_exact_mll_parity", PropConfig { cases: 8, max_size: 8, ..Default::default() }, |rng, size| {
        let c = random_case(rng, size);
        let (l1, g1) = match c.tiled.exact_mll(&c.ds.y_train) {
            Some(v) => v,
            None => return Err("tiled exact_mll returned None".into()),
        };
        let (l2, g2) = match c.dense.exact_mll(&c.ds.y_train) {
            Some(v) => v,
            None => return Err("dense exact_mll returned None".into()),
        };
        prop_assert!((l1 - l2).abs() <= 1e-9 * (1.0 + l2.abs()), "mll {l1} vs {l2}");
        for (i, (x, y)) in g1.iter().zip(&g2).enumerate() {
            prop_assert!(
                (x - y).abs() <= 1e-9 * (1.0 + y.abs()),
                "mll grad {i}: {x} vs {y}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_hv_deterministic_and_tile_invariant() {
    // the same operator must be bit-deterministic across calls, and two
    // operators differing only in tile size must agree to FP tolerance
    check("tiled_hv_determinism", PropConfig { cases: 12, max_size: 12, ..Default::default() }, |rng, size| {
        let c = random_case(rng, size);
        let v = Mat::from_fn(c.tiled.n(), c.tiled.k_width(), |_, _| rng.gaussian());
        let a = c.tiled.hv(&v);
        let b = c.tiled.hv(&v);
        prop_assert!(a == b, "hv not deterministic across repeated calls");
        let mut other = TiledOperator::with_options(
            &c.ds,
            c.tiled.s(),
            c.tiled.m(),
            TiledOptions { tile: 1 + rng.below(2 * c.tiled.n()), threads: 1 + rng.below(4) },
        );
        other.set_hp(c.tiled.hp());
        close("hv tile-invariance", &other.hv(&v), &a)
    });
}

#[test]
fn tiled_memory_footprint_is_matrix_free() {
    // Behavioural proxy for O(n d) memory: set_hp on a tiled operator must
    // be effectively free (no H rebuild), whereas the dense backend
    // recomputes the full n x n matrix on every call.  Assert that many
    // repeated set_hp calls complete and products stay finite.
    let ds = igp::data::generate(&igp::data::spec("test").unwrap());
    let mut op = TiledOperator::new(&ds, 4, 16);
    let mut rng = Rng::new(0);
    let v = Mat::from_fn(op.n(), op.k_width(), |_, _| rng.gaussian());
    let mut last = None;
    for i in 0..50 {
        let hp = Hyperparams { ell: vec![1.0; op.d()], sigf: 1.0, sigma: 0.2 + 0.001 * (i % 3) as f64 };
        op.set_hp(&hp);
        if i % 25 == 0 {
            last = Some(op.hv(&v));
        }
    }
    assert!(last.unwrap().data.iter().all(|x| x.is_finite()));
}
