//! Chaos sweep: scheduled fault injection across seeds × sites × solvers
//! × backends, driven entirely through the public `arm_faults` surface.
//!
//! The contract under test is the supervisor's recovery ladder:
//!
//! - **no panics** anywhere in the sweep — every injected fault is either
//!   recovered or surfaced as a typed error;
//! - **recoverable faults are bitwise-invisible**: the run converges to
//!   telemetry (per-step theta/grad/residuals), final hyperparameters and
//!   test metrics bit-identical to the fault-free run, with the recovery
//!   cost metered *on top* in `total_epochs` and `TrainOutcome::recovery`;
//! - **unrecoverable faults** (a schedule that outlasts bounded retry and
//!   the cg-f64 fallback) surface a typed [`igp::fault::FaultError`] and
//!   leave the trainer, its warm-start store and its caches usable.
//!
//! The sweep runs at `Precision::F64` — the bitwise reference path; the
//! f32 pipeline's drift-guard fallback is itself a (deliberate, guarded)
//! divergence source and has its own parity suite.

use std::sync::Arc;

use igp::coordinator::{TrainOutcome, Trainer, TrainerOptions};
use igp::data::{self, Dataset};
use igp::estimator::EstimatorKind;
use igp::fault::FaultPlan;
use igp::operators::{
    DenseOperator, KernelOperator, ShardedOperator, TiledOperator, TiledOptions,
};
use igp::solvers::SolverKind;

const BACKENDS: [&str; 3] = ["dense", "tiled", "sharded"];
const SOLVERS: [SolverKind; 3] = [SolverKind::Cg, SolverKind::Ap, SolverKind::Sgd];

fn make_op(backend: &str, ds: &Dataset) -> Box<dyn KernelOperator> {
    let topts = TiledOptions { tile: 96, threads: 2 };
    match backend {
        "dense" => Box::new(DenseOperator::new(ds, 8, 32)),
        "tiled" => Box::new(TiledOperator::with_options(ds, 8, 32, topts)),
        _ => Box::new(ShardedOperator::with_options(ds, 8, 32, topts, 3)),
    }
}

fn trainer(solver: SolverKind, backend: &str, ds: &Dataset) -> Trainer {
    let opts = TrainerOptions {
        solver,
        estimator: EstimatorKind::Pathwise,
        warm_start: true,
        lr: 0.05,
        epoch_cap: 200.0,
        block_size: Some(64),
        sgd_lr: Some(8.0),
        seed: 13,
        ..Default::default()
    };
    Trainer::new(opts, make_op(backend, ds), ds)
}

/// Everything that must be bit-identical between a fault-free run and a
/// recovered run.  Wall-clock fields and `total_epochs` (which carries
/// the metered recovery cost) are deliberately excluded.
fn fingerprint(out: &TrainOutcome) -> Vec<u64> {
    let mut fp = Vec::new();
    for s in &out.telemetry {
        fp.extend(s.theta.iter().map(|x| x.to_bits()));
        fp.extend(s.grad.iter().map(|x| x.to_bits()));
        fp.push(s.ry.to_bits());
        fp.push(s.rz.to_bits());
        fp.push(s.iterations as u64);
        fp.push(s.epochs.to_bits());
    }
    fp.extend(out.theta.iter().map(|x| x.to_bits()));
    fp.push(out.final_metrics.rmse.to_bits());
    fp.push(out.final_metrics.llh.to_bits());
    fp
}

#[test]
fn chaos_sweep_recoverable_faults_are_bitwise_invisible() {
    let ds = data::generate(&data::spec("test").unwrap());
    for solver in SOLVERS {
        for backend in BACKENDS {
            let want = trainer(solver, backend, &ds).run(3).unwrap();
            let want_fp = fingerprint(&want);
            for site in ["panel", "probe", "shard", "precond", "solver"] {
                for seed in [5u64, 11] {
                    let tag = format!("{solver:?}/{backend}/{site}/seed={seed}");
                    let spec = format!("seed={seed};{site}@1");
                    let mut t = trainer(solver, backend, &ds);
                    t.arm_faults(Arc::new(FaultPlan::parse(&spec).unwrap()));
                    let out = t
                        .run(3)
                        .unwrap_or_else(|e| panic!("{tag}: recoverable fault errored: {e}"));
                    assert_eq!(
                        fingerprint(&out),
                        want_fp,
                        "{tag}: recovered run diverged from the fault-free run"
                    );
                    assert!(
                        out.total_epochs >= want.total_epochs - 1e-9,
                        "{tag}: recovery cost vanished ({} < {})",
                        out.total_epochs,
                        want.total_epochs
                    );
                    // sites every solver is guaranteed to consume
                    match site {
                        "solver" => {
                            assert!(
                                out.recovery.retries >= 1,
                                "{tag}: stall did not meter a retry: {:?}",
                                out.recovery
                            );
                            assert!(
                                out.recovery.wasted_epochs > 0.0,
                                "{tag}: stall wasted no epochs: {:?}",
                                out.recovery
                            );
                            assert!(
                                out.total_epochs > want.total_epochs,
                                "{tag}: wasted epochs not charged on top"
                            );
                        }
                        "probe" => {
                            assert_eq!(
                                out.recovery.target_repairs, 1,
                                "{tag}: probe corruption not repaired: {:?}",
                                out.recovery
                            );
                        }
                        // panel/shard/precond corruption is consumed only
                        // if the solver routes through the poisoned
                        // product kind (e.g. SGD never builds a
                        // preconditioner panel); when it is consumed the
                        // retry must be metered
                        _ => {
                            if out.recovery.retries > 0 {
                                assert!(
                                    out.recovery.cache_rebuilds >= 1,
                                    "{tag}: retry without quarantine: {:?}",
                                    out.recovery
                                );
                            }
                        }
                    }
                }
            }
            // CG consumes an injected panel corruption through its very
            // first residual product — assert at least one sweep cell
            // exercised the full product-corruption recovery path
            if matches!(solver, SolverKind::Cg) {
                let mut t = trainer(solver, backend, &ds);
                t.arm_faults(Arc::new(FaultPlan::parse("seed=5;panel@1").unwrap()));
                let out = t.run(3).unwrap();
                assert_eq!(fingerprint(&out), want_fp);
                assert!(
                    out.recovery.retries >= 1,
                    "CG/{backend}: panel corruption was never consumed: {:?}",
                    out.recovery
                );
            }
        }
    }
}

#[test]
fn chaos_unrecoverable_fault_is_typed_and_leaves_the_trainer_usable() {
    let ds = data::generate(&data::spec("test").unwrap());
    for solver in SOLVERS {
        let mut t = trainer(solver, "tiled", &ds);
        t.arm_faults(Arc::new(FaultPlan::parse("seed=5;solver@1x99").unwrap()));
        let err = t.run(3).unwrap_err().to_string();
        assert!(
            err.contains("solve failed at outer step 1"),
            "{solver:?}: untyped error: {err}"
        );
        assert!(
            err.contains("cg-f64 fallback"),
            "{solver:?}: error does not name the exhausted fallback: {err}"
        );
        let stats = t.recovery_stats();
        assert_eq!(stats.retries, 3, "{solver:?}: bounded retry drifted: {stats:?}");
        assert_eq!(stats.fallback_solves, 0, "{solver:?}: failed fallback was counted");
        // the trainer survives: re-arm a benign plan and keep training —
        // caches, warm-start store and optimiser state must all be intact
        t.arm_faults(Arc::new(FaultPlan::parse("seed=1").unwrap()));
        let out = t.run(2).unwrap_or_else(|e| panic!("{solver:?}: trainer died: {e}"));
        assert!(
            out.theta.iter().all(|x| x.is_finite()),
            "{solver:?}: post-fault training went non-finite"
        );
        let art = t.posterior_artifact().unwrap();
        assert!(
            art.vy.iter().all(|v| v.is_finite()),
            "{solver:?}: post-fault artifact is poisoned"
        );
    }
}

#[test]
fn armed_but_benign_plan_is_a_bitwise_noop_on_every_backend() {
    let ds = data::generate(&data::spec("test").unwrap());
    for backend in BACKENDS {
        let want = trainer(SolverKind::Cg, backend, &ds).run(2).unwrap();
        let mut t = trainer(SolverKind::Cg, backend, &ds);
        t.arm_faults(Arc::new(FaultPlan::parse("seed=42").unwrap()));
        let out = t.run(2).unwrap();
        assert_eq!(fingerprint(&out), fingerprint(&want), "{backend}: benign plan perturbed");
        assert_eq!(out.total_epochs.to_bits(), want.total_epochs.to_bits());
        assert_eq!(out.recovery.total_events(), 0, "{backend}: {:?}", out.recovery);
    }
}
