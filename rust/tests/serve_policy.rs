//! Staleness-policy and flush-error regressions for
//! [`igp::serve::PredictionService`]:
//!
//! * a failed flush/drain restores the queue — the error path must not
//!   drop queued queries (regression: an early version `mem::replace`d
//!   the queue away before serving, losing everything on error);
//! * `serve_stale` answers bitwise the pre-arrival answers with **zero**
//!   solves, while `refresh_first` pays exactly **one** warm solve and
//!   answers from the grown posterior — observably different answers;
//! * `refuse` rejects with a typed [`ServeError::Stale`] (counted in
//!   `rejected`) until `refresh()` closes the window.

use igp::coordinator::{Trainer, TrainerOptions};
use igp::data::{Dataset, DatasetSpec};
use igp::estimator::EstimatorKind;
use igp::kernels::{Hyperparams, KernelFamily};
use igp::linalg::Mat;
use igp::operators::DenseOperator;
use igp::serve::{PredictionService, ServeError, ServeOptions, StalenessPolicy};
use igp::solvers::SolverKind;
use igp::util::rng::Rng;

fn toy_dataset(rng: &mut Rng, n: usize, d: usize) -> Dataset {
    let x_train = Mat::from_fn(n, d, |_, _| rng.gaussian());
    let y_train = rng.gaussian_vec(n);
    let x_test = Mat::from_fn(4, d, |_, _| rng.gaussian());
    let y_test = rng.gaussian_vec(4);
    let spec = DatasetSpec {
        name: "toy",
        paper_n: 0,
        n,
        n_test: 4,
        d,
        true_sigma: 0.3,
        ell_lo: 0.5,
        ell_hi: 1.5,
        cluster_frac: 0.0,
        family: KernelFamily::Rbf,
        seed: 0,
    };
    Dataset { spec, x_train, y_train, x_test, y_test, true_hp: Hyperparams::ones(d) }
}

fn service(rng: &mut Rng, n: usize, d: usize, policy: StalenessPolicy) -> PredictionService {
    let ds = toy_dataset(rng, n, d);
    let op = Box::new(DenseOperator::new(&ds, 4, 16));
    let opts = TrainerOptions {
        solver: SolverKind::Cg,
        estimator: EstimatorKind::Pathwise,
        warm_start: true,
        lr: 0.05,
        seed: 11,
        ..Default::default()
    };
    let t = Trainer::new(opts, op, &ds);
    PredictionService::new(t, ServeOptions { batch: 8, threads: 1, policy, ..Default::default() })
}

fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn failed_flush_restores_the_queue_instead_of_dropping_it() {
    let mut rng = Rng::new(1);
    let d = 2;
    let mut svc = service(&mut rng, 20, d, StalenessPolicy::RefreshFirst);
    let q1 = Mat::from_fn(3, d, |_, _| rng.gaussian());
    let q2 = Mat::from_fn(5, d, |_, _| rng.gaussian());
    svc.enqueue(&q1).unwrap();
    svc.enqueue(&q2).unwrap();

    // open a staleness window, then make the serve fail under `refuse`
    let x_new = Mat::from_fn(2, d, |_, _| rng.gaussian());
    let y_new = rng.gaussian_vec(2);
    svc.extend_data(&x_new, &y_new).unwrap();
    svc.set_policy(StalenessPolicy::Refuse);
    assert!(svc.flush().is_err(), "refuse inside the staleness window must fail the flush");
    assert_eq!(svc.pending_rows(), 8, "a failed flush dropped queued queries");
    assert_eq!(svc.pending_requests(), 2);
    assert_eq!(svc.stats().counters.rows_served, 0);

    // the queue survived intact: the same flush succeeds once allowed
    svc.set_policy(StalenessPolicy::RefreshFirst);
    let (mean, var) = svc.flush().unwrap();
    assert_eq!((mean.len(), var.len()), (8, 8));
    assert_eq!(svc.pending_rows(), 0);
    // ... answered in enqueue order: bitwise the one-shot answer
    let mut all = q1.clone();
    all.append_rows(&q2);
    let (mean_once, var_once) = svc.predict(&all).unwrap();
    assert!(bits_eq(&mean, &mean_once), "flushed mean drifted from the one-shot answer");
    assert!(bits_eq(&var, &var_once), "flushed variance drifted from the one-shot answer");
}

#[test]
fn serve_stale_is_bitwise_pre_arrival_and_refresh_first_pays_one_warm_solve() {
    let mut rng = Rng::new(2);
    let d = 3;
    let mut svc = service(&mut rng, 24, d, StalenessPolicy::ServeStale);
    let xq = Mat::from_fn(7, d, |_, _| rng.gaussian());

    // pre-arrival serve: pays the one artifact build
    let (mean_pre, var_pre) = svc.predict(&xq).unwrap();
    let solves = svc.trainer().solve_count();

    let x_new = Mat::from_fn(3, d, |_, _| rng.gaussian());
    let y_new = rng.gaussian_vec(3);
    svc.extend_data(&x_new, &y_new).unwrap();

    // serve_stale: bitwise the pre-arrival answers, zero solves, counted
    let (mean_stale, var_stale) = svc.predict(&xq).unwrap();
    assert!(bits_eq(&mean_stale, &mean_pre), "stale mean must be bitwise pre-arrival");
    assert!(bits_eq(&var_stale, &var_pre), "stale variance must be bitwise pre-arrival");
    assert_eq!(svc.trainer().solve_count(), solves, "serve_stale must not solve");
    assert_eq!(svc.stats().counters.stale_rows_served, 7);

    // queued requests carry the stale marker too
    svc.enqueue_with_deadline(&xq, Some(1)).unwrap();
    let r = svc.drain().unwrap();
    assert!(r[0].stale, "drained answers inside the window are marked stale");
    assert!(bits_eq(&r[0].mean, &mean_pre));
    assert_eq!(svc.trainer().solve_count(), solves);

    // refresh_first: exactly one warm solve, and the answers move — the
    // behavioural difference between the two policies
    svc.set_policy(StalenessPolicy::RefreshFirst);
    let (mean_fresh, var_fresh) = svc.predict(&xq).unwrap();
    assert_eq!(
        svc.trainer().solve_count(),
        solves + 1,
        "the refresh must cost exactly one (warm) solve"
    );
    assert!(
        !bits_eq(&mean_fresh, &mean_stale),
        "the grown posterior must answer differently from the stale snapshot"
    );
    assert!(var_fresh.iter().all(|v| *v > 0.0));
    assert_eq!(
        svc.stats().counters.stale_rows_served,
        14,
        "fresh serves are not stale-counted"
    );

    // window closed: the snapshot is gone, serve_stale now serves fresh
    svc.set_policy(StalenessPolicy::ServeStale);
    let (m2, _) = svc.predict(&xq).unwrap();
    assert!(bits_eq(&m2, &mean_fresh));
}

#[test]
fn refuse_rejects_typed_until_refresh_closes_the_window() {
    let mut rng = Rng::new(3);
    let d = 2;
    let mut svc = service(&mut rng, 18, d, StalenessPolicy::Refuse);
    let xq = Mat::from_fn(4, d, |_, _| rng.gaussian());
    // no arrival yet: refuse is inert
    svc.predict(&xq).unwrap();

    let x_new = Mat::from_fn(2, d, |_, _| rng.gaussian());
    let y_new = rng.gaussian_vec(2);
    svc.extend_data(&x_new, &y_new).unwrap();
    let n_new = svc.trainer().operator().n();

    svc.enqueue_with_deadline(&xq, Some(1)).unwrap();
    let err = svc.drain().unwrap_err();
    assert_eq!(err, ServeError::Stale { artifact_n: 18, data_n: n_new });
    assert_eq!(svc.pending_rows(), 4, "a refused drain must keep the queue");
    assert!(svc.predict(&xq).is_err());
    assert_eq!(svc.stats().counters.rejected, 2, "each refused serve attempt is counted");
    assert_eq!(svc.stats().counters.rows_served, 4, "only the pre-arrival serve answered");

    // refresh() closes the window; the kept queue then drains fine
    svc.refresh().unwrap();
    let r = svc.drain().unwrap();
    assert_eq!(r.len(), 1);
    assert_eq!(r[0].mean.len(), 4);
    assert!(!r[0].stale);
    assert_eq!(svc.pending_rows(), 0);
}

#[test]
fn serve_stale_without_a_prior_snapshot_pays_the_build_and_serves_fresh() {
    let mut rng = Rng::new(4);
    let d = 2;
    let mut svc = service(&mut rng, 16, d, StalenessPolicy::ServeStale);
    // arrival before anything was ever served: no snapshot to answer from,
    // so the first query falls through to the (warm) build
    let x_new = Mat::from_fn(2, d, |_, _| rng.gaussian());
    let y_new = rng.gaussian_vec(2);
    svc.extend_data(&x_new, &y_new).unwrap();
    let xq = Mat::from_fn(3, d, |_, _| rng.gaussian());
    let (mean, _var) = svc.predict(&xq).unwrap();
    assert_eq!(mean.len(), 3);
    let c = svc.stats().counters;
    assert_eq!(c.stale_rows_served, 0, "nothing stale was ever served");
    assert_eq!(c.artifact_builds, 1);
    assert_eq!(svc.trainer().solve_count(), 1);
}
