//! Fixture tests for the `igp-lint` rule engine, plus the acceptance
//! test that the tree itself is clean against the checked-in baseline.
//!
//! This file lives in `tests/` (outside `src/`), so the lint pass never
//! scans it — fixture strings below can freely contain violations and
//! suppression directives without tripping the self-scan.

use igp::lint::{self, Baseline, LintReport};
use std::path::Path;

fn lint_one(path: &str, text: &str) -> LintReport {
    lint::lint_sources(&[(path.to_string(), text.to_string())], None)
}

fn rules_of(report: &LintReport) -> Vec<&'static str> {
    report.violations.iter().map(|v| v.rule).collect()
}

// ---------------------------------------------------------------- rules

#[test]
fn float_total_order_flags_partial_cmp_unwrap_and_comparators() {
    let bad = "pub fn f(v: &mut Vec<f64>) {\n    v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";
    let r = lint_one("src/foo.rs", bad);
    assert!(rules_of(&r).contains(&"float-total-order"), "{:?}", r.violations);
    // both patterns fire on the same line but dedup to one finding
    assert_eq!(rules_of(&r).iter().filter(|r| **r == "float-total-order").count(), 1);
    assert_eq!(r.violations.iter().find(|v| v.rule == "float-total-order").map(|v| v.line), Some(2));

    let bad2 = "pub fn g(xs: &[f64]) -> Option<f64> {\n    xs.iter().cloned().max_by(|a, b| a.partial_cmp(b).unwrap())\n}\n";
    let r2 = lint_one("src/foo.rs", bad2);
    assert!(rules_of(&r2).contains(&"float-total-order"), "{:?}", r2.violations);

    let good = "pub fn f(v: &mut Vec<f64>) {\n    v.sort_by(|a, b| a.total_cmp(b));\n}\npub fn g(xs: &[f64]) -> Option<f64> {\n    xs.iter().cloned().max_by(|a, b| a.total_cmp(b))\n}\n";
    let rg = lint_one("src/foo.rs", good);
    assert!(!rules_of(&rg).contains(&"float-total-order"), "{:?}", rg.violations);
}

#[test]
fn float_total_order_applies_inside_test_code_too() {
    // a NaN-panicking comparator in a test helper is the same latent
    // crash, so the test-region exemption does NOT apply to this rule
    let fixture = "#[cfg(test)]\nmod tests {\n    fn sorted(mut v: Vec<f64>) -> Vec<f64> {\n        v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n        v\n    }\n}\n";
    let r = lint_one("src/foo.rs", fixture);
    assert!(rules_of(&r).contains(&"float-total-order"), "{:?}", r.violations);
    // ...while lib-unwrap IS test-exempt, so the unwrap itself is free
    assert!(!rules_of(&r).contains(&"lib-unwrap"), "{:?}", r.violations);
}

#[test]
fn ordered_reduction_is_scoped_to_numeric_dirs_and_helper_homes() {
    let body = "pub fn total(xs: &[f64]) -> f64 {\n    xs.iter().sum()\n}\n";
    assert!(rules_of(&lint_one("src/solvers/foo.rs", body)).contains(&"ordered-reduction"));
    assert!(rules_of(&lint_one("src/operators/foo.rs", body)).contains(&"ordered-reduction"));
    // out of scope: reductions in util/serve/etc are not solver math
    assert!(!rules_of(&lint_one("src/util/foo.rs", body)).contains(&"ordered-reduction"));
    // the canonical helpers themselves are where reductions belong
    assert!(!rules_of(&lint_one("src/linalg/micro.rs", body)).contains(&"ordered-reduction"));
    assert!(!rules_of(&lint_one("src/solvers/recurrence.rs", body)).contains(&"ordered-reduction"));

    let turbofish = "pub fn total(xs: &[f64]) -> f64 {\n    xs.iter().sum::<f64>() * 2.0\n}\n";
    assert!(rules_of(&lint_one("src/linalg/foo.rs", turbofish)).contains(&"ordered-reduction"));

    let fold = "pub fn total(xs: &[f64]) -> f64 {\n    xs.iter().fold(0.0, |a, x| a + x)\n}\n";
    assert!(rules_of(&lint_one("src/solvers/foo.rs", fold)).contains(&"ordered-reduction"));
    // max/min folds are order-insensitive and stay allowed
    let fold_max = "pub fn peak(xs: &[f64]) -> f64 {\n    xs.iter().cloned().fold(0.0, f64::max)\n}\n";
    assert!(!rules_of(&lint_one("src/solvers/foo.rs", fold_max)).contains(&"ordered-reduction"));
}

#[test]
fn ordered_reduction_is_exempt_in_test_code() {
    let fixture = "#[cfg(test)]\nmod tests {\n    fn total(xs: &[f64]) -> f64 {\n        xs.iter().sum()\n    }\n}\n";
    let r = lint_one("src/solvers/foo.rs", fixture);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
}

#[test]
fn no_raw_threads_allows_only_the_parallel_module() {
    let body = "pub fn go() {\n    std::thread::spawn(|| {});\n}\n";
    assert!(rules_of(&lint_one("src/solvers/foo.rs", body)).contains(&"no-raw-threads"));
    assert!(!rules_of(&lint_one("src/util/parallel.rs", body)).contains(&"no-raw-threads"));
    let scoped = "pub fn go() {\n    std::thread::scope(|s| {\n        s.spawn(|| {});\n    });\n}\n";
    assert!(rules_of(&lint_one("src/serve/foo.rs", scoped)).contains(&"no-raw-threads"));
}

#[test]
fn nondeterministic_iteration_allows_runtime_and_respects_ident_boundaries() {
    let body = "use std::collections::HashMap;\npub fn f() -> HashMap<u32, u32> {\n    HashMap::new()\n}\n";
    let r = lint_one("src/solvers/foo.rs", body);
    assert!(rules_of(&r).contains(&"nondeterministic-iteration"), "{:?}", r.violations);
    // runtime/ marshals into external APIs keyed by name; allowlisted
    assert!(!rules_of(&lint_one("src/runtime/foo.rs", body)).contains(&"nondeterministic-iteration"));
    // identifier boundaries: a type that merely embeds the name is fine
    let embedded = "pub struct MyHashMapLike;\npub fn f() -> MyHashMapLike {\n    MyHashMapLike\n}\n";
    let re = lint_one("src/solvers/foo.rs", embedded);
    assert!(re.violations.is_empty(), "{:?}", re.violations);
}

#[test]
fn precision_cast_allows_only_the_blessed_demotion_sites() {
    let body = "pub fn demote(x: f64) -> f32 {\n    x as f32\n}\n";
    assert!(rules_of(&lint_one("src/solvers/foo.rs", body)).contains(&"precision-cast"));
    assert!(!rules_of(&lint_one("src/kernels/panel.rs", body)).contains(&"precision-cast"));
    assert!(!rules_of(&lint_one("src/linalg/micro.rs", body)).contains(&"precision-cast"));
    // test code may build f32 fixtures freely
    let test_code = "#[cfg(test)]\nmod tests {\n    fn d(x: f64) -> f32 {\n        x as f32\n    }\n}\n";
    assert!(lint_one("src/solvers/foo.rs", test_code).violations.is_empty());
}

#[test]
fn lib_unwrap_flags_library_code_but_not_tests() {
    let body = "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\npub fn g(x: Option<u32>) -> u32 {\n    x.expect(\"present\")\n}\n";
    let r = lint_one("src/foo.rs", body);
    assert_eq!(rules_of(&r).iter().filter(|r| **r == "lib-unwrap").count(), 2, "{:?}", r.violations);
    let test_code = "#[test]\nfn t() {\n    Some(1u32).unwrap();\n}\n";
    assert!(lint_one("src/foo.rs", test_code).violations.is_empty());
}

// ------------------------------------------------------------ stripping

#[test]
fn patterns_inside_comments_and_strings_never_fire() {
    let fixture = concat!(
        "// v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n",
        "/* x.unwrap() and std::thread::spawn too,\n   even /* nested */ x.unwrap() */\n",
        "pub fn f() -> &'static str {\n",
        "    let _c = 'x';\n",
        "    let _raw = r#\"x.unwrap() as f32\"#;\n",
        "    \".unwrap() HashMap as f32\"\n",
        "}\n",
    );
    let r = lint_one("src/solvers/foo.rs", fixture);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
}

#[test]
fn lifetimes_do_not_confuse_the_char_literal_scanner() {
    let fixture = "pub fn f<'a>(x: &'a str) -> &'a str {\n    x\n}\npub fn g(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
    let r = lint_one("src/foo.rs", fixture);
    // the unwrap after the lifetimes must still be visible to the scanner
    assert_eq!(rules_of(&r), vec!["lib-unwrap"], "{:?}", r.violations);
}

// ---------------------------------------------------------- suppression

#[test]
fn allow_with_reason_suppresses_the_next_line_and_its_own_line() {
    let above = "pub fn total(xs: &[f64]) -> f64 {\n    // lint:allow(ordered-reduction): fixture waiver\n    xs.iter().sum()\n}\n";
    let r = lint_one("src/solvers/foo.rs", above);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
    assert_eq!(r.suppressed, 1);

    let trailing = "pub fn total(xs: &[f64]) -> f64 {\n    xs.iter().sum() // lint:allow(ordered-reduction): fixture waiver\n}\n";
    let rt = lint_one("src/solvers/foo.rs", trailing);
    assert!(rt.violations.is_empty(), "{:?}", rt.violations);
    assert_eq!(rt.suppressed, 1);
}

#[test]
fn allow_only_covers_the_rules_it_names() {
    let fixture = "pub fn f(xs: &[f64]) -> f64 {\n    // lint:allow(lib-unwrap): wrong rule named\n    xs.iter().sum()\n}\n";
    let r = lint_one("src/solvers/foo.rs", fixture);
    assert_eq!(rules_of(&r), vec!["ordered-reduction"], "{:?}", r.violations);
    // a two-rule directive covers both
    let both = "pub fn f(xs: &[f64]) -> f64 {\n    // lint:allow(ordered-reduction, lib-unwrap): fixture waiver\n    xs.iter().sum()\n}\n";
    assert!(lint_one("src/solvers/foo.rs", both).violations.is_empty());
}

#[test]
fn allow_without_reason_is_malformed_and_suppresses_nothing() {
    let fixture = "pub fn total(xs: &[f64]) -> f64 {\n    // lint:allow(ordered-reduction)\n    xs.iter().sum()\n}\n";
    let r = lint_one("src/solvers/foo.rs", fixture);
    let mut rules = rules_of(&r);
    rules.sort();
    assert_eq!(rules, vec!["malformed-allow", "ordered-reduction"], "{:?}", r.violations);
    // empty reason after the colon is just as malformed
    let empty = "pub fn total(xs: &[f64]) -> f64 {\n    // lint:allow(ordered-reduction):   \n    xs.iter().sum()\n}\n";
    assert!(rules_of(&lint_one("src/solvers/foo.rs", empty)).contains(&"malformed-allow"));
}

#[test]
fn allow_naming_only_unknown_rules_is_inert() {
    // unknown names must not error (forward-compat with rule renames)
    // and must not demand a reason either
    let fixture = "pub fn f() -> u32 {\n    // lint:allow(no-such-rule)\n    7\n}\n";
    let r = lint_one("src/solvers/foo.rs", fixture);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
}

// -------------------------------------------------------------- ratchet

#[test]
fn ratchet_passes_at_baseline_fails_above_and_notes_below() {
    let two = "pub fn f(a: Option<u32>, b: Option<u32>) -> u32 {\n    a.unwrap() + b.unwrap()\n}\n";
    let files = vec![("src/foo.rs".to_string(), two.to_string())];
    let baseline = lint::baseline_from(&files);
    assert_eq!(baseline.count("lib-unwrap", "src/foo.rs"), 2);

    // at baseline: clean
    let r = lint::lint_sources(&files, Some(&baseline));
    assert!(r.violations.is_empty(), "{:?}", r.violations);
    assert!(r.notes.is_empty(), "{:?}", r.notes);

    // one more site: a single per-file summary violation
    let three = "pub fn f(a: Option<u32>, b: Option<u32>) -> u32 {\n    a.unwrap() + b.unwrap() + a.unwrap()\n}\n";
    let worse = vec![("src/foo.rs".to_string(), three.to_string())];
    let rw = lint::lint_sources(&worse, Some(&baseline));
    assert_eq!(rules_of(&rw), vec!["lib-unwrap"], "{:?}", rw.violations);
    assert_eq!(rw.violations[0].line, 0);
    assert!(rw.violations[0].message.contains("baseline"), "{}", rw.violations[0].message);

    // one fewer: clean, but with a tighten-the-ratchet note
    let one = "pub fn f(a: Option<u32>) -> u32 {\n    a.unwrap()\n}\n";
    let better = vec![("src/foo.rs".to_string(), one.to_string())];
    let rb = lint::lint_sources(&better, Some(&baseline));
    assert!(rb.violations.is_empty(), "{:?}", rb.violations);
    assert_eq!(rb.notes.len(), 1, "{:?}", rb.notes);
    assert!(rb.notes[0].contains("--update-baseline"), "{}", rb.notes[0]);

    // updating the baseline locks the better count in
    let updated = lint::baseline_from(&better);
    assert_eq!(updated.count("lib-unwrap", "src/foo.rs"), 1);
    assert!(lint::lint_sources(&better, Some(&updated)).notes.is_empty());
}

#[test]
fn without_a_baseline_ratcheted_violations_report_individually() {
    let two = "pub fn f(a: Option<u32>) -> u32 {\n    a.unwrap() + a.unwrap()\n}\n";
    let r = lint_one("src/foo.rs", two);
    // two sites on one line are two findings — the ratchet counts sites
    assert_eq!(rules_of(&r), vec!["lib-unwrap", "lib-unwrap"], "{:?}", r.violations);
    assert_eq!(r.violations[0].line, 2);
}

#[test]
fn baseline_render_parse_roundtrips_byte_stable() {
    let mut b = Baseline::default();
    b.set("lib-unwrap", "src/z.rs", 3);
    b.set("lib-unwrap", "src/a.rs", 1);
    let text = b.render();
    let re = Baseline::parse(&text).expect("rendered baseline parses");
    assert_eq!(re, b);
    assert_eq!(re.render(), text, "render must be a fixed point");
    // keys come out sorted regardless of insertion order
    let a = text.find("src/a.rs").expect("a present");
    let z = text.find("src/z.rs").expect("z present");
    assert!(a < z, "{text}");
    // the empty baseline also roundtrips
    let empty = Baseline::default();
    assert_eq!(Baseline::parse(&empty.render()).expect("empty parses"), empty);
}

#[test]
fn suppressed_ratcheted_sites_do_not_count_against_the_baseline() {
    let fixture = "pub fn f(a: Option<u32>) -> u32 {\n    // lint:allow(lib-unwrap): fixture waiver\n    a.unwrap()\n}\n";
    let files = vec![("src/foo.rs".to_string(), fixture.to_string())];
    assert_eq!(lint::baseline_from(&files).count("lib-unwrap", "src/foo.rs"), 0);
    let r = lint::lint_sources(&files, Some(&Baseline::default()));
    assert!(r.violations.is_empty(), "{:?}", r.violations);
    assert_eq!(r.suppressed, 1);
}

// ----------------------------------------------------------- acceptance

#[test]
fn the_tree_is_lint_clean_against_the_checked_in_baseline() {
    let crate_root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let baseline_path = crate_root.join("../lint-baseline.json");
    let text = std::fs::read_to_string(&baseline_path)
        .expect("lint-baseline.json must be checked in at the repo root");
    let baseline = Baseline::parse(&text).expect("checked-in baseline must parse");
    let report = lint::lint_tree(crate_root, Some(&baseline)).expect("tree must be readable");
    assert!(
        report.violations.is_empty(),
        "igp-lint must be clean on the tree (fix or suppress with a reason):\n{:#?}",
        report.violations
    );
    assert!(report.files_scanned > 40, "the walk found only {} files", report.files_scanned);
}

#[test]
fn binary_end_to_end_exit_codes_and_json_report() {
    let dir = std::env::temp_dir().join(format!("igp-lint-e2e-{}", std::process::id()));
    let src = dir.join("src");
    std::fs::create_dir_all(&src).expect("temp fixture tree");
    std::fs::write(src.join("foo.rs"), "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n")
        .expect("fixture source");
    let baseline = dir.join("baseline.json");
    std::fs::write(&baseline, Baseline::default().render()).expect("fixture baseline");
    let json = dir.join("report.json");

    let run = |extra: &[&str]| {
        let mut cmd = std::process::Command::new(env!("CARGO_BIN_EXE_igp-lint"));
        cmd.arg("--root").arg(&dir).arg("--baseline").arg(&baseline).arg("--json").arg(&json);
        for a in extra {
            cmd.arg(a);
        }
        cmd.output().expect("igp-lint runs")
    };

    // above baseline: exit 1 and a machine-readable report
    let out = run(&[]);
    assert_eq!(out.status.code(), Some(1), "stdout: {}", String::from_utf8_lossy(&out.stdout));
    let report = std::fs::read_to_string(&json).expect("json report written");
    assert!(report.starts_with("[\n") && report.ends_with("]\n"), "{report}");
    assert!(report.contains("\"rule\": \"lib-unwrap\""), "{report}");
    assert!(report.contains("\"file\": \"src/foo.rs\""), "{report}");

    // --update-baseline grandfathers the site; the same run is then clean
    let out2 = run(&["--update-baseline"]);
    assert_eq!(out2.status.code(), Some(0), "stdout: {}", String::from_utf8_lossy(&out2.stdout));
    let rebased = std::fs::read_to_string(&baseline).expect("baseline rewritten");
    assert!(rebased.contains("\"src/foo.rs\": 1"), "{rebased}");
    let clean = std::fs::read_to_string(&json).expect("json rewritten");
    assert_eq!(clean, "[\n]\n", "a clean run writes an empty record array");

    let _ = std::fs::remove_dir_all(&dir);
}
