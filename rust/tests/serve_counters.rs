//! Property test: [`igp::serve::ServeCounters`] must track a simple
//! reference model under any interleaving of enqueue / flush / predict /
//! refresh / extend_data:
//!
//! * `rows_served` is the total of query rows actually answered;
//! * `batches` counts evaluation blocks actually executed — on the dense
//!   backend's generic fan-out that is ceil(rows / batch) per non-empty
//!   serve, while the tiled backend coalesces each serve into ONE
//!   internally-parallel pass (regression-tested below);
//! * every non-empty serve (or explicit refresh) costs exactly one
//!   artifact *build* when the snapshot is stale (first use, or after an
//!   online arrival) and exactly one cache *hit* otherwise;
//! * empty serves (zero query rows, flush of an empty queue) touch
//!   nothing — no counters, no artifact work, no latency samples.

use igp::coordinator::{Trainer, TrainerOptions};
use igp::data::{Dataset, DatasetSpec};
use igp::estimator::EstimatorKind;
use igp::kernels::{Hyperparams, KernelFamily};
use igp::linalg::Mat;
use igp::operators::{DenseOperator, TiledOperator, TiledOptions};
use igp::serve::{PredictionService, ServeCounters, ServeOptions};
use igp::solvers::SolverKind;
use igp::util::proptest::{check, PropConfig};
use igp::util::rng::Rng;

fn toy_dataset(rng: &mut Rng, n: usize, n_test: usize, d: usize) -> Dataset {
    let x_train = Mat::from_fn(n, d, |_, _| rng.gaussian());
    let y_train = rng.gaussian_vec(n);
    let x_test = Mat::from_fn(n_test, d, |_, _| rng.gaussian());
    let y_test = rng.gaussian_vec(n_test);
    let spec = DatasetSpec {
        name: "toy",
        paper_n: 0,
        n,
        n_test,
        d,
        true_sigma: 0.3,
        ell_lo: 0.5,
        ell_hi: 1.5,
        cluster_frac: 0.0,
        family: KernelFamily::Rbf,
        seed: 0,
    };
    Dataset {
        spec,
        x_train,
        y_train,
        x_test,
        y_test,
        true_hp: Hyperparams::ones(d),
    }
}

fn service(rng: &mut Rng, size: usize, batch: usize) -> (PredictionService, usize) {
    let n = 16 + rng.below(8 + 4 * size.max(1));
    let d = 1 + rng.below(3);
    let ds = toy_dataset(rng, n, 4, d);
    let op = Box::new(DenseOperator::new(&ds, 4, 16));
    let opts = TrainerOptions {
        solver: SolverKind::Cg,
        estimator: EstimatorKind::Pathwise,
        warm_start: true,
        lr: 0.05,
        seed: 1 + size as u64,
        ..Default::default()
    };
    // deliberately no run(): the trainer starts with an empty artifact
    // cache, so the model below starts from all-zero counters
    let t = Trainer::new(opts, op, &ds);
    let so = ServeOptions { batch, threads: 1, ..Default::default() };
    (PredictionService::new(t, so), d)
}

/// What one non-empty serve of `rows` rows must do to the counters (dense
/// backend: the generic fan-out executes ceil(rows / batch) blocks).
fn model_serve(exp: &mut ServeCounters, have_artifact: &mut bool, rows: usize, batch: usize) {
    if *have_artifact {
        exp.artifact_hits += 1;
    } else {
        exp.artifact_builds += 1;
        *have_artifact = true;
    }
    exp.rows_served += rows as u64;
    exp.batches += ((rows + batch - 1) / batch) as u64;
}

fn stats_check(
    label: &str,
    step: usize,
    got: ServeCounters,
    exp: ServeCounters,
) -> Result<(), String> {
    if got != exp {
        return Err(format!("op {step} ({label}): counters {got:?}, expected {exp:?}"));
    }
    Ok(())
}

#[test]
fn prop_serve_stats_track_the_reference_model() {
    check(
        "serve_stats_model",
        PropConfig { cases: 10, max_size: 8, ..Default::default() },
        |rng, size| {
            let batch = 1 + rng.below(5);
            let (mut svc, d) = service(rng, size, batch);
            let mut exp = ServeCounters::default();
            let mut have_artifact = false;
            let mut pending = 0usize;
            stats_check("init", 0, svc.stats().counters, exp)?;

            for step in 1..=12 {
                match rng.below(5) {
                    0 => {
                        // enqueue (possibly zero rows): no serving happens
                        let rows = rng.below(2 * batch + 2);
                        let x = Mat::from_fn(rows, d, |_, _| rng.gaussian());
                        svc.enqueue(&x).map_err(|e| e.to_string())?;
                        pending += rows;
                        stats_check("enqueue", step, svc.stats().counters, exp)?;
                    }
                    1 => {
                        // flush serves exactly the queued rows, in one go
                        let (mean, var) = svc.flush().map_err(|e| e.to_string())?;
                        if mean.len() != pending || var.len() != pending {
                            return Err(format!(
                                "op {step} (flush): served {} rows, {} queued",
                                mean.len(),
                                pending
                            ));
                        }
                        if pending > 0 {
                            model_serve(&mut exp, &mut have_artifact, pending, batch);
                        }
                        pending = 0;
                        stats_check("flush", step, svc.stats().counters, exp)?;
                        if svc.pending_rows() != 0 {
                            return Err(format!("op {step}: flush left a non-empty queue"));
                        }
                    }
                    2 => {
                        // one-shot predict (possibly zero rows); does not
                        // disturb the queue
                        let rows = rng.below(2 * batch + 2);
                        let xq = Mat::from_fn(rows, d, |_, _| rng.gaussian());
                        let (mean, var) = svc.predict(&xq).map_err(|e| e.to_string())?;
                        if mean.len() != rows || var.len() != rows {
                            return Err(format!("op {step} (predict): wrong output length"));
                        }
                        if rows > 0 {
                            model_serve(&mut exp, &mut have_artifact, rows, batch);
                        }
                        stats_check("predict", step, svc.stats().counters, exp)?;
                        if svc.pending_rows() != pending {
                            return Err(format!("op {step}: predict disturbed the queue"));
                        }
                    }
                    3 => {
                        // online arrival: invalidates the snapshot but must
                        // leave every lifetime counter in place
                        let rows = 1 + rng.below(4);
                        let x = Mat::from_fn(rows, d, |_, _| rng.gaussian());
                        let y = rng.gaussian_vec(rows);
                        svc.extend_data(&x, &y).map_err(|e| e.to_string())?;
                        have_artifact = false;
                        stats_check("extend_data", step, svc.stats().counters, exp)?;
                    }
                    _ => {
                        // explicit refresh: pays the build (or hit) without
                        // serving any rows
                        svc.refresh().map_err(|e| e.to_string())?;
                        if have_artifact {
                            exp.artifact_hits += 1;
                        } else {
                            exp.artifact_builds += 1;
                            have_artifact = true;
                        }
                        stats_check("refresh", step, svc.stats().counters, exp)?;
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn empty_serves_do_not_touch_counters_or_the_artifact() {
    let mut rng = Rng::new(42);
    let (mut svc, d) = service(&mut rng, 4, 8);
    let none = Mat::zeros(0, d);
    let (mean, var) = svc.predict(&none).unwrap();
    assert!(mean.is_empty() && var.is_empty());
    let (mean, var) = svc.flush().unwrap();
    assert!(mean.is_empty() && var.is_empty());
    assert_eq!(svc.stats().counters, ServeCounters::default());
    assert_eq!(svc.stats().latency.count(), 0, "empty serves record no latency");
    assert!(svc.trainer().artifact_cache().is_empty(), "empty serve built an artifact");
}

#[test]
fn tiled_backend_counts_executed_blocks_not_a_formula() {
    // the tiled backend coalesces each serve into one internally-parallel
    // pass: `batches` must count that ONE executed block, not the generic
    // ceil(rows / batch) fan-out the dense backend runs
    let mut rng = Rng::new(7);
    let ds = toy_dataset(&mut rng, 24, 6, 2);
    let op = Box::new(TiledOperator::with_options(
        &ds,
        4,
        16,
        TiledOptions { tile: 8, threads: 1 },
    ));
    let opts = TrainerOptions {
        solver: SolverKind::Cg,
        estimator: EstimatorKind::Pathwise,
        warm_start: true,
        lr: 0.05,
        seed: 5,
        ..Default::default()
    };
    let t = Trainer::new(opts, op, &ds);
    let mut svc =
        PredictionService::new(t, ServeOptions { batch: 2, threads: 1, ..Default::default() });
    let xq = Mat::from_fn(9, 2, |_, _| rng.gaussian());
    svc.predict(&xq).unwrap(); // ceil(9/2) = 5 generic blocks, but 1 executed
    let c = svc.stats().counters;
    assert_eq!(c.rows_served, 9);
    assert_eq!(c.batches, 1, "tiled serve must count one executed block");
    svc.predict(&xq).unwrap();
    assert_eq!(svc.stats().counters.batches, 2);
}
