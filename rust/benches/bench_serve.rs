//! Prediction-serving throughput: rows/sec through [`PredictionService`]
//! over the amortised pathwise posterior — serial vs threaded sweeps,
//! dense vs tiled backends, and a batch-size sweep.  Pure Rust, no
//! artifacts needed.  The artifact is built once per trained model (cache
//! hit on every query), so this measures the serving hot path alone.
//!
//! Threading knobs differ by backend: the tiled backend parallelises over
//! query rows inside `predict_at` (its own `TiledOptions::threads` pool),
//! while the dense backend uses the generic block fan-out driven by
//! `ServeOptions::{batch, threads}` — so the batch sweep runs on dense,
//! where the knob actually governs the work partition.

use igp::coordinator::{Trainer, TrainerOptions};
use igp::data;
use igp::estimator::EstimatorKind;
use igp::linalg::Mat;
use igp::operators::{BackendKind, TiledOptions};
use igp::serve::{PredictionService, ServeOptions};
use igp::solvers::SolverKind;
use igp::util::bench::{quick_mode, Bencher, JsonReport};

fn trained(ds: &data::Dataset, backend: BackendKind, threads: usize) -> Trainer {
    let op = igp::operators::make_cpu_backend(
        backend,
        ds,
        8,
        64,
        TiledOptions { tile: 256, threads },
        1,
    )
    .unwrap();
    let opts = TrainerOptions {
        solver: SolverKind::Ap,
        estimator: EstimatorKind::Pathwise,
        warm_start: true,
        lr: 0.05,
        seed: 13,
        ..Default::default()
    };
    let mut t = Trainer::new(opts, op, ds);
    t.run(2).unwrap();
    t
}

/// A query workload: the test split tiled up to `rows` rows.
fn queries(ds: &data::Dataset, rows: usize) -> Mat {
    let idx: Vec<usize> = (0..rows).map(|i| i % ds.x_test.rows).collect();
    ds.x_test.gather_rows(&idx)
}

fn main() {
    let quick = quick_mode();
    let mut json = JsonReport::from_args();
    let b = Bencher::default();
    let ds = data::generate(&data::spec(if quick { "test" } else { "protein" }).unwrap());
    let xq = queries(&ds, if quick { 256 } else { 2048 });
    let rows = xq.rows as f64;

    // dense vs tiled, serial vs threaded (batch fixed at 64)
    for backend in [BackendKind::Dense, BackendKind::Tiled] {
        for threads in [1usize, 0] {
            let mut service = PredictionService::new(
                trained(&ds, backend, threads),
                ServeOptions { batch: 64, threads, ..Default::default() },
            );
            let label = format!(
                "serve/{}/{} {} rows",
                backend.name(),
                if threads == 1 { "serial" } else { "threaded" },
                xq.rows
            );
            let r = b.run(&label, None, || {
                let (mean, _var) = service.predict(&xq).unwrap();
                assert_eq!(mean.len(), xq.rows);
            });
            println!("   -> {label}: {:.0} rows/s", rows / r.median());
            if let Some(j) = json.as_mut() {
                j.push("serve", backend.name(), ds.spec.n, ds.spec.d, threads, &r);
                // the service's own observability: per-request latency
                // quantiles + rows/sec across the whole timed traffic
                let st = service.stats();
                j.push_with(
                    "serve-latency",
                    backend.name(),
                    ds.spec.n,
                    ds.spec.d,
                    threads,
                    r.median() * 1e9,
                    &[
                        ("p50_ns", st.p50_ns() as f64),
                        ("p99_ns", st.p99_ns() as f64),
                        ("rows_per_sec", st.rows_per_sec()),
                    ],
                );
            }
        }
    }

    // batch-size sweep on the dense backend (generic fan-out), threaded
    let mut trainer = Some(trained(&ds, BackendKind::Dense, 0));
    for batch in [16, 64, 256, 1024] {
        let t = trainer.take().unwrap();
        let mut service =
            PredictionService::new(t, ServeOptions { batch, threads: 0, ..Default::default() });
        let label = format!("serve/dense/batch={batch} {} rows", xq.rows);
        let r = b.run(&label, None, || {
            let (mean, _var) = service.predict(&xq).unwrap();
            assert_eq!(mean.len(), xq.rows);
        });
        println!("   -> {label}: {:.0} rows/s", rows / r.median());
        if let Some(j) = json.as_mut() {
            j.push(&format!("serve-batch{batch}"), "dense", ds.spec.n, ds.spec.d, 0, &r);
        }
        trainer = Some(service.into_trainer());
    }

    // queue path: enqueue the workload as deadline-tagged requests and
    // drain — measures the micro-batching overhead over direct predict
    {
        let mut service = PredictionService::new(
            trained(&ds, BackendKind::Tiled, 0),
            ServeOptions { batch: 64, threads: 0, ..Default::default() },
        );
        let half = xq.rows / 2;
        let idx_a: Vec<usize> = (0..half).collect();
        let idx_b: Vec<usize> = (half..xq.rows).collect();
        let (xa, xb) = (xq.gather_rows(&idx_a), xq.gather_rows(&idx_b));
        let label = format!("serve/tiled/queue-drain {} rows", xq.rows);
        let r = b.run(&label, None, || {
            service.enqueue_with_deadline(&xa, Some(2)).unwrap();
            service.enqueue_with_deadline(&xb, Some(1)).unwrap();
            let results = service.drain().unwrap();
            assert_eq!(results.len(), 2);
        });
        println!("   -> {label}: {:.0} rows/s", rows / r.median());
        if let Some(j) = json.as_mut() {
            let st = service.stats();
            j.push_with(
                "serve-latency",
                "tiled-queue",
                ds.spec.n,
                ds.spec.d,
                0,
                r.median() * 1e9,
                &[
                    ("p50_ns", st.p50_ns() as f64),
                    ("p99_ns", st.p99_ns() as f64),
                    ("rows_per_sec", st.rows_per_sec()),
                ],
            );
        }
    }

    if let Some(j) = &json {
        j.write().expect("bench json write");
    }
}
