//! Fig-7 shape: cumulative epochs over several outer steps, warm vs cold
//! (the full coordinator in the loop).

mod common;

use igp::coordinator::{Trainer, TrainerOptions};
use igp::estimator::EstimatorKind;
use igp::operators::KernelOperator;
use igp::solvers::SolverKind;
use igp::util::bench::Bencher;

fn main() {
    common::skip_or(|| {
        let b = Bencher { warmup: 0, samples: 1 };
        for kind in [SolverKind::Cg, SolverKind::Ap, SolverKind::Sgd] {
            for warm in [false, true] {
                let (op, ds) = common::load("test");
                let block = op.meta().b;
                let opts = TrainerOptions {
                    solver: kind,
                    estimator: EstimatorKind::Pathwise,
                    warm_start: warm,
                    block_size: Some(block),
                    sgd_lr: Some(8.0),
                    epoch_cap: 100.0,
                    seed: 3,
                    ..Default::default()
                };
                let mut trainer = Trainer::new(opts, Box::new(op), &ds);
                let mut epochs = 0.0;
                let label = format!("test/{}/{}", kind.name(), if warm { "warm" } else { "cold" });
                b.run(&label, None, || {
                    epochs = trainer.run(8).unwrap().total_epochs;
                });
                println!("   -> {label}: {epochs:.1} cumulative epochs / 8 outer steps");
            }
        }
    });
}
