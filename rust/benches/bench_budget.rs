//! Fig-9 shape: final probe residual after training under epoch budgets
//! {2, 5, 10} with and without warm starting.

mod common;

use igp::coordinator::{Trainer, TrainerOptions};
use igp::estimator::EstimatorKind;
use igp::operators::KernelOperator;
use igp::solvers::SolverKind;
use igp::util::bench::Bencher;

fn main() {
    common::skip_or(|| {
        let b = Bencher { warmup: 0, samples: 1 };
        for budget in [2.0, 5.0, 10.0] {
            for warm in [false, true] {
                let (op, ds) = common::load("test");
                let block = op.meta().b;
                let opts = TrainerOptions {
                    solver: SolverKind::Ap,
                    estimator: EstimatorKind::Pathwise,
                    warm_start: warm,
                    max_epochs: Some(budget),
                    block_size: Some(block),
                    seed: 4,
                    ..Default::default()
                };
                let mut trainer = Trainer::new(opts, Box::new(op), &ds);
                let mut rz = f64::NAN;
                let label =
                    format!("test/ap/b{budget}/{}", if warm { "warm" } else { "cold" });
                b.run(&label, None, || {
                    let out = trainer.run(10).unwrap();
                    rz = out.telemetry.last().unwrap().rz;
                });
                println!("   -> {label}: final rz={rz:.4}");
            }
        }
    });
}
