//! L3<->PJRT boundary cost: literal conversion + executable dispatch,
//! isolated from compute by comparing a full hv call against its pure
//! conversion cost (DESIGN.md §6: coordinator must stay <5% of step time).

mod common;

use igp::kernels::Hyperparams;
use igp::linalg::Mat;
use igp::operators::KernelOperator;
use igp::runtime::{mat_from_lit, mat_to_lit};
use igp::util::bench::Bencher;
use igp::util::rng::Rng;

fn main() {
    common::skip_or(|| {
        let b = Bencher::default();
        let (mut op, _ds) = common::load("pol");
        op.set_hp(&Hyperparams { ell: vec![1.0; op.d()], sigf: 1.0, sigma: 0.3 });
        let mut rng = Rng::new(6);
        let v = Mat::from_fn(op.n(), op.k_width(), |_, _| rng.gaussian());

        // conversion-only roundtrip of the solver-state payload
        b.run("pol/lit-convert roundtrip [n,k]", None, || {
            let lit = mat_to_lit(&v).unwrap();
            std::hint::black_box(mat_from_lit(&lit, v.rows, v.cols).unwrap());
        });
        // full dispatch incl. compute
        b.run("pol/hv full call", None, || {
            std::hint::black_box(op.hv(&v));
        });
        // rust-side vector math of one CG iteration (axpy etc.)
        let hd = op.hv(&v);
        b.run("pol/cg vector-math per iter", None, || {
            let mut vv = v.clone();
            let alpha = vec![0.5; vv.cols];
            igp::solvers::axpy_cols(&mut vv, &alpha, &hd);
            let g = igp::solvers::col_dots(&vv, &hd);
            std::hint::black_box(g);
        });
    });
}
