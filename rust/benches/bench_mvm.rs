//! L1 perf ablation for the full H@V product (DESIGN.md §6):
//! * pure-Rust section (always runs): multi-threaded matrix-free
//!   `TiledOperator` vs single-threaded tiled vs the materialised
//!   `DenseOperator`, up to n = 4096 where dense storage is at its limit.
//! * sharded-vs-monolithic section: the row-sharded tiled layout
//!   (per-shard panel caches, canonical-order partial folds) against the
//!   monolithic tiled sweep it is bitwise-equal to.
//! * panel-vs-reference section: the Gram-trick panel engine against the
//!   retained scalar `kval` path on the same shapes — the ablation behind
//!   the panel engine's multi-× claim (acceptance: >= 2x at n >= 4096 on
//!   both backends).
//! * precision section: the same H@V in f32 compute (f64 accumulation)
//!   vs the f64 reference on tiled/dense/sharded backends — the PR-7
//!   mixed-precision ablation (target: ~2x from halved memory traffic).
//! * XLA section (needs `make artifacts`): Pallas kmv_full vs the pure-jnp
//!   reference artifact.
//!
//! Flags (after `cargo bench --bench bench_mvm --`): `--json PATH` emits
//! machine-readable records (see `igp::util::bench`), `--quick` restricts
//! to the tiny `test` config (CI smoke).

mod common;

use igp::data;
use igp::kernels::{self, Hyperparams, KernelFamily};
use igp::linalg::Mat;
use igp::operators::{DenseOperator, KernelOperator, ShardedOperator, TiledOperator, TiledOptions};
use igp::util::bench::{quick_mode, Bencher, JsonReport};
use igp::util::rng::Rng;

/// Kernel-eval + matmul flop estimate for one H@V.
fn hv_flops(n: usize, d: usize, k: usize) -> f64 {
    let n = n as f64;
    n * n * (3.0 * d as f64 + 6.0 + 2.0 * k as f64)
}

fn configs(quick: bool) -> &'static [&'static str] {
    if quick {
        &["test"]
    } else {
        &["test", "pol", "protein", "houseelectric"]
    }
}

fn rust_backends(json: &mut Option<JsonReport>, quick: bool) {
    let b = Bencher::default();
    for &config in configs(quick) {
        let ds = data::generate(&data::spec(config).unwrap());
        let (s, m) = (8, 64);
        let hp = Hyperparams { ell: vec![1.0; ds.spec.d], sigf: 1.1, sigma: 0.3 };

        let mut tiled = TiledOperator::new(&ds, s, m);
        tiled.set_hp(&hp);
        let mut rng = Rng::new(0);
        let v = Mat::from_fn(tiled.n(), tiled.k_width(), |_, _| rng.gaussian());
        let (n, d) = (tiled.n(), tiled.d());
        let flops = hv_flops(n, d, tiled.k_width());

        let r = b.run(
            &format!("{config}/hv tiled t{} (rust)", tiled.threads()),
            Some(flops),
            || {
                std::hint::black_box(tiled.hv(&v));
            },
        );
        if let Some(j) = json.as_mut() {
            j.push("hv", "tiled", n, d, tiled.threads(), &r);
        }

        let mut tiled1 =
            TiledOperator::with_options(&ds, s, m, TiledOptions { tile: 256, threads: 1 });
        tiled1.set_hp(&hp);
        let r = b.run(&format!("{config}/hv tiled t1 (rust)"), Some(flops), || {
            std::hint::black_box(tiled1.hv(&v));
        });
        if let Some(j) = json.as_mut() {
            j.push("hv", "tiled", n, d, 1, &r);
        }

        let mut dense = DenseOperator::new(&ds, s, m);
        dense.set_hp(&hp);
        let r = b.run(&format!("{config}/hv dense (rust)"), Some(flops), || {
            std::hint::black_box(dense.hv(&v));
        });
        if let Some(j) = json.as_mut() {
            j.push("hv", "dense", n, d, 1, &r);
        }
    }
}

/// Sharded vs monolithic H@V on the tiled layout: same tile size and
/// thread pool, S row shards with per-shard panel caches.  Results are
/// bitwise-identical by construction (tests/sharded_parity.rs), so this
/// section isolates the *cost* of the shard decomposition — the partial
/// folds and per-shard cache walks — against the monolithic sweep.
fn sharded_vs_monolithic(json: &mut Option<JsonReport>, quick: bool) {
    let b = Bencher::default();
    for &config in configs(quick) {
        let ds = data::generate(&data::spec(config).unwrap());
        let (s, m) = (8, 64);
        let hp = Hyperparams { ell: vec![1.0; ds.spec.d], sigf: 1.1, sigma: 0.3 };

        let mut tiled = TiledOperator::new(&ds, s, m);
        tiled.set_hp(&hp);
        let mut rng = Rng::new(2);
        let v = Mat::from_fn(tiled.n(), tiled.k_width(), |_, _| rng.gaussian());
        let (n, d) = (tiled.n(), tiled.d());
        let flops = hv_flops(n, d, tiled.k_width());

        let r = b.run(
            &format!("{config}/hv monolithic t{} (rust)", tiled.threads()),
            Some(flops),
            || {
                std::hint::black_box(tiled.hv(&v));
            },
        );
        if let Some(j) = json.as_mut() {
            j.push("hv_sharded", "monolithic", n, d, tiled.threads(), &r);
        }

        for shards in [1usize, 2, 4, 8] {
            let mut op = ShardedOperator::new(&ds, s, m, shards);
            op.set_hp(&hp);
            let r = b.run(
                &format!("{config}/hv sharded S={shards} t{} (rust)", op.threads()),
                Some(flops),
                || {
                    std::hint::black_box(op.hv(&v));
                },
            );
            if let Some(j) = json.as_mut() {
                j.push("hv_sharded", &format!("sharded-s{shards}"), n, d, op.threads(), &r);
            }
        }
    }
}

/// H @ V through the retained scalar `kval` path — the pre-panel per-pair
/// math, kept in `igp::kernels` as the reference.  This is what the panel
/// engine is benchmarked against.
fn scalar_kval_hv(x: &Mat, hp: &Hyperparams, family: KernelFamily, v: &Mat) -> Mat {
    let (n, k) = (x.rows, v.cols);
    let noise_var = hp.noise_var();
    let mut out = Mat::zeros(n, k);
    for i in 0..n {
        let xi = x.row(i);
        let orow = &mut out.data[i * k..(i + 1) * k];
        for j in 0..n {
            let mut kij = kernels::kval(xi, x.row(j), hp, family);
            if i == j {
                kij += noise_var;
            }
            let vrow = v.row(j);
            for q in 0..k {
                orow[q] += kij * vrow[q];
            }
        }
    }
    out
}

/// Panel engine vs retained scalar path, per backend:
/// * tiled: `hv` (panel, t=1 for apples-to-apples, plus t=auto) vs a
///   single-threaded scalar-kval sweep of the same product;
/// * dense: `set_hp + hv` (panel materialise) vs scalar `h_matrix` +
///   matmul — the dense backend pays its kernel evaluations at
///   materialisation time, so that is where the panel win shows.
fn panel_vs_reference(json: &mut Option<JsonReport>, quick: bool) {
    let b = Bencher::default();
    for &config in configs(quick) {
        let ds = data::generate(&data::spec(config).unwrap());
        let (s, m) = (8, 64);
        let hp = Hyperparams { ell: vec![1.0; ds.spec.d], sigf: 1.1, sigma: 0.3 };
        let mut rng = Rng::new(1);

        let mut tiled1 =
            TiledOperator::with_options(&ds, s, m, TiledOptions { tile: 256, threads: 1 });
        tiled1.set_hp(&hp);
        let (n, d) = (tiled1.n(), tiled1.d());
        let v = Mat::from_fn(n, tiled1.k_width(), |_, _| rng.gaussian());
        let flops = hv_flops(n, d, tiled1.k_width());

        let r = b.run(&format!("{config}/hv panel tiled t1"), Some(flops), || {
            std::hint::black_box(tiled1.hv(&v));
        });
        if let Some(j) = json.as_mut() {
            j.push("hv_panel", "tiled", n, d, 1, &r);
        }
        let r = b.run(&format!("{config}/hv kval-ref tiled t1"), Some(flops), || {
            std::hint::black_box(scalar_kval_hv(&ds.x_train, &hp, ds.spec.family, &v));
        });
        if let Some(j) = json.as_mut() {
            j.push("hv_kval_ref", "tiled", n, d, 1, &r);
        }

        let mut dense = DenseOperator::new(&ds, s, m);
        let r = b.run(&format!("{config}/materialise+hv panel dense"), Some(flops), || {
            dense.set_hp(&hp); // panel H rebuild: the kernel-eval cost
            std::hint::black_box(dense.hv(&v));
        });
        if let Some(j) = json.as_mut() {
            j.push("materialise_hv_panel", "dense", n, d, 1, &r);
        }
        let r = b.run(&format!("{config}/materialise+hv kval-ref dense"), Some(flops), || {
            let h = kernels::h_matrix(&ds.x_train, &hp, ds.spec.family);
            std::hint::black_box(h.matmul(&v));
        });
        if let Some(j) = json.as_mut() {
            j.push("materialise_hv_kval_ref", "dense", n, d, 1, &r);
        }
    }
}

/// f32-vs-f64 compute precision on the same H@V product (tentpole PR 7
/// ablation): the tiled f64 reference, then the f32 path (f32 panel
/// cross-products with f64 accumulation) on tiled, dense (materialised
/// f32-product H) and sharded backends.  Target: ~2x hv throughput from
/// the halved panel memory traffic.  `hv_into_prec` is driven directly so
/// the section measures the product, not the solver wrappers.
fn precision_f32_vs_f64(json: &mut Option<JsonReport>, quick: bool) {
    use igp::operators::{HvScratch, Precision};
    let b = Bencher::default();
    for &config in configs(quick) {
        let ds = data::generate(&data::spec(config).unwrap());
        let (s, m) = (8, 64);
        let hp = Hyperparams { ell: vec![1.0; ds.spec.d], sigf: 1.1, sigma: 0.3 };
        let mut rng = Rng::new(3);

        let mut tiled = TiledOperator::new(&ds, s, m);
        tiled.set_hp(&hp);
        let (n, d) = (tiled.n(), tiled.d());
        let v = Mat::from_fn(n, tiled.k_width(), |_, _| rng.gaussian());
        let flops = hv_flops(n, d, tiled.k_width());
        let scratch = HvScratch::default();
        let mut out = Mat::zeros(n, tiled.k_width());

        let r = b.run(
            &format!("{config}/hv tiled f64 t{} (prec)", tiled.threads()),
            Some(flops),
            || {
                tiled.hv_into_prec(&v, &mut out, &scratch, Precision::F64);
                std::hint::black_box(&out);
            },
        );
        if let Some(j) = json.as_mut() {
            j.push("hv_prec", "tiled-f64", n, d, tiled.threads(), &r);
        }

        tiled.set_precision(Precision::F32).unwrap();
        let r = b.run(
            &format!("{config}/hv tiled f32 t{} (prec)", tiled.threads()),
            Some(flops),
            || {
                tiled.hv_into_prec(&v, &mut out, &scratch, Precision::F32);
                std::hint::black_box(&out);
            },
        );
        if let Some(j) = json.as_mut() {
            j.push("hv_prec", "tiled-f32", n, d, tiled.threads(), &r);
        }

        // dense pays f32 at materialisation; the product itself is the
        // same f64 matmul against the f32-product H
        let mut dense = DenseOperator::new(&ds, s, m);
        dense.set_hp(&hp);
        dense.set_precision(Precision::F32).unwrap();
        let r = b.run(&format!("{config}/hv dense f32 (prec)"), Some(flops), || {
            dense.hv_into_prec(&v, &mut out, &scratch, Precision::F32);
            std::hint::black_box(&out);
        });
        if let Some(j) = json.as_mut() {
            j.push("hv_prec", "dense-f32", n, d, 1, &r);
        }

        let mut sharded = ShardedOperator::new(&ds, s, m, 4);
        sharded.set_hp(&hp);
        sharded.set_precision(Precision::F32).unwrap();
        let r = b.run(
            &format!("{config}/hv sharded S=4 f32 t{} (prec)", sharded.threads()),
            Some(flops),
            || {
                sharded.hv_into_prec(&v, &mut out, &scratch, Precision::F32);
                std::hint::black_box(&out);
            },
        );
        if let Some(j) = json.as_mut() {
            j.push("hv_prec", "sharded-f32", n, d, sharded.threads(), &r);
        }
    }
}

fn xla_backends(json: &mut Option<JsonReport>, quick: bool) {
    common::skip_or(|| {
        let b = Bencher::default();
        let configs: &[&str] = if quick { &["test"] } else { &["test", "pol", "protein"] };
        for &config in configs {
            if !std::path::Path::new(&format!("artifacts/{config}/meta.txt")).exists() {
                continue;
            }
            let (mut op, _ds) = common::load(config);
            let hp = Hyperparams {
                ell: vec![1.0; op.d()],
                sigf: 1.1,
                sigma: 0.3,
            };
            op.set_hp(&hp);
            let mut rng = Rng::new(0);
            let v = Mat::from_fn(op.n(), op.k_width(), |_, _| rng.gaussian());
            let flops = hv_flops(op.n(), op.d(), op.k_width());

            let r = b.run(&format!("{config}/hv pallas (xla)"), Some(flops), || {
                std::hint::black_box(op.hv(&v));
            });
            if let Some(j) = json.as_mut() {
                j.push("hv", "xla-pallas", op.n(), op.d(), 0, &r);
            }
            let r = b.run(&format!("{config}/hv jnp-ref (xla)"), Some(flops), || {
                std::hint::black_box(op.hv_ref(&v));
            });
            if let Some(j) = json.as_mut() {
                j.push("hv", "xla-jnp", op.n(), op.d(), 0, &r);
            }
        }
    });
}

fn main() {
    let quick = quick_mode();
    let mut json = JsonReport::from_args();
    rust_backends(&mut json, quick);
    sharded_vs_monolithic(&mut json, quick);
    panel_vs_reference(&mut json, quick);
    precision_f32_vs_f64(&mut json, quick);
    xla_backends(&mut json, quick);
    if let Some(j) = &json {
        j.write().expect("bench json write");
    }
}
