//! L1 perf ablation: Pallas kmv_full vs the pure-jnp reference artifact vs
//! the naive Rust dense operator for the full H@V product (DESIGN.md §6).

mod common;

use igp::kernels::Hyperparams;
use igp::linalg::Mat;
use igp::operators::{DenseOperator, KernelOperator};
use igp::util::bench::Bencher;
use igp::util::rng::Rng;

fn main() {
    common::skip_or(|| {
        let b = Bencher::default();
        for config in ["test", "pol", "protein"] {
            if !std::path::Path::new(&format!("artifacts/{config}/meta.txt")).exists() {
                continue;
            }
            let (mut op, ds) = common::load(config);
            let hp = Hyperparams {
                ell: vec![1.0; op.d()],
                sigf: 1.1,
                sigma: 0.3,
            };
            op.set_hp(&hp);
            let mut rng = Rng::new(0);
            let v = Mat::from_fn(op.n(), op.k_width(), |_, _| rng.gaussian());
            // flops: K eval ~ n^2 (3d+6) + matmul 2 n^2 k
            let n = op.n() as f64;
            let flops = n * n * (3.0 * op.d() as f64 + 6.0 + 2.0 * op.k_width() as f64);

            b.run(&format!("{config}/hv pallas (xla)"), Some(flops), || {
                std::hint::black_box(op.hv(&v));
            });
            b.run(&format!("{config}/hv jnp-ref (xla)"), Some(flops), || {
                std::hint::black_box(op.hv_ref(&v));
            });
            if op.n() <= 1024 {
                let mut dense = DenseOperator::new(&ds, op.s(), op.m());
                dense.set_hp(&hp);
                b.run(&format!("{config}/hv dense (rust)"), Some(flops), || {
                    std::hint::black_box(dense.hv(&v));
                });
            }
        }
    });
}
