//! L1 perf ablation for the full H@V product (DESIGN.md §6):
//! * pure-Rust section (always runs): multi-threaded matrix-free
//!   `TiledOperator` vs single-threaded tiled vs the materialised
//!   `DenseOperator`, up to n = 4096 where dense storage is at its limit.
//! * XLA section (needs `make artifacts`): Pallas kmv_full vs the pure-jnp
//!   reference artifact.

mod common;

use igp::data;
use igp::kernels::Hyperparams;
use igp::linalg::Mat;
use igp::operators::{DenseOperator, KernelOperator, TiledOperator, TiledOptions};
use igp::util::bench::Bencher;
use igp::util::rng::Rng;

/// Kernel-eval + matmul flop estimate for one H@V.
fn hv_flops(n: usize, d: usize, k: usize) -> f64 {
    let n = n as f64;
    n * n * (3.0 * d as f64 + 6.0 + 2.0 * k as f64)
}

fn rust_backends() {
    let b = Bencher::default();
    for config in ["test", "pol", "protein", "houseelectric"] {
        let ds = data::generate(&data::spec(config).unwrap());
        let (s, m) = (8, 64);
        let hp = Hyperparams { ell: vec![1.0; ds.spec.d], sigf: 1.1, sigma: 0.3 };

        let mut tiled = TiledOperator::new(&ds, s, m);
        tiled.set_hp(&hp);
        let mut rng = Rng::new(0);
        let v = Mat::from_fn(tiled.n(), tiled.k_width(), |_, _| rng.gaussian());
        let flops = hv_flops(tiled.n(), tiled.d(), tiled.k_width());

        b.run(
            &format!("{config}/hv tiled t{} (rust)", tiled.threads()),
            Some(flops),
            || {
                std::hint::black_box(tiled.hv(&v));
            },
        );

        let mut tiled1 =
            TiledOperator::with_options(&ds, s, m, TiledOptions { tile: 256, threads: 1 });
        tiled1.set_hp(&hp);
        b.run(&format!("{config}/hv tiled t1 (rust)"), Some(flops), || {
            std::hint::black_box(tiled1.hv(&v));
        });

        let mut dense = DenseOperator::new(&ds, s, m);
        dense.set_hp(&hp);
        b.run(&format!("{config}/hv dense (rust)"), Some(flops), || {
            std::hint::black_box(dense.hv(&v));
        });
    }
}

fn xla_backends() {
    common::skip_or(|| {
        let b = Bencher::default();
        for config in ["test", "pol", "protein"] {
            if !std::path::Path::new(&format!("artifacts/{config}/meta.txt")).exists() {
                continue;
            }
            let (mut op, _ds) = common::load(config);
            let hp = Hyperparams {
                ell: vec![1.0; op.d()],
                sigf: 1.1,
                sigma: 0.3,
            };
            op.set_hp(&hp);
            let mut rng = Rng::new(0);
            let v = Mat::from_fn(op.n(), op.k_width(), |_, _| rng.gaussian());
            let flops = hv_flops(op.n(), op.d(), op.k_width());

            b.run(&format!("{config}/hv pallas (xla)"), Some(flops), || {
                std::hint::black_box(op.hv(&v));
            });
            b.run(&format!("{config}/hv jnp-ref (xla)"), Some(flops), || {
                std::hint::black_box(op.hv_ref(&v));
            });
        }
    });
}

fn main() {
    rust_backends();
    xla_backends();
}
