//! Shared bench setup: load a config's artifacts + dataset, skip when
//! artifacts are missing or the `xla` feature is off (so `cargo bench`
//! works on a fresh checkout and in default offline builds).

use igp::data::{self, Dataset};
use igp::operators::XlaOperator;
use igp::runtime::Runtime;

pub fn ready() -> bool {
    cfg!(feature = "xla") && std::path::Path::new("artifacts/test/meta.txt").exists()
}

pub fn load(config: &str) -> (XlaOperator, Dataset) {
    let ds = data::generate(&data::spec(config).unwrap());
    let rt = Runtime::cpu().unwrap();
    let model = rt.load_config("artifacts", config).unwrap();
    (XlaOperator::new(model, &ds), ds)
}

pub fn skip_or<F: FnOnce()>(f: F) {
    if ready() {
        f();
    } else {
        println!("skipping xla benches: needs `make artifacts` and the `xla` cargo feature");
    }
}
