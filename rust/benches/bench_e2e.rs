//! End-to-end outer-step throughput: full coordinator steps (targets,
//! solve, gradient, Adam) per second on the XLA backend.

mod common;

use igp::coordinator::{Trainer, TrainerOptions};
use igp::estimator::EstimatorKind;
use igp::operators::KernelOperator;
use igp::solvers::SolverKind;
use igp::util::bench::Bencher;

fn main() {
    common::skip_or(|| {
        let b = Bencher { warmup: 1, samples: 3 };
        for config in ["test", "pol"] {
            for kind in [SolverKind::Cg, SolverKind::Ap, SolverKind::Sgd] {
                let (op, ds) = common::load(config);
                let block = op.meta().b;
                let opts = TrainerOptions {
                    solver: kind,
                    estimator: EstimatorKind::Pathwise,
                    warm_start: true,
                    block_size: Some(block),
                    sgd_lr: Some(8.0),
                    epoch_cap: 50.0,
                    seed: 5,
                    ..Default::default()
                };
                let mut trainer = Trainer::new(opts, Box::new(op), &ds);
                trainer.run(2).unwrap(); // settle warm-start state
                b.run(&format!("{config}/{}-outer-step", kind.name()), None, || {
                    trainer.run(1).unwrap();
                });
            }
        }
    });
}
