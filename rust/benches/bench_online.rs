//! Online data-arrival section: epochs and wall time per arrival for a
//! warm-carried trainer (`Trainer::extend_data`) vs cold restarts on the
//! accumulated data — the serve-fresh-data-fast scenario.  Pure Rust, no
//! artifacts needed.

use igp::coordinator::{Trainer, TrainerOptions};
use igp::data;
use igp::estimator::EstimatorKind;
use igp::operators::{TiledOperator, TiledOptions};
use igp::solvers::SolverKind;
use igp::util::bench::Bencher;

fn opts() -> TrainerOptions {
    TrainerOptions {
        solver: SolverKind::Ap,
        estimator: EstimatorKind::Pathwise,
        warm_start: true,
        lr: 0.05,
        seed: 9,
        ..Default::default()
    }
}

fn main() {
    let b = Bencher { warmup: 0, samples: 1 };
    let chunks_k = 4;
    let steps = 3;
    for config in ["test", "protein"] {
        let ds = data::generate(&data::spec(config).unwrap());
        let (base, arrivals) = ds.replay_chunks(chunks_k);

        let mut warm_epochs = 0.0;
        b.run(&format!("{config}/online warm-carried ({chunks_k} arrivals)"), None, || {
            let op = TiledOperator::with_options(&base, 8, 64, TiledOptions::default());
            let mut t = Trainer::new(opts(), Box::new(op), &base);
            warm_epochs = t.run(steps).unwrap().total_epochs;
            for (x, y) in &arrivals {
                t.extend_data(x, y).unwrap();
                warm_epochs += t.run(steps).unwrap().total_epochs;
            }
        });

        let mut cold_epochs = 0.0;
        b.run(&format!("{config}/online cold restarts ({chunks_k} arrivals)"), None, || {
            cold_epochs = 0.0;
            let mut acc_x = base.x_train.clone();
            let mut acc_y = base.y_train.clone();
            for arrival in 0..chunks_k {
                if arrival > 0 {
                    let (x, y) = &arrivals[arrival - 1];
                    acc_x.append_rows(x);
                    acc_y.extend_from_slice(y);
                }
                let acc = ds.with_train(acc_x.clone(), acc_y.clone());
                let op = TiledOperator::with_options(&acc, 8, 64, TiledOptions::default());
                let mut t = Trainer::new(opts(), Box::new(op), &acc);
                cold_epochs += t.run(steps).unwrap().total_epochs;
            }
        });

        println!(
            "   -> {config}: warm-carried {warm_epochs:.1} epochs vs cold restarts {cold_epochs:.1}"
        );
    }
}
