//! Table-1 shape: epochs to tolerance for each (estimator, warm) variant,
//! per solver, at a fixed mid-training hyperparameter setting.

mod common;

use igp::estimator::{EstimatorKind, ProbeSet};
use igp::kernels::Hyperparams;
use igp::linalg::Mat;
use igp::operators::KernelOperator;
use igp::solvers::{make_solver, SolveOptions, SolverKind};
use igp::util::bench::Bencher;
use igp::util::rng::Rng;

fn main() {
    common::skip_or(|| {
        let b = Bencher { warmup: 0, samples: 3 };
        let (mut op, ds) = common::load("pol");
        // mid-training hyperparameters: tighter noise = harder system
        op.set_hp(&Hyperparams { ell: vec![1.5; op.d()], sigf: 1.0, sigma: 0.15 });
        let block = op.meta().b;
        let mut rng = Rng::new(2);
        for kind in [SolverKind::Cg, SolverKind::Ap, SolverKind::Sgd] {
            for estimator in [EstimatorKind::Standard, EstimatorKind::Pathwise] {
                for warm in [false, true] {
                    let probes = ProbeSet::sample(estimator, &op, &mut rng);
                    let targets = probes.targets(&op, &ds.y_train);
                    let opts = SolveOptions {
                        tolerance: 0.01,
                        max_epochs: 150.0,
                        block_size: block,
                        sgd_lr: 8.0,
                        ..Default::default()
                    };
                    // warm start proxy: 60%-converged solution
                    let mut v_init = Mat::zeros(op.n(), op.k_width());
                    if warm {
                        let mut pre = make_solver(kind);
                        let mut o = opts.clone();
                        o.max_epochs = 20.0;
                        o.tolerance = 1e-16;
                        pre.solve(&op, &targets, &mut v_init, &o);
                    }
                    let mut solver = make_solver(kind);
                    let mut epochs = 0.0;
                    let label = format!(
                        "pol/{}/{}/{}",
                        kind.name(),
                        estimator.name(),
                        if warm { "warm" } else { "cold" }
                    );
                    b.run(&label, None, || {
                        let mut v = v_init.clone();
                        let rep = solver.solve(&op, &targets, &mut v, &opts);
                        epochs = rep.epochs;
                    });
                    println!("   -> {label}: {epochs:.1} epochs to tau=0.01");
                }
            }
        }
    });
}
