//! Per-solver epoch latency (the inner-loop unit of compute): one CG
//! iteration vs one AP epoch vs one SGD epoch on the same system.
//!
//! Pure-Rust section (always runs) compares the dense and tiled backends;
//! the precision section runs CG at f32 and f64 compute (the full guarded
//! f32 path, refinement + drift verify); the XLA section needs
//! `make artifacts`.
//!
//! Flags (after `--`): `--json PATH` emits machine-readable records,
//! `--quick` restricts to the tiny `test` config (CI smoke).

mod common;

use igp::data;
use igp::estimator::{EstimatorKind, ProbeSet};
use igp::kernels::Hyperparams;
use igp::linalg::Mat;
use igp::operators::{DenseOperator, KernelOperator, ShardedOperator, TiledOperator};
use igp::solvers::{make_solver, SolveOptions, SolverKind};
use igp::util::bench::{quick_mode, Bencher, JsonReport};
use igp::util::rng::Rng;

fn epoch_opts(block: usize) -> SolveOptions {
    SolveOptions {
        tolerance: 1e-16, // never converge: measure raw epochs
        max_epochs: 1.0,
        block_size: block,
        sgd_lr: 8.0,
        ..Default::default()
    }
}

fn rust_backends(json: &mut Option<JsonReport>, quick: bool) {
    let b = Bencher::default();
    let configs: &[&str] = if quick { &["test"] } else { &["test", "protein"] };
    for &config in configs {
        let ds = data::generate(&data::spec(config).unwrap());
        let hp = Hyperparams { ell: vec![1.0; ds.spec.d], sigf: 1.0, sigma: 0.3 };
        let block = (ds.spec.n / 16).clamp(32, 256);

        let mut tiled = TiledOperator::new(&ds, 8, 64);
        tiled.set_hp(&hp);
        let mut dense = DenseOperator::new(&ds, 8, 64);
        dense.set_hp(&hp);

        let mut rng = Rng::new(1);
        let probes = ProbeSet::sample(EstimatorKind::Pathwise, &tiled, &mut rng);
        let targets = probes.targets(&tiled, &ds.y_train);
        let (n, d) = (tiled.n(), tiled.d());

        for kind in [SolverKind::Cg, SolverKind::Ap, SolverKind::Sgd] {
            let op_name = format!("{}-epoch", kind.name());
            let mut solver = make_solver(kind);
            let opts = epoch_opts(block);
            let r = b.run(
                &format!("{config}/{}-epoch tiled t{} (rust)", kind.name(), tiled.threads()),
                None,
                || {
                    let mut v = Mat::zeros(tiled.n(), tiled.k_width());
                    std::hint::black_box(solver.solve(&tiled, &targets, &mut v, &opts));
                },
            );
            if let Some(j) = json.as_mut() {
                j.push(&op_name, "tiled", n, d, tiled.threads(), &r);
            }
            let mut solver = make_solver(kind);
            let r = b.run(&format!("{config}/{}-epoch dense (rust)", kind.name()), None, || {
                let mut v = Mat::zeros(dense.n(), dense.k_width());
                std::hint::black_box(solver.solve(&dense, &targets, &mut v, &opts));
            });
            if let Some(j) = json.as_mut() {
                j.push(&op_name, "dense", n, d, 1, &r);
            }
        }
    }
}

/// Sharded-operator section: per-solver epoch latency against the
/// row-sharded tiled layout (S = 4), plus CG with the matching
/// block-Jacobi-of-shards preconditioner (`precond_shards`) against the
/// global Woodbury build — the factorisation cost scales per shard, the
/// preconditioner is weaker, and this records both sides of that trade.
fn sharded_backend(json: &mut Option<JsonReport>, quick: bool) {
    let b = Bencher::default();
    let configs: &[&str] = if quick { &["test"] } else { &["test", "protein"] };
    for &config in configs {
        let ds = data::generate(&data::spec(config).unwrap());
        let hp = Hyperparams { ell: vec![1.0; ds.spec.d], sigf: 1.0, sigma: 0.3 };
        let block = (ds.spec.n / 16).clamp(32, 256);
        let shards = 4usize;

        let mut op = ShardedOperator::new(&ds, 8, 64, shards);
        op.set_hp(&hp);
        let mut rng = Rng::new(1);
        let probes = ProbeSet::sample(EstimatorKind::Pathwise, &op, &mut rng);
        let targets = probes.targets(&op, &ds.y_train);
        let (n, d) = (op.n(), op.d());

        for kind in [SolverKind::Cg, SolverKind::Ap, SolverKind::Sgd] {
            let mut solver = make_solver(kind);
            let opts = epoch_opts(block);
            let r = b.run(
                &format!("{config}/{}-epoch sharded S={shards} (rust)", kind.name()),
                None,
                || {
                    let mut v = Mat::zeros(n, op.k_width());
                    std::hint::black_box(solver.solve(&op, &targets, &mut v, &opts));
                },
            );
            if let Some(j) = json.as_mut() {
                j.push(
                    &format!("{}-epoch-sharded", kind.name()),
                    &format!("sharded-s{shards}"),
                    n,
                    d,
                    op.threads(),
                    &r,
                );
            }
        }

        // preconditioner build + one CG iteration, global vs block-Jacobi
        for (label, precond_shards) in [("woodbury", 0usize), ("block-jacobi", shards)] {
            let mut solver = make_solver(SolverKind::Cg);
            let opts = SolveOptions {
                precond_rank: 64.min(n / 4),
                precond_shards,
                ..epoch_opts(block)
            };
            let r = b.run(
                &format!("{config}/cg-precond {label} S={precond_shards} (rust)"),
                None,
                || {
                    let mut v = Mat::zeros(n, op.k_width());
                    std::hint::black_box(solver.solve(&op, &targets, &mut v, &opts));
                },
            );
            if let Some(j) = json.as_mut() {
                j.push(&format!("cg-precond-{label}"), "sharded-s4", n, d, op.threads(), &r);
            }
        }
    }
}

/// Threaded-vs-serial *recurrence* section: run the solvers against the
/// single-threaded dense backend, so the only parallelism in play is the
/// solver-recurrence layer (`SolveOptions::threads`).  The two rows per
/// solver isolate what the recurrence layer buys on top of the operator
/// products; outputs are bitwise-identical by construction.
fn recurrence_threads(json: &mut Option<JsonReport>, quick: bool) {
    let b = Bencher::default();
    let auto = igp::solvers::recurrence::resolve_threads(0);
    let configs: &[&str] = if quick { &["test"] } else { &["test", "protein"] };
    for &config in configs {
        let ds = data::generate(&data::spec(config).unwrap());
        let hp = Hyperparams { ell: vec![1.0; ds.spec.d], sigf: 1.0, sigma: 0.3 };
        let block = (ds.spec.n / 16).clamp(32, 256);
        let mut dense = DenseOperator::new(&ds, 8, 64);
        dense.set_hp(&hp);
        let mut rng = Rng::new(2);
        let probes = ProbeSet::sample(EstimatorKind::Pathwise, &dense, &mut rng);
        let targets = probes.targets(&dense, &ds.y_train);
        for kind in [SolverKind::Cg, SolverKind::Ap, SolverKind::Sgd] {
            for (label, threads) in [("serial t1", 1usize), ("threaded auto", 0)] {
                let mut solver = make_solver(kind);
                let opts = SolveOptions { threads, ..epoch_opts(block) };
                let t = if threads == 0 { auto } else { threads };
                let r = b.run(
                    &format!("{config}/{}-epoch recurrence {label} (t={t})", kind.name()),
                    None,
                    || {
                        let mut v = Mat::zeros(dense.n(), dense.k_width());
                        std::hint::black_box(solver.solve(&dense, &targets, &mut v, &opts));
                    },
                );
                if let Some(j) = json.as_mut() {
                    j.push(
                        &format!("{}-epoch-recurrence", kind.name()),
                        "dense",
                        dense.n(),
                        dense.d(),
                        t,
                        &r,
                    );
                }
            }
        }
    }
}

/// f32-vs-f64 solve section: CG on the tiled backend at both compute
/// precisions.  The f32 row exercises the full guarded path — iterative
/// refinement plus the end-of-solve f64 drift verification — so the
/// recorded time is what a real `--precision f32` training step pays, not
/// just the cheaper products.
fn precision_f32_vs_f64(json: &mut Option<JsonReport>, quick: bool) {
    use igp::operators::Precision;
    let b = Bencher::default();
    let configs: &[&str] = if quick { &["test"] } else { &["test", "protein"] };
    for &config in configs {
        let ds = data::generate(&data::spec(config).unwrap());
        let hp = Hyperparams { ell: vec![1.0; ds.spec.d], sigf: 1.0, sigma: 0.3 };
        let block = (ds.spec.n / 16).clamp(32, 256);

        let mut tiled = TiledOperator::new(&ds, 8, 64);
        tiled.set_hp(&hp);
        let mut rng = Rng::new(4);
        let probes = ProbeSet::sample(EstimatorKind::Pathwise, &tiled, &mut rng);
        let targets = probes.targets(&tiled, &ds.y_train);
        let (n, d) = (tiled.n(), tiled.d());

        // 3-epoch budget: one f32 refinement round costs 1.5 epochs
        // (inner product + f64 recompute) plus the 1-epoch drift verify,
        // so the 1-epoch default would never enter the refinement loop
        let mut solver = make_solver(SolverKind::Cg);
        let opts = SolveOptions { max_epochs: 3.0, ..epoch_opts(block) };
        let r = b.run(
            &format!("{config}/cg-epoch f64 tiled t{} (prec)", tiled.threads()),
            None,
            || {
                let mut v = Mat::zeros(n, tiled.k_width());
                std::hint::black_box(solver.solve(&tiled, &targets, &mut v, &opts));
            },
        );
        if let Some(j) = json.as_mut() {
            j.push("cg-epoch-f64", "tiled", n, d, tiled.threads(), &r);
        }

        tiled.set_precision(Precision::F32).unwrap();
        let mut solver = make_solver(SolverKind::Cg);
        let opts =
            SolveOptions { precision: Precision::F32, max_epochs: 3.0, ..epoch_opts(block) };
        let r = b.run(
            &format!("{config}/cg-epoch f32 tiled t{} (prec)", tiled.threads()),
            None,
            || {
                let mut v = Mat::zeros(n, tiled.k_width());
                std::hint::black_box(solver.solve(&tiled, &targets, &mut v, &opts));
            },
        );
        if let Some(j) = json.as_mut() {
            j.push("cg-epoch-f32", "tiled", n, d, tiled.threads(), &r);
        }
    }
}

/// Supervision-overhead section: one full trainer outer step with the
/// fault-injection supervisor unarmed vs armed-but-benign (a `seed=`-only
/// plan).  Unarmed, the supervised path *is* the plain path — no clone,
/// no wrapper, no branch inside the solver.  Armed-benign pays the
/// warm-start snapshot, the Adam rollback bookkeeping and the per-site
/// schedule draws without a single fault firing, so the delta between the
/// two records is the whole price of supervision.
fn supervision_overhead(json: &mut Option<JsonReport>) {
    use std::sync::Arc;

    use igp::coordinator::{Trainer, TrainerOptions};
    use igp::fault::FaultPlan;

    let b = Bencher::default();
    let ds = data::generate(&data::spec("test").unwrap());
    let make = || {
        let opts = TrainerOptions {
            solver: SolverKind::Cg,
            estimator: EstimatorKind::Pathwise,
            warm_start: true,
            lr: 0.05,
            seed: 13,
            ..Default::default()
        };
        Trainer::new(opts, Box::new(TiledOperator::new(&ds, 8, 64)), &ds)
    };
    let (n, d) = (ds.spec.n, ds.spec.d);

    let mut plain = make();
    let r = b.run("test/train-step unsupervised (chaos off)", None, || {
        std::hint::black_box(plain.run(1).expect("unsupervised train step"));
    });
    if let Some(j) = json.as_mut() {
        j.push("train-step-unsupervised", "tiled", n, d, 0, &r);
    }

    let mut armed = make();
    armed.arm_faults(Arc::new(FaultPlan::parse("seed=7").expect("benign plan")));
    let r = b.run("test/train-step supervised (chaos armed, benign)", None, || {
        std::hint::black_box(armed.run(1).expect("supervised train step"));
    });
    if let Some(j) = json.as_mut() {
        j.push("train-step-supervised", "tiled", n, d, 0, &r);
    }
    assert_eq!(
        armed.recovery_stats().total_events(),
        0,
        "benign plan must never fire"
    );
}

fn xla_backends(quick: bool) {
    common::skip_or(|| {
        let b = Bencher::default();
        let configs: &[&str] = if quick { &["test"] } else { &["test", "pol"] };
        for &config in configs {
            let (mut op, ds) = common::load(config);
            op.set_hp(&Hyperparams { ell: vec![1.0; op.d()], sigf: 1.0, sigma: 0.3 });
            let mut rng = Rng::new(1);
            let probes = ProbeSet::sample(EstimatorKind::Pathwise, &op, &mut rng);
            let targets = probes.targets(&op, &ds.y_train);
            let block = op.meta().b;
            for kind in [SolverKind::Cg, SolverKind::Ap, SolverKind::Sgd] {
                let mut solver = make_solver(kind);
                let opts = epoch_opts(block);
                b.run(&format!("{config}/{}-epoch (xla)", kind.name()), None, || {
                    let mut v = Mat::zeros(op.n(), op.k_width());
                    std::hint::black_box(solver.solve(&op, &targets, &mut v, &opts));
                });
            }
        }
    });
}

fn main() {
    let quick = quick_mode();
    let mut json = JsonReport::from_args();
    rust_backends(&mut json, quick);
    sharded_backend(&mut json, quick);
    recurrence_threads(&mut json, quick);
    precision_f32_vs_f64(&mut json, quick);
    supervision_overhead(&mut json);
    xla_backends(quick);
    if let Some(j) = &json {
        j.write().expect("bench json write");
    }
}
