//! Per-solver epoch latency (the inner-loop unit of compute): one CG
//! iteration vs one AP epoch vs one SGD epoch on the same system.

mod common;

use igp::estimator::{EstimatorKind, ProbeSet};
use igp::kernels::Hyperparams;
use igp::linalg::Mat;
use igp::operators::KernelOperator;
use igp::solvers::{make_solver, SolveOptions, SolverKind};
use igp::util::bench::Bencher;
use igp::util::rng::Rng;

fn main() {
    common::skip_or(|| {
        let b = Bencher::default();
        for config in ["test", "pol"] {
            let (mut op, ds) = common::load(config);
            op.set_hp(&Hyperparams { ell: vec![1.0; op.d()], sigf: 1.0, sigma: 0.3 });
            let mut rng = Rng::new(1);
            let probes = ProbeSet::sample(EstimatorKind::Pathwise, &op, &mut rng);
            let targets = probes.targets(&op, &ds.y_train);
            let block = op.meta().b;
            for kind in [SolverKind::Cg, SolverKind::Ap, SolverKind::Sgd] {
                let mut solver = make_solver(kind);
                let opts = SolveOptions {
                    tolerance: 1e-16, // never converge: measure raw epochs
                    max_epochs: 1.0,
                    block_size: block,
                    sgd_lr: 8.0,
                    ..Default::default()
                };
                b.run(&format!("{config}/{}-epoch", kind.name()), None, || {
                    let mut v = Mat::zeros(op.n(), op.k_width());
                    std::hint::black_box(solver.solve(&op, &targets, &mut v, &opts));
                });
            }
        }
    });
}
