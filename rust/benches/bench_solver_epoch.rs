//! Per-solver epoch latency (the inner-loop unit of compute): one CG
//! iteration vs one AP epoch vs one SGD epoch on the same system.
//!
//! Pure-Rust section (always runs) compares the dense and tiled backends;
//! the XLA section needs `make artifacts`.

mod common;

use igp::data;
use igp::estimator::{EstimatorKind, ProbeSet};
use igp::kernels::Hyperparams;
use igp::linalg::Mat;
use igp::operators::{DenseOperator, KernelOperator, TiledOperator};
use igp::solvers::{make_solver, SolveOptions, SolverKind};
use igp::util::bench::Bencher;
use igp::util::rng::Rng;

fn epoch_opts(block: usize) -> SolveOptions {
    SolveOptions {
        tolerance: 1e-16, // never converge: measure raw epochs
        max_epochs: 1.0,
        block_size: block,
        sgd_lr: 8.0,
        ..Default::default()
    }
}

fn rust_backends() {
    let b = Bencher::default();
    for config in ["test", "protein"] {
        let ds = data::generate(&data::spec(config).unwrap());
        let hp = Hyperparams { ell: vec![1.0; ds.spec.d], sigf: 1.0, sigma: 0.3 };
        let block = (ds.spec.n / 16).clamp(32, 256);

        let mut tiled = TiledOperator::new(&ds, 8, 64);
        tiled.set_hp(&hp);
        let mut dense = DenseOperator::new(&ds, 8, 64);
        dense.set_hp(&hp);

        let mut rng = Rng::new(1);
        let probes = ProbeSet::sample(EstimatorKind::Pathwise, &tiled, &mut rng);
        let targets = probes.targets(&tiled, &ds.y_train);

        for kind in [SolverKind::Cg, SolverKind::Ap, SolverKind::Sgd] {
            let mut solver = make_solver(kind);
            let opts = epoch_opts(block);
            b.run(
                &format!("{config}/{}-epoch tiled t{} (rust)", kind.name(), tiled.threads()),
                None,
                || {
                    let mut v = Mat::zeros(tiled.n(), tiled.k_width());
                    std::hint::black_box(solver.solve(&tiled, &targets, &mut v, &opts));
                },
            );
            let mut solver = make_solver(kind);
            b.run(&format!("{config}/{}-epoch dense (rust)", kind.name()), None, || {
                let mut v = Mat::zeros(dense.n(), dense.k_width());
                std::hint::black_box(solver.solve(&dense, &targets, &mut v, &opts));
            });
        }
    }
}

/// Threaded-vs-serial *recurrence* section: run the solvers against the
/// single-threaded dense backend, so the only parallelism in play is the
/// solver-recurrence layer (`SolveOptions::threads`).  The two rows per
/// solver isolate what the recurrence layer buys on top of the operator
/// products; outputs are bitwise-identical by construction.
fn recurrence_threads() {
    let b = Bencher::default();
    let auto = igp::solvers::recurrence::resolve_threads(0);
    for config in ["test", "protein"] {
        let ds = data::generate(&data::spec(config).unwrap());
        let hp = Hyperparams { ell: vec![1.0; ds.spec.d], sigf: 1.0, sigma: 0.3 };
        let block = (ds.spec.n / 16).clamp(32, 256);
        let mut dense = DenseOperator::new(&ds, 8, 64);
        dense.set_hp(&hp);
        let mut rng = Rng::new(2);
        let probes = ProbeSet::sample(EstimatorKind::Pathwise, &dense, &mut rng);
        let targets = probes.targets(&dense, &ds.y_train);
        for kind in [SolverKind::Cg, SolverKind::Ap, SolverKind::Sgd] {
            for (label, threads) in [("serial t1", 1usize), ("threaded auto", 0)] {
                let mut solver = make_solver(kind);
                let opts = SolveOptions { threads, ..epoch_opts(block) };
                b.run(
                    &format!(
                        "{config}/{}-epoch recurrence {label} (t={})",
                        kind.name(),
                        if threads == 0 { auto } else { threads }
                    ),
                    None,
                    || {
                        let mut v = Mat::zeros(dense.n(), dense.k_width());
                        std::hint::black_box(solver.solve(&dense, &targets, &mut v, &opts));
                    },
                );
            }
        }
    }
}

fn xla_backends() {
    common::skip_or(|| {
        let b = Bencher::default();
        for config in ["test", "pol"] {
            let (mut op, ds) = common::load(config);
            op.set_hp(&Hyperparams { ell: vec![1.0; op.d()], sigf: 1.0, sigma: 0.3 });
            let mut rng = Rng::new(1);
            let probes = ProbeSet::sample(EstimatorKind::Pathwise, &op, &mut rng);
            let targets = probes.targets(&op, &ds.y_train);
            let block = op.meta().b;
            for kind in [SolverKind::Cg, SolverKind::Ap, SolverKind::Sgd] {
                let mut solver = make_solver(kind);
                let opts = epoch_opts(block);
                b.run(&format!("{config}/{}-epoch (xla)", kind.name()), None, || {
                    let mut v = Mat::zeros(op.n(), op.k_width());
                    std::hint::black_box(solver.solve(&op, &targets, &mut v, &opts));
                });
            }
        }
    });
}

fn main() {
    rust_backends();
    recurrence_threads();
    xla_backends();
}
