//! Rust mirror of the kernel-family math in `python/compile/kernels/common.py`.
//!
//! Two evaluation paths live here:
//!
//! * the **scalar reference path** (`kval` / `kernel_matrix` / `h_matrix` /
//!   `kernel_row`): one pair at a time, `(a − b)/ell` differences — used by
//!   the synthetic data generator, the exact-GP oracle and as the
//!   independent reference in tolerance tests;
//! * the **panel engine** ([`panel`]): blocked, norm-cached Gram-trick
//!   evaluation of whole tiles — the production path every operator
//!   backend, the Woodbury preconditioner and AP's block factors route
//!   through.  Values differ from the scalar path by Gram-trick rounding
//!   (~1e-14 on standardised data); see the `panel` module docs.
//!
//! The numerics are kept bit-comparable with the JAX side (same formulas,
//! f64) and cross-checked in the integration tests.

pub mod panel;

use crate::linalg::Mat;

pub const SQRT3: f64 = 1.732_050_807_568_877_2;
pub const SQRT5: f64 = 2.236_067_977_499_79;

/// Stationary covariance families supported across all three layers.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum KernelFamily {
    Matern12,
    Matern32,
    Matern52,
    Rbf,
}

impl KernelFamily {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "matern12" => KernelFamily::Matern12,
            "matern32" => KernelFamily::Matern32,
            "matern52" => KernelFamily::Matern52,
            "rbf" => KernelFamily::Rbf,
            other => anyhow::bail!("unknown kernel family '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelFamily::Matern12 => "matern12",
            KernelFamily::Matern32 => "matern32",
            KernelFamily::Matern52 => "matern52",
            KernelFamily::Rbf => "rbf",
        }
    }

    /// Unit-signal covariance g(.) from *squared scaled* distance.
    #[inline]
    pub fn unit_cov(&self, sq: f64) -> f64 {
        match self {
            KernelFamily::Rbf => (-0.5 * sq).exp(),
            KernelFamily::Matern12 => (-sq.max(0.0).sqrt()).exp(),
            KernelFamily::Matern32 => {
                let r = sq.max(0.0).sqrt();
                (1.0 + SQRT3 * r) * (-SQRT3 * r).exp()
            }
            KernelFamily::Matern52 => {
                let r = sq.max(0.0).sqrt();
                (1.0 + SQRT5 * r + (5.0 / 3.0) * sq) * (-SQRT5 * r).exp()
            }
        }
    }

    /// Degrees of freedom of the spectral density (multivariate t with
    /// df = 2 nu); `None` for the Gaussian spectral density of RBF.
    pub fn spectral_t_df(&self) -> Option<f64> {
        match self {
            KernelFamily::Matern12 => Some(1.0),
            KernelFamily::Matern32 => Some(3.0),
            KernelFamily::Matern52 => Some(5.0),
            KernelFamily::Rbf => None,
        }
    }
}

/// Packed hyperparameters, matching the artifact convention
/// `theta = [ell_1..ell_d, sigf, sigma]` (raw positive values).
#[derive(Clone, Debug, PartialEq)]
pub struct Hyperparams {
    pub ell: Vec<f64>,
    pub sigf: f64,
    pub sigma: f64,
}

impl Hyperparams {
    pub fn ones(d: usize) -> Self {
        Hyperparams { ell: vec![1.0; d], sigf: 1.0, sigma: 1.0 }
    }

    pub fn dim(&self) -> usize {
        self.ell.len() + 2
    }

    pub fn pack(&self) -> Vec<f64> {
        let mut v = self.ell.clone();
        v.push(self.sigf);
        v.push(self.sigma);
        v
    }

    pub fn unpack(theta: &[f64], d: usize) -> Self {
        assert_eq!(theta.len(), d + 2);
        Hyperparams {
            ell: theta[..d].to_vec(),
            sigf: theta[d],
            sigma: theta[d + 1],
        }
    }

    pub fn noise_var(&self) -> f64 {
        self.sigma * self.sigma
    }
}

/// Squared scaled distance between two points.
#[inline]
pub fn sqdist_scaled(xa: &[f64], xb: &[f64], ell: &[f64]) -> f64 {
    debug_assert_eq!(xa.len(), xb.len());
    let mut s = 0.0;
    for k in 0..xa.len() {
        let dlt = (xa[k] - xb[k]) / ell[k];
        s += dlt * dlt;
    }
    s
}

/// Single covariance value k(xa, xb).
pub fn kval(xa: &[f64], xb: &[f64], hp: &Hyperparams, family: KernelFamily) -> f64 {
    hp.sigf * hp.sigf * family.unit_cov(sqdist_scaled(xa, xb, &hp.ell))
}

/// Full cross-covariance matrix K(Xa, Xb) [ma, mb].
pub fn kernel_matrix(xa: &Mat, xb: &Mat, hp: &Hyperparams, family: KernelFamily) -> Mat {
    assert_eq!(xa.cols, xb.cols);
    let sf2 = hp.sigf * hp.sigf;
    Mat::from_fn(xa.rows, xb.rows, |i, j| {
        sf2 * family.unit_cov(sqdist_scaled(xa.row(i), xb.row(j), &hp.ell))
    })
}

/// Regularised kernel matrix H = K(X, X) + sigma^2 I.
pub fn h_matrix(x: &Mat, hp: &Hyperparams, family: KernelFamily) -> Mat {
    let mut h = kernel_matrix(x, x, hp, family);
    h.add_diag(hp.noise_var());
    h
}

/// One dense row K(X_i, X) [n] (for the pivoted-Cholesky preconditioner).
pub fn kernel_row(x: &Mat, i: usize, hp: &Hyperparams, family: KernelFamily) -> Vec<f64> {
    let sf2 = hp.sigf * hp.sigf;
    let xi = x.row(i).to_vec();
    (0..x.rows)
        .map(|j| sf2 * family.unit_cov(sqdist_scaled(&xi, x.row(j), &hp.ell)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn unit_cov_at_zero_is_one() {
        for f in [
            KernelFamily::Matern12,
            KernelFamily::Matern32,
            KernelFamily::Matern52,
            KernelFamily::Rbf,
        ] {
            assert!((f.unit_cov(0.0) - 1.0).abs() < 1e-15, "{f:?}");
        }
    }

    #[test]
    fn cov_decreases_with_distance() {
        for f in [
            KernelFamily::Matern12,
            KernelFamily::Matern32,
            KernelFamily::Matern52,
            KernelFamily::Rbf,
        ] {
            let mut prev = 1.0;
            for i in 1..20 {
                let c = f.unit_cov((i as f64 * 0.3).powi(2));
                assert!(c < prev, "{f:?} not decreasing at {i}");
                assert!(c > 0.0);
                prev = c;
            }
        }
    }

    #[test]
    fn matern32_known_value() {
        // k(r=1) = (1+sqrt(3)) exp(-sqrt(3))
        let want = (1.0 + SQRT3) * (-SQRT3).exp();
        assert!((KernelFamily::Matern32.unit_cov(1.0) - want).abs() < 1e-15);
    }

    #[test]
    fn kernel_matrix_symmetric_psd_diag() {
        let mut rng = Rng::new(0);
        let x = Mat::from_fn(16, 3, |_, _| rng.gaussian());
        let hp = Hyperparams { ell: vec![0.7, 1.1, 1.4], sigf: 1.3, sigma: 0.2 };
        let k = kernel_matrix(&x, &x, &hp, KernelFamily::Matern32);
        for i in 0..16 {
            assert!((k[(i, i)] - 1.69).abs() < 1e-12);
            for j in 0..16 {
                assert!((k[(i, j)] - k[(j, i)]).abs() < 1e-12);
            }
        }
        // H must be SPD (choleskyable)
        let h = h_matrix(&x, &hp, KernelFamily::Matern32);
        assert!(crate::linalg::Cholesky::factor(&h).is_ok());
    }

    #[test]
    fn kernel_row_matches_matrix() {
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(12, 2, |_, _| rng.gaussian());
        let hp = Hyperparams { ell: vec![0.9, 1.2], sigf: 1.1, sigma: 0.3 };
        let k = kernel_matrix(&x, &x, &hp, KernelFamily::Matern52);
        for i in [0, 5, 11] {
            let row = kernel_row(&x, i, &hp, KernelFamily::Matern52);
            for j in 0..12 {
                assert!((row[j] - k[(i, j)]).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let hp = Hyperparams { ell: vec![0.5, 2.0], sigf: 1.5, sigma: 0.1 };
        let rt = Hyperparams::unpack(&hp.pack(), 2);
        assert_eq!(hp, rt);
    }

    #[test]
    fn family_parse_roundtrip() {
        for name in ["matern12", "matern32", "matern52", "rbf"] {
            assert_eq!(KernelFamily::parse(name).unwrap().name(), name);
        }
        assert!(KernelFamily::parse("bogus").is_err());
    }
}
