//! The kernel panel engine: blocked, norm-cached Gram-trick evaluation of
//! whole kernel tiles — the single hot path behind every O(n²d) kernel
//! product in the crate.
//!
//! The scalar reference path ([`super::kval`]) re-applies the lengthscale
//! and walks the d-loop once *per pair*: O(n²·3d) flops, no vectorisation,
//! per-row work recomputed n times.  The panel engine instead
//!
//! 1. caches the lengthscale-scaled rows `Xs = X / ell` and their squared
//!    norms once per hyperparameter setting ([`ScaledX`], keyed on the
//!    lengthscale bits + n + an input-content fingerprint, invalidated on
//!    hyperparameter or data change and grown in place by
//!    [`ScaledX::extend`] for online data arrival);
//! 2. computes tile cross-products `Xi · Xjᵀ` with a register-blocked,
//!    4-wide unrolled micro-kernel ([`crate::linalg::micro`], shared with
//!    `Mat::matmul`'s row update);
//! 3. forms squared scaled distances as `‖xi‖² + ‖xj‖² − 2⟨xi, xj⟩`,
//!    clamped at 0 (the Gram trick can go fractionally negative for
//!    duplicate/near-duplicate rows by cancellation);
//! 4. applies the kernel profile (RBF/Matérn exponentials) over the whole
//!    panel.
//!
//! Determinism contract: every panel entry is a *pure function of its
//! global (i, j) pair* — each cross-product accumulates over the feature
//! dimension in plain ascending order regardless of tile boundaries,
//! unroll lane or worker — so panel evaluation is bitwise-identical for
//! every tile size and thread count.  Both pure-Rust operator backends
//! call the same fill functions, which is what upgrades the tiled==dense
//! `hv` parity from tolerance-level to *bitwise* by construction.
//!
//! Values legitimately differ from the scalar path by Gram-trick rounding
//! (`(a/ell − b/ell)` vs `(a − b)/ell`, plus the cancellation in step 3):
//! on standardised data the per-entry difference is O(ε·‖x‖²), about
//! 1e-14.  `kval` is kept as the independent reference for tolerance
//! tests; the diagonal is exact (the cached norm and the cross-product
//! share [`micro::dot`]'s association, so `sq_ii` is exactly 0 and
//! `k_ii = sigf²` bit-for-bit).

use std::ops::Range;

use crate::linalg::{micro, Mat};

use super::{Hyperparams, KernelFamily};

/// Column width of one materialisation panel: keeps the streamed slice of
/// scaled rows (256·d f64) resident in L1/L2 while a block of output rows
/// reuses it.  Purely a performance knob — entry values are
/// position-independent, so the chunking never changes bits.
pub const PANEL_COLS: usize = 256;

/// Compute precision of the panel cross-products.
///
/// `F64` is the reference path: every product and accumulation in f64,
/// bitwise-stable across tile/thread/shard counts — the contract all the
/// parity tests pin.  `F32` forms tile cross-products from an f32 mirror
/// of the scaled rows ([`ScaledX::ensure_f32`]) but *accumulates into f64
/// partials in the identical ascending-index order*, so f32 panels keep
/// the same determinism contract (bitwise-equal across backends at fixed
/// precision) while halving the memory traffic of the dominant `Xi · Xjᵀ`
/// stream.  Everything downstream of the panel values (apply, solver
/// recurrences, residuals) stays f64.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    #[default]
    F64,
    F32,
}

impl Precision {
    #[inline]
    pub fn is_f32(self) -> bool {
        matches!(self, Precision::F32)
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Precision> {
        match s {
            "f64" | "F64" | "double" => Ok(Precision::F64),
            "f32" | "F32" | "single" => Ok(Precision::F32),
            other => anyhow::bail!("unknown precision '{other}' (expected f32 or f64)"),
        }
    }
}

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// Streamed FNV-1a over the exact f64 bits of `vals`, continuing from
/// `h`.  Streaming chunk-by-chunk over concatenated data yields the same
/// hash as one pass over the concatenation, which is what lets
/// [`ScaledX::extend`] keep the content fingerprint incremental.
fn fnv1a_extend(mut h: u64, vals: &[f64]) -> u64 {
    for v in vals {
        for b in v.to_bits().to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// Lazily built f32 mirror of the scaled rows: the same rows cast to f32,
/// with squared norms accumulated through `micro::dot::<f32>` — the same
/// association the f32 cross-product uses, which is what keeps the
/// Gram-trick diagonal exactly zero at reduced precision too.
#[derive(Clone, Debug)]
struct F32Mirror {
    xs: Vec<f32>,
    sq: Vec<f64>,
}

/// Lengthscale-scaled inputs with cached squared row norms — the
/// per-hyperparameter state of the panel engine.
///
/// Keyed on the exact f64 bits of the lengthscales plus the row count,
/// with an FNV-1a fingerprint of the raw input bits folded in by
/// [`ScaledX::refresh`]: a sigf/sigma-only hyperparameter step keeps the
/// cache, while a changed lengthscale *or a same-shape dataset swap*
/// (e.g. restoring a trainer against different data) rebuilds it.
/// [`ScaledX::extend`] grows the cache in place for online data arrival
/// with the appended rows scaled exactly as a fresh build would scale
/// them, so the grown cache — fingerprint and optional f32 mirror
/// included — is bitwise-identical to [`ScaledX::new`] on the
/// concatenated inputs.
#[derive(Clone, Debug)]
pub struct ScaledX {
    key: Vec<u64>,
    xs: Mat,
    sq: Vec<f64>,
    /// FNV-1a over the exact bits of the *unscaled* input rows, streamed
    /// in arrival order — the content half of the cache key.
    xfp: u64,
    /// Lazy f32 mirror for reduced-precision panel compute; carried
    /// through gather/extend, dropped on rebuild unless re-ensured.
    f32m: Option<F32Mirror>,
}

impl ScaledX {
    pub fn new(x: &Mat, ell: &[f64]) -> Self {
        assert_eq!(x.cols, ell.len(), "ScaledX: d = {} but {} lengthscales", x.cols, ell.len());
        let mut sx = ScaledX {
            key: ell.iter().map(|e| e.to_bits()).collect(),
            xs: Mat::zeros(0, x.cols),
            sq: Vec::with_capacity(x.rows),
            xfp: FNV_OFFSET,
            f32m: None,
        };
        sx.append(x, ell);
        sx
    }

    pub fn n(&self) -> usize {
        self.xs.rows
    }

    pub fn d(&self) -> usize {
        self.xs.cols
    }

    /// Scaled row `x_i / ell` (elementwise division — the same expression
    /// the RFF feature map uses, so routing RFF row fills through the
    /// cache keeps their bits unchanged).
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        self.xs.row(i)
    }

    /// Cached squared norm `‖x_i / ell‖²`.
    #[inline]
    pub fn sq(&self, i: usize) -> f64 {
        self.sq[i]
    }

    /// True when this cache is valid for (`ell`, `n`): the lengthscale
    /// bits and row count both match.
    pub fn matches(&self, ell: &[f64], n: usize) -> bool {
        self.xs.rows == n
            && self.key.len() == ell.len()
            && self.key.iter().zip(ell).all(|(k, e)| *k == e.to_bits())
    }

    /// Revalidate against (`x`, `ell`): rebuild on a key mismatch, no-op
    /// (and `false`) when the cache is already valid.  The key includes a
    /// fingerprint of `x`'s content, so swapping in a *different* dataset
    /// of the same shape rebuilds instead of silently serving stale
    /// scaled rows; the fingerprint pass is O(n·d), noise against the
    /// O(n²·d) products the cache feeds.  A pre-existing f32 mirror is
    /// rebuilt alongside so reduced-precision callers stay consistent.
    pub fn refresh(&mut self, x: &Mat, ell: &[f64]) -> bool {
        if self.matches(ell, x.rows) && self.xfp == fnv1a_extend(FNV_OFFSET, &x.data) {
            return false;
        }
        let had_f32 = self.f32m.is_some();
        *self = ScaledX::new(x, ell);
        if had_f32 {
            self.ensure_f32();
        }
        true
    }

    /// Build the f32 mirror if absent: scaled rows cast to f32, squared
    /// norms re-accumulated through the f32 dot so the mirror's Gram
    /// diagonal is exactly zero.  Idempotent; `extend` grows an existing
    /// mirror in place with the same per-row procedure, so a grown mirror
    /// is bitwise-identical to a freshly built one.
    pub fn ensure_f32(&mut self) {
        if self.f32m.is_some() {
            return;
        }
        let mut m = F32Mirror {
            xs: Vec::with_capacity(self.xs.data.len()),
            sq: Vec::with_capacity(self.xs.rows),
        };
        Self::grow_mirror(&mut m, &self.xs, 0);
        self.f32m = Some(m);
    }

    /// True when the f32 mirror is built and covers every row.
    pub fn has_f32(&self) -> bool {
        self.f32m.as_ref().is_some_and(|m| m.sq.len() == self.xs.rows)
    }

    fn grow_mirror(m: &mut F32Mirror, xs: &Mat, from_row: usize) {
        let d = xs.cols;
        for i in from_row..xs.rows {
            let start = m.xs.len();
            for &v in xs.row(i) {
                m.xs.push(v as f32);
            }
            let row = &m.xs[start..start + d];
            m.sq.push(micro::dot(row, row));
        }
    }

    /// Grow in place for newly arrived rows (online data arrival).  The
    /// lengthscales must match the cache key — the coordinator extends at
    /// unchanged hyperparameters.
    pub fn extend(&mut self, x_new: &Mat, ell: &[f64]) {
        assert!(
            self.matches(ell, self.xs.rows),
            "ScaledX::extend: lengthscales changed since the cache was built"
        );
        self.append(x_new, ell);
    }

    /// Row subset (AP blocks, k_cols/k_rows batches, pivoted-Cholesky
    /// pivots): rows and norms are *copied*, never recomputed, so gathered
    /// entries keep exactly the bits of the full-set entries — the f32
    /// mirror rows included, when one is built.  The parent fingerprint is
    /// inherited verbatim; gathers are transient and never `refresh`ed.
    pub fn gather(&self, idx: &[usize]) -> ScaledX {
        let d = self.d();
        let f32m = self.f32m.as_ref().map(|m| {
            let mut g = F32Mirror {
                xs: Vec::with_capacity(idx.len() * d),
                sq: Vec::with_capacity(idx.len()),
            };
            for &i in idx {
                g.xs.extend_from_slice(&m.xs[i * d..(i + 1) * d]);
                g.sq.push(m.sq[i]);
            }
            g
        });
        ScaledX {
            key: self.key.clone(),
            xs: self.xs.gather_rows(idx),
            sq: idx.iter().map(|&i| self.sq[i]).collect(),
            xfp: self.xfp,
            f32m,
        }
    }

    /// Row subset gathered across several caches that jointly cover one
    /// contiguous global index space — the sharded operator's counterpart
    /// of [`ScaledX::gather`].  Part `p` owns global rows
    /// `starts[p] .. starts[p] + parts[p].n()` (starts ascending).  Rows
    /// and norms are copied from the owning part, and per-shard caches
    /// hold exactly the bits a monolithic cache holds for those rows, so
    /// the result is bitwise-identical to gathering from one.
    pub fn gather_parts(parts: &[ScaledX], starts: &[usize], idx: &[usize]) -> ScaledX {
        assert!(!parts.is_empty() && parts.len() == starts.len());
        let d = parts[0].d();
        let with_mirror = parts.iter().all(|p| p.f32m.is_some());
        let mut out = ScaledX {
            key: parts[0].key.clone(),
            xs: Mat::zeros(0, d),
            sq: Vec::with_capacity(idx.len()),
            xfp: parts[0].xfp,
            f32m: with_mirror.then(|| F32Mirror {
                xs: Vec::with_capacity(idx.len() * d),
                sq: Vec::with_capacity(idx.len()),
            }),
        };
        out.xs.data.reserve(idx.len() * d);
        for &gi in idx {
            let p = match starts.binary_search(&gi) {
                Ok(p) => p,
                Err(p) => p - 1,
            };
            let li = gi - starts[p];
            out.xs.data.extend_from_slice(parts[p].row(li));
            out.xs.rows += 1;
            out.sq.push(parts[p].sq(li));
            if let Some(g) = out.f32m.as_mut() {
                let pm = parts[p].f32m.as_ref().unwrap();
                g.xs.extend_from_slice(&pm.xs[li * d..(li + 1) * d]);
                g.sq.push(pm.sq[li]);
            }
        }
        out
    }

    fn append(&mut self, x: &Mat, ell: &[f64]) {
        assert_eq!(x.cols, self.xs.cols);
        let d = x.cols;
        let rows_before = self.xs.rows;
        self.xfp = fnv1a_extend(self.xfp, &x.data);
        self.xs.data.reserve(x.rows * d);
        for i in 0..x.rows {
            let start = self.xs.data.len();
            for (r, &v) in x.row(i).iter().enumerate() {
                self.xs.data.push(v / ell[r]);
            }
            self.xs.rows += 1;
            let row = &self.xs.data[start..start + d];
            self.sq.push(micro::dot(row, row));
        }
        if let Some(mut m) = self.f32m.take() {
            Self::grow_mirror(&mut m, &self.xs, rows_before);
            self.f32m = Some(m);
        }
    }
}

/// Generic core of one panel row: clamped squared scaled distances of row
/// `ai` (norm `sqa`) against the contiguous row block `j0..j0+out.len()`
/// of the row-major `[?, d]` buffer `bxs` with norms `bsq`.  The element
/// type `S` sets the product precision; partials always accumulate in f64
/// in the same ascending-index association, so `S = f64` reproduces the
/// historical bits exactly and `S = f32` keeps the identical block-order
/// contract at reduced product precision.
#[inline(always)]
fn fill_sq_row<S: micro::Scalar>(
    ai: &[S],
    sqa: f64,
    bxs: &[S],
    bsq: &[f64],
    d: usize,
    j0: usize,
    out: &mut [f64],
) {
    let jn = out.len();
    let mut c = 0;
    while c + 4 <= jn {
        let j = j0 + c;
        let (s0, s1, s2, s3) = micro::dot4(
            ai,
            &bxs[j * d..(j + 1) * d],
            &bxs[(j + 1) * d..(j + 2) * d],
            &bxs[(j + 2) * d..(j + 3) * d],
            &bxs[(j + 3) * d..(j + 4) * d],
        );
        out[c] = (sqa + bsq[j] - 2.0 * s0).max(0.0);
        out[c + 1] = (sqa + bsq[j + 1] - 2.0 * s1).max(0.0);
        out[c + 2] = (sqa + bsq[j + 2] - 2.0 * s2).max(0.0);
        out[c + 3] = (sqa + bsq[j + 3] - 2.0 * s3).max(0.0);
        c += 4;
    }
    while c < jn {
        let j = j0 + c;
        let s = micro::dot(ai, &bxs[j * d..(j + 1) * d]);
        out[c] = (sqa + bsq[j] - 2.0 * s).max(0.0);
        c += 1;
    }
}

/// One panel row: `out[c] = sf2 · g(clamp(sq_i + sq_{j0+c} − 2⟨xs_i,
/// xs_{j0+c}⟩, 0))` for `c in 0..out.len()`.  First pass fills the clamped
/// squared distances through the 4-wide cross-product micro-kernel, second
/// pass applies the kernel profile over the whole panel row.
pub fn fill_row(
    a: &ScaledX,
    i: usize,
    b: &ScaledX,
    j0: usize,
    sf2: f64,
    family: KernelFamily,
    out: &mut [f64],
) {
    debug_assert_eq!(a.d(), b.d());
    debug_assert!(j0 + out.len() <= b.n());
    let d = b.d();
    fill_sq_row(a.row(i), a.sq[i], &b.xs.data, &b.sq, d, j0, out);
    for v in out.iter_mut() {
        *v = sf2 * family.unit_cov(*v);
    }
}

/// [`fill_row`] against the f32 mirrors of both caches.  Panics if either
/// side's mirror is missing — operators call [`ScaledX::ensure_f32`] when
/// switched to f32 compute.
fn fill_row_f32(
    a: &ScaledX,
    i: usize,
    b: &ScaledX,
    j0: usize,
    sf2: f64,
    family: KernelFamily,
    out: &mut [f64],
) {
    debug_assert_eq!(a.d(), b.d());
    debug_assert!(j0 + out.len() <= b.n());
    let am = a.f32m.as_ref().expect("f32 mirror missing on A (call ensure_f32)");
    let bm = b.f32m.as_ref().expect("f32 mirror missing on B (call ensure_f32)");
    let d = b.d();
    fill_sq_row(&am.xs[i * d..(i + 1) * d], am.sq[i], &bm.xs, &bm.sq, d, j0, out);
    for v in out.iter_mut() {
        *v = sf2 * family.unit_cov(*v);
    }
}

/// Precision-dispatched [`fill_row`]: the `F64` arm is the untouched
/// reference path, the `F32` arm reads the mirrors.
pub fn fill_row_prec(
    a: &ScaledX,
    i: usize,
    b: &ScaledX,
    j0: usize,
    sf2: f64,
    family: KernelFamily,
    out: &mut [f64],
    prec: Precision,
) {
    match prec {
        Precision::F64 => fill_row(a, i, b, j0, sf2, family, out),
        Precision::F32 => fill_row_f32(a, i, b, j0, sf2, family, out),
    }
}

/// Fill a row-major `[i1−i0, j1−j0]` panel (stride `j1−j0`) of
/// K(A[i0..i1], B[j0..j1]).
pub fn fill_panel(
    a: &ScaledX,
    i0: usize,
    i1: usize,
    b: &ScaledX,
    j0: usize,
    j1: usize,
    sf2: f64,
    family: KernelFamily,
    out: &mut [f64],
) {
    fill_panel_prec(a, i0, i1, b, j0, j1, sf2, family, out, Precision::F64);
}

/// Precision-dispatched [`fill_panel`].
#[allow(clippy::too_many_arguments)]
pub fn fill_panel_prec(
    a: &ScaledX,
    i0: usize,
    i1: usize,
    b: &ScaledX,
    j0: usize,
    j1: usize,
    sf2: f64,
    family: KernelFamily,
    out: &mut [f64],
    prec: Precision,
) {
    let w = j1 - j0;
    debug_assert!(out.len() >= (i1 - i0) * w);
    for (r, i) in (i0..i1).enumerate() {
        fill_row_prec(a, i, b, j0, sf2, family, &mut out[r * w..(r + 1) * w], prec);
    }
}

/// Accumulate `out_rows += panel · V[j0..j0+w]` against all k RHS columns
/// with `Mat::matmul`'s exact k-major association — ascending j, skipping
/// exact zeros, [`micro::axpy`] inner update.  `panel` is row-major
/// `[rows, w]`; `out_rows` is row-major `[rows, v.cols]`.
pub fn apply_panel(
    panel: &[f64],
    rows: usize,
    w: usize,
    j0: usize,
    v: &Mat,
    out_rows: &mut [f64],
) {
    let k = v.cols;
    debug_assert!(panel.len() >= rows * w);
    debug_assert!(out_rows.len() >= rows * k);
    debug_assert!(j0 + w <= v.rows);
    for r in 0..rows {
        let prow = &panel[r * w..(r + 1) * w];
        let orow = &mut out_rows[r * k..(r + 1) * k];
        for (jj, &a) in prow.iter().enumerate() {
            if a == 0.0 {
                continue;
            }
            micro::axpy(orow, a, v.row(j0 + jj));
        }
    }
}

/// Full cross-covariance K(A, B) `[a.n, b.n]` — the panel-engine
/// counterpart of [`super::kernel_matrix`].  Columns are filled in
/// [`PANEL_COLS`] chunks so a slice of B's scaled rows stays cache-hot
/// across all of A's rows; chunking never changes bits (entry values are
/// position-independent).
pub fn cross_matrix(a: &ScaledX, b: &ScaledX, sf2: f64, family: KernelFamily) -> Mat {
    cross_matrix_prec(a, b, sf2, family, Precision::F64)
}

/// Precision-dispatched [`cross_matrix`]: the `F64` arm reproduces the
/// reference bits, the `F32` arm streams the mirrors through the same
/// chunking (chunking never changes bits at either precision).
pub fn cross_matrix_prec(
    a: &ScaledX,
    b: &ScaledX,
    sf2: f64,
    family: KernelFamily,
    prec: Precision,
) -> Mat {
    let (an, bn) = (a.n(), b.n());
    let mut out = Mat::zeros(an, bn);
    let mut j0 = 0;
    while j0 < bn {
        let j1 = (j0 + PANEL_COLS).min(bn);
        for i in 0..an {
            fill_row_prec(a, i, b, j0, sf2, family, &mut out.data[i * bn + j0..i * bn + j1], prec);
        }
        j0 = j1;
    }
    out
}

/// Cross-covariance between two row ranges of the *same* point set —
/// what the dense backend's online rank-extension needs for its
/// cross/corner blocks.
pub fn cross_block(
    sx: &ScaledX,
    rows: Range<usize>,
    cols: Range<usize>,
    sf2: f64,
    family: KernelFamily,
) -> Mat {
    let w = cols.len();
    let mut out = Mat::zeros(rows.len(), w);
    for (r, i) in rows.enumerate() {
        fill_row(sx, i, sx, cols.start, sf2, family, out.row_mut(r));
    }
    out
}

/// Regularised kernel matrix H = K(X, X) + sigma² I via the panel engine
/// — the counterpart of [`super::h_matrix`] for the dense backend's
/// materialisation.
pub fn h_panel(sx: &ScaledX, hp: &Hyperparams, family: KernelFamily) -> Mat {
    let mut h = cross_matrix(sx, sx, hp.sigf * hp.sigf, family);
    h.add_diag(hp.noise_var());
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{self, Hyperparams, KernelFamily};
    use crate::util::rng::Rng;

    fn hp(d: usize, seed: u64) -> Hyperparams {
        let mut rng = Rng::new(seed);
        Hyperparams {
            ell: (0..d).map(|_| rng.uniform_in(0.4, 2.0)).collect(),
            sigf: rng.uniform_in(0.5, 1.5),
            sigma: rng.uniform_in(0.1, 0.9),
        }
    }

    #[test]
    fn cross_matrix_matches_kval_reference() {
        let mut rng = Rng::new(0);
        for family in [
            KernelFamily::Matern12,
            KernelFamily::Matern32,
            KernelFamily::Matern52,
            KernelFamily::Rbf,
        ] {
            let (n, d) = (23, 3); // n deliberately not a multiple of 4
            let x = crate::linalg::Mat::from_fn(n, d, |_, _| rng.gaussian());
            let hp = hp(d, 7);
            let sx = ScaledX::new(&x, &hp.ell);
            let km = cross_matrix(&sx, &sx, hp.sigf * hp.sigf, family);
            for i in 0..n {
                for j in 0..n {
                    let want = kernels::kval(x.row(i), x.row(j), &hp, family);
                    assert!(
                        (km[(i, j)] - want).abs() < 1e-12,
                        "{family:?} ({i},{j}): {} vs {want}",
                        km[(i, j)]
                    );
                }
            }
        }
    }

    #[test]
    fn diagonal_is_exact_and_duplicates_clamp() {
        let mut rng = Rng::new(1);
        let (n, d) = (12, 4);
        let mut x = crate::linalg::Mat::from_fn(n, d, |_, _| rng.gaussian());
        // exact duplicate and near-duplicate rows: the Gram trick cancels
        // catastrophically here; the clamp must keep sq >= 0
        let r0 = x.row(0).to_vec();
        x.row_mut(1).copy_from_slice(&r0);
        let mut r2 = x.row(2).to_vec();
        r2[0] += 1e-9;
        x.row_mut(3).copy_from_slice(&r2);
        let hp = hp(d, 9);
        let sf2 = hp.sigf * hp.sigf;
        let sx = ScaledX::new(&x, &hp.ell);
        for family in [KernelFamily::Matern12, KernelFamily::Rbf] {
            let km = cross_matrix(&sx, &sx, sf2, family);
            for i in 0..n {
                assert_eq!(km[(i, i)].to_bits(), sf2.to_bits(), "diag {i}");
                for j in 0..n {
                    assert!(km[(i, j)] <= sf2 + 1e-15, "({i},{j}) above sigf^2");
                    assert!(km[(i, j)] > 0.0);
                }
            }
            // duplicate pair evaluates to exactly sigf^2 too
            assert_eq!(km[(0, 1)].to_bits(), sf2.to_bits());
        }
    }

    #[test]
    fn fill_is_tile_and_symmetry_invariant() {
        let mut rng = Rng::new(2);
        let (n, d) = (19, 5);
        let x = crate::linalg::Mat::from_fn(n, d, |_, _| rng.gaussian());
        let hp = hp(d, 11);
        let sf2 = hp.sigf * hp.sigf;
        let sx = ScaledX::new(&x, &hp.ell);
        let fam = KernelFamily::Matern32;
        let full = cross_matrix(&sx, &sx, sf2, fam);
        // any sub-panel reproduces the same bits
        for (i0, i1, j0, j1) in [(0, n, 0, n), (3, 9, 5, 6), (1, 2, 0, n), (0, n, 17, n)] {
            let w = j1 - j0;
            let mut panel = vec![0.0; (i1 - i0) * w];
            fill_panel(&sx, i0, i1, &sx, j0, j1, sf2, fam, &mut panel);
            for (r, i) in (i0..i1).enumerate() {
                for (c, j) in (j0..j1).enumerate() {
                    assert_eq!(
                        panel[r * w + c].to_bits(),
                        full[(i, j)].to_bits(),
                        "panel ({i0}..{i1},{j0}..{j1}) entry ({i},{j})"
                    );
                }
            }
        }
        // bitwise symmetry (the dense extension's transpose trick relies
        // on it)
        for i in 0..n {
            for j in 0..n {
                assert_eq!(full[(i, j)].to_bits(), full[(j, i)].to_bits());
            }
        }
    }

    #[test]
    fn scaled_x_refresh_and_extend_rules() {
        let mut rng = Rng::new(3);
        let (n, d) = (10, 3);
        let x = crate::linalg::Mat::from_fn(n, d, |_, _| rng.gaussian());
        let ell = vec![0.7, 1.3, 0.9];
        let mut sx = ScaledX::new(&x, &ell);
        assert!(sx.matches(&ell, n));
        // same lengthscales: refresh is a no-op (sigf/sigma-only steps keep
        // the cache)
        assert!(!sx.refresh(&x, &ell));
        // changed lengthscales: rebuild
        let ell2 = vec![0.7, 1.3, 1.0];
        assert!(sx.refresh(&x, &ell2));
        assert!(sx.matches(&ell2, n));
        // extend grows bitwise-identically to a fresh build on the
        // concatenated inputs
        let chunk = crate::linalg::Mat::from_fn(4, d, |_, _| rng.gaussian());
        sx.extend(&chunk, &ell2);
        let mut full = x.clone();
        full.append_rows(&chunk);
        let fresh = ScaledX::new(&full, &ell2);
        assert_eq!(sx.n(), fresh.n());
        for i in 0..sx.n() {
            assert_eq!(sx.sq(i).to_bits(), fresh.sq(i).to_bits(), "sq {i}");
            for (a, b) in sx.row(i).iter().zip(fresh.row(i)) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        // gather copies bits
        let g = sx.gather(&[3, 0, 11]);
        assert_eq!(g.sq(0).to_bits(), sx.sq(3).to_bits());
        assert_eq!(g.row(2), sx.row(11));
    }

    #[test]
    fn gather_parts_matches_monolithic_gather_bitwise() {
        let mut rng = Rng::new(5);
        let (n, d) = (17, 3);
        let x = crate::linalg::Mat::from_fn(n, d, |_, _| rng.gaussian());
        let ell = vec![0.8, 1.1, 0.6];
        let whole = ScaledX::new(&x, &ell);
        // split 0..17 into ragged parts 0..6, 6..12, 12..17
        let bounds = [(0usize, 6usize), (6, 12), (12, 17)];
        let mut parts = Vec::new();
        let mut starts = Vec::new();
        for &(a, b) in &bounds {
            let rows: Vec<usize> = (a..b).collect();
            parts.push(ScaledX::new(&x.gather_rows(&rows), &ell));
            starts.push(a);
        }
        let idx = vec![0, 5, 6, 11, 12, 16, 3, 14];
        let got = ScaledX::gather_parts(&parts, &starts, &idx);
        let want = whole.gather(&idx);
        assert_eq!(got.n(), want.n());
        for i in 0..got.n() {
            assert_eq!(got.sq(i).to_bits(), want.sq(i).to_bits(), "sq {i}");
            for (a, b) in got.row(i).iter().zip(want.row(i)) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn refresh_rebuilds_on_same_shape_dataset_swap() {
        // Regression: the key used to be (lengthscale bits, n) only, so a
        // same-shape dataset swap silently served stale scaled rows.
        let mut rng = Rng::new(6);
        let (n, d) = (9, 3);
        let x1 = crate::linalg::Mat::from_fn(n, d, |_, _| rng.gaussian());
        let x2 = crate::linalg::Mat::from_fn(n, d, |_, _| rng.gaussian());
        let ell = vec![0.9, 1.2, 0.8];
        let mut sx = ScaledX::new(&x1, &ell);
        // same data, same ell: still a no-op
        assert!(!sx.refresh(&x1, &ell));
        // different data, same shape and ell: must rebuild
        assert!(sx.refresh(&x2, &ell));
        let fresh = ScaledX::new(&x2, &ell);
        for i in 0..n {
            assert_eq!(sx.sq(i).to_bits(), fresh.sq(i).to_bits());
            for (a, b) in sx.row(i).iter().zip(fresh.row(i)) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        // and back to a no-op once rebuilt
        assert!(!sx.refresh(&x2, &ell));
        // a single flipped bit in one entry is enough to invalidate
        let mut x3 = x2.clone();
        x3.data[4] = f64::from_bits(x3.data[4].to_bits() ^ 1);
        assert!(sx.refresh(&x3, &ell));
    }

    #[test]
    fn f32_diagonal_is_exact_and_close_to_f64() {
        let mut rng = Rng::new(7);
        let (n, d) = (21, 4);
        let x = crate::linalg::Mat::from_fn(n, d, |_, _| rng.gaussian());
        let hp = hp(d, 13);
        let sf2 = hp.sigf * hp.sigf;
        let mut sx = ScaledX::new(&x, &hp.ell);
        sx.ensure_f32();
        assert!(sx.has_f32());
        for family in [KernelFamily::Matern32, KernelFamily::Rbf] {
            let k64 = cross_matrix_prec(&sx, &sx, sf2, family, Precision::F64);
            let k32 = cross_matrix_prec(&sx, &sx, sf2, family, Precision::F32);
            for i in 0..n {
                // the mirror's norm and cross-product share the f32 dot's
                // association, so the Gram diagonal stays exactly sigf²
                assert_eq!(k32[(i, i)].to_bits(), sf2.to_bits(), "diag {i}");
                for j in 0..n {
                    let err = (k32[(i, j)] - k64[(i, j)]).abs();
                    assert!(err < 1e-5 * sf2.max(1.0), "({i},{j}): err {err}");
                }
            }
        }
        // f64 entries are untouched by the mirror's existence
        let k_ref = cross_matrix(&sx, &sx, sf2, KernelFamily::Rbf);
        let k_prec = cross_matrix_prec(&sx, &sx, sf2, KernelFamily::Rbf, Precision::F64);
        for (a, b) in k_ref.data.iter().zip(&k_prec.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn f32_mirror_survives_gather_and_extend_bitwise() {
        let mut rng = Rng::new(8);
        let (n, d) = (11, 3);
        let x = crate::linalg::Mat::from_fn(n, d, |_, _| rng.gaussian());
        let ell = vec![0.7, 1.4, 1.0];
        let mut sx = ScaledX::new(&x, &ell);
        sx.ensure_f32();
        // extend grows the mirror identically to a fresh build on the
        // concatenated inputs
        let chunk = crate::linalg::Mat::from_fn(5, d, |_, _| rng.gaussian());
        sx.extend(&chunk, &ell);
        assert!(sx.has_f32());
        let mut full = x.clone();
        full.append_rows(&chunk);
        let mut fresh = ScaledX::new(&full, &ell);
        fresh.ensure_f32();
        let (sm, fm) = (sx.f32m.as_ref().unwrap(), fresh.f32m.as_ref().unwrap());
        assert_eq!(sm.xs.len(), fm.xs.len());
        for (a, b) in sm.xs.iter().zip(&fm.xs) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in sm.sq.iter().zip(&fm.sq) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // gather carries mirror rows verbatim
        let idx = vec![2, 0, 13, 7];
        let g = sx.gather(&idx);
        assert!(g.has_f32());
        let gm = g.f32m.as_ref().unwrap();
        for (r, &i) in idx.iter().enumerate() {
            assert_eq!(gm.sq[r].to_bits(), sm.sq[i].to_bits());
            for c in 0..d {
                assert_eq!(gm.xs[r * d + c].to_bits(), sm.xs[i * d + c].to_bits());
            }
        }
        // gather_parts carries mirrors when every part has one
        let parts = vec![sx.gather(&[0, 1, 2, 3, 4, 5, 6, 7]), sx.gather(&[8, 9, 10, 11, 12, 13, 14, 15])];
        let got = ScaledX::gather_parts(&parts, &[0, 8], &idx);
        assert!(got.has_f32());
        let want = sx.gather(&idx);
        let (a, b) = (got.f32m.as_ref().unwrap(), want.f32m.as_ref().unwrap());
        for (x32, y32) in a.xs.iter().zip(&b.xs) {
            assert_eq!(x32.to_bits(), y32.to_bits());
        }
    }

    #[test]
    fn apply_panel_matches_matmul_bitwise() {
        let mut rng = Rng::new(4);
        let (rows, w, k) = (6, 11, 5);
        let panel: Vec<f64> = (0..rows * w).map(|_| rng.gaussian()).collect();
        let v = crate::linalg::Mat::from_fn(w, k, |_, _| rng.gaussian());
        let pm = crate::linalg::Mat::from_vec(rows, w, panel.clone());
        let want = pm.matmul(&v);
        let mut out = vec![0.0; rows * k];
        apply_panel(&panel, rows, w, 0, &v, &mut out);
        for (a, b) in out.iter().zip(&want.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
