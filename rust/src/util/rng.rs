//! Deterministic random number generation: SplitMix64 seeding,
//! xoshiro256++ core, Box–Muller Gaussians, chi-square / Student-t
//! sampling (needed for Matérn spectral densities), and shuffling.
//!
//! Everything in the repository that touches randomness goes through this
//! module so experiments are reproducible from a single `u64` seed.

/// xoshiro256++ PRNG (Blackman & Vigna), seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian from Box–Muller.
    gauss_spare: Option<f64>,
}

/// A serialisable snapshot of an [`Rng`] mid-stream (checkpointing: a
/// restored run must continue the exact random sequence, including the
/// cached Box–Muller spare).
#[derive(Clone, Debug, PartialEq)]
pub struct RngState {
    pub s: [u64; 4],
    pub gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a seed. Different seeds give independent
    /// streams for all practical purposes.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child stream (for per-dataset / per-run seeds).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Snapshot the full generator state (see [`RngState`]).
    pub fn state(&self) -> RngState {
        RngState { s: self.s, gauss_spare: self.gauss_spare }
    }

    /// Rebuild a generator that continues exactly where `state` left off.
    pub fn from_state(state: &RngState) -> Rng {
        Rng { s: state.s, gauss_spare: state.gauss_spare }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Rejection-free Lemire-style bounded sampling is overkill here;
        // the modulo bias at n << 2^64 is negligible for our uses, but we
        // use widening multiply anyway (exact for n < 2^32, near-exact
        // otherwise).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (caches the second draw).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (sin_t, cos_t) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * sin_t);
            return r * cos_t;
        }
    }

    /// Vector of standard normals.
    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.gaussian()).collect()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (shape >= some small positive).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: gamma(a) = gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u = self.uniform().max(f64::MIN_POSITIVE);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.gaussian();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Chi-square with `df` degrees of freedom.
    pub fn chi_square(&mut self, df: f64) -> f64 {
        2.0 * self.gamma(0.5 * df)
    }

    /// Student-t scale factor sqrt(df / chi2(df)) for multivariate-t draws.
    /// Matérn-nu spectral density == multivariate-t with df = 2*nu.
    pub fn student_t_scale(&mut self, df: f64) -> f64 {
        (df / self.chi_square(df).max(1e-300)).sqrt()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Partial Fisher–Yates over an index arena.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_roundtrip_continues_stream() {
        let mut a = Rng::new(12);
        // consume an odd number of gaussians so a Box–Muller spare is cached
        for _ in 0..7 {
            a.gaussian();
        }
        let mut b = Rng::from_state(&a.state());
        for _ in 0..50 {
            assert_eq!(a.gaussian().to_bits(), b.gaussian().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
            sq += u * u;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var={var}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(4);
        let n = 200_000;
        let (mut m1, mut m2, mut m4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            m1 += g;
            m2 += g * g;
            m4 += g * g * g * g;
        }
        let nf = n as f64;
        assert!((m1 / nf).abs() < 0.01);
        assert!((m2 / nf - 1.0).abs() < 0.02);
        assert!((m4 / nf - 3.0).abs() < 0.1);
    }

    #[test]
    fn chi_square_mean_matches_df() {
        let mut r = Rng::new(5);
        for df in [1.0, 3.0, 10.0] {
            let n = 50_000;
            let mean: f64 = (0..n).map(|_| r.chi_square(df)).sum::<f64>() / n as f64;
            assert!((mean - df).abs() / df < 0.05, "df={df} mean={mean}");
        }
    }

    #[test]
    fn student_t_scale_second_moment() {
        // E[(t-scale)^2] = df / (df - 2) for df > 2.
        let mut r = Rng::new(6);
        let df = 5.0;
        let n = 100_000;
        let m2: f64 = (0..n)
            .map(|_| {
                let s = r.student_t_scale(df);
                s * s
            })
            .sum::<f64>()
            / n as f64;
        assert!((m2 - df / (df - 2.0)).abs() < 0.1, "m2={m2}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(8);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let idx = r.sample_indices(100, 40);
        assert_eq!(idx.len(), 40);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 40);
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(10);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
