//! Tiny benchmark harness (criterion is unavailable offline).
//!
//! Each `cargo bench` target is a `harness = false` binary that calls
//! [`Bencher::run`] per case.  Reports median / mean / p10 / p90 wall
//! times and an optional throughput figure, in a stable parseable format:
//!
//! ```text
//! bench <name> ... median 12.3ms mean 12.5ms p10 11.9ms p90 13.0ms [thr 4.1 GF/s]
//! ```
//!
//! Machine-readable perf trajectory: bench binaries accept `--json PATH`
//! (args after `cargo bench --bench <name> --`).  [`JsonReport`] collects
//! one [`BenchRecord`] per case and writes a JSON array of
//!
//! ```text
//! {"op": "hv", "backend": "tiled", "n": 4096, "d": 9, "threads": 8,
//!  "ns_per_op": 123456.789}
//! ```
//!
//! — `op` names the measured operation, `backend` the compute backend,
//! `n`/`d` the problem shape, `threads` the worker count and `ns_per_op`
//! the median wall time per operation in nanoseconds.  `--quick` restricts
//! the sweep to tiny shapes (CI smoke).

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub samples: Vec<Duration>,
}

impl BenchResult {
    fn sorted_secs(&self) -> Vec<f64> {
        let mut v: Vec<f64> = self.samples.iter().map(|d| d.as_secs_f64()).collect();
        sort_total(&mut v);
        v
    }

    pub fn median(&self) -> f64 {
        let v = self.sorted_secs();
        v[v.len() / 2]
    }

    pub fn mean(&self) -> f64 {
        let v = self.sorted_secs();
        v.iter().sum::<f64>() / v.len() as f64
    }

    pub fn percentile(&self, p: f64) -> f64 {
        let v = self.sorted_secs();
        let idx = ((v.len() as f64 - 1.0) * p).round() as usize;
        v[idx]
    }
}

/// Ascending total-order sort for timing samples. `Duration::as_secs_f64`
/// can never yield NaN, but derived figures can; `total_cmp` keeps a NaN
/// from panicking the comparator mid-report (it sorts last instead).
fn sort_total(v: &mut [f64]) {
    v.sort_by(|a, b| a.total_cmp(b));
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

pub struct Bencher {
    pub warmup: usize,
    pub samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        // Keep bench wall time bounded; IGP_BENCH_SAMPLES overrides.
        let samples = std::env::var("IGP_BENCH_SAMPLES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(7);
        Bencher { warmup: 1, samples }
    }
}

impl Bencher {
    /// Time `f`, printing a report line. `flops` (if Some) adds GF/s.
    pub fn run<F: FnMut()>(&self, name: &str, flops: Option<f64>, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        let r = BenchResult { name: name.to_string(), samples };
        let thr = flops
            .map(|fl| format!(" thr {:.2} GF/s", fl / r.median() / 1e9))
            .unwrap_or_default();
        println!(
            "bench {:<44} median {:>9} mean {:>9} p10 {:>9} p90 {:>9}{}",
            r.name,
            fmt_time(r.median()),
            fmt_time(r.mean()),
            fmt_time(r.percentile(0.1)),
            fmt_time(r.percentile(0.9)),
            thr,
        );
        r
    }
}

/// One machine-readable benchmark record (see the module docs for the
/// field meanings and the serialised shape).
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    pub op: String,
    pub backend: String,
    pub n: usize,
    pub d: usize,
    pub threads: usize,
    pub ns_per_op: f64,
    /// Additional numeric fields serialised verbatim after `ns_per_op`
    /// (e.g. the serving benches attach `p50_ns` / `p99_ns` /
    /// `rows_per_sec` latency observability).
    pub extra: Vec<(String, f64)>,
}

/// Collector for the `--json PATH` bench mode.
pub struct JsonReport {
    path: std::path::PathBuf,
    records: Vec<BenchRecord>,
}

impl JsonReport {
    /// Parse `--json PATH` from the process args (`cargo bench --bench x
    /// -- --json out.json`).  `None` when the flag is absent.
    pub fn from_args() -> Option<JsonReport> {
        let args: Vec<String> = std::env::args().collect();
        let i = args.iter().position(|a| a == "--json")?;
        let path = args.get(i + 1).expect("--json needs a PATH argument");
        Some(JsonReport { path: path.into(), records: Vec::new() })
    }

    pub fn at(path: impl Into<std::path::PathBuf>) -> JsonReport {
        JsonReport { path: path.into(), records: Vec::new() }
    }

    /// Record one case (median wall time from `res`).
    pub fn push(
        &mut self,
        op: &str,
        backend: &str,
        n: usize,
        d: usize,
        threads: usize,
        res: &BenchResult,
    ) {
        self.push_with(op, backend, n, d, threads, res.median() * 1e9, &[]);
    }

    /// Record one case with extra numeric fields (serialised after
    /// `ns_per_op`) and an explicit nanosecond figure — the serving
    /// benches use this to attach p50/p99/rows-per-sec observability.
    pub fn push_with(
        &mut self,
        op: &str,
        backend: &str,
        n: usize,
        d: usize,
        threads: usize,
        ns_per_op: f64,
        extra: &[(&str, f64)],
    ) {
        self.records.push(BenchRecord {
            op: op.to_string(),
            backend: backend.to_string(),
            n,
            d,
            threads,
            ns_per_op,
            extra: extra.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        });
    }

    pub fn records(&self) -> &[BenchRecord] {
        &self.records
    }

    /// Serialise the records (insertion order) as a JSON array in the
    /// repo-wide flat record shape (see [`render_flat_records`]).
    pub fn render(&self) -> String {
        let records: Vec<Vec<(String, JsonField)>> = self
            .records
            .iter()
            .map(|r| {
                let mut fields = vec![
                    ("op".to_string(), JsonField::Str(r.op.clone())),
                    ("backend".to_string(), JsonField::Str(r.backend.clone())),
                    ("n".to_string(), JsonField::Int(r.n as i64)),
                    ("d".to_string(), JsonField::Int(r.d as i64)),
                    ("threads".to_string(), JsonField::Int(r.threads as i64)),
                    ("ns_per_op".to_string(), JsonField::F3(r.ns_per_op)),
                ];
                fields.extend(r.extra.iter().map(|(k, v)| (k.clone(), JsonField::F3(*v))));
                fields
            })
            .collect();
        render_flat_records(&records)
    }

    /// Write the report to its path, announcing where it went.
    pub fn write(&self) -> std::io::Result<()> {
        std::fs::write(&self.path, self.render())?;
        println!("bench json: {} records -> {}", self.records.len(), self.path.display());
        Ok(())
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// One field of a flat JSON record: a string, an integer, or a float
/// printed with three decimals (the repo's bench-record convention).
#[derive(Clone, Debug, PartialEq)]
pub enum JsonField {
    Str(String),
    Int(i64),
    F3(f64),
}

impl JsonField {
    fn render(&self) -> String {
        match self {
            JsonField::Str(s) => format!("\"{}\"", json_escape(s)),
            JsonField::Int(i) => i.to_string(),
            JsonField::F3(x) => format!("{x:.3}"),
        }
    }
}

/// Render records in the repo's shared machine-readable shape: a JSON
/// array with one single-line object per record, fields in insertion
/// order.  `BENCH_*.json` and the `igp-lint --json` report both use this
/// so downstream tooling can parse every artifact the same way.
pub fn render_flat_records(records: &[Vec<(String, JsonField)>]) -> String {
    let mut s = String::from("[\n");
    for (i, fields) in records.iter().enumerate() {
        let body: Vec<String> = fields
            .iter()
            .map(|(k, v)| format!("\"{}\": {}", json_escape(k), v.render()))
            .collect();
        s.push_str(&format!(
            "  {{{}}}{}\n",
            body.join(", "),
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    s.push_str("]\n");
    s
}

/// True when the bench was invoked with `--quick` (tiny shapes only — the
/// CI smoke mode that keeps the JSON emitter from rotting).
pub fn quick_mode() -> bool {
    std::env::args().any(|a| a == "--quick")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_report_renders_parseable_records() {
        let mut j = JsonReport::at("/tmp/unused.json");
        let b = Bencher { warmup: 0, samples: 3 };
        let r = b.run("case", None, || {
            std::hint::black_box(1 + 1);
        });
        j.push("hv", "tiled", 256, 4, 8, &r);
        j.push("hv", "den\"se", 512, 9, 1, &r);
        let s = j.render();
        assert!(s.starts_with("[\n") && s.ends_with("]\n"), "{s}");
        assert!(s.contains("\"op\": \"hv\""), "{s}");
        assert!(s.contains("\"backend\": \"tiled\""), "{s}");
        assert!(s.contains("\"n\": 256"), "{s}");
        assert!(s.contains("\"threads\": 8"), "{s}");
        assert!(s.contains("\"ns_per_op\": "), "{s}");
        assert!(s.contains("den\\\"se"), "quote must be escaped: {s}");
        // exactly one separating comma for two records
        assert_eq!(s.matches("},\n").count(), 1, "{s}");
        assert_eq!(j.records().len(), 2);
    }

    #[test]
    fn json_report_renders_extra_fields_after_ns_per_op() {
        let mut j = JsonReport::at("/tmp/unused.json");
        j.push_with(
            "serve-latency",
            "tiled",
            128,
            4,
            2,
            1000.0,
            &[("p50_ns", 1500.0), ("p99_ns", 9000.5), ("rows_per_sec", 250000.0)],
        );
        let s = j.render();
        assert!(s.contains("\"ns_per_op\": 1000.000, \"p50_ns\": 1500.000"), "{s}");
        assert!(s.contains("\"p99_ns\": 9000.500"), "{s}");
        assert!(s.contains("\"rows_per_sec\": 250000.000"), "{s}");
        // extras come before the closing brace, with no trailing comma
        assert!(s.contains("250000.000}"), "{s}");
    }

    #[test]
    fn percentile_sort_orders_nan_last_instead_of_panicking() {
        // Regression: sorted_secs() used sort_by(partial_cmp().unwrap()),
        // which panics the comparator on NaN.  total_cmp gives NaN a
        // defined slot (after +inf) so a poisoned sample degrades the
        // percentile instead of killing the bench mid-JSON-report.
        let mut v = vec![1.0, f64::NAN, 0.5, 2.0];
        sort_total(&mut v);
        assert_eq!(&v[..3], &[0.5, 1.0, 2.0]);
        assert!(v[3].is_nan());
    }

    #[test]
    fn flat_records_render_matches_jsonreport_shape() {
        let rec = vec![
            ("rule".to_string(), JsonField::Str("lib-unwrap".to_string())),
            ("line".to_string(), JsonField::Int(42)),
            ("score".to_string(), JsonField::F3(1.5)),
        ];
        let s = render_flat_records(&[rec]);
        assert_eq!(s, "[\n  {\"rule\": \"lib-unwrap\", \"line\": 42, \"score\": 1.500}\n]\n");
    }

    #[test]
    fn bench_reports_sane_stats() {
        let b = Bencher { warmup: 0, samples: 5 };
        let r = b.run("noop", None, || { std::hint::black_box(1 + 1); });
        assert_eq!(r.samples.len(), 5);
        assert!(r.median() >= 0.0);
        assert!(r.percentile(0.9) >= r.percentile(0.1));
    }
}
