//! Tiny benchmark harness (criterion is unavailable offline).
//!
//! Each `cargo bench` target is a `harness = false` binary that calls
//! [`Bencher::run`] per case.  Reports median / mean / p10 / p90 wall
//! times and an optional throughput figure, in a stable parseable format:
//!
//! ```text
//! bench <name> ... median 12.3ms mean 12.5ms p10 11.9ms p90 13.0ms [thr 4.1 GF/s]
//! ```

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub samples: Vec<Duration>,
}

impl BenchResult {
    fn sorted_secs(&self) -> Vec<f64> {
        let mut v: Vec<f64> = self.samples.iter().map(|d| d.as_secs_f64()).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }

    pub fn median(&self) -> f64 {
        let v = self.sorted_secs();
        v[v.len() / 2]
    }

    pub fn mean(&self) -> f64 {
        let v = self.sorted_secs();
        v.iter().sum::<f64>() / v.len() as f64
    }

    pub fn percentile(&self, p: f64) -> f64 {
        let v = self.sorted_secs();
        let idx = ((v.len() as f64 - 1.0) * p).round() as usize;
        v[idx]
    }
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

pub struct Bencher {
    pub warmup: usize,
    pub samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        // Keep bench wall time bounded; IGP_BENCH_SAMPLES overrides.
        let samples = std::env::var("IGP_BENCH_SAMPLES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(7);
        Bencher { warmup: 1, samples }
    }
}

impl Bencher {
    /// Time `f`, printing a report line. `flops` (if Some) adds GF/s.
    pub fn run<F: FnMut()>(&self, name: &str, flops: Option<f64>, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        let r = BenchResult { name: name.to_string(), samples };
        let thr = flops
            .map(|fl| format!(" thr {:.2} GF/s", fl / r.median() / 1e9))
            .unwrap_or_default();
        println!(
            "bench {:<44} median {:>9} mean {:>9} p10 {:>9} p90 {:>9}{}",
            r.name,
            fmt_time(r.median()),
            fmt_time(r.mean()),
            fmt_time(r.percentile(0.1)),
            fmt_time(r.percentile(0.9)),
            thr,
        );
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let b = Bencher { warmup: 0, samples: 5 };
        let r = b.run("noop", None, || { std::hint::black_box(1 + 1); });
        assert_eq!(r.samples.len(), 5);
        assert!(r.median() >= 0.0);
        assert!(r.percentile(0.9) >= r.percentile(0.1));
    }
}
