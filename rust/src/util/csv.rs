//! CSV and markdown-table writers for experiment results, plus a tiny
//! numeric-CSV reader for the serving path's `--score` input files.

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::linalg::Mat;

/// Parse a numeric CSV into a row-major matrix.  Blank lines are skipped;
/// one leading header row (any field that does not parse as f64) is
/// tolerated and skipped; every data row must have the same number of
/// comma-separated fields.
pub fn parse_matrix(text: &str) -> Result<Mat> {
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut cols: Option<usize> = None;
    let mut saw_lines = false;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let first_line = !saw_lines;
        saw_lines = true;
        let parsed: std::result::Result<Vec<f64>, _> =
            line.split(',').map(|f| f.trim().parse::<f64>()).collect();
        match parsed {
            Ok(vals) => {
                match cols {
                    Some(c) => anyhow::ensure!(
                        vals.len() == c,
                        "line {}: {} fields but earlier rows have {c}",
                        lineno + 1,
                        vals.len()
                    ),
                    None => cols = Some(vals.len()),
                }
                rows.push(vals);
            }
            Err(e) => {
                // a single leading header row is fine; anything later is not
                anyhow::ensure!(first_line, "line {}: unparsable field ({e})", lineno + 1);
            }
        }
    }
    let cols = cols.ok_or_else(|| anyhow::anyhow!("no numeric rows found"))?;
    let mut m = Mat::zeros(rows.len(), cols);
    for (i, r) in rows.iter().enumerate() {
        m.row_mut(i).copy_from_slice(r);
    }
    Ok(m)
}

/// [`parse_matrix`] from a file path.
pub fn read_matrix<P: AsRef<Path>>(path: P) -> Result<Mat> {
    let text = fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    parse_matrix(&text).with_context(|| format!("parsing {}", path.as_ref().display()))
}

/// Incremental CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    columns: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, columns: header.len() })
    }

    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        assert_eq!(fields.len(), self.columns, "csv row arity mismatch");
        writeln!(self.out, "{}", fields.join(","))?;
        Ok(())
    }

    pub fn row_display(&mut self, fields: &[&dyn std::fmt::Display]) -> Result<()> {
        let strs: Vec<String> = fields.iter().map(|f| f.to_string()).collect();
        self.row(&strs)
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Accumulates rows and renders a GitHub-flavoured markdown table (used to
/// print paper-style tables into EXPERIMENTS.md).
pub struct MarkdownTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl MarkdownTable {
    pub fn new(header: &[&str]) -> Self {
        MarkdownTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, fields: Vec<String>) {
        assert_eq!(fields.len(), self.header.len(), "md row arity mismatch");
        self.rows.push(fields);
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("| {} |\n", self.header.join(" | ")));
        s.push_str(&format!(
            "|{}\n",
            "---|".repeat(self.header.len())
        ));
        for r in &self.rows {
            s.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        s
    }

    pub fn write_to<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, self.render())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("igp_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&["1".into(), "2".into()]).unwrap();
            w.row_display(&[&3.5, &"x"]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n3.5,x\n");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn csv_arity_enforced() {
        let dir = std::env::temp_dir().join("igp_csv_test2");
        let mut w = CsvWriter::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        w.row(&["1".into()]).unwrap();
    }

    #[test]
    fn parse_matrix_reads_numeric_rows() {
        let m = parse_matrix("1.0, 2.0\n3.5,-4\n\n5,6\n").unwrap();
        assert_eq!((m.rows, m.cols), (3, 2));
        assert_eq!(m.data, vec![1.0, 2.0, 3.5, -4.0, 5.0, 6.0]);
    }

    #[test]
    fn parse_matrix_skips_a_leading_header() {
        let m = parse_matrix("x1,x2\n1,2\n3,4\n").unwrap();
        assert_eq!((m.rows, m.cols), (2, 2));
        assert_eq!(m.data, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn parse_matrix_rejects_ragged_and_garbage_rows() {
        assert!(parse_matrix("1,2\n3\n").is_err());
        assert!(parse_matrix("1,2\nnope,4\n").is_err());
        assert!(parse_matrix("\n\n").is_err());
        assert!(parse_matrix("header,row\n").is_err()); // header but no data
    }

    #[test]
    fn read_matrix_roundtrips_a_written_file() {
        let dir = std::env::temp_dir().join("igp_csv_read_test");
        let path = dir.join("q.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&["0.5".into(), "1.5".into()]).unwrap();
            w.flush().unwrap();
        }
        let m = read_matrix(&path).unwrap();
        assert_eq!((m.rows, m.cols), (1, 2));
        assert_eq!(m.data, vec![0.5, 1.5]);
        assert!(read_matrix(dir.join("missing.csv")).is_err());
    }

    #[test]
    fn markdown_render() {
        let mut t = MarkdownTable::new(&["x", "y"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("| x | y |"));
        assert!(s.contains("|---|---|"));
        assert!(s.contains("| 1 | 2 |"));
    }
}
