//! CSV and markdown-table writers for experiment results.

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::Result;

/// Incremental CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    columns: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, columns: header.len() })
    }

    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        assert_eq!(fields.len(), self.columns, "csv row arity mismatch");
        writeln!(self.out, "{}", fields.join(","))?;
        Ok(())
    }

    pub fn row_display(&mut self, fields: &[&dyn std::fmt::Display]) -> Result<()> {
        let strs: Vec<String> = fields.iter().map(|f| f.to_string()).collect();
        self.row(&strs)
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Accumulates rows and renders a GitHub-flavoured markdown table (used to
/// print paper-style tables into EXPERIMENTS.md).
pub struct MarkdownTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl MarkdownTable {
    pub fn new(header: &[&str]) -> Self {
        MarkdownTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, fields: Vec<String>) {
        assert_eq!(fields.len(), self.header.len(), "md row arity mismatch");
        self.rows.push(fields);
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("| {} |\n", self.header.join(" | ")));
        s.push_str(&format!(
            "|{}\n",
            "---|".repeat(self.header.len())
        ));
        for r in &self.rows {
            s.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        s
    }

    pub fn write_to<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, self.render())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("igp_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&["1".into(), "2".into()]).unwrap();
            w.row_display(&[&3.5, &"x"]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n3.5,x\n");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn csv_arity_enforced() {
        let dir = std::env::temp_dir().join("igp_csv_test2");
        let mut w = CsvWriter::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        w.row(&["1".into()]).unwrap();
    }

    #[test]
    fn markdown_render() {
        let mut t = MarkdownTable::new(&["x", "y"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("| x | y |"));
        assert!(s.contains("|---|---|"));
        assert!(s.contains("| 1 | 2 |"));
    }
}
