//! Tiny in-tree data-parallel substrate (rayon is unavailable offline):
//! scoped `std::thread` workers with *deterministic* strided task
//! assignment, so results are bit-reproducible for a fixed thread count.
//!
//! Two primitives cover every parallel loop in the tiled operator:
//! * [`parallel_reduce`] — each worker owns a private accumulator; tasks
//!   `w, w+T, w+2T, ...` go to worker `w`; accumulators are combined by the
//!   caller in worker order (deterministic reduction).
//! * [`parallel_row_blocks`] — disjoint row blocks of one output buffer are
//!   processed in parallel; writes never overlap, so the result is
//!   deterministic regardless of scheduling.

/// Resolve a thread count: explicit request > `IGP_THREADS` env var >
/// available hardware parallelism.  Always at least 1.
pub fn num_threads(requested: Option<usize>) -> usize {
    if let Some(t) = requested {
        if t > 0 {
            return t;
        }
    }
    if let Ok(v) = std::env::var("IGP_THREADS") {
        if let Ok(t) = v.parse::<usize>() {
            if t > 0 {
                return t;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `task(&mut acc, i)` for every `i in 0..ntasks` across up to
/// `threads` workers.  Worker `w` processes tasks `w, w+T, w+2T, ...` into
/// its own accumulator created by `init`; the per-worker accumulators are
/// returned in worker order (combine them sequentially for a deterministic
/// reduction).
pub fn parallel_reduce<A, I, T>(ntasks: usize, threads: usize, init: I, task: T) -> Vec<A>
where
    A: Send,
    I: Fn() -> A + Sync,
    T: Fn(&mut A, usize) + Sync,
{
    let threads = threads.max(1).min(ntasks.max(1));
    if threads <= 1 {
        let mut acc = init();
        for i in 0..ntasks {
            task(&mut acc, i);
        }
        return vec![acc];
    }
    let init = &init;
    let task = &task;
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        for w in 0..threads {
            handles.push(s.spawn(move || {
                let mut acc = init();
                let mut i = w;
                while i < ntasks {
                    task(&mut acc, i);
                    i += threads;
                }
                acc
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("parallel_reduce worker panicked"))
            .collect()
    })
}

/// Fill `n` result slots in parallel: slot `i` receives `f(i)`.  Workers
/// own disjoint contiguous chunks of the slot array, and each slot's value
/// depends only on its index, so the result is bitwise-identical for every
/// thread count (including 1, which runs inline without spawning).
///
/// This is the substrate for *order-canonical* reductions: callers split a
/// reduction into fixed-size blocks (block structure independent of the
/// thread count), map each block to a partial result here, and fold the
/// partials sequentially in block order.
pub fn parallel_map_slots<A, F>(n: usize, threads: usize, f: F) -> Vec<A>
where
    A: Send,
    F: Fn(usize) -> A + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        return (0..n).map(&f).collect();
    }
    let mut slots: Vec<Option<A>> = (0..n).map(|_| None).collect();
    let chunk = (n + threads - 1) / threads;
    let f = &f;
    std::thread::scope(|s| {
        for (w, ch) in slots.chunks_mut(chunk).enumerate() {
            s.spawn(move || {
                for (k, slot) in ch.iter_mut().enumerate() {
                    *slot = Some(f(w * chunk + k));
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.expect("parallel_map_slots worker panicked"))
        .collect()
}

/// Contiguous balanced partition of `0..n` into `shards` ranges — the row
/// ownership rule of the sharded operator layer: the first `n % shards`
/// shards get one extra row, so shard sizes differ by at most one.  The
/// shard count is clamped so no range is ever empty while `n > 0` (and a
/// single `(0, 0)` range is returned for `n == 0`); there is always at
/// least one range.
pub fn shard_ranges(n: usize, shards: usize) -> Vec<(usize, usize)> {
    let s = shards.max(1).min(n.max(1));
    let q = n / s;
    let r = n % s;
    let mut out = Vec::with_capacity(s);
    let mut start = 0;
    for k in 0..s {
        let len = q + usize::from(k < r);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// Split a row-major `[n, cols]` buffer into blocks of `block_rows` rows
/// and run `task(first_row, rows_in_block, block)` over the blocks on up to
/// `threads` workers.  Blocks are disjoint `&mut` slices, so writes are
/// race-free and the result is deterministic.
pub fn parallel_row_blocks<T>(
    out: &mut [f64],
    cols: usize,
    block_rows: usize,
    threads: usize,
    task: T,
) where
    T: Fn(usize, usize, &mut [f64]) + Sync,
{
    if out.is_empty() || cols == 0 {
        return;
    }
    let n = out.len() / cols;
    let block_rows = block_rows.max(1).min(n);
    let nblocks = (n + block_rows - 1) / block_rows;
    let threads = threads.max(1).min(nblocks);
    if threads <= 1 {
        for (bi, block) in out.chunks_mut(block_rows * cols).enumerate() {
            task(bi * block_rows, block.len() / cols, block);
        }
        return;
    }
    // deterministic round-robin distribution of blocks to workers
    let mut per_worker: Vec<Vec<(usize, &mut [f64])>> =
        (0..threads).map(|_| Vec::new()).collect();
    for (bi, block) in out.chunks_mut(block_rows * cols).enumerate() {
        per_worker[bi % threads].push((bi * block_rows, block));
    }
    let task = &task;
    std::thread::scope(|s| {
        for worker_blocks in per_worker {
            s.spawn(move || {
                for (first_row, block) in worker_blocks {
                    let rows = block.len() / cols;
                    task(first_row, rows, block);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn num_threads_is_positive() {
        assert!(num_threads(None) >= 1);
        assert_eq!(num_threads(Some(3)), 3);
        assert!(num_threads(Some(0)) >= 1);
    }

    #[test]
    fn reduce_sums_all_tasks() {
        for threads in [1, 2, 4, 7] {
            let partials = parallel_reduce(100, threads, || 0u64, |acc, i| *acc += i as u64);
            let total: u64 = partials.into_iter().sum();
            assert_eq!(total, 99 * 100 / 2, "threads={threads}");
        }
    }

    #[test]
    fn reduce_is_deterministic_for_fixed_threads() {
        let run = || {
            parallel_reduce(37, 4, Vec::new, |acc: &mut Vec<usize>, i| acc.push(i))
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn reduce_handles_zero_tasks() {
        let partials = parallel_reduce(0, 4, || 1i32, |_, _| unreachable!());
        assert_eq!(partials, vec![1]);
    }

    #[test]
    fn row_blocks_cover_every_row_once() {
        let (n, cols) = (53, 3);
        for threads in [1, 2, 5] {
            for block_rows in [1, 7, 53, 200] {
                let mut out = vec![0.0; n * cols];
                parallel_row_blocks(&mut out, cols, block_rows, threads, |r0, rows, block| {
                    assert_eq!(block.len(), rows * cols);
                    for r in 0..rows {
                        for c in 0..cols {
                            block[r * cols + c] += (r0 + r) as f64 + 0.1 * c as f64;
                        }
                    }
                });
                for i in 0..n {
                    for c in 0..cols {
                        let want = i as f64 + 0.1 * c as f64;
                        assert!(
                            (out[i * cols + c] - want).abs() < 1e-12,
                            "threads={threads} block={block_rows} i={i} c={c}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn row_blocks_empty_is_noop() {
        let mut out: Vec<f64> = Vec::new();
        parallel_row_blocks(&mut out, 4, 8, 2, |_, _, _| unreachable!());
    }

    #[test]
    fn map_slots_matches_serial_for_any_thread_count() {
        let serial: Vec<u64> = (0..37).map(|i| (i as u64) * 3 + 1).collect();
        for threads in [1, 2, 4, 9, 64] {
            let got = parallel_map_slots(37, threads, |i| (i as u64) * 3 + 1);
            assert_eq!(got, serial, "threads={threads}");
        }
    }

    #[test]
    fn map_slots_zero_is_empty() {
        let got: Vec<u8> = parallel_map_slots(0, 4, |_| unreachable!());
        assert!(got.is_empty());
    }

    #[test]
    fn shard_ranges_cover_contiguously_and_balance() {
        for n in [1, 2, 5, 53, 256, 1000] {
            for shards in [1, 2, 3, 5, 8, 64] {
                let ranges = shard_ranges(n, shards);
                assert_eq!(ranges.len(), shards.min(n));
                assert_eq!(ranges[0].0, 0);
                assert_eq!(ranges.last().unwrap().1, n);
                let mut sizes = Vec::new();
                for w in ranges.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "n={n} shards={shards}: gap/overlap");
                }
                for &(a, b) in &ranges {
                    assert!(b > a, "n={n} shards={shards}: empty shard");
                    sizes.push(b - a);
                }
                let (lo, hi) = (*sizes.iter().min().unwrap(), *sizes.iter().max().unwrap());
                assert!(hi - lo <= 1, "n={n} shards={shards}: sizes {sizes:?}");
            }
        }
    }

    #[test]
    fn shard_ranges_degenerate_inputs() {
        assert_eq!(shard_ranges(0, 4), vec![(0, 0)]);
        assert_eq!(shard_ranges(7, 0), vec![(0, 7)]);
        assert_eq!(shard_ranges(3, 9), vec![(0, 1), (1, 2), (2, 3)]);
    }
}
