//! Shared substrates built in-tree (no external crates available offline):
//! RNG, statistics, CSV/markdown reporting, a tiny logger, a bench harness
//! and a property-testing harness.

pub mod bench;
pub mod csv;
pub mod logging;
pub mod parallel;
pub mod proptest;
pub mod rng;
pub mod stats;
