//! Minimal leveled logger writing to stderr.  Controlled by `IGP_LOG`
//! (error|warn|info|debug|trace, default info).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Copy, Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: OnceLock<Instant> = OnceLock::new();

/// Initialise from the IGP_LOG environment variable (idempotent).
pub fn init() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("IGP_LOG") {
        let lvl = match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        };
        LEVEL.store(lvl as u8, Ordering::Relaxed);
    }
}

pub fn set_level(lvl: Level) {
    START.get_or_init(Instant::now);
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    lvl as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(lvl: Level, args: std::fmt::Arguments<'_>) {
    if enabled(lvl) {
        let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
        eprintln!("[{t:9.3}s {lvl:?}] {args}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warnlog {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debuglog {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}
