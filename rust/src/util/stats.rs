//! Small statistics helpers used by metrics and experiment reporting.

/// Arithmetic mean (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance (0.0 for fewer than two samples).
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Standard error of the mean.
pub fn stderr(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    (variance(xs) / xs.len() as f64).sqrt()
}

/// Euclidean norm.
pub fn norm2(xs: &[f64]) -> f64 {
    xs.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Dot product.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Root mean squared error between predictions and targets.
pub fn rmse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let s: f64 = pred
        .iter()
        .zip(truth)
        .map(|(p, t)| (p - t) * (p - t))
        .sum();
    (s / pred.len() as f64).sqrt()
}

/// Mean Gaussian predictive log-likelihood: mean_i log N(y_i; mu_i, var_i).
pub fn gaussian_llh(mu: &[f64], var: &[f64], y: &[f64]) -> f64 {
    assert_eq!(mu.len(), y.len());
    assert_eq!(var.len(), y.len());
    let ln2pi = (2.0 * std::f64::consts::PI).ln();
    let s: f64 = mu
        .iter()
        .zip(var)
        .zip(y)
        .map(|((m, v), t)| {
            let v = v.max(1e-12);
            -0.5 * (ln2pi + v.ln() + (t - m) * (t - m) / v)
        })
        .sum();
    s / y.len() as f64
}

/// Relative residual norms per column of R [n, k] given unit-normalised
/// targets; returns (norm of column 0, mean norm of columns 1..k).
pub fn rel_residual_split(r_cols: &[Vec<f64>]) -> (f64, f64) {
    assert!(!r_cols.is_empty());
    let ry = norm2(&r_cols[0]);
    if r_cols.len() == 1 {
        return (ry, 0.0);
    }
    let rz = r_cols[1..].iter().map(|c| norm2(c)).sum::<f64>() / (r_cols.len() - 1) as f64;
    (ry, rz)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var_stderr() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
        assert!((stderr(&xs) - (5.0 / 12.0f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[]), 0.0);
        assert_eq!(stderr(&[1.0]), 0.0);
    }

    #[test]
    fn rmse_known_case() {
        assert!((rmse(&[1.0, 2.0], &[0.0, 4.0]) - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn llh_matches_hand_computation() {
        // log N(0; 0, 1) = -0.5 ln(2 pi)
        let l = gaussian_llh(&[0.0], &[1.0], &[0.0]);
        assert!((l + 0.5 * (2.0 * std::f64::consts::PI).ln()).abs() < 1e-12);
    }

    #[test]
    fn residual_split() {
        let r = vec![vec![3.0, 4.0], vec![1.0, 0.0], vec![0.0, 2.0]];
        let (ry, rz) = rel_residual_split(&r);
        assert!((ry - 5.0).abs() < 1e-12);
        assert!((rz - 1.5).abs() < 1e-12);
    }
}
