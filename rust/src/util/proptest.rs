//! Minimal property-testing harness (proptest is unavailable offline).
//!
//! Runs a property over many seeded random cases; on failure it reports the
//! failing seed and retries with a sequence of "shrunken" size parameters
//! so the smallest failing size is surfaced.  Used for coordinator
//! invariants (routing, batching, warm-start state) per DESIGN.md §5.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    /// Maximum "size" hint handed to generators (e.g. max vector length).
    pub max_size: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xC0FFEE, max_size: 64 }
    }
}

/// Run `prop(rng, size)`; the property fails by returning Err(reason).
/// On failure, retries smaller sizes to find a minimal failing size, then
/// panics with full reproduction info.
pub fn check<F>(name: &str, cfg: PropConfig, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    let mut root = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        // sizes sweep small -> large so trivial sizes are always covered
        let size = 1 + (case * cfg.max_size) / cfg.cases.max(1);
        let case_seed = root.next_u64();
        let mut rng = Rng::new(case_seed);
        if let Err(reason) = prop(&mut rng, size) {
            // shrink: probe smaller sizes with the same seed
            let mut min_fail = (size, reason.clone());
            let mut sz = size / 2;
            while sz >= 1 {
                let mut rng = Rng::new(case_seed);
                match prop(&mut rng, sz) {
                    Err(r) => {
                        min_fail = (sz, r);
                        if sz == 1 {
                            break;
                        }
                        sz /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {case_seed:#x}, \
                 size {} after shrink from {size}): {}",
                min_fail.0, min_fail.1
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", PropConfig { cases: 10, ..Default::default() }, |_, _| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", PropConfig { cases: 5, ..Default::default() }, |_, size| {
            if size > 1 {
                Err("too big".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn shrinking_reports_smaller_size() {
        let result = std::panic::catch_unwind(|| {
            check(
                "shrinkme",
                PropConfig { cases: 3, max_size: 64, ..Default::default() },
                |_, size| {
                    if size >= 2 {
                        Err("boom".into())
                    } else {
                        Ok(())
                    }
                },
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("size 2"), "{msg}");
    }
}
