//! Experiment configuration: a hand-rolled TOML-subset parser (serde/toml
//! are unavailable offline) plus the typed run configuration used by the
//! CLI and the experiment harness.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string
//! ("x"), bool, integer, float and flat arrays ([1, 2.5, "a"]) values, and
//! `#` comments.  This covers everything configs/*.toml need.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Bool(bool),
    Int(i64),
    Float(f64),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }
    pub fn as_int(&self) -> Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            other => bail!("expected int, got {other:?}"),
        }
    }
    pub fn as_float(&self) -> Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            other => bail!("expected float, got {other:?}"),
        }
    }
    pub fn as_array(&self) -> Result<&[Value]> {
        match self {
            Value::Array(a) => Ok(a),
            other => bail!("expected array, got {other:?}"),
        }
    }
}

/// Parsed document: section -> key -> value. Root-level keys live under "".
#[derive(Clone, Debug, Default)]
pub struct Doc {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Doc {
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn get_or<'a>(&'a self, section: &str, key: &str, default: &'a Value) -> &'a Value {
        self.get(section, key).unwrap_or(default)
    }
}

fn parse_scalar(tok: &str) -> Result<Value> {
    let t = tok.trim();
    if let Some(stripped) = t.strip_prefix('"') {
        let Some(inner) = stripped.strip_suffix('"') else {
            bail!("unterminated string: {t}");
        };
        return Ok(Value::Str(inner.to_string()));
    }
    match t {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = t.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = t.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value '{t}'")
}

fn parse_value(tok: &str) -> Result<Value> {
    let t = tok.trim();
    if let Some(inner) = t.strip_prefix('[') {
        let Some(inner) = inner.strip_suffix(']') else {
            bail!("unterminated array: {t}");
        };
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        // split on commas not inside quotes
        let mut items = Vec::new();
        let mut depth_quote = false;
        let mut cur = String::new();
        for c in inner.chars() {
            match c {
                '"' => {
                    depth_quote = !depth_quote;
                    cur.push(c);
                }
                ',' if !depth_quote => {
                    items.push(parse_scalar(&cur)?);
                    cur.clear();
                }
                _ => cur.push(c),
            }
        }
        if !cur.trim().is_empty() {
            items.push(parse_scalar(&cur)?);
        }
        return Ok(Value::Array(items));
    }
    parse_scalar(t)
}

/// Strip a trailing comment that is not inside a string.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

pub fn parse(text: &str) -> Result<Doc> {
    let mut doc = Doc::default();
    let mut section = String::new();
    doc.sections.entry(section.clone()).or_default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let Some(name) = name.strip_suffix(']') else {
                bail!("line {}: bad section header '{raw}'", lineno + 1);
            };
            section = name.trim().to_string();
            doc.sections.entry(section.clone()).or_default();
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            bail!("line {}: expected key = value, got '{raw}'", lineno + 1);
        };
        let value = parse_value(v)
            .with_context(|| format!("line {}: value for '{}'", lineno + 1, k.trim()))?;
        doc.sections
            .get_mut(&section)
            .unwrap()
            .insert(k.trim().to_string(), value);
    }
    Ok(doc)
}

pub fn parse_file<P: AsRef<Path>>(path: P) -> Result<Doc> {
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    parse(&text)
}

// ---------------------------------------------------------------------------
// Typed run configuration
// ---------------------------------------------------------------------------

/// A fully-resolved training-run configuration (one Table-1 cell).
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub dataset: String,
    pub solver: String,      // cg | ap | sgd
    pub estimator: String,   // standard | pathwise
    pub warm_start: bool,
    pub outer_steps: usize,
    pub lr: f64,
    pub tolerance: f64,
    /// Maximum solver epochs per outer step (None = solve to tolerance,
    /// with a safety cap).
    pub max_epochs: Option<usize>,
    pub seed: u64,
    pub artifacts_dir: String,
    /// Compute backend: dense | tiled | xla.
    pub backend: String,
    /// Probe count s for the pure-Rust backends (xla takes it from meta).
    pub probes: usize,
    /// RFF feature pairs m for the pure-Rust backends.
    pub rff: usize,
    /// Tile edge for the tiled backend.
    pub tile: usize,
    /// Row shards for the tiled backend (1 = monolithic).  Each shard owns
    /// its own panel cache; products fold shard partials in canonical
    /// order, so results are bitwise-identical to the monolithic operator.
    pub shards: usize,
    /// Worker threads for the tiled backend (0 = auto).
    pub threads: usize,
    /// Online data-arrival mode: replay the dataset in this many chunks,
    /// carrying solver/optimiser state across arrivals (0 or 1 = off).
    pub online_chunks: usize,
    /// Compute precision for operator products: "f64" (default, the
    /// bitwise-parity reference) or "f32" (reduced-precision compute with
    /// f64 accumulation, iterative refinement for CG, and an f64
    /// residual-drift guard on every solver).  CPU backends only.
    pub precision: String,
    /// Staleness policy of the serving engine: what happens to queries
    /// that arrive between an online data arrival and the warm refresh
    /// solve — refuse | serve_stale | refresh_first.
    pub serve_policy: String,
    /// Serving admission cap in queued rows (0 = unbounded): requests
    /// past the cap are rejected with a typed queue-full error.
    pub serve_queue_cap: usize,
    /// Default logical deadline tick attached to enqueued serve requests
    /// (None = no deadline; smaller ticks drain first).
    pub serve_deadline: Option<u64>,
    /// Deterministic fault-injection plan (chaos spec, e.g.
    /// `"seed=7;solver@3;panel~0.01"`).  None = unarmed: the supervisor
    /// hooks are zero-cost no-ops and every run is bitwise the seed run.
    pub chaos: Option<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            dataset: "test".into(),
            solver: "cg".into(),
            estimator: "standard".into(),
            warm_start: false,
            outer_steps: 30,
            lr: 0.1,
            tolerance: 0.01,
            max_epochs: None,
            seed: 0,
            artifacts_dir: "artifacts".into(),
            backend: "tiled".into(),
            probes: 16,
            rff: 256,
            tile: 256,
            shards: 1,
            threads: 0,
            online_chunks: 0,
            precision: "f64".into(),
            serve_policy: "refresh_first".into(),
            serve_queue_cap: 0,
            serve_deadline: None,
            chaos: None,
        }
    }
}

impl RunConfig {
    /// Load from a TOML file: root keys plus optional [run] section.
    pub fn from_doc(doc: &Doc) -> Result<RunConfig> {
        let mut rc = RunConfig::default();
        for sec in ["", "run"] {
            let Some(tbl) = doc.sections.get(sec) else { continue };
            for (k, v) in tbl {
                match k.as_str() {
                    "dataset" => rc.dataset = v.as_str()?.to_string(),
                    "solver" => rc.solver = v.as_str()?.to_string(),
                    "estimator" => rc.estimator = v.as_str()?.to_string(),
                    "warm_start" => rc.warm_start = v.as_bool()?,
                    "outer_steps" => rc.outer_steps = v.as_int()? as usize,
                    "lr" => rc.lr = v.as_float()?,
                    "tolerance" => rc.tolerance = v.as_float()?,
                    "max_epochs" => rc.max_epochs = Some(v.as_int()? as usize),
                    "seed" => rc.seed = v.as_int()? as u64,
                    "artifacts_dir" => rc.artifacts_dir = v.as_str()?.to_string(),
                    "backend" => rc.backend = v.as_str()?.to_string(),
                    "probes" => rc.probes = v.as_int()? as usize,
                    "rff" => rc.rff = v.as_int()? as usize,
                    "tile" => rc.tile = v.as_int()? as usize,
                    "shards" => rc.shards = v.as_int()? as usize,
                    "threads" => rc.threads = v.as_int()? as usize,
                    "online_chunks" => rc.online_chunks = v.as_int()? as usize,
                    "precision" => rc.precision = v.as_str()?.to_string(),
                    "serve_policy" => rc.serve_policy = v.as_str()?.to_string(),
                    "serve_queue_cap" => rc.serve_queue_cap = v.as_int()? as usize,
                    "serve_deadline" => rc.serve_deadline = Some(v.as_int()? as u64),
                    "chaos" => rc.chaos = Some(v.as_str()?.to_string()),
                    other => bail!("unknown run config key '{other}'"),
                }
            }
        }
        rc.validate()?;
        Ok(rc)
    }

    pub fn validate(&self) -> Result<()> {
        if !["cg", "ap", "sgd", "exact"].contains(&self.solver.as_str()) {
            bail!("solver must be cg|ap|sgd|exact, got '{}'", self.solver);
        }
        if !["standard", "pathwise"].contains(&self.estimator.as_str()) {
            bail!("estimator must be standard|pathwise, got '{}'", self.estimator);
        }
        if self.tolerance <= 0.0 || self.tolerance >= 1.0 {
            bail!("tolerance must be in (0,1)");
        }
        if self.outer_steps == 0 {
            bail!("outer_steps must be positive");
        }
        // single source of truth for backend names
        crate::operators::BackendKind::parse(&self.backend)?;
        if self.probes == 0 {
            bail!("probes must be positive");
        }
        if self.rff == 0 {
            bail!("rff must be positive");
        }
        if self.tile == 0 {
            bail!("tile must be positive");
        }
        if self.shards == 0 {
            bail!("shards must be positive (1 = monolithic)");
        }
        if self.shards > 1 && self.backend != "tiled" {
            bail!("shards > 1 requires the tiled backend, got '{}'", self.backend);
        }
        if self.online_chunks > 1 && self.backend == "xla" {
            bail!("online mode needs a resizable backend (dense|tiled); xla artifacts have static shapes");
        }
        // single source of truth for precision names
        let prec = crate::kernels::panel::Precision::parse(&self.precision)?;
        if prec.is_f32() && self.backend == "xla" {
            bail!("precision = \"f32\" is a CPU-backend feature (dense|tiled); xla artifacts are compiled f64");
        }
        // single source of truth for staleness-policy names
        crate::serve::StalenessPolicy::parse(&self.serve_policy)?;
        // single source of truth for the chaos spec grammar
        if let Some(spec) = &self.chaos {
            crate::fault::FaultPlan::parse(spec)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_sections() {
        let doc = parse(
            r#"
            # top comment
            name = "pol"          # trailing comment
            steps = 100
            lr = 0.1
            warm = true
            [solver]
            kind = "ap"
            budgets = [10, 20, 30]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "name").unwrap().as_str().unwrap(), "pol");
        assert_eq!(doc.get("", "steps").unwrap().as_int().unwrap(), 100);
        assert!((doc.get("", "lr").unwrap().as_float().unwrap() - 0.1).abs() < 1e-15);
        assert!(doc.get("", "warm").unwrap().as_bool().unwrap());
        assert_eq!(doc.get("solver", "kind").unwrap().as_str().unwrap(), "ap");
        let arr = doc.get("solver", "budgets").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_int().unwrap(), 20);
    }

    #[test]
    fn int_coerces_to_float() {
        let doc = parse("x = 3").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_float().unwrap(), 3.0);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = parse(r##"tag = "a#b""##).unwrap();
        assert_eq!(doc.get("", "tag").unwrap().as_str().unwrap(), "a#b");
    }

    #[test]
    fn errors_are_reported_with_lines() {
        let err = parse("x = @@").unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
    }

    #[test]
    fn run_config_roundtrip() {
        let doc = parse(
            r#"
            dataset = "pol"
            solver = "ap"
            estimator = "pathwise"
            warm_start = true
            outer_steps = 50
            max_epochs = 10
            "#,
        )
        .unwrap();
        let rc = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(rc.dataset, "pol");
        assert_eq!(rc.solver, "ap");
        assert_eq!(rc.estimator, "pathwise");
        assert!(rc.warm_start);
        assert_eq!(rc.max_epochs, Some(10));
    }

    #[test]
    fn run_config_backend_selector() {
        let doc = parse(
            r#"
            backend = "tiled"
            tile = 128
            threads = 4
            probes = 8
            rff = 64
            "#,
        )
        .unwrap();
        let rc = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(rc.backend, "tiled");
        assert_eq!(rc.tile, 128);
        assert_eq!(rc.threads, 4);
        assert_eq!(rc.probes, 8);
        assert_eq!(rc.rff, 64);

        let bad = parse(r#"backend = "gpu""#).unwrap();
        assert!(RunConfig::from_doc(&bad).is_err());
        let zero_tile = parse(r#"tile = 0"#).unwrap();
        assert!(RunConfig::from_doc(&zero_tile).is_err());
    }

    #[test]
    fn run_config_shards() {
        let doc = parse("shards = 3").unwrap();
        assert_eq!(RunConfig::from_doc(&doc).unwrap().shards, 3);
        // default is monolithic
        assert_eq!(RunConfig::default().shards, 1);
        let zero = parse("shards = 0").unwrap();
        assert!(RunConfig::from_doc(&zero).is_err());
        // only the tiled backend has a sharded layout
        let dense = parse("shards = 2\nbackend = \"dense\"").unwrap();
        assert!(RunConfig::from_doc(&dense).is_err());
        let one_dense = parse("shards = 1\nbackend = \"dense\"").unwrap();
        assert!(RunConfig::from_doc(&one_dense).is_ok());
    }

    #[test]
    fn run_config_online_chunks() {
        let doc = parse("online_chunks = 4").unwrap();
        assert_eq!(RunConfig::from_doc(&doc).unwrap().online_chunks, 4);
        // static-shape backend cannot grow
        let bad = parse("online_chunks = 4\nbackend = \"xla\"").unwrap();
        assert!(RunConfig::from_doc(&bad).is_err());
    }

    #[test]
    fn run_config_chaos_spec() {
        assert_eq!(RunConfig::default().chaos, None);
        let doc = parse(r#"chaos = "seed=7;solver@3;panel~0.01""#).unwrap();
        assert_eq!(
            RunConfig::from_doc(&doc).unwrap().chaos.as_deref(),
            Some("seed=7;solver@3;panel~0.01")
        );
        // the spec is validated through the one grammar
        let bad = parse(r#"chaos = "seed=7;warp@3""#).unwrap();
        assert!(RunConfig::from_doc(&bad).is_err());
        let bad_prob = parse(r#"chaos = "panel~2.0""#).unwrap();
        assert!(RunConfig::from_doc(&bad_prob).is_err());
    }

    #[test]
    fn run_config_precision() {
        assert_eq!(RunConfig::default().precision, "f64");
        let doc = parse(r#"precision = "f32""#).unwrap();
        assert_eq!(RunConfig::from_doc(&doc).unwrap().precision, "f32");
        let bad = parse(r#"precision = "f16""#).unwrap();
        assert!(RunConfig::from_doc(&bad).is_err());
        // xla artifacts are compiled f64: the combination must be rejected
        let xla = parse("precision = \"f32\"\nbackend = \"xla\"").unwrap();
        assert!(RunConfig::from_doc(&xla).is_err());
        let xla64 = parse("precision = \"f64\"\nbackend = \"xla\"").unwrap();
        assert!(RunConfig::from_doc(&xla64).is_ok());
    }

    #[test]
    fn run_config_serve_keys() {
        let rc = RunConfig::default();
        assert_eq!(rc.serve_policy, "refresh_first");
        assert_eq!(rc.serve_queue_cap, 0);
        assert_eq!(rc.serve_deadline, None);
        let doc = parse(
            r#"
            serve_policy = "serve_stale"
            serve_queue_cap = 128
            serve_deadline = 7
            "#,
        )
        .unwrap();
        let rc = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(rc.serve_policy, "serve_stale");
        assert_eq!(rc.serve_queue_cap, 128);
        assert_eq!(rc.serve_deadline, Some(7));
        // policy names go through StalenessPolicy::parse
        let bad = parse(r#"serve_policy = "drop""#).unwrap();
        assert!(RunConfig::from_doc(&bad).is_err());
    }

    #[test]
    fn run_config_rejects_bad_solver() {
        let doc = parse(r#"solver = "newton""#).unwrap();
        assert!(RunConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn run_config_rejects_unknown_key() {
        let doc = parse("banana = 1").unwrap();
        assert!(RunConfig::from_doc(&doc).is_err());
    }
}
