//! # igp — Iterative Gaussian Processes
//!
//! Production-style reproduction of *“Improving Linear System Solvers for
//! Hyperparameter Optimisation in Iterative Gaussian Processes”* (Lin et
//! al., NeurIPS 2024) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L1 (Pallas)** — blocked kernel-matrix products and a fused
//!   gradient-quadratic-form kernel (`python/compile/kernels/`), AOT-lowered
//!   to HLO text.
//! * **L2 (JAX)** — the marginal-likelihood compute graph
//!   (`python/compile/model.py`), one artifact per static-shape config.
//! * **L3 (this crate)** — the paper's contribution: the bilevel
//!   coordinator with the pathwise gradient estimator, warm-started linear
//!   system solvers (CG / AP / SGD) and epoch-based compute budgets.
//!
//! Python runs only at build time (`make artifacts`); the binary executes
//! compiled artifacts through the PJRT C API (`xla` crate, behind the `xla`
//! cargo feature).  Two pure-Rust backends need no artifacts at all:
//! [`operators::DenseOperator`] (O(n²) oracle) and the matrix-free,
//! multi-threaded [`operators::TiledOperator`] (O(n·d) memory) — see
//! [`operators`] for the backend matrix.
//!
//! ## Quick start (pure Rust, no artifacts required)
//!
//! ```no_run
//! use igp::prelude::*;
//!
//! let data = igp::data::generate(&igp::data::spec("test").unwrap());
//! let op = TiledOperator::new(&data, 16, 256); // s probes, m RFF pairs
//! let mut trainer = Trainer::new(
//!     TrainerOptions {
//!         solver: SolverKind::Ap,
//!         estimator: EstimatorKind::Pathwise,
//!         warm_start: true,
//!         ..TrainerOptions::default()
//!     },
//!     Box::new(op),
//!     &data,
//! );
//! let outcome = trainer.run(30).unwrap();
//! println!("final test llh = {:?}", outcome.final_metrics);
//! ```
//!
//! With compiled artifacts (`make artifacts`), the `xla` crate vendored and
//! the `xla` feature enabled (see `rust/README.md` — the feature alone does
//! not supply the crate), swap the operator for
//! `XlaOperator::new(rt.load_config("artifacts", "test")?, &data)`.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod estimator;
pub mod fault;
pub mod gp;
pub mod kernels;
pub mod linalg;
pub mod lint;
pub mod operators;
pub mod optim;
pub mod runtime;
pub mod serve;
pub mod solvers;
pub mod util;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::coordinator::{Trainer, TrainerOptions, TrainOutcome};
    pub use crate::data::Dataset;
    pub use crate::estimator::EstimatorKind;
    pub use crate::fault::{FaultError, FaultPlan, FaultSite, RecoveryStats};
    pub use crate::kernels::{Hyperparams, KernelFamily};
    pub use crate::linalg::Mat;
    pub use crate::operators::{
        BackendKind, DenseOperator, KernelOperator, TiledOperator, TiledOptions, XlaOperator,
    };
    pub use crate::serve::{
        ModelFleet, PosteriorArtifact, PredictionService, ServeError, ServeOptions, ServeStats,
        StalenessPolicy,
    };
    pub use crate::solvers::{SolveOptions, SolverKind};
    pub use crate::util::rng::Rng;
}
