//! # igp — Iterative Gaussian Processes
//!
//! Production-style reproduction of *“Improving Linear System Solvers for
//! Hyperparameter Optimisation in Iterative Gaussian Processes”* (Lin et
//! al., NeurIPS 2024) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L1 (Pallas)** — blocked kernel-matrix products and a fused
//!   gradient-quadratic-form kernel (`python/compile/kernels/`), AOT-lowered
//!   to HLO text.
//! * **L2 (JAX)** — the marginal-likelihood compute graph
//!   (`python/compile/model.py`), one artifact per static-shape config.
//! * **L3 (this crate)** — the paper's contribution: the bilevel
//!   coordinator with the pathwise gradient estimator, warm-started linear
//!   system solvers (CG / AP / SGD) and epoch-based compute budgets.
//!
//! Python runs only at build time (`make artifacts`); the binary executes
//! compiled artifacts through the PJRT C API (`xla` crate).
//!
//! ## Quick start
//!
//! ```no_run
//! use igp::prelude::*;
//!
//! let data = igp::data::generate(&igp::data::spec("test").unwrap());
//! let rt = igp::runtime::Runtime::cpu().unwrap();
//! let model = rt.load_config("artifacts", "test").unwrap();
//! let mut trainer = Trainer::new(
//!     TrainerOptions {
//!         solver: SolverKind::Ap,
//!         estimator: EstimatorKind::Pathwise,
//!         warm_start: true,
//!         ..TrainerOptions::default()
//!     },
//!     Box::new(igp::operators::XlaOperator::new(model, &data)),
//!     &data,
//! );
//! let outcome = trainer.run(30).unwrap();
//! println!("final test llh = {:?}", outcome.final_metrics);
//! ```

pub mod config;
pub mod coordinator;
pub mod data;
pub mod estimator;
pub mod gp;
pub mod kernels;
pub mod linalg;
pub mod operators;
pub mod optim;
pub mod runtime;
pub mod solvers;
pub mod util;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::coordinator::{Trainer, TrainerOptions, TrainOutcome};
    pub use crate::data::Dataset;
    pub use crate::estimator::EstimatorKind;
    pub use crate::kernels::{Hyperparams, KernelFamily};
    pub use crate::linalg::Mat;
    pub use crate::operators::{DenseOperator, KernelOperator, XlaOperator};
    pub use crate::solvers::{SolveOptions, SolverKind};
    pub use crate::util::rng::Rng;
}
