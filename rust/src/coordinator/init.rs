//! Large-dataset hyperparameter initialisation heuristic (paper App. B,
//! following Lin et al. 2023/24, used to avoid aliasing bias):
//!
//! 1. pick a centroid training example uniformly at random;
//! 2. take the `subset` nearest examples (Euclidean);
//! 3. maximise the *exact* marginal likelihood on that subset;
//! 4. repeat for `centroids` centroids and average the hyperparameters.
//!
//! Paper scale: 10 centroids x 10k points; here scaled with the datasets
//! (DESIGN.md §3).

use anyhow::Result;

use crate::data::Dataset;
use crate::gp::ExactGp;
use crate::kernels::Hyperparams;
use crate::linalg::Mat;
use crate::optim::{Adam, SoftplusParams};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SubsetInitOptions {
    pub centroids: usize,
    pub subset: usize,
    pub steps: usize,
    pub lr: f64,
    pub seed: u64,
}

impl Default for SubsetInitOptions {
    fn default() -> Self {
        SubsetInitOptions { centroids: 3, subset: 256, steps: 15, lr: 0.1, seed: 0 }
    }
}

/// Returns the averaged theta = [ell.., sigf, sigma].
pub fn subset_init(ds: &Dataset, opts: &SubsetInitOptions) -> Result<Vec<f64>> {
    let n = ds.x_train.rows;
    let d = ds.x_train.cols;
    let subset = opts.subset.min(n);
    let mut rng = Rng::new(opts.seed ^ 0x5EED);
    let mut acc = vec![0.0; d + 2];
    for c in 0..opts.centroids {
        let centre = rng.below(n);
        let idx = nearest(&ds.x_train, centre, subset);
        let xs = ds.x_train.gather_rows(&idx);
        let ys: Vec<f64> = idx.iter().map(|&i| ds.y_train[i]).collect();
        let theta = exact_opt(&xs, &ys, ds.spec.family, opts.steps, opts.lr)?;
        for (a, t) in acc.iter_mut().zip(&theta) {
            *a += t / opts.centroids as f64;
        }
        crate::debuglog!("subset_init centroid {c}: theta[d..]={:?}", &theta[d..]);
    }
    Ok(acc)
}

/// Indices of the `k` nearest rows to row `centre` (including itself).
fn nearest(x: &Mat, centre: usize, k: usize) -> Vec<usize> {
    let c = x.row(centre).to_vec();
    let mut dist: Vec<(f64, usize)> = (0..x.rows)
        .map(|i| {
            let r = x.row(i);
            let d2: f64 = r.iter().zip(&c).map(|(a, b)| (a - b) * (a - b)).sum();
            (d2, i)
        })
        .collect();
    // total_cmp, not partial_cmp().unwrap(): a NaN feature (corrupt input
    // row) must not panic the initialiser — NaN distances order last, so
    // the k nearest clean rows are still returned
    dist.sort_by(|a, b| a.0.total_cmp(&b.0));
    dist.into_iter().take(k).map(|(_, i)| i).collect()
}

fn exact_opt(
    x: &Mat,
    y: &[f64],
    family: crate::kernels::KernelFamily,
    steps: usize,
    lr: f64,
) -> Result<Vec<f64>> {
    let d = x.cols;
    let mut params = SoftplusParams::from_theta(&vec![1.0; d + 2]);
    let mut adam = Adam::new(d + 2, lr);
    for _ in 0..steps {
        let theta = params.theta();
        let hp = Hyperparams::unpack(&theta, d);
        let gp = ExactGp::fit(x, y, &hp, family)?;
        let grad = gp.mll_grad();
        let grad_nu = params.chain_grad(&grad);
        adam.step(&mut params.nu, &grad_nu);
    }
    Ok(params.theta())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;

    #[test]
    fn nearest_includes_centre_and_is_sorted() {
        let x = Mat::from_fn(10, 1, |i, _| i as f64);
        let idx = nearest(&x, 5, 3);
        assert_eq!(idx[0], 5);
        assert_eq!(idx.len(), 3);
        for &i in &idx {
            assert!((4..=6).contains(&i), "{i}");
        }
    }

    #[test]
    fn nearest_tolerates_nan_rows_instead_of_panicking() {
        // regression: the comparator was partial_cmp().unwrap(), so one
        // NaN feature anywhere in the dataset aborted the whole
        // initialisation.  NaN distances must sort last (total_cmp: NaN
        // with a positive sign bit orders above every real), leaving the
        // clean rows as the nearest set.
        let mut x = Mat::from_fn(10, 1, |i, _| i as f64);
        x[(7, 0)] = f64::NAN;
        let idx = nearest(&x, 5, 3);
        assert_eq!(idx[0], 5);
        assert_eq!(idx.len(), 3);
        for &i in &idx {
            assert!(i != 7, "NaN row selected as a nearest neighbour");
            assert!((3..=6).contains(&i), "{i}");
        }
        // even a NaN centre must not panic: every distance is NaN, and the
        // call still returns k indices
        let idx = nearest(&x, 7, 3);
        assert_eq!(idx.len(), 3);
    }

    #[test]
    fn subset_init_returns_positive_theta() {
        let ds = data::generate(&data::spec("test").unwrap());
        let opts = SubsetInitOptions { centroids: 2, subset: 64, steps: 8, lr: 0.1, seed: 1 };
        let theta = subset_init(&ds, &opts).unwrap();
        assert_eq!(theta.len(), ds.spec.d + 2);
        assert!(theta.iter().all(|&t| t > 0.0));
    }

    #[test]
    fn subset_init_is_deterministic() {
        let ds = data::generate(&data::spec("test").unwrap());
        let opts = SubsetInitOptions { centroids: 2, subset: 48, steps: 5, lr: 0.1, seed: 2 };
        let a = subset_init(&ds, &opts).unwrap();
        let b = subset_init(&ds, &opts).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn subset_init_improves_on_constant_init() {
        // the heuristic's theta must beat theta = 1 in exact MLL on a
        // fresh subset of the data
        let ds = data::generate(&data::spec("test").unwrap());
        let opts = SubsetInitOptions { centroids: 2, subset: 96, steps: 12, lr: 0.1, seed: 3 };
        let theta = subset_init(&ds, &opts).unwrap();
        let d = ds.spec.d;
        let mll = |th: &[f64]| {
            let hp = Hyperparams::unpack(th, d);
            ExactGp::fit(&ds.x_train, &ds.y_train, &hp, ds.spec.family)
                .unwrap()
                .mll(&ds.y_train)
        };
        assert!(mll(&theta) > mll(&vec![1.0; d + 2]), "heuristic did not help");
    }
}
