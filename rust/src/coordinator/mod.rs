//! The paper's L3 contribution: the bilevel marginal-likelihood
//! coordinator.
//!
//! Outer loop: Adam ascent on softplus-reparameterised hyperparameters.
//! Gradient estimator: standard or pathwise probe sets ([`ProbeSet`]).
//! Inner loop: a warm-startable, budgeted linear-system solver
//! ([`LinearSolver`]) running against a [`KernelOperator`] backend.
//!
//! The three studied techniques are coordinated here:
//! * pathwise estimation (targets + gradient assembly + amortised
//!   prediction through pathwise conditioning),
//! * warm starting (the solution store carried across outer steps, with
//!   frozen probe randomness),
//! * compute budgets (epoch metering per outer step, with censoring
//!   semantics when the tolerance is not reachable).

pub mod checkpoint;
pub mod init;

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::data::Dataset;
use crate::estimator::{EstimatorKind, ProbeSet};
use crate::fault::{
    mat_finite, slice_finite, ChaosOpView, FaultError, FaultPlan, FaultSite, RecoveryStats,
    Supervisor,
};
use crate::gp::{metrics, pathwise_variances, Metrics};
use crate::linalg::Mat;
use crate::operators::{KernelOperator, Precision};
use crate::optim::{Adam, SoftplusParams};
use crate::serve::{ArtifactCache, PosteriorArtifact, SharedArtifactCache, TenantId};
use crate::solvers::{
    autotune_lr, make_solver, LinearSolver, PreconditionerCache, SharedPreconditionerCache,
    SolveOptions, SolveReport, SolverKind,
};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct TrainerOptions {
    pub solver: SolverKind,
    pub estimator: EstimatorKind,
    pub warm_start: bool,
    /// Adam learning rate (paper: 0.1 small, 0.03 large datasets).
    pub lr: f64,
    /// Relative residual tolerance tau.
    pub tolerance: f64,
    /// Per-step epoch budget (None = solve to tolerance under `epoch_cap`).
    pub max_epochs: Option<f64>,
    /// Safety cap when solving "to tolerance" (censoring, stands in for
    /// the paper's 24h timeout).
    pub epoch_cap: f64,
    /// CG preconditioner rank.
    pub precond_rank: usize,
    /// AP block / SGD batch size (None = operator's preferred size).
    pub block_size: Option<usize>,
    /// SGD learning rate (None = auto-tune on the first step).
    pub sgd_lr: Option<f64>,
    /// Halve the auto-tuned SGD rate (paper's large-dataset protocol).
    pub sgd_lr_halve: bool,
    /// Initial hyperparameter value (paper: 1.0 on small datasets).
    pub init_theta: f64,
    /// Also evaluate the exact MLL each step (needs an exact backend path).
    pub track_exact: bool,
    /// Evaluate test metrics every k outer steps (None = only at the end).
    pub predict_every: Option<usize>,
    /// Worker threads for the solver-recurrence layer and preconditioner
    /// builds (0 = auto).  Output is bitwise-identical for every value.
    pub threads: usize,
    /// AP: score blocks on the preconditioned residual (off by default).
    pub ap_precond: bool,
    /// Compute precision for operator products inside the solves.  `F64`
    /// (the default) is the bitwise-parity reference; `F32` enables the
    /// reduced-precision path with iterative refinement (CG) and the f64
    /// residual-drift guard on every solver.  The operator must have been
    /// switched with `set_precision(F32)` as well — the trainer does this
    /// when constructed through the CLI wiring.
    pub precision: Precision,
    pub seed: u64,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions {
            solver: SolverKind::Cg,
            estimator: EstimatorKind::Standard,
            warm_start: false,
            lr: 0.1,
            tolerance: 0.01,
            max_epochs: None,
            epoch_cap: 300.0,
            precond_rank: 64,
            block_size: None,
            sgd_lr: None,
            sgd_lr_halve: false,
            init_theta: 1.0,
            track_exact: false,
            predict_every: None,
            threads: 0,
            ap_precond: false,
            precision: Precision::F64,
            seed: 0,
        }
    }
}

/// Per-outer-step telemetry (drives every figure of the paper).
#[derive(Clone, Debug)]
pub struct StepTelemetry {
    pub step: usize,
    pub theta: Vec<f64>,
    pub grad: Vec<f64>,
    pub ry: f64,
    pub rz: f64,
    pub iterations: usize,
    pub epochs: f64,
    pub solver_secs: f64,
    pub step_secs: f64,
    pub converged: bool,
    pub init_residual_sq: f64,
    pub exact_mll: Option<f64>,
    pub metrics: Option<Metrics>,
}

#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub telemetry: Vec<StepTelemetry>,
    pub theta: Vec<f64>,
    pub final_metrics: Metrics,
    pub total_secs: f64,
    /// Wall time in the solver across *all* solves this run — the per-step
    /// training solves plus prediction, evaluation re-solves (Standard
    /// estimator) and SGD learning-rate autotune probes.
    pub solver_secs: f64,
    /// Epochs across all solves this run (same coverage as `solver_secs`).
    pub total_epochs: f64,
    pub sgd_lr_used: f64,
    /// Recovery events this run (all zero unless a fault plan is armed
    /// and fired; `total_epochs` already includes the wasted epochs).
    pub recovery: RecoveryStats,
}

pub struct Trainer {
    pub opts: TrainerOptions,
    op: Box<dyn KernelOperator>,
    y_train: Vec<f64>,
    y_test: Vec<f64>,
    solver: Box<dyn LinearSolver>,
    probes: ProbeSet,
    params: SoftplusParams,
    adam: Adam,
    rng: Rng,
    /// Warm-start store: previous raw-space solution [n, s+1].
    v_store: Mat,
    solve_opts: SolveOptions,
    sgd_lr_resolved: Option<f64>,
    /// Coordinator-owned preconditioner store, injected into the solver so
    /// factorisations are shared across training, prediction and
    /// evaluation solves.
    precond: SharedPreconditionerCache,
    /// Posterior-snapshot store for the serving path, keyed on
    /// (tenant, hyperparameter bits, n): `evaluate` publishes the state it
    /// just computed, `posterior_artifact` serves from it without
    /// re-solving.  Private by default; a fleet swaps in its shared cache
    /// via [`Trainer::set_artifact_cache`].
    artifacts: SharedArtifactCache,
    /// This trainer's id inside its artifact cache (0 until a fleet
    /// assigns one) — entries and counters are attributed per tenant.
    tenant: TenantId,
    /// Lifetime solver-work accounting (epochs / wall seconds across every
    /// solve, including prediction, evaluation and autotune probes).
    /// `run` reports per-run deltas of these.
    spent_epochs: f64,
    spent_solver_secs: f64,
    /// Outer steps completed over the trainer's lifetime (survives
    /// checkpoint/restore; drives cold-start probe resampling).
    step_count: u64,
    /// Metered solves over the trainer's lifetime (training, prediction,
    /// evaluation re-solves) — regression tests assert redundant solves
    /// stay gone.
    solve_count: u64,
    /// Training size at construction.  A checkpoint with fewer rows than
    /// this cannot be an earlier state of *this* dataset (restore rejects
    /// it as a wrong-dataset mixup instead of silently zero-padding).
    base_n: usize,
    /// Fault-injection plan + recovery accounting.  Unarmed (the default)
    /// every hook below is a cold `is_none` check and the solve path is
    /// byte-for-byte the historical one.
    supervisor: Supervisor,
}

impl Trainer {
    pub fn new(opts: TrainerOptions, mut op: Box<dyn KernelOperator>, ds: &Dataset) -> Self {
        let mut rng = Rng::new(opts.seed ^ 0x16_97);
        let d = op.d();
        let theta0 = vec![opts.init_theta; d + 2];
        let params = SoftplusParams::from_theta(&theta0);
        let hp = crate::kernels::Hyperparams::unpack(&theta0, d);
        op.set_hp(&hp);
        let probes = ProbeSet::sample(opts.estimator, op.as_ref(), &mut rng);
        let adam = Adam::new(d + 2, opts.lr);
        let v_store = Mat::zeros(op.n(), op.s() + 1);
        let block = opts.block_size.unwrap_or_else(|| preferred_block(op.as_ref()));
        let solve_opts = SolveOptions {
            tolerance: opts.tolerance,
            max_epochs: opts.max_epochs.unwrap_or(opts.epoch_cap),
            precond_rank: opts.precond_rank,
            // block-Jacobi preconditioning stays opt-in at the solver
            // layer: the trainer's telemetry must not depend on how the
            // *operator* is sharded
            precond_shards: 0,
            block_size: block,
            sgd_lr: opts.sgd_lr.unwrap_or(0.0), // resolved on first step
            sgd_momentum: 0.9,
            sgd_polyak: false,
            sgd_backoff: true,
            ap_selection: crate::solvers::ApSelection::Greedy,
            threads: opts.threads,
            ap_block_precond: opts.ap_precond,
            precision: opts.precision,
            drift_ratio: 8.0,
        };
        let mut solver = make_solver(opts.solver);
        let precond: SharedPreconditionerCache = PreconditionerCache::shared();
        solver.set_precond_cache(precond.clone());
        let base_n = op.n();
        Trainer {
            opts,
            op,
            y_train: ds.y_train.clone(),
            y_test: ds.y_test.clone(),
            solver,
            probes,
            params,
            adam,
            rng,
            v_store,
            solve_opts,
            sgd_lr_resolved: None,
            precond,
            artifacts: std::sync::Arc::new(ArtifactCache::default()),
            tenant: 0,
            spent_epochs: 0.0,
            spent_solver_secs: 0.0,
            step_count: 0,
            solve_count: 0,
            base_n,
            supervisor: Supervisor::default(),
        }
    }

    /// Arm deterministic fault injection (the `--chaos` path).  Recovery
    /// policies activate with the plan; unarmed trainers never touch them.
    pub fn arm_faults(&mut self, plan: Arc<FaultPlan>) {
        self.supervisor.arm(plan);
    }

    /// Lifetime recovery counters (all zero unless faults were armed and
    /// fired).  `run` reports per-run deltas of the same counters.
    pub fn recovery_stats(&self) -> RecoveryStats {
        self.supervisor.stats
    }

    /// Initialise hyperparameters from values (e.g. the paper's
    /// subset-heuristic for large datasets) instead of the constant init.
    pub fn set_init_theta(&mut self, theta: &[f64]) {
        self.params = SoftplusParams::from_theta(theta);
        let hp = crate::kernels::Hyperparams::unpack(theta, self.op.d());
        self.op.set_hp(&hp);
    }

    pub fn theta(&self) -> Vec<f64> {
        self.params.theta()
    }

    pub fn operator(&self) -> &dyn KernelOperator {
        self.op.as_ref()
    }

    /// The warm-start store (last solved batch, raw space).
    pub fn v_store(&self) -> &Mat {
        &self.v_store
    }

    /// The estimator's probe state (for experiment diagnostics).
    pub fn probes(&self) -> &ProbeSet {
        &self.probes
    }

    /// The coordinator-owned preconditioner cache (diagnostics / tests).
    pub fn precond_cache(&self) -> &PreconditionerCache {
        &self.precond
    }

    /// The posterior-snapshot cache (diagnostics / serve counters).
    pub fn artifact_cache(&self) -> &ArtifactCache {
        &self.artifacts
    }

    /// This trainer's tenant id inside its artifact cache (0 = private /
    /// unassigned).
    pub fn tenant(&self) -> TenantId {
        self.tenant
    }

    /// Join a shared artifact cache under `tenant`: the private cache's
    /// entries and per-tenant counters migrate (nothing is re-counted as
    /// a build), so a trainer can be promoted into a fleet mid-life
    /// without losing its snapshots or its accounting.
    pub fn set_artifact_cache(&mut self, cache: SharedArtifactCache, tenant: TenantId) {
        let old = std::mem::replace(&mut self.artifacts, cache);
        self.tenant = tenant;
        self.artifacts.absorb(tenant, &old);
    }

    /// One metered solve: every epoch and second of solver work anywhere
    /// in the trainer goes through here so nothing is dropped from the
    /// reported totals.
    fn timed_solve(&mut self, b: &Mat, v: &mut Mat) -> SolveReport {
        let t = Instant::now();
        let report = self.solver.solve(self.op.as_ref(), b, v, &self.solve_opts);
        self.spent_solver_secs += t.elapsed().as_secs_f64();
        self.spent_epochs += report.epochs;
        self.solve_count += 1;
        report
    }

    /// One supervised solve attempt: draw this attempt's fault sites from
    /// the armed plan (each a fresh opportunity), then run the — possibly
    /// corrupted — metered solve.  A solver-site hit synthesises a
    /// stall/divergence: the attempt burns its full epoch budget and
    /// reports non-finite residuals without touching `v`.
    fn supervised_attempt(&mut self, b: &Mat, v: &mut Mat) -> SolveReport {
        let stall = self.supervisor.fires(FaultSite::Solver);
        let panel = self.supervisor.fires(FaultSite::Panel);
        let shard = self.supervisor.fires(FaultSite::Shard);
        let precond = self.supervisor.fires(FaultSite::Precond);
        if stall {
            let epochs = self.solve_opts.max_epochs;
            self.spent_epochs += epochs;
            return SolveReport {
                iterations: 0,
                epochs,
                ry: f64::NAN,
                rz: f64::NAN,
                converged: false,
                init_residual_sq: f64::NAN,
            };
        }
        if panel || shard || precond {
            if let Some(plan) = self.supervisor.plan().cloned() {
                let t = Instant::now();
                let view = ChaosOpView::new(self.op.as_ref(), &plan, panel, shard, precond);
                let mut report = self.solver.solve(&view, b, v, &self.solve_opts);
                if view.consumed() {
                    // the corruption entered a product: reject the attempt
                    // outright — a corrupted intermediate can steer a
                    // solver to a finite-but-wrong answer that residual
                    // finiteness alone would accept
                    report.ry = f64::NAN;
                    report.rz = f64::NAN;
                    report.converged = false;
                }
                self.spent_solver_secs += t.elapsed().as_secs_f64();
                self.spent_epochs += report.epochs;
                self.solve_count += 1;
                return report;
            }
        }
        self.timed_solve(b, v)
    }

    /// The supervised solve path.  Unarmed it *is* [`Trainer::timed_solve`]
    /// — no clone, no wrapper, no extra branch inside the solver — which is
    /// what keeps the bitwise-parity suites byte-identical.  Armed, it
    /// drives the recovery ladder: bounded retry (quarantining cached
    /// factorisations and restoring the warm start between attempts), then
    /// the cross-solver CG-f64 fallback, then a typed
    /// [`FaultError::SolveFailed`] with the warm-start store left at its
    /// pre-solve state.
    fn supervised_solve(&mut self, b: &Mat, v: &mut Mat) -> Result<SolveReport> {
        if !self.supervisor.armed() {
            return Ok(self.timed_solve(b, v));
        }
        const RETRIES: u32 = 3;
        let v0 = v.clone();
        for _ in 0..RETRIES {
            let report = self.supervised_attempt(b, v);
            if solve_is_finite(&report) && mat_finite(v) {
                return Ok(report);
            }
            // discard the attempt: meter the waste, quarantine every
            // cached factorisation the corrupted products may have
            // poisoned (the retry rebuilds them deterministically from
            // the same (theta, n) key), restore the warm start
            self.supervisor.stats.retries += 1;
            self.supervisor.stats.wasted_epochs += report.epochs;
            self.precond.invalidate_all();
            self.supervisor.stats.cache_rebuilds += 1;
            *v = v0.clone();
        }
        // cross-solver fallback: a fresh CG solver on the f64 reference
        // path, swapped in so the attempt machinery — and the fault
        // schedule — applies to it like any other attempt
        let mut fb = make_solver(SolverKind::Cg);
        fb.set_precond_cache(self.precond.clone());
        let fb_opts = SolveOptions { precision: Precision::F64, ..self.solve_opts.clone() };
        let saved_solver = std::mem::replace(&mut self.solver, fb);
        let saved_opts = std::mem::replace(&mut self.solve_opts, fb_opts);
        let report = self.supervised_attempt(b, v);
        self.solver = saved_solver;
        self.solve_opts = saved_opts;
        if solve_is_finite(&report) && mat_finite(v) {
            self.supervisor.stats.fallback_solves += 1;
            return Ok(report);
        }
        self.supervisor.stats.wasted_epochs += report.epochs;
        *v = v0.clone();
        Err(FaultError::SolveFailed {
            solver: self.opts.solver.name(),
            step: self.step_count,
            attempts: RETRIES + 1,
        }
        .into())
    }

    /// Pre-step optimiser snapshot for the rollback guard (armed only —
    /// unarmed runs never pay the clones).
    fn adam_snapshot(&self) -> Option<(Vec<f64>, Vec<f64>, Vec<f64>, u64)> {
        if !self.supervisor.armed() {
            return None;
        }
        let (m, v, t) = self.adam.state();
        Some((self.params.nu.clone(), m.to_vec(), v.to_vec(), t))
    }

    /// Post-Adam guard: if the ascent produced a non-finite hyperparameter
    /// state (a corrupt gradient slipped every earlier guard), restore the
    /// snapshot — the last finite checkpointed state — and keep training.
    /// Returns whether a rollback happened.
    fn rollback_if_nonfinite(
        &mut self,
        snapshot: Option<(Vec<f64>, Vec<f64>, Vec<f64>, u64)>,
    ) -> bool {
        let (nu0, m0, v0, t0) = match snapshot {
            Some(s) => s,
            None => return false,
        };
        if slice_finite(&self.params.nu) {
            return false;
        }
        self.params.nu = nu0;
        self.adam.restore_state(m0, v0, t0);
        self.supervisor.stats.rollbacks += 1;
        true
    }

    /// Metered solves over the trainer's lifetime (tests / diagnostics).
    pub fn solve_count(&self) -> u64 {
        self.solve_count
    }

    /// Epochs spent across every metered solve over the trainer's lifetime
    /// (serve telemetry: lets a service report what its artifact refreshes
    /// cost; `run` reports per-run deltas of the same counter).
    pub fn total_spent_epochs(&self) -> f64 {
        self.spent_epochs
    }

    /// Test targets (for experiment-side metric recomputation).
    pub fn y_test(&self) -> &[f64] {
        &self.y_test
    }

    /// Snapshot the resumable training state at the current
    /// completed-step count (the counter controls cold-start probe
    /// resampling after a restore, so it is read from the trainer rather
    /// than trusted to the caller).
    pub fn checkpoint(&self) -> checkpoint::Checkpoint {
        let (m, v, t) = self.adam.state();
        checkpoint::Checkpoint {
            step: self.step_count,
            seed: self.opts.seed,
            nu: self.params.nu.clone(),
            adam_m: m.to_vec(),
            adam_v: v.to_vec(),
            adam_t: t,
            v_store: self.v_store.clone(),
            rng: Some(self.rng.state()),
            sgd_lr: self.sgd_lr_resolved,
        }
    }

    /// Persist a checkpoint to `path` (v3 on-disk format, content
    /// checksummed).  With an armed plan whose `checkpoint` site fires,
    /// the written bytes are deterministically corrupted (truncation or a
    /// bit-flip) to model a torn write — the v3 checksum turns the *next
    /// load* into a typed error instead of a garbage resume, so callers
    /// keeping their previous good file roll back durably.
    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        let mut bytes = self.checkpoint().file_bytes();
        if self.supervisor.armed() && self.supervisor.fires(FaultSite::Checkpoint) {
            if let Some(plan) = self.supervisor.plan() {
                plan.corrupt_bytes(&mut bytes);
            }
        }
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, bytes)?;
        Ok(())
    }

    /// Resume from a checkpoint: hyperparameters, Adam moments, the
    /// warm-start store, the completed-step counter, the resolved SGD
    /// learning rate (so a resumed SGD run does not re-autotune at the
    /// sharpened hyperparameters) and — when present — the trainer RNG
    /// mid-stream state, so runs that keep drawing randomness after the
    /// restore point (cold starts resample probes every step) continue
    /// the exact sequence.  The *initial* probe set is reconstructed from
    /// the seed by `Trainer::new`; cold-start resumes replace it on the
    /// first resumed step.
    ///
    /// Limitation: solver-*internal* randomness (SGD's minibatch stream,
    /// AP's `Random`/`Cyclic` selection state) is not serialised, so those
    /// modes resume correctly but not bit-reproducibly; CG and greedy AP
    /// are RNG-free and reproduce exactly.
    ///
    /// Resize-aware (online data arrival): a checkpoint taken at a
    /// *smaller* n than the trainer currently holds — but no smaller than
    /// the trainer's initial dataset, so it can genuinely be an earlier
    /// state of this run — restores with its missing warm-start rows
    /// zero-padded: exactly the state [`Trainer::extend_data`] would have
    /// produced had the extension happened after the checkpoint.  A
    /// checkpoint taken at a *larger* n is an error: the trainer has
    /// never seen that data, so the caller must replay the arrival chunks
    /// (`extend_data`) before restoring.  A checkpoint smaller than the
    /// construction-time n (wrong dataset) and a probe-width mismatch are
    /// always incompatible.
    pub fn restore(&mut self, ck: &checkpoint::Checkpoint) -> Result<()> {
        anyhow::ensure!(
            ck.nu.len() == self.params.nu.len(),
            "checkpoint has {} hyperparameters but the trainer has {}",
            ck.nu.len(),
            self.params.nu.len()
        );
        anyhow::ensure!(
            ck.v_store.cols == self.v_store.cols,
            "checkpoint solve width {} does not match the trainer's {} (probe count changed?)",
            ck.v_store.cols,
            self.v_store.cols
        );
        anyhow::ensure!(
            ck.v_store.rows <= self.v_store.rows,
            "checkpoint was taken at n = {} but the trainer holds only n = {} training rows; \
             replay the arrival chunks with extend_data before restoring",
            ck.v_store.rows,
            self.v_store.rows
        );
        // zero-padding is only meaningful for rows that *arrived after*
        // the checkpoint — a checkpoint smaller than the trainer's initial
        // dataset belongs to some other dataset
        anyhow::ensure!(
            ck.v_store.rows >= self.base_n,
            "checkpoint was taken at n = {} but this trainer started with n = {} training rows \
             (checkpoint from a different dataset?)",
            ck.v_store.rows,
            self.base_n
        );
        self.params.nu = ck.nu.clone();
        self.adam.restore_state(ck.adam_m.clone(), ck.adam_v.clone(), ck.adam_t);
        // row-major: the checkpointed rows are the prefix; rows that
        // arrived after the checkpoint warm-start from zero
        let mut v = Mat::zeros(self.v_store.rows, self.v_store.cols);
        v.data[..ck.v_store.data.len()].copy_from_slice(&ck.v_store.data);
        self.v_store = v;
        self.step_count = ck.step;
        if let Some(st) = &ck.rng {
            self.rng = Rng::from_state(st);
        }
        if let Some(lr) = ck.sgd_lr {
            self.solve_opts.sgd_lr = lr;
            self.sgd_lr_resolved = Some(lr);
        }
        let theta = self.params.theta();
        let hp = crate::kernels::Hyperparams::unpack(&theta, self.op.d());
        self.op.set_hp(&hp);
        Ok(())
    }

    /// Online data arrival: append `x_new`/`y_new` to the training set and
    /// carry every piece of coordinator state across the growth instead of
    /// cold-restarting — the warm-start asset the paper builds across
    /// outer steps survives across *arrivals* too.
    ///
    /// * the operator appends the rows under the current hyperparameters
    ///   (dense: rank-extends its cached H in O(n·n_new); tiled: O(n_new·d)
    ///   re-tile; static-shape XLA artifacts return an error untouched);
    /// * the warm-start store gains zero rows — solved values for the
    ///   original rows are kept;
    /// * the probe set gains fresh `z`/noise rows from a stream derived
    ///   from (seed, old n, new n); `omega0`/`wts` are reused, so pathwise
    ///   targets on the original rows are unchanged under fixed
    ///   hyperparameters, and the trainer RNG stream is untouched —
    ///   replaying the same chunk schedule after a checkpoint restore
    ///   reproduces the run exactly;
    /// * every cached preconditioner factorisation is dropped (all were
    ///   built for the old n; the n in the cache key already prevents
    ///   wrong reuse, invalidation frees the memory);
    /// * an auto-derived block size (`TrainerOptions::block_size = None`)
    ///   is re-derived for the new n; an explicit block size is kept
    ///   (AP covers any remainder with a ragged tail block).
    pub fn extend_data(&mut self, x_new: &Mat, y_new: &[f64]) -> Result<()> {
        anyhow::ensure!(
            x_new.rows == y_new.len(),
            "extend_data: {} input rows but {} targets",
            x_new.rows,
            y_new.len()
        );
        anyhow::ensure!(x_new.rows > 0, "extend_data: empty chunk");
        anyhow::ensure!(
            x_new.cols == self.op.d(),
            "extend_data: chunk has d = {} but the model has d = {}",
            x_new.cols,
            self.op.d()
        );
        let n0 = self.op.n();
        self.op.extend(x_new)?;
        let n1 = self.op.n();
        self.y_train.extend_from_slice(y_new);
        let mut chunk_rng = Rng::new(
            self.opts.seed
                ^ 0x0E11
                ^ (n0 as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ (n1 as u64).wrapping_mul(0xBF58476D1CE4E5B9),
        );
        self.probes.extend_rows(x_new.rows, &mut chunk_rng);
        self.v_store.append_rows(&Mat::zeros(x_new.rows, self.v_store.cols));
        self.precond.invalidate_all();
        // every posterior snapshot of THIS tenant was taken at the old n:
        // the serving path must refresh (one warm solve) before answering
        // the next query; co-tenants of a shared cache are unaffected
        self.artifacts.invalidate_tenant(self.tenant);
        if self.opts.block_size.is_none() {
            self.solve_opts.block_size = preferred_block(self.op.as_ref());
        }
        Ok(())
    }

    /// Run `steps` outer-loop iterations.
    pub fn run(&mut self, steps: usize) -> Result<TrainOutcome> {
        let t_total = Instant::now();
        let mut telemetry = Vec::with_capacity(steps);
        // totals are deltas of the lifetime spend counters, so *every*
        // solve in this run — training, prediction, evaluation re-solves,
        // autotune probes — is accounted
        let epochs0 = self.spent_epochs;
        let secs0 = self.spent_solver_secs;
        let recovery0 = self.supervisor.stats;

        for step in 0..steps {
            let t_step = Instant::now();
            // position the fault schedule at this outer step (no-op unarmed)
            self.supervisor.set_step(self.step_count);
            let theta = self.params.theta();
            let hp = crate::kernels::Hyperparams::unpack(&theta, self.op.d());
            self.op.set_hp(&hp);

            // (re)sample probes unless warm starting (targets must be
            // frozen for warm starts; Section 4).  `step_count` counts
            // completed steps over the trainer's lifetime, so a restored
            // run resamples exactly where the uninterrupted run would.
            if !self.opts.warm_start && self.step_count > 0 {
                self.probes = ProbeSet::sample(self.opts.estimator, self.op.as_ref(), &mut self.rng);
            }
            let mut b = self.probes.targets(self.op.as_ref(), &self.y_train);
            if self.supervisor.armed() {
                if self.supervisor.fires(FaultSite::Probe) {
                    if let Some(plan) = self.supervisor.plan() {
                        let r = plan.target_row(b.rows);
                        for x in b.row_mut(r) {
                            *x = f64::NAN;
                        }
                    }
                }
                if !mat_finite(&b) {
                    // probe targets are a pure function of the frozen
                    // probe state — recompute from scratch, and only fail
                    // typed if the corruption persists
                    b = self.probes.targets(self.op.as_ref(), &self.y_train);
                    if !mat_finite(&b) {
                        return Err(FaultError::ProbeCorrupt { step: self.step_count }.into());
                    }
                    self.supervisor.stats.target_repairs += 1;
                }
            }

            // SGD learning-rate auto-tune on the first step (paper
            // protocol); the probe epochs are real solver work and are
            // charged against the totals
            if self.opts.solver == SolverKind::Sgd && self.sgd_lr_resolved.is_none() {
                let lr = match self.opts.sgd_lr {
                    Some(lr) => lr,
                    None => {
                        let t_tune = Instant::now();
                        let (lr, probe_epochs) = autotune_lr(
                            self.op.as_ref(),
                            &b,
                            &self.solve_opts,
                            &[1.0, 2.0, 4.0, 8.0, 12.0, 16.0, 24.0, 32.0, 48.0, 64.0],
                            self.opts.sgd_lr_halve,
                        );
                        self.spent_solver_secs += t_tune.elapsed().as_secs_f64();
                        self.spent_epochs += probe_epochs;
                        lr
                    }
                };
                self.solve_opts.sgd_lr = lr;
                self.sgd_lr_resolved = Some(lr);
            }

            // inner solve (warm start from the stored solution)
            let mut v = if self.opts.warm_start {
                self.v_store.clone()
            } else {
                Mat::zeros(self.op.n(), self.op.s() + 1)
            };
            let secs_before = self.spent_solver_secs;
            let report = self.supervised_solve(&b, &mut v)?;
            let solve_elapsed = self.spent_solver_secs - secs_before;
            if self.opts.warm_start {
                self.v_store = v.clone();
            }

            // gradient estimate + Adam ascent
            let grad_theta = self.probes.grad(self.op.as_ref(), &v, &b);
            let grad_nu = self.params.chain_grad(&grad_theta);
            let snapshot = self.adam_snapshot();
            self.adam.step(&mut self.params.nu, &grad_nu);
            self.rollback_if_nonfinite(snapshot);

            let exact_mll = if self.opts.track_exact {
                self.op.exact_mll(&self.y_train).map(|(l, _)| l)
            } else {
                None
            };
            let step_metrics = match self.opts.predict_every {
                Some(k) if (step + 1) % k == 0 => Some(self.evaluate(Some(&v))?),
                _ => None,
            };

            telemetry.push(StepTelemetry {
                step,
                theta,
                grad: grad_theta,
                ry: report.ry,
                rz: report.rz,
                iterations: report.iterations,
                epochs: report.epochs,
                solver_secs: solve_elapsed,
                step_secs: t_step.elapsed().as_secs_f64(),
                converged: report.converged,
                init_residual_sq: report.init_residual_sq,
                exact_mll,
                metrics: step_metrics,
            });
            self.step_count += 1;
        }

        // final prediction: set final hyperparameters, make sure we have a
        // solved system for them
        let theta = self.params.theta();
        let hp = crate::kernels::Hyperparams::unpack(&theta, self.op.d());
        self.op.set_hp(&hp);
        let final_metrics = match self.opts.estimator {
            // Standard: `evaluate` ignores any solved batch and re-solves
            // a pathwise system itself, so the prediction solve here was a
            // full metered solve whose result was discarded — skip it.
            // (The skipped solve also used to refresh `v_store` at the
            // final theta when warm starting; dropping that is epoch-
            // neutral — a subsequent `run` pays the same work in its
            // first step that the tail would have paid here — and a
            // strict saving whenever no run follows.)
            EstimatorKind::Standard => self.evaluate(None)?,
            EstimatorKind::Pathwise => {
                let final_v = self.solve_for_prediction()?;
                self.evaluate(Some(&final_v))?
            }
        };

        Ok(TrainOutcome {
            telemetry,
            theta,
            final_metrics,
            total_secs: t_total.elapsed().as_secs_f64(),
            solver_secs: self.spent_solver_secs - secs0,
            total_epochs: self.spent_epochs - epochs0,
            sgd_lr_used: self.sgd_lr_resolved.unwrap_or(0.0),
            recovery: self.supervisor.stats.delta_since(&recovery0),
        })
    }

    /// Solve the current system for prediction purposes (amortised for the
    /// warm-started pathwise estimator: the stored solution is reused).
    /// The solve is metered like any other — its epochs and wall time land
    /// in the reported totals.
    fn solve_for_prediction(&mut self) -> Result<Mat> {
        let b = self.probes.targets(self.op.as_ref(), &self.y_train);
        let mut v = if self.opts.warm_start {
            self.v_store.clone()
        } else {
            Mat::zeros(self.op.n(), self.op.s() + 1)
        };
        let _report = self.supervised_solve(&b, &mut v)?;
        if self.opts.warm_start {
            self.v_store = v.clone();
        }
        Ok(v)
    }

    /// Test metrics via pathwise conditioning (eq. 16).
    ///
    /// Pathwise estimator: the solved probe columns *are* zhat — prediction
    /// is amortised, and `v` (the solved batch) is required.  Standard
    /// estimator: the probes are not posterior samples, so an extra batch
    /// of pathwise solves is run and `v` is ignored (this is exactly the
    /// amortisation gap the paper quantifies) — callers pass `None` so no
    /// solve is wasted producing an input this path throws away.
    ///
    /// The posterior state computed here is published in the artifact
    /// cache, so a [`Trainer::posterior_artifact`] call at the same
    /// hyperparameters (the serving path) reuses it — bitwise — without
    /// another solve.
    fn evaluate(&mut self, v: Option<&Mat>) -> Result<Metrics> {
        let art = self.build_artifact(v)?;
        let (mean, samples) = self.op.predict(&art.vy, &art.zhat, &art.omega0, &art.wts);
        let var = pathwise_variances(&samples, art.noise_var);
        Ok(metrics(&mean, &var, &self.y_test))
    }

    /// Build the amortised posterior snapshot at the operator's current
    /// hyperparameters — from the solved batch `v` (pathwise) or a fresh
    /// metered evaluation solve (standard) — and publish it in the
    /// artifact cache.
    fn build_artifact(&mut self, v: Option<&Mat>) -> Result<Arc<PosteriorArtifact>> {
        let (zhat, omega0, wts, vy) = match self.opts.estimator {
            EstimatorKind::Pathwise => {
                let v = match v {
                    Some(v) => v,
                    None => anyhow::bail!("pathwise evaluation needs the solved batch"),
                };
                (
                    self.probes.zhat(v),
                    self.probes.omega0.clone(),
                    self.probes.wts.clone(),
                    v.col(0),
                )
            }
            EstimatorKind::Standard => {
                // extra pathwise solves for posterior samples — this is
                // exactly the amortisation gap the paper quantifies, so
                // the work is metered into the totals like any solve.
                // The probes come from a stream derived from (seed, step
                // count) instead of the trainer RNG: evaluation must not
                // advance the training stream, or a checkpoint taken
                // after `run` (whose tail always evaluates) would resume
                // on a different random sequence than the uninterrupted
                // run at the same step.
                let mut eval_rng = Rng::new(
                    self.opts.seed ^ 0xE7A1 ^ self.step_count.wrapping_mul(0x9E3779B97F4A7C15),
                );
                let pw = ProbeSet::sample(EstimatorKind::Pathwise, self.op.as_ref(), &mut eval_rng);
                let b = pw.targets(self.op.as_ref(), &self.y_train);
                let mut vs = Mat::zeros(self.op.n(), self.op.s() + 1);
                let _ = self.supervised_solve(&b, &mut vs)?;
                (pw.zhat(&vs), pw.omega0.clone(), pw.wts.clone(), vs.col(0))
            }
        };
        let art = Arc::new(PosteriorArtifact {
            theta: self.op.hp().pack(),
            n: self.op.n(),
            vy,
            zhat,
            omega0,
            wts,
            noise_var: self.op.hp().noise_var(),
        });
        self.artifacts.insert(self.tenant, self.op.hp(), self.op.n(), art.clone());
        Ok(art)
    }

    /// The amortised posterior snapshot at the *current* hyperparameters
    /// and data — the export point of the serving subsystem
    /// ([`crate::serve::PredictionService`] answers every query from it).
    ///
    /// Served from the artifact cache when one was already built at this
    /// (theta, n) — e.g. by the evaluation `run`'s tail always performs —
    /// so repeated serve/refresh cycles never re-solve.  On a miss (fresh
    /// hyperparameters, or data grown by [`Trainer::extend_data`]), one
    /// solve refreshes it: warm-started from the carried `v_store` for the
    /// pathwise estimator, so an online arrival costs a warm solve rather
    /// than a cold restart.  The solve is metered like any other.
    pub fn posterior_artifact(&mut self) -> Result<Arc<PosteriorArtifact>> {
        let theta = self.params.theta();
        let hp = crate::kernels::Hyperparams::unpack(&theta, self.op.d());
        if let Some(art) = self.artifacts.get(self.tenant, &hp, self.op.n()) {
            if self.supervisor.armed() && self.supervisor.fires(FaultSite::Cache) {
                // cache-poisoning injection: replace the published entry
                // with a non-finite clone and serve that — downstream
                // validation (`PredictionService::fetch_artifact`) must
                // quarantine the tenant's entries and rebuild
                let mut bad = (*art).clone();
                for x in &mut bad.vy {
                    *x = f64::NAN;
                }
                let bad = Arc::new(bad);
                self.artifacts.insert(self.tenant, &hp, self.op.n(), bad.clone());
                return Ok(bad);
            }
            return Ok(art);
        }
        self.op.set_hp(&hp);
        match self.opts.estimator {
            EstimatorKind::Pathwise => {
                let v = self.solve_for_prediction()?;
                self.build_artifact(Some(&v))
            }
            EstimatorKind::Standard => self.build_artifact(None),
        }
    }
}

/// A solve attempt is accepted when its residuals are finite (budget-capped
/// non-converged reports pass — censoring is not a fault); the supervisor
/// additionally requires the solution batch itself to scan finite.
fn solve_is_finite(report: &SolveReport) -> bool {
    report.ry.is_finite() && report.rz.is_finite()
}

fn preferred_block(op: &dyn KernelOperator) -> usize {
    // XlaOperator's artifact fixes b; DenseOperator accepts anything.
    // Encode the convention n/16 bounded to [32, 256]; the XLA path
    // overrides via TrainerOptions.block_size = meta.b.  Non-dividing
    // blocks are fine — AP covers the remainder with a ragged tail block
    // (online arrivals make arbitrary n routine).
    (op.n() / 16).clamp(32, 256).min(op.n().max(1))
}

// ---------------------------------------------------------------------------
// Exact-optimisation baseline (Figs 5, 8, 11-13)
// ---------------------------------------------------------------------------

/// Run exact (Cholesky) marginal-likelihood optimisation with the same
/// Adam/softplus outer loop, via the backend's exact path.
pub fn run_exact(
    op: &mut dyn KernelOperator,
    y: &[f64],
    steps: usize,
    lr: f64,
    init_theta: f64,
) -> Result<Vec<(Vec<f64>, f64)>> {
    let d = op.d();
    let mut params = SoftplusParams::from_theta(&vec![init_theta; d + 2]);
    let mut adam = Adam::new(d + 2, lr);
    let mut traj = Vec::with_capacity(steps);
    for _ in 0..steps {
        let theta = params.theta();
        op.set_hp(&crate::kernels::Hyperparams::unpack(&theta, d));
        let (mll, grad) = op
            .exact_mll(y)
            .ok_or_else(|| anyhow::anyhow!("backend has no exact MLL path"))?;
        traj.push((theta, mll));
        let grad_nu = params.chain_grad(&grad);
        adam.step(&mut params.nu, &grad_nu);
    }
    Ok(traj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::operators::DenseOperator;

    fn trainer(solver: SolverKind, estimator: EstimatorKind, warm: bool) -> (Trainer, Dataset) {
        let ds = data::generate(&data::spec("test").unwrap());
        let op = DenseOperator::new(&ds, 8, 32);
        let opts = TrainerOptions {
            solver,
            estimator,
            warm_start: warm,
            lr: 0.1,
            epoch_cap: 200.0,
            block_size: Some(64),
            sgd_lr: Some(8.0),
            seed: 7,
            ..Default::default()
        };
        (Trainer::new(opts, Box::new(op), &ds), ds)
    }

    #[test]
    fn rollback_restores_last_finite_state_and_is_counted() {
        let (mut t, _ds) = trainer(SolverKind::Cg, EstimatorKind::Pathwise, true);
        t.arm_faults(Arc::new(FaultPlan::parse("seed=3").unwrap()));
        let snapshot = t.adam_snapshot();
        let nu0 = t.params.nu.clone();
        let (m0, v0, t0) = {
            let (m, v, tt) = t.adam.state();
            (m.to_vec(), v.to_vec(), tt)
        };
        // a poisoned ascent: non-finite hyperparameter state
        t.params.nu[0] = f64::NAN;
        assert!(t.rollback_if_nonfinite(snapshot));
        assert_eq!(t.params.nu, nu0);
        let (m1, v1, t1) = t.adam.state();
        assert_eq!((m1, v1, t1), (&m0[..], &v0[..], t0));
        assert_eq!(t.recovery_stats().rollbacks, 1);
        // finite state: the guard is a no-op
        let snapshot = t.adam_snapshot();
        assert!(!t.rollback_if_nonfinite(snapshot));
        assert_eq!(t.recovery_stats().rollbacks, 1);
        // unarmed trainers never snapshot, so the guard never fires
        let (t2, _ds) = trainer(SolverKind::Cg, EstimatorKind::Pathwise, true);
        assert!(t2.adam_snapshot().is_none());
    }

    #[test]
    fn armed_but_benign_plan_changes_nothing_and_reports_zero_recovery() {
        let (mut plain, _ds) = trainer(SolverKind::Cg, EstimatorKind::Pathwise, true);
        let (mut armed, _ds) = trainer(SolverKind::Cg, EstimatorKind::Pathwise, true);
        armed.arm_faults(Arc::new(FaultPlan::parse("seed=11").unwrap()));
        let a = plain.run(4).unwrap();
        let b = armed.run(4).unwrap();
        assert_eq!(a.theta, b.theta);
        assert_eq!(b.recovery, RecoveryStats::default());
        assert_eq!(a.total_epochs, b.total_epochs);
    }

    #[test]
    fn scheduled_solver_stall_recovers_bitwise_with_metered_waste() {
        let (mut plain, _ds) = trainer(SolverKind::Cg, EstimatorKind::Pathwise, true);
        let (mut armed, _ds) = trainer(SolverKind::Cg, EstimatorKind::Pathwise, true);
        armed.arm_faults(Arc::new(FaultPlan::parse("seed=5;solver@1").unwrap()));
        let a = plain.run(4).unwrap();
        let b = armed.run(4).unwrap();
        assert_eq!(a.theta, b.theta, "recovered run must converge bitwise");
        assert_eq!(b.recovery.retries, 1);
        assert!(b.recovery.wasted_epochs > 0.0);
        assert!(
            b.total_epochs >= a.total_epochs + b.recovery.wasted_epochs,
            "recovery epochs are charged on top: {} vs {} + {}",
            b.total_epochs,
            a.total_epochs,
            b.recovery.wasted_epochs
        );
        for (ta, tb) in a.telemetry.iter().zip(&b.telemetry) {
            assert_eq!(ta.theta, tb.theta);
            assert_eq!(ta.grad, tb.grad);
            assert_eq!(ta.ry.to_bits(), tb.ry.to_bits());
            assert_eq!(ta.rz.to_bits(), tb.rz.to_bits());
        }
    }

    #[test]
    fn probe_corruption_is_repaired_by_recomputation() {
        let (mut plain, _ds) = trainer(SolverKind::Cg, EstimatorKind::Pathwise, true);
        let (mut armed, _ds) = trainer(SolverKind::Cg, EstimatorKind::Pathwise, true);
        armed.arm_faults(Arc::new(FaultPlan::parse("seed=5;probe@0;probe@2").unwrap()));
        let a = plain.run(4).unwrap();
        let b = armed.run(4).unwrap();
        assert_eq!(a.theta, b.theta);
        assert_eq!(b.recovery.target_repairs, 2);
        assert_eq!(b.recovery.retries, 0);
    }

    #[test]
    fn persistent_fault_exhausts_fallback_into_a_typed_error() {
        let (mut t, _ds) = trainer(SolverKind::Cg, EstimatorKind::Pathwise, true);
        // the solver site stalls every attempt at step 1 — three retries
        // and the CG-f64 fallback all burn out
        t.arm_faults(Arc::new(FaultPlan::parse("seed=5;solver@1x99").unwrap()));
        let err = t.run(4).unwrap_err().to_string();
        assert!(err.contains("solve failed at outer step 1"), "{err}");
        assert!(err.contains("cg-f64 fallback"), "{err}");
        let stats = t.recovery_stats();
        assert_eq!(stats.retries, 3);
        assert_eq!(stats.fallback_solves, 0);
        // the caches survive the failure: the trainer still answers a
        // posterior-artifact request afterwards
        let art = t.posterior_artifact();
        assert!(art.is_err() || slice_finite(&art.unwrap().vy));
    }

    #[test]
    fn save_checkpoint_corruption_yields_typed_load_error_and_durable_rollback() {
        let dir = std::env::temp_dir().join(format!("igp-chaos-ckpt-{}", std::process::id()));
        let good = dir.join("good.ckpt");
        let bad = dir.join("bad.ckpt");
        let (mut t, _ds) = trainer(SolverKind::Cg, EstimatorKind::Pathwise, true);
        t.run(2).unwrap();
        t.save_checkpoint(&good).unwrap();
        // arm a plan whose checkpoint site fires on the very next save
        t.arm_faults(Arc::new(FaultPlan::parse("seed=9;checkpoint@2").unwrap()));
        t.supervisor.set_step(2);
        t.save_checkpoint(&bad).unwrap();
        assert!(checkpoint::Checkpoint::load(&bad).is_err(), "corrupted save must not load");
        // durable rollback: the previous good file still restores
        let ck = checkpoint::Checkpoint::load(&good).unwrap();
        t.restore(&ck).unwrap();
        assert_eq!(t.step_count, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cache_poison_site_publishes_a_nonfinite_artifact() {
        let (mut t, _ds) = trainer(SolverKind::Cg, EstimatorKind::Pathwise, true);
        t.run(2).unwrap();
        // warm the cache at the final theta (run's tail already did), then
        // poison the next cache hit
        let clean = t.posterior_artifact().unwrap();
        assert!(slice_finite(&clean.vy));
        t.arm_faults(Arc::new(FaultPlan::parse("seed=9;cache@2").unwrap()));
        t.supervisor.set_step(2);
        let poisoned = t.posterior_artifact().unwrap();
        assert!(!slice_finite(&poisoned.vy), "cache site must poison the served artifact");
        // quarantine-and-rebuild: invalidating the tenant heals it
        t.artifact_cache().invalidate_tenant(t.tenant());
        let healed = t.posterior_artifact().unwrap();
        assert!(slice_finite(&healed.vy), "rebuild after quarantine must be clean");
        assert_eq!(healed.theta, clean.theta);
    }

    #[test]
    fn training_improves_exact_mll() {
        let (mut t, ds) = trainer(SolverKind::Cg, EstimatorKind::Pathwise, true);
        let op0 = DenseOperator::new(&ds, 8, 32);
        let mll0 = {
            let mut o = op0;
            o.set_hp(&crate::kernels::Hyperparams::ones(4));
            o.exact_mll(&ds.y_train).unwrap().0
        };
        let out = t.run(15).unwrap();
        let mll1 = {
            let mut o = DenseOperator::new(&ds, 8, 32);
            o.set_hp(&crate::kernels::Hyperparams::unpack(&out.theta, 4));
            o.exact_mll(&ds.y_train).unwrap().0
        };
        assert!(mll1 > mll0, "mll {mll0} -> {mll1}");
        assert!(out.final_metrics.llh.is_finite());
    }

    #[test]
    fn warm_start_reduces_total_epochs() {
        let (mut cold, _) = trainer(SolverKind::Ap, EstimatorKind::Standard, false);
        let (mut warm, _) = trainer(SolverKind::Ap, EstimatorKind::Standard, true);
        let out_cold = cold.run(10).unwrap();
        let out_warm = warm.run(10).unwrap();
        assert!(
            out_warm.total_epochs < out_cold.total_epochs,
            "warm {} cold {}",
            out_warm.total_epochs,
            out_cold.total_epochs
        );
    }

    #[test]
    fn pathwise_reduces_epochs_vs_standard_high_precision() {
        // The test dataset has sigma_true = 0.3; after a few steps noise
        // precision rises and the pathwise advantage (eq 14 vs 15) shows.
        let (mut st, _) = trainer(SolverKind::Ap, EstimatorKind::Standard, false);
        let (mut pw, _) = trainer(SolverKind::Ap, EstimatorKind::Pathwise, false);
        let out_st = st.run(12).unwrap();
        let out_pw = pw.run(12).unwrap();
        assert!(
            out_pw.total_epochs <= out_st.total_epochs * 1.1,
            "pathwise {} vs standard {}",
            out_pw.total_epochs,
            out_st.total_epochs
        );
    }

    #[test]
    fn budget_mode_respects_epoch_cap() {
        let ds = data::generate(&data::spec("test").unwrap());
        let op = DenseOperator::new(&ds, 8, 32);
        let opts = TrainerOptions {
            solver: SolverKind::Ap,
            estimator: EstimatorKind::Pathwise,
            warm_start: true,
            max_epochs: Some(3.0),
            block_size: Some(64),
            seed: 1,
            ..Default::default()
        };
        let mut t = Trainer::new(opts, Box::new(op), &ds);
        let out = t.run(5).unwrap();
        for tel in &out.telemetry {
            assert!(tel.epochs <= 3.0 + 1e-9, "{}", tel.epochs);
        }
    }

    #[test]
    fn warm_start_accumulates_progress_under_budget() {
        // Fig 10 phenomenon: with a tiny budget, warm starting drives the
        // residual down across outer steps while cold restarts cannot.
        let mk = |warm| {
            let ds = data::generate(&data::spec("test").unwrap());
            let op = DenseOperator::new(&ds, 8, 32);
            let opts = TrainerOptions {
                solver: SolverKind::Ap,
                estimator: EstimatorKind::Pathwise,
                warm_start: warm,
                max_epochs: Some(2.0),
                block_size: Some(64),
                lr: 0.05,
                seed: 3,
                ..Default::default()
            };
            Trainer::new(opts, Box::new(op), &ds)
        };
        let out_warm = mk(true).run(10).unwrap();
        let out_cold = mk(false).run(10).unwrap();
        let last_warm = out_warm.telemetry.last().unwrap().rz;
        let last_cold = out_cold.telemetry.last().unwrap().rz;
        assert!(last_warm < last_cold, "warm {last_warm} vs cold {last_cold}");
    }

    #[test]
    fn exact_baseline_increases_mll() {
        let ds = data::generate(&data::spec("test").unwrap());
        let mut op = DenseOperator::new(&ds, 8, 32);
        let traj = run_exact(&mut op, &ds.y_train, 10, 0.1, 1.0).unwrap();
        assert!(traj.last().unwrap().1 > traj.first().unwrap().1);
    }

    #[test]
    fn checkpoint_resume_reproduces_training() {
        // run 8 steps straight vs 4 + checkpoint/restore + 4: identical
        // thetas (warm-started, so no mid-run probe resampling).
        let (mut a, _) = trainer(SolverKind::Ap, EstimatorKind::Pathwise, true);
        a.run(8).unwrap();
        let (mut b1, ds) = trainer(SolverKind::Ap, EstimatorKind::Pathwise, true);
        b1.run(4).unwrap();
        let ck = b1.checkpoint();
        let op2 = DenseOperator::new(&ds, 8, 32);
        let opts2 = b1.opts.clone();
        let mut b2 = Trainer::new(opts2, Box::new(op2), &ds);
        b2.restore(&ck).unwrap();
        b2.run(4).unwrap();
        let ta = a.theta();
        let tb = b2.theta();
        for (x, y) in ta.iter().zip(&tb) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn prediction_and_evaluation_solves_are_accounted() {
        // regression: solve_for_prediction discarded its SolveReport and
        // the Standard estimator's extra pathwise solves in evaluate were
        // uncounted, so totals under-reported real work.  The totals must
        // strictly exceed the per-step telemetry sum (final prediction
        // solve + Standard evaluation re-solve are on top of it).
        let (mut t, _) = trainer(SolverKind::Ap, EstimatorKind::Standard, false);
        let out = t.run(4).unwrap();
        let telemetry_epochs: f64 = out.telemetry.iter().map(|tel| tel.epochs).sum();
        assert!(
            out.total_epochs > telemetry_epochs + 1e-9,
            "totals {} must include prediction/evaluation work beyond telemetry {}",
            out.total_epochs,
            telemetry_epochs
        );
        let telemetry_secs: f64 = out.telemetry.iter().map(|tel| tel.solver_secs).sum();
        assert!(out.solver_secs >= telemetry_secs);
    }

    #[test]
    fn autotune_probe_epochs_are_accounted() {
        let ds = data::generate(&data::spec("test").unwrap());
        let mk = |sgd_lr| {
            let op = DenseOperator::new(&ds, 8, 32);
            let opts = TrainerOptions {
                solver: SolverKind::Sgd,
                estimator: EstimatorKind::Pathwise,
                warm_start: true,
                epoch_cap: 200.0,
                block_size: Some(64),
                sgd_lr,
                seed: 7,
                ..Default::default()
            };
            Trainer::new(opts, Box::new(op), &ds)
        };
        // identical run except the None trainer pays for autotune probes
        let out_fixed = mk(Some(8.0)).run(3).unwrap();
        let out_tuned = mk(None).run(3).unwrap();
        let tel_fixed: f64 = out_fixed.telemetry.iter().map(|tel| tel.epochs).sum();
        let tel_tuned: f64 = out_tuned.telemetry.iter().map(|tel| tel.epochs).sum();
        // probes cost >= 1 epoch of extra accounted work relative to the
        // telemetry sum (which excludes them)
        assert!(
            out_tuned.total_epochs - tel_tuned >= out_fixed.total_epochs - tel_fixed + 1.0 - 1e-9,
            "tuned {} (tel {tel_tuned}) vs fixed {} (tel {tel_fixed})",
            out_tuned.total_epochs,
            out_fixed.total_epochs
        );
        assert!(out_tuned.sgd_lr_used > 0.0);
    }

    #[test]
    fn cold_start_checkpoint_resume_reproduces_training() {
        // regression: checkpoints omitted the trainer RNG state, so
        // cold-start runs (which resample probes from that RNG every
        // step) diverged after a restore.  8 straight steps vs
        // 4 + checkpoint/restore + 4 must give identical thetas.
        let (mut a, _) = trainer(SolverKind::Ap, EstimatorKind::Pathwise, false);
        a.run(8).unwrap();
        let (mut b1, ds) = trainer(SolverKind::Ap, EstimatorKind::Pathwise, false);
        b1.run(4).unwrap();
        let ck = b1.checkpoint();
        assert!(ck.rng.is_some(), "checkpoint must carry the RNG state");
        let op2 = DenseOperator::new(&ds, 8, 32);
        let mut b2 = Trainer::new(b1.opts.clone(), Box::new(op2), &ds);
        b2.restore(&ck).unwrap();
        b2.run(4).unwrap();
        for (x, y) in a.theta().iter().zip(&b2.theta()) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn restored_sgd_keeps_autotuned_rate() {
        // the checkpoint carries the resolved SGD learning rate, so a
        // resumed run neither re-autotunes (at sharpened hyperparameters,
        // against the paper's first-step-only protocol) nor re-pays the
        // probe epochs
        let ds = data::generate(&data::spec("test").unwrap());
        let opts = TrainerOptions {
            solver: SolverKind::Sgd,
            estimator: EstimatorKind::Pathwise,
            warm_start: true,
            epoch_cap: 200.0,
            block_size: Some(64),
            sgd_lr: None, // autotune on the first step
            seed: 7,
            ..Default::default()
        };
        let op = DenseOperator::new(&ds, 8, 32);
        let mut t1 = Trainer::new(opts.clone(), Box::new(op), &ds);
        let out1 = t1.run(2).unwrap();
        assert!(out1.sgd_lr_used > 0.0);
        let ck = t1.checkpoint();
        assert_eq!(ck.sgd_lr, Some(out1.sgd_lr_used));

        let op2 = DenseOperator::new(&ds, 8, 32);
        let mut t2 = Trainer::new(opts, Box::new(op2), &ds);
        t2.restore(&ck).unwrap();
        let out2 = t2.run(2).unwrap();
        assert_eq!(out2.sgd_lr_used, out1.sgd_lr_used);
    }

    #[test]
    fn preconditioner_cache_is_shared_across_solves() {
        // With the Standard estimator and per-step metrics, `evaluate`
        // runs an extra pathwise solve at the same hyperparameters as
        // that step's training solve; the coordinator-owned cache must
        // serve it from the existing factorisation instead of rebuilding.
        // (The run() tail no longer issues a redundant prediction solve
        // for Standard, so per-step evaluation is where sharing shows.)
        let ds = data::generate(&data::spec("test").unwrap());
        let op = DenseOperator::new(&ds, 8, 32);
        let opts = TrainerOptions {
            solver: SolverKind::Cg,
            estimator: EstimatorKind::Standard,
            warm_start: true,
            lr: 0.1,
            epoch_cap: 200.0,
            block_size: Some(64),
            predict_every: Some(1),
            seed: 7,
            ..Default::default()
        };
        let mut t = Trainer::new(opts, Box::new(op), &ds);
        let steps = 5;
        let out = t.run(steps).unwrap();
        assert!(out.final_metrics.rmse.is_finite());
        let builds = t.precond_cache().woodbury_builds();
        // one build per distinct theta: one per training step plus the
        // final (post-Adam) theta of the tail evaluation re-solve
        assert!(
            builds <= steps as u64 + 1,
            "cache not shared: {builds} builds for {steps} steps"
        );
        // each step's evaluation re-solve runs at that step's theta and
        // must hit the factorisation the training solve just built
        assert!(
            t.precond_cache().hits() >= steps as u64,
            "evaluation solves should hit the cache ({} hits)",
            t.precond_cache().hits()
        );
    }

    #[test]
    fn telemetry_is_complete() {
        let (mut t, _) = trainer(SolverKind::Sgd, EstimatorKind::Pathwise, true);
        let out = t.run(4).unwrap();
        assert_eq!(out.telemetry.len(), 4);
        for (i, tel) in out.telemetry.iter().enumerate() {
            assert_eq!(tel.step, i);
            assert_eq!(tel.theta.len(), 6);
            assert_eq!(tel.grad.len(), 6);
            assert!(tel.epochs > 0.0);
        }
        assert!(out.sgd_lr_used > 0.0);
    }

    #[test]
    fn standard_estimator_skips_redundant_final_prediction_solve() {
        // regression: the run() tail called solve_for_prediction
        // unconditionally, but the Standard estimator's evaluate ignores
        // the passed batch and re-solves a pathwise system — a full
        // metered solve whose result was discarded.  Exactly one training
        // solve per step plus one evaluation re-solve must remain.
        let steps = 3;
        let (mut t, _) = trainer(SolverKind::Cg, EstimatorKind::Standard, true);
        let out = t.run(steps).unwrap();
        assert_eq!(
            t.solve_count(),
            steps as u64 + 1,
            "the discarded prediction solve is back"
        );
        assert!(out.final_metrics.rmse.is_finite());
        // the pathwise tail still pays its (useful) prediction solve
        let (mut p, _) = trainer(SolverKind::Cg, EstimatorKind::Pathwise, true);
        p.run(steps).unwrap();
        assert_eq!(p.solve_count(), steps as u64 + 1);
    }

    #[test]
    fn posterior_artifact_reuses_the_tail_evaluation_state() {
        // run()'s tail always evaluates, publishing the posterior snapshot
        // at the final theta — a serve-side artifact fetch right after must
        // hit the cache instead of re-solving (the LRU is what makes
        // repeated serve/refresh cycles free)
        for estimator in [EstimatorKind::Pathwise, EstimatorKind::Standard] {
            let (mut t, _) = trainer(SolverKind::Cg, estimator, true);
            t.run(3).unwrap();
            let solves = t.solve_count();
            let hits = t.artifact_cache().hits();
            let art = t.posterior_artifact().unwrap();
            assert_eq!(t.solve_count(), solves, "{estimator:?}: artifact fetch re-solved");
            assert_eq!(t.artifact_cache().hits(), hits + 1);
            assert_eq!(art.theta, t.theta(), "{estimator:?}: artifact theta mismatch");
            assert_eq!(art.n, t.operator().n());
            assert_eq!(art.vy.len(), t.operator().n());
            assert_eq!(art.zhat.rows, t.operator().n());
            // a second fetch is also free
            let art2 = t.posterior_artifact().unwrap();
            assert!(Arc::ptr_eq(&art, &art2));
        }
    }

    #[test]
    fn extend_data_invalidates_the_artifact_and_refreshes_warm() {
        // online arrival: the snapshot is stale (old n); the next fetch
        // must pay exactly one (warm) solve and come back at the new n
        let (_ds, base, chunks) = online_fixture();
        let mut t = online_trainer(&base, true, 7);
        t.run(2).unwrap();
        let art_old = t.posterior_artifact().unwrap();
        assert_eq!(art_old.n, base.spec.n);
        let (x, y) = &chunks[0];
        t.extend_data(x, y).unwrap();
        assert!(t.artifact_cache().is_empty(), "extend_data must invalidate snapshots");
        let solves = t.solve_count();
        let art_new = t.posterior_artifact().unwrap();
        assert_eq!(t.solve_count(), solves + 1, "refresh must cost exactly one solve");
        assert_eq!(art_new.n, base.spec.n + x.rows);
        assert_eq!(art_new.vy.len(), art_new.n);
        // and the refreshed snapshot is immediately cached
        let solves = t.solve_count();
        let again = t.posterior_artifact().unwrap();
        assert!(Arc::ptr_eq(&art_new, &again));
        assert_eq!(t.solve_count(), solves);
    }

    /// Online fixture: the "test" dataset replayed as a 128-row prefix
    /// plus two 64-row arrival chunks.
    fn online_fixture() -> (Dataset, Dataset, Vec<(Mat, Vec<f64>)>) {
        let ds = data::generate(&data::spec("test").unwrap());
        let (base, chunks) = ds.replay_chunks(2);
        // split the 128-row tail once more for two uneven-phase arrivals
        let (x, y) = &chunks[0];
        let half = x.rows / 2;
        let c1 = (
            x.gather_rows(&(0..half).collect::<Vec<_>>()),
            y[..half].to_vec(),
        );
        let c2 = (
            x.gather_rows(&(half..x.rows).collect::<Vec<_>>()),
            y[half..].to_vec(),
        );
        (ds, base, vec![c1, c2])
    }

    fn online_trainer(base: &Dataset, warm: bool, seed: u64) -> Trainer {
        let op = DenseOperator::new(base, 8, 32);
        let opts = TrainerOptions {
            solver: SolverKind::Ap,
            estimator: EstimatorKind::Pathwise,
            warm_start: warm,
            lr: 0.05,
            epoch_cap: 300.0,
            block_size: Some(32),
            seed,
            ..Default::default()
        };
        Trainer::new(opts, Box::new(op), base)
    }

    #[test]
    fn extend_data_grows_every_piece_of_state() {
        let (ds, base, chunks) = online_fixture();
        let mut t = online_trainer(&base, true, 3);
        t.run(2).unwrap();
        let builds_before = t.precond_cache().ap_builds();
        for (x, y) in &chunks {
            t.extend_data(x, y).unwrap();
        }
        assert_eq!(t.operator().n(), ds.spec.n);
        assert_eq!(t.v_store().rows, ds.spec.n);
        assert_eq!(t.probes().z.rows, ds.spec.n);
        assert_eq!(t.probes().noise.rows, ds.spec.n);
        // old warm-start rows carried, new rows zero
        assert!(t.v_store().data[..10].iter().any(|&x| x != 0.0));
        let tail = &t.v_store().data[(ds.spec.n - 64) * t.v_store().cols..];
        assert!(tail.iter().all(|&x| x == 0.0));
        // training continues and rebuilds factorisations for the new n
        let out = t.run(2).unwrap();
        assert!(out.final_metrics.rmse.is_finite());
        assert!(t.precond_cache().ap_builds() > builds_before);
        // shape-mismatched chunks are rejected
        assert!(t.extend_data(&Mat::zeros(2, 3), &[0.0, 0.0]).is_err());
        assert!(t.extend_data(&Mat::zeros(2, 4), &[0.0]).is_err());
        assert!(t.extend_data(&Mat::zeros(0, 4), &[]).is_err());
    }

    #[test]
    fn warm_carried_online_run_beats_cold_restarts() {
        // the tentpole claim: carrying solver + optimiser state across
        // arrivals reaches tolerance in strictly fewer total epochs than
        // cold-restarting on the accumulated data at every arrival
        let (ds, base, chunks) = online_fixture();
        let steps = 3;

        let mut warm = online_trainer(&base, true, 5);
        let mut warm_epochs = warm.run(steps).unwrap().total_epochs;
        for (x, y) in &chunks {
            warm.extend_data(x, y).unwrap();
            warm_epochs += warm.run(steps).unwrap().total_epochs;
        }

        let mut cold_epochs = 0.0;
        let mut acc_x = base.x_train.clone();
        let mut acc_y = base.y_train.clone();
        let mut acc = base.clone();
        cold_epochs += online_trainer(&acc, true, 5).run(steps).unwrap().total_epochs;
        for (x, y) in &chunks {
            acc_x.append_rows(x);
            acc_y.extend_from_slice(y);
            acc = ds.with_train(acc_x.clone(), acc_y.clone());
            cold_epochs += online_trainer(&acc, true, 5).run(steps).unwrap().total_epochs;
        }

        assert!(
            warm_epochs < cold_epochs,
            "warm-carried {warm_epochs} vs cold restarts {cold_epochs}"
        );
    }

    #[test]
    fn checkpoint_restore_is_resize_aware() {
        let (_, base, chunks) = online_fixture();
        let (x1, y1) = &chunks[0];

        let mut t = online_trainer(&base, true, 11);
        t.run(2).unwrap();
        let ck_small = t.checkpoint();
        t.extend_data(x1, y1).unwrap();
        t.run(2).unwrap();
        let ck_big = t.checkpoint();

        // same-shape restore still works
        let mut fresh = online_trainer(&base, true, 11);
        fresh.restore(&ck_small).unwrap();

        // a checkpoint from a larger n cannot restore before the chunks
        // are replayed (the old code hard-asserted here)
        let mut fresh = online_trainer(&base, true, 11);
        let err = fresh.restore(&ck_big).unwrap_err().to_string();
        assert!(err.contains("extend_data"), "{err}");
        fresh.extend_data(x1, y1).unwrap();
        fresh.restore(&ck_big).unwrap();
        assert_eq!(fresh.v_store().data, ck_big.v_store.data);
        fresh.run(1).unwrap();

        // an older (smaller-n) checkpoint restores into an extended
        // trainer with the missing warm-start rows zero-padded
        let mut padded = online_trainer(&base, true, 11);
        padded.extend_data(x1, y1).unwrap();
        padded.restore(&ck_small).unwrap();
        assert_eq!(padded.v_store().rows, base.spec.n + x1.rows);
        let k = padded.v_store().cols;
        assert_eq!(
            &padded.v_store().data[..ck_small.v_store.data.len()],
            &ck_small.v_store.data[..]
        );
        assert!(padded.v_store().data[base.spec.n * k..].iter().all(|&v| v == 0.0));
        padded.run(1).unwrap();

        // a checkpoint smaller than a trainer's *initial* dataset cannot
        // be an earlier state of that run — reject it instead of silently
        // zero-padding a wrong-dataset restore
        let ds_full = data::generate(&data::spec("test").unwrap());
        let mut other = online_trainer(&ds_full, true, 11);
        let err = other.restore(&ck_small).unwrap_err().to_string();
        assert!(err.contains("different dataset"), "{err}");

        // probe-width mismatch is genuinely incompatible
        let op_wide = DenseOperator::new(&base, 9, 32);
        let mut wide = Trainer::new(
            TrainerOptions { seed: 11, ..online_trainer(&base, true, 11).opts },
            Box::new(op_wide),
            &base,
        );
        assert!(wide.restore(&ck_small).is_err());
    }

    #[test]
    fn extension_resume_reproduces_straight_online_run() {
        // checkpoint + replayed chunk + restore must continue the exact
        // trajectory: probe extensions come from a (seed, old n, new n)
        // derived stream, not the trainer RNG
        let (_, base, chunks) = online_fixture();
        let (x1, y1) = &chunks[0];

        let mut straight = online_trainer(&base, true, 13);
        straight.run(2).unwrap();
        straight.extend_data(x1, y1).unwrap();
        straight.run(2).unwrap();

        let mut first = online_trainer(&base, true, 13);
        first.run(2).unwrap();
        first.extend_data(x1, y1).unwrap();
        let ck = first.checkpoint();

        let mut resumed = online_trainer(&base, true, 13);
        resumed.extend_data(x1, y1).unwrap();
        resumed.restore(&ck).unwrap();
        resumed.run(2).unwrap();

        for (a, b) in straight.theta().iter().zip(&resumed.theta()) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }
}
