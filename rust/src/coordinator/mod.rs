//! The paper's L3 contribution: the bilevel marginal-likelihood
//! coordinator.
//!
//! Outer loop: Adam ascent on softplus-reparameterised hyperparameters.
//! Gradient estimator: standard or pathwise probe sets ([`ProbeSet`]).
//! Inner loop: a warm-startable, budgeted linear-system solver
//! ([`LinearSolver`]) running against a [`KernelOperator`] backend.
//!
//! The three studied techniques are coordinated here:
//! * pathwise estimation (targets + gradient assembly + amortised
//!   prediction through pathwise conditioning),
//! * warm starting (the solution store carried across outer steps, with
//!   frozen probe randomness),
//! * compute budgets (epoch metering per outer step, with censoring
//!   semantics when the tolerance is not reachable).

pub mod checkpoint;
pub mod init;

use std::time::Instant;

use anyhow::Result;

use crate::data::Dataset;
use crate::estimator::{EstimatorKind, ProbeSet};
use crate::gp::{metrics, Metrics};
use crate::linalg::Mat;
use crate::operators::KernelOperator;
use crate::optim::{Adam, SoftplusParams};
use crate::solvers::{autotune_lr, make_solver, LinearSolver, SolveOptions, SolverKind};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct TrainerOptions {
    pub solver: SolverKind,
    pub estimator: EstimatorKind,
    pub warm_start: bool,
    /// Adam learning rate (paper: 0.1 small, 0.03 large datasets).
    pub lr: f64,
    /// Relative residual tolerance tau.
    pub tolerance: f64,
    /// Per-step epoch budget (None = solve to tolerance under `epoch_cap`).
    pub max_epochs: Option<f64>,
    /// Safety cap when solving "to tolerance" (censoring, stands in for
    /// the paper's 24h timeout).
    pub epoch_cap: f64,
    /// CG preconditioner rank.
    pub precond_rank: usize,
    /// AP block / SGD batch size (None = operator's preferred size).
    pub block_size: Option<usize>,
    /// SGD learning rate (None = auto-tune on the first step).
    pub sgd_lr: Option<f64>,
    /// Halve the auto-tuned SGD rate (paper's large-dataset protocol).
    pub sgd_lr_halve: bool,
    /// Initial hyperparameter value (paper: 1.0 on small datasets).
    pub init_theta: f64,
    /// Also evaluate the exact MLL each step (needs an exact backend path).
    pub track_exact: bool,
    /// Evaluate test metrics every k outer steps (None = only at the end).
    pub predict_every: Option<usize>,
    pub seed: u64,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions {
            solver: SolverKind::Cg,
            estimator: EstimatorKind::Standard,
            warm_start: false,
            lr: 0.1,
            tolerance: 0.01,
            max_epochs: None,
            epoch_cap: 300.0,
            precond_rank: 64,
            block_size: None,
            sgd_lr: None,
            sgd_lr_halve: false,
            init_theta: 1.0,
            track_exact: false,
            predict_every: None,
            seed: 0,
        }
    }
}

/// Per-outer-step telemetry (drives every figure of the paper).
#[derive(Clone, Debug)]
pub struct StepTelemetry {
    pub step: usize,
    pub theta: Vec<f64>,
    pub grad: Vec<f64>,
    pub ry: f64,
    pub rz: f64,
    pub iterations: usize,
    pub epochs: f64,
    pub solver_secs: f64,
    pub step_secs: f64,
    pub converged: bool,
    pub init_residual_sq: f64,
    pub exact_mll: Option<f64>,
    pub metrics: Option<Metrics>,
}

#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub telemetry: Vec<StepTelemetry>,
    pub theta: Vec<f64>,
    pub final_metrics: Metrics,
    pub total_secs: f64,
    pub solver_secs: f64,
    pub total_epochs: f64,
    pub sgd_lr_used: f64,
}

pub struct Trainer {
    pub opts: TrainerOptions,
    op: Box<dyn KernelOperator>,
    y_train: Vec<f64>,
    y_test: Vec<f64>,
    solver: Box<dyn LinearSolver>,
    probes: ProbeSet,
    params: SoftplusParams,
    adam: Adam,
    rng: Rng,
    /// Warm-start store: previous raw-space solution [n, s+1].
    v_store: Mat,
    solve_opts: SolveOptions,
    sgd_lr_resolved: Option<f64>,
}

impl Trainer {
    pub fn new(opts: TrainerOptions, mut op: Box<dyn KernelOperator>, ds: &Dataset) -> Self {
        let mut rng = Rng::new(opts.seed ^ 0x16_97);
        let d = op.d();
        let theta0 = vec![opts.init_theta; d + 2];
        let params = SoftplusParams::from_theta(&theta0);
        let hp = crate::kernels::Hyperparams::unpack(&theta0, d);
        op.set_hp(&hp);
        let probes = ProbeSet::sample(opts.estimator, op.as_ref(), &mut rng);
        let adam = Adam::new(d + 2, opts.lr);
        let v_store = Mat::zeros(op.n(), op.s() + 1);
        let block = opts.block_size.unwrap_or_else(|| preferred_block(op.as_ref()));
        let solve_opts = SolveOptions {
            tolerance: opts.tolerance,
            max_epochs: opts.max_epochs.unwrap_or(opts.epoch_cap),
            precond_rank: opts.precond_rank,
            block_size: block,
            sgd_lr: opts.sgd_lr.unwrap_or(0.0), // resolved on first step
            sgd_momentum: 0.9,
            sgd_polyak: false,
            sgd_backoff: true,
            ap_selection: crate::solvers::ApSelection::Greedy,
        };
        let solver = make_solver(opts.solver);
        Trainer {
            opts,
            op,
            y_train: ds.y_train.clone(),
            y_test: ds.y_test.clone(),
            solver,
            probes,
            params,
            adam,
            rng,
            v_store,
            solve_opts,
            sgd_lr_resolved: None,
        }
    }

    /// Initialise hyperparameters from values (e.g. the paper's
    /// subset-heuristic for large datasets) instead of the constant init.
    pub fn set_init_theta(&mut self, theta: &[f64]) {
        self.params = SoftplusParams::from_theta(theta);
        let hp = crate::kernels::Hyperparams::unpack(theta, self.op.d());
        self.op.set_hp(&hp);
    }

    pub fn theta(&self) -> Vec<f64> {
        self.params.theta()
    }

    pub fn operator(&self) -> &dyn KernelOperator {
        self.op.as_ref()
    }

    /// The warm-start store (last solved batch, raw space).
    pub fn v_store(&self) -> &Mat {
        &self.v_store
    }

    /// The estimator's probe state (for experiment diagnostics).
    pub fn probes(&self) -> &ProbeSet {
        &self.probes
    }

    /// Test targets (for experiment-side metric recomputation).
    pub fn y_test(&self) -> &[f64] {
        &self.y_test
    }

    /// Snapshot the resumable training state.
    pub fn checkpoint(&self, step: u64) -> checkpoint::Checkpoint {
        let (m, v, t) = self.adam.state();
        checkpoint::Checkpoint {
            step,
            seed: self.opts.seed,
            nu: self.params.nu.clone(),
            adam_m: m.to_vec(),
            adam_v: v.to_vec(),
            adam_t: t,
            v_store: self.v_store.clone(),
        }
    }

    /// Resume from a checkpoint (hyperparameters, Adam moments and the
    /// warm-start store; probe randomness is reconstructed from the seed,
    /// which `Trainer::new` already derives deterministically).
    pub fn restore(&mut self, ck: &checkpoint::Checkpoint) {
        assert_eq!(ck.nu.len(), self.params.nu.len());
        assert_eq!(
            (ck.v_store.rows, ck.v_store.cols),
            (self.v_store.rows, self.v_store.cols)
        );
        self.params.nu = ck.nu.clone();
        self.adam.restore_state(ck.adam_m.clone(), ck.adam_v.clone(), ck.adam_t);
        self.v_store = ck.v_store.clone();
        let theta = self.params.theta();
        let hp = crate::kernels::Hyperparams::unpack(&theta, self.op.d());
        self.op.set_hp(&hp);
    }

    /// Run `steps` outer-loop iterations.
    pub fn run(&mut self, steps: usize) -> Result<TrainOutcome> {
        let t_total = Instant::now();
        let mut telemetry = Vec::with_capacity(steps);
        let mut solver_secs = 0.0;
        let mut total_epochs = 0.0;

        for step in 0..steps {
            let t_step = Instant::now();
            let theta = self.params.theta();
            let hp = crate::kernels::Hyperparams::unpack(&theta, self.op.d());
            self.op.set_hp(&hp);

            // (re)sample probes unless warm starting (targets must be
            // frozen for warm starts; Section 4)
            if !self.opts.warm_start && step > 0 {
                self.probes = ProbeSet::sample(self.opts.estimator, self.op.as_ref(), &mut self.rng);
            }
            let b = self.probes.targets(self.op.as_ref(), &self.y_train);

            // SGD learning-rate auto-tune on the first step (paper protocol)
            if self.opts.solver == SolverKind::Sgd && self.sgd_lr_resolved.is_none() {
                let lr = match self.opts.sgd_lr {
                    Some(lr) => lr,
                    None => autotune_lr(
                        self.op.as_ref(),
                        &b,
                        &self.solve_opts,
                        &[1.0, 2.0, 4.0, 8.0, 12.0, 16.0, 24.0, 32.0, 48.0, 64.0],
                        self.opts.sgd_lr_halve,
                    ),
                };
                self.solve_opts.sgd_lr = lr;
                self.sgd_lr_resolved = Some(lr);
            }

            // inner solve (warm start from the stored solution)
            let mut v = if self.opts.warm_start {
                self.v_store.clone()
            } else {
                Mat::zeros(self.op.n(), self.op.s() + 1)
            };
            let t_solve = Instant::now();
            let report = self.solver.solve(self.op.as_ref(), &b, &mut v, &self.solve_opts);
            let solve_elapsed = t_solve.elapsed().as_secs_f64();
            solver_secs += solve_elapsed;
            total_epochs += report.epochs;
            if self.opts.warm_start {
                self.v_store = v.clone();
            }

            // gradient estimate + Adam ascent
            let grad_theta = self.probes.grad(self.op.as_ref(), &v, &b);
            let grad_nu = self.params.chain_grad(&grad_theta);
            self.adam.step(&mut self.params.nu, &grad_nu);

            let exact_mll = if self.opts.track_exact {
                self.op.exact_mll(&self.y_train).map(|(l, _)| l)
            } else {
                None
            };
            let step_metrics = match self.opts.predict_every {
                Some(k) if (step + 1) % k == 0 => Some(self.evaluate(&v)?),
                _ => None,
            };

            telemetry.push(StepTelemetry {
                step,
                theta,
                grad: grad_theta,
                ry: report.ry,
                rz: report.rz,
                iterations: report.iterations,
                epochs: report.epochs,
                solver_secs: solve_elapsed,
                step_secs: t_step.elapsed().as_secs_f64(),
                converged: report.converged,
                init_residual_sq: report.init_residual_sq,
                exact_mll,
                metrics: step_metrics,
            });
        }

        // final prediction: set final hyperparameters, make sure we have a
        // solved system for them
        let theta = self.params.theta();
        let hp = crate::kernels::Hyperparams::unpack(&theta, self.op.d());
        self.op.set_hp(&hp);
        let final_v = self.solve_for_prediction()?;
        let final_metrics = self.evaluate(&final_v)?;

        Ok(TrainOutcome {
            telemetry,
            theta,
            final_metrics,
            total_secs: t_total.elapsed().as_secs_f64(),
            solver_secs,
            total_epochs,
            sgd_lr_used: self.sgd_lr_resolved.unwrap_or(0.0),
        })
    }

    /// Solve the current system for prediction purposes (amortised for the
    /// warm-started pathwise estimator: the stored solution is reused).
    fn solve_for_prediction(&mut self) -> Result<Mat> {
        let b = self.probes.targets(self.op.as_ref(), &self.y_train);
        let mut v = if self.opts.warm_start {
            self.v_store.clone()
        } else {
            Mat::zeros(self.op.n(), self.op.s() + 1)
        };
        let report = self.solver.solve(self.op.as_ref(), &b, &mut v, &self.solve_opts);
        let _ = report;
        if self.opts.warm_start {
            self.v_store = v.clone();
        }
        Ok(v)
    }

    /// Test metrics via pathwise conditioning (eq. 16).
    ///
    /// Pathwise estimator: the solved probe columns *are* zhat — prediction
    /// is amortised.  Standard estimator: the probes are not posterior
    /// samples, so an extra batch of pathwise solves is required (this is
    /// exactly the amortisation gap the paper quantifies).
    fn evaluate(&mut self, v: &Mat) -> Result<Metrics> {
        let (zhat, omega0, wts, vy) = match self.opts.estimator {
            EstimatorKind::Pathwise => (
                self.probes.zhat(v),
                self.probes.omega0.clone(),
                self.probes.wts.clone(),
                v.col(0),
            ),
            EstimatorKind::Standard => {
                // extra pathwise solves for posterior samples
                let pw = ProbeSet::sample(EstimatorKind::Pathwise, self.op.as_ref(), &mut self.rng);
                let b = pw.targets(self.op.as_ref(), &self.y_train);
                let mut vs = Mat::zeros(self.op.n(), self.op.s() + 1);
                let _ = self.solver.solve(self.op.as_ref(), &b, &mut vs, &self.solve_opts);
                (pw.zhat(&vs), pw.omega0.clone(), pw.wts.clone(), vs.col(0))
            }
        };
        let (mean, samples) = self.op.predict(&vy, &zhat, &omega0, &wts);
        let noise_var = self.op.hp().noise_var();
        let var: Vec<f64> = (0..samples.rows)
            .map(|i| {
                let row = samples.row(i);
                let mu: f64 = row.iter().sum::<f64>() / row.len() as f64;
                let v: f64 =
                    row.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / (row.len() - 1).max(1) as f64;
                v + noise_var
            })
            .collect();
        Ok(metrics(&mean, &var, &self.y_test))
    }
}

fn preferred_block(op: &dyn KernelOperator) -> usize {
    // XlaOperator's artifact fixes b; DenseOperator accepts anything.
    // Encode the convention n/16 bounded to [32, 256]; the XLA path
    // overrides via TrainerOptions.block_size = meta.b.
    (op.n() / 16).clamp(32, 256)
}

// ---------------------------------------------------------------------------
// Exact-optimisation baseline (Figs 5, 8, 11-13)
// ---------------------------------------------------------------------------

/// Run exact (Cholesky) marginal-likelihood optimisation with the same
/// Adam/softplus outer loop, via the backend's exact path.
pub fn run_exact(
    op: &mut dyn KernelOperator,
    y: &[f64],
    steps: usize,
    lr: f64,
    init_theta: f64,
) -> Result<Vec<(Vec<f64>, f64)>> {
    let d = op.d();
    let mut params = SoftplusParams::from_theta(&vec![init_theta; d + 2]);
    let mut adam = Adam::new(d + 2, lr);
    let mut traj = Vec::with_capacity(steps);
    for _ in 0..steps {
        let theta = params.theta();
        op.set_hp(&crate::kernels::Hyperparams::unpack(&theta, d));
        let (mll, grad) = op
            .exact_mll(y)
            .ok_or_else(|| anyhow::anyhow!("backend has no exact MLL path"))?;
        traj.push((theta, mll));
        let grad_nu = params.chain_grad(&grad);
        adam.step(&mut params.nu, &grad_nu);
    }
    Ok(traj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::operators::DenseOperator;

    fn trainer(solver: SolverKind, estimator: EstimatorKind, warm: bool) -> (Trainer, Dataset) {
        let ds = data::generate(&data::spec("test").unwrap());
        let op = DenseOperator::new(&ds, 8, 32);
        let opts = TrainerOptions {
            solver,
            estimator,
            warm_start: warm,
            lr: 0.1,
            epoch_cap: 200.0,
            block_size: Some(64),
            sgd_lr: Some(8.0),
            seed: 7,
            ..Default::default()
        };
        (Trainer::new(opts, Box::new(op), &ds), ds)
    }

    #[test]
    fn training_improves_exact_mll() {
        let (mut t, ds) = trainer(SolverKind::Cg, EstimatorKind::Pathwise, true);
        let op0 = DenseOperator::new(&ds, 8, 32);
        let mll0 = {
            let mut o = op0;
            o.set_hp(&crate::kernels::Hyperparams::ones(4));
            o.exact_mll(&ds.y_train).unwrap().0
        };
        let out = t.run(15).unwrap();
        let mll1 = {
            let mut o = DenseOperator::new(&ds, 8, 32);
            o.set_hp(&crate::kernels::Hyperparams::unpack(&out.theta, 4));
            o.exact_mll(&ds.y_train).unwrap().0
        };
        assert!(mll1 > mll0, "mll {mll0} -> {mll1}");
        assert!(out.final_metrics.llh.is_finite());
    }

    #[test]
    fn warm_start_reduces_total_epochs() {
        let (mut cold, _) = trainer(SolverKind::Ap, EstimatorKind::Standard, false);
        let (mut warm, _) = trainer(SolverKind::Ap, EstimatorKind::Standard, true);
        let out_cold = cold.run(10).unwrap();
        let out_warm = warm.run(10).unwrap();
        assert!(
            out_warm.total_epochs < out_cold.total_epochs,
            "warm {} cold {}",
            out_warm.total_epochs,
            out_cold.total_epochs
        );
    }

    #[test]
    fn pathwise_reduces_epochs_vs_standard_high_precision() {
        // The test dataset has sigma_true = 0.3; after a few steps noise
        // precision rises and the pathwise advantage (eq 14 vs 15) shows.
        let (mut st, _) = trainer(SolverKind::Ap, EstimatorKind::Standard, false);
        let (mut pw, _) = trainer(SolverKind::Ap, EstimatorKind::Pathwise, false);
        let out_st = st.run(12).unwrap();
        let out_pw = pw.run(12).unwrap();
        assert!(
            out_pw.total_epochs <= out_st.total_epochs * 1.1,
            "pathwise {} vs standard {}",
            out_pw.total_epochs,
            out_st.total_epochs
        );
    }

    #[test]
    fn budget_mode_respects_epoch_cap() {
        let ds = data::generate(&data::spec("test").unwrap());
        let op = DenseOperator::new(&ds, 8, 32);
        let opts = TrainerOptions {
            solver: SolverKind::Ap,
            estimator: EstimatorKind::Pathwise,
            warm_start: true,
            max_epochs: Some(3.0),
            block_size: Some(64),
            seed: 1,
            ..Default::default()
        };
        let mut t = Trainer::new(opts, Box::new(op), &ds);
        let out = t.run(5).unwrap();
        for tel in &out.telemetry {
            assert!(tel.epochs <= 3.0 + 1e-9, "{}", tel.epochs);
        }
    }

    #[test]
    fn warm_start_accumulates_progress_under_budget() {
        // Fig 10 phenomenon: with a tiny budget, warm starting drives the
        // residual down across outer steps while cold restarts cannot.
        let mk = |warm| {
            let ds = data::generate(&data::spec("test").unwrap());
            let op = DenseOperator::new(&ds, 8, 32);
            let opts = TrainerOptions {
                solver: SolverKind::Ap,
                estimator: EstimatorKind::Pathwise,
                warm_start: warm,
                max_epochs: Some(2.0),
                block_size: Some(64),
                lr: 0.05,
                seed: 3,
                ..Default::default()
            };
            Trainer::new(opts, Box::new(op), &ds)
        };
        let out_warm = mk(true).run(10).unwrap();
        let out_cold = mk(false).run(10).unwrap();
        let last_warm = out_warm.telemetry.last().unwrap().rz;
        let last_cold = out_cold.telemetry.last().unwrap().rz;
        assert!(last_warm < last_cold, "warm {last_warm} vs cold {last_cold}");
    }

    #[test]
    fn exact_baseline_increases_mll() {
        let ds = data::generate(&data::spec("test").unwrap());
        let mut op = DenseOperator::new(&ds, 8, 32);
        let traj = run_exact(&mut op, &ds.y_train, 10, 0.1, 1.0).unwrap();
        assert!(traj.last().unwrap().1 > traj.first().unwrap().1);
    }

    #[test]
    fn checkpoint_resume_reproduces_training() {
        // run 8 steps straight vs 4 + checkpoint/restore + 4: identical
        // thetas (warm-started, so no mid-run probe resampling).
        let (mut a, _) = trainer(SolverKind::Ap, EstimatorKind::Pathwise, true);
        a.run(8).unwrap();
        let (mut b1, ds) = trainer(SolverKind::Ap, EstimatorKind::Pathwise, true);
        b1.run(4).unwrap();
        let ck = b1.checkpoint(4);
        let op2 = DenseOperator::new(&ds, 8, 32);
        let opts2 = b1.opts.clone();
        let mut b2 = Trainer::new(opts2, Box::new(op2), &ds);
        b2.restore(&ck);
        b2.run(4).unwrap();
        let ta = a.theta();
        let tb = b2.theta();
        for (x, y) in ta.iter().zip(&tb) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn telemetry_is_complete() {
        let (mut t, _) = trainer(SolverKind::Sgd, EstimatorKind::Pathwise, true);
        let out = t.run(4).unwrap();
        assert_eq!(out.telemetry.len(), 4);
        for (i, tel) in out.telemetry.iter().enumerate() {
            assert_eq!(tel.step, i);
            assert_eq!(tel.theta.len(), 6);
            assert_eq!(tel.grad.len(), 6);
            assert!(tel.epochs > 0.0);
        }
        assert!(out.sgd_lr_used > 0.0);
    }
}
