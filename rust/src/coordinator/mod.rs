//! The paper's L3 contribution: the bilevel marginal-likelihood
//! coordinator.
//!
//! Outer loop: Adam ascent on softplus-reparameterised hyperparameters.
//! Gradient estimator: standard or pathwise probe sets ([`ProbeSet`]).
//! Inner loop: a warm-startable, budgeted linear-system solver
//! ([`LinearSolver`]) running against a [`KernelOperator`] backend.
//!
//! The three studied techniques are coordinated here:
//! * pathwise estimation (targets + gradient assembly + amortised
//!   prediction through pathwise conditioning),
//! * warm starting (the solution store carried across outer steps, with
//!   frozen probe randomness),
//! * compute budgets (epoch metering per outer step, with censoring
//!   semantics when the tolerance is not reachable).

pub mod checkpoint;
pub mod init;

use std::time::Instant;

use anyhow::Result;

use crate::data::Dataset;
use crate::estimator::{EstimatorKind, ProbeSet};
use crate::gp::{metrics, Metrics};
use crate::linalg::Mat;
use crate::operators::KernelOperator;
use crate::optim::{Adam, SoftplusParams};
use crate::solvers::{
    autotune_lr, make_solver, LinearSolver, PreconditionerCache, SharedPreconditionerCache,
    SolveOptions, SolveReport, SolverKind,
};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct TrainerOptions {
    pub solver: SolverKind,
    pub estimator: EstimatorKind,
    pub warm_start: bool,
    /// Adam learning rate (paper: 0.1 small, 0.03 large datasets).
    pub lr: f64,
    /// Relative residual tolerance tau.
    pub tolerance: f64,
    /// Per-step epoch budget (None = solve to tolerance under `epoch_cap`).
    pub max_epochs: Option<f64>,
    /// Safety cap when solving "to tolerance" (censoring, stands in for
    /// the paper's 24h timeout).
    pub epoch_cap: f64,
    /// CG preconditioner rank.
    pub precond_rank: usize,
    /// AP block / SGD batch size (None = operator's preferred size).
    pub block_size: Option<usize>,
    /// SGD learning rate (None = auto-tune on the first step).
    pub sgd_lr: Option<f64>,
    /// Halve the auto-tuned SGD rate (paper's large-dataset protocol).
    pub sgd_lr_halve: bool,
    /// Initial hyperparameter value (paper: 1.0 on small datasets).
    pub init_theta: f64,
    /// Also evaluate the exact MLL each step (needs an exact backend path).
    pub track_exact: bool,
    /// Evaluate test metrics every k outer steps (None = only at the end).
    pub predict_every: Option<usize>,
    /// Worker threads for the solver-recurrence layer and preconditioner
    /// builds (0 = auto).  Output is bitwise-identical for every value.
    pub threads: usize,
    /// AP: score blocks on the preconditioned residual (off by default).
    pub ap_precond: bool,
    pub seed: u64,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions {
            solver: SolverKind::Cg,
            estimator: EstimatorKind::Standard,
            warm_start: false,
            lr: 0.1,
            tolerance: 0.01,
            max_epochs: None,
            epoch_cap: 300.0,
            precond_rank: 64,
            block_size: None,
            sgd_lr: None,
            sgd_lr_halve: false,
            init_theta: 1.0,
            track_exact: false,
            predict_every: None,
            threads: 0,
            ap_precond: false,
            seed: 0,
        }
    }
}

/// Per-outer-step telemetry (drives every figure of the paper).
#[derive(Clone, Debug)]
pub struct StepTelemetry {
    pub step: usize,
    pub theta: Vec<f64>,
    pub grad: Vec<f64>,
    pub ry: f64,
    pub rz: f64,
    pub iterations: usize,
    pub epochs: f64,
    pub solver_secs: f64,
    pub step_secs: f64,
    pub converged: bool,
    pub init_residual_sq: f64,
    pub exact_mll: Option<f64>,
    pub metrics: Option<Metrics>,
}

#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub telemetry: Vec<StepTelemetry>,
    pub theta: Vec<f64>,
    pub final_metrics: Metrics,
    pub total_secs: f64,
    /// Wall time in the solver across *all* solves this run — the per-step
    /// training solves plus prediction, evaluation re-solves (Standard
    /// estimator) and SGD learning-rate autotune probes.
    pub solver_secs: f64,
    /// Epochs across all solves this run (same coverage as `solver_secs`).
    pub total_epochs: f64,
    pub sgd_lr_used: f64,
}

pub struct Trainer {
    pub opts: TrainerOptions,
    op: Box<dyn KernelOperator>,
    y_train: Vec<f64>,
    y_test: Vec<f64>,
    solver: Box<dyn LinearSolver>,
    probes: ProbeSet,
    params: SoftplusParams,
    adam: Adam,
    rng: Rng,
    /// Warm-start store: previous raw-space solution [n, s+1].
    v_store: Mat,
    solve_opts: SolveOptions,
    sgd_lr_resolved: Option<f64>,
    /// Coordinator-owned preconditioner store, injected into the solver so
    /// factorisations are shared across training, prediction and
    /// evaluation solves.
    precond: SharedPreconditionerCache,
    /// Lifetime solver-work accounting (epochs / wall seconds across every
    /// solve, including prediction, evaluation and autotune probes).
    /// `run` reports per-run deltas of these.
    spent_epochs: f64,
    spent_solver_secs: f64,
    /// Outer steps completed over the trainer's lifetime (survives
    /// checkpoint/restore; drives cold-start probe resampling).
    step_count: u64,
}

impl Trainer {
    pub fn new(opts: TrainerOptions, mut op: Box<dyn KernelOperator>, ds: &Dataset) -> Self {
        let mut rng = Rng::new(opts.seed ^ 0x16_97);
        let d = op.d();
        let theta0 = vec![opts.init_theta; d + 2];
        let params = SoftplusParams::from_theta(&theta0);
        let hp = crate::kernels::Hyperparams::unpack(&theta0, d);
        op.set_hp(&hp);
        let probes = ProbeSet::sample(opts.estimator, op.as_ref(), &mut rng);
        let adam = Adam::new(d + 2, opts.lr);
        let v_store = Mat::zeros(op.n(), op.s() + 1);
        let block = opts.block_size.unwrap_or_else(|| preferred_block(op.as_ref()));
        let solve_opts = SolveOptions {
            tolerance: opts.tolerance,
            max_epochs: opts.max_epochs.unwrap_or(opts.epoch_cap),
            precond_rank: opts.precond_rank,
            block_size: block,
            sgd_lr: opts.sgd_lr.unwrap_or(0.0), // resolved on first step
            sgd_momentum: 0.9,
            sgd_polyak: false,
            sgd_backoff: true,
            ap_selection: crate::solvers::ApSelection::Greedy,
            threads: opts.threads,
            ap_block_precond: opts.ap_precond,
        };
        let mut solver = make_solver(opts.solver);
        let precond: SharedPreconditionerCache = PreconditionerCache::shared();
        solver.set_precond_cache(precond.clone());
        Trainer {
            opts,
            op,
            y_train: ds.y_train.clone(),
            y_test: ds.y_test.clone(),
            solver,
            probes,
            params,
            adam,
            rng,
            v_store,
            solve_opts,
            sgd_lr_resolved: None,
            precond,
            spent_epochs: 0.0,
            spent_solver_secs: 0.0,
            step_count: 0,
        }
    }

    /// Initialise hyperparameters from values (e.g. the paper's
    /// subset-heuristic for large datasets) instead of the constant init.
    pub fn set_init_theta(&mut self, theta: &[f64]) {
        self.params = SoftplusParams::from_theta(theta);
        let hp = crate::kernels::Hyperparams::unpack(theta, self.op.d());
        self.op.set_hp(&hp);
    }

    pub fn theta(&self) -> Vec<f64> {
        self.params.theta()
    }

    pub fn operator(&self) -> &dyn KernelOperator {
        self.op.as_ref()
    }

    /// The warm-start store (last solved batch, raw space).
    pub fn v_store(&self) -> &Mat {
        &self.v_store
    }

    /// The estimator's probe state (for experiment diagnostics).
    pub fn probes(&self) -> &ProbeSet {
        &self.probes
    }

    /// The coordinator-owned preconditioner cache (diagnostics / tests).
    pub fn precond_cache(&self) -> &PreconditionerCache {
        &self.precond
    }

    /// One metered solve: every epoch and second of solver work anywhere
    /// in the trainer goes through here so nothing is dropped from the
    /// reported totals.
    fn timed_solve(&mut self, b: &Mat, v: &mut Mat) -> SolveReport {
        let t = Instant::now();
        let report = self.solver.solve(self.op.as_ref(), b, v, &self.solve_opts);
        self.spent_solver_secs += t.elapsed().as_secs_f64();
        self.spent_epochs += report.epochs;
        report
    }

    /// Test targets (for experiment-side metric recomputation).
    pub fn y_test(&self) -> &[f64] {
        &self.y_test
    }

    /// Snapshot the resumable training state at the current
    /// completed-step count (the counter controls cold-start probe
    /// resampling after a restore, so it is read from the trainer rather
    /// than trusted to the caller).
    pub fn checkpoint(&self) -> checkpoint::Checkpoint {
        let (m, v, t) = self.adam.state();
        checkpoint::Checkpoint {
            step: self.step_count,
            seed: self.opts.seed,
            nu: self.params.nu.clone(),
            adam_m: m.to_vec(),
            adam_v: v.to_vec(),
            adam_t: t,
            v_store: self.v_store.clone(),
            rng: Some(self.rng.state()),
            sgd_lr: self.sgd_lr_resolved,
        }
    }

    /// Resume from a checkpoint: hyperparameters, Adam moments, the
    /// warm-start store, the completed-step counter, the resolved SGD
    /// learning rate (so a resumed SGD run does not re-autotune at the
    /// sharpened hyperparameters) and — when present — the trainer RNG
    /// mid-stream state, so runs that keep drawing randomness after the
    /// restore point (cold starts resample probes every step) continue
    /// the exact sequence.  The *initial* probe set is reconstructed from
    /// the seed by `Trainer::new`; cold-start resumes replace it on the
    /// first resumed step.
    ///
    /// Limitation: solver-*internal* randomness (SGD's minibatch stream,
    /// AP's `Random`/`Cyclic` selection state) is not serialised, so those
    /// modes resume correctly but not bit-reproducibly; CG and greedy AP
    /// are RNG-free and reproduce exactly.
    pub fn restore(&mut self, ck: &checkpoint::Checkpoint) {
        assert_eq!(ck.nu.len(), self.params.nu.len());
        assert_eq!(
            (ck.v_store.rows, ck.v_store.cols),
            (self.v_store.rows, self.v_store.cols)
        );
        self.params.nu = ck.nu.clone();
        self.adam.restore_state(ck.adam_m.clone(), ck.adam_v.clone(), ck.adam_t);
        self.v_store = ck.v_store.clone();
        self.step_count = ck.step;
        if let Some(st) = &ck.rng {
            self.rng = Rng::from_state(st);
        }
        if let Some(lr) = ck.sgd_lr {
            self.solve_opts.sgd_lr = lr;
            self.sgd_lr_resolved = Some(lr);
        }
        let theta = self.params.theta();
        let hp = crate::kernels::Hyperparams::unpack(&theta, self.op.d());
        self.op.set_hp(&hp);
    }

    /// Run `steps` outer-loop iterations.
    pub fn run(&mut self, steps: usize) -> Result<TrainOutcome> {
        let t_total = Instant::now();
        let mut telemetry = Vec::with_capacity(steps);
        // totals are deltas of the lifetime spend counters, so *every*
        // solve in this run — training, prediction, evaluation re-solves,
        // autotune probes — is accounted
        let epochs0 = self.spent_epochs;
        let secs0 = self.spent_solver_secs;

        for step in 0..steps {
            let t_step = Instant::now();
            let theta = self.params.theta();
            let hp = crate::kernels::Hyperparams::unpack(&theta, self.op.d());
            self.op.set_hp(&hp);

            // (re)sample probes unless warm starting (targets must be
            // frozen for warm starts; Section 4).  `step_count` counts
            // completed steps over the trainer's lifetime, so a restored
            // run resamples exactly where the uninterrupted run would.
            if !self.opts.warm_start && self.step_count > 0 {
                self.probes = ProbeSet::sample(self.opts.estimator, self.op.as_ref(), &mut self.rng);
            }
            let b = self.probes.targets(self.op.as_ref(), &self.y_train);

            // SGD learning-rate auto-tune on the first step (paper
            // protocol); the probe epochs are real solver work and are
            // charged against the totals
            if self.opts.solver == SolverKind::Sgd && self.sgd_lr_resolved.is_none() {
                let lr = match self.opts.sgd_lr {
                    Some(lr) => lr,
                    None => {
                        let t_tune = Instant::now();
                        let (lr, probe_epochs) = autotune_lr(
                            self.op.as_ref(),
                            &b,
                            &self.solve_opts,
                            &[1.0, 2.0, 4.0, 8.0, 12.0, 16.0, 24.0, 32.0, 48.0, 64.0],
                            self.opts.sgd_lr_halve,
                        );
                        self.spent_solver_secs += t_tune.elapsed().as_secs_f64();
                        self.spent_epochs += probe_epochs;
                        lr
                    }
                };
                self.solve_opts.sgd_lr = lr;
                self.sgd_lr_resolved = Some(lr);
            }

            // inner solve (warm start from the stored solution)
            let mut v = if self.opts.warm_start {
                self.v_store.clone()
            } else {
                Mat::zeros(self.op.n(), self.op.s() + 1)
            };
            let secs_before = self.spent_solver_secs;
            let report = self.timed_solve(&b, &mut v);
            let solve_elapsed = self.spent_solver_secs - secs_before;
            if self.opts.warm_start {
                self.v_store = v.clone();
            }

            // gradient estimate + Adam ascent
            let grad_theta = self.probes.grad(self.op.as_ref(), &v, &b);
            let grad_nu = self.params.chain_grad(&grad_theta);
            self.adam.step(&mut self.params.nu, &grad_nu);

            let exact_mll = if self.opts.track_exact {
                self.op.exact_mll(&self.y_train).map(|(l, _)| l)
            } else {
                None
            };
            let step_metrics = match self.opts.predict_every {
                Some(k) if (step + 1) % k == 0 => Some(self.evaluate(&v)?),
                _ => None,
            };

            telemetry.push(StepTelemetry {
                step,
                theta,
                grad: grad_theta,
                ry: report.ry,
                rz: report.rz,
                iterations: report.iterations,
                epochs: report.epochs,
                solver_secs: solve_elapsed,
                step_secs: t_step.elapsed().as_secs_f64(),
                converged: report.converged,
                init_residual_sq: report.init_residual_sq,
                exact_mll,
                metrics: step_metrics,
            });
            self.step_count += 1;
        }

        // final prediction: set final hyperparameters, make sure we have a
        // solved system for them
        let theta = self.params.theta();
        let hp = crate::kernels::Hyperparams::unpack(&theta, self.op.d());
        self.op.set_hp(&hp);
        let final_v = self.solve_for_prediction()?;
        let final_metrics = self.evaluate(&final_v)?;

        Ok(TrainOutcome {
            telemetry,
            theta,
            final_metrics,
            total_secs: t_total.elapsed().as_secs_f64(),
            solver_secs: self.spent_solver_secs - secs0,
            total_epochs: self.spent_epochs - epochs0,
            sgd_lr_used: self.sgd_lr_resolved.unwrap_or(0.0),
        })
    }

    /// Solve the current system for prediction purposes (amortised for the
    /// warm-started pathwise estimator: the stored solution is reused).
    /// The solve is metered like any other — its epochs and wall time land
    /// in the reported totals.
    fn solve_for_prediction(&mut self) -> Result<Mat> {
        let b = self.probes.targets(self.op.as_ref(), &self.y_train);
        let mut v = if self.opts.warm_start {
            self.v_store.clone()
        } else {
            Mat::zeros(self.op.n(), self.op.s() + 1)
        };
        let _report = self.timed_solve(&b, &mut v);
        if self.opts.warm_start {
            self.v_store = v.clone();
        }
        Ok(v)
    }

    /// Test metrics via pathwise conditioning (eq. 16).
    ///
    /// Pathwise estimator: the solved probe columns *are* zhat — prediction
    /// is amortised.  Standard estimator: the probes are not posterior
    /// samples, so an extra batch of pathwise solves is required (this is
    /// exactly the amortisation gap the paper quantifies).
    fn evaluate(&mut self, v: &Mat) -> Result<Metrics> {
        let (zhat, omega0, wts, vy) = match self.opts.estimator {
            EstimatorKind::Pathwise => (
                self.probes.zhat(v),
                self.probes.omega0.clone(),
                self.probes.wts.clone(),
                v.col(0),
            ),
            EstimatorKind::Standard => {
                // extra pathwise solves for posterior samples — this is
                // exactly the amortisation gap the paper quantifies, so
                // the work is metered into the totals like any solve.
                // The probes come from a stream derived from (seed, step
                // count) instead of the trainer RNG: evaluation must not
                // advance the training stream, or a checkpoint taken
                // after `run` (whose tail always evaluates) would resume
                // on a different random sequence than the uninterrupted
                // run at the same step.
                let mut eval_rng = Rng::new(
                    self.opts.seed ^ 0xE7A1 ^ self.step_count.wrapping_mul(0x9E3779B97F4A7C15),
                );
                let pw = ProbeSet::sample(EstimatorKind::Pathwise, self.op.as_ref(), &mut eval_rng);
                let b = pw.targets(self.op.as_ref(), &self.y_train);
                let mut vs = Mat::zeros(self.op.n(), self.op.s() + 1);
                let _ = self.timed_solve(&b, &mut vs);
                (pw.zhat(&vs), pw.omega0.clone(), pw.wts.clone(), vs.col(0))
            }
        };
        let (mean, samples) = self.op.predict(&vy, &zhat, &omega0, &wts);
        let noise_var = self.op.hp().noise_var();
        let var: Vec<f64> = (0..samples.rows)
            .map(|i| {
                let row = samples.row(i);
                let mu: f64 = row.iter().sum::<f64>() / row.len() as f64;
                let v: f64 =
                    row.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / (row.len() - 1).max(1) as f64;
                v + noise_var
            })
            .collect();
        Ok(metrics(&mean, &var, &self.y_test))
    }
}

fn preferred_block(op: &dyn KernelOperator) -> usize {
    // XlaOperator's artifact fixes b; DenseOperator accepts anything.
    // Encode the convention n/16 bounded to [32, 256]; the XLA path
    // overrides via TrainerOptions.block_size = meta.b.
    (op.n() / 16).clamp(32, 256)
}

// ---------------------------------------------------------------------------
// Exact-optimisation baseline (Figs 5, 8, 11-13)
// ---------------------------------------------------------------------------

/// Run exact (Cholesky) marginal-likelihood optimisation with the same
/// Adam/softplus outer loop, via the backend's exact path.
pub fn run_exact(
    op: &mut dyn KernelOperator,
    y: &[f64],
    steps: usize,
    lr: f64,
    init_theta: f64,
) -> Result<Vec<(Vec<f64>, f64)>> {
    let d = op.d();
    let mut params = SoftplusParams::from_theta(&vec![init_theta; d + 2]);
    let mut adam = Adam::new(d + 2, lr);
    let mut traj = Vec::with_capacity(steps);
    for _ in 0..steps {
        let theta = params.theta();
        op.set_hp(&crate::kernels::Hyperparams::unpack(&theta, d));
        let (mll, grad) = op
            .exact_mll(y)
            .ok_or_else(|| anyhow::anyhow!("backend has no exact MLL path"))?;
        traj.push((theta, mll));
        let grad_nu = params.chain_grad(&grad);
        adam.step(&mut params.nu, &grad_nu);
    }
    Ok(traj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::operators::DenseOperator;

    fn trainer(solver: SolverKind, estimator: EstimatorKind, warm: bool) -> (Trainer, Dataset) {
        let ds = data::generate(&data::spec("test").unwrap());
        let op = DenseOperator::new(&ds, 8, 32);
        let opts = TrainerOptions {
            solver,
            estimator,
            warm_start: warm,
            lr: 0.1,
            epoch_cap: 200.0,
            block_size: Some(64),
            sgd_lr: Some(8.0),
            seed: 7,
            ..Default::default()
        };
        (Trainer::new(opts, Box::new(op), &ds), ds)
    }

    #[test]
    fn training_improves_exact_mll() {
        let (mut t, ds) = trainer(SolverKind::Cg, EstimatorKind::Pathwise, true);
        let op0 = DenseOperator::new(&ds, 8, 32);
        let mll0 = {
            let mut o = op0;
            o.set_hp(&crate::kernels::Hyperparams::ones(4));
            o.exact_mll(&ds.y_train).unwrap().0
        };
        let out = t.run(15).unwrap();
        let mll1 = {
            let mut o = DenseOperator::new(&ds, 8, 32);
            o.set_hp(&crate::kernels::Hyperparams::unpack(&out.theta, 4));
            o.exact_mll(&ds.y_train).unwrap().0
        };
        assert!(mll1 > mll0, "mll {mll0} -> {mll1}");
        assert!(out.final_metrics.llh.is_finite());
    }

    #[test]
    fn warm_start_reduces_total_epochs() {
        let (mut cold, _) = trainer(SolverKind::Ap, EstimatorKind::Standard, false);
        let (mut warm, _) = trainer(SolverKind::Ap, EstimatorKind::Standard, true);
        let out_cold = cold.run(10).unwrap();
        let out_warm = warm.run(10).unwrap();
        assert!(
            out_warm.total_epochs < out_cold.total_epochs,
            "warm {} cold {}",
            out_warm.total_epochs,
            out_cold.total_epochs
        );
    }

    #[test]
    fn pathwise_reduces_epochs_vs_standard_high_precision() {
        // The test dataset has sigma_true = 0.3; after a few steps noise
        // precision rises and the pathwise advantage (eq 14 vs 15) shows.
        let (mut st, _) = trainer(SolverKind::Ap, EstimatorKind::Standard, false);
        let (mut pw, _) = trainer(SolverKind::Ap, EstimatorKind::Pathwise, false);
        let out_st = st.run(12).unwrap();
        let out_pw = pw.run(12).unwrap();
        assert!(
            out_pw.total_epochs <= out_st.total_epochs * 1.1,
            "pathwise {} vs standard {}",
            out_pw.total_epochs,
            out_st.total_epochs
        );
    }

    #[test]
    fn budget_mode_respects_epoch_cap() {
        let ds = data::generate(&data::spec("test").unwrap());
        let op = DenseOperator::new(&ds, 8, 32);
        let opts = TrainerOptions {
            solver: SolverKind::Ap,
            estimator: EstimatorKind::Pathwise,
            warm_start: true,
            max_epochs: Some(3.0),
            block_size: Some(64),
            seed: 1,
            ..Default::default()
        };
        let mut t = Trainer::new(opts, Box::new(op), &ds);
        let out = t.run(5).unwrap();
        for tel in &out.telemetry {
            assert!(tel.epochs <= 3.0 + 1e-9, "{}", tel.epochs);
        }
    }

    #[test]
    fn warm_start_accumulates_progress_under_budget() {
        // Fig 10 phenomenon: with a tiny budget, warm starting drives the
        // residual down across outer steps while cold restarts cannot.
        let mk = |warm| {
            let ds = data::generate(&data::spec("test").unwrap());
            let op = DenseOperator::new(&ds, 8, 32);
            let opts = TrainerOptions {
                solver: SolverKind::Ap,
                estimator: EstimatorKind::Pathwise,
                warm_start: warm,
                max_epochs: Some(2.0),
                block_size: Some(64),
                lr: 0.05,
                seed: 3,
                ..Default::default()
            };
            Trainer::new(opts, Box::new(op), &ds)
        };
        let out_warm = mk(true).run(10).unwrap();
        let out_cold = mk(false).run(10).unwrap();
        let last_warm = out_warm.telemetry.last().unwrap().rz;
        let last_cold = out_cold.telemetry.last().unwrap().rz;
        assert!(last_warm < last_cold, "warm {last_warm} vs cold {last_cold}");
    }

    #[test]
    fn exact_baseline_increases_mll() {
        let ds = data::generate(&data::spec("test").unwrap());
        let mut op = DenseOperator::new(&ds, 8, 32);
        let traj = run_exact(&mut op, &ds.y_train, 10, 0.1, 1.0).unwrap();
        assert!(traj.last().unwrap().1 > traj.first().unwrap().1);
    }

    #[test]
    fn checkpoint_resume_reproduces_training() {
        // run 8 steps straight vs 4 + checkpoint/restore + 4: identical
        // thetas (warm-started, so no mid-run probe resampling).
        let (mut a, _) = trainer(SolverKind::Ap, EstimatorKind::Pathwise, true);
        a.run(8).unwrap();
        let (mut b1, ds) = trainer(SolverKind::Ap, EstimatorKind::Pathwise, true);
        b1.run(4).unwrap();
        let ck = b1.checkpoint();
        let op2 = DenseOperator::new(&ds, 8, 32);
        let opts2 = b1.opts.clone();
        let mut b2 = Trainer::new(opts2, Box::new(op2), &ds);
        b2.restore(&ck);
        b2.run(4).unwrap();
        let ta = a.theta();
        let tb = b2.theta();
        for (x, y) in ta.iter().zip(&tb) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn prediction_and_evaluation_solves_are_accounted() {
        // regression: solve_for_prediction discarded its SolveReport and
        // the Standard estimator's extra pathwise solves in evaluate were
        // uncounted, so totals under-reported real work.  The totals must
        // strictly exceed the per-step telemetry sum (final prediction
        // solve + Standard evaluation re-solve are on top of it).
        let (mut t, _) = trainer(SolverKind::Ap, EstimatorKind::Standard, false);
        let out = t.run(4).unwrap();
        let telemetry_epochs: f64 = out.telemetry.iter().map(|tel| tel.epochs).sum();
        assert!(
            out.total_epochs > telemetry_epochs + 1e-9,
            "totals {} must include prediction/evaluation work beyond telemetry {}",
            out.total_epochs,
            telemetry_epochs
        );
        let telemetry_secs: f64 = out.telemetry.iter().map(|tel| tel.solver_secs).sum();
        assert!(out.solver_secs >= telemetry_secs);
    }

    #[test]
    fn autotune_probe_epochs_are_accounted() {
        let ds = data::generate(&data::spec("test").unwrap());
        let mk = |sgd_lr| {
            let op = DenseOperator::new(&ds, 8, 32);
            let opts = TrainerOptions {
                solver: SolverKind::Sgd,
                estimator: EstimatorKind::Pathwise,
                warm_start: true,
                epoch_cap: 200.0,
                block_size: Some(64),
                sgd_lr,
                seed: 7,
                ..Default::default()
            };
            Trainer::new(opts, Box::new(op), &ds)
        };
        // identical run except the None trainer pays for autotune probes
        let out_fixed = mk(Some(8.0)).run(3).unwrap();
        let out_tuned = mk(None).run(3).unwrap();
        let tel_fixed: f64 = out_fixed.telemetry.iter().map(|tel| tel.epochs).sum();
        let tel_tuned: f64 = out_tuned.telemetry.iter().map(|tel| tel.epochs).sum();
        // probes cost >= 1 epoch of extra accounted work relative to the
        // telemetry sum (which excludes them)
        assert!(
            out_tuned.total_epochs - tel_tuned >= out_fixed.total_epochs - tel_fixed + 1.0 - 1e-9,
            "tuned {} (tel {tel_tuned}) vs fixed {} (tel {tel_fixed})",
            out_tuned.total_epochs,
            out_fixed.total_epochs
        );
        assert!(out_tuned.sgd_lr_used > 0.0);
    }

    #[test]
    fn cold_start_checkpoint_resume_reproduces_training() {
        // regression: checkpoints omitted the trainer RNG state, so
        // cold-start runs (which resample probes from that RNG every
        // step) diverged after a restore.  8 straight steps vs
        // 4 + checkpoint/restore + 4 must give identical thetas.
        let (mut a, _) = trainer(SolverKind::Ap, EstimatorKind::Pathwise, false);
        a.run(8).unwrap();
        let (mut b1, ds) = trainer(SolverKind::Ap, EstimatorKind::Pathwise, false);
        b1.run(4).unwrap();
        let ck = b1.checkpoint();
        assert!(ck.rng.is_some(), "checkpoint must carry the RNG state");
        let op2 = DenseOperator::new(&ds, 8, 32);
        let mut b2 = Trainer::new(b1.opts.clone(), Box::new(op2), &ds);
        b2.restore(&ck);
        b2.run(4).unwrap();
        for (x, y) in a.theta().iter().zip(&b2.theta()) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn restored_sgd_keeps_autotuned_rate() {
        // the checkpoint carries the resolved SGD learning rate, so a
        // resumed run neither re-autotunes (at sharpened hyperparameters,
        // against the paper's first-step-only protocol) nor re-pays the
        // probe epochs
        let ds = data::generate(&data::spec("test").unwrap());
        let opts = TrainerOptions {
            solver: SolverKind::Sgd,
            estimator: EstimatorKind::Pathwise,
            warm_start: true,
            epoch_cap: 200.0,
            block_size: Some(64),
            sgd_lr: None, // autotune on the first step
            seed: 7,
            ..Default::default()
        };
        let op = DenseOperator::new(&ds, 8, 32);
        let mut t1 = Trainer::new(opts.clone(), Box::new(op), &ds);
        let out1 = t1.run(2).unwrap();
        assert!(out1.sgd_lr_used > 0.0);
        let ck = t1.checkpoint();
        assert_eq!(ck.sgd_lr, Some(out1.sgd_lr_used));

        let op2 = DenseOperator::new(&ds, 8, 32);
        let mut t2 = Trainer::new(opts, Box::new(op2), &ds);
        t2.restore(&ck);
        let out2 = t2.run(2).unwrap();
        assert_eq!(out2.sgd_lr_used, out1.sgd_lr_used);
    }

    #[test]
    fn preconditioner_cache_is_shared_across_solves() {
        // With the Standard estimator, `evaluate` runs an extra pathwise
        // solve at the same hyperparameters as the final prediction solve;
        // the coordinator-owned cache must serve it from the existing
        // factorisation instead of rebuilding.
        let (mut t, _) = trainer(SolverKind::Cg, EstimatorKind::Standard, true);
        let steps = 5;
        let out = t.run(steps).unwrap();
        assert!(out.final_metrics.rmse.is_finite());
        let builds = t.precond_cache().woodbury_builds();
        // one build per distinct theta: one per training step plus the
        // final (post-Adam) theta of the prediction solve
        assert!(
            builds <= steps as u64 + 1,
            "cache not shared: {builds} builds for {steps} steps"
        );
        assert!(t.precond_cache().hits() >= 1, "evaluation solve should hit the cache");
    }

    #[test]
    fn telemetry_is_complete() {
        let (mut t, _) = trainer(SolverKind::Sgd, EstimatorKind::Pathwise, true);
        let out = t.run(4).unwrap();
        assert_eq!(out.telemetry.len(), 4);
        for (i, tel) in out.telemetry.iter().enumerate() {
            assert_eq!(tel.step, i);
            assert_eq!(tel.theta.len(), 6);
            assert_eq!(tel.grad.len(), 6);
            assert!(tel.epochs > 0.0);
        }
        assert!(out.sgd_lr_used > 0.0);
    }
}
