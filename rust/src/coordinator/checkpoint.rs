//! Training-state checkpointing: persist the coordinator's resumable state
//! (hyperparameters nu, Adam moments, warm-start solution, probe
//! randomness seed bookkeeping) in a small self-describing binary format.
//!
//! Production motivation: the paper's large runs take hours (HOUSEELECTRIC:
//! 32h in Table 10) — a crash without checkpoints loses the accumulated
//! warm-start progress, which is exactly the asset warm starting builds.
//!
//! Format (little-endian): magic "IGPCKPT2", then length-prefixed f64
//! vectors in fixed order: nu, adam_m, adam_v, v_store (+ rows/cols), plus
//! step counter, seed, the trainer RNG state and the resolved SGD
//! learning rate.  No external serde available offline.  Version-1 files
//! ("IGPCKPT1", no RNG/lr trailer) still load — with `rng: None`, a
//! restore keeps the trainer's current stream, which is only exactly
//! reproducible for warm-started runs (frozen probes); cold-start runs
//! need v2.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::linalg::Mat;
use crate::util::rng::RngState;

const MAGIC_V1: &[u8; 8] = b"IGPCKPT1";
const MAGIC_V2: &[u8; 8] = b"IGPCKPT2";

#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub seed: u64,
    pub nu: Vec<f64>,
    pub adam_m: Vec<f64>,
    pub adam_v: Vec<f64>,
    pub adam_t: u64,
    pub v_store: Mat,
    /// Trainer RNG mid-stream state (None only for legacy v1 files).
    /// Without it, runs that keep drawing randomness after the restore
    /// point — cold starts resample probes every step — do not reproduce.
    pub rng: Option<RngState>,
    /// SGD learning rate resolved by the first-step autotune (None when
    /// not yet resolved, or for legacy v1 files).  Restoring it keeps a
    /// resumed SGD run from re-autotuning at the sharpened
    /// hyperparameters, which the paper's protocol forbids.
    pub sgd_lr: Option<f64>,
}

fn write_vec(out: &mut impl Write, v: &[f64]) -> Result<()> {
    out.write_all(&(v.len() as u64).to_le_bytes())?;
    for x in v {
        out.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_u64(inp: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    inp.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_vec(inp: &mut impl Read) -> Result<Vec<f64>> {
    let len = read_u64(inp)? as usize;
    if len > (1 << 28) {
        bail!("checkpoint vector too large ({len})");
    }
    let mut v = Vec::with_capacity(len);
    let mut b = [0u8; 8];
    for _ in 0..len {
        inp.read_exact(&mut b)?;
        v.push(f64::from_le_bytes(b));
    }
    Ok(v)
}

impl Checkpoint {
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = std::io::BufWriter::new(std::fs::File::create(&path)?);
        out.write_all(MAGIC_V2)?;
        out.write_all(&self.step.to_le_bytes())?;
        out.write_all(&self.seed.to_le_bytes())?;
        out.write_all(&self.adam_t.to_le_bytes())?;
        write_vec(&mut out, &self.nu)?;
        write_vec(&mut out, &self.adam_m)?;
        write_vec(&mut out, &self.adam_v)?;
        out.write_all(&(self.v_store.rows as u64).to_le_bytes())?;
        out.write_all(&(self.v_store.cols as u64).to_le_bytes())?;
        write_vec(&mut out, &self.v_store.data)?;
        // RNG state: presence flag, 4 state words, spare flag + value
        match &self.rng {
            Some(st) => {
                out.write_all(&1u64.to_le_bytes())?;
                for w in st.s {
                    out.write_all(&w.to_le_bytes())?;
                }
                match st.gauss_spare {
                    Some(g) => {
                        out.write_all(&1u64.to_le_bytes())?;
                        out.write_all(&g.to_le_bytes())?;
                    }
                    None => out.write_all(&0u64.to_le_bytes())?,
                }
            }
            None => out.write_all(&0u64.to_le_bytes())?,
        }
        // resolved SGD learning rate: presence flag + value
        match self.sgd_lr {
            Some(lr) => {
                out.write_all(&1u64.to_le_bytes())?;
                out.write_all(&lr.to_le_bytes())?;
            }
            None => out.write_all(&0u64.to_le_bytes())?,
        }
        out.flush()?;
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let mut inp = std::io::BufReader::new(
            std::fs::File::open(&path)
                .with_context(|| format!("opening {}", path.as_ref().display()))?,
        );
        let mut magic = [0u8; 8];
        inp.read_exact(&mut magic)?;
        let version = match &magic {
            m if m == MAGIC_V1 => 1,
            m if m == MAGIC_V2 => 2,
            _ => bail!("not an igp checkpoint (bad magic)"),
        };
        let step = read_u64(&mut inp)?;
        let seed = read_u64(&mut inp)?;
        let adam_t = read_u64(&mut inp)?;
        let nu = read_vec(&mut inp)?;
        let adam_m = read_vec(&mut inp)?;
        let adam_v = read_vec(&mut inp)?;
        let rows = read_u64(&mut inp)? as usize;
        let cols = read_u64(&mut inp)? as usize;
        let data = read_vec(&mut inp)?;
        if data.len() != rows * cols {
            bail!("checkpoint v_store shape mismatch: {}x{cols} vs {} values", rows, data.len());
        }
        let rng = if version >= 2 {
            match read_u64(&mut inp)? {
                0 => None,
                1 => {
                    let mut s = [0u64; 4];
                    for w in &mut s {
                        *w = read_u64(&mut inp)?;
                    }
                    let gauss_spare = match read_u64(&mut inp)? {
                        0 => None,
                        1 => {
                            let mut b = [0u8; 8];
                            inp.read_exact(&mut b)?;
                            Some(f64::from_le_bytes(b))
                        }
                        other => bail!("bad rng spare flag {other}"),
                    };
                    Some(RngState { s, gauss_spare })
                }
                other => bail!("bad rng presence flag {other}"),
            }
        } else {
            None
        };
        let sgd_lr = if version >= 2 {
            match read_u64(&mut inp)? {
                0 => None,
                1 => {
                    let mut b = [0u8; 8];
                    inp.read_exact(&mut b)?;
                    Some(f64::from_le_bytes(b))
                }
                other => bail!("bad sgd_lr presence flag {other}"),
            }
        } else {
            None
        };
        Ok(Checkpoint {
            step,
            seed,
            nu,
            adam_m,
            adam_v,
            adam_t,
            v_store: Mat::from_vec(rows, cols, data),
            rng,
            sgd_lr,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            step: 17,
            seed: 42,
            nu: vec![0.1, -0.5, 2.0],
            adam_m: vec![1e-3, 2e-3, -3e-3],
            adam_v: vec![1e-6, 4e-6, 9e-6],
            adam_t: 17,
            v_store: Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            rng: Some(RngState { s: [1, 2, 3, u64::MAX], gauss_spare: Some(-0.25) }),
            sgd_lr: Some(6.5),
        }
    }

    #[test]
    fn roundtrip_exact() {
        let d = std::env::temp_dir().join("igp_ckpt_rt");
        let p = d.join("c.ckpt");
        let c = sample();
        c.save(&p).unwrap();
        let l = Checkpoint::load(&p).unwrap();
        assert_eq!(c, l);
    }

    #[test]
    fn roundtrip_without_rng_and_without_spare() {
        let d = std::env::temp_dir().join("igp_ckpt_rt2");
        for rng in [None, Some(RngState { s: [9, 8, 7, 6], gauss_spare: None })] {
            for sgd_lr in [None, Some(12.0)] {
                let p = d.join("c.ckpt");
                let c = Checkpoint { rng: rng.clone(), sgd_lr, ..sample() };
                c.save(&p).unwrap();
                assert_eq!(Checkpoint::load(&p).unwrap(), c);
            }
        }
    }

    #[test]
    fn legacy_v1_loads_with_no_rng() {
        // a v1 file is a v2 file minus the rng + sgd_lr trailer, with the
        // old magic
        let d = std::env::temp_dir().join("igp_ckpt_v1");
        let p = d.join("c.ckpt");
        let c = sample();
        c.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[..8].copy_from_slice(b"IGPCKPT1");
        // drop the trailer: rng flag + 4 words + spare flag + spare value,
        // then sgd_lr flag + value (sample() has both Some)
        let trailer = 8 * (1 + 4 + 1 + 1) + 8 * (1 + 1);
        bytes.truncate(bytes.len() - trailer);
        std::fs::write(&p, &bytes).unwrap();
        let l = Checkpoint::load(&p).unwrap();
        assert_eq!(l.rng, None);
        assert_eq!(l.sgd_lr, None);
        assert_eq!(l.v_store, c.v_store);
        assert_eq!(l.step, c.step);
    }

    #[test]
    fn bad_magic_rejected() {
        let d = std::env::temp_dir().join("igp_ckpt_bad");
        std::fs::create_dir_all(&d).unwrap();
        let p = d.join("bad.ckpt");
        std::fs::write(&p, b"NOTACKPTxxxxxxxxxxxx").unwrap();
        assert!(Checkpoint::load(&p).is_err());
    }

    #[test]
    fn truncated_file_rejected() {
        let d = std::env::temp_dir().join("igp_ckpt_trunc");
        let p = d.join("t.ckpt");
        sample().save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(Checkpoint::load(&p).is_err());
    }
}
