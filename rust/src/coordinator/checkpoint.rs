//! Training-state checkpointing: persist the coordinator's resumable state
//! (hyperparameters nu, Adam moments, warm-start solution, probe
//! randomness seed bookkeeping) in a small self-describing binary format.
//!
//! Production motivation: the paper's large runs take hours (HOUSEELECTRIC:
//! 32h in Table 10) — a crash without checkpoints loses the accumulated
//! warm-start progress, which is exactly the asset warm starting builds.
//!
//! Format v3 (little-endian): magic "IGPCKPT3", a payload, then the
//! FNV-1a 64 hash of the payload ([`crate::fault::fnv1a`]) so torn writes
//! and media corruption surface as a typed
//! [`FaultError::CheckpointChecksum`] instead of a garbage load.  The
//! payload is the v2 layout: length-prefixed f64 vectors in fixed order
//! (nu, adam_m, adam_v, v_store + rows/cols) after step/seed/adam_t
//! counters, then the trainer RNG state and the resolved SGD learning
//! rate.  No external serde available offline.
//!
//! Older files still load: "IGPCKPT2" (same payload, no checksum) and
//! "IGPCKPT1" (no RNG/lr trailer; `rng: None` keeps the trainer's current
//! stream, exactly reproducible only for warm-started runs).  Every
//! section length is validated against the bytes actually present before
//! any allocation, so a truncated or length-corrupted file of ANY version
//! is a typed [`FaultError::CheckpointTruncated`] /
//! [`FaultError::CheckpointMalformed`] — never a panic, oversized
//! allocation, or silent zero-fill.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::fault::{fnv1a, FaultError};
use crate::linalg::Mat;
use crate::util::rng::RngState;

const MAGIC_V1: &[u8; 8] = b"IGPCKPT1";
const MAGIC_V2: &[u8; 8] = b"IGPCKPT2";
const MAGIC_V3: &[u8; 8] = b"IGPCKPT3";

#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub seed: u64,
    pub nu: Vec<f64>,
    pub adam_m: Vec<f64>,
    pub adam_v: Vec<f64>,
    pub adam_t: u64,
    pub v_store: Mat,
    /// Trainer RNG mid-stream state (None only for legacy v1 files).
    /// Without it, runs that keep drawing randomness after the restore
    /// point — cold starts resample probes every step — do not reproduce.
    pub rng: Option<RngState>,
    /// SGD learning rate resolved by the first-step autotune (None when
    /// not yet resolved, or for legacy v1 files).  Restoring it keeps a
    /// resumed SGD run from re-autotuning at the sharpened
    /// hyperparameters, which the paper's protocol forbids.
    pub sgd_lr: Option<f64>,
}

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_vec(out: &mut Vec<u8>, v: &[f64]) {
    push_u64(out, v.len() as u64);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Bounds-checked byte-slice reader shared by every checkpoint version:
/// each read names its section and validates the requested length against
/// the bytes remaining BEFORE allocating or copying, so corrupted on-disk
/// lengths surface as typed errors instead of multi-gigabyte allocations
/// or `read_exact` zero-fill surprises.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, section: &'static str) -> Result<&'a [u8], FaultError> {
        if n > self.remaining() {
            return Err(FaultError::CheckpointTruncated {
                section,
                need: n,
                have: self.remaining(),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u64(&mut self, section: &'static str) -> Result<u64, FaultError> {
        let b = self.take(8, section)?;
        let mut w = [0u8; 8];
        w.copy_from_slice(b);
        Ok(u64::from_le_bytes(w))
    }

    fn f64(&mut self, section: &'static str) -> Result<f64, FaultError> {
        Ok(f64::from_bits(self.u64(section)?))
    }

    /// Length-prefixed f64 vector; the byte count implied by the prefix is
    /// validated against the remaining bytes before the allocation.
    fn vec(&mut self, section: &'static str) -> Result<Vec<f64>, FaultError> {
        let len = self.u64(section)? as usize;
        let need = len.checked_mul(8).ok_or(FaultError::CheckpointMalformed {
            detail: format!("section '{section}' length overflows: {len} elements"),
        })?;
        let bytes = self.take(need, section)?;
        let mut v = Vec::with_capacity(len);
        for c in bytes.chunks_exact(8) {
            let mut w = [0u8; 8];
            w.copy_from_slice(c);
            v.push(f64::from_le_bytes(w));
        }
        Ok(v)
    }
}

impl Checkpoint {
    /// The version-3 payload (everything between the magic and the
    /// checksum; byte-identical to a v2 file's body).
    fn payload(&self) -> Vec<u8> {
        let mut out = Vec::new();
        push_u64(&mut out, self.step);
        push_u64(&mut out, self.seed);
        push_u64(&mut out, self.adam_t);
        push_vec(&mut out, &self.nu);
        push_vec(&mut out, &self.adam_m);
        push_vec(&mut out, &self.adam_v);
        push_u64(&mut out, self.v_store.rows as u64);
        push_u64(&mut out, self.v_store.cols as u64);
        push_vec(&mut out, &self.v_store.data);
        // RNG state: presence flag, 4 state words, spare flag + value
        match &self.rng {
            Some(st) => {
                push_u64(&mut out, 1);
                for w in st.s {
                    push_u64(&mut out, w);
                }
                match st.gauss_spare {
                    Some(g) => {
                        push_u64(&mut out, 1);
                        push_u64(&mut out, g.to_bits());
                    }
                    None => push_u64(&mut out, 0),
                }
            }
            None => push_u64(&mut out, 0),
        }
        // resolved SGD learning rate: presence flag + value
        match self.sgd_lr {
            Some(lr) => {
                push_u64(&mut out, 1);
                push_u64(&mut out, lr.to_bits());
            }
            None => push_u64(&mut out, 0),
        }
        out
    }

    /// The complete v3 on-disk image: magic + payload + FNV-1a(payload).
    /// Exposed so the chaos checkpoint site can corrupt the exact bytes a
    /// save would write.
    pub fn file_bytes(&self) -> Vec<u8> {
        let payload = self.payload();
        let mut out = Vec::with_capacity(8 + payload.len() + 8);
        out.extend_from_slice(MAGIC_V3);
        let sum = fnv1a(&payload);
        out.extend_from_slice(&payload);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(&path, self.file_bytes())
            .with_context(|| format!("writing {}", path.as_ref().display()))?;
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let bytes = std::fs::read(&path)
            .with_context(|| format!("opening {}", path.as_ref().display()))?;
        Self::from_bytes(&bytes)
    }

    /// Parse a checkpoint image of any supported version (the on-disk
    /// byte layout of [`Checkpoint::file_bytes`] and its v1/v2
    /// predecessors).  Every length is validated before use; corruption
    /// is always a typed error.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut cur = Cursor::new(bytes);
        let magic = cur.take(8, "magic")?;
        let version = match magic {
            m if m == MAGIC_V1 => 1,
            m if m == MAGIC_V2 => 2,
            m if m == MAGIC_V3 => 3,
            _ => bail!("not an igp checkpoint (bad magic)"),
        };
        let body = if version >= 3 {
            // magic | payload | 8-byte checksum — verify before parsing
            if cur.remaining() < 8 {
                return Err(FaultError::CheckpointTruncated {
                    section: "checksum",
                    need: 8,
                    have: cur.remaining(),
                })?;
            }
            let payload = &bytes[8..bytes.len() - 8];
            let mut w = [0u8; 8];
            w.copy_from_slice(&bytes[bytes.len() - 8..]);
            let stored = u64::from_le_bytes(w);
            let computed = fnv1a(payload);
            if stored != computed {
                return Err(FaultError::CheckpointChecksum { stored, computed })?;
            }
            payload
        } else {
            &bytes[8..]
        };
        let mut cur = Cursor::new(body);
        let step = cur.u64("step")?;
        let seed = cur.u64("seed")?;
        let adam_t = cur.u64("adam_t")?;
        let nu = cur.vec("nu")?;
        let adam_m = cur.vec("adam_m")?;
        let adam_v = cur.vec("adam_v")?;
        let rows = cur.u64("v_store shape")? as usize;
        let cols = cur.u64("v_store shape")? as usize;
        let data = cur.vec("v_store")?;
        let cells = rows.checked_mul(cols).ok_or(FaultError::CheckpointMalformed {
            detail: format!("v_store shape {rows}x{cols} overflows"),
        })?;
        if data.len() != cells {
            return Err(FaultError::CheckpointMalformed {
                detail: format!(
                    "v_store shape mismatch: {rows}x{cols} vs {} values",
                    data.len()
                ),
            })?;
        }
        let rng = if version >= 2 {
            match cur.u64("rng flag")? {
                0 => None,
                1 => {
                    let mut s = [0u64; 4];
                    for w in &mut s {
                        *w = cur.u64("rng state")?;
                    }
                    let gauss_spare = match cur.u64("rng spare flag")? {
                        0 => None,
                        1 => Some(cur.f64("rng spare")?),
                        other => {
                            return Err(FaultError::CheckpointMalformed {
                                detail: format!("bad rng spare flag {other}"),
                            })?
                        }
                    };
                    Some(RngState { s, gauss_spare })
                }
                other => {
                    return Err(FaultError::CheckpointMalformed {
                        detail: format!("bad rng presence flag {other}"),
                    })?
                }
            }
        } else {
            None
        };
        let sgd_lr = if version >= 2 {
            match cur.u64("sgd_lr flag")? {
                0 => None,
                1 => Some(cur.f64("sgd_lr")?),
                other => {
                    return Err(FaultError::CheckpointMalformed {
                        detail: format!("bad sgd_lr presence flag {other}"),
                    })?
                }
            }
        } else {
            None
        };
        Ok(Checkpoint {
            step,
            seed,
            nu,
            adam_m,
            adam_v,
            adam_t,
            v_store: Mat::from_vec(rows, cols, data),
            rng,
            sgd_lr,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            step: 17,
            seed: 42,
            nu: vec![0.1, -0.5, 2.0],
            adam_m: vec![1e-3, 2e-3, -3e-3],
            adam_v: vec![1e-6, 4e-6, 9e-6],
            adam_t: 17,
            v_store: Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            rng: Some(RngState { s: [1, 2, 3, u64::MAX], gauss_spare: Some(-0.25) }),
            sgd_lr: Some(6.5),
        }
    }

    #[test]
    fn roundtrip_exact() {
        let d = std::env::temp_dir().join("igp_ckpt_rt");
        let p = d.join("c.ckpt");
        let c = sample();
        c.save(&p).unwrap();
        let l = Checkpoint::load(&p).unwrap();
        assert_eq!(c, l);
    }

    #[test]
    fn roundtrip_without_rng_and_without_spare() {
        let d = std::env::temp_dir().join("igp_ckpt_rt2");
        for rng in [None, Some(RngState { s: [9, 8, 7, 6], gauss_spare: None })] {
            for sgd_lr in [None, Some(12.0)] {
                let p = d.join("c.ckpt");
                let c = Checkpoint { rng: rng.clone(), sgd_lr, ..sample() };
                c.save(&p).unwrap();
                assert_eq!(Checkpoint::load(&p).unwrap(), c);
            }
        }
    }

    #[test]
    fn legacy_v1_loads_with_no_rng() {
        // a v1 file is the payload minus the rng + sgd_lr trailer, under
        // the old magic and with no checksum
        let d = std::env::temp_dir().join("igp_ckpt_v1");
        std::fs::create_dir_all(&d).unwrap();
        let p = d.join("c.ckpt");
        let c = sample();
        let payload = c.payload();
        // rng flag + 4 words + spare flag + spare value, then sgd_lr
        // flag + value (sample() has both Some)
        let trailer = 8 * (1 + 4 + 1 + 1) + 8 * (1 + 1);
        let mut bytes = MAGIC_V1.to_vec();
        bytes.extend_from_slice(&payload[..payload.len() - trailer]);
        std::fs::write(&p, &bytes).unwrap();
        let l = Checkpoint::load(&p).unwrap();
        assert_eq!(l.rng, None);
        assert_eq!(l.sgd_lr, None);
        assert_eq!(l.v_store, c.v_store);
        assert_eq!(l.step, c.step);
    }

    #[test]
    fn legacy_v2_loads_exactly() {
        // a v2 file is the full payload under the v2 magic, no checksum
        let d = std::env::temp_dir().join("igp_ckpt_v2");
        std::fs::create_dir_all(&d).unwrap();
        let p = d.join("c.ckpt");
        let c = sample();
        let mut bytes = MAGIC_V2.to_vec();
        bytes.extend_from_slice(&c.payload());
        std::fs::write(&p, &bytes).unwrap();
        assert_eq!(Checkpoint::load(&p).unwrap(), c);
    }

    #[test]
    fn bad_magic_rejected() {
        let d = std::env::temp_dir().join("igp_ckpt_bad");
        std::fs::create_dir_all(&d).unwrap();
        let p = d.join("bad.ckpt");
        std::fs::write(&p, b"NOTACKPTxxxxxxxxxxxx").unwrap();
        assert!(Checkpoint::load(&p).is_err());
    }

    #[test]
    fn truncated_file_rejected_at_every_length() {
        // satellite regression: EVERY prefix of a valid file must fail
        // with a typed error, never panic or misparse
        let full = sample().file_bytes();
        for keep in 0..full.len() {
            let e = Checkpoint::from_bytes(&full[..keep]);
            assert!(e.is_err(), "prefix of {keep} bytes must be rejected");
        }
        assert!(Checkpoint::from_bytes(&full).is_ok());
    }

    #[test]
    fn bit_flip_is_a_checksum_error() {
        let mut bytes = sample().file_bytes();
        // flip one payload bit (past the magic, before the checksum)
        let mid = 8 + (bytes.len() - 16) / 2;
        bytes[mid] ^= 0x10;
        let e = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(e.to_string().contains("checksum mismatch"), "{e:#}");
    }

    #[test]
    fn corrupt_length_prefix_is_typed_not_an_allocation() {
        // v2 path (no checksum to save us): a corrupted nu length that
        // claims far more data than the file holds must be a typed
        // truncation error, not a giant allocation or zero-fill
        let c = sample();
        let mut bytes = MAGIC_V2.to_vec();
        bytes.extend_from_slice(&c.payload());
        let nu_len_off = 8 + 24; // magic + step/seed/adam_t
        bytes[nu_len_off..nu_len_off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        let e = Checkpoint::from_bytes(&bytes).unwrap_err();
        let msg = format!("{e:#}");
        assert!(
            msg.contains("overflow") || msg.contains("truncated"),
            "unexpected error: {msg}"
        );
        // a large-but-not-overflowing claim is a truncation naming the section
        bytes[nu_len_off..nu_len_off + 8].copy_from_slice(&(1u64 << 30).to_le_bytes());
        let e = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(format!("{e:#}").contains("'nu'"), "{e:#}");
    }

    #[test]
    fn shape_mismatch_is_typed() {
        // corrupt the v_store rows field so rows*cols != data.len()
        let c = sample();
        let mut bytes = MAGIC_V2.to_vec();
        bytes.extend_from_slice(&c.payload());
        // offset of rows: magic + 3 u64 + three vecs of 3 elements each
        let off = 8 + 24 + 3 * (8 + 3 * 8);
        bytes[off..off + 8].copy_from_slice(&5u64.to_le_bytes());
        let e = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(format!("{e:#}").contains("shape mismatch"), "{e:#}");
    }

    #[test]
    fn chaos_corruption_is_always_a_typed_error() {
        // whatever corrupt_bytes does at any seed — truncation or a bit
        // flip anywhere in the image — the load must fail typed, not panic
        use crate::fault::FaultPlan;
        let c = sample();
        for seed in 0..32u64 {
            let plan = FaultPlan::parse(&format!("seed={seed};checkpoint@0")).unwrap();
            let mut bytes = c.file_bytes();
            plan.corrupt_bytes(&mut bytes);
            if bytes == c.file_bytes() {
                continue; // a flip of a redundant bit pattern cannot occur; defensive
            }
            assert!(Checkpoint::from_bytes(&bytes).is_err(), "seed {seed}");
        }
    }
}
