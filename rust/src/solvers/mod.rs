//! Batched linear-system solvers for  H [v_y, v_1..v_s] = [y, b_1..b_s].
//!
//! The paper's three solvers — conjugate gradients (CG), alternating
//! projections (AP) and stochastic gradient descent (SGD) — with the three
//! studied coordination techniques:
//!
//! * **warm starting**: `v0` is an in/out parameter; the coordinator passes
//!   the previous outer step's solution and receives the new one;
//! * **epoch budgets**: compute is metered in *epochs* (one epoch = one
//!   full pass over the entries of H, the paper's solver-agnostic unit) and
//!   solvers stop at `max_epochs` even if the tolerance is not reached;
//! * **normalised tolerance**: each column solves the unit-normalised
//!   system b~ = b / (||b|| + eps); termination needs both the mean column
//!   (`ry`) and the probe average (`rz`) below `tolerance`.
//!
//! Solver *recurrences* are O(n k) Rust; every O(n^2) product goes through
//! [`KernelOperator`] (Pallas kernels on the XLA backend).

mod ap;
mod cg;
mod precond;
pub mod recurrence;
mod sgd;

pub use ap::ApSolver;
pub use cg::CgSolver;
pub use precond::{
    PreconditionerCache, SharedPreconditionerCache, ShardedJacobiPreconditioner, SolverPrecond,
    WoodburyPreconditioner,
};
pub use sgd::{autotune_lr, SgdSolver};

use crate::linalg::Mat;
use crate::operators::{HvScratch, KernelOperator, Precision};

pub const NORM_EPS: f64 = 1e-12;

#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum SolverKind {
    Cg,
    Ap,
    Sgd,
}

impl SolverKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "cg" => SolverKind::Cg,
            "ap" => SolverKind::Ap,
            "sgd" => SolverKind::Sgd,
            other => anyhow::bail!("unknown solver '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SolverKind::Cg => "cg",
            SolverKind::Ap => "ap",
            SolverKind::Sgd => "sgd",
        }
    }
}

/// AP block-selection rule (ablation: the paper/Wu et al. use greedy).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum ApSelection {
    /// Algorithm 2: block with the largest summed-column residual norm.
    Greedy,
    /// Uniform random block.
    Random,
    /// Round-robin sweep.
    Cyclic,
}

#[derive(Clone, Debug)]
pub struct SolveOptions {
    /// Relative residual norm tolerance tau (paper: 0.01).
    pub tolerance: f64,
    /// Budget in epochs; f64 because AP/SGD iterations are fractional
    /// epochs (b/n each).
    pub max_epochs: f64,
    /// CG preconditioner rank (paper: pivoted Cholesky rank 100).
    pub precond_rank: usize,
    /// AP block size == SGD batch size (must match the artifact's b).
    pub block_size: usize,
    pub sgd_lr: f64,
    pub sgd_momentum: f64,
    /// Polyak (tail) iterate averaging for SGD (paper: off, because it
    /// interferes with the residual-estimation heuristic).
    pub sgd_polyak: bool,
    /// Halve-and-retry on detected SGD divergence (robustness feature
    /// motivated by the paper's Section-5 observation; disabled inside
    /// the learning-rate auto-tuner so it can observe raw divergence).
    pub sgd_backoff: bool,
    pub ap_selection: ApSelection,
    /// Worker threads for the solver-recurrence layer (0 = auto: the
    /// `IGP_THREADS` env var, else all cores).  Results are
    /// bitwise-identical for every value — see [`recurrence`].
    pub threads: usize,
    /// AP: score blocks on the preconditioned residual M^-1 r instead of r
    /// (greedy selection only; needs `precond_rank > 0`).  Off by default.
    pub ap_block_precond: bool,
    /// CG/AP: factor the preconditioner as block-Jacobi over this many row
    /// shards ([`ShardedJacobiPreconditioner`]) instead of one global
    /// Woodbury build — per-shard factorisation cost and memory, at the
    /// price of a weaker preconditioner per unit rank.  0 or 1 keeps the
    /// global build (the default).
    pub precond_shards: usize,
    /// Compute precision for the O(n^2) operator products.  `F64` (the
    /// default) is the bitwise-parity reference path.  `F32` runs kernel
    /// products in f32 with f64 accumulation — CG wraps it in an
    /// iterative-refinement outer loop, AP/SGD apply their updates from
    /// reduced-precision products directly — and every solve ends with a
    /// drift guard (see [`SolveOptions::drift_ratio`]).  Takes effect only
    /// when the operator also reports
    /// [`KernelOperator::precision`]` == F32` (i.e. `set_precision(F32)`
    /// succeeded on the backend); otherwise the f64 path runs untouched.
    pub precision: Precision,
    /// Residual-drift guard ratio (Maddox et al.-style low-precision
    /// monitoring): after an f32 solve, the residual is recomputed in f64
    /// against the reference operator; if the f64 residual exceeds the
    /// solver's internally-tracked residual by more than this factor (or
    /// is non-finite), the solve falls back to the untouched f64 path and
    /// returns its (bitwise-reference) answer, with the wasted f32 epochs
    /// added to the report.  Ignored for `precision = F64`.
    pub drift_ratio: f64,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            tolerance: 0.01,
            max_epochs: 1000.0,
            precond_rank: 64,
            block_size: 64,
            sgd_lr: 10.0,
            sgd_momentum: 0.9,
            sgd_polyak: false,
            sgd_backoff: true,
            ap_selection: ApSelection::Greedy,
            threads: 0,
            ap_block_precond: false,
            precond_shards: 0,
            precision: Precision::F64,
            drift_ratio: 8.0,
        }
    }
}

/// Outcome of one inner-loop solve.
#[derive(Clone, Debug, PartialEq)]
pub struct SolveReport {
    pub iterations: usize,
    /// Epochs actually spent (incl. the exact initial residual when warm).
    pub epochs: f64,
    /// Final relative residual of the mean system  H v_y = y.
    pub ry: f64,
    /// Final average relative residual of the probe systems.
    pub rz: f64,
    pub converged: bool,
    /// RKHS distance proxy at initialisation: ||r_0||^2 summed over
    /// normalised columns (for Figs 3 and 6 diagnostics).
    pub init_residual_sq: f64,
}

impl SolveReport {
    /// The report for a solve that could not start — e.g. a preconditioner
    /// build hit a typed [`crate::linalg::LinalgError`] (non-finite kernel
    /// diagonal from a poisoned hyperparameter, non-SPD core).  Mirrors the
    /// solvers' NaN-residual divergence reports: zero iterations/epochs,
    /// NaN residuals, `converged = false`, so the outer loop treats it like
    /// any other diverged step instead of crashing.  `v0` is left
    /// untouched by callers returning this.
    pub(crate) fn aborted() -> SolveReport {
        SolveReport {
            iterations: 0,
            epochs: 0.0,
            ry: f64::NAN,
            rz: f64::NAN,
            converged: false,
            init_residual_sq: f64::NAN,
        }
    }
}

/// Common solver interface.  `v0` carries the warm start in and the
/// (raw-space) solution out.
pub trait LinearSolver {
    fn solve(
        &mut self,
        op: &dyn KernelOperator,
        b: &Mat,
        v0: &mut Mat,
        opts: &SolveOptions,
    ) -> SolveReport;

    fn kind(&self) -> SolverKind;

    /// Inject a coordinator-owned preconditioner cache so factorisations
    /// are shared across solves (and across solver instances).  Solvers
    /// without cached factorisations (SGD) ignore this.
    fn set_precond_cache(&mut self, _cache: SharedPreconditionerCache) {}
}

pub fn make_solver(kind: SolverKind) -> Box<dyn LinearSolver> {
    match kind {
        SolverKind::Cg => Box::new(CgSolver::default()),
        SolverKind::Ap => Box::new(ApSolver::default()),
        SolverKind::Sgd => Box::new(SgdSolver::default()),
    }
}

// ---------------------------------------------------------------------------
// Shared column helpers (Mat is row-major; columns are strided).
//
// The implementations live in [`recurrence`] — the parallel recurrence
// layer — with results bitwise-identical for every thread count.  These
// wrappers keep the historical signatures (auto thread count) for callers
// outside the solver inner loops; the solvers themselves resolve
// `SolveOptions::threads` once per solve and call the `recurrence`
// functions directly.
// ---------------------------------------------------------------------------

/// Per-column euclidean norms of a [n, k] matrix.
pub fn col_norms(m: &Mat) -> Vec<f64> {
    recurrence::col_norms(m, 0)
}

/// Scale column j by c[j].
pub fn scale_cols(m: &mut Mat, c: &[f64]) {
    recurrence::scale_cols(m, c, 0);
}

/// m += diag-scaled other: m[:,j] += a[j] * o[:,j].
pub fn axpy_cols(m: &mut Mat, a: &[f64], o: &Mat) {
    recurrence::axpy_cols(m, a, o, 0);
}

/// Per-column dot products <a_j, b_j>.
pub fn col_dots(a: &Mat, b: &Mat) -> Vec<f64> {
    recurrence::col_dots(a, b, 0)
}

/// (ry, rz) from a residual matrix whose columns are unit-normalised:
/// ry = ||R[:,0]||, rz = mean_j ||R[:,j]||, j >= 1.
pub fn residual_norms(r: &Mat) -> (f64, f64) {
    residual_norms_t(r, 0)
}

/// [`residual_norms`] with an explicit recurrence thread count.
pub fn residual_norms_t(r: &Mat, threads: usize) -> (f64, f64) {
    let norms = recurrence::col_norms(r, threads);
    let ry = norms[0];
    let rz = if norms.len() > 1 {
        crate::linalg::micro::sum(&norms[1..]) / (norms.len() - 1) as f64
    } else {
        0.0
    };
    (ry, rz)
}

/// Recompute the relative residuals of  H v = b  in full f64 against the
/// operator's reference path, ignoring any reduced-precision mode: returns
/// `(ry, rz)` with `r_j = ||b_j - (H v)_j|| / (||b_j|| + NORM_EPS)`, split
/// as (column 0, mean of columns 1..) exactly like [`residual_norms`].
///
/// Because the solvers track residuals of the unit-normalised system
/// b~ = b / (||b|| + eps), whose residual is r~_j = r_j / (||b_j|| + eps),
/// the values returned here are directly comparable to
/// [`SolveReport::ry`]/[`SolveReport::rz`].  Costs one epoch.
pub fn verify_residuals_f64(
    op: &dyn KernelOperator,
    b: &Mat,
    v: &Mat,
    threads: usize,
) -> (f64, f64) {
    let mut hv = Mat::zeros(v.rows, v.cols);
    op.hv_into(v, &mut hv, &HvScratch::default());
    let mut r = b.clone();
    recurrence::sub_assign(&mut r, &hv, threads);
    let bn = recurrence::col_norms(b, threads);
    let rn = recurrence::col_norms(&r, threads);
    let rel: Vec<f64> = rn.iter().zip(&bn).map(|(&r, &b)| r / (b + NORM_EPS)).collect();
    let ry = rel[0];
    let rz = if rel.len() > 1 {
        crate::linalg::micro::sum(&rel[1..]) / (rel.len() - 1) as f64
    } else {
        0.0
    };
    (ry, rz)
}

/// Drift-guard predicate shared by the three solvers' f32 paths: true when
/// the f64-recomputed residual exceeds the solver's internal residual by
/// more than `drift_ratio`, or is non-finite (the `!(..)` form catches
/// NaN).  `drift_ratio = 0.0` deterministically forces the fallback, since
/// an honest recomputation always drifts by some nonzero factor.
pub(crate) fn drift_exceeded(rep: &SolveReport, ry64: f64, rz64: f64, drift_ratio: f64) -> bool {
    let drift = (ry64 / rep.ry.max(1e-300)).max(rz64 / rep.rz.max(1e-300));
    !(drift <= drift_ratio)
}

/// Normalisation bookkeeping shared by all solvers: scales the system to
/// unit RHS columns, optionally computes the exact initial residual for a
/// warm start (costing one epoch), and restores raw space at the end.
pub struct Normalized {
    pub b: Mat,
    pub norms: Vec<f64>,
    pub warm_epoch_cost: f64,
}

impl Normalized {
    /// Scale b and v0 into normalised space.  Returns the residual
    /// R = b~ - H v~ and the epoch cost of computing it (1.0 if the warm
    /// start is nonzero, else 0.0 since R = b~ is free).
    pub fn setup(op: &dyn KernelOperator, b: &Mat, v0: &mut Mat) -> (Self, Mat) {
        Self::setup_t(op, b, v0, 0)
    }

    /// [`Normalized::setup`] with an explicit recurrence thread count.
    /// Allocates a fresh warm-start product buffer and scratch pool; inner
    /// solver loops that already own both should call
    /// [`Normalized::setup_pooled`] instead.
    pub fn setup_t(
        op: &dyn KernelOperator,
        b: &Mat,
        v0: &mut Mat,
        threads: usize,
    ) -> (Self, Mat) {
        let mut hv = Mat::zeros(v0.rows, v0.cols);
        Self::setup_pooled(op, b, v0, threads, &HvScratch::default(), &mut hv)
    }

    /// [`Normalized::setup_t`] with a caller-owned warm-start product
    /// buffer and panel-scratch pool — the allocation-free form for solver
    /// loops, which reuse the same `hv` output and `scratch` across the
    /// warm-start residual and every subsequent `hv_into` iteration.  `hv`
    /// must be [v0.rows, v0.cols] and is fully overwritten when the warm
    /// start is nonzero (untouched otherwise); bits are identical to
    /// [`Normalized::setup_t`] for every reuse pattern (the `hv_into`
    /// contract).
    pub fn setup_pooled(
        op: &dyn KernelOperator,
        b: &Mat,
        v0: &mut Mat,
        threads: usize,
        scratch: &HvScratch,
        hv: &mut Mat,
    ) -> (Self, Mat) {
        // solve-width checks: catch a store that did not grow with the
        // operator (online data arrival) before it turns into a silent
        // out-of-bounds product or a garbage solve
        assert_eq!(
            b.rows,
            op.n(),
            "solver RHS has {} rows but the operator holds n = {} training points \
             (stale targets after an online extension?)",
            b.rows,
            op.n()
        );
        assert_eq!(
            (v0.rows, v0.cols),
            (b.rows, b.cols),
            "warm-start store is {}x{} but the system is {}x{} \
             (stale v_store after an online extension?)",
            v0.rows,
            v0.cols,
            b.rows,
            b.cols
        );
        let mut norms = recurrence::col_norms(b, threads);
        for n in &mut norms {
            *n += NORM_EPS;
        }
        let inv: Vec<f64> = norms.iter().map(|&x| 1.0 / x).collect();
        let mut bs = b.clone();
        recurrence::scale_cols(&mut bs, &inv, threads);
        recurrence::scale_cols(v0, &inv, threads);
        let warm = v0.data.iter().any(|&x| x != 0.0);
        let (r, cost) = if warm {
            op.hv_into(v0, hv, scratch);
            let mut r = bs.clone();
            recurrence::sub_assign(&mut r, hv, threads);
            (r, 1.0)
        } else {
            (bs.clone(), 0.0)
        };
        (Normalized { b: bs, norms, warm_epoch_cost: cost }, r)
    }

    /// Restore v to raw space.
    pub fn finish(&self, v: &mut Mat) {
        self.finish_t(v, 0);
    }

    /// [`Normalized::finish`] with an explicit recurrence thread count.
    pub fn finish_t(&self, v: &mut Mat, threads: usize) {
        recurrence::scale_cols(v, &self.norms, threads);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn col_helpers_roundtrip() {
        let mut rng = Rng::new(0);
        let m = Mat::from_fn(10, 3, |_, _| rng.gaussian());
        let norms = col_norms(&m);
        let mut scaled = m.clone();
        scale_cols(&mut scaled, &norms.iter().map(|&x| 1.0 / x).collect::<Vec<_>>());
        for (j, _) in norms.iter().enumerate() {
            let n = crate::util::stats::norm2(&scaled.col(j));
            assert!((n - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn axpy_and_dots() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![10.0, 20.0, 30.0, 40.0]);
        let mut m = a.clone();
        axpy_cols(&mut m, &[2.0, 0.5], &b);
        assert_eq!(m.data, vec![21.0, 12.0, 63.0, 24.0]);
        let d = col_dots(&a, &b);
        assert_eq!(d, vec![1.0 * 10.0 + 3.0 * 30.0, 2.0 * 20.0 + 4.0 * 40.0]);
    }

    #[test]
    fn residual_norms_split() {
        let r = Mat::from_vec(2, 3, vec![3.0, 1.0, 0.0, 4.0, 0.0, 2.0]);
        let (ry, rz) = residual_norms(&r);
        assert!((ry - 5.0).abs() < 1e-12);
        assert!((rz - 1.5).abs() < 1e-12);
    }

    #[test]
    fn solver_kind_parse() {
        assert_eq!(SolverKind::parse("ap").unwrap(), SolverKind::Ap);
        assert!(SolverKind::parse("lu").is_err());
    }
}
