//! Alternating projections (Algorithm 2 of the paper; Wu et al. 2024):
//! greedy block-coordinate descent on the quadratic objective.  Each
//! iteration Cholesky-solves one diagonal block and downdates the full
//! residual through a K(X, X_I) product, i.e. touches b/n of H's entries,
//! so one epoch = n/b iterations.
//!
//! Per outer step the block Cholesky factors are computed once in Rust
//! (O(n b d + n b^2)) and cached; the paper notes the factorisation does
//! not dominate.

use super::{
    drift_exceeded, recurrence, residual_norms_t, verify_residuals_f64, ApSelection, LinearSolver,
    Normalized, PreconditionerCache, SharedPreconditionerCache, SolveOptions, SolveReport,
    SolverKind,
};
use crate::linalg::{micro, Mat};
use crate::operators::{KernelOperator, Precision};
use crate::util::rng::Rng;

pub struct ApSolver {
    /// Per-block Cholesky factors live in the shared preconditioner cache,
    /// keyed on (hyperparameter bits, block size) — changing either
    /// rebuilds.  The `Trainer` injects its own cache via
    /// [`LinearSolver::set_precond_cache`].
    cache: SharedPreconditionerCache,
    /// RNG for ApSelection::Random; cursor for ApSelection::Cyclic.
    rng: Rng,
    cursor: usize,
}

impl Default for ApSolver {
    fn default() -> Self {
        ApSolver { cache: PreconditionerCache::shared(), rng: Rng::new(0xA9), cursor: 0 }
    }
}

impl ApSolver {
    /// The solve body, parameterised on compute precision.  `F64` is the
    /// bitwise-parity reference path: the cost scale is exactly 1.0 (an
    /// IEEE-exact multiply), the products go through the plain `k_cols`,
    /// and every historical exact-epoch-count property is preserved.
    /// `F32` prices each block product at half an epoch fraction (half the
    /// memory traffic) and routes it through `k_cols_prec`.
    fn solve_impl(
        &mut self,
        op: &dyn KernelOperator,
        b_mat: &Mat,
        v0: &mut Mat,
        opts: &SolveOptions,
        prec: Precision,
    ) -> SolveReport {
        let cost_scale = if prec.is_f32() { 0.5 } else { 1.0 };
        let bsz = opts.block_size;
        let n = op.n();
        let threads = recurrence::resolve_threads(opts.threads);
        let noise_var = op.hp().noise_var();
        // a failed block factorisation (typed LinalgError from a poisoned
        // hyperparameter) becomes an aborted report, like any divergence
        let factors = match self.cache.ap_block_factors(op, bsz, threads) {
            Ok(f) => f,
            Err(_) => return SolveReport::aborted(),
        };
        // optional block preconditioning: greedy selection scores the
        // M^-1-preconditioned residual, steering sweeps toward blocks
        // whose error survives the low-rank correction (greedy-only: the
        // other selection rules never look at scores, so don't pay the
        // O(rho^2 n) build for them)
        let pre = if opts.ap_block_precond
            && opts.precond_rank > 0
            && opts.ap_selection == ApSelection::Greedy
        {
            match self.cache.solver_preconditioner(
                op,
                opts.precond_rank,
                opts.precond_shards,
                threads,
            ) {
                Ok(pre) => Some(pre),
                Err(_) => return SolveReport::aborted(),
            }
        } else {
            None
        };

        let (norm, mut r) = Normalized::setup_t(op, b_mat, v0, threads);
        let mut v = v0.clone();
        let init_residual_sq: f64 = micro::sum(&recurrence::col_sq_sums(&r, threads));

        let mut epochs = norm.warm_epoch_cost;
        let mut iterations = 0usize;
        let (mut ry, mut rz) = residual_norms_t(&r, threads);
        let tol = opts.tolerance;
        let nblocks = (n + bsz - 1) / bsz;
        // Budget guard: the loop continues while the *cheapest selectable*
        // block still fits the budget.  With a ragged tail (block does not
        // divide n — routine after online arrivals) that is the tail's
        // actual fraction, not the full-block cost: pricing every
        // iteration at full-block cost made the solver exit without
        // running a tail iteration it could afford.  Greedy selection then
        // restricts itself to affordable blocks, so the budget is never
        // exceeded either.
        let block_cost =
            |blk: usize| cost_scale * ((((blk + 1) * bsz).min(n) - blk * bsz) as f64 / n as f64);
        let min_epoch_per_iter = block_cost(nblocks - 1).min(block_cost(0));
        // Greedy no-progress guards.  Solving block I leaves r[I] at fp
        // dust, so what a repeat selection *means* depends on the scoring:
        //
        // - Direct scoring reads the residual itself, so greedy
        //   re-selecting the block it just solved means every other block
        //   carries even less than that block's fp dust — stop.  Masking
        //   the previous block here instead would make greedy alternate
        //   between dust blocks when the tolerance sits below the
        //   achievable residual, burning the whole remaining budget on
        //   near-zero updates.
        // - Preconditioned scoring mixes rows through M^-1, so the
        //   just-solved block can legitimately rank highest again while
        //   other blocks still carry real residual — breaking there froze
        //   the solve far from tolerance.  Mask the previous block from
        //   the candidate set for one round instead.  If masking empties
        //   the affordable set (budget edge: only the cheap tail fits),
        //   the selection yields None and the loop stops, preserving the
        //   old budget-edge behaviour.
        //
        // Either way, four full rounds of greedy selections without a new
        // residual-norm minimum mean the solve is grinding dust (e.g.
        // masked selection alternating between dust blocks): stop,
        // bounding the wasted work at ~four epochs instead of the whole
        // remaining budget.  Several rounds, not one, because block
        // coordinate descent is monotone in the error's H-norm, not the
        // residual 2-norm — short non-improving stretches mid-convergence
        // are legitimate and must not end the solve.
        let mut last_greedy: Option<usize> = None;
        let mut best_rsum = ry + rz;
        let mut stalled_iters = 0usize;

        while (ry > tol || rz > tol) && epochs + min_epoch_per_iter <= opts.max_epochs {
            // affordability uses the same `epochs + cost <= max` expression
            // as the loop guard, so uniform-block runs behave exactly as
            // before the ragged-tail guard fix
            let affordable = |blk: usize| epochs + block_cost(blk) <= opts.max_epochs;
            let blk = match opts.ap_selection {
                ApSelection::Greedy => {
                    let scores = match &pre {
                        Some(p) => {
                            let z = p.apply_t(&r, threads);
                            recurrence::block_scores(&z, bsz, threads)
                        }
                        None => recurrence::block_scores(&r, bsz, threads),
                    };
                    // a NaN/Inf block score means the residual has blown up
                    // (divergence): bail out with a divergence report, like
                    // SGD's finiteness guard, instead of panicking in the
                    // comparator below
                    if scores.iter().any(|s| !s.is_finite()) {
                        break;
                    }
                    // mask the just-solved block only under preconditioned
                    // scoring (see the guard comment above the loop)
                    let masked = if pre.is_some() { last_greedy } else { None };
                    let best = match scores
                        .iter()
                        .enumerate()
                        .filter(|(i, _)| affordable(*i) && Some(*i) != masked)
                        .max_by(|a, b| a.1.total_cmp(b.1))
                        .map(|(i, _)| i)
                    {
                        Some(i) => i,
                        // the affordable set net of the masked previous
                        // block is empty: nothing useful is selectable
                        None => break,
                    };
                    if pre.is_none() && last_greedy == Some(best) {
                        // direct scoring re-selected the just-solved
                        // block: all residual is fp dust
                        break;
                    }
                    last_greedy = Some(best);
                    best
                }
                ApSelection::Random => {
                    let b = self.rng.below(nblocks);
                    if !affordable(b) {
                        break;
                    }
                    b
                }
                ApSelection::Cyclic => {
                    let b = self.cursor % nblocks;
                    if !affordable(b) {
                        break;
                    }
                    self.cursor += 1;
                    b
                }
            };
            let idx: Vec<usize> = (blk * bsz..((blk + 1) * bsz).min(n)).collect();

            // u = H[I,I]^-1 r[I]
            let r_blk = r.gather_rows(&idx);
            let u = factors[blk].solve_mat(&r_blk); // [|I|, k]

            // v[I] += u
            for (bi, &i) in idx.iter().enumerate() {
                let vr = v.row_mut(i);
                for (j, val) in vr.iter_mut().enumerate() {
                    *val += u[(bi, j)];
                }
            }

            // r -= K(X, X_I) u  (operator product) and the sigma^2 scatter
            let ku = op.k_cols_prec(&idx, &u, prec); // [n, k]
            recurrence::sub_assign(&mut r, &ku, threads);
            for (bi, &i) in idx.iter().enumerate() {
                let rr = r.row_mut(i);
                for (j, val) in rr.iter_mut().enumerate() {
                    *val -= noise_var * u[(bi, j)];
                }
            }

            epochs += cost_scale * (idx.len() as f64 / n as f64);
            iterations += 1;
            let (a, b_) = residual_norms_t(&r, threads);
            ry = a;
            rz = b_;
            // divergence guard: NaN norms make both `> tol` comparisons
            // false, so without this check a blown-up solve would exit the
            // loop *looking* converged on the probe side; report it instead
            if !ry.is_finite() || !rz.is_finite() {
                break;
            }
            // greedy round-level stall stop (see guard comment above)
            if opts.ap_selection == ApSelection::Greedy {
                if ry + rz < best_rsum {
                    best_rsum = ry + rz;
                    stalled_iters = 0;
                } else {
                    stalled_iters += 1;
                    if stalled_iters >= 4 * nblocks {
                        break;
                    }
                }
            }
        }

        norm.finish_t(&mut v, threads);
        *v0 = v;
        SolveReport {
            iterations,
            epochs,
            ry,
            rz,
            converged: ry <= tol && rz <= tol,
            init_residual_sq,
        }
    }
}

impl LinearSolver for ApSolver {
    fn solve(
        &mut self,
        op: &dyn KernelOperator,
        b_mat: &Mat,
        v0: &mut Mat,
        opts: &SolveOptions,
    ) -> SolveReport {
        if !(opts.precision.is_f32() && op.precision().is_f32()) {
            return self.solve_impl(op, b_mat, v0, opts, Precision::F64);
        }
        let threads = recurrence::resolve_threads(opts.threads);
        let backup = v0.clone();
        let mut rep = self.solve_impl(op, b_mat, v0, opts, Precision::F32);
        // drift guard: one f64 epoch verifying the incrementally-tracked
        // residual against the reference operator.  On excessive drift the
        // warm start is restored and the untouched f64 path reruns; with
        // greedy selection (the default, stateless across solves) that
        // rerun is bitwise-equal to a pure --precision f64 solve.
        let (ry64, rz64) = verify_residuals_f64(op, b_mat, v0, threads);
        rep.epochs += 1.0;
        if drift_exceeded(&rep, ry64, rz64, opts.drift_ratio) {
            let wasted = rep.epochs;
            *v0 = backup;
            let mut rep64 = self.solve_impl(op, b_mat, v0, opts, Precision::F64);
            rep64.epochs += wasted;
            return rep64;
        }
        rep
    }

    fn kind(&self) -> SolverKind {
        SolverKind::Ap
    }

    fn set_precond_cache(&mut self, cache: SharedPreconditionerCache) {
        self.cache = cache;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::kernels::Hyperparams;
    use crate::linalg::Cholesky as Chol;
    use crate::operators::{DenseOperator, KernelOperator};
    use crate::util::rng::Rng;

    fn setup() -> (DenseOperator, Mat) {
        let ds = data::generate(&data::spec("test").unwrap());
        let mut op = DenseOperator::new(&ds, 4, 16);
        op.set_hp(&Hyperparams { ell: vec![1.2; 4], sigf: 1.0, sigma: 0.5 });
        let mut rng = Rng::new(1);
        let mut b = Mat::from_fn(op.n(), op.k_width(), |_, _| rng.gaussian());
        b.set_col(0, &ds.y_train);
        (op, b)
    }

    #[test]
    fn ap_converges_to_direct_solution() {
        let (op, b) = setup();
        let mut v = Mat::zeros(op.n(), op.k_width());
        let opts = SolveOptions { tolerance: 1e-6, max_epochs: 3000.0, block_size: 64, ..Default::default() };
        let rep = ApSolver::default().solve(&op, &b, &mut v, &opts);
        assert!(rep.converged, "{rep:?}");
        let want = Chol::factor(op.h()).unwrap().solve_mat(&b);
        assert!(v.max_abs_diff(&want) < 1e-4, "{}", v.max_abs_diff(&want));
    }

    #[test]
    fn residual_tracking_is_exact() {
        // The incrementally maintained residual must match b - H v.
        let (op, b) = setup();
        let mut v = Mat::zeros(op.n(), op.k_width());
        let opts = SolveOptions { tolerance: 0.05, block_size: 64, ..Default::default() };
        let rep = ApSolver::default().solve(&op, &b, &mut v, &opts);
        // recompute residual from the returned raw-space solution
        let hv = op.hv(&v);
        let mut r = b.clone();
        r.sub_assign(&hv);
        // columns were solved in normalised space: compare relative norms
        let bn = super::super::col_norms(&b);
        let rn = super::super::col_norms(&r);
        let rel: Vec<f64> = rn.iter().zip(&bn).map(|(r, b)| r / b).collect();
        let ry = rel[0];
        let rz = rel[1..].iter().sum::<f64>() / (rel.len() - 1) as f64;
        assert!((ry - rep.ry).abs() < 1e-8, "{ry} vs {}", rep.ry);
        assert!((rz - rep.rz).abs() < 1e-8, "{rz} vs {}", rep.rz);
    }

    #[test]
    fn epochs_counted_in_block_fractions() {
        let (op, b) = setup();
        let mut v = Mat::zeros(op.n(), op.k_width());
        let opts = SolveOptions { tolerance: 1e-12, max_epochs: 2.0, block_size: 64, ..Default::default() };
        let rep = ApSolver::default().solve(&op, &b, &mut v, &opts);
        // 256/64 = 4 iterations per epoch -> exactly 8 iterations in 2 epochs
        assert_eq!(rep.iterations, 8);
        assert!((rep.epochs - 2.0).abs() < 1e-9);
    }

    #[test]
    fn warm_start_reduces_iterations() {
        let (op, b) = setup();
        let opts = SolveOptions { tolerance: 0.01, block_size: 64, max_epochs: 3000.0, ..Default::default() };
        let mut cold = Mat::zeros(op.n(), op.k_width());
        let rep_cold = ApSolver::default().solve(&op, &b, &mut cold, &opts);
        let mut warm = cold.clone();
        let rep_warm = ApSolver::default().solve(&op, &b, &mut warm, &opts);
        assert!(
            rep_warm.iterations < rep_cold.iterations / 2,
            "warm {} vs cold {}",
            rep_warm.iterations,
            rep_cold.iterations
        );
    }

    #[test]
    fn random_and_cyclic_selection_also_converge() {
        let (op, b) = setup();
        for sel in [super::super::ApSelection::Random, super::super::ApSelection::Cyclic] {
            let mut v = Mat::zeros(op.n(), op.k_width());
            let opts = SolveOptions {
                tolerance: 1e-4,
                max_epochs: 3000.0,
                block_size: 64,
                ap_selection: sel,
                ..Default::default()
            };
            let rep = ApSolver::default().solve(&op, &b, &mut v, &opts);
            assert!(rep.converged, "{sel:?}: {rep:?}");
        }
    }

    #[test]
    fn selection_rules_are_comparable_in_cost() {
        // Greedy is not universally fastest (its summed-column metric is a
        // heuristic); assert all three rules land within a small factor of
        // each other on a well-conditioned system.
        let (op, b) = setup();
        let run = |sel| {
            let mut v = Mat::zeros(op.n(), op.k_width());
            let opts = SolveOptions {
                tolerance: 0.01,
                max_epochs: 3000.0,
                block_size: 64,
                ap_selection: sel,
                ..Default::default()
            };
            ApSolver::default().solve(&op, &b, &mut v, &opts).iterations
        };
        let greedy = run(super::super::ApSelection::Greedy);
        let cyclic = run(super::super::ApSelection::Cyclic);
        let random = run(super::super::ApSelection::Random);
        let max = greedy.max(cyclic).max(random) as f64;
        let min = greedy.min(cyclic).min(random).max(1) as f64;
        assert!(max / min < 3.0, "greedy {greedy} cyclic {cyclic} random {random}");
    }

    #[test]
    fn greedy_selection_picks_worst_block() {
        let mut r = Mat::zeros(8, 2);
        r[(5, 0)] = 10.0; // block 1 of size 4
        let scores = recurrence::block_scores(&r, 4, 1);
        assert!(scores[1] > scores[0]);
    }

    #[test]
    fn block_size_change_between_solves_rebuilds_factors() {
        // regression: factors were keyed on hyperparameters alone, so a
        // block-size change silently reused the wrong factorisation
        let (op, b) = setup();
        let mut solver = ApSolver::default();
        let mk = |bsz| SolveOptions {
            tolerance: 0.05,
            block_size: bsz,
            max_epochs: 3000.0,
            ..Default::default()
        };
        let mut v1 = Mat::zeros(op.n(), op.k_width());
        let rep64 = solver.solve(&op, &b, &mut v1, &mk(64));
        let mut v2 = Mat::zeros(op.n(), op.k_width());
        let rep32 = solver.solve(&op, &b, &mut v2, &mk(32));
        assert!(rep64.converged && rep32.converged, "{rep64:?} {rep32:?}");
        let mut v3 = Mat::zeros(op.n(), op.k_width());
        let rep32_fresh = ApSolver::default().solve(&op, &b, &mut v3, &mk(32));
        assert_eq!(rep32, rep32_fresh);
        assert_eq!(v2.data, v3.data);
    }

    #[test]
    fn ragged_tail_block_converges_to_direct_solution() {
        // online arrivals make block sizes that do not divide n routine:
        // 256 = 5 * 48 + 16, so the sixth block is a 16-row ragged tail
        let (op, b) = setup();
        let mut v = Mat::zeros(op.n(), op.k_width());
        let opts = SolveOptions {
            tolerance: 1e-6,
            max_epochs: 3000.0,
            block_size: 48,
            ..Default::default()
        };
        let rep = ApSolver::default().solve(&op, &b, &mut v, &opts);
        assert!(rep.converged, "{rep:?}");
        let want = Chol::factor(op.h()).unwrap().solve_mat(&b);
        assert!(v.max_abs_diff(&want) < 1e-4, "{}", v.max_abs_diff(&want));
        // random + cyclic selection must also cover the tail block
        for sel in [super::super::ApSelection::Random, super::super::ApSelection::Cyclic] {
            let mut v = Mat::zeros(op.n(), op.k_width());
            let o = SolveOptions { ap_selection: sel, ..opts.clone() };
            let rep = ApSolver::default().solve(&op, &b, &mut v, &o);
            assert!(rep.converged, "{sel:?}: {rep:?}");
        }
    }

    #[test]
    fn budget_between_tail_and_full_block_cost_still_runs_the_tail() {
        // regression: the budget guard priced every iteration at the
        // full-block cost (bsz/n), so a remaining budget that fits only
        // the cheaper ragged tail block exited without running the tail
        // iteration it could afford.  n = 256, bsz = 48 -> five 48-row
        // blocks plus a 16-row tail; budget 0.1 epochs sits between the
        // tail cost (16/256 = 0.0625) and the full cost (48/256 = 0.1875).
        let (op, b) = setup();
        let mut v = Mat::zeros(op.n(), op.k_width());
        let opts = SolveOptions {
            tolerance: 1e-12,
            max_epochs: 0.1,
            block_size: 48,
            ..Default::default()
        };
        let rep = ApSolver::default().solve(&op, &b, &mut v, &opts);
        assert_eq!(rep.iterations, 1, "the affordable tail iteration must run");
        assert!((rep.epochs - 16.0 / 256.0).abs() < 1e-12, "{}", rep.epochs);
        assert!(rep.epochs <= opts.max_epochs + 1e-12);
        // greedy selection restricted itself to the affordable tail block:
        // only the last 16 rows moved
        let k = op.k_width();
        assert!(v.data[..240 * k].iter().all(|&x| x == 0.0), "non-tail rows touched");
        assert!(v.data[240 * k..].iter().any(|&x| x != 0.0), "tail rows untouched");
    }

    #[test]
    fn budget_edge_does_not_burn_epochs_re_solving_the_tail() {
        // at the budget edge only the tail block is affordable; once it is
        // solved, greedy would re-select it forever (its fp-dust score is
        // the max of a singleton set), charging real epoch fractions for
        // no-op iterations.  The consecutive-repeat guard must stop after
        // the one useful tail solve.  Budget 0.19 affords three tail
        // iterations (3 * 0.0625) but only the first does work.
        let (op, b) = setup();
        let mut v = Mat::zeros(op.n(), op.k_width());
        let opts = SolveOptions {
            tolerance: 1e-12,
            max_epochs: 0.19,
            block_size: 48,
            ..Default::default()
        };
        let rep = ApSolver::default().solve(&op, &b, &mut v, &opts);
        assert_eq!(rep.iterations, 1, "no-op tail re-solves burned budget");
        assert!((rep.epochs - 16.0 / 256.0).abs() < 1e-12, "{}", rep.epochs);
    }

    #[test]
    fn nan_residual_reports_divergence_instead_of_panicking() {
        // regression: greedy selection compared block scores with
        // partial_cmp().unwrap(), so a NaN score (diverged residual)
        // panicked the process; it must report divergence the way SGD's
        // finiteness guard does
        let (op, mut b) = setup();
        b[(5, 2)] = f64::NAN; // poison one probe column
        let mut v = Mat::zeros(op.n(), op.k_width());
        let opts = SolveOptions {
            tolerance: 0.01,
            max_epochs: 100.0,
            block_size: 64,
            ..Default::default()
        };
        let rep = ApSolver::default().solve(&op, &b, &mut v, &opts);
        assert!(!rep.converged);
        assert!(!rep.rz.is_finite(), "report must reflect the divergence: {rep:?}");
        assert_eq!(rep.iterations, 0, "no useful work is possible on a NaN residual");
    }

    #[test]
    fn nan_score_under_preconditioned_scoring_bails_instead_of_panicking() {
        // regression: the preconditioned-scoring sibling of the greedy
        // selection above kept its own partial_cmp().unwrap() after the
        // direct-scoring path was fixed, so a NaN block score under
        // `ap_block_precond` still panicked.  total_cmp orders NaN above
        // every finite score, the finiteness guard catches it, and the
        // solve reports divergence.
        let (op, mut b) = setup();
        b[(5, 2)] = f64::NAN; // poison one probe column
        let mut v = Mat::zeros(op.n(), op.k_width());
        let opts = SolveOptions {
            tolerance: 0.01,
            max_epochs: 100.0,
            block_size: 64,
            precond_rank: 32,
            ap_block_precond: true,
            ..Default::default()
        };
        let rep = ApSolver::default().solve(&op, &b, &mut v, &opts);
        assert!(!rep.converged);
        assert!(!rep.rz.is_finite(), "report must reflect the divergence: {rep:?}");
        assert_eq!(rep.iterations, 0, "no useful work is possible on a NaN residual");
    }

    #[test]
    fn poisoned_hyperparameters_abort_instead_of_panicking() {
        // a NaN sigf poisons the kernel diagonal the preconditioner's
        // pivoted Cholesky pivots on; the typed LinalgError from the build
        // must surface as an aborted report, not a panic
        let (mut op, b) = setup();
        op.set_hp(&Hyperparams { ell: vec![1.0; 4], sigf: f64::NAN, sigma: 0.4 });
        let mut v = Mat::zeros(op.n(), op.k_width());
        let opts = SolveOptions {
            tolerance: 1e-6,
            max_epochs: 100.0,
            block_size: 64,
            precond_rank: 32,
            ap_block_precond: true,
            ..Default::default()
        };
        let rep = ApSolver::default().solve(&op, &b, &mut v, &opts);
        assert!(!rep.converged);
        assert_eq!(rep.iterations, 0);
        assert!(rep.ry.is_nan() && rep.rz.is_nan(), "{rep:?}");
    }

    #[test]
    fn unpreconditioned_greedy_stops_at_fp_dust_instead_of_burning_budget() {
        // regression: masking the previous block unconditionally let
        // direct-scoring greedy alternate between fp-dust blocks whenever
        // the tolerance sat below the achievable residual, charging real
        // epoch fractions for near-zero updates until the whole budget was
        // gone.  With an unreachable tolerance the solve must still stop
        // once all residual is dust — on the immediate-repeat break or,
        // if dust scores alternate, the round-level stall stop.  The buggy
        // version exits within one block cost of max_epochs; the fix stops
        // as soon as progress does, so assert a wide margin of unspent
        // budget (the 1e-6 convergence tests finish far inside 3000).
        let (op, b) = setup();
        let mut v = Mat::zeros(op.n(), op.k_width());
        let opts = SolveOptions {
            tolerance: 0.0, // unreachable: fp dust never reaches exact zero
            max_epochs: 3000.0,
            block_size: 64,
            ..Default::default()
        };
        let rep = ApSolver::default().solve(&op, &b, &mut v, &opts);
        assert!(!rep.converged);
        assert!(
            rep.epochs < 2000.0,
            "greedy burned the budget grinding fp dust: {rep:?}"
        );
        // the work it did do must still be the right answer
        let want = Chol::factor(op.h()).unwrap().solve_mat(&b);
        assert!(v.max_abs_diff(&want) < 1e-4, "{}", v.max_abs_diff(&want));
    }

    #[test]
    fn block_precond_mode_converges_to_same_solution() {
        let (op, b) = setup();
        let opts = SolveOptions {
            tolerance: 1e-6,
            max_epochs: 3000.0,
            block_size: 64,
            precond_rank: 32,
            ap_block_precond: true,
            ..Default::default()
        };
        let mut v = Mat::zeros(op.n(), op.k_width());
        let rep = ApSolver::default().solve(&op, &b, &mut v, &opts);
        assert!(rep.converged, "{rep:?}");
        let want = Chol::factor(op.h()).unwrap().solve_mat(&b);
        assert!(v.max_abs_diff(&want) < 1e-4, "{}", v.max_abs_diff(&want));
    }

    #[test]
    fn preconditioned_greedy_does_not_stall_on_a_repeat_selection() {
        // regression: the no-progress guard broke the loop whenever greedy
        // selected the same block twice running.  Under `ap_block_precond`
        // the M^-1-mixed score of the just-solved block routinely ranks
        // highest again (with rank ~ n the mix tracks the *error*, which a
        // single block solve does not zero), so the solve froze far above
        // tolerance while other blocks still carried real residual.  The
        // previous block is now masked for one round instead, and the
        // solve must reach the same solution as the direct factorisation.
        let (op, b) = setup();
        let opts = SolveOptions {
            tolerance: 1e-6,
            max_epochs: 3000.0,
            block_size: 64,
            precond_rank: 192, // near-full rank: scores follow the error
            ap_block_precond: true,
            ..Default::default()
        };
        let mut v = Mat::zeros(op.n(), op.k_width());
        let rep = ApSolver::default().solve(&op, &b, &mut v, &opts);
        assert!(rep.converged, "preconditioned greedy stalled: {rep:?}");
        let want = Chol::factor(op.h()).unwrap().solve_mat(&b);
        assert!(v.max_abs_diff(&want) < 1e-4, "{}", v.max_abs_diff(&want));
        // the budget-edge case (see
        // budget_edge_does_not_burn_epochs_re_solving_the_tail) still
        // terminates via the direct-scoring immediate-repeat break, and
        // masked dust-alternation is bounded by the round-level stall stop
    }

    #[test]
    fn sharded_precond_scoring_converges() {
        // block-Jacobi-of-shards scoring is a different mix than global
        // Woodbury, but must still steer greedy to a converged solve
        let (op, b) = setup();
        let opts = SolveOptions {
            tolerance: 1e-6,
            max_epochs: 3000.0,
            block_size: 64,
            precond_rank: 32,
            precond_shards: 4,
            ap_block_precond: true,
            ..Default::default()
        };
        let mut v = Mat::zeros(op.n(), op.k_width());
        let rep = ApSolver::default().solve(&op, &b, &mut v, &opts);
        assert!(rep.converged, "{rep:?}");
        let want = Chol::factor(op.h()).unwrap().solve_mat(&b);
        assert!(v.max_abs_diff(&want) < 1e-4, "{}", v.max_abs_diff(&want));
    }

    #[test]
    fn threaded_solve_is_bitwise_equal_to_serial() {
        let (op, b) = setup();
        let run = |threads: usize| {
            let opts = SolveOptions {
                tolerance: 1e-6,
                max_epochs: 3000.0,
                block_size: 64,
                threads,
                ..Default::default()
            };
            let mut v = Mat::zeros(op.n(), op.k_width());
            let rep = ApSolver::default().solve(&op, &b, &mut v, &opts);
            (rep, v)
        };
        let (rep1, v1) = run(1);
        for t in [2, 4] {
            let (rep, v) = run(t);
            assert_eq!(rep, rep1, "threads={t}");
            assert_eq!(v.data, v1.data, "threads={t}");
        }
    }
}
