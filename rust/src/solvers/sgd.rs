//! Stochastic gradient descent on the quadratic objective (Algorithm 3 of
//! the paper; Lin et al. 2023/2024): minibatch gradients with heavy-ball
//! momentum and the sparse residual-estimation heuristic (the negative
//! minibatch gradient *is* the residual on those rows, so a persistent
//! residual buffer updated on visited rows upper-bounds the true residual).
//! One iteration touches b/n of H's entries -> one epoch = n/b iterations.

use super::{
    drift_exceeded, recurrence, residual_norms_t, verify_residuals_f64, LinearSolver, Normalized,
    SolveOptions, SolveReport, SolverKind,
};
use crate::linalg::{micro, Mat};
use crate::operators::{HvScratch, KernelOperator, Precision};
use crate::util::rng::Rng;

pub struct SgdSolver {
    pub rng: Rng,
}

impl Default for SgdSolver {
    fn default() -> Self {
        SgdSolver { rng: Rng::new(0x5DD) }
    }
}

impl SgdSolver {
    pub fn with_seed(seed: u64) -> Self {
        SgdSolver { rng: Rng::new(seed) }
    }
}

impl SgdSolver {
    /// The solve body (backoff loop + attempts), parameterised on compute
    /// precision.  `F64` is the bitwise-parity reference path — the cost
    /// scale is exactly 1.0 and the minibatch products go through the
    /// plain `k_rows` — so every historical exact-epoch-count property is
    /// preserved.  `F32` routes the minibatch gradient products through
    /// `k_rows_prec` at half the epoch fraction each.
    fn solve_impl(
        &mut self,
        op: &dyn KernelOperator,
        b_mat: &Mat,
        v0: &mut Mat,
        opts: &SolveOptions,
        prec: Precision,
    ) -> SolveReport {
        // Learning-rate backoff: the optimal SGD rate shrinks as the
        // hyperparameters sharpen during optimisation (paper Section 5
        // observes SGD "can suffer due to the optimal learning rate
        // changing").  On detected divergence, halve the rate and retry
        // from the same initialisation; epochs AND iterations spent across
        // attempts are both charged, so the report reflects all work done.
        //
        // The warm-start residual is computed ONCE here — every retry
        // restarts from the identical (b, v0), so re-deriving R = b~ − H v~
        // per attempt was a full wasted epoch each (and the product buffer
        // and panel scratch are pooled across the whole solve).  Attempt 0
        // charges `warm_epoch_cost`; retries get the residual for free.
        let threads = recurrence::resolve_threads(opts.threads);
        let scratch = HvScratch::default();
        let mut hv = Mat::zeros(b_mat.rows, b_mat.cols);
        let (norm, r_init) = Normalized::setup_pooled(op, b_mat, v0, threads, &scratch, &mut hv);
        let init_residual_sq: f64 = micro::sum(&recurrence::col_sq_sums(&r_init, threads));
        let (ry0, rz0) = residual_norms_t(&r_init, threads);
        // Divergence guard scaled to the initial residual: a cold start (or
        // a fresh warm start) begins at ~1 per normalised column, keeping
        // the historical absolute floor; a legitimately-large *stale* warm
        // start after a big hyperparameter step can begin well above the
        // floor, and must only be flagged when the estimate grows past
        // GROWTH × its own starting point — not merely for starting high.
        let guard = divergence_threshold(ry0.max(rz0));

        let mut lr = opts.sgd_lr;
        let mut spent = norm.warm_epoch_cost;
        let mut spent_iters = 0usize;
        let attempts = if opts.sgd_backoff { 4 } else { 1 };
        for attempt in 0..attempts {
            // attempt 0 starts its epoch counter at the warm cost (exactly
            // the historical accounting); retries reuse the residual, so
            // they start at zero and only iteration work counts
            let start = if attempt == 0 { norm.warm_epoch_cost } else { 0.0 };
            let remaining = (opts.max_epochs - spent).max(0.0);
            let mut o = opts.clone();
            o.sgd_lr = lr;
            o.max_epochs = remaining + start;
            let mut v = v0.clone();
            let mut rep =
                self.attempt(op, &norm, r_init.clone(), &mut v, &o, threads, start, guard, prec);
            spent += rep.epochs - start;
            spent_iters += rep.iterations;
            rep.epochs = spent;
            rep.iterations = spent_iters;
            rep.init_residual_sq = init_residual_sq;
            let diverged =
                !rep.ry.is_finite() || !rep.rz.is_finite() || rep.ry > guard || rep.rz > guard;
            if !diverged || attempt == attempts - 1 || remaining <= 0.0 {
                norm.finish_t(&mut v, threads);
                *v0 = v;
                return rep;
            }
            lr *= 0.5;
            crate::debuglog!("sgd diverged (attempt {attempt}), retrying with lr={lr}");
        }
        unreachable!("backoff loop returns")
    }
}

impl LinearSolver for SgdSolver {
    fn solve(
        &mut self,
        op: &dyn KernelOperator,
        b_mat: &Mat,
        v0: &mut Mat,
        opts: &SolveOptions,
    ) -> SolveReport {
        if !(opts.precision.is_f32() && op.precision().is_f32()) {
            return self.solve_impl(op, b_mat, v0, opts, Precision::F64);
        }
        let threads = recurrence::resolve_threads(opts.threads);
        let backup = v0.clone();
        let mut rep = self.solve_impl(op, b_mat, v0, opts, Precision::F32);
        // drift guard: SGD's internal residual is already only an estimate
        // (the sparse upper-bound heuristic), so the f64 verification
        // doubles as the paper's recommended exactness check — on drift
        // past the ratio, restore the warm start and rerun in f64.  (The
        // rerun draws fresh minibatches — the rng advanced during the f32
        // attempt — so it is a fresh f64 solve, not a bitwise replay.)
        let (ry64, rz64) = verify_residuals_f64(op, b_mat, v0, threads);
        rep.epochs += 1.0;
        if drift_exceeded(&rep, ry64, rz64, opts.drift_ratio) {
            let wasted = rep.epochs;
            *v0 = backup;
            let mut rep64 = self.solve_impl(op, b_mat, v0, opts, Precision::F64);
            rep64.epochs += wasted;
            return rep64;
        }
        rep
    }

    fn kind(&self) -> SolverKind {
        SolverKind::Sgd
    }
}

/// Absolute floor of the divergence guard — the historical threshold,
/// which cold starts (normalised initial residual ~1 per column) keep.
const DIVERGENCE_FLOOR: f64 = 3.0;
/// An attempt is divergent once its residual estimate exceeds this factor
/// times its own initial residual norm (stale warm starts legitimately
/// *begin* above the floor while still descending).
const DIVERGENCE_GROWTH: f64 = 2.0;

/// Threshold for the in-loop and backoff divergence checks, scaled to the
/// solve's initial residual norm `r0 = max(ry_0, rz_0)`.
fn divergence_threshold(r0: f64) -> f64 {
    if r0.is_finite() {
        DIVERGENCE_FLOOR.max(DIVERGENCE_GROWTH * r0)
    } else {
        DIVERGENCE_FLOOR
    }
}

impl SgdSolver {
    /// One backoff attempt, entirely in normalised space: the caller owns
    /// the [`Normalized`] bookkeeping and the (shared) initial residual
    /// estimate `r`, and restores raw space after the final attempt.
    /// `start_epochs` seeds the epoch counter (the warm-start cost on
    /// attempt 0, zero on retries); `guard` is the divergence threshold
    /// from [`divergence_threshold`].
    #[allow(clippy::too_many_arguments)]
    fn attempt(
        &mut self,
        op: &dyn KernelOperator,
        norm: &Normalized,
        mut r: Mat,
        v: &mut Mat,
        opts: &SolveOptions,
        threads: usize,
        start_epochs: f64,
        guard: f64,
        prec: Precision,
    ) -> SolveReport {
        let n = op.n();
        let k = norm.b.cols;
        let bsz = opts.block_size;
        let noise_var = op.hp().noise_var();

        let mut momentum = Mat::zeros(n, k);
        // Polyak tail averaging (optional): average iterates over the back
        // half of the budget *actually available to this attempt*.  The
        // window is anchored past this attempt's starting epoch count
        // (`start_epochs` is the warm-residual cost on attempt 0 and 0 on
        // backoff retries, which inherit the residual for free) and
        // `opts.max_epochs` is already this attempt's budget, so warm
        // starts and retries keep the intended back-half coverage —
        // measuring against the raw budget made averaging start almost
        // immediately under warm starts (or swallow early noisy iterates
        // on retries).
        let mut polyak_sum: Option<Mat> = None;
        let mut polyak_count = 0usize;
        let polyak_start = polyak_window_start(opts.max_epochs, start_epochs);
        let mut epochs = start_epochs;
        let mut iterations = 0usize;
        let (mut ry, mut rz) = residual_norms_t(&r, threads);
        let tol = opts.tolerance;
        // f32 minibatch products cost half the memory traffic; the f64
        // multiply by exactly 1.0 keeps the reference path's epoch
        // accounting bitwise-unchanged
        let cost_scale = if prec.is_f32() { 0.5 } else { 1.0 };
        let epoch_per_iter = cost_scale * (bsz as f64 / n as f64);
        let step = opts.sgd_lr / bsz as f64;
        let rho = opts.sgd_momentum;

        while (ry > tol || rz > tol) && epochs + epoch_per_iter <= opts.max_epochs {
            let idx = self.rng.sample_indices(n, bsz);
            // g[I] = H[I,:] v - b[I]  = K(X_I, X) v + sigma^2 v[I] - b[I]
            let mut g = op.k_rows_prec(&idx, v, prec); // [b, k]
            for (bi, &i) in idx.iter().enumerate() {
                let gr = g.row_mut(bi);
                let vr = &v.data[i * k..(i + 1) * k];
                let br = &norm.b.data[i * k..(i + 1) * k];
                for j in 0..k {
                    gr[j] += noise_var * vr[j] - br[j];
                }
            }
            // momentum decays densely (O(nk), on the recurrence pool),
            // receives sparse gradient rows
            recurrence::scale_all(&mut momentum, rho, threads);
            for (bi, &i) in idx.iter().enumerate() {
                let mr = momentum.row_mut(i);
                let gr = g.row(bi);
                for j in 0..k {
                    mr[j] -= step * gr[j];
                }
            }
            recurrence::add_assign(&mut v, &momentum, threads);
            // sparse residual estimate: r[I] = -g[I]
            for (bi, &i) in idx.iter().enumerate() {
                let rr = r.row_mut(i);
                let gr = g.row(bi);
                for j in 0..k {
                    rr[j] = -gr[j];
                }
            }
            if opts.sgd_polyak && epochs >= polyak_start {
                let sum = polyak_sum.get_or_insert_with(|| Mat::zeros(n, k));
                recurrence::add_assign(sum, &v, threads);
                polyak_count += 1;
            }

            epochs += epoch_per_iter;
            iterations += 1;
            // residual norms are estimates here (paper: approximate upper bound)
            let (a, b_) = residual_norms_t(&r, threads);
            ry = a;
            rz = b_;
            // divergence guard (lr too large); backoff retries.  `guard`
            // is scaled to the attempt's initial residual (floor 3.0) so a
            // legitimately-large stale warm start is not mistaken for
            // divergence while its residual is still decreasing.  The
            // finite checks matter: a NaN norm makes both `> guard`
            // comparisons false, and the old guard only inspected
            // v.data[0], so a NaN anywhere else could burn the remaining
            // epoch budget before the outer backoff noticed.
            if !ry.is_finite() || !rz.is_finite() || ry > guard || rz > guard {
                break;
            }
        }

        if let Some(sum) = polyak_sum {
            if polyak_count > 0 {
                let mut avg = sum;
                recurrence::scale_all(&mut avg, 1.0 / polyak_count as f64, threads);
                *v = avg;
            }
        }
        SolveReport {
            iterations,
            epochs,
            ry,
            rz,
            converged: ry <= tol && rz <= tol,
            // the outer solve() owns the warm residual and overwrites this
            init_residual_sq: 0.0,
        }
    }
}

/// First epoch value at which Polyak tail averaging engages: the midpoint
/// of the iteration budget actually available to the attempt — what is
/// left of `max_epochs` after the warm-start residual cost (`warm_cost`,
/// where the epoch counter starts).  Cold starts (`warm_cost = 0`) keep
/// the historical `0.5 * max_epochs`; warm starts and shrunk backoff-retry
/// budgets get the genuine back half instead of a window that opened
/// before the first iteration.
fn polyak_window_start(max_epochs: f64, warm_cost: f64) -> f64 {
    warm_cost + 0.5 * (max_epochs - warm_cost).max(0.0)
}

/// Learning-rate auto-tune mirroring the paper's protocol: pick the largest
/// rate from `grid` whose first epoch does not increase the residual
/// estimate (run on the very first outer step only). `halve` returns half
/// of that rate (paper's choice on large datasets).
///
/// If even the smallest grid rate diverges (the old code returned it
/// anyway, seeding the first real solve with a known-divergent rate), the
/// tuner keeps halving *below* the grid until a rate survives its probe
/// epoch, bounded at [`AUTOTUNE_MAX_HALVINGS`] so a hopeless system still
/// terminates.
///
/// Returns `(rate, probe_epochs)`: every probe — grid or fallback — costs
/// real solver work (up to one epoch each), which the caller must charge
/// against its totals — silently dropping it would under-report exactly
/// the kind of hidden compute the paper's epoch accounting is meant to
/// expose.
pub fn autotune_lr(
    op: &dyn KernelOperator,
    b: &Mat,
    opts: &SolveOptions,
    grid: &[f64],
    halve: bool,
) -> (f64, f64) {
    assert!(!grid.is_empty(), "autotune_lr: empty grid");
    let mut probe_epochs = 0.0;
    let mut best = None;
    for &lr in grid {
        let (stable, epochs) = probe_rate(op, b, opts, lr);
        probe_epochs += epochs;
        if stable {
            best = Some(lr);
        } else {
            break;
        }
    }
    let best = best.unwrap_or_else(|| {
        let mut lr = grid[0];
        for _ in 0..AUTOTUNE_MAX_HALVINGS {
            lr *= 0.5;
            let (stable, epochs) = probe_rate(op, b, opts, lr);
            probe_epochs += epochs;
            if stable {
                return lr;
            }
        }
        crate::debuglog!("autotune_lr: no stable rate down to {lr}; returning it anyway");
        lr
    });
    let rate = if halve { best / 2.0 } else { best };
    (rate, probe_epochs)
}

/// Halving steps the fallback search takes below `grid[0]` before giving
/// up — 2^-24 below the grid is far past any plausible stability boundary.
const AUTOTUNE_MAX_HALVINGS: usize = 24;

/// One auto-tune probe: a single cold epoch at `lr`.  `(stable, epochs)`
/// where stable means finite iterates and a residual estimate that did not
/// grow (initial normalised residual is ~1 per column).
fn probe_rate(op: &dyn KernelOperator, b: &Mat, opts: &SolveOptions, lr: f64) -> (bool, f64) {
    let mut v = Mat::zeros(b.rows, b.cols);
    let mut o = opts.clone();
    o.sgd_lr = lr;
    o.max_epochs = 1.0;
    o.tolerance = 1e-16;
    o.sgd_backoff = false;
    let rep = SgdSolver::with_seed(42).solve(op, b, &mut v, &o);
    let finite = v.data.iter().all(|x| x.is_finite());
    (finite && rep.ry <= 1.5 && rep.rz <= 1.5, rep.epochs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::kernels::Hyperparams;
    use crate::linalg::Cholesky;
    use crate::operators::{DenseOperator, KernelOperator};

    fn setup() -> (DenseOperator, Mat) {
        let ds = data::generate(&data::spec("test").unwrap());
        let mut op = DenseOperator::new(&ds, 4, 16);
        op.set_hp(&Hyperparams { ell: vec![1.2; 4], sigf: 1.0, sigma: 0.5 });
        let mut rng = Rng::new(2);
        let mut b = Mat::from_fn(op.n(), op.k_width(), |_, _| rng.gaussian());
        b.set_col(0, &ds.y_train);
        (op, b)
    }

    #[test]
    fn sgd_reaches_modest_tolerance() {
        let (op, b) = setup();
        let mut v = Mat::zeros(op.n(), op.k_width());
        let opts = SolveOptions {
            tolerance: 0.05,
            max_epochs: 400.0,
            block_size: 64,
            sgd_lr: 8.0,
            ..Default::default()
        };
        let rep = SgdSolver::default().solve(&op, &b, &mut v, &opts);
        assert!(rep.converged, "{rep:?}");
        // solution close to direct solve
        let want = Cholesky::factor(op.h()).unwrap().solve_mat(&b);
        let mut diff = v.clone();
        diff.sub_assign(&want);
        assert!(diff.fro_norm() / want.fro_norm() < 0.15);
    }

    #[test]
    fn residual_estimate_upper_bounds_truth_after_convergence() {
        let (op, b) = setup();
        let mut v = Mat::zeros(op.n(), op.k_width());
        let opts = SolveOptions {
            tolerance: 0.05,
            max_epochs: 400.0,
            block_size: 64,
            sgd_lr: 8.0,
            ..Default::default()
        };
        let rep = SgdSolver::default().solve(&op, &b, &mut v, &opts);
        // exact residual from raw-space solution
        let hv = op.hv(&v);
        let mut r = b.clone();
        r.sub_assign(&hv);
        let bn = super::super::col_norms(&b);
        let rn = super::super::col_norms(&r);
        let ry_true = rn[0] / bn[0];
        assert!(ry_true <= rep.ry * 3.0 + 0.05, "true {ry_true} est {}", rep.ry);
    }

    #[test]
    fn lr_backoff_recovers_from_divergent_rate() {
        // grossly divergent initial rate: the backoff halves it (up to 3
        // times) and must still return finite iterates within budget
        let (op, b) = setup();
        let mut v = Mat::zeros(op.n(), op.k_width());
        let opts = SolveOptions {
            tolerance: 0.05,
            max_epochs: 400.0,
            block_size: 64,
            sgd_lr: 64.0, // diverges; 8.0 converges (see other tests)
            ..Default::default()
        };
        let rep = SgdSolver::default().solve(&op, &b, &mut v, &opts);
        assert!(v.data.iter().all(|x| x.is_finite()));
        assert!(rep.ry.is_finite() && rep.rz.is_finite());
        assert!(rep.epochs <= 400.0 + 1e-9);
    }

    #[test]
    fn stale_warm_start_above_the_floor_is_not_flagged_as_divergence() {
        // regression: the divergence guard compared the residual estimate
        // against an absolute 3.0, so a warm start left stale by a big
        // hyperparameter step — legitimately starting well above the floor
        // but still descending — tripped the guard on the first iteration
        // of every backoff attempt and the solve returned unconverged.
        // The guard now scales with the attempt's own initial residual.
        let (op, b) = setup();
        let opts = SolveOptions {
            tolerance: 0.05,
            max_epochs: 400.0,
            block_size: 64,
            sgd_lr: 8.0, // stable rate: any failure is the guard's fault
            ..Default::default()
        };
        let mut sol = Mat::zeros(op.n(), op.k_width());
        let rep_cold = SgdSolver::default().solve(&op, &b, &mut sol, &opts);
        assert!(rep_cold.converged, "{rep_cold:?}");
        // v0 = -10 x solution: H v0 = -10 b, so the normalised initial
        // residual is ~11 per column — far above the 3.0 floor
        let mut stale = sol.clone();
        stale.data.iter_mut().for_each(|x| *x *= -10.0);
        let rep = SgdSolver::default().solve(&op, &b, &mut stale, &opts);
        assert!(rep.init_residual_sq > 9.0 * rep_cold.init_residual_sq, "{rep:?}");
        assert!(rep.converged, "stale-but-descending warm start flagged as divergent: {rep:?}");
        let want = Cholesky::factor(op.h()).unwrap().solve_mat(&b);
        let mut diff = stale.clone();
        diff.sub_assign(&want);
        assert!(diff.fro_norm() / want.fro_norm() < 0.15);
    }

    #[test]
    fn backoff_retries_reuse_the_warm_residual() {
        // regression: every backoff attempt re-derived the warm-start
        // residual R = b~ - H v~ from the identical (b, v0), charging a
        // full extra epoch per retry for a product the first attempt had
        // already computed.  The residual is now computed once, so total
        // epochs must be exactly one warm epoch plus the iteration work.
        let (op, b) = setup();
        let warmup = SolveOptions {
            tolerance: 0.05,
            max_epochs: 400.0,
            block_size: 64,
            sgd_lr: 8.0,
            sgd_backoff: false,
            ..Default::default()
        };
        let mut v0 = Mat::zeros(op.n(), op.k_width());
        SgdSolver::with_seed(3).solve(&op, &b, &mut v0, &warmup);
        assert!(v0.data.iter().any(|&x| x != 0.0));

        let opts = SolveOptions {
            tolerance: 1e-16, // never converges: budget governs
            max_epochs: 12.0,
            block_size: 64,
            sgd_lr: 64.0, // diverges; backoff halves and retries
            sgd_backoff: true,
            ..Default::default()
        };
        let mut v = v0.clone();
        let rep = SgdSolver::default().solve(&op, &b, &mut v, &opts);
        let epoch_per_iter = 64.0 / op.n() as f64;
        assert!(
            (rep.epochs - (1.0 + rep.iterations as f64 * epoch_per_iter)).abs() < 1e-9,
            "warm epoch not charged exactly once: {rep:?}"
        );
        // the retries really happened (more iterations than one attempt)
        let mut v2 = v0.clone();
        let single = SgdSolver::default()
            .solve(&op, &b, &mut v2, &SolveOptions { sgd_backoff: false, ..opts.clone() });
        assert!(rep.iterations > single.iterations, "{} vs {}", rep.iterations, single.iterations);
    }

    #[test]
    fn backoff_iterations_accumulate_across_attempts() {
        // regression: rep.epochs accumulated across backoff retries but
        // rep.iterations reported only the last attempt's count.  With a
        // cold start every attempt costs exactly iterations * b/n epochs,
        // so the two must stay consistent even after retries.
        let (op, b) = setup();
        let mut v = Mat::zeros(op.n(), op.k_width());
        let opts = SolveOptions {
            tolerance: 0.05,
            max_epochs: 400.0,
            block_size: 64,
            sgd_lr: 64.0, // diverges; backoff halves and retries
            ..Default::default()
        };
        let rep = SgdSolver::default().solve(&op, &b, &mut v, &opts);
        let epoch_per_iter = 64.0 / op.n() as f64;
        assert!(
            (rep.epochs - rep.iterations as f64 * epoch_per_iter).abs() < 1e-9,
            "epochs {} vs iterations {} * {epoch_per_iter}",
            rep.epochs,
            rep.iterations
        );
        // the retries add the diverged attempt's iterations on top of what
        // a single (backoff-disabled) attempt reports
        let mut v2 = Mat::zeros(op.n(), op.k_width());
        let single = SgdSolver::default()
            .solve(&op, &b, &mut v2, &SolveOptions { sgd_backoff: false, ..opts.clone() });
        assert!(
            rep.iterations > single.iterations,
            "{} vs {}",
            rep.iterations,
            single.iterations
        );
    }

    #[test]
    fn autotune_picks_stable_rate_and_reports_probe_epochs() {
        let (op, b) = setup();
        let opts = SolveOptions { block_size: 64, ..Default::default() };
        let (lr, probe_epochs) = autotune_lr(&op, &b, &opts, &[1.0, 4.0, 8.0, 1e6], false);
        assert!(lr >= 1.0 && lr < 1e6, "{lr}");
        // every tried rate costs ~1 epoch of real work
        assert!(probe_epochs >= 1.0, "{probe_epochs}");
        assert!(probe_epochs <= 4.0 + 1e-9, "{probe_epochs}");
        let (halved, _) = autotune_lr(&op, &b, &opts, &[1.0, 4.0], true);
        assert!(halved <= 2.0);
    }

    #[test]
    fn autotune_falls_back_below_a_fully_divergent_grid() {
        // regression: `best` was initialised to grid[0], so a grid whose
        // smallest entry diverges returned that known-divergent rate and
        // the first real solve started by blowing up
        let (op, b) = setup();
        let opts = SolveOptions { block_size: 64, ..Default::default() };
        let (lr, probe_epochs) = autotune_lr(&op, &b, &opts, &[1e6, 2e6], false);
        assert!(lr < 1e6, "divergent grid floor returned verbatim: {lr}");
        assert!(lr > 0.0);
        // the fallback keeps halving until a probe epoch survives, and
        // every probe (grid + fallback) is real charged work
        let (stable, _) = probe_rate(&op, &b, &opts, lr);
        assert!(stable, "fallback returned a rate that fails its own probe: {lr}");
        assert!(probe_epochs > 0.0);
        // a grid with a stable floor is unaffected by the fallback path
        let (lr_ok, _) = autotune_lr(&op, &b, &opts, &[1.0, 4.0, 8.0], false);
        assert!(lr_ok >= 1.0);
    }

    #[test]
    fn divergent_attempt_stops_within_a_few_iterations() {
        // regression: the in-loop guard checked `ry > 3.0 || rz > 3.0`
        // (both false once the norms go NaN) and only inspected v.data[0]
        // for finiteness, so a diverged attempt could burn the whole
        // remaining epoch budget before the outer backoff noticed
        let (op, b) = setup();
        for lr in [1e12, 1e300] {
            let mut v = Mat::zeros(op.n(), op.k_width());
            let opts = SolveOptions {
                tolerance: 0.05,
                max_epochs: 400.0, // 1600 iterations at b=64, n=256
                block_size: 64,
                sgd_lr: lr,
                sgd_backoff: false,
                ..Default::default()
            };
            let rep = SgdSolver::default().solve(&op, &b, &mut v, &opts);
            assert!(!rep.converged, "lr={lr}");
            assert!(
                rep.iterations <= 8,
                "lr={lr}: diverged attempt ran {} iterations",
                rep.iterations
            );
            let blown = !rep.ry.is_finite() || !rep.rz.is_finite() || rep.ry > 3.0 || rep.rz > 3.0;
            assert!(blown, "lr={lr}: report does not reflect the divergence: {rep:?}");
        }
    }

    #[test]
    fn threaded_solve_is_bitwise_equal_to_serial() {
        let (op, b) = setup();
        let run = |threads: usize| {
            let opts = SolveOptions {
                tolerance: 0.05,
                max_epochs: 400.0,
                block_size: 64,
                sgd_lr: 8.0,
                threads,
                ..Default::default()
            };
            let mut v = Mat::zeros(op.n(), op.k_width());
            // fixed seed: identical minibatch draws across runs
            let rep = SgdSolver::with_seed(9).solve(&op, &b, &mut v, &opts);
            (rep, v)
        };
        let (rep1, v1) = run(1);
        for t in [2, 4] {
            let (rep, v) = run(t);
            assert_eq!(rep, rep1, "threads={t}");
            assert_eq!(v.data, v1.data, "threads={t}");
        }
    }

    #[test]
    fn polyak_window_start_is_anchored_to_the_attempt_budget() {
        // cold start: historical behaviour (back half of the raw budget)
        assert_eq!(polyak_window_start(2.0, 0.0), 1.0);
        // warm start: the epoch counter starts at 1.0, so the old raw
        // formula (0.5 * 2.0 = 1.0) opened the window before the first
        // iteration; the anchored window covers the genuine back half
        assert_eq!(polyak_window_start(2.0, 1.0), 1.5);
        // shrunk backoff-retry budget under a warm start: the old formula
        // (0.5 * 1.5 = 0.75) again opened immediately
        assert_eq!(polyak_window_start(1.5, 1.0), 1.25);
        // degenerate budget below the warm cost: the window clamps shut at
        // the warm cost instead of going negative
        assert_eq!(polyak_window_start(0.5, 1.0), 1.0);
    }

    #[test]
    fn warm_start_polyak_averages_only_the_back_half() {
        // regression: polyak_start = max_epochs * 0.5 was measured against
        // the raw budget, but a warm start pays 1.0 epoch for the exact
        // initial residual before iterating — with budget 2.0 the window
        // opened at 1.0 (i.e. before iteration one), so ALL iterates were
        // averaged instead of the back half.  n = 256, b = 64 -> exactly
        // 0.25 epochs per iteration (exact in fp), budget 2.0 -> 4
        // iterations; the fixed window [1.5, 2.0) covers iterates 3 and 4.
        let (op, b) = setup();
        // converged-ish warm start so warm_epoch_cost = 1.0
        let mut v0 = Mat::zeros(op.n(), op.k_width());
        let warmup = SolveOptions {
            tolerance: 0.05,
            max_epochs: 400.0,
            block_size: 64,
            sgd_lr: 8.0,
            sgd_backoff: false,
            ..Default::default()
        };
        SgdSolver::with_seed(3).solve(&op, &b, &mut v0, &warmup);
        assert!(v0.data.iter().any(|&x| x != 0.0));

        let run = |budget: f64, polyak: bool| {
            let opts = SolveOptions {
                tolerance: 1e-16, // never converges: budget governs
                max_epochs: budget,
                block_size: 64,
                sgd_lr: 8.0,
                sgd_backoff: false,
                sgd_polyak: polyak,
                ..Default::default()
            };
            let mut v = v0.clone();
            // fixed seed: identical minibatch draws, so shorter runs are
            // exact prefixes of longer ones
            SgdSolver::with_seed(7).solve(&op, &b, &mut v, &opts);
            v
        };
        let avg = run(2.0, true);
        let v3 = run(1.75, false); // iterate after 3 iterations
        let v4 = run(2.0, false); // iterate after 4 iterations
        for i in 0..avg.data.len() {
            let want = 0.5 * (v3.data[i] + v4.data[i]);
            assert!(
                (avg.data[i] - want).abs() <= 1e-11 * (1.0 + want.abs()),
                "elem {i}: polyak {} vs back-half mean {want}",
                avg.data[i]
            );
        }
    }

    #[test]
    fn backoff_retry_polyak_matches_standalone_attempt_with_shrunk_budget() {
        // retry path: after a diverged attempt the backoff re-solves with
        // the *remaining* budget; the polyak window must behave exactly as
        // a standalone solve given that shrunk budget (same warm-start
        // anchoring).  Reconstruct attempt-by-attempt with a second solver
        // sharing the minibatch stream and demand bitwise equality.
        let (op, b) = setup();
        let mut v0 = Mat::zeros(op.n(), op.k_width());
        let warmup = SolveOptions {
            tolerance: 0.05,
            max_epochs: 400.0,
            block_size: 64,
            sgd_lr: 8.0,
            sgd_backoff: false,
            ..Default::default()
        };
        SgdSolver::with_seed(3).solve(&op, &b, &mut v0, &warmup);

        let base = SolveOptions {
            tolerance: 1e-16,
            max_epochs: 12.0,
            block_size: 64,
            sgd_lr: 64.0, // diverges; backoff halves toward the stable 8.0
            sgd_backoff: true,
            sgd_polyak: true,
            ..Default::default()
        };
        let mut v_backoff = v0.clone();
        let rep = SgdSolver::with_seed(11).solve(&op, &b, &mut v_backoff, &base);
        assert!(v_backoff.data.iter().all(|x| x.is_finite()), "{rep:?}");

        // mirror the backoff loop through the public API (backoff off per
        // attempt), sharing one solver so the rng stream lines up.  Each
        // standalone solve re-pays its own 1.0 warm epoch (the real loop
        // computes the warm residual once and charges it only on attempt
        // 0), so grant every attempt `remaining + 1.0` and deduct the 1.0
        // back out of `spent` — that offsets both the budget check and the
        // polyak window anchor by exactly the standalone warm cost, making
        // the iterate trajectories bitwise-identical.  The literal 3.0
        // divergence check matches the scaled guard because the warm start
        // is converged (initial residual ~0.05 -> guard sits at the floor).
        let mut solver = SgdSolver::with_seed(11);
        let mut lr = base.sgd_lr;
        let mut spent = 1.0;
        let mut v_rec = v0.clone();
        for attempt in 0..4 {
            let remaining = (base.max_epochs - spent).max(0.0);
            let o = SolveOptions {
                sgd_backoff: false,
                sgd_lr: lr,
                max_epochs: remaining + 1.0,
                ..base.clone()
            };
            let mut v = v0.clone();
            let r = solver.solve(&op, &b, &mut v, &o);
            spent += r.epochs - 1.0;
            let diverged =
                !r.ry.is_finite() || !r.rz.is_finite() || r.ry > 3.0 || r.rz > 3.0;
            if !diverged || attempt == 3 || remaining <= 0.0 {
                v_rec = v;
                break;
            }
            lr *= 0.5;
        }
        assert_eq!(v_backoff.data, v_rec.data, "retry attempt drifted from standalone solve");
    }

    #[test]
    fn polyak_averaging_returns_finite_solution_near_plain() {
        let (op, b) = setup();
        let base = SolveOptions {
            tolerance: 1e-16, // force full budget
            max_epochs: 120.0,
            block_size: 64,
            sgd_lr: 8.0,
            ..Default::default()
        };
        let mut v_plain = Mat::zeros(op.n(), op.k_width());
        SgdSolver::with_seed(1).solve(&op, &b, &mut v_plain, &base);
        let mut opts = base.clone();
        opts.sgd_polyak = true;
        let mut v_avg = Mat::zeros(op.n(), op.k_width());
        SgdSolver::with_seed(1).solve(&op, &b, &mut v_avg, &opts);
        assert!(v_avg.data.iter().all(|x| x.is_finite()));
        // averaged solution is close to (and usually smoother than) plain
        let mut diff = v_avg.clone();
        diff.sub_assign(&v_plain);
        assert!(diff.fro_norm() / v_plain.fro_norm() < 0.5);
    }

    #[test]
    fn warm_start_helps() {
        let (op, b) = setup();
        let opts = SolveOptions {
            tolerance: 0.05,
            max_epochs: 400.0,
            block_size: 64,
            sgd_lr: 8.0,
            ..Default::default()
        };
        let mut cold = Mat::zeros(op.n(), op.k_width());
        let rep_cold = SgdSolver::default().solve(&op, &b, &mut cold, &opts);
        let mut warm = cold.clone();
        let rep_warm = SgdSolver::default().solve(&op, &b, &mut warm, &opts);
        assert!(rep_warm.epochs < rep_cold.epochs, "{} vs {}", rep_warm.epochs, rep_cold.epochs);
    }
}
