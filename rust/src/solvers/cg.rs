//! Preconditioned conjugate gradients (Algorithm 1 of the paper), batched
//! over the s+1 RHS columns with independent per-column step sizes.
//! One iteration touches every entry of H once, so 1 iteration = 1 epoch.

use super::{
    drift_exceeded, recurrence, residual_norms_t, verify_residuals_f64, LinearSolver, Normalized,
    PreconditionerCache, SharedPreconditionerCache, SolveOptions, SolveReport, SolverKind,
    NORM_EPS,
};
use crate::linalg::{micro, Mat};
use crate::operators::{HvScratch, KernelOperator, Precision};

/// Epoch cost of one f32 operator product: half the memory traffic of the
/// f64 pass (the paper's epoch is a bandwidth unit, not a flop count).
const F32_EPOCH: f64 = 0.5;

/// Inner f32 rounds solve the correction system H dv = r only loosely —
/// iterative refinement recovers the remaining accuracy in the f64 outer
/// loop, and pushing an f32 inner solve much below this wastes epochs on
/// digits the reduced precision cannot represent.
const INNER_TOL: f64 = 0.05;

pub struct CgSolver {
    /// Preconditioner store keyed on (hyperparameter bits, rank) —
    /// rebuilt whenever either changes.  Private by default; the `Trainer`
    /// injects its own via [`LinearSolver::set_precond_cache`] so
    /// factorisations are shared across solves and solver instances.
    cache: SharedPreconditionerCache,
}

impl Default for CgSolver {
    fn default() -> Self {
        CgSolver { cache: PreconditionerCache::shared() }
    }
}

impl CgSolver {
    /// The reference f64 path — untouched by the precision work, so a
    /// `--precision f64` run (and the drift-guard fallback) stays
    /// bitwise-identical to the historical solver.
    fn solve_f64(
        &mut self,
        op: &dyn KernelOperator,
        b: &Mat,
        v0: &mut Mat,
        opts: &SolveOptions,
    ) -> SolveReport {
        let threads = recurrence::resolve_threads(opts.threads);
        // a failed factorisation (typed LinalgError from a poisoned
        // hyperparameter) becomes an aborted report, like any divergence
        let pre = match self
            .cache
            .solver_preconditioner(op, opts.precond_rank, opts.precond_shards, threads)
        {
            Ok(pre) => pre,
            Err(_) => return SolveReport::aborted(),
        };
        // one operator-product output buffer and one panel-scratch pool for
        // the whole solve — the warm-start residual inside setup and every
        // iteration's hv_into reuse them (no allocation churn)
        let mut hd = Mat::zeros(b.rows, b.cols);
        let scratch = HvScratch::default();
        let (norm, mut r) = Normalized::setup_pooled(op, b, v0, threads, &scratch, &mut hd);
        let mut v = v0.clone();
        let init_residual_sq: f64 = micro::sum(&recurrence::col_sq_sums(&r, threads));

        let mut p = pre.apply_t(&r, threads);
        let mut d = p.clone();
        let mut gamma = recurrence::col_dots(&r, &p, threads);

        let mut epochs = norm.warm_epoch_cost;
        let mut iterations = 0usize;
        let (mut ry, mut rz) = residual_norms_t(&r, threads);
        let tol = opts.tolerance;

        while (ry > tol || rz > tol) && epochs + 1.0 <= opts.max_epochs {
            op.hv_into(&d, &mut hd, &scratch);
            epochs += 1.0;
            iterations += 1;

            let denom = recurrence::col_dots(&d, &hd, threads);
            let alpha: Vec<f64> = gamma
                .iter()
                .zip(&denom)
                .map(|(&g, &dn)| if dn > 0.0 { g / dn } else { 0.0 })
                .collect();
            recurrence::axpy_cols(&mut v, &alpha, &d, threads);
            let neg_alpha: Vec<f64> = alpha.iter().map(|a| -a).collect();
            recurrence::axpy_cols(&mut r, &neg_alpha, &hd, threads);

            p = pre.apply_t(&r, threads);
            let gamma_new = recurrence::col_dots(&r, &p, threads);
            let beta: Vec<f64> = gamma_new
                .iter()
                .zip(&gamma)
                .map(|(&gn, &g)| if g.abs() > 0.0 { gn / g } else { 0.0 })
                .collect();
            recurrence::direction_update(&mut d, &p, &beta, threads);
            gamma = gamma_new;
            let (a, b_) = residual_norms_t(&r, threads);
            ry = a;
            rz = b_;
        }

        norm.finish_t(&mut v, threads);
        *v0 = v;
        SolveReport {
            iterations,
            epochs,
            ry,
            rz,
            converged: ry <= tol && rz <= tol,
            init_residual_sq,
        }
    }

    /// f32 compute with iterative refinement: inner PCG rounds run the
    /// operator products in f32 (f64 accumulation) against a loosely
    /// normalised correction system, and the outer loop recomputes the
    /// true residual with the retained f64 reference product.  A final
    /// drift guard falls back to [`CgSolver::solve_f64`] — same solver
    /// instance, so the preconditioner cache is shared and the fallback
    /// answer is bitwise-equal to a pure f64 run.
    fn solve_refined(
        &mut self,
        op: &dyn KernelOperator,
        b: &Mat,
        v0: &mut Mat,
        opts: &SolveOptions,
    ) -> SolveReport {
        let threads = recurrence::resolve_threads(opts.threads);
        let backup = v0.clone();
        let pre = match self
            .cache
            .solver_preconditioner(op, opts.precond_rank, opts.precond_shards, threads)
        {
            Ok(pre) => pre,
            Err(_) => return SolveReport::aborted(),
        };
        let mut hd = Mat::zeros(b.rows, b.cols);
        let scratch = HvScratch::default();
        let (norm, mut r) = Normalized::setup_pooled(op, b, v0, threads, &scratch, &mut hd);
        let mut v = v0.clone();
        let init_residual_sq: f64 = micro::sum(&recurrence::col_sq_sums(&r, threads));

        let mut epochs = norm.warm_epoch_cost;
        let mut iterations = 0usize;
        let (mut ry, mut rz) = residual_norms_t(&r, threads);
        let tol = opts.tolerance;
        let cols = b.cols;
        let mut stalls = 0usize;
        let mut prev = ry.max(rz);

        // Each outer round needs at least one f32 product plus the
        // mandatory f64 residual recomputation to make progress.
        while (ry > tol || rz > tol)
            && epochs + F32_EPOCH + 1.0 <= opts.max_epochs
            && stalls < 2
        {
            // normalise the correction RHS so the inner relative tolerance
            // stays meaningful as the outer residual shrinks
            let mut rnorms = recurrence::col_norms(&r, threads);
            for n in &mut rnorms {
                *n += NORM_EPS;
            }
            let rinv: Vec<f64> = rnorms.iter().map(|&x| 1.0 / x).collect();
            let mut ri = r.clone();
            recurrence::scale_cols(&mut ri, &rinv, threads);

            let mut dv = Mat::zeros(b.rows, cols);
            let mut p = pre.apply_t(&ri, threads);
            let mut d = p.clone();
            let mut gamma = recurrence::col_dots(&ri, &p, threads);
            let (mut iry, mut irz) = residual_norms_t(&ri, threads);
            while (iry > INNER_TOL || irz > INNER_TOL)
                && epochs + F32_EPOCH + 1.0 <= opts.max_epochs
            {
                op.hv_into_prec(&d, &mut hd, &scratch, Precision::F32);
                epochs += F32_EPOCH;
                iterations += 1;
                let denom = recurrence::col_dots(&d, &hd, threads);
                let alpha: Vec<f64> = gamma
                    .iter()
                    .zip(&denom)
                    .map(|(&g, &dn)| if dn > 0.0 { g / dn } else { 0.0 })
                    .collect();
                recurrence::axpy_cols(&mut dv, &alpha, &d, threads);
                let neg_alpha: Vec<f64> = alpha.iter().map(|a| -a).collect();
                recurrence::axpy_cols(&mut ri, &neg_alpha, &hd, threads);
                // preconditioner application stays f64 — it is O(n k rank),
                // not an O(n^2) product, and mixed-precision CG is far more
                // sensitive to preconditioner noise than to product noise
                p = pre.apply_t(&ri, threads);
                let gamma_new = recurrence::col_dots(&ri, &p, threads);
                let beta: Vec<f64> = gamma_new
                    .iter()
                    .zip(&gamma)
                    .map(|(&gn, &g)| if g.abs() > 0.0 { gn / g } else { 0.0 })
                    .collect();
                recurrence::direction_update(&mut d, &p, &beta, threads);
                gamma = gamma_new;
                let (a, b_) = residual_norms_t(&ri, threads);
                iry = a;
                irz = b_;
                if !(iry.is_finite() && irz.is_finite()) {
                    break;
                }
            }

            // undo the correction normalisation, apply, and recompute the
            // true residual with the f64 reference product
            recurrence::scale_cols(&mut dv, &rnorms, threads);
            recurrence::add_assign(&mut v, &dv, threads);
            op.hv_into(&v, &mut hd, &scratch);
            epochs += 1.0;
            r = norm.b.clone();
            recurrence::sub_assign(&mut r, &hd, threads);
            let (a, b_) = residual_norms_t(&r, threads);
            ry = a;
            rz = b_;
            if !(ry.is_finite() && rz.is_finite()) {
                break;
            }
            // two consecutive rounds with < 10% improvement = the f32
            // floor; further rounds would burn epochs without progress
            let cur = ry.max(rz);
            if cur > 0.9 * prev {
                stalls += 1;
            } else {
                stalls = 0;
            }
            prev = cur;
        }

        norm.finish_t(&mut v, threads);
        *v0 = v;
        let mut rep = SolveReport {
            iterations,
            epochs,
            ry,
            rz,
            converged: ry <= tol && rz <= tol,
            init_residual_sq,
        };

        // drift guard: one extra f64 epoch to verify the solution against
        // the reference operator; on excessive drift restore the warm
        // start and rerun the untouched f64 path, charging the wasted
        // f32 epochs to the fallback's report
        let (ry64, rz64) = verify_residuals_f64(op, b, v0, threads);
        rep.epochs += 1.0;
        if drift_exceeded(&rep, ry64, rz64, opts.drift_ratio) {
            let wasted = rep.epochs;
            *v0 = backup;
            let mut rep64 = self.solve_f64(op, b, v0, opts);
            rep64.epochs += wasted;
            return rep64;
        }
        rep
    }
}

impl LinearSolver for CgSolver {
    fn solve(
        &mut self,
        op: &dyn KernelOperator,
        b: &Mat,
        v0: &mut Mat,
        opts: &SolveOptions,
    ) -> SolveReport {
        if opts.precision.is_f32() && op.precision().is_f32() {
            self.solve_refined(op, b, v0, opts)
        } else {
            self.solve_f64(op, b, v0, opts)
        }
    }

    fn kind(&self) -> SolverKind {
        SolverKind::Cg
    }

    fn set_precond_cache(&mut self, cache: SharedPreconditionerCache) {
        self.cache = cache;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::kernels::Hyperparams;
    use crate::linalg::Cholesky;
    use crate::operators::{DenseOperator, KernelOperator};
    use crate::util::rng::Rng;

    fn setup() -> (DenseOperator, Mat) {
        let ds = data::generate(&data::spec("test").unwrap());
        let mut op = DenseOperator::new(&ds, 4, 16);
        op.set_hp(&Hyperparams { ell: vec![1.0; 4], sigf: 1.0, sigma: 0.4 });
        let mut rng = Rng::new(0);
        let mut b = Mat::from_fn(op.n(), op.k_width(), |_, _| rng.gaussian());
        b.set_col(0, &ds.y_train);
        (op, b)
    }

    #[test]
    fn cg_converges_to_direct_solution() {
        let (op, b) = setup();
        let mut v = Mat::zeros(op.n(), op.k_width());
        let mut solver = CgSolver::default();
        let opts = SolveOptions { tolerance: 1e-8, max_epochs: 500.0, precond_rank: 32, ..Default::default() };
        let rep = solver.solve(&op, &b, &mut v, &opts);
        assert!(rep.converged, "{rep:?}");
        let want = Cholesky::factor(op.h()).unwrap().solve_mat(&b);
        assert!(v.max_abs_diff(&want) < 1e-5, "{}", v.max_abs_diff(&want));
    }

    #[test]
    fn preconditioning_reduces_iterations() {
        let (op, b) = setup();
        let opts_no = SolveOptions { tolerance: 0.01, precond_rank: 0, ..Default::default() };
        let opts_pc = SolveOptions { tolerance: 0.01, precond_rank: 64, ..Default::default() };
        let mut v1 = Mat::zeros(op.n(), op.k_width());
        let mut v2 = Mat::zeros(op.n(), op.k_width());
        let it_no = CgSolver::default().solve(&op, &b, &mut v1, &opts_no).iterations;
        let it_pc = CgSolver::default().solve(&op, &b, &mut v2, &opts_pc).iterations;
        assert!(it_pc <= it_no, "precond {it_pc} vs plain {it_no}");
    }

    #[test]
    fn warm_start_costs_one_epoch_but_fewer_iterations() {
        let (op, b) = setup();
        let opts = SolveOptions { tolerance: 0.01, precond_rank: 32, ..Default::default() };
        let mut cold = Mat::zeros(op.n(), op.k_width());
        let rep_cold = CgSolver::default().solve(&op, &b, &mut cold, &opts);
        // warm start at the solution: should converge (almost) immediately
        let mut warm = cold.clone();
        let rep_warm = CgSolver::default().solve(&op, &b, &mut warm, &opts);
        assert!(rep_warm.iterations <= 1, "{rep_warm:?}");
        assert!(rep_warm.epochs >= 1.0); // initial residual costs an epoch
        assert!(rep_cold.iterations > rep_warm.iterations);
    }

    #[test]
    fn budget_is_respected() {
        let (op, b) = setup();
        let opts = SolveOptions { tolerance: 1e-12, max_epochs: 5.0, precond_rank: 0, ..Default::default() };
        let mut v = Mat::zeros(op.n(), op.k_width());
        let rep = CgSolver::default().solve(&op, &b, &mut v, &opts);
        assert!(!rep.converged);
        assert!(rep.epochs <= 5.0 + 1e-9);
        assert_eq!(rep.iterations, 5);
    }

    #[test]
    fn rank_change_between_solves_rebuilds_preconditioner() {
        // regression: the old cache was keyed on hyperparameters only, so
        // flipping precond_rank 64 -> 0 between solves kept applying the
        // rank-64 preconditioner.  With the rank in the key, the second
        // solve must behave exactly like a fresh unpreconditioned one.
        let (op, b) = setup();
        let opts64 = SolveOptions { tolerance: 0.01, precond_rank: 64, ..Default::default() };
        let opts0 = SolveOptions { tolerance: 0.01, precond_rank: 0, ..Default::default() };

        let mut solver = CgSolver::default();
        let mut v = Mat::zeros(op.n(), op.k_width());
        solver.solve(&op, &b, &mut v, &opts64);
        let mut v_reused = Mat::zeros(op.n(), op.k_width());
        let rep_reused = solver.solve(&op, &b, &mut v_reused, &opts0);

        let mut v_fresh = Mat::zeros(op.n(), op.k_width());
        let rep_fresh = CgSolver::default().solve(&op, &b, &mut v_fresh, &opts0);
        assert_eq!(rep_reused, rep_fresh, "stale preconditioner leaked across ranks");
        assert_eq!(v_reused.data, v_fresh.data);
    }

    #[test]
    fn sharded_preconditioner_converges_to_the_same_solution() {
        // block-Jacobi-of-shards is a different (weaker) preconditioner,
        // so iteration counts may differ — but the solution must agree
        // with the direct solve, and S <= 1 must stay bitwise on the
        // global-Woodbury path
        let (op, b) = setup();
        let base = SolveOptions {
            tolerance: 1e-8,
            max_epochs: 500.0,
            precond_rank: 32,
            ..Default::default()
        };
        let want = Cholesky::factor(op.h()).unwrap().solve_mat(&b);
        let mut v_global = Mat::zeros(op.n(), op.k_width());
        let rep_global = CgSolver::default().solve(&op, &b, &mut v_global, &base);
        assert!(rep_global.converged);
        let sharded = SolveOptions { precond_shards: 4, ..base.clone() };
        let mut v_sharded = Mat::zeros(op.n(), op.k_width());
        let rep_sharded = CgSolver::default().solve(&op, &b, &mut v_sharded, &sharded);
        assert!(rep_sharded.converged, "{rep_sharded:?}");
        assert!(v_sharded.max_abs_diff(&want) < 1e-5, "{}", v_sharded.max_abs_diff(&want));
        let one = SolveOptions { precond_shards: 1, ..base };
        let mut v_one = Mat::zeros(op.n(), op.k_width());
        let rep_one = CgSolver::default().solve(&op, &b, &mut v_one, &one);
        assert_eq!(rep_one, rep_global, "S=1 must be the global path");
        assert_eq!(v_one.data, v_global.data);
    }

    #[test]
    fn threaded_solve_is_bitwise_equal_to_serial() {
        let (op, b) = setup();
        let run = |threads: usize| {
            let opts = SolveOptions {
                tolerance: 1e-8,
                max_epochs: 200.0,
                precond_rank: 32,
                threads,
                ..Default::default()
            };
            let mut v = Mat::zeros(op.n(), op.k_width());
            let rep = CgSolver::default().solve(&op, &b, &mut v, &opts);
            (rep, v)
        };
        let (rep1, v1) = run(1);
        for t in [2, 4] {
            let (rep, v) = run(t);
            assert_eq!(rep, rep1, "threads={t}");
            assert_eq!(v.data, v1.data, "threads={t}");
        }
    }

    #[test]
    fn poisoned_hyperparameters_abort_instead_of_panicking() {
        // A NaN signal variance poisons the kernel diagonal the pivoted
        // Cholesky pivots on.  The typed LinalgError from the build must
        // surface as an aborted (non-converged, NaN-residual) report — the
        // same contract as the solvers' divergence reports — not a panic.
        let (mut op, b) = setup();
        op.set_hp(&Hyperparams { ell: vec![1.0; 4], sigf: f64::NAN, sigma: 0.4 });
        let mut v = Mat::zeros(op.n(), op.k_width());
        let opts = SolveOptions { precond_rank: 32, ..Default::default() };
        let rep = CgSolver::default().solve(&op, &b, &mut v, &opts);
        assert!(!rep.converged);
        assert_eq!(rep.iterations, 0);
        assert!(rep.ry.is_nan() && rep.rz.is_nan(), "{rep:?}");
    }

    #[test]
    fn residuals_decrease_monotonically_enough() {
        // CG residuals are not strictly monotone, but the final residual
        // must be far below the initial one.
        let (op, b) = setup();
        let opts = SolveOptions { tolerance: 1e-6, precond_rank: 32, ..Default::default() };
        let mut v = Mat::zeros(op.n(), op.k_width());
        let rep = CgSolver::default().solve(&op, &b, &mut v, &opts);
        assert!(rep.ry < 1e-6 && rep.rz < 1e-6);
        assert!(rep.init_residual_sq > 1.0); // k unit columns
    }
}
