//! Preconditioned conjugate gradients (Algorithm 1 of the paper), batched
//! over the s+1 RHS columns with independent per-column step sizes.
//! One iteration touches every entry of H once, so 1 iteration = 1 epoch.

use super::{
    axpy_cols, col_dots, residual_norms, LinearSolver, Normalized, SolveOptions, SolveReport,
    SolverKind, WoodburyPreconditioner,
};
use crate::linalg::Mat;
use crate::operators::KernelOperator;

#[derive(Default)]
pub struct CgSolver {
    /// Keep the preconditioner across `solve` calls when hyperparameters
    /// did not change (rebuilt whenever they do).
    cache: Option<(Vec<f64>, WoodburyPreconditioner)>,
}

impl CgSolver {
    fn preconditioner(
        &mut self,
        op: &dyn KernelOperator,
        opts: &SolveOptions,
    ) -> &WoodburyPreconditioner {
        let theta = op.hp().pack();
        let stale = match &self.cache {
            Some((t, _)) => t != &theta,
            None => true,
        };
        if stale {
            let pre =
                WoodburyPreconditioner::build(op.x(), op.hp(), op.family(), opts.precond_rank);
            self.cache = Some((theta, pre));
        }
        &self.cache.as_ref().unwrap().1
    }
}

impl LinearSolver for CgSolver {
    fn solve(
        &mut self,
        op: &dyn KernelOperator,
        b: &Mat,
        v0: &mut Mat,
        opts: &SolveOptions,
    ) -> SolveReport {
        let pre = {
            // borrow dance: build/refresh the cache first
            self.preconditioner(op, opts);
            &self.cache.as_ref().unwrap().1
        };
        let (norm, mut r) = Normalized::setup(op, b, v0);
        let mut v = v0.clone();
        let init_residual_sq: f64 = r.data.iter().map(|x| x * x).sum();

        let mut p = pre.apply(&r);
        let mut d = p.clone();
        let mut gamma = col_dots(&r, &p);

        let mut epochs = norm.warm_epoch_cost;
        let mut iterations = 0usize;
        let (mut ry, mut rz) = residual_norms(&r);
        let tol = opts.tolerance;

        while (ry > tol || rz > tol) && epochs + 1.0 <= opts.max_epochs {
            let hd = op.hv(&d);
            epochs += 1.0;
            iterations += 1;

            let denom = col_dots(&d, &hd);
            let alpha: Vec<f64> = gamma
                .iter()
                .zip(&denom)
                .map(|(&g, &dn)| if dn > 0.0 { g / dn } else { 0.0 })
                .collect();
            axpy_cols(&mut v, &alpha, &d);
            let neg_alpha: Vec<f64> = alpha.iter().map(|a| -a).collect();
            axpy_cols(&mut r, &neg_alpha, &hd);

            p = pre.apply(&r);
            let gamma_new = col_dots(&r, &p);
            let beta: Vec<f64> = gamma_new
                .iter()
                .zip(&gamma)
                .map(|(&gn, &g)| if g.abs() > 0.0 { gn / g } else { 0.0 })
                .collect();
            // d = p + beta * d
            for i in 0..d.rows {
                let dr = d.row_mut(i);
                let pr = &p.data[i * p.cols..(i + 1) * p.cols];
                for j in 0..dr.len() {
                    dr[j] = pr[j] + beta[j] * dr[j];
                }
            }
            gamma = gamma_new;
            let (a, b_) = residual_norms(&r);
            ry = a;
            rz = b_;
        }

        norm.finish(&mut v);
        *v0 = v;
        SolveReport {
            iterations,
            epochs,
            ry,
            rz,
            converged: ry <= tol && rz <= tol,
            init_residual_sq,
        }
    }

    fn kind(&self) -> SolverKind {
        SolverKind::Cg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::kernels::Hyperparams;
    use crate::linalg::Cholesky;
    use crate::operators::{DenseOperator, KernelOperator};
    use crate::util::rng::Rng;

    fn setup() -> (DenseOperator, Mat) {
        let ds = data::generate(&data::spec("test").unwrap());
        let mut op = DenseOperator::new(&ds, 4, 16);
        op.set_hp(&Hyperparams { ell: vec![1.0; 4], sigf: 1.0, sigma: 0.4 });
        let mut rng = Rng::new(0);
        let mut b = Mat::from_fn(op.n(), op.k_width(), |_, _| rng.gaussian());
        b.set_col(0, &ds.y_train);
        (op, b)
    }

    #[test]
    fn cg_converges_to_direct_solution() {
        let (op, b) = setup();
        let mut v = Mat::zeros(op.n(), op.k_width());
        let mut solver = CgSolver::default();
        let opts = SolveOptions { tolerance: 1e-8, max_epochs: 500.0, precond_rank: 32, ..Default::default() };
        let rep = solver.solve(&op, &b, &mut v, &opts);
        assert!(rep.converged, "{rep:?}");
        let want = Cholesky::factor(op.h()).unwrap().solve_mat(&b);
        assert!(v.max_abs_diff(&want) < 1e-5, "{}", v.max_abs_diff(&want));
    }

    #[test]
    fn preconditioning_reduces_iterations() {
        let (op, b) = setup();
        let opts_no = SolveOptions { tolerance: 0.01, precond_rank: 0, ..Default::default() };
        let opts_pc = SolveOptions { tolerance: 0.01, precond_rank: 64, ..Default::default() };
        let mut v1 = Mat::zeros(op.n(), op.k_width());
        let mut v2 = Mat::zeros(op.n(), op.k_width());
        let it_no = CgSolver::default().solve(&op, &b, &mut v1, &opts_no).iterations;
        let it_pc = CgSolver::default().solve(&op, &b, &mut v2, &opts_pc).iterations;
        assert!(it_pc <= it_no, "precond {it_pc} vs plain {it_no}");
    }

    #[test]
    fn warm_start_costs_one_epoch_but_fewer_iterations() {
        let (op, b) = setup();
        let opts = SolveOptions { tolerance: 0.01, precond_rank: 32, ..Default::default() };
        let mut cold = Mat::zeros(op.n(), op.k_width());
        let rep_cold = CgSolver::default().solve(&op, &b, &mut cold, &opts);
        // warm start at the solution: should converge (almost) immediately
        let mut warm = cold.clone();
        let rep_warm = CgSolver::default().solve(&op, &b, &mut warm, &opts);
        assert!(rep_warm.iterations <= 1, "{rep_warm:?}");
        assert!(rep_warm.epochs >= 1.0); // initial residual costs an epoch
        assert!(rep_cold.iterations > rep_warm.iterations);
    }

    #[test]
    fn budget_is_respected() {
        let (op, b) = setup();
        let opts = SolveOptions { tolerance: 1e-12, max_epochs: 5.0, precond_rank: 0, ..Default::default() };
        let mut v = Mat::zeros(op.n(), op.k_width());
        let rep = CgSolver::default().solve(&op, &b, &mut v, &opts);
        assert!(!rep.converged);
        assert!(rep.epochs <= 5.0 + 1e-9);
        assert_eq!(rep.iterations, 5);
    }

    #[test]
    fn residuals_decrease_monotonically_enough() {
        // CG residuals are not strictly monotone, but the final residual
        // must be far below the initial one.
        let (op, b) = setup();
        let opts = SolveOptions { tolerance: 1e-6, precond_rank: 32, ..Default::default() };
        let mut v = Mat::zeros(op.n(), op.k_width());
        let rep = CgSolver::default().solve(&op, &b, &mut v, &opts);
        assert!(rep.ry < 1e-6 && rep.rz < 1e-6);
        assert!(rep.init_residual_sq > 1.0); // k unit columns
    }
}
