//! The parallel solver-recurrence layer: every O(n k) dense recurrence the
//! three solvers run between operator products — column norms/dots, axpy,
//! scaling, the CG direction update, AP's residual downdate and block
//! scores, SGD's dense momentum decay — routed through the deterministic
//! strided pool in [`crate::util::parallel`].
//!
//! Determinism contract (matches the `TiledOperator` invariant, and is in
//! fact stronger): every function here returns **bitwise-identical**
//! results for *every* thread count, including the serial fallback.
//!
//! * Elementwise updates partition rows into disjoint `&mut` blocks; each
//!   output element is computed by the same scalar expression as the serial
//!   loop, so the bits cannot differ.
//! * Reductions are *order-canonical*: rows are grouped into fixed blocks
//!   of [`REDUCE_BLOCK_ROWS`] (independent of the thread count), per-block
//!   partials are computed in row order and folded sequentially in block
//!   order.  Threads only change *who* computes a block, never the
//!   floating-point association.
//!
//! Below [`PAR_MIN_ELEMS`] elements everything runs inline — spawning
//! scoped workers costs tens of microseconds, which dwarfs small
//! recurrences — and, per the contract above, produces the same bits.
//!
//! `threads == 0` means auto-resolve (`IGP_THREADS` env var, else all
//! cores); solvers resolve once per solve via [`resolve_threads`] and pass
//! the concrete count down.

use crate::linalg::Mat;
use crate::util::parallel::{num_threads, parallel_map_slots, parallel_row_blocks};

/// Minimum number of f64 elements before a recurrence is worth spawning
/// workers for (below this, run inline on the calling thread).
pub const PAR_MIN_ELEMS: usize = 1 << 16;

/// Rows per reduction block.  Fixed — NOT derived from the thread count —
/// so the fold order (block-major) and therefore the result bits are
/// identical for every thread count.
pub const REDUCE_BLOCK_ROWS: usize = 512;

/// Resolve a requested thread count (0 = auto) to a concrete one.
pub fn resolve_threads(requested: usize) -> usize {
    num_threads(if requested == 0 { None } else { Some(requested) })
}

/// Workers to actually use for `elems` elements: 1 below the parallel
/// threshold, else the resolved count.
fn effective(elems: usize, threads: usize) -> usize {
    if elems < PAR_MIN_ELEMS {
        1
    } else {
        resolve_threads(threads)
    }
}

/// One row block per worker (elementwise ops need no finer granularity:
/// the per-row work is uniform).
fn rows_per_worker(rows: usize, threads: usize) -> usize {
    ((rows + threads - 1) / threads).max(1)
}

fn fold_partials(partials: Vec<Vec<f64>>, cols: usize) -> Vec<f64> {
    let mut acc = vec![0.0; cols];
    for p in partials {
        for (a, v) in acc.iter_mut().zip(&p) {
            *a += v;
        }
    }
    acc
}

/// Per-column sums of squares (order-canonical blocked reduction).
pub fn col_sq_sums(m: &Mat, threads: usize) -> Vec<f64> {
    if m.rows == 0 {
        return vec![0.0; m.cols];
    }
    let nblocks = (m.rows + REDUCE_BLOCK_ROWS - 1) / REDUCE_BLOCK_ROWS;
    let t = effective(m.rows * m.cols, threads);
    let partials = parallel_map_slots(nblocks, t, |bi| {
        let r0 = bi * REDUCE_BLOCK_ROWS;
        let r1 = (r0 + REDUCE_BLOCK_ROWS).min(m.rows);
        let mut acc = vec![0.0; m.cols];
        for i in r0..r1 {
            for (j, &x) in m.row(i).iter().enumerate() {
                acc[j] += x * x;
            }
        }
        acc
    });
    fold_partials(partials, m.cols)
}

/// Per-column euclidean norms of a [n, k] matrix.
pub fn col_norms(m: &Mat, threads: usize) -> Vec<f64> {
    col_sq_sums(m, threads).into_iter().map(f64::sqrt).collect()
}

/// Per-column dot products <a_j, b_j> (order-canonical blocked reduction).
pub fn col_dots(a: &Mat, b: &Mat, threads: usize) -> Vec<f64> {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    if a.rows == 0 {
        return vec![0.0; a.cols];
    }
    let nblocks = (a.rows + REDUCE_BLOCK_ROWS - 1) / REDUCE_BLOCK_ROWS;
    let t = effective(a.rows * a.cols, threads);
    let partials = parallel_map_slots(nblocks, t, |bi| {
        let r0 = bi * REDUCE_BLOCK_ROWS;
        let r1 = (r0 + REDUCE_BLOCK_ROWS).min(a.rows);
        let mut acc = vec![0.0; a.cols];
        for i in r0..r1 {
            let ar = a.row(i);
            let br = b.row(i);
            for j in 0..a.cols {
                acc[j] += ar[j] * br[j];
            }
        }
        acc
    });
    fold_partials(partials, a.cols)
}

/// Scale column j by c[j] (row-parallel, disjoint writes).
pub fn scale_cols(m: &mut Mat, c: &[f64], threads: usize) {
    assert_eq!(c.len(), m.cols);
    if m.data.is_empty() {
        return;
    }
    let t = effective(m.data.len(), threads);
    let cols = m.cols;
    let block = rows_per_worker(m.rows, t);
    parallel_row_blocks(&mut m.data, cols, block, t, |_r0, rows, blk| {
        for r in 0..rows {
            let row = &mut blk[r * cols..(r + 1) * cols];
            for (j, x) in row.iter_mut().enumerate() {
                *x *= c[j];
            }
        }
    });
}

/// m[:,j] += a[j] * o[:,j] (row-parallel, disjoint writes).
pub fn axpy_cols(m: &mut Mat, a: &[f64], o: &Mat, threads: usize) {
    assert_eq!((m.rows, m.cols), (o.rows, o.cols));
    assert_eq!(a.len(), m.cols);
    if m.data.is_empty() {
        return;
    }
    let t = effective(m.data.len(), threads);
    let cols = m.cols;
    let block = rows_per_worker(m.rows, t);
    parallel_row_blocks(&mut m.data, cols, block, t, |r0, rows, blk| {
        for r in 0..rows {
            let or = o.row(r0 + r);
            let mr = &mut blk[r * cols..(r + 1) * cols];
            for j in 0..cols {
                mr[j] += a[j] * or[j];
            }
        }
    });
}

/// CG direction update d = p + beta ∘ d (columnwise beta; row-parallel).
pub fn direction_update(d: &mut Mat, p: &Mat, beta: &[f64], threads: usize) {
    assert_eq!((d.rows, d.cols), (p.rows, p.cols));
    assert_eq!(beta.len(), d.cols);
    if d.data.is_empty() {
        return;
    }
    let t = effective(d.data.len(), threads);
    let cols = d.cols;
    let block = rows_per_worker(d.rows, t);
    parallel_row_blocks(&mut d.data, cols, block, t, |r0, rows, blk| {
        for r in 0..rows {
            let pr = p.row(r0 + r);
            let dr = &mut blk[r * cols..(r + 1) * cols];
            for j in 0..cols {
                dr[j] = pr[j] + beta[j] * dr[j];
            }
        }
    });
}

/// Dense elementwise m += o (SGD momentum application, Polyak sums).
pub fn add_assign(m: &mut Mat, o: &Mat, threads: usize) {
    assert_eq!((m.rows, m.cols), (o.rows, o.cols));
    if m.data.is_empty() {
        return;
    }
    let t = effective(m.data.len(), threads);
    let cols = m.cols;
    let block = rows_per_worker(m.rows, t);
    parallel_row_blocks(&mut m.data, cols, block, t, |r0, rows, blk| {
        let src = &o.data[r0 * cols..r0 * cols + rows * cols];
        for (x, y) in blk.iter_mut().zip(src) {
            *x += y;
        }
    });
}

/// Dense elementwise m -= o (AP/CG residual downdates).
pub fn sub_assign(m: &mut Mat, o: &Mat, threads: usize) {
    assert_eq!((m.rows, m.cols), (o.rows, o.cols));
    if m.data.is_empty() {
        return;
    }
    let t = effective(m.data.len(), threads);
    let cols = m.cols;
    let block = rows_per_worker(m.rows, t);
    parallel_row_blocks(&mut m.data, cols, block, t, |r0, rows, blk| {
        let src = &o.data[r0 * cols..r0 * cols + rows * cols];
        for (x, y) in blk.iter_mut().zip(src) {
            *x -= y;
        }
    });
}

/// Dense scalar scale m *= a (SGD momentum decay).
pub fn scale_all(m: &mut Mat, a: f64, threads: usize) {
    if m.data.is_empty() {
        return;
    }
    let t = effective(m.data.len(), threads);
    let cols = m.cols;
    let block = rows_per_worker(m.rows, t);
    parallel_row_blocks(&mut m.data, cols, block, t, |_r0, _rows, blk| {
        for x in blk.iter_mut() {
            *x *= a;
        }
    });
}

/// AP block-selection scores || sum_cols R[block rows] ||, one slot per
/// block (blocks are independent, so this is embarrassingly parallel and
/// each block's row-order sum matches the serial loop exactly).  The last
/// block may be a ragged tail when `b` does not divide the row count
/// (online data arrival makes such n routine).
pub fn block_scores(r: &Mat, b: usize, threads: usize) -> Vec<f64> {
    let nblocks = (r.rows + b - 1) / b;
    let t = effective(r.rows * r.cols, threads);
    parallel_map_slots(nblocks, t, |blk| {
        let mut s = 0.0;
        for i in blk * b..((blk + 1) * b).min(r.rows) {
            let row_sum: f64 = r.row(i).iter().sum();
            s += row_sum * row_sum;
        }
        s.sqrt()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(rows, cols, |_, _| rng.gaussian())
    }

    /// Naive single-loop references (the pre-parallel implementations).
    fn ref_col_norms(m: &Mat) -> Vec<f64> {
        (0..m.cols)
            .map(|j| (0..m.rows).map(|i| m[(i, j)] * m[(i, j)]).sum::<f64>().sqrt())
            .collect()
    }

    #[test]
    fn reductions_are_bitwise_thread_invariant() {
        // sizes straddling both REDUCE_BLOCK_ROWS and PAR_MIN_ELEMS
        for (rows, cols) in [(3, 2), (511, 5), (513, 7), (5000, 17)] {
            let a = mat(rows, cols, 1);
            let b = mat(rows, cols, 2);
            let n1 = col_norms(&a, 1);
            let d1 = col_dots(&a, &b, 1);
            let s1 = col_sq_sums(&a, 1);
            for t in [2, 3, 8] {
                assert_eq!(col_norms(&a, t), n1, "col_norms {rows}x{cols} t={t}");
                assert_eq!(col_dots(&a, &b, t), d1, "col_dots {rows}x{cols} t={t}");
                assert_eq!(col_sq_sums(&a, t), s1, "col_sq_sums {rows}x{cols} t={t}");
            }
            // and the values are right (up to fp association vs naive)
            for (x, y) in n1.iter().zip(ref_col_norms(&a)) {
                assert!((x - y).abs() < 1e-9 * (1.0 + y.abs()));
            }
        }
    }

    #[test]
    fn elementwise_ops_are_bitwise_thread_invariant() {
        for (rows, cols) in [(7, 3), (4097, 17)] {
            let base = mat(rows, cols, 3);
            let other = mat(rows, cols, 4);
            let coef: Vec<f64> = (0..cols).map(|j| 0.25 * (j as f64 + 1.0)).collect();
            let run = |t: usize| {
                let mut m1 = base.clone();
                scale_cols(&mut m1, &coef, t);
                let mut m2 = base.clone();
                axpy_cols(&mut m2, &coef, &other, t);
                let mut m3 = base.clone();
                direction_update(&mut m3, &other, &coef, t);
                let mut m4 = base.clone();
                add_assign(&mut m4, &other, t);
                let mut m5 = base.clone();
                sub_assign(&mut m5, &other, t);
                let mut m6 = base.clone();
                scale_all(&mut m6, 0.9, t);
                (m1, m2, m3, m4, m5, m6)
            };
            let serial = run(1);
            for t in [2, 5, 16] {
                assert_eq!(run(t), serial, "{rows}x{cols} t={t}");
            }
        }
    }

    #[test]
    fn block_scores_matches_serial_reference() {
        let r = mat(512, 9, 5);
        let serial = block_scores(&r, 64, 1);
        for t in [2, 4] {
            assert_eq!(block_scores(&r, 64, t), serial);
        }
        // reference value for one block
        let mut s = 0.0;
        for i in 0..64 {
            let rs: f64 = r.row(i).iter().sum();
            s += rs * rs;
        }
        assert!((serial[0] - s.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn direction_update_formula() {
        let mut d = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let p = Mat::from_vec(2, 2, vec![10.0, 20.0, 30.0, 40.0]);
        direction_update(&mut d, &p, &[2.0, 0.5], 1);
        assert_eq!(d.data, vec![12.0, 21.0, 36.0, 42.0]);
    }
}
