//! The preconditioner subsystem.
//!
//! [`WoodburyPreconditioner`] — rank-rho pivoted Cholesky of K plus the
//! Woodbury identity (paper follows Wang et al. 2019's rank-100 pivoted
//! Cholesky):
//!
//!   M = L L^T + sigma^2 I,
//!   M^-1 R = (R - L C^-1 (L^T R)) / sigma^2,   C = sigma^2 I_rho + L^T L.
//!
//! Built matrix-free from kernel rows (O(rho^2 n + rho n d)); the apply is
//! O(n rho k) per CG iteration.  Kernel rows (and AP's diagonal kernel
//! blocks below) are evaluated through the Gram-trick panel engine
//! ([`crate::kernels::panel`]) over one per-build [`ScaledX`] cache, and
//! the build is parallel end to end — kernel rows, the pivoted-Cholesky
//! column updates and the Gram accumulation C = L^T L all run on the
//! deterministic worker pool, with results bitwise-identical for every
//! thread count (order-canonical blocked reductions; see
//! [`super::recurrence`]).
//!
//! [`PreconditionerCache`] — a coordinator-owned store keyed on
//! (hyperparameter bits, rank).  The outer loop solves several systems per
//! hyperparameter setting (mean/probe batch, prediction, evaluation
//! re-solves); keying on the *exact* f64 bits of the packed
//! hyperparameters plus the requested rank makes reuse safe: any change to
//! either rebuilds.  The same cache also holds AP's per-block Cholesky
//! factors, keyed on (hyperparameter bits, block size).

use std::sync::{Arc, Mutex};

use crate::kernels::panel::{self, ScaledX};
use crate::kernels::{Hyperparams, KernelFamily};
use crate::linalg::{pivoted_cholesky_threaded, Cholesky, LinalgError, Mat};
use crate::operators::KernelOperator;
use crate::util::parallel::{num_threads, parallel_map_slots, parallel_row_blocks, shard_ranges};

pub struct WoodburyPreconditioner {
    l: Mat,              // [n, rho]
    lt: Mat,             // L^T [rho, n], cached for the apply
    c_chol: Cholesky,    // chol of sigma^2 I + L^T L
    noise_var: f64,
}

/// Rows per Gram reduction block — fixed so the block-major fold order is
/// independent of the thread count (bitwise-deterministic C).
const GRAM_BLOCK_ROWS: usize = 512;

impl WoodburyPreconditioner {
    /// Identity preconditioner (rank 0).
    pub fn identity() -> Self {
        WoodburyPreconditioner {
            l: Mat::zeros(0, 0),
            lt: Mat::zeros(0, 0),
            c_chol: Cholesky { l: Mat::from_vec(1, 1, vec![1.0]) },
            noise_var: 1.0,
        }
    }

    /// Build the rank-`rank` factorisation.  A non-finite kernel diagonal
    /// (poisoned hyperparameter) or a non-SPD Woodbury core is a typed
    /// [`LinalgError`], not a panic — solvers turn it into a divergence
    /// report so a bad outer-loop step cannot kill the training run.
    pub fn build(
        x: &Mat,
        hp: &Hyperparams,
        family: KernelFamily,
        rank: usize,
    ) -> Result<Self, LinalgError> {
        Self::build_threaded(x, hp, family, rank, 0)
    }

    /// [`WoodburyPreconditioner::build`] on `threads` workers (0 = auto).
    /// Bitwise-identical output for every thread count.
    pub fn build_threaded(
        x: &Mat,
        hp: &Hyperparams,
        family: KernelFamily,
        rank: usize,
        threads: usize,
    ) -> Result<Self, LinalgError> {
        if rank == 0 {
            return Ok(Self::identity());
        }
        let n = x.rows;
        let t = num_threads(if threads == 0 { None } else { Some(threads) });
        let sf2 = hp.sigf * hp.sigf;
        let diag = vec![sf2; n];
        // one ScaledX for the whole build (O(n·d)); kernel rows are then
        // Gram-trick panel fills, row-parallel inside the pivot closure —
        // each entry is a pure function of (i, j), so the row is
        // bitwise-identical for every thread count and block split
        let sx = ScaledX::new(x, &hp.ell);
        let kernel_row_par = |i: usize| -> Vec<f64> {
            let mut out = vec![0.0; n];
            let tk = if n * x.cols < (1 << 14) { 1 } else { t };
            let block = ((n + tk - 1) / tk).max(1);
            parallel_row_blocks(&mut out, 1, block, tk, |r0, rows, blk| {
                panel::fill_row(&sx, i, &sx, r0, sf2, family, &mut blk[..rows]);
            });
            out
        };
        let pc = pivoted_cholesky_threaded(n, rank, &diag, kernel_row_par, t)?;
        let rho = pc.rank();
        let noise_var = hp.noise_var();
        // C = sigma^2 I + L^T L: order-canonical blocked row reduction —
        // block partials of the upper triangle folded in block order.
        let nblocks = (n + GRAM_BLOCK_ROWS - 1) / GRAM_BLOCK_ROWS;
        let tg = if n * rho * rho < (1 << 16) { 1 } else { t };
        let partials = parallel_map_slots(nblocks, tg, |bi| {
            let r0 = bi * GRAM_BLOCK_ROWS;
            let r1 = (r0 + GRAM_BLOCK_ROWS).min(n);
            let mut acc = vec![0.0; rho * rho];
            for i in r0..r1 {
                let li = pc.l.row(i);
                for a in 0..rho {
                    let la = li[a];
                    if la == 0.0 {
                        continue;
                    }
                    for b in a..rho {
                        acc[a * rho + b] += la * li[b];
                    }
                }
            }
            acc
        });
        let mut c = Mat::zeros(rho, rho);
        for p in partials {
            for (x, y) in c.data.iter_mut().zip(&p) {
                *x += y;
            }
        }
        for a in 0..rho {
            for b in a + 1..rho {
                c[(b, a)] = c[(a, b)];
            }
        }
        c.add_diag(noise_var);
        let c_chol = Cholesky::factor(&c).map_err(|e| LinalgError::Factorization {
            what: "woodbury core (sigma^2 I + L^T L)",
            detail: format!("{e:#}"),
        })?;
        let lt = pc.l.transpose();
        Ok(WoodburyPreconditioner { l: pc.l, lt, c_chol, noise_var })
    }

    pub fn rank(&self) -> usize {
        if self.l.rows == 0 {
            0
        } else {
            self.l.cols
        }
    }

    /// Apply M^-1 to every column of R.
    pub fn apply(&self, r: &Mat) -> Mat {
        self.apply_t(r, 0)
    }

    /// [`WoodburyPreconditioner::apply`] with an explicit thread count
    /// (0 = auto); bitwise-identical output for every thread count.
    pub fn apply_t(&self, r: &Mat, threads: usize) -> Mat {
        if self.rank() == 0 {
            return r.clone();
        }
        let lt_r = self.lt.matmul_threaded(r, threads); // [rho, k]
        let c_inv = self.c_chol.solve_mat(&lt_r); // [rho, k]
        let l_c = self.l.matmul_threaded(&c_inv, threads); // [n, k]
        let mut out = r.clone();
        super::recurrence::sub_assign(&mut out, &l_c, threads);
        super::recurrence::scale_all(&mut out, 1.0 / self.noise_var, threads);
        out
    }
}

// ---------------------------------------------------------------------------
// ShardedJacobiPreconditioner
// ---------------------------------------------------------------------------

/// Block-Jacobi-of-shards preconditioner: one independent rank-rho
/// [`WoodburyPreconditioner`] per row shard (same contiguous balanced
/// partition as the sharded operator, [`shard_ranges`]),
///
///   M = blkdiag(M_1, ..., M_S),   M_s = L_s L_sᵀ + sigma² I  over shard s,
///
/// so the pivoted-Cholesky factorisation costs O(rho² n_s + rho n_s d) *per
/// shard* instead of globally, the factor memory is rho·n_s per shard, and
/// — the property that matters for the multi-process follow-up — each
/// shard's factor is built from that shard's rows alone, with the apply
/// touching only that shard's slice of R.
///
/// This is a genuinely different (weaker per unit rank, cheaper per unit n)
/// operator than the global Woodbury preconditioner, so it is opt-in via
/// `SolveOptions::precond_shards`; with a single shard it degenerates to
/// exactly the global factorisation (bitwise — asserted below).
pub struct ShardedJacobiPreconditioner {
    parts: Vec<WoodburyPreconditioner>,
    ranges: Vec<(usize, usize)>,
}

impl ShardedJacobiPreconditioner {
    /// Factor each shard of `x` independently at rank `min(rank, shard
    /// rows)`.  Bitwise-identical output for every thread count (each
    /// per-shard build already is).
    pub fn build_threaded(
        x: &Mat,
        hp: &Hyperparams,
        family: KernelFamily,
        rank: usize,
        shards: usize,
        threads: usize,
    ) -> Result<Self, LinalgError> {
        let ranges = shard_ranges(x.rows, shards);
        let mut parts = Vec::with_capacity(ranges.len());
        for &(r0, r1) in &ranges {
            let rows: Vec<usize> = (r0..r1).collect();
            let xs = x.gather_rows(&rows);
            parts.push(WoodburyPreconditioner::build_threaded(
                &xs,
                hp,
                family,
                rank.min(r1 - r0),
                threads,
            )?);
        }
        Ok(ShardedJacobiPreconditioner { parts, ranges })
    }

    pub fn num_shards(&self) -> usize {
        self.parts.len()
    }

    /// Largest per-shard factor rank (telemetry).
    pub fn rank(&self) -> usize {
        self.parts.iter().map(|p| p.rank()).max().unwrap_or(0)
    }

    /// Apply blkdiag(M_s)⁻¹ to every column of R: each shard's contiguous
    /// row slice goes through its own Woodbury apply, written back in
    /// place.  Shards never read each other's rows — the communication
    /// pattern a multi-process deployment needs.
    pub fn apply_t(&self, r: &Mat, threads: usize) -> Mat {
        let k = r.cols;
        let mut out = Mat::zeros(r.rows, k);
        for (part, &(r0, r1)) in self.parts.iter().zip(&self.ranges) {
            let rs = Mat::from_vec(r1 - r0, k, r.data[r0 * k..r1 * k].to_vec());
            let ys = part.apply_t(&rs, threads);
            out.data[r0 * k..r1 * k].copy_from_slice(&ys.data);
        }
        out
    }
}

/// What a solver gets back from
/// [`PreconditionerCache::solver_preconditioner`]: the global Woodbury
/// factorisation, or the block-Jacobi-of-shards variant when the caller
/// opted in with `precond_shards > 1`.  One `apply_t` entry point so the
/// CG/AP hot loops stay agnostic.
#[derive(Clone)]
pub enum SolverPrecond {
    Woodbury(Arc<WoodburyPreconditioner>),
    BlockJacobi(Arc<ShardedJacobiPreconditioner>),
}

impl SolverPrecond {
    pub fn rank(&self) -> usize {
        match self {
            SolverPrecond::Woodbury(p) => p.rank(),
            SolverPrecond::BlockJacobi(p) => p.rank(),
        }
    }

    /// Apply M⁻¹ to every column of R (0 threads = auto); bitwise-identical
    /// for every thread count.
    pub fn apply_t(&self, r: &Mat, threads: usize) -> Mat {
        match self {
            SolverPrecond::Woodbury(p) => p.apply_t(r, threads),
            SolverPrecond::BlockJacobi(p) => p.apply_t(r, threads),
        }
    }
}

// ---------------------------------------------------------------------------
// PreconditionerCache
// ---------------------------------------------------------------------------

/// Shared handle to a [`PreconditionerCache`] (the `Trainer` owns one and
/// injects it into its solver via [`super::LinearSolver::set_precond_cache`]).
pub type SharedPreconditionerCache = Arc<PreconditionerCache>;

/// Cache key: exact f64 bit patterns of the packed hyperparameters plus
/// the integer knob (Woodbury rank or AP block size) plus the training
/// size n.  Bit-exact equality is the right notion here: the outer loop
/// re-solves the *same* theta several times per step, and any genuine
/// hyperparameter step changes the bits.  n is in the key because online
/// data arrival grows the operator at *unchanged* hyperparameters — a
/// factorisation built for the old n must never be served for the new one
/// (`Trainer::extend_data` additionally calls [`PreconditionerCache::invalidate_all`]
/// to free the stale entries).
type HpKey = (Vec<u64>, usize, usize);

fn hp_key(hp: &Hyperparams, knob: usize, n: usize) -> HpKey {
    (hp.pack().iter().map(|x| x.to_bits()).collect(), knob, n)
}

/// Cache key for the block-Jacobi variant: [`HpKey`] with the shard count
/// alongside the rank knob — changing either rebuilds.
type JacobiKey = (HpKey, usize);

#[derive(Default)]
struct CacheInner {
    /// Small LRU lists (linear scan; capacity is single digits).
    woodbury: Vec<(HpKey, Arc<WoodburyPreconditioner>)>,
    jacobi: Vec<(JacobiKey, Arc<ShardedJacobiPreconditioner>)>,
    ap_blocks: Vec<(HpKey, Arc<Vec<Cholesky>>)>,
    woodbury_builds: u64,
    jacobi_builds: u64,
    ap_builds: u64,
    hits: u64,
}

/// Coordinator-owned preconditioner store, shared across solves (and, via
/// `Arc`, across solver instances).  Interior-mutable so solvers can take
/// it behind a shared reference.
pub struct PreconditionerCache {
    inner: Mutex<CacheInner>,
    cap: usize,
}

impl Default for PreconditionerCache {
    fn default() -> Self {
        PreconditionerCache::with_capacity(4)
    }
}

impl std::fmt::Debug for PreconditionerCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.inner.lock().unwrap();
        f.debug_struct("PreconditionerCache")
            .field("woodbury_entries", &inner.woodbury.len())
            .field("jacobi_entries", &inner.jacobi.len())
            .field("ap_entries", &inner.ap_blocks.len())
            .field("woodbury_builds", &inner.woodbury_builds)
            .field("jacobi_builds", &inner.jacobi_builds)
            .field("ap_builds", &inner.ap_builds)
            .field("hits", &inner.hits)
            .finish()
    }
}

impl PreconditionerCache {
    /// `cap` entries are retained per factorisation kind (LRU eviction).
    pub fn with_capacity(cap: usize) -> Self {
        PreconditionerCache { inner: Mutex::new(CacheInner::default()), cap: cap.max(1) }
    }

    /// Fresh shared handle (what `Trainer` constructs).
    pub fn shared() -> SharedPreconditionerCache {
        Arc::new(PreconditionerCache::default())
    }

    /// The Woodbury preconditioner for the operator's *current*
    /// hyperparameters at `rank`, building (on `threads` workers, 0 =
    /// auto) on a miss.  A cached entry is returned only when both the
    /// hyperparameter bits and the rank match — changing `precond_rank`
    /// between solves rebuilds instead of silently reusing the old rank.
    pub fn woodbury(
        &self,
        op: &dyn KernelOperator,
        rank: usize,
        threads: usize,
    ) -> Result<Arc<WoodburyPreconditioner>, LinalgError> {
        let key = hp_key(op.hp(), rank, op.n());
        let mut inner = self.inner.lock().unwrap();
        if let Some(pos) = inner.woodbury.iter().position(|(k, _)| *k == key) {
            inner.hits += 1;
            let entry = inner.woodbury.remove(pos);
            let pre = entry.1.clone();
            inner.woodbury.push(entry); // LRU: move to back
            return Ok(pre);
        }
        // a failed build is reported, never cached — a later request at the
        // same key (e.g. after the outer loop steps back) retries cleanly
        let pre = Arc::new(WoodburyPreconditioner::build_threaded(
            op.x(),
            op.hp(),
            op.family(),
            rank,
            threads,
        )?);
        inner.woodbury_builds += 1;
        if inner.woodbury.len() >= self.cap {
            inner.woodbury.remove(0);
        }
        inner.woodbury.push((key, pre.clone()));
        Ok(pre)
    }

    /// The preconditioner a solver should use for this solve: the global
    /// Woodbury factorisation by default, or the block-Jacobi-of-shards
    /// variant when `shards > 1` was requested (and `rank > 0` — the
    /// identity needs no sharding).  Both kinds are cached with the same
    /// (hyperparameter bits, knobs, n) staleness guarantee.
    pub fn solver_preconditioner(
        &self,
        op: &dyn KernelOperator,
        rank: usize,
        shards: usize,
        threads: usize,
    ) -> Result<SolverPrecond, LinalgError> {
        if shards <= 1 || rank == 0 {
            return Ok(SolverPrecond::Woodbury(self.woodbury(op, rank, threads)?));
        }
        let key = (hp_key(op.hp(), rank, op.n()), shards);
        let mut inner = self.inner.lock().unwrap();
        if let Some(pos) = inner.jacobi.iter().position(|(k, _)| *k == key) {
            inner.hits += 1;
            let entry = inner.jacobi.remove(pos);
            let pre = entry.1.clone();
            inner.jacobi.push(entry); // LRU: move to back
            return Ok(SolverPrecond::BlockJacobi(pre));
        }
        let pre = Arc::new(ShardedJacobiPreconditioner::build_threaded(
            op.x(),
            op.hp(),
            op.family(),
            rank,
            shards,
            threads,
        )?);
        inner.jacobi_builds += 1;
        if inner.jacobi.len() >= self.cap {
            inner.jacobi.remove(0);
        }
        inner.jacobi.push((key, pre.clone()));
        Ok(SolverPrecond::BlockJacobi(pre))
    }

    /// AP's per-block Cholesky factors for the operator's current
    /// hyperparameters at `block_size`, built block-parallel on a miss.
    /// Keyed on (hyperparameter bits, block size, n) — the same staleness
    /// guarantee as [`PreconditionerCache::woodbury`].  When `block_size`
    /// does not divide n (routine after online arrivals), the last factor
    /// covers the ragged tail block.
    pub fn ap_block_factors(
        &self,
        op: &dyn KernelOperator,
        block_size: usize,
        threads: usize,
    ) -> Result<Arc<Vec<Cholesky>>, LinalgError> {
        let key = hp_key(op.hp(), block_size, op.n());
        let mut inner = self.inner.lock().unwrap();
        if let Some(pos) = inner.ap_blocks.iter().position(|(k, _)| *k == key) {
            inner.hits += 1;
            let entry = inner.ap_blocks.remove(pos);
            let factors = entry.1.clone();
            inner.ap_blocks.push(entry);
            return Ok(factors);
        }
        let n = op.n();
        let x = op.x();
        let hp = op.hp();
        let fam = op.family();
        let sf2 = hp.sigf * hp.sigf;
        let nblocks = (n + block_size - 1) / block_size;
        let t = num_threads(if threads == 0 { None } else { Some(threads) });
        // one ScaledX shared by all block builds; each block gathers its
        // rows (norms copied, not recomputed) and panel-fills its diagonal
        // kernel block
        let sx = ScaledX::new(x, &hp.ell);
        // per-block factorisation failures (non-SPD block from a poisoned
        // hyperparameter) come back as values and surface as one typed
        // error, never a panic inside a pool worker
        let results = parallel_map_slots(nblocks, t.min(nblocks), |blk| {
            let idx: Vec<usize> =
                (blk * block_size..((blk + 1) * block_size).min(n)).collect();
            let sb = sx.gather(&idx);
            let mut h_blk = panel::cross_matrix(&sb, &sb, sf2, fam);
            h_blk.add_diag(hp.noise_var());
            Cholesky::factor(&h_blk).map_err(|e| LinalgError::Factorization {
                what: "AP diagonal kernel block",
                detail: format!("block {blk}: {e:#}"),
            })
        });
        let factors: Vec<Cholesky> =
            results.into_iter().collect::<Result<_, LinalgError>>()?;
        let factors = Arc::new(factors);
        inner.ap_builds += 1;
        if inner.ap_blocks.len() >= self.cap {
            inner.ap_blocks.remove(0);
        }
        inner.ap_blocks.push((key, factors.clone()));
        Ok(factors)
    }

    /// Drop every cached factorisation of both kinds.  Called by the
    /// coordinator on online data arrival: all entries were built for the
    /// old n, so they can only waste memory (the n in the key already
    /// prevents wrong reuse).  Build/hit counters are preserved.
    pub fn invalidate_all(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.woodbury.clear();
        inner.jacobi.clear();
        inner.ap_blocks.clear();
    }

    /// Woodbury factorisations built so far (telemetry / regression tests).
    pub fn woodbury_builds(&self) -> u64 {
        self.inner.lock().unwrap().woodbury_builds
    }

    /// Block-Jacobi-of-shards factorisations built so far.
    pub fn jacobi_builds(&self) -> u64 {
        self.inner.lock().unwrap().jacobi_builds
    }

    /// AP block factorisations built so far.
    pub fn ap_builds(&self) -> u64 {
        self.inner.lock().unwrap().ap_builds
    }

    /// Cache hits across both kinds.
    pub fn hits(&self) -> u64 {
        self.inner.lock().unwrap().hits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::kernels::h_matrix;
    use crate::operators::DenseOperator;
    use crate::util::rng::Rng;

    #[test]
    fn full_rank_preconditioner_is_exact_inverse() {
        let mut rng = Rng::new(0);
        let n = 24;
        let x = Mat::from_fn(n, 2, |_, _| rng.gaussian());
        let hp = Hyperparams { ell: vec![1.0, 1.0], sigf: 1.2, sigma: 0.5 };
        let fam = KernelFamily::Matern32;
        let pre = WoodburyPreconditioner::build(&x, &hp, fam, n).unwrap();
        let h = h_matrix(&x, &hp, fam);
        let b = Mat::from_fn(n, 3, |_, _| rng.gaussian());
        let got = pre.apply(&b);
        let want = Cholesky::factor(&h).unwrap().solve_mat(&b);
        assert!(got.max_abs_diff(&want) < 1e-7, "{}", got.max_abs_diff(&want));
    }

    #[test]
    fn identity_rank_zero() {
        let pre = WoodburyPreconditioner::identity();
        let r = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(pre.apply(&r), r);
    }

    #[test]
    fn preconditioner_is_spd_quadratic_form() {
        // v^T M^-1 v > 0 for random v.
        let mut rng = Rng::new(1);
        let n = 32;
        let x = Mat::from_fn(n, 3, |_, _| rng.gaussian());
        let hp = Hyperparams { ell: vec![0.8; 3], sigf: 1.0, sigma: 0.3 };
        let pre = WoodburyPreconditioner::build(&x, &hp, KernelFamily::Matern32, 8).unwrap();
        for _ in 0..5 {
            let v = Mat::from_fn(n, 1, |_, _| rng.gaussian());
            let mv = pre.apply(&v);
            let q = crate::util::stats::dot(&v.data, &mv.data);
            assert!(q > 0.0);
        }
    }

    #[test]
    fn threaded_build_and_apply_are_bitwise_equal_to_serial() {
        let mut rng = Rng::new(2);
        let n = 64;
        let x = Mat::from_fn(n, 3, |_, _| rng.gaussian());
        let hp = Hyperparams { ell: vec![0.9; 3], sigf: 1.1, sigma: 0.4 };
        let fam = KernelFamily::Matern52;
        let r = Mat::from_fn(n, 5, |_, _| rng.gaussian());
        let serial = WoodburyPreconditioner::build_threaded(&x, &hp, fam, 16, 1).unwrap();
        let want = serial.apply_t(&r, 1);
        for t in [2, 4] {
            let pre = WoodburyPreconditioner::build_threaded(&x, &hp, fam, 16, t).unwrap();
            assert_eq!(pre.l, serial.l, "t={t}");
            assert_eq!(pre.apply_t(&r, t), want, "t={t}");
        }
    }

    fn test_op(sigma: f64) -> DenseOperator {
        let ds = data::generate(&data::spec("test").unwrap());
        let mut op = DenseOperator::new(&ds, 4, 16);
        op.set_hp(&Hyperparams { ell: vec![1.0; 4], sigf: 1.0, sigma });
        op
    }

    #[test]
    fn cache_rebuilds_on_rank_change() {
        // regression: a cache keyed on hyperparameters alone would reuse
        // the rank-64 factorisation for the rank-8 request
        let cache = PreconditionerCache::default();
        let op = test_op(0.4);
        let p64 = cache.woodbury(&op, 64, 1).unwrap();
        let p8 = cache.woodbury(&op, 8, 1).unwrap();
        assert_eq!(cache.woodbury_builds(), 2);
        assert!(p8.rank() <= 8, "rank {} leaked from the rank-64 entry", p8.rank());
        assert!(p64.rank() > p8.rank());
        // rank 0 must yield the identity, not any cached factorisation
        let p0 = cache.woodbury(&op, 0, 1).unwrap();
        assert_eq!(p0.rank(), 0);
    }

    #[test]
    fn cache_rebuilds_on_hp_change_and_hits_otherwise() {
        let cache = PreconditionerCache::default();
        let op = test_op(0.4);
        let a = cache.woodbury(&op, 16, 1).unwrap();
        let b = cache.woodbury(&op, 16, 1).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same hp+rank must hit");
        assert_eq!(cache.woodbury_builds(), 1);
        assert_eq!(cache.hits(), 1);
        let op2 = test_op(0.7);
        let c = cache.woodbury(&op2, 16, 1).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.woodbury_builds(), 2);
    }

    #[test]
    fn cached_and_fresh_preconditioners_apply_identically() {
        let cache = PreconditionerCache::default();
        let op = test_op(0.5);
        let mut rng = Rng::new(3);
        let r = Mat::from_fn(op.n(), 4, |_, _| rng.gaussian());
        let cached = cache.woodbury(&op, 24, 2).unwrap();
        let fresh =
            WoodburyPreconditioner::build_threaded(op.x(), op.hp(), op.family(), 24, 4).unwrap();
        assert_eq!(cached.apply_t(&r, 3), fresh.apply_t(&r, 1));
    }

    #[test]
    fn ap_factors_cached_and_keyed_on_block_size() {
        let cache = PreconditionerCache::default();
        let op = test_op(0.4);
        let fa = cache.ap_block_factors(&op, 64, 2).unwrap();
        let fb = cache.ap_block_factors(&op, 64, 2).unwrap();
        assert!(Arc::ptr_eq(&fa, &fb));
        let fc = cache.ap_block_factors(&op, 32, 2).unwrap();
        assert_eq!(fa.len(), op.n() / 64);
        assert_eq!(fc.len(), op.n() / 32);
        assert_eq!(cache.ap_builds(), 2);
        // block-parallel build matches the serial one factor-for-factor
        let serial = cache.ap_block_factors(&test_op(0.9), 64, 1).unwrap();
        let op2 = test_op(0.9);
        let par = PreconditionerCache::default().ap_block_factors(&op2, 64, 4).unwrap();
        for (a, b) in serial.iter().zip(par.iter()) {
            assert_eq!(a.l, b.l);
        }
    }

    #[test]
    fn cache_rebuilds_after_operator_extension() {
        // regression: the key omitted n, so growing the operator at
        // unchanged hyperparameters served a factorisation built for the
        // old n (wrong shape, silently wrong apply)
        let cache = PreconditionerCache::default();
        let mut op = test_op(0.4);
        let p_small = cache.woodbury(&op, 16, 1).unwrap();
        let f_small = cache.ap_block_factors(&op, 64, 1).unwrap();
        let mut rng = Rng::new(5);
        let chunk = Mat::from_fn(64, op.d(), |_, _| rng.gaussian());
        op.extend(&chunk).unwrap();
        let p_big = cache.woodbury(&op, 16, 1).unwrap();
        assert!(!Arc::ptr_eq(&p_small, &p_big), "stale preconditioner served after extend");
        assert_eq!(p_big.l.rows, op.n());
        let f_big = cache.ap_block_factors(&op, 64, 1).unwrap();
        assert!(!Arc::ptr_eq(&f_small, &f_big));
        assert_eq!(f_big.len(), op.n() / 64);
        assert_eq!(cache.woodbury_builds(), 2);
        assert_eq!(cache.ap_builds(), 2);
        // invalidate_all drops the entries (next request rebuilds) but
        // keeps the counters
        cache.invalidate_all();
        let _ = cache.woodbury(&op, 16, 1).unwrap();
        assert_eq!(cache.woodbury_builds(), 3);
    }

    #[test]
    fn single_shard_jacobi_matches_global_woodbury_bitwise() {
        // S = 1 block-Jacobi IS the global factorisation: same rows, same
        // rank, same build path
        let mut rng = Rng::new(6);
        let n = 48;
        let x = Mat::from_fn(n, 3, |_, _| rng.gaussian());
        let hp = Hyperparams { ell: vec![0.9; 3], sigf: 1.1, sigma: 0.4 };
        let fam = KernelFamily::Matern32;
        let r = Mat::from_fn(n, 4, |_, _| rng.gaussian());
        let global = WoodburyPreconditioner::build_threaded(&x, &hp, fam, 12, 2).unwrap();
        let jac = ShardedJacobiPreconditioner::build_threaded(&x, &hp, fam, 12, 1, 2).unwrap();
        assert_eq!(jac.num_shards(), 1);
        let a = global.apply_t(&r, 2);
        let b = jac.apply_t(&r, 2);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn sharded_jacobi_applies_blockwise_and_stays_spd() {
        // each shard's slice must equal that shard's own Woodbury apply,
        // and the quadratic form must stay positive (valid preconditioner)
        let mut rng = Rng::new(7);
        let n = 53; // deliberately not divisible by the shard count
        let x = Mat::from_fn(n, 3, |_, _| rng.gaussian());
        let hp = Hyperparams { ell: vec![0.8; 3], sigf: 1.0, sigma: 0.3 };
        let fam = KernelFamily::Matern52;
        let jac = ShardedJacobiPreconditioner::build_threaded(&x, &hp, fam, 8, 3, 2).unwrap();
        assert_eq!(jac.num_shards(), 3);
        let r = Mat::from_fn(n, 3, |_, _| rng.gaussian());
        let got = jac.apply_t(&r, 1);
        for &(r0, r1) in &shard_ranges(n, 3) {
            let rows: Vec<usize> = (r0..r1).collect();
            let xs = x.gather_rows(&rows);
            let part = WoodburyPreconditioner::build_threaded(&xs, &hp, fam, 8, 1).unwrap();
            let rs = r.gather_rows(&rows);
            let want = part.apply_t(&rs, 1);
            for (a, b) in got.data[r0 * 3..r1 * 3].iter().zip(&want.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "shard {r0}..{r1}");
            }
        }
        let v = Mat::from_fn(n, 1, |_, _| rng.gaussian());
        let mv = jac.apply_t(&v, 1);
        assert!(crate::util::stats::dot(&v.data, &mv.data) > 0.0);
    }

    #[test]
    fn solver_preconditioner_routes_and_caches() {
        let cache = PreconditionerCache::default();
        let op = test_op(0.4);
        // shards <= 1 or rank 0: global Woodbury path
        match cache.solver_preconditioner(&op, 16, 1, 1).unwrap() {
            SolverPrecond::Woodbury(_) => {}
            SolverPrecond::BlockJacobi(_) => panic!("S=1 must stay on the global path"),
        }
        match cache.solver_preconditioner(&op, 0, 4, 1).unwrap() {
            SolverPrecond::Woodbury(p) => assert_eq!(p.rank(), 0),
            SolverPrecond::BlockJacobi(_) => panic!("rank 0 must stay on the global path"),
        }
        assert_eq!(cache.jacobi_builds(), 0);
        // opted in: block-Jacobi, cached on (hp, rank, shards, n)
        let a = match cache.solver_preconditioner(&op, 16, 3, 1).unwrap() {
            SolverPrecond::BlockJacobi(p) => p,
            SolverPrecond::Woodbury(_) => panic!("S=3 must shard"),
        };
        assert_eq!(a.num_shards(), 3);
        let b = match cache.solver_preconditioner(&op, 16, 3, 1).unwrap() {
            SolverPrecond::BlockJacobi(p) => p,
            SolverPrecond::Woodbury(_) => panic!(),
        };
        assert!(Arc::ptr_eq(&a, &b), "same (hp, rank, shards) must hit");
        let c = match cache.solver_preconditioner(&op, 16, 4, 1).unwrap() {
            SolverPrecond::BlockJacobi(p) => p,
            SolverPrecond::Woodbury(_) => panic!(),
        };
        assert!(!Arc::ptr_eq(&a, &c), "shard count is part of the key");
        assert_eq!(cache.jacobi_builds(), 2);
        cache.invalidate_all();
        let _ = cache.solver_preconditioner(&op, 16, 3, 1).unwrap();
        assert_eq!(cache.jacobi_builds(), 3);
    }

    #[test]
    fn cache_evicts_lru() {
        let cache = PreconditionerCache::with_capacity(2);
        let op = test_op(0.4);
        cache.woodbury(&op, 4, 1).unwrap();
        cache.woodbury(&op, 8, 1).unwrap();
        cache.woodbury(&op, 12, 1).unwrap(); // evicts rank 4
        cache.woodbury(&op, 8, 1).unwrap(); // still cached
        assert_eq!(cache.hits(), 1);
        cache.woodbury(&op, 4, 1).unwrap(); // rebuilt
        assert_eq!(cache.woodbury_builds(), 4);
    }
}
