//! CG preconditioner: rank-rho pivoted Cholesky of K plus the Woodbury
//! identity (paper follows Wang et al. 2019's rank-100 pivoted Cholesky).
//!
//!   M = L L^T + sigma^2 I,
//!   M^-1 R = (R - L C^-1 (L^T R)) / sigma^2,   C = sigma^2 I_rho + L^T L.
//!
//! Built matrix-free from kernel rows (O(rho^2 n + rho n d)) in Rust; the
//! apply is O(n rho k) per CG iteration.

use crate::kernels::{kernel_row, Hyperparams, KernelFamily};
use crate::linalg::{pivoted_cholesky, Cholesky, Mat};

pub struct WoodburyPreconditioner {
    l: Mat,              // [n, rho]
    c_chol: Cholesky,    // chol of sigma^2 I + L^T L
    noise_var: f64,
}

impl WoodburyPreconditioner {
    /// Identity preconditioner (rank 0).
    pub fn identity() -> Self {
        WoodburyPreconditioner {
            l: Mat::zeros(0, 0),
            c_chol: Cholesky { l: Mat::from_vec(1, 1, vec![1.0]) },
            noise_var: 1.0,
        }
    }

    pub fn build(x: &Mat, hp: &Hyperparams, family: KernelFamily, rank: usize) -> Self {
        if rank == 0 {
            return Self::identity();
        }
        let n = x.rows;
        let sf2 = hp.sigf * hp.sigf;
        let diag = vec![sf2; n];
        let pc = pivoted_cholesky(n, rank, &diag, |i| kernel_row(x, i, hp, family));
        let rho = pc.rank();
        let noise_var = hp.noise_var();
        // C = sigma^2 I + L^T L
        let mut c = Mat::zeros(rho, rho);
        for a in 0..rho {
            for b in a..rho {
                let mut s = 0.0;
                for i in 0..n {
                    s += pc.l[(i, a)] * pc.l[(i, b)];
                }
                c[(a, b)] = s;
                c[(b, a)] = s;
            }
        }
        c.add_diag(noise_var);
        let c_chol = Cholesky::factor(&c).expect("woodbury core SPD");
        WoodburyPreconditioner { l: pc.l, c_chol, noise_var }
    }

    pub fn rank(&self) -> usize {
        if self.l.rows == 0 {
            0
        } else {
            self.l.cols
        }
    }

    /// Apply M^-1 to every column of R.
    pub fn apply(&self, r: &Mat) -> Mat {
        if self.rank() == 0 {
            return r.clone();
        }
        let lt_r = self.l.transpose().matmul(r); // [rho, k]
        let c_inv = self.c_chol.solve_mat(&lt_r); // [rho, k]
        let l_c = self.l.matmul(&c_inv); // [n, k]
        let mut out = r.clone();
        out.sub_assign(&l_c);
        out.scale(1.0 / self.noise_var);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::h_matrix;
    use crate::util::rng::Rng;

    #[test]
    fn full_rank_preconditioner_is_exact_inverse() {
        let mut rng = Rng::new(0);
        let n = 24;
        let x = Mat::from_fn(n, 2, |_, _| rng.gaussian());
        let hp = Hyperparams { ell: vec![1.0, 1.0], sigf: 1.2, sigma: 0.5 };
        let fam = KernelFamily::Matern32;
        let pre = WoodburyPreconditioner::build(&x, &hp, fam, n);
        let h = h_matrix(&x, &hp, fam);
        let b = Mat::from_fn(n, 3, |_, _| rng.gaussian());
        let got = pre.apply(&b);
        let want = Cholesky::factor(&h).unwrap().solve_mat(&b);
        assert!(got.max_abs_diff(&want) < 1e-7, "{}", got.max_abs_diff(&want));
    }

    #[test]
    fn identity_rank_zero() {
        let pre = WoodburyPreconditioner::identity();
        let r = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(pre.apply(&r), r);
    }

    #[test]
    fn preconditioner_is_spd_quadratic_form() {
        // v^T M^-1 v > 0 for random v.
        let mut rng = Rng::new(1);
        let n = 32;
        let x = Mat::from_fn(n, 3, |_, _| rng.gaussian());
        let hp = Hyperparams { ell: vec![0.8; 3], sigf: 1.0, sigma: 0.3 };
        let pre = WoodburyPreconditioner::build(&x, &hp, KernelFamily::Matern32, 8);
        for _ in 0..5 {
            let v = Mat::from_fn(n, 1, |_, _| rng.gaussian());
            let mv = pre.apply(&v);
            let q = crate::util::stats::dot(&v.data, &mv.data);
            assert!(q > 0.0);
        }
    }
}
