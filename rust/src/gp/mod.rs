//! Exact (Cholesky) Gaussian-process baseline and predictive metrics.
//!
//! Serves three roles: (i) the "exact optimisation" comparator of Figs 5,
//! 8, 11–13; (ii) the oracle the iterative path is validated against in
//! tests; (iii) exact diagnostics for Fig 3 (tr H^-1, top eigenvalue of
//! H^-1, noise precision).  O(n^3), so small-n configs only; the
//! XLA `exact_mll` artifact provides the same quantities on the fast path.

use crate::kernels::{h_matrix, kernel_matrix, Hyperparams, KernelFamily};
use crate::linalg::{Cholesky, Mat};
use crate::util::stats;
use anyhow::Result;

/// Exact GP posterior built once per hyperparameter setting.
pub struct ExactGp {
    pub hp: Hyperparams,
    pub family: KernelFamily,
    chol: Cholesky,
    alpha: Vec<f64>, // H^-1 y
    x: Mat,
}

impl ExactGp {
    pub fn fit(x: &Mat, y: &[f64], hp: &Hyperparams, family: KernelFamily) -> Result<Self> {
        let h = h_matrix(x, hp, family);
        let chol = Cholesky::factor(&h)?;
        let alpha = chol.solve(y);
        Ok(ExactGp { hp: hp.clone(), family, chol, alpha, x: x.clone() })
    }

    /// Exact marginal log-likelihood (eq. 4).
    pub fn mll(&self, y: &[f64]) -> f64 {
        let n = y.len() as f64;
        -0.5 * stats::dot(y, &self.alpha)
            - 0.5 * self.chol.logdet()
            - 0.5 * n * (2.0 * std::f64::consts::PI).ln()
    }

    /// Exact MLL gradient (eq. 5) via closed form with explicit H^-1.
    /// Returns d/dtheta for theta = [ell.., sigf, sigma].
    pub fn mll_grad(&self) -> Vec<f64> {
        let n = self.x.rows;
        let d = self.x.cols;
        let hinv = self.chol.inverse();
        let mut grad = vec![0.0; d + 2];
        // dH/dell_k and dH/dsigf share the pairwise pass; see
        // python/compile/kernels/common.py for the derivative identities.
        let sf2 = self.hp.sigf * self.hp.sigf;
        for i in 0..n {
            for j in 0..n {
                let quad = self.alpha[i] * self.alpha[j]; // vy vy^T
                let weight = 0.5 * quad - 0.5 * hinv[(i, j)];
                let sq = crate::kernels::sqdist_scaled(
                    self.x.row(i),
                    self.x.row(j),
                    &self.hp.ell,
                );
                let h_r = dl_weight(sq, self.family);
                let kij = sf2 * self.family.unit_cov(sq);
                for k in 0..d {
                    let dlt = (self.x[(i, k)] - self.x[(j, k)]) / self.hp.ell[k];
                    grad[k] += weight * sf2 * h_r * dlt * dlt / self.hp.ell[k];
                }
                grad[d] += weight * 2.0 * kij / self.hp.sigf;
            }
            // noise: dH/dsigma = 2 sigma I
            grad[d + 1] += (0.5 * self.alpha[i] * self.alpha[i] - 0.5 * hinv[(i, i)])
                * 2.0
                * self.hp.sigma;
        }
        grad
    }

    /// Posterior predictive mean and variance (with observation noise).
    ///
    /// All t variance right-hand sides go through one batched
    /// `Cholesky::solve_mat` sweep (L is streamed once) instead of one
    /// O(n²) triangular solve per test row; `solve_mat` replays the
    /// per-column operation order, so predictions are bitwise-unchanged.
    pub fn predict(&self, x_test: &Mat) -> (Vec<f64>, Vec<f64>) {
        let kx = kernel_matrix(x_test, &self.x, &self.hp, self.family); // [t, n]
        let mean = kx.matvec(&self.alpha);
        let w = self.chol.solve_mat(&kx.transpose()); // [n, t]
        let n = self.x.rows;
        let prior = self.hp.sigf * self.hp.sigf;
        let mut var = Vec::with_capacity(x_test.rows);
        for i in 0..x_test.rows {
            let krow = kx.row(i);
            let mut reduction = 0.0;
            for j in 0..n {
                reduction += krow[j] * w[(j, i)];
            }
            var.push((prior - reduction).max(1e-12) + self.hp.noise_var());
        }
        (mean, var)
    }

    /// tr(H^-1) and the top eigenvalue of H^-1 (Fig 3 diagnostics).
    pub fn hinv_diagnostics(&self) -> (f64, f64) {
        let hinv = self.chol.inverse();
        let trace = hinv.trace();
        let top = crate::linalg::power_iteration(hinv.rows, |v| hinv.matvec(v), 100, 0);
        (trace, top)
    }

    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        self.chol.solve(b)
    }
}

/// Radial weight h(r): mirror of kernels/common.py::dl_weight.
fn dl_weight(sq: f64, family: KernelFamily) -> f64 {
    use crate::kernels::{SQRT3, SQRT5};
    match family {
        KernelFamily::Rbf => (-0.5 * sq).exp(),
        KernelFamily::Matern12 => {
            let r = sq.max(0.0).sqrt();
            (-r).exp() / r.max(1e-30)
        }
        KernelFamily::Matern32 => 3.0 * (-SQRT3 * sq.max(0.0).sqrt()).exp(),
        KernelFamily::Matern52 => {
            let r = sq.max(0.0).sqrt();
            (5.0 / 3.0) * (1.0 + SQRT5 * r) * (-SQRT5 * r).exp()
        }
    }
}

/// Per-row pathwise predictive variances from posterior samples [t, s]:
/// the unbiased sample variance across the s pathwise draws plus the
/// observation noise.  Single source for `Trainer::evaluate` and the
/// prediction-serving path — the serve parity suite demands bitwise-equal
/// variances between the two, so the summation order here is load-bearing.
pub fn pathwise_variances(samples: &Mat, noise_var: f64) -> Vec<f64> {
    (0..samples.rows)
        .map(|i| {
            let row = samples.row(i);
            let mu: f64 = row.iter().sum::<f64>() / row.len() as f64;
            let v: f64 = row.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>()
                / (row.len() - 1).max(1) as f64;
            v + noise_var
        })
        .collect()
}

/// Predictive metrics from mean/variance predictions.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Metrics {
    pub rmse: f64,
    pub llh: f64,
}

pub fn metrics(mean: &[f64], var: &[f64], y_test: &[f64]) -> Metrics {
    Metrics {
        rmse: stats::rmse(mean, y_test),
        llh: stats::gaussian_llh(mean, var, y_test),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn toy(n: usize, d: usize, seed: u64) -> (Mat, Vec<f64>, Hyperparams) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, d, |_, _| rng.gaussian());
        let y = rng.gaussian_vec(n);
        let hp = Hyperparams { ell: vec![1.0; d], sigf: 1.2, sigma: 0.4 };
        (x, y, hp)
    }

    #[test]
    fn mll_matches_direct_formula() {
        let (x, y, hp) = toy(32, 2, 0);
        let gp = ExactGp::fit(&x, &y, &hp, KernelFamily::Matern32).unwrap();
        let h = h_matrix(&x, &hp, KernelFamily::Matern32);
        let ch = Cholesky::factor(&h).unwrap();
        let want = -0.5 * stats::dot(&y, &ch.solve(&y))
            - 0.5 * ch.logdet()
            - 0.5 * 32.0 * (2.0 * std::f64::consts::PI).ln();
        assert!((gp.mll(&y) - want).abs() < 1e-10);
    }

    #[test]
    fn mll_grad_matches_finite_difference() {
        let (x, y, hp) = toy(24, 2, 1);
        let fam = KernelFamily::Matern32;
        let gp = ExactGp::fit(&x, &y, &hp, fam).unwrap();
        let grad = gp.mll_grad();
        let theta = hp.pack();
        let eps = 1e-5;
        for k in 0..theta.len() {
            let mut tp = theta.clone();
            tp[k] += eps;
            let hp_p = Hyperparams::unpack(&tp, 2);
            let lp = ExactGp::fit(&x, &y, &hp_p, fam).unwrap().mll(&y);
            let mut tm = theta.clone();
            tm[k] -= eps;
            let hp_m = Hyperparams::unpack(&tm, 2);
            let lm = ExactGp::fit(&x, &y, &hp_m, fam).unwrap().mll(&y);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (grad[k] - fd).abs() < 1e-5 * (1.0 + fd.abs()),
                "k={k}: analytic {} vs fd {fd}",
                grad[k]
            );
        }
    }

    #[test]
    fn predictions_interpolate_clean_data() {
        // Noise-free-ish GP regression on its own training points must
        // reproduce the targets closely.
        let mut rng = Rng::new(2);
        let x = Mat::from_fn(24, 1, |i, _| i as f64 * 0.3 + 0.01 * rng.gaussian());
        let y: Vec<f64> = (0..24).map(|i| (i as f64 * 0.3).sin()).collect();
        let hp = Hyperparams { ell: vec![1.0], sigf: 1.0, sigma: 0.01 };
        let gp = ExactGp::fit(&x, &y, &hp, KernelFamily::Matern52).unwrap();
        let (mean, _) = gp.predict(&x);
        for (m, t) in mean.iter().zip(&y) {
            assert!((m - t).abs() < 0.05, "{m} vs {t}");
        }
    }

    #[test]
    fn predictive_variance_grows_off_data() {
        let (x, y, hp) = toy(32, 1, 3);
        let gp = ExactGp::fit(&x, &y, &hp, KernelFamily::Matern32).unwrap();
        let near = Mat::from_vec(1, 1, vec![0.0]);
        let far = Mat::from_vec(1, 1, vec![50.0]);
        let (_, v_near) = gp.predict(&near);
        let (_, v_far) = gp.predict(&far);
        assert!(v_far[0] > v_near[0]);
        // far from data, variance approaches prior + noise
        assert!((v_far[0] - (1.44 + 0.16)).abs() < 1e-6);
    }

    #[test]
    fn batched_predict_is_bitwise_equal_to_per_row_solves() {
        // regression: predict used one O(n²) triangular solve per test
        // row; the batched solve_mat path must reproduce those
        // predictions bit for bit
        let (x, y, hp) = toy(48, 3, 9);
        let gp = ExactGp::fit(&x, &y, &hp, KernelFamily::Matern32).unwrap();
        let mut rng = Rng::new(10);
        let x_test = Mat::from_fn(17, 3, |_, _| rng.gaussian());
        let (mean, var) = gp.predict(&x_test);
        // per-row reference (the pre-batching algorithm)
        let kx = kernel_matrix(&x_test, &gp.x, &gp.hp, gp.family);
        let prior = gp.hp.sigf * gp.hp.sigf;
        for i in 0..x_test.rows {
            let krow = kx.row(i);
            let w = gp.chol.solve(krow);
            let reduction = stats::dot(krow, &w);
            let want = (prior - reduction).max(1e-12) + gp.hp.noise_var();
            assert_eq!(var[i].to_bits(), want.to_bits(), "var row {i}");
            let want_mean = stats::dot(krow, &gp.alpha);
            assert_eq!(mean[i].to_bits(), want_mean.to_bits(), "mean row {i}");
        }
    }

    #[test]
    fn hinv_diagnostics_consistent() {
        let (x, y, hp) = toy(24, 2, 4);
        let gp = ExactGp::fit(&x, &y, &hp, KernelFamily::Matern32).unwrap();
        let (trace, top) = gp.hinv_diagnostics();
        // top eigenvalue <= trace <= n * top for SPD
        assert!(top <= trace + 1e-9);
        assert!(trace <= 24.0 * top + 1e-9);
        // top eig of H^-1 is at most 1/sigma^2
        assert!(top <= 1.0 / hp.noise_var() + 1e-9);
    }

    #[test]
    fn metrics_computation() {
        let m = metrics(&[0.0, 1.0], &[1.0, 1.0], &[0.0, 1.0]);
        assert!(m.rmse.abs() < 1e-12);
        assert!((m.llh + 0.5 * (2.0 * std::f64::consts::PI).ln()).abs() < 1e-12);
    }
}
