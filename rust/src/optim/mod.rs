//! Outer-loop optimiser: Adam over softplus-reparameterised positive
//! hyperparameters (paper Appendix B: theta = log(1 + exp(nu)), Adam with
//! default betas, learning rate 0.1 small / 0.03 large datasets).
//!
//! Adam here *maximises* the marginal likelihood (ascent), matching the
//! sign convention of the gradient estimator.

/// Softplus and its inverse, numerically stable for large inputs.
pub fn softplus(nu: f64) -> f64 {
    if nu > 30.0 {
        nu
    } else {
        nu.exp().ln_1p()
    }
}

pub fn softplus_inv(theta: f64) -> f64 {
    assert!(theta > 0.0, "softplus_inv needs positive input");
    if theta > 30.0 {
        theta
    } else {
        theta.exp_m1().ln()
    }
}

/// d theta / d nu = sigmoid(nu).
pub fn softplus_grad(nu: f64) -> f64 {
    1.0 / (1.0 + (-nu).exp())
}

/// Adam state over the unconstrained parameters nu.
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: u64,
}

impl Adam {
    pub fn new(dim: usize, lr: f64) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; dim],
            v: vec![0.0; dim],
            t: 0,
        }
    }

    /// One ascent step: nu += lr * mhat / (sqrt(vhat) + eps).
    pub fn step(&mut self, nu: &mut [f64], grad_nu: &[f64]) {
        assert_eq!(nu.len(), self.m.len());
        assert_eq!(grad_nu.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..nu.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grad_nu[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * grad_nu[i] * grad_nu[i];
            let mhat = self.m[i] / b1t;
            let vhat = self.v[i] / b2t;
            nu[i] += self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }

    pub fn steps_taken(&self) -> u64 {
        self.t
    }

    /// Raw optimiser state (for checkpointing).
    pub fn state(&self) -> (&[f64], &[f64], u64) {
        (&self.m, &self.v, self.t)
    }

    /// Restore optimiser state (checkpoint resume).
    pub fn restore_state(&mut self, m: Vec<f64>, v: Vec<f64>, t: u64) {
        assert_eq!(m.len(), self.m.len());
        assert_eq!(v.len(), self.v.len());
        self.m = m;
        self.v = v;
        self.t = t;
    }
}

/// Positive hyperparameter vector handled through the softplus bijection.
#[derive(Clone, Debug)]
pub struct SoftplusParams {
    pub nu: Vec<f64>,
}

impl SoftplusParams {
    /// Initialise from positive theta values.
    pub fn from_theta(theta: &[f64]) -> Self {
        SoftplusParams { nu: theta.iter().map(|&t| softplus_inv(t)).collect() }
    }

    pub fn theta(&self) -> Vec<f64> {
        self.nu.iter().map(|&v| softplus(v)).collect()
    }

    /// Chain rule: dL/dnu = dL/dtheta * sigmoid(nu).
    pub fn chain_grad(&self, grad_theta: &[f64]) -> Vec<f64> {
        assert_eq!(grad_theta.len(), self.nu.len());
        grad_theta
            .iter()
            .zip(&self.nu)
            .map(|(&g, &nu)| g * softplus_grad(nu))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softplus_inverse_roundtrip() {
        for t in [0.01, 0.5, 1.0, 5.0, 50.0] {
            assert!((softplus(softplus_inv(t)) - t).abs() / t < 1e-10, "{t}");
        }
    }

    #[test]
    fn softplus_grad_is_sigmoid() {
        let eps = 1e-6;
        for nu in [-3.0, 0.0, 2.5] {
            let fd = (softplus(nu + eps) - softplus(nu - eps)) / (2.0 * eps);
            assert!((softplus_grad(nu) - fd).abs() < 1e-8);
        }
    }

    #[test]
    fn adam_maximises_simple_quadratic() {
        // maximise -(x - 3)^2: gradient = -2 (x - 3)
        let mut adam = Adam::new(1, 0.1);
        let mut x = vec![0.0];
        for _ in 0..500 {
            let g = vec![-2.0 * (x[0] - 3.0)];
            adam.step(&mut x, &g);
        }
        assert!((x[0] - 3.0).abs() < 1e-3, "{}", x[0]);
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // With bias correction the very first step is ~lr * sign(grad).
        let mut adam = Adam::new(1, 0.05);
        let mut x = vec![0.0];
        adam.step(&mut x, &[123.0]);
        assert!((x[0] - 0.05).abs() < 1e-6);
    }

    #[test]
    fn softplus_params_keep_theta_positive() {
        let mut p = SoftplusParams::from_theta(&[1.0, 1.0]);
        let mut adam = Adam::new(2, 0.5);
        // push hard in the negative direction; theta must stay positive
        for _ in 0..100 {
            let g = p.chain_grad(&[-10.0, -10.0]);
            adam.step(&mut p.nu, &g);
        }
        for t in p.theta() {
            assert!(t > 0.0);
        }
    }

    #[test]
    fn chain_grad_matches_finite_difference() {
        let p = SoftplusParams::from_theta(&[0.7]);
        let g_theta = 2.0; // dL/dtheta
        let eps = 1e-6;
        // L(nu) = 2 * softplus(nu): dL/dnu = 2 sigmoid(nu)
        let fd = (2.0 * softplus(p.nu[0] + eps) - 2.0 * softplus(p.nu[0] - eps)) / (2.0 * eps);
        let got = p.chain_grad(&[g_theta])[0];
        assert!((got - fd).abs() < 1e-8);
    }
}
