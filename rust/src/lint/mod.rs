//! # `igp-lint` — determinism & panic-safety static analysis
//!
//! A zero-dependency lint pass over `rust/src/**` enforcing the
//! invariants this codebase's correctness arguments rest on: total
//! float orderings (no NaN panics), order-canonical reductions (bitwise
//! parallel/serial parity), centralised threading, deterministic
//! iteration, the f32/f64 precision contract, and a ratcheted ban on
//! `unwrap`/`expect` in library code.  See the rule table in
//! `rust/README.md` for the motivating bug behind each rule.
//!
//! The pass has three layers:
//!
//! * [`scan`] — strips comments/strings (offset-preserving), masks test
//!   regions, and parses suppression directives of the form
//!   `lint:allow(<rule>): <why>` (in a line comment; covers that line
//!   and the next; the reason is mandatory).
//! * [`rules`] — pattern rules over the stripped text.
//! * [`baseline`] — the `lint-baseline.json` ratchet for grandfathered
//!   `lib-unwrap` sites: counts may only go down.
//!
//! Entry points: [`lint_sources`] for in-memory fixtures (tests) and
//! [`lint_tree`] for a crate directory (the `igp-lint` binary and the
//! tree-cleanliness integration test).

pub mod baseline;
pub mod rules;
pub mod scan;

pub use baseline::Baseline;
pub use rules::{Violation, MALFORMED_ALLOW, RATCHETED, RULES};

use scan::SourceFile;
use std::collections::BTreeMap;
use std::io;
use std::path::Path;

/// Outcome of a lint run: actionable findings plus advisory notes
/// (ratchet-tightening opportunities).  Clean means `violations` empty.
#[derive(Debug, Default)]
pub struct LintReport {
    pub violations: Vec<Violation>,
    pub notes: Vec<String>,
    pub files_scanned: usize,
    pub suppressed: usize,
}

/// Lint in-memory `(path, text)` pairs.  Paths are crate-relative with
/// `/` separators (`src/...`) — rule scoping keys off them.  With a
/// baseline, ratcheted rules are folded into per-file count comparisons;
/// without one, every ratcheted violation is reported individually.
pub fn lint_sources(files: &[(String, String)], baseline: Option<&Baseline>) -> LintReport {
    let mut report = LintReport { files_scanned: files.len(), ..LintReport::default() };
    // per ratcheted rule: file -> current count (post-suppression)
    let mut ratchet_counts: BTreeMap<&'static str, BTreeMap<String, usize>> = BTreeMap::new();
    for (path, text) in files {
        let sf = SourceFile::new(path, text);
        for allow in &sf.allows {
            let names_known = allow.rules.iter().any(|r| RULES.contains(&r.as_str()));
            if names_known && !allow.reason_ok {
                report.violations.push(Violation {
                    rule: MALFORMED_ALLOW,
                    file: path.clone(),
                    line: allow.line,
                    message: "suppression without a reason; every allow must say why \
                              the invariant is safe to waive here"
                        .into(),
                });
            }
        }
        for v in rules::check_file(&sf) {
            if suppressed(&sf, v.rule, v.line) {
                report.suppressed += 1;
            } else if RATCHETED.contains(&v.rule) && baseline.is_some() {
                *ratchet_counts.entry(v.rule).or_default().entry(v.file).or_insert(0) += 1;
            } else {
                report.violations.push(v);
            }
        }
    }
    if let Some(base) = baseline {
        for &rule in RATCHETED {
            let current = ratchet_counts.remove(rule).unwrap_or_default();
            let baseline_files = base.rules.get(rule).cloned().unwrap_or_default();
            let mut all_files: Vec<&String> = current.keys().chain(baseline_files.keys()).collect();
            all_files.sort();
            all_files.dedup();
            for file in all_files {
                let cur = current.get(file).copied().unwrap_or(0);
                let grand = baseline_files.get(file).copied().unwrap_or(0);
                if cur > grand {
                    report.violations.push(Violation {
                        rule,
                        file: file.clone(),
                        line: 0,
                        message: format!(
                            "{cur} {rule} sites but the baseline grandfathers {grand}; \
                             fix the new sites (the ratchet only goes down)"
                        ),
                    });
                } else if cur < grand {
                    report.notes.push(format!(
                        "{file}: {rule} improved {grand} -> {cur}; run \
                         `igp-lint --update-baseline` to lock in the progress"
                    ));
                }
            }
        }
    }
    report.violations.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    report
}

/// Recompute a baseline from the current tree state: per-file counts of
/// every ratcheted rule, after suppressions.
pub fn baseline_from(files: &[(String, String)]) -> Baseline {
    let mut out = Baseline::default();
    let mut counts: BTreeMap<(&'static str, String), usize> = BTreeMap::new();
    for (path, text) in files {
        let sf = SourceFile::new(path, text);
        for v in rules::check_file(&sf) {
            if RATCHETED.contains(&v.rule) && !suppressed(&sf, v.rule, v.line) {
                *counts.entry((v.rule, v.file)).or_insert(0) += 1;
            }
        }
    }
    for ((rule, file), count) in counts {
        out.set(rule, &file, count);
    }
    out
}

fn suppressed(sf: &SourceFile, rule: &str, line: usize) -> bool {
    sf.allows.iter().any(|a| {
        a.reason_ok
            && (a.line == line || a.line + 1 == line)
            && a.rules.iter().any(|r| r.as_str() == rule)
    })
}

/// Collect `(relative_path, text)` for every `.rs` file under
/// `<crate_root>/src`, sorted by path (the walk itself must be
/// deterministic, for the same reason the code it scans must be).
pub fn collect_sources(crate_root: &Path) -> io::Result<Vec<(String, String)>> {
    let src = crate_root.join("src");
    let mut stack = vec![src.clone()];
    let mut files = Vec::new();
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<_> = std::fs::read_dir(&dir)?.collect::<io::Result<_>>()?;
        entries.sort_by_key(|e| e.file_name());
        for entry in entries {
            let path = entry.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                let rel = path
                    .strip_prefix(crate_root)
                    .unwrap_or(&path)
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy())
                    .collect::<Vec<_>>()
                    .join("/");
                files.push((rel, std::fs::read_to_string(&path)?));
            }
        }
    }
    files.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(files)
}

/// Lint a crate directory (the one holding `src/`).
pub fn lint_tree(crate_root: &Path, baseline: Option<&Baseline>) -> io::Result<LintReport> {
    Ok(lint_sources(&collect_sources(crate_root)?, baseline))
}
