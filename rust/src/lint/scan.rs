//! Lexical preprocessing for `igp-lint`.
//!
//! The scanner is deliberately *not* a parser: it classifies bytes into
//! code / comment / string / char-literal with a small state machine and
//! blanks everything that is not code with spaces, preserving byte
//! offsets and line numbers exactly.  Rules pattern-match on the
//! stripped text only, so occurrences inside comments or string
//! literals can never fire, while suppression directives are read from
//! the *raw* lines (they live in comments by construction).

/// A source file prepared for rule matching.
pub struct SourceFile {
    /// Crate-relative path with `/` separators, e.g. `src/solvers/cg.rs`.
    pub path: String,
    /// Original text (directive parsing, context snippets).
    pub raw: String,
    /// Same length as `raw`, with comments, strings and char literals
    /// blanked to spaces (newlines kept, so offsets and lines agree).
    pub stripped: String,
    /// Byte offset of the start of each line (index 0 = line 1).
    pub line_starts: Vec<usize>,
    /// Per line (0-indexed): is this line inside `#[cfg(test)]` /
    /// `#[test]` code?
    pub test_mask: Vec<bool>,
    /// Parsed suppression directives, in file order.
    pub allows: Vec<Allow>,
}

/// One suppression directive.  It covers its own line and the line
/// directly below it, for the rules it names, and only when a non-empty
/// reason follows the rule list.
pub struct Allow {
    /// 1-based line the directive sits on.
    pub line: usize,
    /// Rule names inside the parentheses (may include unknown names;
    /// those are inert).
    pub rules: Vec<String>,
    /// Whether a `: reason` with non-empty reason text was present.
    pub reason_ok: bool,
}

impl SourceFile {
    pub fn new(path: &str, raw: &str) -> SourceFile {
        let stripped = strip(raw);
        let line_starts = line_starts(raw);
        let test_mask = test_mask(&stripped, &line_starts);
        let allows = parse_allows(raw);
        SourceFile { path: path.to_string(), raw: raw.to_string(), stripped, line_starts, test_mask, allows }
    }

    /// 1-based line number of a byte offset into `stripped`/`raw`.
    pub fn line_of(&self, off: usize) -> usize {
        self.line_starts.partition_point(|&s| s <= off)
    }

    /// Is the (1-based) line inside test-only code?
    pub fn is_test_line(&self, line: usize) -> bool {
        line >= 1 && self.test_mask.get(line - 1).copied().unwrap_or(false)
    }
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// First occurrence of `needle` in `hay` at or after `from`.
pub fn find_from(hay: &[u8], needle: &[u8], from: usize) -> Option<usize> {
    if needle.is_empty() || hay.len() < needle.len() {
        return None;
    }
    (from..=hay.len() - needle.len()).find(|&i| &hay[i..i + needle.len()] == needle)
}

pub fn contains(hay: &[u8], needle: &[u8]) -> bool {
    find_from(hay, needle, 0).is_some()
}

fn line_starts(raw: &str) -> Vec<usize> {
    let mut starts = vec![0usize];
    for (i, b) in raw.bytes().enumerate() {
        if b == b'\n' && i + 1 < raw.len() {
            starts.push(i + 1);
        }
    }
    starts
}

/// Blank comments, string literals and char literals with spaces,
/// keeping newlines so byte offsets map 1:1 onto the original text.
pub fn strip(raw: &str) -> String {
    let b = raw.as_bytes();
    let n = b.len();
    let mut out = b.to_vec();
    let blank = |out: &mut [u8], from: usize, to: usize| {
        for slot in out.iter_mut().take(to.min(n)).skip(from) {
            if *slot != b'\n' {
                *slot = b' ';
            }
        }
    };
    let mut i = 0usize;
    while i < n {
        let c = b[i];
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let mut j = i;
            while j < n && b[j] != b'\n' {
                j += 1;
            }
            blank(&mut out, i, j);
            i = j;
        } else if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            // block comments nest in Rust
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, i, j);
            i = j;
        } else if c == b'"' {
            let j = skip_plain_string(b, i);
            blank(&mut out, i, j);
            i = j;
        } else if (c == b'r' || c == b'b') && (i == 0 || !is_ident(b[i - 1])) {
            // raw / byte string prefixes: r"..", r#".."#, b"..", br"..", br#".."#
            let mut j = i + 1;
            if c == b'b' && j < n && b[j] == b'r' {
                j += 1;
            }
            let raw_form = j > i + 1 || c == b'r';
            let mut hashes = 0usize;
            if raw_form {
                while j < n && b[j] == b'#' {
                    hashes += 1;
                    j += 1;
                }
            }
            if j < n && b[j] == b'"' {
                let end = if raw_form {
                    skip_raw_string(b, j, hashes)
                } else {
                    skip_plain_string(b, j)
                };
                blank(&mut out, i, end);
                i = end;
            } else {
                i += 1;
            }
        } else if c == b'\'' {
            if i + 1 < n && b[i + 1] == b'\\' {
                // escaped char literal, e.g. '\n', '\'', '\u{1F600}'
                let mut j = i + 2;
                if j < n && b[j] == b'u' {
                    while j < n && b[j] != b'}' {
                        j += 1;
                    }
                }
                j += 1;
                while j < n && b[j] != b'\'' {
                    j += 1;
                }
                blank(&mut out, i, (j + 1).min(n));
                i = (j + 1).min(n);
            } else if i + 2 < n && b[i + 2] == b'\'' {
                // one-byte char literal 'x'
                blank(&mut out, i, i + 3);
                i += 3;
            } else {
                // multi-byte char literal ('é') has only continuation
                // bytes (>= 0x80) before the closing quote; anything
                // else is a lifetime, which needs no blanking
                let mut j = i + 1;
                while j < n && j <= i + 4 && b[j] >= 0x80 {
                    j += 1;
                }
                if j > i + 1 && j < n && b[j] == b'\'' {
                    blank(&mut out, i, j + 1);
                    i = j + 1;
                } else {
                    i += 1;
                }
            }
        } else {
            i += 1;
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Byte offset just past the closing quote of a `"…"` string starting
/// at `start` (which must point at the opening quote).
fn skip_plain_string(b: &[u8], start: usize) -> usize {
    let n = b.len();
    let mut j = start + 1;
    while j < n {
        match b[j] {
            b'\\' => j += 2,
            b'"' => return j + 1,
            _ => j += 1,
        }
    }
    n
}

fn skip_raw_string(b: &[u8], quote: usize, hashes: usize) -> usize {
    let n = b.len();
    let mut j = quote + 1;
    while j < n {
        if b[j] == b'"' && j + hashes < n && b[j + 1..j + 1 + hashes].iter().all(|&h| h == b'#') {
            return j + 1 + hashes;
        }
        j += 1;
    }
    n
}

/// Mark every line covered by a `#[cfg(test)]` or `#[test]` item.  The
/// walk from the attribute skips intervening attributes and signatures
/// to the item's `{`, then brace-matches to its end (a `;` at bracket
/// depth 0 first means an item with no body, e.g. `#[cfg(test)] use …;`).
fn test_mask(stripped: &str, line_starts: &[usize]) -> Vec<bool> {
    let b = stripped.as_bytes();
    let mut mask = vec![false; line_starts.len()];
    for pat in [&b"#[cfg(test)]"[..], &b"#[test]"[..]] {
        let mut from = 0usize;
        while let Some(p) = find_from(b, pat, from) {
            from = p + pat.len();
            let mut j = from;
            let mut nest = 0isize; // () and [] nesting along the signature
            let mut end = b.len();
            while j < b.len() {
                match b[j] {
                    b'(' | b'[' => nest += 1,
                    b')' | b']' => nest -= 1,
                    b';' if nest == 0 => {
                        end = j + 1;
                        break;
                    }
                    b'{' => {
                        let mut depth = 1isize;
                        let mut k = j + 1;
                        while k < b.len() && depth > 0 {
                            match b[k] {
                                b'{' => depth += 1,
                                b'}' => depth -= 1,
                                _ => {}
                            }
                            k += 1;
                        }
                        end = k;
                        break;
                    }
                    _ => {}
                }
                j += 1;
            }
            let l0 = line_starts.partition_point(|&s| s <= p);
            let l1 = line_starts.partition_point(|&s| s < end);
            for l in l0..=l1.min(mask.len()) {
                mask[l - 1] = true;
            }
        }
    }
    mask
}

/// Parse suppression directives from the raw lines.  A directive must
/// sit in a `//` comment and name its rules in parentheses; suppression
/// additionally requires a trailing `: reason` (checked by the caller
/// via [`Allow::reason_ok`]).
fn parse_allows(raw: &str) -> Vec<Allow> {
    let marker = "lint:allow(";
    let mut out = Vec::new();
    for (idx, line) in raw.lines().enumerate() {
        let Some(slash) = line.find("//") else { continue };
        let Some(rel) = line[slash..].find(marker) else { continue };
        let body = &line[slash + rel + marker.len()..];
        let Some(close) = body.find(')') else {
            out.push(Allow { line: idx + 1, rules: Vec::new(), reason_ok: false });
            continue;
        };
        let rules: Vec<String> = body[..close]
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        let after = body[close + 1..].trim_start();
        let reason_ok = after.starts_with(':') && !after[1..].trim().is_empty();
        out.push(Allow { line: idx + 1, rules, reason_ok });
    }
    out
}
