//! The rule set.  Each rule pattern-matches on the stripped text of a
//! [`SourceFile`] (so comments and string literals never fire) and
//! yields candidate [`Violation`]s; suppression, test-region exemption
//! and the ratchet baseline are applied by the caller in `lint::`.

use super::scan::{contains, find_from, SourceFile};

/// `partial_cmp(..).unwrap()` and float comparators built on
/// `partial_cmp` — both panic (or misbehave) on NaN; use `total_cmp`.
pub const FLOAT_TOTAL_ORDER: &str = "float-total-order";
/// Ad-hoc float reductions in numeric code; route through the
/// order-canonical helpers so parallel/serial results stay bitwise equal.
pub const ORDERED_REDUCTION: &str = "ordered-reduction";
/// Raw `std::thread` spawns outside `util/parallel.rs`.
pub const NO_RAW_THREADS: &str = "no-raw-threads";
/// `HashMap`/`HashSet` in deterministic paths: iteration order is
/// randomised per process, which breaks bitwise reproducibility.
pub const NONDET_ITERATION: &str = "nondeterministic-iteration";
/// `as f32` truncation outside the two blessed demotion sites.
pub const PRECISION_CAST: &str = "precision-cast";
/// `unwrap()`/`expect()` in non-test library code (ratcheted).
pub const LIB_UNWRAP: &str = "lib-unwrap";
/// Synthesised for a suppression directive that names a known rule but
/// carries no reason; never suppressible.
pub const MALFORMED_ALLOW: &str = "malformed-allow";

/// Every suppressible rule, in reporting order.
pub const RULES: &[&str] = &[
    FLOAT_TOTAL_ORDER,
    ORDERED_REDUCTION,
    NO_RAW_THREADS,
    NONDET_ITERATION,
    PRECISION_CAST,
    LIB_UNWRAP,
];

/// Rules whose existing violation counts are grandfathered by
/// `lint-baseline.json` and may only go down.
pub const RATCHETED: &[&str] = &[LIB_UNWRAP];

/// One finding, before or after baseline filtering.  `line` is 1-based;
/// line 0 is used for per-file ratchet summaries.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Violation {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
}

/// Run every rule over one prepared file.  Returns candidates in file
/// order, deduplicated per rule and line.
pub fn check_file(sf: &SourceFile) -> Vec<Violation> {
    let mut out = Vec::new();
    float_total_order(sf, &mut out);
    ordered_reduction(sf, &mut out);
    no_raw_threads(sf, &mut out);
    nondet_iteration(sf, &mut out);
    precision_cast(sf, &mut out);
    lib_unwrap(sf, &mut out);
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    // the two float-total-order patterns double-fire on one-line
    // comparators; other rules keep one finding per *site* so the
    // ratchet counts sites, not lines
    out.dedup_by(|a, b| a.rule == FLOAT_TOTAL_ORDER && b.rule == FLOAT_TOTAL_ORDER && a.line == b.line);
    out
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// The statement window after a match: up to `max` bytes, truncated at
/// the first `;` so a pattern never leaks into the next statement.
fn window(hay: &[u8], start: usize, max: usize) -> &[u8] {
    let end = (start + max).min(hay.len());
    let w = &hay[start..end];
    match w.iter().position(|&c| c == b';') {
        Some(p) => &w[..p],
        None => w,
    }
}

fn each_match(sf: &SourceFile, needle: &str, mut f: impl FnMut(usize, usize)) {
    let hay = sf.stripped.as_bytes();
    let nb = needle.as_bytes();
    let mut from = 0usize;
    while let Some(p) = find_from(hay, nb, from) {
        f(p, sf.line_of(p));
        from = p + nb.len();
    }
}

fn float_total_order(sf: &SourceFile, out: &mut Vec<Violation>) {
    // applies to test code too: a NaN-panicking comparator in a test
    // helper is the same latent crash
    let hay = sf.stripped.as_bytes();
    each_match(sf, ".partial_cmp(", |p, line| {
        if contains(window(hay, p, 64), b".unwrap()") {
            out.push(Violation {
                rule: FLOAT_TOTAL_ORDER,
                file: sf.path.clone(),
                line,
                message: "partial_cmp(..).unwrap() panics on NaN; compare with f64::total_cmp".into(),
            });
        }
    });
    for family in ["sort_by(", "sort_unstable_by(", "max_by(", "min_by("] {
        each_match(sf, family, |p, line| {
            if contains(window(hay, p, 160), b"partial_cmp") {
                out.push(Violation {
                    rule: FLOAT_TOTAL_ORDER,
                    file: sf.path.clone(),
                    line,
                    message: format!(
                        "{family}..) comparator built on partial_cmp; use f64::total_cmp for a total order"
                    ),
                });
            }
        });
    }
}

fn ordered_reduction(sf: &SourceFile, out: &mut Vec<Violation>) {
    let in_scope = ["src/solvers/", "src/operators/", "src/kernels/", "src/linalg/"]
        .iter()
        .any(|d| sf.path.starts_with(d));
    let canonical_home = sf.path == "src/linalg/micro.rs" || sf.path == "src/solvers/recurrence.rs";
    if !in_scope || canonical_home {
        return;
    }
    let hay = sf.stripped.as_bytes();
    let mut push = |line: usize| {
        if !sf.is_test_line(line) {
            out.push(Violation {
                rule: ORDERED_REDUCTION,
                file: sf.path.clone(),
                line,
                message: "ad-hoc float reduction; route through linalg::micro::sum or \
                          util::parallel so the association order stays canonical"
                    .into(),
            });
        }
    };
    for needle in [".sum()", ".sum::<", ".product()"] {
        each_match(sf, needle, |_, line| push(line));
    }
    each_match(sf, ".fold(", |p, line| {
        let w = window(hay, p, 48);
        // folds seeded with a float accumulate in iteration order; max/min
        // folds are order-insensitive and stay allowed
        if contains(w, b"0.0") && !contains(w, b"max") && !contains(w, b"min") {
            push(line);
        }
    });
}

fn no_raw_threads(sf: &SourceFile, out: &mut Vec<Violation>) {
    if sf.path == "src/util/parallel.rs" {
        return;
    }
    for needle in ["thread::spawn", ".spawn("] {
        each_match(sf, needle, |_, line| {
            out.push(Violation {
                rule: NO_RAW_THREADS,
                file: sf.path.clone(),
                line,
                message: "raw thread spawn; go through util::parallel so worker counts, \
                          panic propagation and result order stay deterministic"
                    .into(),
            });
        });
    }
}

fn nondet_iteration(sf: &SourceFile, out: &mut Vec<Violation>) {
    if sf.path.starts_with("src/runtime/") {
        return;
    }
    let hay = sf.stripped.as_bytes();
    for needle in ["HashMap", "HashSet"] {
        each_match(sf, needle, |p, line| {
            let pre_ok = p == 0 || !is_ident(hay[p - 1]);
            let post = p + needle.len();
            let post_ok = post >= hay.len() || !is_ident(hay[post]);
            if pre_ok && post_ok && !sf.is_test_line(line) {
                out.push(Violation {
                    rule: NONDET_ITERATION,
                    file: sf.path.clone(),
                    line,
                    message: format!(
                        "{needle} iteration order is randomised per process; use BTreeMap/BTreeSet \
                         (or a Vec) in deterministic paths"
                    ),
                });
            }
        });
    }
}

fn precision_cast(sf: &SourceFile, out: &mut Vec<Violation>) {
    if sf.path == "src/kernels/panel.rs" || sf.path == "src/linalg/micro.rs" {
        return;
    }
    let hay = sf.stripped.as_bytes();
    each_match(sf, "as f32", |p, line| {
        let pre_ok = p == 0 || !is_ident(hay[p - 1]);
        let post = p + "as f32".len();
        let post_ok = post >= hay.len() || !is_ident(hay[post]);
        if pre_ok && post_ok && !sf.is_test_line(line) {
            out.push(Violation {
                rule: PRECISION_CAST,
                file: sf.path.clone(),
                line,
                message: "f32 demotion outside kernels::panel / linalg::micro; the precision \
                          contract keeps every other path f64"
                    .into(),
            });
        }
    });
}

fn lib_unwrap(sf: &SourceFile, out: &mut Vec<Violation>) {
    for needle in [".unwrap()", ".expect("] {
        each_match(sf, needle, |_, line| {
            if !sf.is_test_line(line) {
                out.push(Violation {
                    rule: LIB_UNWRAP,
                    file: sf.path.clone(),
                    line,
                    message: "unwrap/expect in library code; return a typed error instead \
                              (grandfathered sites are ratcheted by lint-baseline.json)"
                        .into(),
                });
            }
        });
    }
}
