//! The ratchet baseline: per-rule, per-file grandfathered violation
//! counts, stored as `lint-baseline.json` at the repo root.  Counts may
//! only go down — a count above baseline fails the run, a count below
//! it asks for `--update-baseline` so the ceiling follows the progress.
//!
//! Parsing and rendering are hand-rolled over the one fixed shape the
//! file uses (the crate is dependency-free by policy):
//!
//! ```json
//! {
//!   "version": 1,
//!   "rules": { "<rule>": { "<file>": <count> } }
//! }
//! ```

use std::collections::BTreeMap;

/// Grandfathered counts, keyed rule → file → count.  `BTreeMap` keeps
/// rendering (and therefore diffs) stable.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Baseline {
    pub rules: BTreeMap<String, BTreeMap<String, usize>>,
}

impl Baseline {
    pub fn count(&self, rule: &str, file: &str) -> usize {
        self.rules.get(rule).and_then(|m| m.get(file)).copied().unwrap_or(0)
    }

    pub fn set(&mut self, rule: &str, file: &str, count: usize) {
        if count > 0 {
            self.rules.entry(rule.to_string()).or_default().insert(file.to_string(), count);
        }
    }

    /// Strict parse of the baseline shape above.
    pub fn parse(text: &str) -> Result<Baseline, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        let mut out = Baseline::default();
        p.ws();
        p.eat(b'{')?;
        let mut first = true;
        loop {
            p.ws();
            if p.peek() == Some(b'}') {
                p.i += 1;
                break;
            }
            if !first {
                p.eat(b',')?;
                p.ws();
            }
            first = false;
            let key = p.string()?;
            p.ws();
            p.eat(b':')?;
            p.ws();
            match key.as_str() {
                "version" => {
                    let v = p.number()?;
                    if v != 1 {
                        return Err(format!("unsupported baseline version {v}"));
                    }
                }
                "rules" => {
                    p.eat(b'{')?;
                    let mut first_rule = true;
                    loop {
                        p.ws();
                        if p.peek() == Some(b'}') {
                            p.i += 1;
                            break;
                        }
                        if !first_rule {
                            p.eat(b',')?;
                            p.ws();
                        }
                        first_rule = false;
                        let rule = p.string()?;
                        p.ws();
                        p.eat(b':')?;
                        p.ws();
                        p.eat(b'{')?;
                        let mut files = BTreeMap::new();
                        let mut first_file = true;
                        loop {
                            p.ws();
                            if p.peek() == Some(b'}') {
                                p.i += 1;
                                break;
                            }
                            if !first_file {
                                p.eat(b',')?;
                                p.ws();
                            }
                            first_file = false;
                            let file = p.string()?;
                            p.ws();
                            p.eat(b':')?;
                            p.ws();
                            let count = p.number()?;
                            files.insert(file, count);
                        }
                        out.rules.insert(rule, files);
                    }
                }
                other => return Err(format!("unknown baseline key {other:?}")),
            }
        }
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at offset {}", p.i));
        }
        Ok(out)
    }

    /// Render in the exact shape `parse` accepts, keys sorted, with a
    /// trailing newline (diff-friendly; byte-stable across runs).
    pub fn render(&self) -> String {
        let mut s = String::from("{\n  \"version\": 1,\n  \"rules\": {");
        let live: Vec<_> = self.rules.iter().filter(|(_, files)| !files.is_empty()).collect();
        for (ri, (rule, files)) in live.iter().enumerate() {
            s.push_str(if ri == 0 { "\n" } else { ",\n" });
            s.push_str(&format!("    {}: {{\n", quote(rule)));
            for (fi, (file, count)) in files.iter().enumerate() {
                if fi > 0 {
                    s.push_str(",\n");
                }
                s.push_str(&format!("      {}: {count}", quote(file)));
            }
            s.push_str("\n    }");
        }
        if live.is_empty() {
            s.push_str("}\n}\n");
        } else {
            s.push_str("\n  }\n}\n");
        }
        s
    }
}

fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            _ => out.push(c),
        }
    }
    out.push('"');
    out
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while self.peek().is_some_and(|c| c.is_ascii_whitespace()) {
            self.i += 1;
        }
    }

    fn eat(&mut self, want: u8) -> Result<(), String> {
        match self.peek() {
            Some(c) if c == want => {
                self.i += 1;
                Ok(())
            }
            got => Err(format!(
                "expected {:?} at offset {}, found {:?}",
                want as char,
                self.i,
                got.map(|c| c as char)
            )),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = Vec::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return String::from_utf8(out).map_err(|e| e.to_string());
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(c @ (b'"' | b'\\' | b'/')) => {
                            out.push(c);
                            self.i += 1;
                        }
                        other => return Err(format!("unsupported escape {other:?}")),
                    }
                }
                Some(c) => {
                    out.push(c);
                    self.i += 1;
                }
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn number(&mut self) -> Result<usize, String> {
        let start = self.i;
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.i == start {
            return Err(format!("expected a number at offset {start}"));
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| format!("bad number at offset {start}"))
    }
}
