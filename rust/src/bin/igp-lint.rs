//! `igp-lint` — the determinism & panic-safety lint pass, as a binary.
//!
//! ```text
//! igp-lint [--root DIR] [--baseline FILE] [--json FILE] [--update-baseline]
//! ```
//!
//! Exit codes: 0 clean, 1 violations found, 2 usage or I/O error.  The
//! default root is this crate's own directory, so `cargo run --bin
//! igp-lint` lints the tree it was built from; the default baseline is
//! `lint-baseline.json` at the repo root (one level above the crate).

use igp::lint::{self, Baseline};
use igp::util::bench::{render_flat_records, JsonField};
use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "usage: igp-lint [--root DIR] [--baseline FILE] [--json FILE] [--update-baseline]";

fn main() -> ExitCode {
    match run() {
        Ok(code) => code,
        Err(msg) => {
            eprintln!("igp-lint: error: {msg}");
            ExitCode::from(2)
        }
    }
}

fn run() -> Result<ExitCode, String> {
    let mut root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut baseline_path: Option<PathBuf> = None;
    let mut json_path: Option<PathBuf> = None;
    let mut update = false;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => root = PathBuf::from(args.next().ok_or("--root needs a directory")?),
            "--baseline" => {
                baseline_path = Some(PathBuf::from(args.next().ok_or("--baseline needs a file")?))
            }
            "--json" => json_path = Some(PathBuf::from(args.next().ok_or("--json needs a file")?)),
            "--update-baseline" => update = true,
            "--help" | "-h" => {
                println!("{USAGE}");
                return Ok(ExitCode::SUCCESS);
            }
            other => return Err(format!("unknown argument {other:?}\n{USAGE}")),
        }
    }
    let baseline_path = baseline_path.unwrap_or_else(|| root.join("../lint-baseline.json"));

    let files = lint::collect_sources(&root)
        .map_err(|e| format!("scanning {}: {e}", root.display()))?;

    if update {
        let fresh = lint::baseline_from(&files);
        std::fs::write(&baseline_path, fresh.render())
            .map_err(|e| format!("writing {}: {e}", baseline_path.display()))?;
        println!("igp-lint: baseline updated: {}", baseline_path.display());
    }

    let text = std::fs::read_to_string(&baseline_path).map_err(|e| {
        format!(
            "reading baseline {}: {e} (run with --update-baseline to create it)",
            baseline_path.display()
        )
    })?;
    let baseline = Baseline::parse(&text)
        .map_err(|e| format!("parsing {}: {e}", baseline_path.display()))?;

    let report = lint::lint_sources(&files, Some(&baseline));

    for v in &report.violations {
        if v.line == 0 {
            println!("{}: [{}] {}", v.file, v.rule, v.message);
        } else {
            println!("{}:{}: [{}] {}", v.file, v.line, v.rule, v.message);
        }
    }
    for note in &report.notes {
        println!("note: {note}");
    }

    if let Some(path) = json_path {
        let records: Vec<Vec<(String, JsonField)>> = report
            .violations
            .iter()
            .map(|v| {
                vec![
                    ("rule".to_string(), JsonField::Str(v.rule.to_string())),
                    ("file".to_string(), JsonField::Str(v.file.clone())),
                    ("line".to_string(), JsonField::Int(v.line as i64)),
                    ("message".to_string(), JsonField::Str(v.message.clone())),
                ]
            })
            .collect();
        std::fs::write(&path, render_flat_records(&records))
            .map_err(|e| format!("writing {}: {e}", path.display()))?;
    }

    if report.violations.is_empty() {
        println!(
            "igp-lint: clean — {} files scanned, {} suppression(s) honoured",
            report.files_scanned, report.suppressed
        );
        Ok(ExitCode::SUCCESS)
    } else {
        println!(
            "igp-lint: {} violation(s) across {} file(s)",
            report.violations.len(),
            {
                let mut f: Vec<&str> = report.violations.iter().map(|v| v.file.as_str()).collect();
                f.sort();
                f.dedup();
                f.len()
            }
        );
        Ok(ExitCode::from(1))
    }
}
