//! Gradient estimators for the marginal likelihood (Sections 2.1 and 3).
//!
//! * **Standard** (Hutchinson): probes z ~ N(0, I); solver targets
//!   [y | z_1..z_s]; gradient needs the pairs (v_j, z_j).
//! * **Pathwise**: probes xi = f(X) + sigma w with f an RFF prior draw, so
//!   xi ~ N(0, H~); the solutions zhat = H^-1 xi are N(0, H^-1)-distributed
//!   probes *and* the pathwise-conditioning terms for prediction (eq. 16).
//!
//! Warm-start contract (Section 4): targets must stay fixed across outer
//! steps — the standard z are sampled once, the pathwise randomness
//! (omega0, wts, w-noise) is sampled once and xi is *re-evaluated* under
//! the current hyperparameters each step (eps = sigma*w reparameterisation,
//! fixed RFF frequencies scaled by the current lengthscales).

use crate::linalg::Mat;
use crate::operators::KernelOperator;
use crate::util::rng::Rng;

#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum EstimatorKind {
    Standard,
    Pathwise,
}

impl EstimatorKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "standard" => EstimatorKind::Standard,
            "pathwise" => EstimatorKind::Pathwise,
            other => anyhow::bail!("unknown estimator '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            EstimatorKind::Standard => "standard",
            EstimatorKind::Pathwise => "pathwise",
        }
    }
}

/// Distribution of the standard estimator's probe vectors.  Both satisfy
/// E[z z^T] = I; Rademacher has the smaller fourth moment (E z^4 = 1 vs 3),
/// which tightens the concentration bound of Theorem 2.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum ProbeDist {
    Gaussian,
    Rademacher,
}

impl ProbeDist {
    pub fn draw(&self, rng: &mut Rng) -> f64 {
        match self {
            ProbeDist::Gaussian => rng.gaussian(),
            ProbeDist::Rademacher => {
                if rng.uniform() < 0.5 {
                    -1.0
                } else {
                    1.0
                }
            }
        }
    }
}

/// All randomness of one estimator instance.
pub struct ProbeSet {
    pub kind: EstimatorKind,
    /// Distribution the standard probes were drawn from (extensions must
    /// append rows from the same distribution).
    pub dist: ProbeDist,
    /// Standard probes Z [n, s] (kept for the standard estimator).
    pub z: Mat,
    /// RFF base frequencies [d, m] (unit-lengthscale spectral density).
    pub omega0: Mat,
    /// RFF weights [2m, s].
    pub wts: Mat,
    /// Noise reparameterisation draws [n, s] (eps = sigma * noise).
    pub noise: Mat,
}

impl ProbeSet {
    pub fn sample(kind: EstimatorKind, op: &dyn KernelOperator, rng: &mut Rng) -> Self {
        Self::sample_with(kind, ProbeDist::Gaussian, op, rng)
    }

    pub fn sample_with(
        kind: EstimatorKind,
        dist: ProbeDist,
        op: &dyn KernelOperator,
        rng: &mut Rng,
    ) -> Self {
        let (n, d, s, m) = (op.n(), op.d(), op.s(), op.m());
        let z = Mat::from_fn(n, s, |_, _| dist.draw(rng));
        // Matern spectral density: per-feature student-t scale shared
        // across input dims; RBF: plain Gaussian frequencies.
        let df = op.family().spectral_t_df();
        let mut omega0 = Mat::zeros(d, m);
        for c in 0..m {
            let t = df.map(|v| rng.student_t_scale(v)).unwrap_or(1.0);
            for r in 0..d {
                omega0[(r, c)] = t * rng.gaussian();
            }
        }
        let wts = Mat::from_fn(2 * m, s, |_, _| rng.gaussian());
        let noise = Mat::from_fn(n, s, |_, _| rng.gaussian());
        ProbeSet { kind, dist, z, omega0, wts, noise }
    }

    /// Grow the probe state by `n_new` training rows (online data
    /// arrival): `z` gains rows from the set's own probe distribution and
    /// the noise reparameterisation gains Gaussian rows, both freshly
    /// drawn from `rng` (the coordinator passes a per-chunk derived
    /// stream), while `omega0`/`wts` are **reused** — the RFF prior draw
    /// is a function on input space, so pathwise targets on the original
    /// rows are unchanged under fixed hyperparameters and the warm-start
    /// contract survives the extension.
    pub fn extend_rows(&mut self, n_new: usize, rng: &mut Rng) {
        let s = self.z.cols;
        let dist = self.dist;
        self.z.append_rows(&Mat::from_fn(n_new, s, |_, _| dist.draw(rng)));
        self.noise.append_rows(&Mat::from_fn(n_new, s, |_, _| rng.gaussian()));
    }

    /// Solver targets B = [y | probes] under the current hyperparameters.
    pub fn targets(&self, op: &dyn KernelOperator, y: &[f64]) -> Mat {
        let (n, s) = (op.n(), op.s());
        assert_eq!(y.len(), n);
        let probes = match self.kind {
            EstimatorKind::Standard => self.z.clone(),
            EstimatorKind::Pathwise => op.rff_eval(&self.omega0, &self.wts, &self.noise),
        };
        let mut b = Mat::zeros(n, s + 1);
        b.set_col(0, y);
        for j in 0..s {
            for i in 0..n {
                b[(i, j + 1)] = probes[(i, j)];
            }
        }
        b
    }

    /// Gradient estimate of L from the solved batch V = [v_y | v_1..v_s]
    /// and the targets B used to produce it:
    ///
    ///   g = 1/2 v_y' dH v_y - 1/(2s) sum_j a_j' dH b_j
    ///
    /// standard: (a_j, b_j) = (v_j, z_j);  pathwise: (zhat_j, zhat_j).
    pub fn grad(&self, op: &dyn KernelOperator, v: &Mat, b_targets: &Mat) -> Vec<f64> {
        let s = op.s();
        assert_eq!(v.cols, s + 1);
        let mut w = vec![-1.0 / (2.0 * s as f64); s + 1];
        w[0] = 0.5;
        match self.kind {
            EstimatorKind::Standard => {
                // A = V (v_y and v_j), B = [v_y | z_1..z_s]
                let mut bq = b_targets.clone();
                let vy = v.col(0);
                bq.set_col(0, &vy);
                op.grad_quad(v, &bq, &w)
            }
            EstimatorKind::Pathwise => {
                // A = B = [v_y | zhat_1..zhat_s]
                op.grad_quad(v, v, &w)
            }
        }
    }

    /// The pathwise-conditioning probes zhat [n, s] from the solved batch.
    pub fn zhat(&self, v: &Mat) -> Mat {
        let (n, k) = (v.rows, v.cols);
        Mat::from_fn(n, k - 1, |i, j| v[(i, j + 1)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::kernels::Hyperparams;
    use crate::linalg::Cholesky;
    use crate::operators::{DenseOperator, KernelOperator};

    fn op() -> (DenseOperator, Vec<f64>) {
        let ds = data::generate(&data::spec("test").unwrap());
        let mut op = DenseOperator::new(&ds, 8, 32);
        op.set_hp(&Hyperparams { ell: vec![1.0; 4], sigf: 1.0, sigma: 0.4 });
        (op, ds.y_train)
    }

    #[test]
    fn targets_first_column_is_y() {
        let (op, y) = op();
        let mut rng = Rng::new(0);
        for kind in [EstimatorKind::Standard, EstimatorKind::Pathwise] {
            let ps = ProbeSet::sample(kind, &op, &mut rng);
            let b = ps.targets(&op, &y);
            assert_eq!(b.cols, op.s() + 1);
            for i in 0..op.n() {
                assert_eq!(b[(i, 0)], y[i]);
            }
        }
    }

    #[test]
    fn standard_targets_are_fixed_pathwise_rescale() {
        let (mut o, y) = op();
        let mut rng = Rng::new(1);
        let ps_std = ProbeSet::sample(EstimatorKind::Standard, &o, &mut rng);
        let ps_pw = ProbeSet::sample(EstimatorKind::Pathwise, &o, &mut rng);
        let b_std_1 = ps_std.targets(&o, &y);
        let b_pw_1 = ps_pw.targets(&o, &y);
        o.set_hp(&Hyperparams { ell: vec![0.5; 4], sigf: 1.5, sigma: 0.2 });
        let b_std_2 = ps_std.targets(&o, &y);
        let b_pw_2 = ps_pw.targets(&o, &y);
        // standard: identical; pathwise: same randomness, new theta -> differs
        assert!(b_std_1.max_abs_diff(&b_std_2) < 1e-15);
        assert!(b_pw_1.max_abs_diff(&b_pw_2) > 1e-3);
    }

    #[test]
    fn pathwise_probe_second_moment_tracks_h() {
        // E[xi xi'] ~ H: check diagonal within MC error using many probes.
        let ds = data::generate(&data::spec("test").unwrap());
        let mut o = DenseOperator::new(&ds, 256, 128);
        let hp = Hyperparams { ell: vec![1.0; 4], sigf: 1.2, sigma: 0.3 };
        o.set_hp(&hp);
        let mut rng = Rng::new(2);
        let ps = ProbeSet::sample(EstimatorKind::Pathwise, &o, &mut rng);
        let b = ps.targets(&o, &ds.y_train);
        let n = o.n();
        let s = o.s();
        let mut diag_mean = 0.0;
        for i in 0..n {
            let mut acc = 0.0;
            for j in 1..=s {
                acc += b[(i, j)] * b[(i, j)];
            }
            diag_mean += acc / s as f64;
        }
        diag_mean /= n as f64;
        let want = 1.2 * 1.2 + 0.3 * 0.3; // k(x,x) + sigma^2
        assert!(
            (diag_mean - want).abs() / want < 0.25,
            "emp {diag_mean} vs want {want}"
        );
    }

    #[test]
    fn extend_rows_keeps_old_pathwise_targets_bitwise() {
        // online contract: appending probe rows must not disturb the
        // targets of the rows that were already there (omega0/wts reused;
        // only fresh z/noise rows are drawn)
        let ds = data::generate(&data::spec("test").unwrap());
        let hp = Hyperparams { ell: vec![0.9; 4], sigf: 1.1, sigma: 0.35 };
        let n0 = 180;
        let base = ds.with_train(
            ds.x_train.gather_rows(&(0..n0).collect::<Vec<_>>()),
            ds.y_train[..n0].to_vec(),
        );
        let mut op = DenseOperator::new(&base, 6, 24);
        op.set_hp(&hp);
        let mut rng = Rng::new(11);
        for kind in [EstimatorKind::Standard, EstimatorKind::Pathwise] {
            let mut ps = ProbeSet::sample(kind, &op, &mut rng);
            let before = ps.targets(&op, &base.y_train);
            let mut grown = op.clone();
            let chunk = ds.x_train.gather_rows(&(n0..ds.x_train.rows).collect::<Vec<_>>());
            grown.extend(&chunk).unwrap();
            let mut chunk_rng = Rng::new(99);
            ps.extend_rows(chunk.rows, &mut chunk_rng);
            assert_eq!(ps.z.rows, grown.n());
            assert_eq!(ps.noise.rows, grown.n());
            let mut y = base.y_train.clone();
            y.extend_from_slice(&ds.y_train[n0..]);
            let after = ps.targets(&grown, &y);
            assert_eq!(after.rows, grown.n());
            for i in 0..n0 {
                for j in 0..before.cols {
                    assert_eq!(
                        before[(i, j)].to_bits(),
                        after[(i, j)].to_bits(),
                        "{kind:?} old target ({i},{j}) changed"
                    );
                }
            }
        }
    }

    #[test]
    fn extend_rows_keeps_the_probe_distribution() {
        // regression: extensions drew Gaussian rows regardless of the
        // distribution the set was sampled with, silently mixing probe
        // statistics on the appended rows
        let ds = data::generate(&data::spec("test").unwrap());
        let op = DenseOperator::new(&ds, 6, 24);
        let mut rng = Rng::new(17);
        let mut ps =
            ProbeSet::sample_with(EstimatorKind::Standard, ProbeDist::Rademacher, &op, &mut rng);
        let mut chunk_rng = Rng::new(18);
        ps.extend_rows(40, &mut chunk_rng);
        assert_eq!(ps.z.rows, op.n() + 40);
        assert!(ps.z.data.iter().all(|&v| v == 1.0 || v == -1.0));
    }

    #[test]
    fn grad_estimates_unbiased_vs_exact() {
        // With many probes the estimator must approach the exact gradient.
        let ds = data::generate(&data::spec("test").unwrap());
        // many probes + many RFF features: the pathwise estimator carries
        // both MC variance and RFF bias (paper Fig 5 discusses the latter)
        let mut o = DenseOperator::new(&ds, 192, 512);
        let hp = Hyperparams { ell: vec![0.9; 4], sigf: 1.1, sigma: 0.5 };
        o.set_hp(&hp);
        let y = &ds.y_train;
        let (_, exact_grad) = o.exact_mll(y).unwrap();
        let ch = Cholesky::factor(o.h()).unwrap();
        let mut rng = Rng::new(3);
        for kind in [EstimatorKind::Standard, EstimatorKind::Pathwise] {
            let ps = ProbeSet::sample(kind, &o, &mut rng);
            let b = ps.targets(&o, y);
            let v = ch.solve_mat(&b); // exact inner solve isolates estimator error
            let g = ps.grad(&o, &v, &b);
            for k in 0..g.len() {
                let scale = 1.0 + exact_grad[k].abs();
                assert!(
                    (g[k] - exact_grad[k]).abs() / scale < 0.5,
                    "{kind:?} comp {k}: est {} vs exact {}",
                    g[k],
                    exact_grad[k]
                );
            }
        }
    }

    #[test]
    fn rademacher_probes_are_pm_one_with_identity_second_moment() {
        let (op, _) = op();
        let mut rng = Rng::new(9);
        let ps = ProbeSet::sample_with(EstimatorKind::Standard, ProbeDist::Rademacher, &op, &mut rng);
        let mut mean = 0.0;
        for v in &ps.z.data {
            assert!(*v == 1.0 || *v == -1.0);
            mean += v;
        }
        mean /= ps.z.data.len() as f64;
        assert!(mean.abs() < 0.1, "{mean}");
    }

    #[test]
    fn initial_distance_identity_pathwise_vs_standard() {
        // Eq (14)/(15): E||u*||_H^2 = tr(H^-1) (standard) vs n (pathwise).
        // With the test config's noise (sigma=0.4), tr(H^-1) >> n would
        // mean standard is worse; verify the *measured* quadratic forms.
        let ds = data::generate(&data::spec("test").unwrap());
        let mut o = DenseOperator::new(&ds, 64, 64);
        let hp = Hyperparams { ell: vec![1.0; 4], sigf: 1.0, sigma: 0.1 }; // high precision
        o.set_hp(&hp);
        let ch = Cholesky::factor(o.h()).unwrap();
        let mut rng = Rng::new(4);
        let n = o.n() as f64;
        let mut dist = |kind| {
            let ps = ProbeSet::sample(kind, &o, &mut rng);
            let b = ps.targets(&o, &vec![0.0; o.n()]);
            let mut acc = 0.0;
            for j in 1..=o.s() {
                let bj = b.col(j);
                let sol = ch.solve(&bj);
                acc += crate::util::stats::dot(&bj, &sol);
            }
            acc / o.s() as f64
        };
        let d_std = dist(EstimatorKind::Standard);
        let d_pw = dist(EstimatorKind::Pathwise);
        // pathwise ~= n (up to RFF/MC error), standard ~= tr(H^-1) > n here
        assert!((d_pw - n) / n < 0.5, "pathwise {d_pw} vs n {n}");
        assert!(d_std > d_pw, "std {d_std} pw {d_pw}");
    }
}
