//! Tiny CLI argument parser (clap is unavailable offline): supports
//! `--key value`, `--key=value`, boolean `--flag`, and positionals.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

pub struct Parser {
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Parser {
    /// `value_keys` lists options that consume a value; every other
    /// `--name` is treated as a boolean flag.
    pub fn new(args: &[String], value_keys: &[&str]) -> Result<Self> {
        let mut options = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    options.insert(k.to_string(), v.to_string());
                } else if value_keys.contains(&name) {
                    let Some(v) = args.get(i + 1) else {
                        bail!("option --{name} expects a value");
                    };
                    options.insert(name.to_string(), v.clone());
                    i += 1;
                } else {
                    flags.push(name.to_string());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Parser { options, flags, positional })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    /// Typed option access with a readable error mentioning the flag name.
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.options.get(key) {
            None => Ok(None),
            Some(v) => match v.parse::<T>() {
                Ok(t) => Ok(Some(t)),
                Err(e) => bail!("option --{key}: cannot parse '{v}': {e}"),
            },
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_styles() {
        let p = Parser::new(
            &v(&["table1", "--steps", "30", "--lr=0.1", "--warm-start"]),
            &["steps", "lr"],
        )
        .unwrap();
        assert_eq!(p.positional, vec!["table1"]);
        assert_eq!(p.get("steps"), Some("30"));
        assert_eq!(p.get("lr"), Some("0.1"));
        assert!(p.flag("warm-start"));
        assert!(!p.flag("other"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Parser::new(&v(&["--steps"]), &["steps"]).is_err());
    }

    #[test]
    fn get_parsed_typed_access() {
        let p = Parser::new(&v(&["--steps", "30", "--lr=0.5"]), &["steps", "lr"]).unwrap();
        assert_eq!(p.get_parsed::<usize>("steps").unwrap(), Some(30));
        assert_eq!(p.get_parsed::<f64>("lr").unwrap(), Some(0.5));
        assert_eq!(p.get_parsed::<usize>("absent").unwrap(), None);
        assert!(p.get_parsed::<usize>("lr").is_err());
    }
}
