//! Deterministic fault injection and supervised recovery.
//!
//! The paper's three techniques (pathwise estimation, warm starting, early
//! stopping) all trade solver work for tolerable bias — a trade that only
//! pays off in production if the system survives the failure modes it
//! creates: divergent warm starts, drifted low-precision solves, poisoned
//! preconditioners, stale artifacts (Maddox et al., *When are Iterative
//! Gaussian Processes Reliably Accurate?*).  This module provides the one
//! coherent, *testable* recovery layer the scattered per-site guards
//! (SGD backoff, CG drift fallback, [`SolveReport::aborted`]) grew toward:
//!
//! * [`FaultPlan`] — a seeded, deterministic fault schedule parsed from the
//!   `--chaos SPEC` / `chaos` config key.  Every fault is a pure function
//!   of `(seed, site, step, draw index)`, so a chaos run is exactly
//!   reproducible.  Unarmed (the default) the hooks are `Option::None`
//!   checks on cold paths — provably zero-cost: the operator is never
//!   wrapped and the supervised code path is never taken.
//! * [`FaultSite`] — the named injection points spanning train, solve and
//!   serve (see the README site table).
//! * [`ChaosOpView`] — a borrowing [`KernelOperator`] wrapper that corrupts
//!   the first kernel products of a solve attempt (NaN panel rows, Inf
//!   shard partials, poisoned preconditioner columns) and then burns out,
//!   so a retry against the same view is bitwise-transparent.
//! * [`Supervisor`] — the recovery driver owned by `Trainer` and mirrored
//!   by `PredictionService`: bounded retry with quarantine-and-rebuild,
//!   cross-solver fallback (configured solver → CG-f64 reference),
//!   outer-step rollback, and serve-side graceful degradation, all metered
//!   into [`RecoveryStats`].
//! * [`FaultError`] — the typed taxonomy every recovery failure surfaces
//!   as (convertible into the vendored `anyhow` via `std::error::Error`).
//! * [`fnv1a`] — the checkpoint-v3 content checksum.
//!
//! [`SolveReport::aborted`]: crate::solvers::SolveReport

use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::kernels::{Hyperparams, KernelFamily};
use crate::linalg::Mat;
use crate::operators::{HvScratch, KernelOperator, Precision};

// ---------------------------------------------------------------------------
// Hashing primitives
// ---------------------------------------------------------------------------

/// FNV-1a 64-bit content hash (checkpoint v3 checksum).  Chosen for its
/// trivial, dependency-free, endianness-independent definition; this is a
/// corruption detector, not a cryptographic MAC.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// SplitMix64 — the deterministic per-(seed, site, step, draw) stream
/// behind probabilistic triggers and corruption offsets.
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

// ---------------------------------------------------------------------------
// Fault sites
// ---------------------------------------------------------------------------

/// Named injection points.  Step semantics differ by owner: train-side
/// sites tick once per outer optimisation step; serve-side sites
/// (`cache`, `refresh`) tick once per service operation (flush/drain).
#[derive(Copy, Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultSite {
    /// NaN row in a kernel panel product (`hv` / `k_rows` / `k_cols`).
    Panel,
    /// NaN row in the probe targets b (caught and repaired pre-solve).
    Probe,
    /// Inf row-range in an `hv` partial (a corrupted shard partial).
    Shard,
    /// Poisoned preconditioner build (NaN in the first `k_cols` panel).
    Precond,
    /// Solver stall: the attempt burns its epoch budget and diverges.
    Solver,
    /// Artifact-cache poisoning (NaN `vy` in a cached posterior).
    Cache,
    /// Checkpoint corruption on save (truncation or bit-flip).
    Checkpoint,
    /// Serve-side artifact refresh failure (`refresh_first` path).
    Refresh,
}

impl FaultSite {
    pub const ALL: [FaultSite; 8] = [
        FaultSite::Panel,
        FaultSite::Probe,
        FaultSite::Shard,
        FaultSite::Precond,
        FaultSite::Solver,
        FaultSite::Cache,
        FaultSite::Checkpoint,
        FaultSite::Refresh,
    ];

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "panel" => FaultSite::Panel,
            "probe" => FaultSite::Probe,
            "shard" => FaultSite::Shard,
            "precond" => FaultSite::Precond,
            "solver" => FaultSite::Solver,
            "cache" => FaultSite::Cache,
            "checkpoint" => FaultSite::Checkpoint,
            "refresh" => FaultSite::Refresh,
            other => anyhow::bail!(
                "unknown fault site '{other}' \
                 (panel|probe|shard|precond|solver|cache|checkpoint|refresh)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            FaultSite::Panel => "panel",
            FaultSite::Probe => "probe",
            FaultSite::Shard => "shard",
            FaultSite::Precond => "precond",
            FaultSite::Solver => "solver",
            FaultSite::Cache => "cache",
            FaultSite::Checkpoint => "checkpoint",
            FaultSite::Refresh => "refresh",
        }
    }

    /// Stable per-site stream key (independent of declaration order).
    fn key(&self) -> u64 {
        match self {
            FaultSite::Panel => 0x01,
            FaultSite::Probe => 0x02,
            FaultSite::Shard => 0x03,
            FaultSite::Precond => 0x04,
            FaultSite::Solver => 0x05,
            FaultSite::Cache => 0x06,
            FaultSite::Checkpoint => 0x07,
            FaultSite::Refresh => 0x08,
        }
    }
}

// ---------------------------------------------------------------------------
// Chaos spec grammar + FaultPlan
// ---------------------------------------------------------------------------

/// When an entry fires.
#[derive(Copy, Clone, Debug, PartialEq)]
enum Trigger {
    /// Fire on the first `count` opportunities at exactly `step`.
    At { step: u64, count: u32 },
    /// Fire each opportunity independently with probability `p`, drawn
    /// from the deterministic `(seed, site, step, draw)` stream.
    Prob(f64),
}

#[derive(Copy, Clone, Debug, PartialEq)]
struct FaultEntry {
    site: FaultSite,
    trigger: Trigger,
}

#[derive(Debug, Default)]
struct FaultState {
    /// Current step (outer optimisation step, or service-operation tick).
    step: u64,
    /// Opportunities consumed per scheduled entry (parallel to `entries`).
    burned: Vec<u32>,
    /// Draw counters per (site key, step) for probabilistic triggers.
    draws: BTreeMap<(u64, u64), u64>,
}

/// A parsed, armed chaos schedule.
///
/// Spec grammar (entries separated by `;`, whitespace ignored):
///
/// ```text
/// SPEC  := ENTRY (';' ENTRY)*
/// ENTRY := 'seed=' N                      -- stream seed (default 0)
///        | SITE '@' STEP ('x' COUNT)?     -- scheduled: COUNT consecutive
///                                         --   failing opportunities at
///                                         --   STEP (default COUNT = 1)
///        | SITE '~' PROB                  -- probabilistic per opportunity
/// SITE  := panel|probe|shard|precond|solver|cache|checkpoint|refresh
/// ```
///
/// Example: `seed=7;panel@1;solver@2x3;refresh~0.25`.
///
/// An *opportunity* is one supervised action that consults the site: one
/// solve attempt (panel/shard/precond/solver), one outer step (probe),
/// one service operation (cache/refresh), one checkpoint save
/// (checkpoint).  A spec with only `seed=` is valid and fires nothing —
/// it arms the supervised path without injecting (the bench baseline for
/// supervision overhead).
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    entries: Vec<FaultEntry>,
    state: Mutex<FaultState>,
}

impl FaultPlan {
    /// Parse a chaos spec (see the type-level grammar).  Single-source:
    /// config validation, the CLI and tests all route through here.
    pub fn parse(spec: &str) -> anyhow::Result<FaultPlan> {
        let mut seed = 0u64;
        let mut entries = Vec::new();
        for raw in spec.split(';') {
            let ent = raw.trim();
            if ent.is_empty() {
                continue;
            }
            if let Some(v) = ent.strip_prefix("seed=") {
                seed = v
                    .trim()
                    .parse::<u64>()
                    .map_err(|_| anyhow::anyhow!("chaos spec: bad seed '{v}'"))?;
            } else if let Some((site, prob)) = ent.split_once('~') {
                let site = FaultSite::parse(site.trim())?;
                let p = prob
                    .trim()
                    .parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("chaos spec: bad probability '{prob}'"))?;
                if !(0.0..=1.0).contains(&p) {
                    anyhow::bail!("chaos spec: probability {p} outside [0, 1]");
                }
                entries.push(FaultEntry { site, trigger: Trigger::Prob(p) });
            } else if let Some((site, at)) = ent.split_once('@') {
                let site = FaultSite::parse(site.trim())?;
                let (step, count) = match at.split_once('x') {
                    Some((s, c)) => {
                        let count = c
                            .trim()
                            .parse::<u32>()
                            .map_err(|_| anyhow::anyhow!("chaos spec: bad count '{c}'"))?;
                        if count == 0 {
                            anyhow::bail!("chaos spec: count must be >= 1");
                        }
                        (s, count)
                    }
                    None => (at, 1),
                };
                let step = step
                    .trim()
                    .parse::<u64>()
                    .map_err(|_| anyhow::anyhow!("chaos spec: bad step '{step}'"))?;
                entries.push(FaultEntry { site, trigger: Trigger::At { step, count } });
            } else {
                anyhow::bail!(
                    "chaos spec: cannot parse entry '{ent}' \
                     (expected seed=N, site@STEP[xCOUNT] or site~PROB)"
                );
            }
        }
        let burned = vec![0u32; entries.len()];
        Ok(FaultPlan {
            seed,
            entries,
            state: Mutex::new(FaultState { step: 0, burned, draws: BTreeMap::new() }),
        })
    }

    /// Seed of the deterministic fault stream (also used to derive
    /// corruption rows/offsets, so distinct seeds hit distinct rows).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// True when some entry can still fire at some step (a seed-only plan
    /// is armed but benign).
    pub fn has_entries(&self) -> bool {
        !self.entries.is_empty()
    }

    /// Poison-recovering state access: a panicked holder cannot have left
    /// the counters half-updated in a way recovery cares about, and the
    /// fault layer must itself never panic.
    fn state(&self) -> MutexGuard<'_, FaultState> {
        match self.state.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Position the schedule at an owner-defined step (outer optimisation
    /// step for the trainer, service-operation tick for the serve layer).
    pub fn set_step(&self, step: u64) {
        self.state().step = step;
    }

    /// Consume one opportunity for `site` at the current step; true when
    /// any entry fires.  Scheduled entries burn one of their COUNT
    /// opportunities per call; probabilistic entries draw from the
    /// deterministic stream, advancing the per-(site, step) draw counter.
    pub fn fires(&self, site: FaultSite) -> bool {
        let mut st = self.state();
        let step = st.step;
        let mut fired = false;
        for (i, e) in self.entries.iter().enumerate() {
            if e.site != site {
                continue;
            }
            match e.trigger {
                Trigger::At { step: s, count } => {
                    if s == step && st.burned[i] < count {
                        st.burned[i] += 1;
                        fired = true;
                    }
                }
                Trigger::Prob(p) => {
                    let draw = st.draws.entry((site.key(), step)).or_insert(0);
                    let h = splitmix64(
                        self.seed
                            ^ site.key().wrapping_mul(0x9e37_79b9_7f4a_7c15)
                            ^ step.wrapping_mul(0xd1b5_4a32_d192_ed03)
                            ^ *draw,
                    );
                    *draw += 1;
                    let u = (h >> 11) as f64 * (1.0 / 9_007_199_254_740_992.0);
                    if u < p {
                        fired = true;
                    }
                }
            }
        }
        fired
    }

    /// Seed-derived corruption target inside `n` rows.
    pub fn target_row(&self, n: usize) -> usize {
        if n == 0 {
            return 0;
        }
        (splitmix64(self.seed ^ 0x726f_77) as usize) % n
    }

    /// Deterministically corrupt a serialized byte payload: even stream
    /// parity truncates, odd parity flips one bit at a seed-derived
    /// offset.  Models the checkpoint failure modes (torn write, media
    /// corruption) the v3 checksum exists to catch.
    pub fn corrupt_bytes(&self, bytes: &mut Vec<u8>) {
        if bytes.is_empty() {
            return;
        }
        let h = splitmix64(self.seed ^ 0x6368_6563_6b70_7431);
        let off = ((h >> 1) as usize) % bytes.len();
        if h & 1 == 0 {
            bytes.truncate(off);
        } else {
            bytes[off] ^= 1 << ((h >> 33) & 7);
        }
    }
}

// ---------------------------------------------------------------------------
// FaultError taxonomy
// ---------------------------------------------------------------------------

/// Typed failure taxonomy for supervised recovery.  Every unrecoverable
/// fault surfaces as one of these (converting into the vendored `anyhow`
/// through the `std::error::Error` blanket, like `ServeError`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FaultError {
    /// All retry attempts and the CG-f64 fallback failed.
    SolveFailed { solver: &'static str, step: u64, attempts: u32 },
    /// Probe targets were corrupt and recomputation did not heal them.
    ProbeCorrupt { step: u64 },
    /// A cached posterior artifact failed validation after rebuild.
    ArtifactPoisoned { tenant: u64 },
    /// A serve-side artifact refresh failed with no stale fallback.
    RefreshFailed { detail: String },
    /// A checkpoint section claims more bytes than the file holds.
    CheckpointTruncated { section: &'static str, need: usize, have: usize },
    /// Checkpoint v3 content checksum mismatch.
    CheckpointChecksum { stored: u64, computed: u64 },
    /// Structurally invalid checkpoint payload.
    CheckpointMalformed { detail: String },
}

impl fmt::Display for FaultError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultError::SolveFailed { solver, step, attempts } => write!(
                f,
                "solve failed at outer step {step}: {attempts} supervised attempt(s) with \
                 the '{solver}' solver and the cg-f64 fallback all diverged"
            ),
            FaultError::ProbeCorrupt { step } => {
                write!(f, "probe targets non-finite at outer step {step} after recomputation")
            }
            FaultError::ArtifactPoisoned { tenant } => write!(
                f,
                "posterior artifact for tenant {tenant} non-finite after quarantine and rebuild"
            ),
            FaultError::RefreshFailed { detail } => {
                write!(f, "artifact refresh failed with no stale fallback: {detail}")
            }
            FaultError::CheckpointTruncated { section, need, have } => write!(
                f,
                "checkpoint truncated in section '{section}': needs {need} more byte(s), \
                 file has {have}"
            ),
            FaultError::CheckpointChecksum { stored, computed } => write!(
                f,
                "checkpoint checksum mismatch: stored {stored:#018x}, computed {computed:#018x}"
            ),
            FaultError::CheckpointMalformed { detail } => {
                write!(f, "checkpoint malformed: {detail}")
            }
        }
    }
}

impl std::error::Error for FaultError {}

// ---------------------------------------------------------------------------
// Recovery accounting
// ---------------------------------------------------------------------------

/// Recovery-event counters metered by the [`Supervisor`].  All monotone;
/// `TrainOutcome` carries the per-run delta next to its epoch totals.
#[derive(Copy, Clone, Debug, Default, PartialEq)]
pub struct RecoveryStats {
    /// Failed solve attempts that were retried.
    pub retries: u64,
    /// Epochs spent on attempts whose results were discarded (charged to
    /// the budget on top of the converged solve's own epochs).
    pub wasted_epochs: f64,
    /// Solves answered by the cross-solver CG-f64 fallback.
    pub fallback_solves: u64,
    /// Outer steps rolled back to the last finite hyperparameter state.
    pub rollbacks: u64,
    /// Probe-target batches repaired by recomputation.
    pub target_repairs: u64,
    /// Poisoned cache entries quarantined and rebuilt (preconditioner or
    /// posterior-artifact).
    pub cache_rebuilds: u64,
}

impl RecoveryStats {
    /// Total discrete recovery events (ignores the epoch meter).
    pub fn total_events(&self) -> u64 {
        self.retries
            + self.fallback_solves
            + self.rollbacks
            + self.target_repairs
            + self.cache_rebuilds
    }

    /// Per-run delta: `self - base` (counters are monotone).
    pub fn delta_since(&self, base: &RecoveryStats) -> RecoveryStats {
        RecoveryStats {
            retries: self.retries - base.retries,
            wasted_epochs: self.wasted_epochs - base.wasted_epochs,
            fallback_solves: self.fallback_solves - base.fallback_solves,
            rollbacks: self.rollbacks - base.rollbacks,
            target_repairs: self.target_repairs - base.target_repairs,
            cache_rebuilds: self.cache_rebuilds - base.cache_rebuilds,
        }
    }

    /// The CLI/telemetry one-liner (CI greps for this shape).
    pub fn summary(&self) -> String {
        format!(
            "retries={} wasted_epochs={:.2} fallbacks={} rollbacks={} repairs={} \
             cache_rebuilds={}",
            self.retries,
            self.wasted_epochs,
            self.fallback_solves,
            self.rollbacks,
            self.target_repairs,
            self.cache_rebuilds,
        )
    }
}

/// Recovery driver state shared by `Trainer` and `PredictionService`: the
/// armed plan (None = unarmed = every hook is a cold `is_none` check) plus
/// the monotone recovery counters.  The recovery *policies* live with
/// their owners — the coordinator drives retry/fallback/rollback, the
/// serve layer drives degradation — because they need the owners' state;
/// this struct is the bookkeeping they share.
#[derive(Debug, Default)]
pub struct Supervisor {
    plan: Option<Arc<FaultPlan>>,
    pub stats: RecoveryStats,
}

impl Supervisor {
    /// Arm with a parsed plan.  Re-arming replaces the schedule but keeps
    /// the monotone counters.
    pub fn arm(&mut self, plan: Arc<FaultPlan>) {
        self.plan = Some(plan);
    }

    pub fn armed(&self) -> bool {
        self.plan.is_some()
    }

    pub fn plan(&self) -> Option<&Arc<FaultPlan>> {
        self.plan.as_ref()
    }

    /// Position the schedule (no-op unarmed).
    pub fn set_step(&self, step: u64) {
        if let Some(p) = &self.plan {
            p.set_step(step);
        }
    }

    /// Consume one opportunity for `site` (always false unarmed).
    pub fn fires(&self, site: FaultSite) -> bool {
        match &self.plan {
            Some(p) => p.fires(site),
            None => false,
        }
    }
}

// ---------------------------------------------------------------------------
// Finite-scan helpers
// ---------------------------------------------------------------------------

/// True when every entry is finite (no NaN/Inf).
pub fn mat_finite(m: &Mat) -> bool {
    m.data.iter().all(|x| x.is_finite())
}

/// True when every entry is finite (no NaN/Inf).
pub fn slice_finite(v: &[f64]) -> bool {
    v.iter().all(|x| x.is_finite())
}

// ---------------------------------------------------------------------------
// ChaosOpView
// ---------------------------------------------------------------------------

/// A borrowing [`KernelOperator`] view that injects the pre-drawn faults
/// of ONE solve attempt and then burns out.
///
/// The supervisor consults the plan once per attempt per site, builds a
/// view with the fired sites armed, and hands it to the solver; each
/// armed corruption applies to the *first* matching product (atomically
/// swapped off), so the view is bitwise-transparent afterwards — and a
/// fresh view with nothing armed is transparent from the start, which is
/// what makes retry convergence bitwise-identical to the fault-free run.
///
/// The `&mut` trait methods (`set_hp`, `set_precision`, `extend`) are
/// never reachable through the shared reference a solver holds; they are
/// implemented as inert stubs to satisfy the trait.
pub struct ChaosOpView<'a> {
    inner: &'a dyn KernelOperator,
    /// Seed-derived corruption row (reduced modulo each product's rows).
    row: usize,
    /// Whether any corruption was armed at construction (consumption
    /// tracking baseline).
    armed: bool,
    /// 0 = off, 1 = NaN panel row, 2 = Inf shard row-range.
    panel: AtomicU8,
    /// Poison the next `k_cols` panel (the preconditioner build path).
    precond: AtomicBool,
}

/// Rows corrupted by the shard-partial fault (a contiguous Inf range,
/// modelling one shard's partial buffer going bad).
const SHARD_FAULT_ROWS: usize = 8;

impl<'a> ChaosOpView<'a> {
    pub fn new(
        inner: &'a dyn KernelOperator,
        plan: &FaultPlan,
        panel_nan: bool,
        shard_inf: bool,
        precond_nan: bool,
    ) -> ChaosOpView<'a> {
        let mode = if shard_inf {
            2
        } else if panel_nan {
            1
        } else {
            0
        };
        ChaosOpView {
            inner,
            row: plan.target_row(inner.n()),
            armed: mode != 0 || precond_nan,
            panel: AtomicU8::new(mode),
            precond: AtomicBool::new(precond_nan),
        }
    }

    /// Whether an armed corruption was actually burnt into a product.
    /// The supervisor rejects any attempt whose view consumed its
    /// corruption — even if the solve came back finite — because a
    /// corrupted intermediate can steer a solver (block selection, line
    /// searches) to a finite-but-divergent answer that a residual check
    /// alone would accept.
    pub fn consumed(&self) -> bool {
        self.armed
            && self.panel.load(Ordering::Relaxed) == 0
            && !self.precond.load(Ordering::Relaxed)
    }

    /// Apply (and burn) the panel/shard corruption to a product output.
    fn corrupt_product(&self, out: &mut Mat) {
        if out.rows == 0 {
            return;
        }
        match self.panel.swap(0, Ordering::Relaxed) {
            1 => {
                let r = self.row % out.rows;
                for v in out.row_mut(r) {
                    *v = f64::NAN;
                }
            }
            2 => {
                let r0 = self.row % out.rows;
                let r1 = (r0 + SHARD_FAULT_ROWS).min(out.rows);
                for r in r0..r1 {
                    for v in out.row_mut(r) {
                        *v = f64::INFINITY;
                    }
                }
            }
            _ => {}
        }
    }

    /// Apply (and burn) the preconditioner-column corruption, falling
    /// through to the panel corruption (AP's update path is `k_cols`).
    fn corrupt_cols(&self, out: &mut Mat) {
        if out.rows == 0 {
            return;
        }
        if self.precond.swap(false, Ordering::Relaxed) {
            let r = self.row % out.rows;
            for v in out.row_mut(r) {
                *v = f64::NAN;
            }
        } else {
            self.corrupt_product(out);
        }
    }
}

impl KernelOperator for ChaosOpView<'_> {
    fn n(&self) -> usize {
        self.inner.n()
    }
    fn d(&self) -> usize {
        self.inner.d()
    }
    fn s(&self) -> usize {
        self.inner.s()
    }
    fn m(&self) -> usize {
        self.inner.m()
    }
    fn family(&self) -> KernelFamily {
        self.inner.family()
    }
    fn x(&self) -> &Mat {
        self.inner.x()
    }
    fn x_test(&self) -> &Mat {
        self.inner.x_test()
    }
    fn hp(&self) -> &Hyperparams {
        self.inner.hp()
    }
    fn set_hp(&mut self, _hp: &Hyperparams) {
        // unreachable through the shared reference a solve holds
    }
    fn precision(&self) -> Precision {
        self.inner.precision()
    }
    fn set_precision(&mut self, _prec: Precision) -> anyhow::Result<()> {
        anyhow::bail!("chaos view: set_precision on the underlying operator instead")
    }

    fn hv(&self, v: &Mat) -> Mat {
        let mut out = self.inner.hv(v);
        self.corrupt_product(&mut out);
        out
    }

    fn hv_into(&self, v: &Mat, out: &mut Mat, scratch: &HvScratch) {
        self.inner.hv_into(v, out, scratch);
        self.corrupt_product(out);
    }

    fn hv_into_prec(&self, v: &Mat, out: &mut Mat, scratch: &HvScratch, prec: Precision) {
        self.inner.hv_into_prec(v, out, scratch, prec);
        self.corrupt_product(out);
    }

    fn k_cols(&self, idx: &[usize], u: &Mat) -> Mat {
        let mut out = self.inner.k_cols(idx, u);
        self.corrupt_cols(&mut out);
        out
    }

    fn k_cols_prec(&self, idx: &[usize], u: &Mat, prec: Precision) -> Mat {
        let mut out = self.inner.k_cols_prec(idx, u, prec);
        self.corrupt_cols(&mut out);
        out
    }

    fn k_rows(&self, idx: &[usize], v: &Mat) -> Mat {
        let mut out = self.inner.k_rows(idx, v);
        self.corrupt_product(&mut out);
        out
    }

    fn k_rows_prec(&self, idx: &[usize], v: &Mat, prec: Precision) -> Mat {
        let mut out = self.inner.k_rows_prec(idx, v, prec);
        self.corrupt_product(&mut out);
        out
    }

    fn grad_quad(&self, a: &Mat, b: &Mat, w: &[f64]) -> Vec<f64> {
        self.inner.grad_quad(a, b, w)
    }

    fn extend(&mut self, _x_new: &Mat) -> anyhow::Result<()> {
        anyhow::bail!("chaos view: extend the underlying operator instead")
    }

    fn rff_eval(&self, omega0: &Mat, wts: &Mat, noise: &Mat) -> Mat {
        self.inner.rff_eval(omega0, wts, noise)
    }

    fn predict_at(
        &self,
        x_query: &Mat,
        vy: &[f64],
        zhat: &Mat,
        omega0: &Mat,
        wts: &Mat,
    ) -> anyhow::Result<(Vec<f64>, Mat)> {
        self.inner.predict_at(x_query, vy, zhat, omega0, wts)
    }

    fn predict_at_prec(
        &self,
        x_query: &Mat,
        vy: &[f64],
        zhat: &Mat,
        omega0: &Mat,
        wts: &Mat,
        prec: Precision,
    ) -> anyhow::Result<(Vec<f64>, Mat)> {
        self.inner.predict_at_prec(x_query, vy, zhat, omega0, wts, prec)
    }

    fn predict_batched(
        &self,
        x_query: &Mat,
        batch: usize,
        threads: usize,
        vy: &[f64],
        zhat: &Mat,
        omega0: &Mat,
        wts: &Mat,
    ) -> anyhow::Result<(Vec<f64>, Mat, u64)> {
        self.inner.predict_batched(x_query, batch, threads, vy, zhat, omega0, wts)
    }

    fn exact_mll(&self, y: &[f64]) -> Option<(f64, Vec<f64>)> {
        self.inner.exact_mll(y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::operators::{make_cpu_backend, BackendKind, TiledOptions};

    #[test]
    fn fnv1a_known_vectors() {
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        // one flipped bit changes the hash
        assert_ne!(fnv1a(&[0u8, 1, 2, 3]), fnv1a(&[0u8, 1, 2, 7]));
    }

    #[test]
    fn spec_parses_scheduled_prob_and_seed() {
        let p = FaultPlan::parse("seed=7; panel@1 ; solver@2x3; refresh~0.25").unwrap();
        assert_eq!(p.seed(), 7);
        assert!(p.has_entries());
        assert_eq!(p.entries.len(), 3);
        assert_eq!(
            p.entries[0],
            FaultEntry { site: FaultSite::Panel, trigger: Trigger::At { step: 1, count: 1 } }
        );
        assert_eq!(
            p.entries[1],
            FaultEntry { site: FaultSite::Solver, trigger: Trigger::At { step: 2, count: 3 } }
        );
        assert_eq!(
            p.entries[2],
            FaultEntry { site: FaultSite::Refresh, trigger: Trigger::Prob(0.25) }
        );
    }

    #[test]
    fn seed_only_spec_is_armed_but_benign() {
        let p = FaultPlan::parse("seed=3").unwrap();
        assert!(!p.has_entries());
        for site in FaultSite::ALL {
            assert!(!p.fires(site));
        }
    }

    #[test]
    fn bad_specs_are_typed_errors() {
        for bad in [
            "panel",             // no trigger
            "panel@",            // missing step
            "panel@one",         // non-numeric step
            "panel@1x0",         // zero count
            "warp@1",            // unknown site
            "panel~1.5",         // probability out of range
            "panel~NaN",         // non-finite probability
            "seed=minus",        // bad seed
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "spec '{bad}' should fail");
        }
    }

    #[test]
    fn scheduled_trigger_burns_count_opportunities_at_its_step() {
        let p = FaultPlan::parse("solver@2x2").unwrap();
        p.set_step(1);
        assert!(!p.fires(FaultSite::Solver));
        p.set_step(2);
        assert!(p.fires(FaultSite::Solver));
        assert!(p.fires(FaultSite::Solver));
        assert!(!p.fires(FaultSite::Solver)); // count exhausted
        p.set_step(3);
        assert!(!p.fires(FaultSite::Solver));
        // other sites never fire
        p.set_step(2);
        assert!(!p.fires(FaultSite::Panel));
    }

    #[test]
    fn prob_trigger_is_deterministic_per_draw_index() {
        let a = FaultPlan::parse("seed=11;panel~0.5").unwrap();
        let b = FaultPlan::parse("seed=11;panel~0.5").unwrap();
        let mut draws_a = Vec::new();
        let mut draws_b = Vec::new();
        for step in 0..4 {
            a.set_step(step);
            b.set_step(step);
            for _ in 0..8 {
                draws_a.push(a.fires(FaultSite::Panel));
                draws_b.push(b.fires(FaultSite::Panel));
            }
        }
        assert_eq!(draws_a, draws_b);
        assert!(draws_a.iter().any(|&f| f), "p=0.5 over 32 draws should fire");
        assert!(draws_a.iter().any(|&f| !f), "p=0.5 over 32 draws should also miss");
        // p=0 never fires, p=1 always fires
        let never = FaultPlan::parse("panel~0").unwrap();
        let always = FaultPlan::parse("panel~1").unwrap();
        for _ in 0..8 {
            assert!(!never.fires(FaultSite::Panel));
            assert!(always.fires(FaultSite::Panel));
        }
    }

    #[test]
    fn corrupt_bytes_is_deterministic_and_damaging() {
        let p = FaultPlan::parse("seed=5;checkpoint@0").unwrap();
        let orig: Vec<u8> = (0..64u8).collect();
        let mut a = orig.clone();
        let mut b = orig.clone();
        p.corrupt_bytes(&mut a);
        FaultPlan::parse("seed=5;checkpoint@0").unwrap().corrupt_bytes(&mut b);
        assert_eq!(a, b, "corruption is a pure function of the seed");
        assert_ne!(a, orig, "corruption must damage the payload");
        let mut empty: Vec<u8> = Vec::new();
        p.corrupt_bytes(&mut empty); // no panic on empty payloads
        assert!(empty.is_empty());
    }

    #[test]
    fn supervisor_unarmed_is_inert() {
        let sup = Supervisor::default();
        assert!(!sup.armed());
        sup.set_step(3);
        for site in FaultSite::ALL {
            assert!(!sup.fires(site));
        }
        assert_eq!(sup.stats, RecoveryStats::default());
    }

    #[test]
    fn recovery_stats_delta_and_summary() {
        let base = RecoveryStats { retries: 1, wasted_epochs: 2.0, ..Default::default() };
        let now = RecoveryStats {
            retries: 3,
            wasted_epochs: 5.5,
            fallback_solves: 1,
            rollbacks: 0,
            target_repairs: 2,
            cache_rebuilds: 4,
        };
        let d = now.delta_since(&base);
        assert_eq!(d.retries, 2);
        assert!((d.wasted_epochs - 3.5).abs() < 1e-12);
        assert_eq!(d.total_events(), 2 + 1 + 0 + 2 + 4);
        let s = d.summary();
        assert!(s.contains("retries=2"), "{s}");
        assert!(s.contains("cache_rebuilds=4"), "{s}");
    }

    fn tiny_op() -> Box<dyn KernelOperator> {
        let ds = data::generate(&data::spec("test").unwrap());
        make_cpu_backend(BackendKind::Dense, &ds, 4, 8, TiledOptions::default(), 1).unwrap()
    }

    #[test]
    fn chaos_view_corrupts_first_product_then_turns_transparent() {
        let op = tiny_op();
        let plan = FaultPlan::parse("seed=9;panel@0").unwrap();
        let v = Mat::from_fn(op.n(), op.k_width(), |i, j| ((i + 2 * j) % 5) as f64 - 2.0);
        let clean = op.hv(&v);
        let view = ChaosOpView::new(op.as_ref(), &plan, true, false, false);
        let hit = view.hv(&v);
        assert!(!mat_finite(&hit), "first product must carry the NaN row");
        // exactly one row is poisoned, every other entry is bitwise clean
        let r = plan.target_row(op.n());
        for i in 0..clean.rows {
            for j in 0..clean.cols {
                if i == r {
                    assert!(hit.row(i)[j].is_nan());
                } else {
                    assert_eq!(hit.row(i)[j].to_bits(), clean.row(i)[j].to_bits());
                }
            }
        }
        // burned out: the second product is bitwise clean
        let again = view.hv(&v);
        for (x, y) in again.data.iter().zip(&clean.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn chaos_view_shard_fault_is_an_inf_row_range() {
        let op = tiny_op();
        let plan = FaultPlan::parse("seed=4;shard@0").unwrap();
        let v = Mat::from_fn(op.n(), op.k_width(), |i, j| ((i * 3 + j) % 7) as f64);
        let view = ChaosOpView::new(op.as_ref(), &plan, false, true, false);
        let mut out = Mat::zeros(op.n(), op.k_width());
        view.hv_into(&v, &mut out, &HvScratch::default());
        let r0 = plan.target_row(op.n()) % out.rows;
        let r1 = (r0 + SHARD_FAULT_ROWS).min(out.rows);
        for r in r0..r1 {
            for v in out.row(r) {
                assert!(v.is_infinite());
            }
        }
    }

    #[test]
    fn chaos_view_precond_fault_targets_k_cols_only() {
        let op = tiny_op();
        let plan = FaultPlan::parse("seed=2;precond@0").unwrap();
        let view = ChaosOpView::new(op.as_ref(), &plan, false, false, true);
        let v = Mat::from_fn(op.n(), op.k_width(), |i, j| (i + j) as f64 * 0.25);
        // hv is NOT corrupted by the precond fault
        let hv = view.hv(&v);
        assert!(mat_finite(&hv));
        // the first k_cols panel is
        let idx: Vec<usize> = (0..6).collect();
        let u = Mat::from_fn(idx.len(), op.k_width(), |i, j| (i * j + 1) as f64 * 0.5);
        let cols = view.k_cols(&idx, &u);
        assert!(!mat_finite(&cols));
        // and it burns out too
        let cols2 = view.k_cols(&idx, &u);
        assert!(mat_finite(&cols2));
    }

    #[test]
    fn unarmed_view_is_bitwise_transparent() {
        let op = tiny_op();
        let plan = FaultPlan::parse("seed=1").unwrap();
        let view = ChaosOpView::new(op.as_ref(), &plan, false, false, false);
        let v = Mat::from_fn(op.n(), op.k_width(), |i, j| ((i ^ j) % 9) as f64 - 4.0);
        let a = op.hv(&v);
        let b = view.hv(&v);
        assert_eq!(a.data.len(), b.data.len());
        for (x, y) in a.data.iter().zip(&b.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
