//! Cholesky factorisation and triangular solves for SPD matrices.

use super::Mat;
use anyhow::{bail, Result};

/// Lower-triangular Cholesky factor L with A = L L^T.
#[derive(Clone, Debug)]
pub struct Cholesky {
    pub l: Mat,
}

impl Cholesky {
    /// Factor an SPD matrix. Fails on non-positive pivots.
    pub fn factor(a: &Mat) -> Result<Self> {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = a[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if s <= 0.0 {
                        bail!("cholesky: non-positive pivot {s} at {i}");
                    }
                    l[(i, j)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Ok(Cholesky { l })
    }

    pub fn n(&self) -> usize {
        self.l.rows
    }

    /// Solve L y = b (forward substitution).
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(b.len(), n);
        let mut y = b.to_vec();
        for i in 0..n {
            let row = self.l.row(i);
            let mut s = y[i];
            for k in 0..i {
                s -= row[k] * y[k];
            }
            y[i] = s / row[i];
        }
        y
    }

    /// Solve L^T x = y (backward substitution).
    pub fn solve_upper(&self, y: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(y.len(), n);
        let mut x = y.to_vec();
        for i in (0..n).rev() {
            let mut s = x[i];
            for k in i + 1..n {
                s -= self.l[(k, i)] * x[k];
            }
            x[i] = s / self.l[(i, i)];
        }
        x
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        self.solve_upper(&self.solve_lower(b))
    }

    /// Solve A X = B for a matrix RHS in one blocked sweep: the forward
    /// and backward substitutions carry all `k` columns through each row
    /// of L, so L is read once instead of once per column (the column-wise
    /// loop re-streamed the whole factor k times).  The per-column
    /// operation order is exactly the one `solve` uses, so the result is
    /// **bitwise-identical** to solving each column separately.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        assert_eq!(b.rows, self.n());
        let n = self.n();
        let k = b.cols;
        let mut y = b.clone();
        // forward: L Y = B
        for i in 0..n {
            let (head, tail) = y.data.split_at_mut(i * k);
            let yi = &mut tail[..k];
            let li = self.l.row(i);
            for (kk, &c) in li.iter().enumerate().take(i) {
                let yk = &head[kk * k..(kk + 1) * k];
                for j in 0..k {
                    yi[j] -= c * yk[j];
                }
            }
            let d = li[i];
            for v in yi.iter_mut() {
                *v /= d;
            }
        }
        // backward: L^T X = Y
        for i in (0..n).rev() {
            let (head, tail) = y.data.split_at_mut((i + 1) * k);
            let yi = &mut head[i * k..];
            for kk in i + 1..n {
                let c = self.l[(kk, i)];
                let yk = &tail[(kk - i - 1) * k..(kk - i) * k];
                for j in 0..k {
                    yi[j] -= c * yk[j];
                }
            }
            let d = self.l[(i, i)];
            for v in yi.iter_mut() {
                *v /= d;
            }
        }
        y
    }

    /// log det A = 2 sum log L_ii.
    pub fn logdet(&self) -> f64 {
        // lint:allow(ordered-reduction): serial ascending fold over a strided diagonal is already canonical
        (0..self.n()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    /// Explicit inverse (small n only; used for tr(H^-1) diagnostics).
    pub fn inverse(&self) -> Mat {
        let n = self.n();
        let mut inv = Mat::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            inv.set_col(j, &self.solve(&e));
            e[j] = 0.0;
        }
        inv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let g = Mat::from_fn(n, n, |_, _| rng.gaussian());
        let mut a = g.matmul(&g.transpose());
        a.add_diag(n as f64 * 0.5);
        a
    }

    #[test]
    fn factor_reconstructs() {
        let a = random_spd(16, 1);
        let ch = Cholesky::factor(&a).unwrap();
        let rec = ch.l.matmul(&ch.l.transpose());
        assert!(rec.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn solve_inverts() {
        let a = random_spd(24, 2);
        let ch = Cholesky::factor(&a).unwrap();
        let mut rng = Rng::new(3);
        let b = rng.gaussian_vec(24);
        let x = ch.solve(&b);
        let ax = a.matvec(&x);
        let err: f64 = ax.iter().zip(&b).map(|(p, q)| (p - q).abs()).fold(0.0, f64::max);
        assert!(err < 1e-9, "err={err}");
    }

    #[test]
    fn logdet_matches_2x2() {
        let a = Mat::from_vec(2, 2, vec![4.0, 1.0, 1.0, 3.0]);
        let ch = Cholesky::factor(&a).unwrap();
        assert!((ch.logdet() - (11.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn inverse_times_a_is_identity() {
        let a = random_spd(12, 4);
        let inv = Cholesky::factor(&a).unwrap().inverse();
        let prod = inv.matmul(&a);
        assert!(prod.max_abs_diff(&Mat::eye(12)) < 1e-9);
    }

    #[test]
    fn non_spd_fails() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // indefinite
        assert!(Cholesky::factor(&a).is_err());
    }

    #[test]
    fn solve_mat_is_bitwise_equal_to_per_column_solves() {
        // the batched sweep must replay exactly the per-column operation
        // order (ExactGp::predict relies on this for bitwise-stable
        // predictions after the batching optimisation)
        for (n, k, seed) in [(8usize, 3usize, 5u64), (24, 7, 6), (1, 1, 7), (16, 1, 8)] {
            let a = random_spd(n, seed);
            let ch = Cholesky::factor(&a).unwrap();
            let mut rng = Rng::new(seed + 100);
            let b = Mat::from_fn(n, k, |_, _| rng.gaussian());
            let x = ch.solve_mat(&b);
            for j in 0..k {
                let xj = ch.solve(&b.col(j));
                for i in 0..n {
                    assert_eq!(
                        x[(i, j)].to_bits(),
                        xj[i].to_bits(),
                        "n={n} k={k} entry ({i},{j})"
                    );
                }
            }
        }
    }
}
