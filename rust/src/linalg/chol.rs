//! Cholesky factorisation and triangular solves for SPD matrices.

use super::Mat;
use anyhow::{bail, Result};

/// Lower-triangular Cholesky factor L with A = L L^T.
#[derive(Clone, Debug)]
pub struct Cholesky {
    pub l: Mat,
}

impl Cholesky {
    /// Factor an SPD matrix. Fails on non-positive pivots.
    pub fn factor(a: &Mat) -> Result<Self> {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = a[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if s <= 0.0 {
                        bail!("cholesky: non-positive pivot {s} at {i}");
                    }
                    l[(i, j)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Ok(Cholesky { l })
    }

    pub fn n(&self) -> usize {
        self.l.rows
    }

    /// Solve L y = b (forward substitution).
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(b.len(), n);
        let mut y = b.to_vec();
        for i in 0..n {
            let row = self.l.row(i);
            let mut s = y[i];
            for k in 0..i {
                s -= row[k] * y[k];
            }
            y[i] = s / row[i];
        }
        y
    }

    /// Solve L^T x = y (backward substitution).
    pub fn solve_upper(&self, y: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(y.len(), n);
        let mut x = y.to_vec();
        for i in (0..n).rev() {
            let mut s = x[i];
            for k in i + 1..n {
                s -= self.l[(k, i)] * x[k];
            }
            x[i] = s / self.l[(i, i)];
        }
        x
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        self.solve_upper(&self.solve_lower(b))
    }

    /// Solve A X = B column-wise for a matrix RHS.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        assert_eq!(b.rows, self.n());
        let mut out = Mat::zeros(b.rows, b.cols);
        for j in 0..b.cols {
            out.set_col(j, &self.solve(&b.col(j)));
        }
        out
    }

    /// log det A = 2 sum log L_ii.
    pub fn logdet(&self) -> f64 {
        (0..self.n()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    /// Explicit inverse (small n only; used for tr(H^-1) diagnostics).
    pub fn inverse(&self) -> Mat {
        let n = self.n();
        let mut inv = Mat::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            inv.set_col(j, &self.solve(&e));
            e[j] = 0.0;
        }
        inv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let g = Mat::from_fn(n, n, |_, _| rng.gaussian());
        let mut a = g.matmul(&g.transpose());
        a.add_diag(n as f64 * 0.5);
        a
    }

    #[test]
    fn factor_reconstructs() {
        let a = random_spd(16, 1);
        let ch = Cholesky::factor(&a).unwrap();
        let rec = ch.l.matmul(&ch.l.transpose());
        assert!(rec.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn solve_inverts() {
        let a = random_spd(24, 2);
        let ch = Cholesky::factor(&a).unwrap();
        let mut rng = Rng::new(3);
        let b = rng.gaussian_vec(24);
        let x = ch.solve(&b);
        let ax = a.matvec(&x);
        let err: f64 = ax.iter().zip(&b).map(|(p, q)| (p - q).abs()).fold(0.0, f64::max);
        assert!(err < 1e-9, "err={err}");
    }

    #[test]
    fn logdet_matches_2x2() {
        let a = Mat::from_vec(2, 2, vec![4.0, 1.0, 1.0, 3.0]);
        let ch = Cholesky::factor(&a).unwrap();
        assert!((ch.logdet() - (11.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn inverse_times_a_is_identity() {
        let a = random_spd(12, 4);
        let inv = Cholesky::factor(&a).unwrap().inverse();
        let prod = inv.matmul(&a);
        assert!(prod.max_abs_diff(&Mat::eye(12)) < 1e-9);
    }

    #[test]
    fn non_spd_fails() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // indefinite
        assert!(Cholesky::factor(&a).is_err());
    }

    #[test]
    fn solve_mat_matches_columns() {
        let a = random_spd(8, 5);
        let ch = Cholesky::factor(&a).unwrap();
        let mut rng = Rng::new(6);
        let b = Mat::from_fn(8, 3, |_, _| rng.gaussian());
        let x = ch.solve_mat(&b);
        for j in 0..3 {
            let xj = ch.solve(&b.col(j));
            for i in 0..8 {
                assert!((x[(i, j)] - xj[i]).abs() < 1e-12);
            }
        }
    }
}
