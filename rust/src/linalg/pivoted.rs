//! Rank-k pivoted (partial) Cholesky of a kernel matrix, used to build the
//! CG preconditioner (paper: rank-100 pivoted Cholesky, following Wang et
//! al. 2019).  Works matrix-free: only the diagonal and selected rows of K
//! are evaluated, so the cost is O(rank^2 n + rank * n * d).

use super::Mat;

/// Partial Cholesky factor: K ~= L L^T with L [n, rank].
#[derive(Clone, Debug)]
pub struct PivotedCholesky {
    pub l: Mat,
    pub pivots: Vec<usize>,
}

/// `diag[i]` = K_ii; `row(i)` returns the dense row K_i.
pub fn pivoted_cholesky(
    n: usize,
    rank: usize,
    diag: &[f64],
    mut row: impl FnMut(usize) -> Vec<f64>,
) -> PivotedCholesky {
    assert_eq!(diag.len(), n);
    let rank = rank.min(n);
    let mut d = diag.to_vec();
    let mut l = Mat::zeros(n, rank);
    let mut pivots = Vec::with_capacity(rank);
    for k in 0..rank {
        // greedy pivot: largest remaining diagonal
        let (p, &dp) = d
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        if dp <= 1e-12 {
            // numerically exhausted: shrink rank
            let mut small = Mat::zeros(n, k);
            for i in 0..n {
                small.row_mut(i).copy_from_slice(&l.row(i)[..k]);
            }
            return PivotedCholesky { l: small, pivots };
        }
        pivots.push(p);
        let sqrt_dp = dp.sqrt();
        let kp = row(p); // K[:, p] by symmetry
        for i in 0..n {
            let mut v = kp[i];
            for j in 0..k {
                v -= l[(i, j)] * l[(p, j)];
            }
            l[(i, k)] = v / sqrt_dp;
        }
        // exact zero for the pivot column residual
        for i in 0..n {
            let lik = l[(i, k)];
            d[i] = (d[i] - lik * lik).max(0.0);
        }
        d[p] = 0.0;
    }
    PivotedCholesky { l, pivots }
}

impl PivotedCholesky {
    pub fn rank(&self) -> usize {
        self.l.cols
    }

    /// Low-rank reconstruction L L^T (tests / diagnostics only).
    pub fn reconstruct(&self) -> Mat {
        self.l.matmul(&self.l.transpose())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let g = Mat::from_fn(n, 4, |_, _| rng.gaussian()); // rank-4 + jitter
        let mut a = g.matmul(&g.transpose());
        a.add_diag(1e-8);
        a
    }

    #[test]
    fn full_rank_reconstructs_low_rank_matrix() {
        let a = spd(24, 1);
        let diag: Vec<f64> = (0..24).map(|i| a[(i, i)]).collect();
        let pc = pivoted_cholesky(24, 8, &diag, |i| a.row(i).to_vec());
        let rec = pc.reconstruct();
        assert!(rec.max_abs_diff(&a) < 1e-6, "{}", rec.max_abs_diff(&a));
    }

    #[test]
    fn approximation_improves_with_rank() {
        let mut rng = Rng::new(2);
        let n = 32;
        let g = Mat::from_fn(n, n, |_, _| rng.gaussian());
        let mut a = g.matmul(&g.transpose());
        a.add_diag(0.1);
        let diag: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
        let mut prev = f64::INFINITY;
        for rank in [2, 8, 16, 32] {
            let pc = pivoted_cholesky(n, rank, &diag, |i| a.row(i).to_vec());
            let mut err = pc.reconstruct();
            err.sub_assign(&a);
            let e = err.fro_norm();
            assert!(e <= prev + 1e-9, "rank {rank}: {e} > {prev}");
            prev = e;
        }
        assert!(prev < 1e-8); // full rank is exact
    }

    #[test]
    fn pivots_are_distinct() {
        let a = spd(16, 3);
        let diag: Vec<f64> = (0..16).map(|i| a[(i, i)]).collect();
        let pc = pivoted_cholesky(16, 4, &diag, |i| a.row(i).to_vec());
        let mut p = pc.pivots.clone();
        p.sort_unstable();
        p.dedup();
        assert_eq!(p.len(), pc.pivots.len());
    }

    #[test]
    fn rank_capped_at_numerical_rank() {
        let a = spd(20, 4); // numerical rank ~4
        let diag: Vec<f64> = (0..20).map(|i| a[(i, i)]).collect();
        let pc = pivoted_cholesky(20, 16, &diag, |i| a.row(i).to_vec());
        assert!(pc.rank() <= 16);
        assert!(pc.rank() >= 4);
    }
}
