//! Rank-k pivoted (partial) Cholesky of a kernel matrix, used to build the
//! CG preconditioner (paper: rank-100 pivoted Cholesky, following Wang et
//! al. 2019).  Works matrix-free: only the diagonal and selected rows of K
//! are evaluated, so the cost is O(rank^2 n + rank * n * d).

use super::{LinalgError, Mat};
use crate::util::parallel::{num_threads, parallel_row_blocks};

/// Partial Cholesky factor: K ~= L L^T with L [n, rank].
#[derive(Clone, Debug)]
pub struct PivotedCholesky {
    pub l: Mat,
    pub pivots: Vec<usize>,
}

/// Below this many row-update elements the column update runs inline
/// (spawning workers costs more than the update itself).
const PAR_MIN_ELEMS: usize = 1 << 16;

/// `diag[i]` = K_ii; `row(i)` returns the dense row K_i.
///
/// A non-finite diagonal entry (NaN/inf kernel variance, e.g. from a
/// poisoned hyperparameter) is a typed [`LinalgError::NonFiniteDiagonal`]
/// instead of a panic, so preconditioner builds degrade into a reported
/// failure rather than killing the training run.
pub fn pivoted_cholesky(
    n: usize,
    rank: usize,
    diag: &[f64],
    row: impl FnMut(usize) -> Vec<f64>,
) -> Result<PivotedCholesky, LinalgError> {
    pivoted_cholesky_threaded(n, rank, diag, row, 0)
}

/// [`pivoted_cholesky`] with the O(n) column/diagonal updates of every
/// elimination step spread over `threads` workers (0 = auto).  Each row of
/// L is updated by the same scalar expressions as the serial loop on
/// disjoint `&mut` blocks, so the factor is bitwise-identical for every
/// thread count.  `row(i)` itself is still evaluated on the calling thread
/// (callers that can parallelise the kernel row do so inside the closure).
pub fn pivoted_cholesky_threaded(
    n: usize,
    rank: usize,
    diag: &[f64],
    mut row: impl FnMut(usize) -> Vec<f64>,
    threads: usize,
) -> Result<PivotedCholesky, LinalgError> {
    assert_eq!(diag.len(), n);
    let rank = rank.min(n);
    let t = num_threads(if threads == 0 { None } else { Some(threads) });
    let mut d = diag.to_vec();
    let mut l = Mat::zeros(n, rank);
    let mut pivots = Vec::with_capacity(rank);
    for k in 0..rank {
        // Greedy pivot: largest remaining diagonal under the *total* float
        // order (partial_cmp().unwrap() panicked on NaN).  Last max wins,
        // matching max_by's tie rule, so pivot sequences — and therefore
        // factors — are bit-for-bit what the old comparator produced on
        // finite input.
        let mut p = 0;
        for i in 1..n {
            if d[i].total_cmp(&d[p]).is_ge() {
                p = i;
            }
        }
        let dp = d[p];
        // NaN orders above +inf in the total order, so a poisoned entry is
        // always *selected* — catch it here and report, rather than letting
        // NaN spread through the factor.
        if !dp.is_finite() {
            return Err(LinalgError::NonFiniteDiagonal { index: p, value: dp });
        }
        if dp <= 1e-12 {
            // numerically exhausted: shrink rank
            let mut small = Mat::zeros(n, k);
            for i in 0..n {
                small.row_mut(i).copy_from_slice(&l.row(i)[..k]);
            }
            return Ok(PivotedCholesky { l: small, pivots });
        }
        pivots.push(p);
        let sqrt_dp = dp.sqrt();
        let kp = row(p); // K[:, p] by symmetry
        let lp: Vec<f64> = l.row(p)[..k].to_vec();
        let tk = if n * (k + 1) < PAR_MIN_ELEMS { 1 } else { t };
        // column update: row i touches only L[i, ..] — disjoint writes
        let block = ((n + tk - 1) / tk).max(1);
        parallel_row_blocks(&mut l.data, rank, block, tk, |r0, rows, blk| {
            for r in 0..rows {
                let lrow = &mut blk[r * rank..(r + 1) * rank];
                let mut v = kp[r0 + r];
                for j in 0..k {
                    v -= lrow[j] * lp[j];
                }
                lrow[k] = v / sqrt_dp;
            }
        });
        // diagonal downdate, row-parallel over the (now final) column k
        let lref = &l;
        parallel_row_blocks(&mut d, 1, block, tk, |r0, rows, blk| {
            for r in 0..rows {
                let lik = lref[(r0 + r, k)];
                blk[r] = (blk[r] - lik * lik).max(0.0);
            }
        });
        // exact zero for the pivot column residual
        d[p] = 0.0;
    }
    Ok(PivotedCholesky { l, pivots })
}

impl PivotedCholesky {
    pub fn rank(&self) -> usize {
        self.l.cols
    }

    /// Low-rank reconstruction L L^T (tests / diagnostics only).
    pub fn reconstruct(&self) -> Mat {
        self.l.matmul(&self.l.transpose())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let g = Mat::from_fn(n, 4, |_, _| rng.gaussian()); // rank-4 + jitter
        let mut a = g.matmul(&g.transpose());
        a.add_diag(1e-8);
        a
    }

    #[test]
    fn full_rank_reconstructs_low_rank_matrix() {
        let a = spd(24, 1);
        let diag: Vec<f64> = (0..24).map(|i| a[(i, i)]).collect();
        let pc = pivoted_cholesky(24, 8, &diag, |i| a.row(i).to_vec()).unwrap();
        let rec = pc.reconstruct();
        assert!(rec.max_abs_diff(&a) < 1e-6, "{}", rec.max_abs_diff(&a));
    }

    #[test]
    fn approximation_improves_with_rank() {
        let mut rng = Rng::new(2);
        let n = 32;
        let g = Mat::from_fn(n, n, |_, _| rng.gaussian());
        let mut a = g.matmul(&g.transpose());
        a.add_diag(0.1);
        let diag: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
        let mut prev = f64::INFINITY;
        for rank in [2, 8, 16, 32] {
            let pc = pivoted_cholesky(n, rank, &diag, |i| a.row(i).to_vec()).unwrap();
            let mut err = pc.reconstruct();
            err.sub_assign(&a);
            let e = err.fro_norm();
            assert!(e <= prev + 1e-9, "rank {rank}: {e} > {prev}");
            prev = e;
        }
        assert!(prev < 1e-8); // full rank is exact
    }

    #[test]
    fn pivots_are_distinct() {
        let a = spd(16, 3);
        let diag: Vec<f64> = (0..16).map(|i| a[(i, i)]).collect();
        let pc = pivoted_cholesky(16, 4, &diag, |i| a.row(i).to_vec()).unwrap();
        let mut p = pc.pivots.clone();
        p.sort_unstable();
        p.dedup();
        assert_eq!(p.len(), pc.pivots.len());
    }

    #[test]
    fn threaded_factor_is_bitwise_equal_to_serial() {
        let mut rng = Rng::new(7);
        let n = 48;
        let g = Mat::from_fn(n, n, |_, _| rng.gaussian());
        let mut a = g.matmul(&g.transpose());
        a.add_diag(0.2);
        let diag: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
        let serial =
            pivoted_cholesky_threaded(n, 12, &diag, |i| a.row(i).to_vec(), 1).unwrap();
        for t in [2, 4] {
            let par =
                pivoted_cholesky_threaded(n, 12, &diag, |i| a.row(i).to_vec(), t).unwrap();
            assert_eq!(par.pivots, serial.pivots, "t={t}");
            assert_eq!(par.l, serial.l, "t={t}");
        }
    }

    #[test]
    fn rank_capped_at_numerical_rank() {
        let a = spd(20, 4); // numerical rank ~4
        let diag: Vec<f64> = (0..20).map(|i| a[(i, i)]).collect();
        let pc = pivoted_cholesky(20, 16, &diag, |i| a.row(i).to_vec()).unwrap();
        assert!(pc.rank() <= 16);
        assert!(pc.rank() >= 4);
    }

    #[test]
    fn nan_diagonal_is_a_typed_error_not_a_panic() {
        // Regression: pivot selection used max_by(partial_cmp().unwrap()),
        // which panics as soon as a NaN diagonal entry reaches the
        // comparator.  Under total_cmp the NaN is *selected* (it orders
        // above +inf) and reported as a typed error naming the bad index.
        let diag = vec![1.0, f64::NAN, 2.0];
        let err = pivoted_cholesky(3, 2, &diag, |_| vec![0.0; 3]).unwrap_err();
        match err {
            LinalgError::NonFiniteDiagonal { index, value } => {
                assert_eq!(index, 1);
                assert!(value.is_nan());
            }
            other => panic!("wrong error variant: {other:?}"),
        }
    }

    #[test]
    fn infinite_diagonal_is_a_typed_error_too() {
        let diag = vec![1.0, 2.0, f64::INFINITY, 3.0];
        let err = pivoted_cholesky(4, 2, &diag, |_| vec![0.0; 4]).unwrap_err();
        assert!(matches!(err, LinalgError::NonFiniteDiagonal { index: 2, .. }), "{err:?}");
    }
}
