//! Power iteration (top eigenvalue) and inverse power iteration via a
//! provided solve, used for the Fig. 3 conditioning diagnostics
//! (top eigenvalue of H^-1 vs noise precision).

use crate::util::rng::Rng;
use crate::util::stats::{dot, norm2};

/// Top eigenvalue (by magnitude) of a symmetric operator `av`.
pub fn power_iteration(
    n: usize,
    mut av: impl FnMut(&[f64]) -> Vec<f64>,
    iters: usize,
    seed: u64,
) -> f64 {
    let mut rng = Rng::new(seed);
    let mut v = rng.gaussian_vec(n);
    let nv = norm2(&v);
    for x in &mut v {
        *x /= nv;
    }
    let mut lambda = 0.0;
    for _ in 0..iters {
        let w = av(&v);
        lambda = dot(&v, &w);
        let nw = norm2(&w);
        if nw == 0.0 {
            return 0.0;
        }
        v = w.into_iter().map(|x| x / nw).collect();
    }
    lambda
}

/// Top eigenvalue of A^-1 given a solver for A x = b
/// (equals 1 / lambda_min(A) for SPD A).
pub fn inverse_power_iteration(
    n: usize,
    mut solve: impl FnMut(&[f64]) -> Vec<f64>,
    iters: usize,
    seed: u64,
) -> f64 {
    power_iteration(n, |v| solve(v), iters, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{Cholesky, Mat};

    #[test]
    fn power_iteration_diagonal() {
        let a = Mat::from_fn(4, 4, |i, j| if i == j { (i + 1) as f64 } else { 0.0 });
        let lam = power_iteration(4, |v| a.matvec(v), 200, 0);
        assert!((lam - 4.0).abs() < 1e-6, "{lam}");
    }

    #[test]
    fn inverse_power_iteration_matches_min_eig() {
        let a = Mat::from_fn(4, 4, |i, j| if i == j { (i + 2) as f64 } else { 0.0 });
        let ch = Cholesky::factor(&a).unwrap();
        let lam = inverse_power_iteration(4, |b| ch.solve(b), 200, 1);
        assert!((lam - 0.5).abs() < 1e-6, "{lam}"); // 1/min_eig = 1/2
    }
}
