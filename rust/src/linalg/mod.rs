//! Dense linear algebra substrate (f64, row-major).
//!
//! Built in-tree because no external linear-algebra crate is available
//! offline.  Used by the exact-GP baseline, the dense test operator, AP's
//! per-block Cholesky factors and the pivoted-Cholesky CG preconditioner.
//! Sizes stay modest (n <= 4096), so straightforward cache-blocked loops
//! are sufficient; the O(n^2) solver hot path runs in XLA, not here.

mod chol;
pub mod micro;
mod pivoted;
mod power;

pub use chol::Cholesky;
pub use pivoted::{pivoted_cholesky, pivoted_cholesky_threaded, PivotedCholesky};
pub use power::{inverse_power_iteration, power_iteration};

/// Typed errors of the factorisation layer.
///
/// The vendored mini-`anyhow` has no downcasting, so failures callers need
/// to *match on* (a preconditioner build hitting a poisoned hyperparameter,
/// say, which solvers turn into a divergence report rather than a crash)
/// are concrete enums, mirroring `serve::ServeError`.  At `anyhow` API
/// boundaries `?` still converts via the blanket `From<E: Error>`.
#[derive(Clone, Debug, PartialEq)]
pub enum LinalgError {
    /// A factorisation input carried a NaN/inf diagonal entry — typically a
    /// non-finite kernel variance from a poisoned hyperparameter.
    NonFiniteDiagonal { index: usize, value: f64 },
    /// A dense factorisation failed (`what` names the matrix being
    /// factorised, `detail` carries the underlying report).
    Factorization { what: &'static str, detail: String },
}

impl std::fmt::Display for LinalgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LinalgError::NonFiniteDiagonal { index, value } => {
                write!(f, "non-finite diagonal entry {value} at index {index}")
            }
            LinalgError::Factorization { what, detail } => {
                write!(f, "factorisation of {what} failed: {detail}")
            }
        }
    }
}

impl std::error::Error for LinalgError {}

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// Append another matrix's rows below this one's (online data
    /// arrival).  Row-major storage makes this a single buffer extend.
    pub fn append_rows(&mut self, other: &Mat) {
        assert_eq!(
            self.cols, other.cols,
            "append_rows: column mismatch ({} vs {})",
            self.cols, other.cols
        );
        self.data.extend_from_slice(&other.data);
        self.rows += other.rows;
    }

    /// Select a subset of rows.
    pub fn gather_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (oi, &i) in idx.iter().enumerate() {
            out.row_mut(oi).copy_from_slice(self.row(i));
        }
        out
    }

    /// Matrix product self [m,k] * other [k,n] -> [m,n]; ikj loop order for
    /// cache-friendly access on row-major data.
    ///
    /// NOTE: the k-major accumulation order here is load-bearing beyond
    /// performance — `TiledOperator::{k_cols, k_rows}` reproduce it exactly
    /// so the backend-parity property tests
    /// (`tests/proptest_invariants.rs::prop_solver_residuals_match_across_backends`)
    /// can demand near-bitwise AP/SGD trajectory equality.  If you change
    /// the accumulation order (blocking, SIMD reassociation), relax those
    /// tests and the tiled implementations together.
    pub fn matmul(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out);
        out
    }

    /// [`Mat::matmul`] writing into a caller-owned (correctly shaped)
    /// output, zeroed here — so hot loops can reuse the allocation.
    /// Bitwise-identical to `matmul`.
    pub fn matmul_into(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, kk, n) = (self.rows, self.cols, other.cols);
        assert_eq!(
            (out.rows, out.cols),
            (m, n),
            "matmul_into: output is {}x{} but the product is {}x{}",
            out.rows,
            out.cols,
            m,
            n
        );
        out.data.fill(0.0);
        for i in 0..m {
            let a_row = self.row(i);
            let out_row = &mut out.data[i * n..(i + 1) * n];
            matmul_row(a_row, other, kk, n, out_row);
        }
    }

    /// [`Mat::matmul`] with output rows spread over `threads` workers
    /// (0 = auto).  Every output row is produced by exactly the same
    /// k-major accumulation as the serial path (`matmul_row`), and rows are
    /// disjoint `&mut` blocks, so the product is **bitwise-identical** to
    /// `matmul` for every thread count — the parity-test contract above is
    /// preserved.
    pub fn matmul_threaded(&self, other: &Mat, threads: usize) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, kk, n) = (self.rows, self.cols, other.cols);
        let flops = m * kk * n;
        let t = if flops < (1 << 16) {
            1
        } else {
            crate::util::parallel::num_threads(if threads == 0 { None } else { Some(threads) })
        };
        if t <= 1 {
            return self.matmul(other);
        }
        let mut out = Mat::zeros(m, n);
        let block = ((m + t - 1) / t).max(1);
        crate::util::parallel::parallel_row_blocks(
            &mut out.data,
            n,
            block,
            t,
            |r0, rows, blk| {
                for r in 0..rows {
                    let a_row = self.row(r0 + r);
                    let out_row = &mut blk[r * n..(r + 1) * n];
                    matmul_row(a_row, other, kk, n, out_row);
                }
            },
        );
        out
    }

    /// Matrix-vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|i| crate::util::stats::dot(self.row(i), v))
            .collect()
    }

    pub fn scale(&mut self, a: f64) {
        for x in &mut self.data {
            *x *= a;
        }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (x, y) in self.data.iter_mut().zip(&other.data) {
            *x += y;
        }
    }

    pub fn sub_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (x, y) in self.data.iter_mut().zip(&other.data) {
            *x -= y;
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        crate::util::stats::norm2(&self.data)
    }

    /// Maximum absolute difference to another matrix.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Add `a` to every diagonal element (square matrices).
    pub fn add_diag(&mut self, a: f64) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self[(i, i)] += a;
        }
    }

    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        // lint:allow(ordered-reduction): serial ascending fold over a strided diagonal is already canonical
        (0..self.rows).map(|i| self[(i, i)]).sum()
    }
}

/// One output row of `matmul` — the single source of the k-major (ikj)
/// accumulation order shared by the serial and threaded products.  The
/// inner axpy is the register-blocked micro-kernel shared with the kernel
/// panel engine's tile-apply ([`micro::axpy`], bitwise-equal to the plain
/// loop), so both paths carry exactly the same association.
#[inline]
fn matmul_row(a_row: &[f64], other: &Mat, kk: usize, n: usize, out_row: &mut [f64]) {
    for (k, &a) in a_row.iter().enumerate().take(kk) {
        if a == 0.0 {
            continue;
        }
        micro::axpy(out_row, a, &other.data[k * n..(k + 1) * n]);
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(3, 3, |i, j| (i * 3 + j) as f64);
        let i3 = Mat::eye(3);
        assert_eq!(a.matmul(&i3), a);
        assert_eq!(i3.matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_threaded_is_bitwise_equal_to_serial() {
        let mut rng = crate::util::rng::Rng::new(11);
        // big enough to clear the parallel threshold (96*96*96 > 2^16)
        let a = Mat::from_fn(96, 96, |_, _| rng.gaussian());
        let b = Mat::from_fn(96, 96, |_, _| rng.gaussian());
        let serial = a.matmul(&b);
        for t in [1, 2, 4, 7] {
            assert_eq!(a.matmul_threaded(&b, t), serial, "threads={t}");
        }
    }

    #[test]
    fn matmul_into_reuses_dirty_output_bitwise() {
        let mut rng = crate::util::rng::Rng::new(12);
        let a = Mat::from_fn(9, 7, |_, _| rng.gaussian());
        let b = Mat::from_fn(7, 5, |_, _| rng.gaussian());
        let want = a.matmul(&b);
        let mut out = Mat::from_fn(9, 5, |_, _| rng.gaussian()); // dirty
        a.matmul_into(&b, &mut out);
        assert_eq!(out, want);
        a.matmul_into(&b, &mut out); // and again, reusing the buffer
        assert_eq!(out, want);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Mat::from_fn(4, 3, |i, j| (i + 2 * j) as f64);
        let v = vec![1.0, -1.0, 2.0];
        let mv = a.matvec(&v);
        let vm = a.matmul(&Mat::from_vec(3, 1, v));
        assert_eq!(mv, vm.data);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::from_fn(3, 5, |i, j| (i * 10 + j) as f64);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn append_rows_stacks() {
        let mut a = Mat::from_fn(2, 3, |i, j| (i * 3 + j) as f64);
        let b = Mat::from_fn(1, 3, |_, j| 100.0 + j as f64);
        a.append_rows(&b);
        assert_eq!((a.rows, a.cols), (3, 3));
        assert_eq!(a.row(2), &[100.0, 101.0, 102.0]);
        assert_eq!(a.row(0), &[0.0, 1.0, 2.0]);
    }

    #[test]
    fn gather_rows_selects() {
        let a = Mat::from_fn(4, 2, |i, j| (i * 2 + j) as f64);
        let g = a.gather_rows(&[2, 0]);
        assert_eq!(g.data, vec![4.0, 5.0, 0.0, 1.0]);
    }

    #[test]
    fn add_diag_and_trace() {
        let mut a = Mat::zeros(3, 3);
        a.add_diag(2.5);
        assert!((a.trace() - 7.5).abs() < 1e-15);
    }
}
