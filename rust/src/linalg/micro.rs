//! Register-blocked micro-kernels shared by `Mat::matmul`'s row update and
//! the kernel panel engine (`crate::kernels::panel`).
//!
//! Everything here is written so the floating-point association of each
//! *output element* is a plain ascending-index sum, independent of the
//! unroll factor: `dot4` keeps four independent accumulators (one per
//! output), and `axpy` unrolls across independent output elements.  That
//! makes the bits of every caller identical to the corresponding scalar
//! loop — the determinism and backend-parity contracts upstream
//! (`Mat::matmul`'s load-bearing k-major order, tiled==dense bitwise
//! equality) survive the blocking.

/// Element scalar for the precision-generic micro-kernels: products are
/// formed in the element type (`Self::Mul`), then widened to f64 for the
/// accumulation.  For f64 the widening is the identity, so the generic
/// kernels are bitwise-identical to the historical f64-only ones; for f32
/// each product rounds to f32 first (cheap, vectorises twice as wide) and
/// the running sum stays in f64, which bounds the accumulation error at
/// the per-product rounding rather than letting it grow with the sum
/// length.
pub trait Scalar:
    Copy + Send + Sync + PartialEq + std::ops::Mul<Output = Self> + 'static
{
    fn to_f64(self) -> f64;
    fn from_f64(v: f64) -> Self;
}

impl Scalar for f64 {
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline(always)]
    fn from_f64(v: f64) -> Self {
        v
    }
}

impl Scalar for f32 {
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline(always)]
    fn from_f64(v: f64) -> Self {
        v as f32
    }
}

/// Order-canonical float sum: a plain ascending-index fold, bitwise
/// identical to `xs.iter().sum::<f64>()`.  Solver/operator code routes
/// scalar reductions through this (or the recurrence/parallel helpers)
/// instead of ad-hoc `.sum()` calls so the association order that the
/// bitwise-parity contracts depend on is named in exactly one place —
/// the `ordered-reduction` lint rule enforces the routing.
#[inline(always)]
pub fn sum(xs: &[f64]) -> f64 {
    let mut s = 0.0;
    for &x in xs {
        s += x;
    }
    s
}

/// Plain ascending-order dot product — the canonical association every
/// other kernel here reproduces.  Also the single source of the squared
/// row norms cached in `ScaledX` (the Gram-trick diagonal is exactly zero
/// only because the norm and the cross-product use the same sum order).
///
/// Generic over the element [`Scalar`]: each product is taken in the
/// element type and accumulated in f64.  `S = f64` (what every existing
/// call site infers) is bitwise-identical to the historical f64-only
/// implementation.
#[inline(always)]
pub fn dot<S: Scalar>(a: &[S], b: &[S]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for r in 0..a.len() {
        s += (a[r] * b[r]).to_f64();
    }
    s
}

/// Four dot products of `a` against `b0..b3` in one pass — the 4-wide
/// unrolled core of the panel cross-product `Xi · Xjᵀ`.  Each accumulator
/// sums in ascending index order, so every output is bitwise-identical to
/// [`dot`] on the same pair (at either precision).
#[inline(always)]
pub fn dot4<S: Scalar>(
    a: &[S],
    b0: &[S],
    b1: &[S],
    b2: &[S],
    b3: &[S],
) -> (f64, f64, f64, f64) {
    let d = a.len();
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for r in 0..d {
        let ar = a[r];
        s0 += (ar * b0[r]).to_f64();
        s1 += (ar * b1[r]).to_f64();
        s2 += (ar * b2[r]).to_f64();
        s3 += (ar * b3[r]).to_f64();
    }
    (s0, s1, s2, s3)
}

/// `out[j] += a * b[j]` — the k-major axpy at the heart of `Mat::matmul`'s
/// row update and the panel tile-apply.  4-wide unrolled; the per-element
/// accumulators are independent, so the bits match the plain loop for
/// every length.  Deliberately f64-only: the apply side of every operator
/// product accumulates panel *values* (already f64 at either compute
/// precision) into f64 outputs, so reduced precision never touches it.
#[inline(always)]
pub fn axpy(out: &mut [f64], a: f64, b: &[f64]) {
    debug_assert_eq!(out.len(), b.len());
    let n = out.len();
    let n4 = n - n % 4;
    let mut j = 0;
    while j < n4 {
        out[j] += a * b[j];
        out[j + 1] += a * b[j + 1];
        out[j + 2] += a * b[j + 2];
        out[j + 3] += a * b[j + 3];
        j += 4;
    }
    while j < n {
        out[j] += a * b[j];
        j += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn sum_is_bitwise_equal_to_iter_sum() {
        let mut rng = Rng::new(11);
        for n in [0, 1, 2, 5, 17, 64] {
            let xs = rng.gaussian_vec(n);
            let want: f64 = xs.iter().sum();
            assert_eq!(sum(&xs).to_bits(), want.to_bits(), "n={n}");
        }
    }

    #[test]
    fn dot4_is_bitwise_equal_to_dot() {
        let mut rng = Rng::new(0);
        for d in [1, 3, 4, 7, 17] {
            let a: Vec<f64> = rng.gaussian_vec(d);
            let bs: Vec<Vec<f64>> = (0..4).map(|_| rng.gaussian_vec(d)).collect();
            let (s0, s1, s2, s3) = dot4(&a, &bs[0], &bs[1], &bs[2], &bs[3]);
            for (got, b) in [s0, s1, s2, s3].iter().zip(&bs) {
                assert_eq!(got.to_bits(), dot(&a, b).to_bits(), "d={d}");
            }
        }
    }

    #[test]
    fn f32_dot_accumulates_products_in_f64() {
        let mut rng = Rng::new(7);
        for d in [1, 3, 4, 9, 33] {
            let a32: Vec<f32> = rng.gaussian_vec(d).iter().map(|&v| v as f32).collect();
            let b32: Vec<f32> = rng.gaussian_vec(d).iter().map(|&v| v as f32).collect();
            // reference: f32 products, f64 running sum, ascending order
            let mut want = 0.0f64;
            for r in 0..d {
                want += (a32[r] * b32[r]) as f64;
            }
            assert_eq!(dot(&a32, &b32).to_bits(), want.to_bits(), "d={d}");
        }
    }

    #[test]
    fn f32_dot4_is_bitwise_equal_to_f32_dot() {
        let mut rng = Rng::new(8);
        for d in [1, 2, 4, 5, 16] {
            let a: Vec<f32> = rng.gaussian_vec(d).iter().map(|&v| v as f32).collect();
            let bs: Vec<Vec<f32>> = (0..4)
                .map(|_| rng.gaussian_vec(d).iter().map(|&v| v as f32).collect())
                .collect();
            let (s0, s1, s2, s3) = dot4(&a, &bs[0], &bs[1], &bs[2], &bs[3]);
            for (got, b) in [s0, s1, s2, s3].iter().zip(&bs) {
                assert_eq!(got.to_bits(), dot(&a, b).to_bits(), "d={d}");
            }
        }
    }

    #[test]
    fn axpy_is_bitwise_equal_to_plain_loop() {
        let mut rng = Rng::new(1);
        for n in [0, 1, 3, 4, 5, 8, 13] {
            let base = rng.gaussian_vec(n);
            let b = rng.gaussian_vec(n);
            let a = rng.gaussian();
            let mut got = base.clone();
            axpy(&mut got, a, &b);
            let mut want = base;
            for j in 0..n {
                want[j] += a * b[j];
            }
            for (x, y) in got.iter().zip(&want) {
                assert_eq!(x.to_bits(), y.to_bits(), "n={n}");
            }
        }
    }
}
