//! `igp exp report` — assemble the measured-results section of
//! EXPERIMENTS.md from the markdown/CSV outputs under results/, and
//! compute the headline comparisons (speed-up factors, residual
//! reductions) that the paper's abstract quotes.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

/// Parse a results CSV into (header, rows).
pub fn read_csv(path: &Path) -> Result<(Vec<String>, Vec<Vec<String>>)> {
    let text = std::fs::read_to_string(path)?;
    let mut lines = text.lines();
    let header: Vec<String> = lines
        .next()
        .unwrap_or_default()
        .split(',')
        .map(str::to_string)
        .collect();
    let rows = lines
        .map(|l| l.split(',').map(str::to_string).collect())
        .collect();
    Ok((header, rows))
}

fn col(header: &[String], name: &str) -> Option<usize> {
    header.iter().position(|h| h == name)
}

/// Headline numbers from table1.csv: per (dataset, solver), the total-time
/// speed-up of each variant relative to (standard, cold).
pub fn table1_speedups(path: &Path) -> Result<BTreeMap<(String, String), Vec<(String, f64)>>> {
    let (header, rows) = read_csv(path)?;
    let (c_ds, c_sol, c_est, c_warm, c_total) = (
        col(&header, "dataset").unwrap(),
        col(&header, "solver").unwrap(),
        col(&header, "estimator").unwrap(),
        col(&header, "warm").unwrap(),
        col(&header, "total_secs").unwrap(),
    );
    // mean over splits
    let mut acc: BTreeMap<(String, String, String, String), (f64, usize)> = BTreeMap::new();
    for r in &rows {
        let key = (r[c_ds].clone(), r[c_sol].clone(), r[c_est].clone(), r[c_warm].clone());
        let e = acc.entry(key).or_insert((0.0, 0));
        e.0 += r[c_total].parse::<f64>().unwrap_or(f64::NAN);
        e.1 += 1;
    }
    let mut out: BTreeMap<(String, String), Vec<(String, f64)>> = BTreeMap::new();
    for ((ds, sol, est, warm), (sum, cnt)) in &acc {
        let base = acc
            .get(&(ds.clone(), sol.clone(), "standard".into(), "false".into()))
            .map(|(s, c)| s / *c as f64)
            .unwrap_or(f64::NAN);
        let mean = sum / *cnt as f64;
        out.entry((ds.clone(), sol.clone())).or_default().push((
            format!("{est}/{}", if warm == "true" { "warm" } else { "cold" }),
            base / mean,
        ));
    }
    Ok(out)
}

/// Residual-norm reduction from warm starting under a budget (fig10 CSVs):
/// max over datasets/solvers of cold_rz / warm_rz at the final step.
pub fn fig10_residual_reduction(dir: &Path) -> Result<Vec<(String, f64)>> {
    let mut last_rz: BTreeMap<(String, String), f64> = BTreeMap::new();
    if dir.exists() {
        for entry in std::fs::read_dir(dir)? {
            let p = entry?.path();
            let name = p.file_name().unwrap().to_string_lossy().to_string();
            let Some(stem) = name.strip_prefix("steps_").and_then(|s| s.strip_suffix(".csv"))
            else {
                continue;
            };
            let (header, rows) = read_csv(&p)?;
            let Some(c_rz) = col(&header, "rz") else { continue };
            let Some(last) = rows.last() else { continue };
            let rz: f64 = last[c_rz].parse().unwrap_or(f64::NAN);
            // stem = <dataset>_<solver>_<warm|cold>
            let Some((rest, mode)) = stem.rsplit_once('_') else { continue };
            last_rz.insert((rest.to_string(), mode.to_string()), rz);
        }
    }
    let mut out = Vec::new();
    let keys: Vec<String> = last_rz
        .keys()
        .map(|(k, _)| k.clone())
        .collect::<std::collections::BTreeSet<_>>()
        .into_iter()
        .collect();
    for k in keys {
        if let (Some(&cold), Some(&warm)) = (
            last_rz.get(&(k.clone(), "cold".into())),
            last_rz.get(&(k.clone(), "warm".into())),
        ) {
            out.push((k, cold / warm));
        }
    }
    Ok(out)
}

/// Render the measured-results markdown fragment.
pub fn render(results_dir: &Path) -> Result<String> {
    let mut s = String::new();
    // embed each experiment's own markdown table if present
    for (id, title) in [
        ("table1", "Table 1 — solve-to-tolerance (small suite)"),
        ("table7", "Tables 7–10 — large datasets, 10-epoch budget"),
        ("fig1", "Fig 1 — runtime breakdown"),
        ("fig9", "Fig 9 — limited budgets"),
        ("fig10", "Fig 10 — budget + warm-start accumulation"),
    ] {
        let p = results_dir.join(id).join(format!("{id}.md"));
        if p.exists() {
            let _ = writeln!(s, "### {title}\n");
            s.push_str(&std::fs::read_to_string(&p)?);
            s.push('\n');
        }
    }
    // headline numbers
    let t1 = results_dir.join("table1").join("table1.csv");
    if t1.exists() {
        let _ = writeln!(s, "### Headline speed-ups (vs standard/cold, same solver & dataset)\n");
        let mut best = (String::new(), 0.0);
        for ((ds, sol), variants) in table1_speedups(&t1)? {
            for (v, x) in variants {
                if x.is_finite() && x > best.1 {
                    best = (format!("{ds}/{sol}/{v}"), x);
                }
                if v == "pathwise/warm" {
                    let _ = writeln!(s, "- {ds}/{sol}: pathwise+warm = **{x:.1}×**");
                }
            }
        }
        let _ = writeln!(s, "\nBest observed speed-up: **{} at {:.1}×** (paper: up to 72×\non AP at n=44k; smaller factors are expected at our reduced n — the AP\ncold baseline is censored at the epoch cap, so its true time is larger).", best.0, best.1);
    }
    let f10 = results_dir.join("fig10");
    let red = fig10_residual_reduction(&f10)?;
    if !red.is_empty() {
        let _ = writeln!(s, "\n### Warm-start residual reduction under a 10-epoch budget (Fig 10)\n");
        for (k, x) in &red {
            let _ = writeln!(s, "- {k}: cold/warm final residual = **{x:.1}×**");
        }
        let best = red.iter().map(|(_, x)| *x).fold(0.0, f64::max);
        let _ = writeln!(s, "\nMax residual-norm reduction: **{best:.1}×** (paper: up to 7×).");
    }
    Ok(s)
}

pub fn write_into_experiments_md(results_dir: &Path, experiments_md: &Path) -> Result<()> {
    let fragment = render(results_dir)?;
    let text = std::fs::read_to_string(experiments_md)?;
    let (pre, rest) = text
        .split_once("<!-- RESULTS-START -->")
        .ok_or_else(|| anyhow::anyhow!("missing RESULTS-START marker"))?;
    let (_, post) = rest
        .split_once("<!-- RESULTS-END -->")
        .ok_or_else(|| anyhow::anyhow!("missing RESULTS-END marker"))?;
    let new = format!(
        "{pre}<!-- RESULTS-START -->\n{fragment}\n<!-- RESULTS-END -->{post}"
    );
    std::fs::write(experiments_md, new)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("igp_report_{tag}"));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn csv_roundtrip_and_speedups() {
        let d = tmpdir("t1");
        std::fs::create_dir_all(d.join("table1")).unwrap();
        std::fs::write(
            d.join("table1/table1.csv"),
            "dataset,solver,estimator,warm,split,rmse,llh,total_secs,solver_secs,epochs,censored\n\
             pol,ap,standard,false,0,0.1,1.0,100.0,90.0,500,false\n\
             pol,ap,pathwise,true,0,0.1,1.0,10.0,9.0,50,false\n",
        )
        .unwrap();
        let s = table1_speedups(&d.join("table1/table1.csv")).unwrap();
        let v = &s[&("pol".to_string(), "ap".to_string())];
        let pw = v.iter().find(|(k, _)| k == "pathwise/warm").unwrap();
        assert!((pw.1 - 10.0).abs() < 1e-9, "{}", pw.1);
    }

    #[test]
    fn fig10_reduction_parses_step_files() {
        let d = tmpdir("f10");
        for (mode, rz) in [("cold", 0.09), ("warm", 0.01)] {
            std::fs::write(
                d.join(format!("steps_song_ap_{mode}.csv")),
                format!("step,ry,rz\n0,1.0,1.0\n1,0.5,{rz}\n"),
            )
            .unwrap();
        }
        let red = fig10_residual_reduction(&d).unwrap();
        assert_eq!(red.len(), 1);
        assert!((red[0].1 - 9.0).abs() < 1e-9);
    }

    #[test]
    fn marker_splice_replaces_between_markers() {
        let d = tmpdir("md");
        let md = d.join("EXPERIMENTS.md");
        std::fs::write(&md, "head\n<!-- RESULTS-START -->\nold\n<!-- RESULTS-END -->\ntail\n").unwrap();
        write_into_experiments_md(&d, &md).unwrap();
        let out = std::fs::read_to_string(&md).unwrap();
        assert!(out.contains("head"));
        assert!(out.contains("tail"));
        assert!(!out.contains("old"));
    }
}
