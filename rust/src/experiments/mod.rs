//! Experiment harness: one subcommand per paper table/figure (DESIGN.md §4).
//!
//! Every experiment writes CSV series plus a markdown summary under
//! `results/` mirroring the paper's rows; EXPERIMENTS.md records the
//! paper-vs-measured comparison.  Scales are reduced per DESIGN.md §3
//! (synthetic UCI-like datasets, fewer outer steps); the *shape* of each
//! result (who wins, by what factor, where crossovers fall) is the target.

mod cells;
mod figs;
pub mod report;

use anyhow::Result;

use cells::{run_cell, write_telemetry, Cell};
use igp::estimator::EstimatorKind;
use igp::runtime::Runtime;
use igp::solvers::SolverKind;
use igp::util::csv::{CsvWriter, MarkdownTable};

use crate::cli::Parser;

const SOLVERS: [SolverKind; 3] = [SolverKind::Cg, SolverKind::Ap, SolverKind::Sgd];
const VARIANTS: [(EstimatorKind, bool); 4] = [
    (EstimatorKind::Standard, false),
    (EstimatorKind::Pathwise, false),
    (EstimatorKind::Standard, true),
    (EstimatorKind::Pathwise, true),
];

pub fn dispatch(args: &[String]) -> Result<()> {
    let p = Parser::new(args, &["out", "steps", "splits", "artifacts", "datasets"])?;
    let Some(id) = p.positional.first() else {
        anyhow::bail!("usage: igp exp <id|all> [--out DIR] [--steps N] [--splits N] [--full]");
    };
    let ctx = Ctx {
        rt: Runtime::cpu()?,
        artifacts: p.get("artifacts").unwrap_or("artifacts").to_string(),
        out: p.get("out").unwrap_or("results").to_string(),
        steps: p.get("steps").map(|v| v.parse()).transpose()?.unwrap_or(0),
        splits: p.get("splits").map(|v| v.parse()).transpose()?.unwrap_or(1),
        full: p.flag("full"),
        datasets: p
            .get("datasets")
            .map(|v| v.split(',').map(str::to_string).collect()),
    };
    match id.as_str() {
        "table1" => table1(&ctx),
        "table7" => table7(&ctx),
        "fig1" => fig1(&ctx),
        "fig3" => figs::fig3(&ctx),
        "fig4" => figs::fig4(&ctx),
        "fig5" | "fig8" | "fig11" | "traj" => figs::traj(&ctx),
        "fig6" => figs::fig6(&ctx),
        "fig7" | "fig21" => figs::fig7(&ctx),
        "fig9" | "fig14" => figs::fig9(&ctx),
        "fig10" | "fig18" => figs::fig10(&ctx),
        "report" => report::write_into_experiments_md(
            std::path::Path::new(&ctx.out),
            std::path::Path::new("EXPERIMENTS.md"),
        ),
        "all" => {
            table1(&ctx)?;
            table7(&ctx)?;
            fig1(&ctx)?;
            figs::fig3(&ctx)?;
            figs::fig4(&ctx)?;
            figs::traj(&ctx)?;
            figs::fig6(&ctx)?;
            figs::fig7(&ctx)?;
            figs::fig9(&ctx)?;
            figs::fig10(&ctx)?;
            Ok(())
        }
        other => anyhow::bail!("unknown experiment '{other}'"),
    }
}

pub struct Ctx {
    pub rt: Runtime,
    pub artifacts: String,
    pub out: String,
    /// 0 = experiment default.
    pub steps: usize,
    pub splits: u64,
    pub full: bool,
    pub datasets: Option<Vec<String>>,
}

impl Ctx {
    fn steps_or(&self, default: usize) -> usize {
        if self.steps == 0 {
            default
        } else {
            self.steps
        }
    }

    fn small_datasets(&self) -> Vec<String> {
        if let Some(ds) = &self.datasets {
            return ds.clone();
        }
        let mut v = vec!["pol".to_string(), "elevators".to_string(), "bike".to_string()];
        if self.full {
            v.push("protein".into());
            v.push("keggdir".into());
        }
        v
    }

    fn large_datasets(&self) -> Vec<String> {
        if let Some(ds) = &self.datasets {
            return ds.clone();
        }
        let mut v = vec!["threedroad".to_string(), "song".to_string(), "buzz".to_string()];
        if self.full {
            v.push("houseelectric".into());
        }
        v
    }

    fn out_dir(&self, id: &str) -> std::path::PathBuf {
        let p = std::path::PathBuf::from(&self.out).join(id);
        std::fs::create_dir_all(&p).expect("create results dir");
        p
    }
}

fn fmt3(x: f64) -> String {
    format!("{x:.3}")
}

// ---------------------------------------------------------------------------
// Table 1 (+ Tables 2-6): solve-to-tolerance study on the small suite
// ---------------------------------------------------------------------------

pub fn table1(ctx: &Ctx) -> Result<()> {
    let dir = ctx.out_dir("table1");
    let steps = ctx.steps_or(25);
    let mut md = MarkdownTable::new(&[
        "solver", "pathwise", "warm", "dataset", "rmse", "llh", "total(s)", "solver(s)",
        "epochs", "censored", "speedup",
    ]);
    let mut csv = CsvWriter::create(
        dir.join("table1.csv"),
        &[
            "dataset", "solver", "estimator", "warm", "split", "rmse", "llh", "total_secs",
            "solver_secs", "epochs", "censored",
        ],
    )?;

    for dataset in ctx.small_datasets() {
        for solver in SOLVERS {
            let mut baseline_time: Option<f64> = None;
            for (estimator, warm) in VARIANTS {
                // mean over splits
                let mut agg = Vec::new();
                for split in 0..ctx.splits {
                    let mut cell = Cell::new(&dataset, solver, estimator, warm);
                    cell.steps = steps;
                    cell.split = split;
                    let res = run_cell(&ctx.rt, &ctx.artifacts, &cell)?;
                    csv.row(&[
                        dataset.clone(),
                        solver.name().into(),
                        estimator.name().into(),
                        warm.to_string(),
                        split.to_string(),
                        format!("{:.4}", res.out.final_metrics.rmse),
                        format!("{:.4}", res.out.final_metrics.llh),
                        fmt3(res.out.total_secs),
                        fmt3(res.out.solver_secs),
                        format!("{:.1}", res.out.total_epochs),
                        res.censored.to_string(),
                    ])?;
                    if split == 0 {
                        write_telemetry(
                            &res,
                            &dir.join(format!(
                                "steps_{}_{}_{}_{}.csv",
                                dataset,
                                solver.name(),
                                estimator.name(),
                                if warm { "warm" } else { "cold" }
                            )),
                        )?;
                    }
                    agg.push(res);
                }
                let mean = |f: &dyn Fn(&cells::CellResult) -> f64| {
                    agg.iter().map(|r| f(r)).sum::<f64>() / agg.len() as f64
                };
                let total = mean(&|r| r.out.total_secs);
                let speedup = match baseline_time {
                    None => {
                        baseline_time = Some(total);
                        "-".to_string()
                    }
                    Some(base) => format!("{:.1}x", base / total),
                };
                let censored = agg.iter().any(|r| r.censored);
                igp::info!(
                    "table1 {} done: llh={:.3} total={:.1}s epochs={:.0}{}",
                    agg[0].cell.label(),
                    mean(&|r| r.out.final_metrics.llh),
                    total,
                    mean(&|r| r.out.total_epochs),
                    if censored { " (censored)" } else { "" }
                );
                md.row(vec![
                    solver.name().to_string(),
                    if estimator == EstimatorKind::Pathwise { "x".into() } else { "".into() },
                    if warm { "x".into() } else { "".into() },
                    dataset.clone(),
                    format!("{:.4}", mean(&|r| r.out.final_metrics.rmse)),
                    format!("{:.4}", mean(&|r| r.out.final_metrics.llh)),
                    fmt3(total),
                    fmt3(mean(&|r| r.out.solver_secs)),
                    format!("{:.0}", mean(&|r| r.out.total_epochs)),
                    if censored { ">".into() } else { "".into() },
                    speedup,
                ]);
            }
        }
    }
    csv.flush()?;
    md.write_to(dir.join("table1.md"))?;
    println!("{}", md.render());
    Ok(())
}

// ---------------------------------------------------------------------------
// Tables 7-10: large datasets, 10-epoch budget, warm vs cold
// ---------------------------------------------------------------------------

pub fn table7(ctx: &Ctx) -> Result<()> {
    let dir = ctx.out_dir("table7");
    let steps = ctx.steps_or(12);
    let mut md = MarkdownTable::new(&[
        "dataset", "solver", "warm", "rmse", "llh", "total(s)", "resid mean", "resid probes",
    ]);
    let mut csv = CsvWriter::create(
        dir.join("table7.csv"),
        &["dataset", "solver", "warm", "rmse", "llh", "total_secs", "ry", "rz"],
    )?;
    for dataset in ctx.large_datasets() {
        for solver in SOLVERS {
            for warm in [false, true] {
                let mut cell = Cell::new(&dataset, solver, EstimatorKind::Pathwise, warm);
                cell.steps = steps;
                cell.lr = 0.03;
                cell.max_epochs = Some(10.0);
                cell.subset_init = true; // paper App. B heuristic
                let res = run_cell(&ctx.rt, &ctx.artifacts, &cell)?;
                let last = res.out.telemetry.last().unwrap();
                igp::info!(
                    "table7 {} done: llh={:.3} rz={:.3}",
                    res.cell.label(),
                    res.out.final_metrics.llh,
                    last.rz
                );
                write_telemetry(
                    &res,
                    &dir.join(format!(
                        "steps_{}_{}_{}.csv",
                        dataset,
                        solver.name(),
                        if warm { "warm" } else { "cold" }
                    )),
                )?;
                md.row(vec![
                    dataset.clone(),
                    solver.name().into(),
                    if warm { "x".into() } else { "".into() },
                    format!("{:.4}", res.out.final_metrics.rmse),
                    format!("{:.4}", res.out.final_metrics.llh),
                    fmt3(res.out.total_secs),
                    format!("{:.4}", last.ry),
                    format!("{:.4}", last.rz),
                ]);
                csv.row(&[
                    dataset.clone(),
                    solver.name().into(),
                    warm.to_string(),
                    format!("{:.4}", res.out.final_metrics.rmse),
                    format!("{:.4}", res.out.final_metrics.llh),
                    fmt3(res.out.total_secs),
                    format!("{:.4}", last.ry),
                    format!("{:.4}", last.rz),
                ])?;
            }
        }
    }
    csv.flush()?;
    md.write_to(dir.join("table7.md"))?;
    println!("{}", md.render());
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig 1: runtime breakdown (solver time vs total) per method
// ---------------------------------------------------------------------------

pub fn fig1(ctx: &Ctx) -> Result<()> {
    let dir = ctx.out_dir("fig1");
    let steps = ctx.steps_or(12);
    let mut csv = CsvWriter::create(
        dir.join("fig1.csv"),
        &["dataset", "solver", "estimator", "warm", "total_secs", "solver_secs", "solver_frac"],
    )?;
    let mut md = MarkdownTable::new(&["method", "dataset", "total(s)", "solver(s)", "solver %"]);
    for dataset in ["pol".to_string(), "elevators".to_string()] {
        for solver in SOLVERS {
            for (estimator, warm) in VARIANTS {
                let mut cell = Cell::new(&dataset, solver, estimator, warm);
                cell.steps = steps;
                let res = run_cell(&ctx.rt, &ctx.artifacts, &cell)?;
                let frac = res.out.solver_secs / res.out.total_secs;
                csv.row(&[
                    dataset.clone(),
                    solver.name().into(),
                    estimator.name().into(),
                    warm.to_string(),
                    fmt3(res.out.total_secs),
                    fmt3(res.out.solver_secs),
                    format!("{frac:.3}"),
                ])?;
                md.row(vec![
                    res.cell.label(),
                    dataset.clone(),
                    fmt3(res.out.total_secs),
                    fmt3(res.out.solver_secs),
                    format!("{:.0}%", 100.0 * frac),
                ]);
            }
        }
    }
    csv.flush()?;
    md.write_to(dir.join("fig1.md"))?;
    println!("{}", md.render());
    Ok(())
}
