//! Shared experiment-cell runner: one cell = one training run of a
//! (dataset, solver, estimator, warm-start, budget) combination on the XLA
//! backend — the unit from which every table and figure is assembled.

use anyhow::Result;

use igp::coordinator::{Trainer, TrainerOptions, TrainOutcome};
use igp::data;
use igp::estimator::EstimatorKind;
use igp::operators::XlaOperator;
use igp::runtime::Runtime;
use igp::solvers::SolverKind;

#[derive(Clone, Debug)]
pub struct Cell {
    pub dataset: String,
    pub solver: SolverKind,
    pub estimator: EstimatorKind,
    pub warm: bool,
    pub steps: usize,
    pub lr: f64,
    /// None = solve to tolerance (under `epoch_cap`).
    pub max_epochs: Option<f64>,
    /// Censoring cap for to-tolerance solving (the paper's 24h timeout).
    pub epoch_cap: f64,
    pub split: u64,
    pub seed: u64,
    /// Evaluate test metrics every k steps.
    pub predict_every: Option<usize>,
    /// Track the exact MLL per step (small configs only).
    pub track_exact: bool,
    /// Initialise hyperparameters with the paper's subset heuristic
    /// (App. B; used on the large datasets).
    pub subset_init: bool,
}

impl Cell {
    pub fn new(dataset: &str, solver: SolverKind, estimator: EstimatorKind, warm: bool) -> Self {
        Cell {
            dataset: dataset.to_string(),
            solver,
            estimator,
            warm,
            steps: 25,
            lr: 0.1,
            max_epochs: None,
            epoch_cap: 100.0,
            split: 0,
            seed: 0,
            predict_every: None,
            track_exact: false,
            subset_init: false,
        }
    }

    pub fn label(&self) -> String {
        format!(
            "{}/{}/{}/{}",
            self.dataset,
            self.solver.name(),
            self.estimator.name(),
            if self.warm { "warm" } else { "cold" }
        )
    }
}

pub struct CellResult {
    pub cell: Cell,
    pub out: TrainOutcome,
    /// Whether any step hit the epoch cap without converging (censoring).
    pub censored: bool,
}

pub fn run_cell(rt: &Runtime, artifacts: &str, cell: &Cell) -> Result<CellResult> {
    let spec = data::spec(&cell.dataset)?;
    let ds = data::generate_split(&spec, cell.split);
    let model = rt.load_config(artifacts, &cell.dataset)?;
    let block = model.meta.b;
    let op = XlaOperator::new(model, &ds);
    let opts = TrainerOptions {
        solver: cell.solver,
        estimator: cell.estimator,
        warm_start: cell.warm,
        lr: cell.lr,
        max_epochs: cell.max_epochs,
        epoch_cap: cell.epoch_cap,
        block_size: Some(block),
        predict_every: cell.predict_every,
        track_exact: cell.track_exact,
        seed: cell.seed ^ cell.split.wrapping_mul(0x9E37),
        sgd_lr_halve: cell.max_epochs.is_some(), // paper: halve on budgeted/large runs
        ..Default::default()
    };
    let mut trainer = Trainer::new(opts, Box::new(op), &ds);
    if cell.subset_init {
        let theta = igp::coordinator::init::subset_init(
            &ds,
            &igp::coordinator::init::SubsetInitOptions { seed: cell.seed, ..Default::default() },
        )?;
        trainer.set_init_theta(&theta);
    }
    let out = trainer.run(cell.steps)?;
    let censored = cell.max_epochs.is_none() && out.telemetry.iter().any(|t| !t.converged);
    Ok(CellResult { cell: cell.clone(), out, censored })
}

/// Write full per-step telemetry of a cell to CSV.
pub fn write_telemetry(res: &CellResult, path: &std::path::Path) -> Result<()> {
    let mut w = igp::util::csv::CsvWriter::create(
        path,
        &[
            "step", "ry", "rz", "iterations", "epochs", "solver_secs", "converged",
            "init_residual_sq", "exact_mll", "rmse", "llh", "theta_sigma", "theta_sigf",
        ],
    )?;
    for t in &res.out.telemetry {
        let d = t.theta.len() - 2;
        let (rmse, llh) = t
            .metrics
            .map(|m| (format!("{:.6}", m.rmse), format!("{:.6}", m.llh)))
            .unwrap_or_default();
        w.row(&[
            t.step.to_string(),
            format!("{:.6e}", t.ry),
            format!("{:.6e}", t.rz),
            t.iterations.to_string(),
            format!("{:.3}", t.epochs),
            format!("{:.4}", t.solver_secs),
            t.converged.to_string(),
            format!("{:.4e}", t.init_residual_sq),
            t.exact_mll.map(|v| format!("{v:.4}")).unwrap_or_default(),
            rmse,
            llh,
            format!("{:.5}", t.theta[d + 1]),
            format!("{:.5}", t.theta[d]),
        ])?;
    }
    w.flush()
}
