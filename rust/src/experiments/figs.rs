//! Figure experiments (DESIGN.md §4): conditioning diagnostics, probe
//! sweeps, trajectory comparisons, warm-start geometry, budget studies.

use anyhow::Result;

use igp::coordinator::{run_exact, Trainer, TrainerOptions};
use igp::data;
use igp::estimator::{EstimatorKind, ProbeSet};
use igp::gp::ExactGp;
use igp::kernels::Hyperparams;
use igp::linalg::{Cholesky, Mat};
use igp::operators::{DenseOperator, KernelOperator, XlaOperator};
use igp::optim::{Adam, SoftplusParams};
use igp::solvers::{make_solver, SolveOptions, SolverKind};
use igp::util::csv::{CsvWriter, MarkdownTable};
use igp::util::rng::Rng;
use igp::util::stats;

use super::cells::{run_cell, write_telemetry, Cell};
use super::{Ctx, SOLVERS, VARIANTS};

// ---------------------------------------------------------------------------
// Fig 3: initial RKHS distance, tr(H^-1), top eigenvalue, noise precision
// ---------------------------------------------------------------------------

pub fn fig3(ctx: &Ctx) -> Result<()> {
    let dir = ctx.out_dir("fig3");
    let steps = ctx.steps_or(15);
    let mut csv = CsvWriter::create(
        dir.join("fig3.csv"),
        &[
            "dataset", "estimator", "step", "ap_iterations", "init_dist_measured",
            "tr_hinv", "top_eig_hinv", "noise_precision", "expected_dist",
        ],
    )?;
    for dataset in ["pol", "elevators"] {
        let ds = data::generate(&data::spec(dataset)?);
        for estimator in [EstimatorKind::Standard, EstimatorKind::Pathwise] {
            let mut cell = Cell::new(dataset, SolverKind::Ap, estimator, false);
            cell.steps = steps;
            let res = run_cell(&ctx.rt, &ctx.artifacts, &cell)?;
            for t in &res.out.telemetry {
                // exact conditioning diagnostics at this step's theta
                let hp = Hyperparams::unpack(&t.theta, ds.spec.d);
                let gp = ExactGp::fit(&ds.x_train, &ds.y_train, &hp, ds.spec.family)?;
                let (tr, top) = gp.hinv_diagnostics();
                let noise_prec = 1.0 / hp.noise_var();
                let expected = match estimator {
                    EstimatorKind::Standard => tr,           // eq (14)
                    EstimatorKind::Pathwise => ds.spec.n as f64, // eq (15)
                };
                csv.row(&[
                    dataset.to_string(),
                    estimator.name().into(),
                    t.step.to_string(),
                    t.iterations.to_string(),
                    format!("{:.4e}", t.init_residual_sq),
                    format!("{tr:.4e}"),
                    format!("{top:.4e}"),
                    format!("{noise_prec:.4e}"),
                    format!("{expected:.4e}"),
                ])?;
            }
            igp::info!("fig3 {dataset}/{} done", estimator.name());
        }
    }
    csv.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig 4: posterior-sample count sweep + probe-count runtime overhead
// ---------------------------------------------------------------------------

pub fn fig4(ctx: &Ctx) -> Result<()> {
    let dir = ctx.out_dir("fig4");
    let steps = ctx.steps_or(15);
    let mut runtime_csv = CsvWriter::create(
        dir.join("fig4_runtime.csv"),
        &["config", "s", "total_secs", "solver_secs", "llh"],
    )?;
    let mut llh_csv =
        CsvWriter::create(dir.join("fig4_llh_vs_samples.csv"), &["num_samples", "llh", "rmse"])?;

    for (config, s) in [("pol_s4", 4usize), ("pol", 16), ("pol_s64", 64)] {
        let spec = data::spec(config)?;
        let ds = data::generate(&spec);
        let model = ctx.rt.load_config(&ctx.artifacts, config)?;
        let block = model.meta.b;
        let op = XlaOperator::new(model, &ds);
        let opts = TrainerOptions {
            solver: SolverKind::Ap,
            estimator: EstimatorKind::Pathwise,
            warm_start: true,
            block_size: Some(block),
            seed: 4,
            ..Default::default()
        };
        let mut trainer = Trainer::new(opts, Box::new(op), &ds);
        let out = trainer.run(steps)?;
        runtime_csv.row(&[
            config.to_string(),
            s.to_string(),
            format!("{:.3}", out.total_secs),
            format!("{:.3}", out.solver_secs),
            format!("{:.4}", out.final_metrics.llh),
        ])?;
        igp::info!("fig4 {config} (s={s}): total {:.1}s", out.total_secs);

        // sample-count sweep on the biggest config
        if config == "pol_s64" {
            let v = trainer.v_store().clone();
            let probes = trainer.probes();
            let vy = v.col(0);
            let zhat = probes.zhat(&v);
            let (mean, samples) =
                trainer.operator().predict(&vy, &zhat, &probes.omega0, &probes.wts);
            let noise_var = trainer.operator().hp().noise_var();
            let mut k = 1usize;
            while k <= s {
                let var: Vec<f64> = (0..samples.rows)
                    .map(|i| {
                        let row = &samples.row(i)[..k];
                        let mu = row.iter().sum::<f64>() / k as f64;
                        let v = if k > 1 {
                            row.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / (k - 1) as f64
                        } else {
                            0.0
                        };
                        v + noise_var
                    })
                    .collect();
                let m = igp::gp::metrics(&mean, &var, trainer.y_test());
                llh_csv.row(&[k.to_string(), format!("{:.4}", m.llh), format!("{:.4}", m.rmse)])?;
                k *= 2;
            }
        }
    }
    runtime_csv.flush()?;
    llh_csv.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Figs 5, 8, 11-13: hyperparameter trajectories vs exact optimisation
// ---------------------------------------------------------------------------

pub fn traj(ctx: &Ctx) -> Result<()> {
    let dir = ctx.out_dir("traj");
    let steps = ctx.steps_or(12);
    for dataset in ["pol", "elevators"] {
        let ds = data::generate(&data::spec(dataset)?);
        // exact baseline (Cholesky in Rust through the XLA operator's
        // exact path — Figs 5/8 reference)
        let model = ctx.rt.load_config(&ctx.artifacts, dataset)?;
        let mut op = XlaOperator::new(model, &ds);
        let exact = run_exact(&mut op, &ds.y_train, steps, 0.1, 1.0)?;
        let d = ds.spec.d;
        let mut w = CsvWriter::create(
            dir.join(format!("{dataset}_exact.csv")),
            &["step", "mll", "theta"],
        )?;
        for (i, (theta, mll)) in exact.iter().enumerate() {
            w.row(&[i.to_string(), format!("{mll:.5}"), join_theta(theta)])?;
        }
        w.flush()?;

        // iterative variants (per solver, the 4 estimator/warm combos)
        let mut summary = MarkdownTable::new(&[
            "dataset", "solver", "estimator", "warm", "mean |dtheta| vs exact", "max |dtheta|",
        ]);
        for solver in SOLVERS {
            for (estimator, warm) in VARIANTS {
                let mut cell = Cell::new(dataset, solver, estimator, warm);
                cell.steps = steps;
                let res = run_cell(&ctx.rt, &ctx.artifacts, &cell)?;
                let mut w = CsvWriter::create(
                    dir.join(format!(
                        "{dataset}_{}_{}_{}.csv",
                        solver.name(),
                        estimator.name(),
                        if warm { "warm" } else { "cold" }
                    )),
                    &["step", "theta"],
                )?;
                let mut devs = Vec::new();
                for t in &res.out.telemetry {
                    w.row(&[t.step.to_string(), join_theta(&t.theta)])?;
                    let (ex_theta, _) = &exact[t.step];
                    for kk in 0..d + 2 {
                        devs.push((t.theta[kk] - ex_theta[kk]).abs());
                    }
                }
                w.flush()?;
                let mean_dev = stats::mean(&devs);
                let max_dev = devs.iter().cloned().fold(0.0, f64::max);
                summary.row(vec![
                    dataset.to_string(),
                    solver.name().into(),
                    estimator.name().into(),
                    warm.to_string(),
                    format!("{mean_dev:.4}"),
                    format!("{max_dev:.4}"),
                ]);
                igp::info!(
                    "traj {} done: mean|dtheta|={:.4}",
                    res.cell.label(),
                    mean_dev
                );
            }
        }
        summary.write_to(dir.join(format!("{dataset}_summary.md")))?;
        println!("{}", summary.render());
    }
    Ok(())
}

fn join_theta(theta: &[f64]) -> String {
    theta
        .iter()
        .map(|t| format!("{t:.5}"))
        .collect::<Vec<_>>()
        .join(";")
}

// ---------------------------------------------------------------------------
// Fig 6: exact initial RKHS distance to the solution, warm vs cold
// ---------------------------------------------------------------------------

pub fn fig6(ctx: &Ctx) -> Result<()> {
    let dir = ctx.out_dir("fig6");
    let steps = ctx.steps_or(15);
    let dataset = "pol";
    let ds = data::generate(&data::spec(dataset)?);
    let d = ds.spec.d;
    let mut op = DenseOperator::new(&ds, 16, 256);
    let mut rng = Rng::new(6);
    let probes = ProbeSet::sample(EstimatorKind::Pathwise, &op, &mut rng);
    let mut params = SoftplusParams::from_theta(&vec![1.0; d + 2]);
    let mut adam = Adam::new(d + 2, 0.1);
    let mut solver = make_solver(SolverKind::Ap);
    let solve_opts = SolveOptions {
        block_size: 128,
        max_epochs: 100.0,
        ..Default::default()
    };
    let mut v_warm = Mat::zeros(op.n(), op.k_width());

    let mut csv = CsvWriter::create(
        dir.join("fig6.csv"),
        &["step", "rms_dist_warm", "rms_dist_cold", "ratio"],
    )?;
    for step in 0..steps {
        let theta = params.theta();
        op.set_hp(&Hyperparams::unpack(&theta, d));
        let b = probes.targets(&op, &ds.y_train);
        // exact solution and RKHS distances ||v0 - v*||_H
        let ch = Cholesky::factor(op.h())?;
        let v_star = ch.solve_mat(&b);
        let dist = |v0: &Mat| -> f64 {
            let mut diff = v_star.clone();
            diff.sub_assign(v0);
            let hd = op.hv(&diff);
            let per_col = igp::solvers::col_dots(&diff, &hd);
            (per_col.iter().sum::<f64>() / per_col.len() as f64).sqrt()
        };
        let cold = Mat::zeros(op.n(), op.k_width());
        let d_warm = dist(&v_warm);
        let d_cold = dist(&cold);
        csv.row(&[
            step.to_string(),
            format!("{d_warm:.5e}"),
            format!("{d_cold:.5e}"),
            format!("{:.3}", d_cold / d_warm.max(1e-300)),
        ])?;
        // advance the run with a warm-started solve + Adam step
        let report = solver.solve(&op, &b, &mut v_warm, &solve_opts);
        let _ = report;
        let grad = probes.grad(&op, &v_warm, &b);
        let grad_nu = params.chain_grad(&grad);
        adam.step(&mut params.nu, &grad_nu);
    }
    csv.flush()?;
    igp::info!("fig6 done");
    Ok(())
}

// ---------------------------------------------------------------------------
// Figs 7 & 21: iterations to tolerance per outer step
// ---------------------------------------------------------------------------

pub fn fig7(ctx: &Ctx) -> Result<()> {
    let dir = ctx.out_dir("fig7");
    let steps = ctx.steps_or(12);
    let mut csv = CsvWriter::create(
        dir.join("fig7.csv"),
        &["dataset", "solver", "estimator", "warm", "step", "iterations", "epochs", "llh"],
    )?;
    for dataset in ["pol", "elevators"] {
        for solver in SOLVERS {
            for (estimator, warm) in VARIANTS {
                let mut cell = Cell::new(dataset, solver, estimator, warm);
                cell.steps = steps;
                let res = run_cell(&ctx.rt, &ctx.artifacts, &cell)?;
                for t in &res.out.telemetry {
                    csv.row(&[
                        dataset.to_string(),
                        solver.name().into(),
                        estimator.name().into(),
                        warm.to_string(),
                        t.step.to_string(),
                        t.iterations.to_string(),
                        format!("{:.2}", t.epochs),
                        format!("{:.4}", res.out.final_metrics.llh),
                    ])?;
                }
                igp::info!("fig7 {} done", res.cell.label());
            }
        }
    }
    csv.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Figs 9 & 14-17: limited compute budgets on the small suite
// ---------------------------------------------------------------------------

pub fn fig9(ctx: &Ctx) -> Result<()> {
    let dir = ctx.out_dir("fig9");
    let steps = ctx.steps_or(15);
    let budgets: &[f64] = if ctx.full { &[10.0, 20.0, 30.0, 40.0, 50.0] } else { &[10.0, 30.0, 50.0] };
    let datasets: Vec<String> = if ctx.full {
        ctx.small_datasets()
    } else {
        vec!["pol".to_string()]
    };
    let mut md = MarkdownTable::new(&[
        "dataset", "solver", "estimator", "warm", "budget", "final ry", "final rz", "llh",
    ]);
    for dataset in &datasets {
        for solver in SOLVERS {
            for (estimator, warm) in VARIANTS {
                for &budget in budgets {
                    let mut cell = Cell::new(dataset, solver, estimator, warm);
                    cell.steps = steps;
                    cell.max_epochs = Some(budget);
                    let res = run_cell(&ctx.rt, &ctx.artifacts, &cell)?;
                    write_telemetry(
                        &res,
                        &dir.join(format!(
                            "steps_{}_{}_{}_{}_b{}.csv",
                            dataset,
                            solver.name(),
                            estimator.name(),
                            if warm { "warm" } else { "cold" },
                            budget as usize
                        )),
                    )?;
                    let last = res.out.telemetry.last().unwrap();
                    md.row(vec![
                        dataset.clone(),
                        solver.name().into(),
                        estimator.name().into(),
                        warm.to_string(),
                        format!("{budget}"),
                        format!("{:.4}", last.ry),
                        format!("{:.4}", last.rz),
                        format!("{:.4}", res.out.final_metrics.llh),
                    ]);
                    igp::info!(
                        "fig9 {} b={} done: rz={:.4} llh={:.3}",
                        res.cell.label(),
                        budget,
                        last.rz,
                        res.out.final_metrics.llh
                    );
                }
            }
        }
    }
    md.write_to(dir.join("fig9.md"))?;
    println!("{}", md.render());
    Ok(())
}

// ---------------------------------------------------------------------------
// Figs 10 & 18-20: large datasets under a 10-epoch budget, tracked per step
// ---------------------------------------------------------------------------

pub fn fig10(ctx: &Ctx) -> Result<()> {
    let dir = ctx.out_dir("fig10");
    let steps = ctx.steps_or(10);
    let mut md = MarkdownTable::new(&[
        "dataset", "solver", "warm", "first rz", "last rz", "last llh",
    ]);
    for dataset in ctx.large_datasets() {
        for solver in SOLVERS {
            for warm in [false, true] {
                let mut cell = Cell::new(&dataset, solver, EstimatorKind::Pathwise, warm);
                cell.steps = steps;
                cell.lr = 0.03;
                cell.max_epochs = Some(10.0);
                cell.predict_every = Some(2);
                cell.subset_init = true; // paper App. B heuristic
                let res = run_cell(&ctx.rt, &ctx.artifacts, &cell)?;
                write_telemetry(
                    &res,
                    &dir.join(format!(
                        "steps_{}_{}_{}.csv",
                        dataset,
                        solver.name(),
                        if warm { "warm" } else { "cold" }
                    )),
                )?;
                let first = res.out.telemetry.first().unwrap();
                let last = res.out.telemetry.last().unwrap();
                md.row(vec![
                    dataset.clone(),
                    solver.name().into(),
                    warm.to_string(),
                    format!("{:.4}", first.rz),
                    format!("{:.4}", last.rz),
                    format!("{:.4}", res.out.final_metrics.llh),
                ]);
                igp::info!(
                    "fig10 {} done: rz {:.4} -> {:.4}",
                    res.cell.label(),
                    first.rz,
                    last.rz
                );
            }
        }
    }
    md.write_to(dir.join("fig10.md"))?;
    println!("{}", md.render());
    Ok(())
}
