//! Synthetic UCI-like regression datasets.
//!
//! The paper evaluates on nine UCI datasets (n = 13.5k .. 1.84M) which are
//! not available offline; per DESIGN.md §3 we substitute GP-generated
//! datasets that keep each dataset's input dimension and *noise character*
//! (the quantity that drives the paper's conditioning phenomena: the
//! initial RKHS distance of the standard estimator follows the noise
//! precision 1/sigma^2).  Inputs mix uniform and clustered components so
//! kernel matrices are realistically ill-conditioned; targets are drawn
//! from an RFF-approximated GP prior plus i.i.d. noise and standardised.

use crate::kernels::{Hyperparams, KernelFamily};
use crate::linalg::Mat;
use crate::util::rng::Rng;

/// Registry entry describing how to synthesise one named dataset.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// Paper-scale n (documentation only).
    pub paper_n: usize,
    pub n: usize,
    pub n_test: usize,
    pub d: usize,
    /// Ground-truth observation noise scale: drives noise precision at the
    /// optimum, matching each UCI dataset's fitted noise level.
    pub true_sigma: f64,
    /// Ground-truth lengthscale spread (relative to sqrt(d)).
    pub ell_lo: f64,
    pub ell_hi: f64,
    /// Fraction of clustered (vs uniform) inputs: higher -> worse
    /// conditioning (near-duplicate rows).
    pub cluster_frac: f64,
    pub family: KernelFamily,
    pub seed: u64,
}

/// A materialised dataset (standardised inputs and targets).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub spec: DatasetSpec,
    pub x_train: Mat,
    pub y_train: Vec<f64>,
    pub x_test: Mat,
    pub y_test: Vec<f64>,
    /// The generating hyperparameters (for diagnostics only; learners never
    /// see these).
    pub true_hp: Hyperparams,
}

impl Dataset {
    /// Clone of this dataset with a replaced training set (test split and
    /// spec metadata preserved; `spec.n` tracks the new training size).
    /// Used by online-replay experiments to materialise the accumulated
    /// data a cold-restart baseline retrains on.
    pub fn with_train(&self, x_train: Mat, y_train: Vec<f64>) -> Dataset {
        assert_eq!(x_train.rows, y_train.len());
        assert_eq!(x_train.cols, self.spec.d);
        let mut spec = self.spec.clone();
        spec.n = x_train.rows;
        Dataset {
            spec,
            x_train,
            y_train,
            x_test: self.x_test.clone(),
            y_test: self.y_test.clone(),
            true_hp: self.true_hp.clone(),
        }
    }

    /// Split the training set into an initial prefix dataset plus `k - 1`
    /// arrival chunks `(x, y)` for online-replay experiments (the test
    /// split stays with the prefix).  Chunks are `n / k` rows each; the
    /// remainder goes to the prefix so every arrival is the same size.
    pub fn replay_chunks(&self, k: usize) -> (Dataset, Vec<(Mat, Vec<f64>)>) {
        let n = self.x_train.rows;
        assert!(k >= 1 && k <= n, "replay_chunks: k = {k} out of range for n = {n}");
        let per = n / k;
        let base_n = n - per * (k - 1);
        let base = self.with_train(
            self.x_train.gather_rows(&(0..base_n).collect::<Vec<_>>()),
            self.y_train[..base_n].to_vec(),
        );
        let mut chunks = Vec::with_capacity(k - 1);
        for c in 0..k - 1 {
            let lo = base_n + c * per;
            let hi = lo + per;
            chunks.push((
                self.x_train.gather_rows(&(lo..hi).collect::<Vec<_>>()),
                self.y_train[lo..hi].to_vec(),
            ));
        }
        (base, chunks)
    }
}

/// The dataset registry, mirroring the paper's UCI suite.
/// Shapes must match the artifact configs in python/compile/configs.py.
pub fn registry() -> Vec<DatasetSpec> {
    let m32 = KernelFamily::Matern32;
    vec![
        DatasetSpec { name: "test", paper_n: 0, n: 256, n_test: 64, d: 4, true_sigma: 0.3, ell_lo: 0.6, ell_hi: 1.4, cluster_frac: 0.3, family: m32, seed: 101 },
        // small suite (Table 1): noise scale chosen to mimic each dataset's
        // fitted noise level (pol/bike/kegg low noise -> high precision).
        DatasetSpec { name: "pol", paper_n: 13_500, n: 1024, n_test: 256, d: 26, true_sigma: 0.08, ell_lo: 0.8, ell_hi: 1.6, cluster_frac: 0.45, family: m32, seed: 11 },
        DatasetSpec { name: "elevators", paper_n: 14_940, n: 1024, n_test: 256, d: 18, true_sigma: 0.35, ell_lo: 0.7, ell_hi: 1.5, cluster_frac: 0.25, family: m32, seed: 12 },
        DatasetSpec { name: "bike", paper_n: 15_642, n: 1024, n_test: 256, d: 17, true_sigma: 0.05, ell_lo: 0.8, ell_hi: 1.7, cluster_frac: 0.40, family: m32, seed: 13 },
        DatasetSpec { name: "protein", paper_n: 41_157, n: 2048, n_test: 512, d: 9, true_sigma: 0.50, ell_lo: 0.5, ell_hi: 1.2, cluster_frac: 0.20, family: m32, seed: 14 },
        DatasetSpec { name: "keggdir", paper_n: 43_945, n: 2048, n_test: 512, d: 20, true_sigma: 0.10, ell_lo: 0.8, ell_hi: 1.6, cluster_frac: 0.45, family: m32, seed: 15 },
        // large suite (Section 5): budgeted solving
        DatasetSpec { name: "threedroad", paper_n: 391_387, n: 2048, n_test: 512, d: 3, true_sigma: 0.10, ell_lo: 0.3, ell_hi: 0.8, cluster_frac: 0.55, family: m32, seed: 16 },
        DatasetSpec { name: "song", paper_n: 463_811, n: 2048, n_test: 512, d: 24, true_sigma: 0.75, ell_lo: 0.8, ell_hi: 1.6, cluster_frac: 0.15, family: m32, seed: 17 },
        DatasetSpec { name: "buzz", paper_n: 524_925, n: 2048, n_test: 512, d: 32, true_sigma: 0.25, ell_lo: 0.8, ell_hi: 1.6, cluster_frac: 0.35, family: m32, seed: 18 },
        DatasetSpec { name: "houseelectric", paper_n: 1_844_352, n: 4096, n_test: 512, d: 11, true_sigma: 0.05, ell_lo: 0.6, ell_hi: 1.3, cluster_frac: 0.50, family: m32, seed: 19 },
    ]
}

/// Look up a spec by name (also accepts the pol_s* artifact aliases, which
/// share pol's data).
pub fn spec(name: &str) -> anyhow::Result<DatasetSpec> {
    let base = match name {
        "pol_s4" | "pol_s64" => "pol",
        other => other,
    };
    registry()
        .into_iter()
        .find(|s| s.name == base)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{name}'"))
}

/// Generate the dataset deterministically from its spec.
pub fn generate(spec: &DatasetSpec) -> Dataset {
    generate_split(spec, 0)
}

/// Generate one of several i.i.d. splits (the paper reports means over 10
/// splits; `split` perturbs the seed).
pub fn generate_split(spec: &DatasetSpec, split: u64) -> Dataset {
    let mut rng = Rng::new(spec.seed.wrapping_mul(0x9E37_79B9).wrapping_add(split));
    let n_total = spec.n + spec.n_test;
    let d = spec.d;

    // --- inputs: uniform background + gaussian clusters -----------------
    let n_clusters = 8.max(d / 2);
    let centers = Mat::from_fn(n_clusters, d, |_, _| rng.uniform_in(-1.5, 1.5));
    let mut x = Mat::zeros(n_total, d);
    for i in 0..n_total {
        if rng.uniform() < spec.cluster_frac {
            let c = rng.below(n_clusters);
            for j in 0..d {
                x[(i, j)] = centers[(c, j)] + 0.15 * rng.gaussian();
            }
        } else {
            for j in 0..d {
                x[(i, j)] = rng.uniform_in(-2.0, 2.0);
            }
        }
    }
    standardize_cols(&mut x);

    // --- ground-truth hyperparameters ------------------------------------
    let scale = (d as f64).sqrt();
    let ell: Vec<f64> = (0..d)
        .map(|_| scale * rng.uniform_in(spec.ell_lo, spec.ell_hi))
        .collect();
    let true_hp = Hyperparams { ell, sigf: 1.0, sigma: spec.true_sigma };

    // --- targets: RFF prior draw + noise ---------------------------------
    let m = 512; // feature pairs; accuracy is ample for data generation
    let mut f = vec![0.0; n_total];
    let df = spec.family.spectral_t_df();
    // omega ~ spectral density at the true lengthscales
    let mut omega = Mat::zeros(d, m);
    for c in 0..m {
        let t_scale = df.map(|v| rng.student_t_scale(v)).unwrap_or(1.0);
        for r in 0..d {
            omega[(r, c)] = t_scale * rng.gaussian() / true_hp.ell[r];
        }
    }
    let w_cos = rng.gaussian_vec(m);
    let w_sin = rng.gaussian_vec(m);
    let amp = true_hp.sigf * (1.0 / m as f64).sqrt();
    for i in 0..n_total {
        let xi = x.row(i);
        let mut acc = 0.0;
        for c in 0..m {
            let mut z = 0.0;
            for r in 0..d {
                z += xi[r] * omega[(r, c)];
            }
            acc += w_cos[c] * z.cos() + w_sin[c] * z.sin();
        }
        f[i] = amp * acc;
    }
    let mut y: Vec<f64> = f
        .iter()
        .map(|&fi| fi + spec.true_sigma * rng.gaussian())
        .collect();
    standardize_vec(&mut y);

    // --- split ------------------------------------------------------------
    let mut idx: Vec<usize> = (0..n_total).collect();
    rng.shuffle(&mut idx);
    let train_idx = &idx[..spec.n];
    let test_idx = &idx[spec.n..];
    Dataset {
        spec: spec.clone(),
        x_train: x.gather_rows(train_idx),
        y_train: train_idx.iter().map(|&i| y[i]).collect(),
        x_test: x.gather_rows(test_idx),
        y_test: test_idx.iter().map(|&i| y[i]).collect(),
        true_hp,
    }
}

/// In-place column standardisation to zero mean / unit variance.
pub fn standardize_cols(x: &mut Mat) {
    for j in 0..x.cols {
        let col = x.col(j);
        let m = crate::util::stats::mean(&col);
        let sd = crate::util::stats::variance(&col).sqrt().max(1e-12);
        for i in 0..x.rows {
            x[(i, j)] = (x[(i, j)] - m) / sd;
        }
    }
}

/// In-place standardisation of a vector.
pub fn standardize_vec(y: &mut [f64]) {
    let m = crate::util::stats::mean(y);
    let sd = crate::util::stats::variance(y).sqrt().max(1e-12);
    for v in y.iter_mut() {
        *v = (*v - m) / sd;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{mean, variance};

    #[test]
    fn registry_names_unique_and_complete() {
        let regs = registry();
        let mut names: Vec<_> = regs.iter().map(|s| s.name).collect();
        names.sort_unstable();
        let len = names.len();
        names.dedup();
        assert_eq!(names.len(), len);
        for want in ["pol", "elevators", "bike", "protein", "keggdir",
                     "threedroad", "song", "buzz", "houseelectric", "test"] {
            assert!(names.contains(&want), "{want}");
        }
    }

    #[test]
    fn generate_is_deterministic() {
        let s = spec("test").unwrap();
        let a = generate(&s);
        let b = generate(&s);
        assert_eq!(a.y_train, b.y_train);
        assert_eq!(a.x_train.data, b.x_train.data);
    }

    #[test]
    fn splits_differ() {
        let s = spec("test").unwrap();
        let a = generate_split(&s, 0);
        let b = generate_split(&s, 1);
        assert_ne!(a.y_train, b.y_train);
    }

    #[test]
    fn shapes_match_spec() {
        let s = spec("test").unwrap();
        let ds = generate(&s);
        assert_eq!(ds.x_train.rows, s.n);
        assert_eq!(ds.x_train.cols, s.d);
        assert_eq!(ds.y_train.len(), s.n);
        assert_eq!(ds.x_test.rows, s.n_test);
        assert_eq!(ds.y_test.len(), s.n_test);
    }

    #[test]
    fn targets_standardised() {
        let s = spec("test").unwrap();
        let ds = generate(&s);
        let mut all = ds.y_train.clone();
        all.extend_from_slice(&ds.y_test);
        assert!(mean(&all).abs() < 0.05);
        assert!((variance(&all) - 1.0).abs() < 0.1);
    }

    #[test]
    fn inputs_standardised() {
        let s = spec("pol").unwrap();
        let ds = generate(&s);
        for j in 0..3 {
            let col = ds.x_train.col(j);
            assert!(mean(&col).abs() < 0.15);
            let v = variance(&col);
            assert!((0.5..1.6).contains(&v), "col {j} var {v}");
        }
    }

    #[test]
    fn replay_chunks_cover_the_training_set_in_order() {
        let s = spec("test").unwrap();
        let ds = generate(&s);
        for k in [1, 2, 3, 5] {
            let (base, chunks) = ds.replay_chunks(k);
            assert_eq!(chunks.len(), k - 1);
            assert_eq!(base.spec.n, base.x_train.rows);
            let mut x = base.x_train.clone();
            let mut y = base.y_train.clone();
            for (cx, cy) in &chunks {
                assert_eq!(cx.rows, ds.spec.n / k, "chunks are even");
                x.append_rows(cx);
                y.extend_from_slice(cy);
            }
            assert_eq!(x.data, ds.x_train.data, "k={k}: inputs replayed in order");
            assert_eq!(y, ds.y_train, "k={k}: targets replayed in order");
            assert_eq!(base.x_test.data, ds.x_test.data);
        }
    }

    #[test]
    fn alias_resolves_to_pol() {
        assert_eq!(spec("pol_s64").unwrap().name, "pol");
        assert!(spec("nope").is_err());
    }

    #[test]
    fn noise_character_ordering() {
        // pol must be much lower-noise than protein (drives Fig 3).
        let pol = spec("pol").unwrap();
        let protein = spec("protein").unwrap();
        assert!(pol.true_sigma < protein.true_sigma / 3.0);
    }
}
