//! meta.txt parsing: the shape contract written by python/compile/aot.py.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::kernels::KernelFamily;

/// Static shapes of one artifact config (must match configs.py).
#[derive(Clone, Debug, PartialEq)]
pub struct Meta {
    pub name: String,
    pub n: usize,
    pub n_test: usize,
    pub d: usize,
    pub s: usize,
    pub m: usize,
    pub b: usize,
    pub tile: usize,
    pub kernel: KernelFamily,
    pub exact: bool,
}

impl Meta {
    pub fn parse(text: &str) -> Result<Meta> {
        let mut kv = std::collections::HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("bad meta line: '{line}'");
            };
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        let get = |k: &str| -> Result<String> {
            kv.get(k)
                .cloned()
                .ok_or_else(|| anyhow::anyhow!("meta missing key '{k}'"))
        };
        let get_usize =
            |k: &str| -> Result<usize> { Ok(get(k)?.parse().context(k.to_string())?) };
        Ok(Meta {
            name: get("name")?,
            n: get_usize("n")?,
            n_test: get_usize("n_test")?,
            d: get_usize("d")?,
            s: get_usize("s")?,
            m: get_usize("m")?,
            b: get_usize("b")?,
            tile: get_usize("tile")?,
            kernel: KernelFamily::parse(&get("kernel")?)?,
            exact: get("exact")? == "true",
        })
    }

    pub fn load(path: &Path) -> Result<Meta> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Meta::parse(&text)
    }

    /// Solver batch width.
    pub fn k(&self) -> usize {
        self.s + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "name=test\nn=256\nn_test=64\nd=4\ns=8\nm=64\nb=64\ntile=64\nkernel=matern32\nexact=true\n";

    #[test]
    fn parse_sample() {
        let m = Meta::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "test");
        assert_eq!(m.n, 256);
        assert_eq!(m.s, 8);
        assert_eq!(m.k(), 9);
        assert_eq!(m.kernel, KernelFamily::Matern32);
        assert!(m.exact);
    }

    #[test]
    fn missing_key_fails() {
        assert!(Meta::parse("name=x\nn=1\n").is_err());
    }

    #[test]
    fn bad_kernel_fails() {
        let bad = SAMPLE.replace("matern32", "cubic");
        assert!(Meta::parse(&bad).is_err());
    }
}
