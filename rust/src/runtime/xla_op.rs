//! [`XlaOperator`]: the production [`KernelOperator`] backend executing the
//! AOT artifacts through PJRT.  The dataset inputs (X, X_test) and the
//! current theta live as *device buffers* (uploaded once per `set_hp`);
//! per-call traffic is the solver state (O(n k) doubles) uploaded through
//! caller-managed `PjRtBuffer`s — the literal-argument `execute` path of
//! this xla_extension build leaks its argument buffers (see Model::call_b).
//!
//! Gated behind the `xla` cargo feature (the `xla` crate is unavailable
//! offline).  Without the feature a stub with the same API compiles; it can
//! never be reached at run time because `Runtime::load_config` (the only
//! source of a `Model`) fails first.

#[cfg(feature = "xla")]
pub use real::XlaOperator;
#[cfg(not(feature = "xla"))]
pub use stub::XlaOperator;

#[cfg(feature = "xla")]
mod real {
    use crate::data::Dataset;
    use crate::kernels::{Hyperparams, KernelFamily};
    use crate::linalg::Mat;
    use crate::operators::KernelOperator;
    use crate::runtime::{mat_from_lit, vec_from_lit, Model};

    pub struct XlaOperator {
        model: Model,
        x: Mat,
        x_test: Mat,
        hp: Hyperparams,
        family: KernelFamily,
        x_buf: xla::PjRtBuffer,
        xt_buf: xla::PjRtBuffer,
        theta_buf: xla::PjRtBuffer,
    }

    impl XlaOperator {
        /// Build from a compiled model and the dataset it was shaped for.
        pub fn new(model: Model, ds: &Dataset) -> Self {
            let meta = &model.meta;
            assert_eq!(meta.n, ds.x_train.rows, "dataset/config n mismatch");
            assert_eq!(meta.d, ds.x_train.cols, "dataset/config d mismatch");
            assert_eq!(meta.n_test, ds.x_test.rows, "dataset/config n_test mismatch");
            let hp = Hyperparams::ones(meta.d);
            let x_buf = model.buf_mat(&ds.x_train).expect("x buffer");
            let xt_buf = model.buf_mat(&ds.x_test).expect("x_test buffer");
            let theta_buf = model.buf_vec(&hp.pack()).expect("theta buffer");
            let family = meta.kernel;
            XlaOperator {
                model,
                x: ds.x_train.clone(),
                x_test: ds.x_test.clone(),
                hp,
                family,
                x_buf,
                xt_buf,
                theta_buf,
            }
        }

        pub fn meta(&self) -> &crate::runtime::Meta {
            &self.model.meta
        }

        /// Pure-jnp (non-Pallas) full MVM — perf-ablation path.
        pub fn hv_ref(&self, v: &Mat) -> Mat {
            let v_buf = self.model.buf_mat(v).expect("v buffer");
            let out = self
                .model
                .call_b("kmv_full_ref", &[&self.x_buf, &v_buf, &self.theta_buf])
                .expect("kmv_full_ref");
            mat_from_lit(&out[0], v.rows, v.cols).expect("kmv_full_ref output")
        }
    }

    impl KernelOperator for XlaOperator {
        fn n(&self) -> usize {
            self.model.meta.n
        }
        fn d(&self) -> usize {
            self.model.meta.d
        }
        fn s(&self) -> usize {
            self.model.meta.s
        }
        fn m(&self) -> usize {
            self.model.meta.m
        }
        fn family(&self) -> KernelFamily {
            self.family
        }
        fn x(&self) -> &Mat {
            &self.x
        }
        fn x_test(&self) -> &Mat {
            &self.x_test
        }
        fn hp(&self) -> &Hyperparams {
            &self.hp
        }

        fn set_hp(&mut self, hp: &Hyperparams) {
            assert_eq!(hp.ell.len(), self.d());
            self.hp = hp.clone();
            self.theta_buf = self.model.buf_vec(&hp.pack()).expect("theta buffer");
        }

        fn hv(&self, v: &Mat) -> Mat {
            assert_eq!((v.rows, v.cols), (self.n(), self.k_width()));
            let v_buf = self.model.buf_mat(v).expect("v buffer");
            let out = self
                .model
                .call_b("kmv_full", &[&self.x_buf, &v_buf, &self.theta_buf])
                .expect("kmv_full");
            mat_from_lit(&out[0], v.rows, v.cols).expect("kmv_full output")
        }

        fn k_cols(&self, idx: &[usize], u: &Mat) -> Mat {
            assert_eq!(idx.len(), self.model.meta.b, "AP block size fixed by artifact");
            assert_eq!((u.rows, u.cols), (idx.len(), self.k_width()));
            let xb_buf = self.model.buf_mat(&self.x.gather_rows(idx)).expect("xb buffer");
            let u_buf = self.model.buf_mat(u).expect("u buffer");
            let out = self
                .model
                .call_b("kmv_cols", &[&self.x_buf, &xb_buf, &u_buf, &self.theta_buf])
                .expect("kmv_cols");
            mat_from_lit(&out[0], self.n(), self.k_width()).expect("kmv_cols output")
        }

        fn k_rows(&self, idx: &[usize], v: &Mat) -> Mat {
            assert_eq!(idx.len(), self.model.meta.b, "SGD batch size fixed by artifact");
            assert_eq!((v.rows, v.cols), (self.n(), self.k_width()));
            let xa_buf = self.model.buf_mat(&self.x.gather_rows(idx)).expect("xa buffer");
            let v_buf = self.model.buf_mat(v).expect("v buffer");
            let out = self
                .model
                .call_b("kmv_rows", &[&xa_buf, &self.x_buf, &v_buf, &self.theta_buf])
                .expect("kmv_rows");
            mat_from_lit(&out[0], idx.len(), self.k_width()).expect("kmv_rows output")
        }

        fn grad_quad(&self, a: &Mat, b: &Mat, w: &[f64]) -> Vec<f64> {
            assert_eq!((a.rows, a.cols), (self.n(), self.k_width()));
            assert_eq!((b.rows, b.cols), (self.n(), self.k_width()));
            assert_eq!(w.len(), self.k_width());
            let a_buf = self.model.buf_mat(a).expect("a buffer");
            let b_buf = self.model.buf_mat(b).expect("b buffer");
            let w_buf = self.model.buf_vec(w).expect("w buffer");
            let out = self
                .model
                .call_b("grad_quad", &[&self.x_buf, &a_buf, &b_buf, &w_buf, &self.theta_buf])
                .expect("grad_quad");
            vec_from_lit(&out[0]).expect("grad_quad output")
        }

        fn rff_eval(&self, omega0: &Mat, wts: &Mat, noise: &Mat) -> Mat {
            let meta = &self.model.meta;
            assert_eq!((omega0.rows, omega0.cols), (meta.d, meta.m));
            assert_eq!((wts.rows, wts.cols), (2 * meta.m, meta.s));
            assert_eq!((noise.rows, noise.cols), (meta.n, meta.s));
            let om_buf = self.model.buf_mat(omega0).expect("omega0 buffer");
            let w_buf = self.model.buf_mat(wts).expect("wts buffer");
            let n_buf = self.model.buf_mat(noise).expect("noise buffer");
            let out = self
                .model
                .call_b("rff_eval", &[&self.x_buf, &om_buf, &w_buf, &n_buf, &self.theta_buf])
                .expect("rff_eval");
            mat_from_lit(&out[0], meta.n, meta.s).expect("rff_eval output")
        }

        fn predict(&self, vy: &[f64], zhat: &Mat, omega0: &Mat, wts: &Mat) -> (Vec<f64>, Mat) {
            let meta = &self.model.meta;
            assert_eq!(vy.len(), meta.n);
            assert_eq!((zhat.rows, zhat.cols), (meta.n, meta.s));
            let vy_buf = self.model.buf_vec(vy).expect("vy buffer");
            let zh_buf = self.model.buf_mat(zhat).expect("zhat buffer");
            let om_buf = self.model.buf_mat(omega0).expect("omega0 buffer");
            let w_buf = self.model.buf_mat(wts).expect("wts buffer");
            let out = self
                .model
                .call_b(
                    "predict",
                    &[&self.xt_buf, &self.x_buf, &self.theta_buf, &vy_buf, &zh_buf, &om_buf, &w_buf],
                )
                .expect("predict");
            let mean = vec_from_lit(&out[0]).expect("predict mean");
            let samples = mat_from_lit(&out[1], meta.n_test, meta.s).expect("predict samples");
            (mean, samples)
        }

        fn exact_mll(&self, y: &[f64]) -> Option<(f64, Vec<f64>)> {
            // The Cholesky-based exact path cannot run through PJRT here
            // (jnp.linalg.cholesky lowers to a typed-FFI LAPACK custom-call
            // that xla_extension 0.5.1 rejects), so it runs in Rust.  Gated
            // by the config's `exact` flag: O(n^3) is only sane on small
            // configs.
            if !self.model.meta.exact {
                return None;
            }
            let gp = crate::gp::ExactGp::fit(&self.x, y, &self.hp, self.family).ok()?;
            Some((gp.mll(y), gp.mll_grad()))
        }
    }
}

#[cfg(not(feature = "xla"))]
mod stub {
    use crate::data::Dataset;
    use crate::kernels::{Hyperparams, KernelFamily};
    use crate::linalg::Mat;
    use crate::operators::KernelOperator;
    use crate::runtime::Model;

    /// API-compatible stand-in compiled when the `xla` feature is off.
    /// Unreachable at run time: the only source of a [`Model`] is
    /// `Runtime::load_config`, which always fails in stub builds.
    pub struct XlaOperator {
        model: Model,
        x: Mat,
        x_test: Mat,
        hp: Hyperparams,
        family: KernelFamily,
    }

    impl XlaOperator {
        pub fn new(model: Model, ds: &Dataset) -> Self {
            let meta = &model.meta;
            assert_eq!(meta.n, ds.x_train.rows, "dataset/config n mismatch");
            assert_eq!(meta.d, ds.x_train.cols, "dataset/config d mismatch");
            let hp = Hyperparams::ones(meta.d);
            let family = meta.kernel;
            XlaOperator {
                model,
                x: ds.x_train.clone(),
                x_test: ds.x_test.clone(),
                hp,
                family,
            }
        }

        pub fn meta(&self) -> &crate::runtime::Meta {
            &self.model.meta
        }

        pub fn hv_ref(&self, _v: &Mat) -> Mat {
            self.unavailable()
        }

        fn unavailable(&self) -> ! {
            panic!("XlaOperator compute path requires the `xla` cargo feature")
        }
    }

    impl KernelOperator for XlaOperator {
        fn n(&self) -> usize {
            self.model.meta.n
        }
        fn d(&self) -> usize {
            self.model.meta.d
        }
        fn s(&self) -> usize {
            self.model.meta.s
        }
        fn m(&self) -> usize {
            self.model.meta.m
        }
        fn family(&self) -> KernelFamily {
            self.family
        }
        fn x(&self) -> &Mat {
            &self.x
        }
        fn x_test(&self) -> &Mat {
            &self.x_test
        }
        fn hp(&self) -> &Hyperparams {
            &self.hp
        }

        fn set_hp(&mut self, hp: &Hyperparams) {
            assert_eq!(hp.ell.len(), self.d());
            self.hp = hp.clone();
        }

        fn hv(&self, _v: &Mat) -> Mat {
            self.unavailable()
        }

        fn k_cols(&self, _idx: &[usize], _u: &Mat) -> Mat {
            self.unavailable()
        }

        fn k_rows(&self, _idx: &[usize], _v: &Mat) -> Mat {
            self.unavailable()
        }

        fn grad_quad(&self, _a: &Mat, _b: &Mat, _w: &[f64]) -> Vec<f64> {
            self.unavailable()
        }

        fn rff_eval(&self, _omega0: &Mat, _wts: &Mat, _noise: &Mat) -> Mat {
            self.unavailable()
        }

        fn predict(&self, _vy: &[f64], _zhat: &Mat, _omega0: &Mat, _wts: &Mat) -> (Vec<f64>, Mat) {
            self.unavailable()
        }
    }
}
