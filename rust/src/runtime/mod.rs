//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the CPU PJRT client and
//! exposes typed entry points to the coordinator.
//!
//! Interchange is HLO *text* — the xla_extension 0.5.1 backing the `xla`
//! crate rejects jax>=0.5 serialized protos (64-bit instruction ids); the
//! text parser reassigns ids (see /opt/xla-example/README.md).

pub mod artifacts;
pub mod xla_op;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::linalg::Mat;
pub use artifacts::Meta;

/// Owner of the PJRT client; create one per process.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile every artifact of one config directory.
    pub fn load_config(&self, artifacts_dir: &str, name: &str) -> Result<Model> {
        let dir = PathBuf::from(artifacts_dir).join(name);
        let meta = artifacts::Meta::load(&dir.join("meta.txt"))
            .with_context(|| format!("loading meta for config '{name}'"))?;
        let mut exes = HashMap::new();
        for entry in std::fs::read_dir(&dir)? {
            let path = entry?.path();
            let fname = path.file_name().unwrap().to_string_lossy().to_string();
            let Some(fn_name) = fname.strip_suffix(".hlo.txt") else {
                continue;
            };
            let exe = self
                .compile_hlo_file(&path)
                .with_context(|| format!("compiling {}", path.display()))?;
            exes.insert(fn_name.to_string(), exe);
        }
        anyhow::ensure!(
            exes.contains_key("kmv_full"),
            "config '{name}' is missing kmv_full — run `make artifacts`"
        );
        Ok(Model { meta, exes, client: self.client.clone() })
    }

    pub fn compile_hlo_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }
}

/// One compiled config: the set of PJRT executables plus its shapes.
pub struct Model {
    pub meta: Meta,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    client: xla::PjRtClient,
}

impl Model {
    pub fn has(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    /// Execute an entry point against caller-managed device buffers and
    /// return the root tuple elements as Literals.
    ///
    /// IMPORTANT: the buffer-based path (`execute_b`) is the only correct
    /// one with this xla_extension build — `execute` (literal args) leaks
    /// its internally-created argument buffers (~arg bytes per call, which
    /// OOMs a long training run).  `PjRtBuffer` has a proper Drop, so
    /// caller-managed buffers are freed deterministically.
    pub fn call_b(&self, name: &str, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no artifact '{name}' in config '{}'", self.meta.name))?;
        let out = exe.execute_b::<&xla::PjRtBuffer>(args)?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Upload a matrix to the device (row-major f64).
    pub fn buf_mat(&self, m: &Mat) -> Result<xla::PjRtBuffer> {
        Ok(self
            .client
            .buffer_from_host_buffer::<f64>(&m.data, &[m.rows, m.cols], None)?)
    }

    /// Upload a vector to the device.
    pub fn buf_vec(&self, v: &[f64]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<f64>(v, &[v.len()], None)?)
    }
}

// ---------------------------------------------------------------------------
// Literal <-> Mat/Vec conversion helpers
// ---------------------------------------------------------------------------

pub fn mat_to_lit(m: &Mat) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(&m.data).reshape(&[m.rows as i64, m.cols as i64])?)
}

pub fn vec_to_lit(v: &[f64]) -> xla::Literal {
    xla::Literal::vec1(v)
}

pub fn scalar_from_lit(l: &xla::Literal) -> Result<f64> {
    Ok(l.to_vec::<f64>()?[0])
}

pub fn vec_from_lit(l: &xla::Literal) -> Result<Vec<f64>> {
    Ok(l.to_vec::<f64>()?)
}

pub fn mat_from_lit(l: &xla::Literal, rows: usize, cols: usize) -> Result<Mat> {
    let data = l.to_vec::<f64>()?;
    anyhow::ensure!(
        data.len() == rows * cols,
        "literal has {} elements, expected {}x{}",
        data.len(),
        rows,
        cols
    );
    Ok(Mat::from_vec(rows, cols, data))
}
