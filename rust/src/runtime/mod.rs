//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the CPU PJRT client and
//! exposes typed entry points to the coordinator.
//!
//! Interchange is HLO *text* — the xla_extension 0.5.1 backing the `xla`
//! crate rejects jax>=0.5 serialized protos (64-bit instruction ids); the
//! text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The external `xla` crate is not available in offline builds, so the
//! whole PJRT path is gated behind the `xla` cargo feature.  Without it, a
//! stub [`Runtime`] compiles whose `load_config` fails gracefully at run
//! time — callers (CLI, experiments, benches, integration tests) already
//! skip or error out when artifacts are unavailable, and the pure-Rust
//! `dense` / `tiled` backends (see [`crate::operators`]) cover every
//! workload without artifacts.

pub mod artifacts;
pub mod xla_op;

pub use artifacts::Meta;

#[cfg(feature = "xla")]
mod pjrt {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    use anyhow::{Context, Result};

    use super::artifacts;
    use super::Meta;
    use crate::linalg::Mat;

    /// Owner of the PJRT client; create one per process.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load and compile every artifact of one config directory.
        pub fn load_config(&self, artifacts_dir: &str, name: &str) -> Result<Model> {
            let dir = PathBuf::from(artifacts_dir).join(name);
            let meta = artifacts::Meta::load(&dir.join("meta.txt"))
                .with_context(|| format!("loading meta for config '{name}'"))?;
            let mut exes = HashMap::new();
            for entry in std::fs::read_dir(&dir)? {
                let path = entry?.path();
                let fname = path.file_name().unwrap().to_string_lossy().to_string();
                let Some(fn_name) = fname.strip_suffix(".hlo.txt") else {
                    continue;
                };
                let exe = self
                    .compile_hlo_file(&path)
                    .with_context(|| format!("compiling {}", path.display()))?;
                exes.insert(fn_name.to_string(), exe);
            }
            anyhow::ensure!(
                exes.contains_key("kmv_full"),
                "config '{name}' is missing kmv_full — run `make artifacts`"
            );
            Ok(Model { meta, exes, client: self.client.clone() })
        }

        pub fn compile_hlo_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(self.client.compile(&comp)?)
        }
    }

    /// One compiled config: the set of PJRT executables plus its shapes.
    pub struct Model {
        pub meta: Meta,
        exes: HashMap<String, xla::PjRtLoadedExecutable>,
        client: xla::PjRtClient,
    }

    impl Model {
        pub fn has(&self, name: &str) -> bool {
            self.exes.contains_key(name)
        }

        /// Execute an entry point against caller-managed device buffers and
        /// return the root tuple elements as Literals.
        ///
        /// IMPORTANT: the buffer-based path (`execute_b`) is the only
        /// correct one with this xla_extension build — `execute` (literal
        /// args) leaks its internally-created argument buffers (~arg bytes
        /// per call, which OOMs a long training run).  `PjRtBuffer` has a
        /// proper Drop, so caller-managed buffers are freed
        /// deterministically.
        pub fn call_b(&self, name: &str, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
            let exe = self.exes.get(name).ok_or_else(|| {
                anyhow::anyhow!("no artifact '{name}' in config '{}'", self.meta.name)
            })?;
            let out = exe.execute_b::<&xla::PjRtBuffer>(args)?;
            let lit = out[0][0].to_literal_sync()?;
            Ok(lit.to_tuple()?)
        }

        /// Upload a matrix to the device (row-major f64).
        pub fn buf_mat(&self, m: &Mat) -> Result<xla::PjRtBuffer> {
            Ok(self
                .client
                .buffer_from_host_buffer::<f64>(&m.data, &[m.rows, m.cols], None)?)
        }

        /// Upload a vector to the device.
        pub fn buf_vec(&self, v: &[f64]) -> Result<xla::PjRtBuffer> {
            Ok(self.client.buffer_from_host_buffer::<f64>(v, &[v.len()], None)?)
        }
    }

    pub use xla::Literal;

    pub fn mat_to_lit(m: &Mat) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(&m.data).reshape(&[m.rows as i64, m.cols as i64])?)
    }

    pub fn vec_to_lit(v: &[f64]) -> xla::Literal {
        xla::Literal::vec1(v)
    }

    pub fn scalar_from_lit(l: &xla::Literal) -> Result<f64> {
        Ok(l.to_vec::<f64>()?[0])
    }

    pub fn vec_from_lit(l: &xla::Literal) -> Result<Vec<f64>> {
        Ok(l.to_vec::<f64>()?)
    }

    pub fn mat_from_lit(l: &xla::Literal, rows: usize, cols: usize) -> Result<Mat> {
        let data = l.to_vec::<f64>()?;
        anyhow::ensure!(
            data.len() == rows * cols,
            "literal has {} elements, expected {}x{}",
            data.len(),
            rows,
            cols
        );
        Ok(Mat::from_vec(rows, cols, data))
    }
}

#[cfg(not(feature = "xla"))]
mod pjrt {
    use anyhow::Result;

    use super::Meta;
    use crate::linalg::Mat;

    /// Stub runtime compiled when the `xla` feature is disabled.  Creation
    /// succeeds (so callers can print the platform) but loading artifacts
    /// fails with a clear message; use the `dense`/`tiled` backends instead.
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        pub fn cpu() -> Result<Self> {
            Ok(Runtime { _private: () })
        }

        pub fn platform(&self) -> String {
            "stub (built without the `xla` feature)".to_string()
        }

        pub fn load_config(&self, _artifacts_dir: &str, name: &str) -> Result<Model> {
            anyhow::bail!(
                "cannot load artifact config '{name}': this binary was built without the \
                 `xla` feature — use `--backend tiled` (or `dense`) instead"
            )
        }
    }

    /// Stub model: never constructed (load_config always fails), but the
    /// type keeps downstream code compiling unchanged.
    pub struct Model {
        pub meta: Meta,
    }

    impl Model {
        pub fn has(&self, _name: &str) -> bool {
            false
        }
    }

    /// Host-side literal stand-in so conversion helpers keep their
    /// signatures (and the runtime-overhead bench keeps measuring the
    /// host-side copy cost).
    #[derive(Clone, Debug)]
    pub struct Literal {
        data: Vec<f64>,
    }

    pub fn mat_to_lit(m: &Mat) -> Result<Literal> {
        Ok(Literal { data: m.data.clone() })
    }

    pub fn vec_to_lit(v: &[f64]) -> Literal {
        Literal { data: v.to_vec() }
    }

    pub fn scalar_from_lit(l: &Literal) -> Result<f64> {
        anyhow::ensure!(!l.data.is_empty(), "empty literal");
        Ok(l.data[0])
    }

    pub fn vec_from_lit(l: &Literal) -> Result<Vec<f64>> {
        Ok(l.data.clone())
    }

    pub fn mat_from_lit(l: &Literal, rows: usize, cols: usize) -> Result<Mat> {
        anyhow::ensure!(
            l.data.len() == rows * cols,
            "literal has {} elements, expected {}x{}",
            l.data.len(),
            rows,
            cols
        );
        Ok(Mat::from_vec(rows, cols, l.data.clone()))
    }
}

pub use pjrt::{mat_from_lit, mat_to_lit, scalar_from_lit, vec_from_lit, vec_to_lit};
pub use pjrt::{Literal, Model, Runtime};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn lit_roundtrip_mat() {
        let m = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = mat_to_lit(&m).unwrap();
        let back = mat_from_lit(&lit, 2, 3).unwrap();
        assert_eq!(m, back);
        assert!(mat_from_lit(&lit, 3, 3).is_err());
    }

    #[test]
    fn lit_roundtrip_vec_and_scalar() {
        let v = vec![7.5, -1.0];
        let lit = vec_to_lit(&v);
        assert_eq!(vec_from_lit(&lit).unwrap(), v);
        assert_eq!(scalar_from_lit(&lit).unwrap(), 7.5);
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_runtime_fails_gracefully() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.platform().contains("stub"));
        let err = rt.load_config("artifacts", "test").unwrap_err();
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
