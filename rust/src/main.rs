//! `igp` — leader binary: train iterative GPs, run the paper's experiment
//! suite, inspect configs.  See README.md for the full CLI reference.

use anyhow::Result;

use igp::config::RunConfig;
use igp::coordinator::{Trainer, TrainerOptions};
use igp::estimator::EstimatorKind;
use igp::operators::{BackendKind, KernelOperator, TiledOptions, XlaOperator};
use igp::solvers::SolverKind;
use igp::util::logging;

mod cli;
mod experiments;

fn main() {
    logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    match cmd.as_str() {
        "train" => cmd_train(&args[1..]),
        "exp" => experiments::dispatch(&args[1..]),
        "list-datasets" => {
            for s in igp::data::registry() {
                println!(
                    "{:<16} n={:<6} d={:<3} sigma={:<5} (paper n={})",
                    s.name, s.n, s.d, s.true_sigma, s.paper_n
                );
            }
            Ok(())
        }
        "info" => cmd_info(&args[1..]),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}' (try `igp help`)"),
    }
}

fn print_help() {
    println!(
        r#"igp — iterative Gaussian processes (NeurIPS 2024 reproduction)

USAGE:
    igp train [--config FILE] [--dataset D] [--solver cg|ap|sgd]
              [--estimator standard|pathwise] [--warm-start]
              [--backend dense|tiled|xla] [--tile N] [--threads N]
              [--probes S] [--rff M]
              [--steps N] [--lr F] [--max-epochs N] [--seed N]
              [--artifacts DIR] [--out results.csv]
    igp exp <id|all> [--out DIR] [--splits N] [--steps N]
              ids: table1 table7 fig1 fig3 fig4 fig5 fig6 fig7 fig9 fig10
    igp list-datasets
    igp info <config>        # print an artifact config's meta

BACKENDS:
    tiled  (default) matrix-free multi-threaded CPU backend, O(n*d) memory;
           knobs: --tile (block edge, default 256), --threads (0 = auto)
    dense  pure-Rust oracle materialising H, O(n^2) memory (tiny n only)
    xla    compiled PJRT artifacts (needs `make artifacts` + xla feature)
"#
    );
}

fn cmd_info(args: &[String]) -> Result<()> {
    let p = cli::Parser::new(args, &["artifacts"])?;
    let name = p.positional.first().map(String::as_str).unwrap_or("test");
    let dir = p.get("artifacts").unwrap_or("artifacts");
    let meta = igp::runtime::Meta::load(std::path::Path::new(dir).join(name).join("meta.txt").as_path())?;
    println!("{meta:#?}");
    Ok(())
}

fn cmd_train(args: &[String]) -> Result<()> {
    let p = cli::Parser::new(
        args,
        &[
            "config", "dataset", "solver", "estimator", "steps", "lr", "max-epochs",
            "seed", "artifacts", "out", "tolerance", "backend", "tile", "threads",
            "probes", "rff",
        ],
    )?;
    let mut rc = match p.get("config") {
        Some(path) => RunConfig::from_doc(&igp::config::parse_file(path)?)?,
        None => RunConfig::default(),
    };
    if let Some(v) = p.get("dataset") {
        rc.dataset = v.to_string();
    }
    if let Some(v) = p.get("solver") {
        rc.solver = v.to_string();
    }
    if let Some(v) = p.get("estimator") {
        rc.estimator = v.to_string();
    }
    if p.flag("warm-start") {
        rc.warm_start = true;
    }
    if let Some(v) = p.get_parsed::<usize>("steps")? {
        rc.outer_steps = v;
    }
    if let Some(v) = p.get_parsed::<f64>("lr")? {
        rc.lr = v;
    }
    if let Some(v) = p.get_parsed::<f64>("tolerance")? {
        rc.tolerance = v;
    }
    if let Some(v) = p.get_parsed::<usize>("max-epochs")? {
        rc.max_epochs = Some(v);
    }
    if let Some(v) = p.get_parsed::<u64>("seed")? {
        rc.seed = v;
    }
    if let Some(v) = p.get("artifacts") {
        rc.artifacts_dir = v.to_string();
    }
    if let Some(v) = p.get("backend") {
        rc.backend = v.to_string();
    }
    if let Some(v) = p.get_parsed::<usize>("tile")? {
        rc.tile = v;
    }
    if let Some(v) = p.get_parsed::<usize>("threads")? {
        rc.threads = v;
    }
    if let Some(v) = p.get_parsed::<usize>("probes")? {
        rc.probes = v;
    }
    if let Some(v) = p.get_parsed::<usize>("rff")? {
        rc.rff = v;
    }
    rc.validate()?;

    let ds = igp::data::generate(&igp::data::spec(&rc.dataset)?);
    let backend = BackendKind::parse(&rc.backend)?;
    let (op, block): (Box<dyn KernelOperator>, Option<usize>) = match backend {
        BackendKind::Xla => {
            let rt = igp::runtime::Runtime::cpu()?;
            igp::info!("PJRT platform: {}", rt.platform());
            let model = rt.load_config(&rc.artifacts_dir, &rc.dataset)?;
            let b = model.meta.b;
            (Box::new(XlaOperator::new(model, &ds)), Some(b))
        }
        kind => {
            let topts = TiledOptions { tile: rc.tile, threads: rc.threads };
            (
                igp::operators::make_cpu_backend(kind, &ds, rc.probes, rc.rff, topts)?,
                None,
            )
        }
    };
    igp::info!("backend: {}", backend.name());
    let opts = TrainerOptions {
        solver: SolverKind::parse(&rc.solver)?,
        estimator: EstimatorKind::parse(&rc.estimator)?,
        warm_start: rc.warm_start,
        lr: rc.lr,
        tolerance: rc.tolerance,
        max_epochs: rc.max_epochs.map(|e| e as f64),
        block_size: block,
        seed: rc.seed,
        predict_every: Some(10),
        threads: rc.threads,
        ..Default::default()
    };
    let mut trainer = Trainer::new(opts, op, &ds);
    let out = trainer.run(rc.outer_steps)?;

    println!(
        "dataset={} solver={} estimator={} warm={} backend={} steps={}",
        rc.dataset, rc.solver, rc.estimator, rc.warm_start, rc.backend, rc.outer_steps
    );
    println!(
        "total {:.2}s (solver {:.2}s, {:.1} epochs) final rmse={:.4} llh={:.4}",
        out.total_secs,
        out.solver_secs,
        out.total_epochs,
        out.final_metrics.rmse,
        out.final_metrics.llh
    );

    if let Some(path) = p.get("out") {
        let mut w = igp::util::csv::CsvWriter::create(
            path,
            &["step", "ry", "rz", "iterations", "epochs", "solver_secs", "rmse", "llh"],
        )?;
        for t in &out.telemetry {
            let (rmse, llh) = t
                .metrics
                .map(|m| (m.rmse.to_string(), m.llh.to_string()))
                .unwrap_or(("".into(), "".into()));
            w.row(&[
                t.step.to_string(),
                t.ry.to_string(),
                t.rz.to_string(),
                t.iterations.to_string(),
                t.epochs.to_string(),
                t.solver_secs.to_string(),
                rmse,
                llh,
            ])?;
        }
        w.flush()?;
        igp::info!("telemetry written to {path}");
    }
    Ok(())
}
